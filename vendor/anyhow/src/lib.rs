//! Offline stand-in for the `anyhow` crate: the API subset this
//! workspace uses (`Error`, `Result`, `anyhow!`, `Context`,
//! `Error::msg`, blanket `From<E: std::error::Error>`), with the same
//! formatting conventions — `{}` shows the outermost context, `{:#}`
//! shows the whole chain joined with `": "`.
//!
//! The build image has no registry access, so this path crate keeps the
//! workspace self-contained. Swapping in the real `anyhow` is a one-line
//! change in the root `Cargo.toml`.

use std::fmt;

/// A dynamic error: a root message plus context frames (innermost
/// first in storage, outermost first when displayed).
pub struct Error {
    msg: String,
    /// Context frames, pushed outermost-last.
    context: Vec<String>,
}

/// `anyhow::Result<T>` — `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display + Send + Sync + 'static>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            context: Vec::new(),
        }
    }

    /// Attach an outer context frame.
    pub fn context<C: fmt::Display + Send + Sync + 'static>(mut self, context: C) -> Error {
        self.context.push(context.to_string());
        self
    }

    /// All frames, outermost first (ending with the root message).
    fn chain_strings(&self) -> impl Iterator<Item = &str> {
        self.context
            .iter()
            .rev()
            .map(String::as_str)
            .chain(std::iter::once(self.msg.as_str()))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, outermost context first.
            let mut first = true;
            for frame in self.chain_strings() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{frame}")?;
                first = false;
            }
            Ok(())
        } else {
            // `{}`: the outermost frame only.
            write!(f, "{}", self.context.last().unwrap_or(&self.msg))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.context.last() {
            None => write!(f, "{}", self.msg),
            Some(outer) => {
                write!(f, "{outer}")?;
                write!(f, "\n\nCaused by:")?;
                for frame in self.context.iter().rev().skip(1) {
                    write!(f, "\n    {frame}")?;
                }
                write!(f, "\n    {}", self.msg)
            }
        }
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e.to_string())
    }
}

/// Context-attachment extension for `Result` (both foreign error types
/// and `anyhow::Error` itself, mirroring the real crate).
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a message, a displayable value, or
/// format arguments.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_shows_outermost_context() {
        let e: Error = Error::from(io_err()).context("opening file");
        assert_eq!(format!("{e}"), "opening file");
    }

    #[test]
    fn alternate_shows_chain() {
        let e: Error = Error::from(io_err()).context("opening file").context("loading config");
        assert_eq!(format!("{e:#}"), "loading config: opening file: gone");
    }

    #[test]
    fn context_on_foreign_result() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading meta").unwrap_err();
        assert!(format!("{e:#}").contains("reading meta"));
        assert!(format!("{e:#}").contains("gone"));
    }

    #[test]
    fn with_context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("base {}", 7));
        let e = r.with_context(|| format!("frame {}", 1)).unwrap_err();
        assert_eq!(format!("{e:#}"), "frame 1: base 7");
    }

    #[test]
    fn macro_accepts_displayable_expression() {
        let msg = String::from("already a string");
        let e = anyhow!(msg);
        assert_eq!(format!("{e}"), "already a string");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }
}
