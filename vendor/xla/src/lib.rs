//! Offline stub of the PJRT/XLA binding surface `repro::runtime` uses.
//!
//! The build image has neither the PJRT C library nor the real binding
//! crate, so this stub keeps the crate compiling and fails *at runtime*
//! with a clear message the callers already handle (`ModelRuntime::load`
//! propagates the error; benches and integration tests skip when the
//! runtime is unavailable). Deployments with a real PJRT toolchain swap
//! this path dependency for the actual bindings in the root
//! `Cargo.toml` — the API below mirrors the names they expose.
//!
//! Types that can only be obtained through a failing constructor
//! (`PjRtClient`, executables, buffers, parsed HLO protos) are empty
//! enums: their methods are statically unreachable (`match *self {}`),
//! which documents that no execution path exists in the stub build.

use std::path::Path;

/// Binding-level error (the real crate's error type is also opaque;
/// callers format it with `{:?}`).
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!(
        "{what}: PJRT/XLA backend not available in this build (offline stub — \
         swap vendor/xla for the real bindings to execute artifacts)"
    )))
}

/// PJRT client handle. Never constructible in the stub.
pub enum PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        match *self {}
    }
}

/// Compiled executable handle. Never constructible in the stub.
pub enum PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        match *self {}
    }
}

/// Device buffer handle. Never constructible in the stub.
pub enum PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        match *self {}
    }
}

/// Parsed HLO module proto. Never constructible in the stub.
pub enum HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation built from a parsed proto.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        match *proto {}
    }
}

/// Element dtypes the runtime constructs literals with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
}

/// Host literal. Constructible (arguments are staged host-side before
/// execution), but every conversion fails in the stub.
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal, Error> {
        unavailable("Literal::create_from_shape_and_untyped_data")
    }

    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        unavailable("Literal::to_tuple1")
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal), Error> {
        unavailable("Literal::to_tuple2")
    }

    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal), Error> {
        unavailable("Literal::to_tuple3")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable("Literal::to_vec")
    }

    pub fn get_first_element<T>(&self) -> Result<T, Error> {
        unavailable("Literal::get_first_element")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_cleanly() {
        let err = PjRtClient::cpu().err().expect("stub must not construct");
        assert!(format!("{err:?}").contains("offline stub"));
    }

    #[test]
    fn literal_conversions_fail_cleanly() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.get_first_element::<f32>().is_err());
    }
}
