"""Kernel-vs-oracle correctness: the CORE L1 signal.

Every Pallas kernel must match its pure-jnp oracle (kernels/ref.py) on
exact shapes (pytest params) and randomized shapes/dtypes (hypothesis).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, sgd, wavg

# Float tolerance: interpret-mode Pallas may fuse/reassociate (FMA) the
# arithmetic differently from the jnp oracle.
RTOL, ATOL = 1e-5, 1e-6


def _rand(shape, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


# ---------------------------------------------------------------- wavg ----


@pytest.mark.parametrize("k", [1, 2, 3, 5, 8])
@pytest.mark.parametrize("p,block", [(256, 256), (1000, 256), (65536, 65536), (70000, 65536)])
def test_wavg_matches_ref(k, p, block):
    stacked = _rand((k, p), seed=k * 1000 + p)
    weights = jnp.asarray(np.random.default_rng(p).uniform(0.1, 5.0, size=(k,)).astype(np.float32))
    got = wavg.wavg(stacked, weights, block=block)
    want = ref.wavg_ref(stacked, weights)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_wavg_identity_on_equal_rows():
    """Averaging K identical models returns that model (FedAvg invariant)."""
    row = _rand((512,), seed=7)
    stacked = jnp.stack([row] * 4)
    got = wavg.wavg(stacked, jnp.ones((4,)), block=128)
    np.testing.assert_allclose(got, row, rtol=RTOL, atol=ATOL)


def test_wavg_zero_weight_child_ignored():
    """Zero weight == absent child: used by the runtime's K-padding."""
    a = _rand((300,), seed=1)
    b = _rand((300,), seed=2)
    junk = jnp.full((300,), 1e9, dtype=jnp.float32)
    stacked = jnp.stack([a, b, junk])
    w = jnp.asarray([1.0, 3.0, 0.0], dtype=jnp.float32)
    got = wavg.wavg(stacked, w, block=128)
    want = ref.wavg_ref(jnp.stack([a, b]), w[:2])
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_wavg_weight_normalization_scale_invariant():
    """Scaling all weights by a constant must not change the output."""
    stacked = _rand((3, 400), seed=3)
    w = jnp.asarray([1.0, 2.0, 3.0], dtype=jnp.float32)
    got1 = wavg.wavg(stacked, w, block=128)
    got2 = wavg.wavg(stacked, w * 100.0, block=128)
    np.testing.assert_allclose(got1, got2, rtol=RTOL, atol=ATOL)


def test_wavg_block_size_invariant():
    """The tile width is a perf knob only — outputs must be identical."""
    stacked = _rand((4, 5000), seed=4)
    w = jnp.asarray([1.0, 2.0, 0.5, 0.25], dtype=jnp.float32)
    outs = [wavg.wavg(stacked, w, block=b) for b in (128, 512, 4096, 8192)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=RTOL, atol=ATOL)


@settings(max_examples=40, deadline=None)
@given(
    k=st.integers(min_value=1, max_value=8),
    p=st.integers(min_value=1, max_value=3000),
    block=st.sampled_from([64, 128, 256, 1024]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_wavg_hypothesis_sweep(k, p, block, seed):
    rng = np.random.default_rng(seed)
    stacked = jnp.asarray(rng.normal(size=(k, p)).astype(np.float32))
    weights = jnp.asarray(rng.uniform(0.05, 10.0, size=(k,)).astype(np.float32))
    got = wavg.wavg(stacked, weights, block=block)
    want = ref.wavg_ref(stacked, weights)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wavg_dtypes(dtype):
    stacked = _rand((2, 512), seed=9).astype(dtype)
    w = jnp.asarray([1.0, 1.0], dtype=jnp.float32)
    got = wavg.wavg(stacked, w, block=256)
    want = ref.wavg_ref(stacked, w.astype(dtype))
    assert got.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32),
        np.asarray(want, dtype=np.float32),
        rtol=1e-2 if dtype == jnp.bfloat16 else RTOL,
        atol=1e-2 if dtype == jnp.bfloat16 else ATOL,
    )


def test_wavg_vmem_budget():
    """DESIGN.md §Perf: the default tiling must fit TPU VMEM (~16 MiB)."""
    assert wavg.vmem_bytes(k=8) < 4 * 1024 * 1024  # leaves 4x headroom


# ----------------------------------------------------------------- sgd ----


@pytest.mark.parametrize("p,block", [(128, 128), (777, 128), (65536, 65536), (70000, 65536)])
@pytest.mark.parametrize("lr", [0.0, 0.01, 1.5])
def test_sgd_matches_ref(p, block, lr):
    params = _rand((p,), seed=p)
    grads = _rand((p,), seed=p + 1)
    lr_arr = jnp.asarray([lr], dtype=jnp.float32)
    got = sgd.sgd(params, grads, lr_arr, block=block)
    want = ref.sgd_ref(params, grads, lr_arr)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_sgd_zero_lr_is_identity():
    params = _rand((1000,), seed=11)
    grads = _rand((1000,), seed=12)
    got = sgd.sgd(params, grads, jnp.asarray([0.0], dtype=jnp.float32), block=256)
    np.testing.assert_allclose(got, params, rtol=0, atol=0)


def test_sgd_zero_grad_is_identity():
    params = _rand((1000,), seed=13)
    got = sgd.sgd(params, jnp.zeros((1000,)), jnp.asarray([0.3], dtype=jnp.float32), block=256)
    np.testing.assert_allclose(got, params, rtol=0, atol=0)


@settings(max_examples=40, deadline=None)
@given(
    p=st.integers(min_value=1, max_value=4000),
    block=st.sampled_from([64, 256, 1024]),
    lr=st.floats(min_value=0.0, max_value=2.0, allow_nan=False),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_sgd_hypothesis_sweep(p, block, lr, seed):
    rng = np.random.default_rng(seed)
    params = jnp.asarray(rng.normal(size=(p,)).astype(np.float32))
    grads = jnp.asarray(rng.normal(size=(p,)).astype(np.float32))
    lr_arr = jnp.asarray([lr], dtype=jnp.float32)
    got = sgd.sgd(params, grads, lr_arr, block=block)
    want = ref.sgd_ref(params, grads, lr_arr)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_sgd_composes_with_wavg():
    """One federated micro-round in pure kernels: K local updates then avg."""
    base = _rand((600,), seed=20)
    lr = jnp.asarray([0.05], dtype=jnp.float32)
    locals_ = []
    for i in range(3):
        g = _rand((600,), seed=30 + i)
        locals_.append(sgd.sgd(base, g, lr, block=128))
    stacked = jnp.stack(locals_)
    w = jnp.ones((3,), dtype=jnp.float32)
    got = wavg.wavg(stacked, w, block=128)
    want = ref.wavg_ref(stacked, w)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)
