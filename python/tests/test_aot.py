"""AOT-export tests: every artifact lowers to parseable HLO text and the
lowered modules keep the interface the rust runtime expects."""

import json
import os
import re

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def lowered():
    """Lower everything once (slow-ish) and cache per module."""
    return dict(aot.lower_all())


def test_all_artifacts_present(lowered):
    names = set(lowered)
    assert "init" in names
    assert f"train_step_b{model.TRAIN_BATCH}" in names
    assert f"eval_b{model.EVAL_BATCH}" in names
    for k in aot.AGGREGATE_KS:
        assert f"aggregate_k{k}" in names


def test_hlo_text_has_entry(lowered):
    for name, text in lowered.items():
        assert "ENTRY" in text, name
        assert "HloModule" in text, name


def _entry_section(text: str) -> str:
    """The ENTRY computation body (signature lives on its parameter/ROOT lines)."""
    return text[text.index("ENTRY") :]


def test_train_step_signature(lowered):
    """params/x/y/lr in, (params', loss) tuple out — rust depends on this."""
    entry = _entry_section(lowered[f"train_step_b{model.TRAIN_BATCH}"])
    p = model.PARAM_COUNT
    b = model.TRAIN_BATCH
    assert re.search(rf"f32\[{p}\]\{{0\}} parameter\(0\)", entry)
    assert re.search(rf"f32\[{b},{model.INPUT_DIM}\][^ ]* parameter\(1\)", entry)
    assert re.search(rf"s32\[{b}\]\{{0\}} parameter\(2\)", entry)
    assert re.search(rf"ROOT [^=]+= \(f32\[{p}\]\{{0\}}, f32\[\]\) tuple", entry)


def test_aggregate_signature(lowered):
    p = model.PARAM_COUNT
    for k in aot.AGGREGATE_KS:
        entry = _entry_section(lowered[f"aggregate_k{k}"])
        assert re.search(rf"f32\[{k},{p}\][^ ]* parameter\(0\)", entry), k
        assert re.search(rf"f32\[{k}\]\{{0\}} parameter\(1\)", entry), k


def test_no_mosaic_custom_calls(lowered):
    """interpret=True must hold: a Mosaic custom-call would be unloadable
    by the CPU PJRT client (see /opt/xla-example/README.md)."""
    for name, text in lowered.items():
        assert "tpu_custom_call" not in text, name
        assert "mosaic" not in text.lower(), name


def test_hlo_reparses_via_xla_client(lowered):
    """Round-trip the text through the XLA parser — what rust will do."""
    from jax._src.lib import xla_client as xc

    for name, text in lowered.items():
        mod = xc._xla.hlo_module_from_text(text)
        assert mod is not None, name


def test_meta_json_consistent(tmp_path):
    aot.write_meta(str(tmp_path))
    meta = json.loads((tmp_path / "meta.json").read_text())
    assert meta["param_count"] == model.PARAM_COUNT
    assert meta["train_batch"] == model.TRAIN_BATCH
    assert sorted(int(k) for k in meta["artifacts"]["aggregate"]) == sorted(aot.AGGREGATE_KS)
    # layer bookkeeping must reproduce the param count
    assert sum(i * o + o for i, o in meta["layers"]) == meta["param_count"]
