"""Momentum kernel vs oracle + model-level momentum training."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import momentum, ref

RTOL, ATOL = 1e-5, 1e-6


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@pytest.mark.parametrize("p,block", [(128, 128), (777, 128), (70000, 65536)])
@pytest.mark.parametrize("lr,mu", [(0.1, 0.9), (0.01, 0.0), (1.0, 0.5)])
def test_momentum_matches_ref(p, block, lr, mu):
    params = _rand((p,), 1)
    grads = _rand((p,), 2)
    velocity = _rand((p,), 3)
    lr_mu = jnp.asarray([lr, mu], dtype=jnp.float32)
    got_p, got_v = momentum.momentum(params, grads, velocity, lr_mu, block=block)
    want_p, want_v = ref.momentum_ref(params, grads, velocity, lr_mu)
    np.testing.assert_allclose(got_p, want_p, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(got_v, want_v, rtol=RTOL, atol=ATOL)


def test_momentum_zero_mu_equals_sgd():
    """mu = 0 reduces heavy-ball to plain SGD."""
    params = _rand((1000,), 4)
    grads = _rand((1000,), 5)
    velocity = _rand((1000,), 6)
    lr_mu = jnp.asarray([0.3, 0.0], dtype=jnp.float32)
    got_p, got_v = momentum.momentum(params, grads, velocity, lr_mu, block=256)
    np.testing.assert_allclose(got_p, ref.sgd_ref(params, grads, lr_mu[:1]), rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(got_v, grads, rtol=RTOL, atol=ATOL)


def test_momentum_accumulates_velocity():
    """Repeated identical gradients build velocity toward g/(1-mu)."""
    p = jnp.zeros((64,), dtype=jnp.float32)
    g = jnp.ones((64,), dtype=jnp.float32)
    v = jnp.zeros((64,), dtype=jnp.float32)
    lr_mu = jnp.asarray([0.0, 0.5], dtype=jnp.float32)  # lr 0: watch v only
    for _ in range(20):
        p, v = momentum.momentum(p, g, v, lr_mu, block=64)
    np.testing.assert_allclose(v, jnp.full((64,), 2.0), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    p=st.integers(min_value=1, max_value=3000),
    block=st.sampled_from([64, 256, 1024]),
    lr=st.floats(min_value=0.0, max_value=1.0),
    mu=st.floats(min_value=0.0, max_value=0.99),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_momentum_hypothesis_sweep(p, block, lr, mu, seed):
    rng = np.random.default_rng(seed)
    params = jnp.asarray(rng.normal(size=(p,)).astype(np.float32))
    grads = jnp.asarray(rng.normal(size=(p,)).astype(np.float32))
    velocity = jnp.asarray(rng.normal(size=(p,)).astype(np.float32))
    lr_mu = jnp.asarray([lr, mu], dtype=jnp.float32)
    got_p, got_v = momentum.momentum(params, grads, velocity, lr_mu, block=block)
    want_p, want_v = ref.momentum_ref(params, grads, velocity, lr_mu)
    np.testing.assert_allclose(got_p, want_p, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(got_v, want_v, rtol=1e-4, atol=1e-5)


def test_model_momentum_training_descends():
    """Full-model check: momentum training reduces loss on a fixed batch
    at least as fast as plain SGD over a few steps."""
    key = jnp.asarray([0, 42], dtype=jnp.uint32)
    params = model.init_params(key)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(model.TRAIN_BATCH, model.INPUT_DIM)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 10, size=(model.TRAIN_BATCH,)).astype(np.int32))
    lr_mu = jnp.asarray([0.05, 0.9], dtype=jnp.float32)
    v = jnp.zeros_like(params)
    p = params
    losses = []
    for _ in range(5):
        p, v, loss = model.train_step_momentum(p, v, x, y, lr_mu)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5, losses
