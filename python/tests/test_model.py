"""L2 model-graph tests: shapes, training signal, aggregation semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

KEY = jnp.asarray([0, 42], dtype=jnp.uint32)


@pytest.fixture(scope="module")
def params():
    return model.init_params(KEY)


def _batch(b, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, model.INPUT_DIM)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, model.NUM_CLASSES, size=(b,)).astype(np.int32))
    return x, y


def test_param_count_matches_paper(params):
    """The paper's docker model is 'about 1.8 million parameters'."""
    assert model.PARAM_COUNT == 1_863_690
    assert params.shape == (model.PARAM_COUNT,)


def test_flatten_unflatten_roundtrip(params):
    layers = model.unflatten(params)
    assert [tuple(w.shape) for w, _ in layers] == [(i, o) for i, o in model.LAYERS]
    back = model.flatten(layers)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(params))


def test_init_deterministic():
    a = model.init_params(KEY)
    b = model.init_params(KEY)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_init_seed_sensitivity():
    a = model.init_params(KEY)
    b = model.init_params(jnp.asarray([1, 43], dtype=jnp.uint32))
    assert not np.allclose(np.asarray(a), np.asarray(b))


def test_forward_shape(params):
    x, _ = _batch(model.TRAIN_BATCH)
    logits = model.forward(params, x)
    assert logits.shape == (model.TRAIN_BATCH, model.NUM_CLASSES)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_initial_loss_near_log_c(params):
    """Random init ⇒ CE loss ≈ ln(10); catches broken init scales."""
    x, y = _batch(model.EVAL_BATCH, seed=5)
    loss, acc = model.evaluate(params, x, y)
    assert abs(float(loss) - np.log(model.NUM_CLASSES)) < 1.0
    assert 0.0 <= float(acc) <= 1.0


def test_train_step_reduces_loss(params):
    """A few steps on a fixed batch must descend — the core training signal."""
    x, y = _batch(model.TRAIN_BATCH, seed=1)
    lr = jnp.asarray([0.1], dtype=jnp.float32)
    p, loss0 = model.train_step(params, x, y, lr)
    losses = [float(loss0)]
    for _ in range(4):
        p, loss = model.train_step(p, x, y, lr)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5, losses


def test_train_step_zero_lr_keeps_params(params):
    x, y = _batch(model.TRAIN_BATCH, seed=2)
    p, _ = model.train_step(params, x, y, jnp.asarray([0.0], dtype=jnp.float32))
    np.testing.assert_array_equal(np.asarray(p), np.asarray(params))


def test_aggregate_identity(params):
    stacked = jnp.stack([params, params, params])
    out = model.aggregate(stacked, jnp.ones((3,), dtype=jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(params), rtol=1e-5, atol=1e-6)


def test_aggregate_midpoint(params):
    """avg(p, p + 2d) == p + d."""
    d = jnp.ones_like(params) * 0.25
    stacked = jnp.stack([params, params + 2 * d])
    out = model.aggregate(stacked, jnp.ones((2,), dtype=jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(params + d), rtol=1e-4, atol=1e-5)


def test_federated_round_improves_over_init(params):
    """Mini FedAvg round: 3 trainers on disjoint batches, aggregate, eval.

    The aggregated model must beat the initial model on the union data —
    the end-to-end semantic the rust coordinator depends on.
    """
    lr = jnp.asarray([0.1], dtype=jnp.float32)
    locals_ = []
    for i in range(3):
        x, y = _batch(model.TRAIN_BATCH, seed=10 + i)
        p = params
        for _ in range(3):
            p, _ = model.train_step(p, x, y, lr)
        locals_.append(p)
    agg = model.aggregate(jnp.stack(locals_), jnp.ones((3,), dtype=jnp.float32))

    xs, ys = zip(*[_batch(model.TRAIN_BATCH, seed=10 + i) for i in range(3)])
    x_all, y_all = jnp.concatenate(xs), jnp.concatenate(ys)
    loss_init = model.loss_fn(params, x_all, y_all)
    loss_agg = model.loss_fn(agg, x_all, y_all)
    assert float(loss_agg) < float(loss_init)
