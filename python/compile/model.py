"""L2: the paper's FL workload as JAX compute graphs over a flat param vector.

The docker evaluation in §IV.C of the paper trains a multi-layer
perceptron with ~1.8 M parameters. We reproduce it exactly as
784 → 1024 → 1024 → 10 (1,863,690 parameters) with ReLU activations and
softmax cross-entropy, expressed over a single flat f32 vector so the
rust side (L3) only ever moves one opaque [P] buffer per model.

Graphs exported by aot.py (all shapes static, HLO-text interchange):
  init_params(key)                     -> params [P]
  train_step(params, x, y, lr)         -> (params', loss)   (B = TRAIN_BATCH)
  evaluate(params, x, y)               -> (loss, accuracy)  (B = EVAL_BATCH)
  aggregate(stacked [K,P], weights[K]) -> params [P]         (per-K variants)

`train_step` calls the L1 Pallas SGD kernel for its update epilogue and
`aggregate` is a thin wrapper over the L1 Pallas weighted-average kernel,
so both kernels lower into the same HLO modules the rust runtime loads.
"""

from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import momentum as momentum_kernel
from .kernels import sgd as sgd_kernel
from .kernels import wavg as wavg_kernel

# (fan_in, fan_out) per dense layer — the paper's ~1.8M-param MLP.
LAYERS: List[Tuple[int, int]] = [(784, 1024), (1024, 1024), (1024, 10)]
INPUT_DIM = LAYERS[0][0]
NUM_CLASSES = LAYERS[-1][1]
PARAM_COUNT = sum(i * o + o for i, o in LAYERS)  # 1,863,690

TRAIN_BATCH = 32
EVAL_BATCH = 256


def unflatten(flat: jnp.ndarray) -> List[Tuple[jnp.ndarray, jnp.ndarray]]:
    """Split the flat [P] vector into per-layer (W [in,out], b [out]) views.

    Layout: [W1, b1, W2, b2, W3, b3] — fixed and shared with the rust side
    (rust never needs it, but artifacts/meta.json records it for tooling).
    """
    out = []
    off = 0
    for fan_in, fan_out in LAYERS:
        w = flat[off : off + fan_in * fan_out].reshape(fan_in, fan_out)
        off += fan_in * fan_out
        b = flat[off : off + fan_out]
        off += fan_out
        out.append((w, b))
    return out


def flatten(layers: List[Tuple[jnp.ndarray, jnp.ndarray]]) -> jnp.ndarray:
    """Inverse of `unflatten`."""
    parts = []
    for w, b in layers:
        parts.append(w.reshape(-1))
        parts.append(b)
    return jnp.concatenate(parts)


def forward(flat: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """MLP forward pass: ReLU hidden layers, linear head. Returns logits [B, C].

    Matmuls stay in plain jnp: XLA already fuses bias+ReLU into the GEMM
    epilogue and (on TPU) maps them to the MXU — see DESIGN.md
    §Hardware-Adaptation for why only the bandwidth-bound pieces are
    Pallas kernels.
    """
    h = x
    layers = unflatten(flat)
    for i, (w, b) in enumerate(layers):
        h = h @ w + b
        if i + 1 < len(layers):
            h = jax.nn.relu(h)
    return h


def loss_fn(flat: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy. y is int32 class ids [B]."""
    logits = forward(flat, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=1))


def train_step(
    flat: jnp.ndarray,
    x: jnp.ndarray,
    y: jnp.ndarray,
    lr: jnp.ndarray,
    *,
    block: int = sgd_kernel.DEFAULT_BLOCK,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One local SGD step: value_and_grad + Pallas SGD epilogue.

    `block` is the Pallas tile width (perf knob — see aot.artifact_block).
    Returns (new_params [P], loss []).
    """
    loss, grads = jax.value_and_grad(loss_fn)(flat, x, y)
    new_flat = sgd_kernel.sgd(flat, grads, lr, block=block)
    return new_flat, loss


def train_step_momentum(
    flat: jnp.ndarray,
    velocity: jnp.ndarray,
    x: jnp.ndarray,
    y: jnp.ndarray,
    lr_mu: jnp.ndarray,
    *,
    block: int = momentum_kernel.DEFAULT_BLOCK,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One local heavy-ball step (optional trainer optimizer).

    Returns (new_params [P], new_velocity [P], loss []).
    """
    loss, grads = jax.value_and_grad(loss_fn)(flat, x, y)
    new_flat, new_v = momentum_kernel.momentum(flat, grads, velocity, lr_mu, block=block)
    return new_flat, new_v, loss


def init_params(key: jnp.ndarray) -> jnp.ndarray:
    """He-initialized flat parameter vector from a threefry key ([2] u32).

    Runs inside the AOT artifact so every node derives its model from a
    seed rather than shipping 7.5 MB of initial weights around.
    """
    k = jax.random.wrap_key_data(key.astype(jnp.uint32), impl="threefry2x32")
    layers = []
    for fan_in, fan_out in LAYERS:
        k, sub = jax.random.split(k)
        scale = jnp.sqrt(2.0 / fan_in).astype(jnp.float32)
        w = jax.random.normal(sub, (fan_in, fan_out), dtype=jnp.float32) * scale
        b = jnp.zeros((fan_out,), dtype=jnp.float32)
        layers.append((w, b))
    return flatten(layers)


def evaluate(
    flat: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Eval pass: returns (mean CE loss [], accuracy [])."""
    logits = forward(flat, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=1))
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return loss, acc


def aggregate(
    stacked: jnp.ndarray,
    weights: jnp.ndarray,
    *,
    block: int = wavg_kernel.DEFAULT_BLOCK,
) -> jnp.ndarray:
    """FedAvg over K child models — delegates to the L1 Pallas kernel."""
    return wavg_kernel.wavg(stacked, weights, block=block)
