"""Build-time Python package: JAX model (L2) + Pallas kernels (L1) + AOT export.

Nothing in here runs on the request path — `aot.py` lowers everything to
HLO text once (`make artifacts`), and the rust coordinator executes the
artifacts via PJRT.
"""
