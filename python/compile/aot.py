"""AOT export: lower every L2 graph to HLO *text* under artifacts/.

Run via `make artifacts` (or `cd python && python -m compile.aot`).
Python's job ends here — the rust coordinator loads these files through
`HloModuleProto::from_text_file` and executes them on the PJRT CPU
client (see rust/src/runtime/).

Interchange is HLO TEXT, not `.serialize()`: jax ≥ 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.
"""

import argparse
import functools
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# K values (child-count fan-ins) for which we export aggregate variants.
# One compiled executable per model variant; the coordinator picks the
# smallest K' >= K and zero-pads weights (zero weight == absent child).
AGGREGATE_KS = [2, 3, 4, 5, 8]

# Tile width for the Pallas kernels in the *exported* artifacts.
#
# DESIGN.md §Perf: the TPU-shaped default (64 Ki, kernels/wavg.py) keeps
# the VMEM working set ≈2.3 MiB — that is what the structural tests
# enforce. The CPU PJRT client, however, executes interpret-mode Pallas
# as an HLO while-loop whose per-step dynamic-update-slice copies the
# output buffer, so many small steps cost far more than one big one.
# Artifacts therefore default to a single-tile export (block = padded P)
# on CPU; override with REPRO_AGG_BLOCK for TPU-shaped artifacts.
def artifact_block() -> int:
    env = os.environ.get("REPRO_AGG_BLOCK")
    if env:
        return int(env)
    # Single tile covering the padded parameter vector.
    p = model.PARAM_COUNT
    base = 64 * 1024
    return ((p + base - 1) // base) * base


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_all():
    """Yield (name, hlo_text) for every artifact."""
    p = model.PARAM_COUNT
    f32, i32, u32 = jnp.float32, jnp.int32, jnp.uint32
    block = artifact_block()

    yield "init", to_hlo_text(jax.jit(model.init_params).lower(_spec((2,), u32)))

    train = functools.partial(model.train_step, block=block)
    yield (
        f"train_step_b{model.TRAIN_BATCH}",
        to_hlo_text(
            jax.jit(train).lower(
                _spec((p,), f32),
                _spec((model.TRAIN_BATCH, model.INPUT_DIM), f32),
                _spec((model.TRAIN_BATCH,), i32),
                _spec((1,), f32),
            )
        ),
    )

    yield (
        f"eval_b{model.EVAL_BATCH}",
        to_hlo_text(
            jax.jit(model.evaluate).lower(
                _spec((p,), f32),
                _spec((model.EVAL_BATCH, model.INPUT_DIM), f32),
                _spec((model.EVAL_BATCH,), i32),
            )
        ),
    )

    train_m = functools.partial(model.train_step_momentum, block=block)
    yield (
        f"train_step_momentum_b{model.TRAIN_BATCH}",
        to_hlo_text(
            jax.jit(train_m).lower(
                _spec((p,), f32),
                _spec((p,), f32),
                _spec((model.TRAIN_BATCH, model.INPUT_DIM), f32),
                _spec((model.TRAIN_BATCH,), i32),
                _spec((2,), f32),
            )
        ),
    )

    agg = functools.partial(model.aggregate, block=block)
    for k in AGGREGATE_KS:
        yield (
            f"aggregate_k{k}",
            to_hlo_text(jax.jit(agg).lower(_spec((k, p), f32), _spec((k,), f32))),
        )


def write_meta(out_dir: str) -> None:
    """artifacts/meta.json — everything the rust side needs to know."""
    meta = {
        "param_count": model.PARAM_COUNT,
        "layers": model.LAYERS,
        "input_dim": model.INPUT_DIM,
        "num_classes": model.NUM_CLASSES,
        "train_batch": model.TRAIN_BATCH,
        "eval_batch": model.EVAL_BATCH,
        "aggregate_ks": AGGREGATE_KS,
        "pallas_block": artifact_block(),
        "artifacts": {
            "init": "init.hlo.txt",
            "train_step": f"train_step_b{model.TRAIN_BATCH}.hlo.txt",
            "train_step_momentum": f"train_step_momentum_b{model.TRAIN_BATCH}.hlo.txt",
            "eval": f"eval_b{model.EVAL_BATCH}.hlo.txt",
            "aggregate": {str(k): f"aggregate_k{k}.hlo.txt" for k in AGGREGATE_KS},
        },
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output dir")
    args = ap.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    total = 0
    for name, text in lower_all():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        total += len(text)
        print(f"wrote {len(text):>9} chars  {path}")
    write_meta(args.out_dir)
    # Stamp file: the Makefile's freshness check target.
    with open(os.path.join(args.out_dir, ".stamp"), "w") as f:
        f.write("ok\n")
    print(f"total {total} chars, meta.json written to {args.out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
