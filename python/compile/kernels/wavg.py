"""Pallas weighted-average aggregation kernel — the FL aggregation hot-spot.

The SDFL aggregator's job each round is FedAvg over the K child models it
received: out = sum_k (w_k / sum w) * params_k, with params_k a flat
[P]-vector (P ≈ 1.86 M for the paper's MLP).

TPU shaping (DESIGN.md §Hardware-Adaptation): the reduction is tiled with
a 1-D grid over the parameter axis. Each grid step streams one
(K × BLOCK) tile HBM→VMEM, reduces it on the VPU, and writes one
[BLOCK] tile back — a single HBM pass per element, VMEM footprint
(K+1)·BLOCK·4 B (≈2.3 MiB at K=8, BLOCK=64 Ki), leaving headroom for the
pipeliner to double-buffer. No MXU use: this kernel is bandwidth-bound,
its roofline is HBM bandwidth, and that is what EXPERIMENTS.md §Perf
estimates against.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO (see /opt/xla-example).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile width along the parameter axis. 64 Ki f32 = 256 KiB per
# input row; with K ≤ 8 the working set stays well under the ~16 MiB VMEM
# budget of a TPU core while amortizing grid overhead.
DEFAULT_BLOCK = 64 * 1024


def _wavg_kernel(w_ref, x_ref, o_ref):
    """One grid step: o[BLOCK] = sum_k w[k] * x[k, BLOCK].

    `w` arrives pre-normalized (see `wavg`) so the kernel itself is a pure
    weighted reduction — keeping the normalization out of the inner loop
    avoids re-dividing per tile.
    """
    # [K, 1] * [K, BLOCK] -> reduce K -> [BLOCK]
    o_ref[...] = jnp.sum(w_ref[...][:, None] * x_ref[...], axis=0)


def _pad_to_multiple(x: jnp.ndarray, block: int, axis: int) -> jnp.ndarray:
    size = x.shape[axis]
    rem = size % block
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, block - rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("block",))
def wavg(stacked: jnp.ndarray, weights: jnp.ndarray, *, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Weighted average of K stacked flat vectors via the Pallas kernel.

    Args:
      stacked: [K, P] child parameter vectors.
      weights: [K] raw weights (normalized internally, FedAvg-style).
      block:   tile width along P; P is zero-padded up to a multiple.

    Returns:
      [P] aggregated parameter vector. Matches `ref.wavg_ref` exactly up
      to float addition-order tolerance.
    """
    k, p = stacked.shape
    w = (weights / jnp.sum(weights)).astype(stacked.dtype)
    padded = _pad_to_multiple(stacked, block, axis=1)
    p_pad = padded.shape[1]
    grid = (p_pad // block,)
    out = pl.pallas_call(
        _wavg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k,), lambda i: (0,)),  # weights: whole vector each step
            pl.BlockSpec((k, block), lambda i: (0, i)),  # one (K, BLOCK) tile
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p_pad,), stacked.dtype),
        interpret=True,
    )(w, padded)
    return out[:p]


def vmem_bytes(k: int, block: int = DEFAULT_BLOCK, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set of one grid step (inputs + output tile).

    Used by python/tests and DESIGN.md §Perf to assert the kernel's tiling
    stays inside the TPU VMEM budget — the only perf signal interpret mode
    can give us (wall-clock under interpret is CPU-numpy, not a TPU proxy).
    """
    return (k * block + k + block) * dtype_bytes
