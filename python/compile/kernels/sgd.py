"""Pallas SGD parameter-update kernel.

The trainer-side hot loop applies `params - lr * grads` over the flat
[P]-vector every local step. Same tiling discipline as `wavg`: 1-D grid
over P, one HBM pass per element, 2·BLOCK·4 B ≈ 512 KiB VMEM per step at
the default tile — trivially double-bufferable. Bandwidth-bound; no MXU.

interpret=True so the kernel lowers to plain HLO for the CPU PJRT client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 64 * 1024


def _sgd_kernel(lr_ref, p_ref, g_ref, o_ref):
    """One grid step: o[BLOCK] = p[BLOCK] - lr * g[BLOCK]."""
    o_ref[...] = p_ref[...] - lr_ref[0] * g_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def sgd(params: jnp.ndarray, grads: jnp.ndarray, lr: jnp.ndarray, *, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """SGD update over a flat parameter vector via the Pallas kernel.

    Args:
      params: [P] flat parameters.
      grads:  [P] flat gradients.
      lr:     [1] learning rate (runtime input, not a baked constant).
      block:  tile width along P; P is zero-padded up to a multiple.

    Returns:
      [P] updated parameters; matches `ref.sgd_ref`.
    """
    (p,) = params.shape
    rem = p % block
    if rem != 0:
        pad = block - rem
        params = jnp.pad(params, (0, pad))
        grads = jnp.pad(grads, (0, pad))
    p_pad = params.shape[0]
    grid = (p_pad // block,)
    out = pl.pallas_call(
        _sgd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # lr scalar, broadcast to all steps
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p_pad,), params.dtype),
        interpret=True,
    )(lr.astype(params.dtype), params, grads)
    return out[:p]
