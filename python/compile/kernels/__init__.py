"""L1: Pallas kernels for the SDFL hot-spots (aggregation, SGD update).

Each kernel ships with a pure-jnp oracle in `ref.py`; pytest + hypothesis
enforce equivalence before anything is AOT-exported.
"""

from . import momentum, ref, sgd, wavg  # noqa: F401
