"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness references: every Pallas kernel in this
package must match its oracle to float tolerance under pytest (exact
shapes) and hypothesis (randomized shapes/dtypes). The oracles are also
what DESIGN.md §Perf compares lowered-HLO op counts against.
"""

import jax.numpy as jnp


def wavg_ref(stacked: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """Weighted average of K stacked flat parameter vectors.

    Args:
      stacked: [K, P] — one row per child model.
      weights: [K]    — raw (unnormalized) aggregation weights, e.g.
               per-child sample counts for FedAvg.

    Returns:
      [P] — sum_k (w_k / sum(w)) * stacked[k].
    """
    w = weights / jnp.sum(weights)
    return jnp.sum(w[:, None] * stacked, axis=0)


def momentum_ref(
    params: jnp.ndarray,
    grads: jnp.ndarray,
    velocity: jnp.ndarray,
    lr_mu: jnp.ndarray,
):
    """Heavy-ball momentum oracle.

    Args:
      params:   [P] flat parameters.
      grads:    [P] flat gradients.
      velocity: [P] momentum buffer.
      lr_mu:    [2] (learning rate, momentum coefficient mu).

    Returns:
      (params - lr * v', v') with v' = mu * velocity + grads.
    """
    v_new = lr_mu[1] * velocity + grads
    return params - lr_mu[0] * v_new, v_new


def sgd_ref(params: jnp.ndarray, grads: jnp.ndarray, lr: jnp.ndarray) -> jnp.ndarray:
    """Plain SGD update over a flat parameter vector.

    Args:
      params: [P] flat parameters.
      grads:  [P] flat gradients.
      lr:     [1] learning rate (kept as an array so it stays a runtime
              input of the AOT artifact rather than a baked constant).

    Returns:
      [P] — params - lr * grads.
    """
    return params - lr[0] * grads
