"""Pallas heavy-ball momentum update kernel (multi-output).

Classic SGD-with-momentum over the flat parameter vector:

    v' = mu * v + g
    p' = p - lr * v'

Exercises the multi-output Pallas path (two refs written per tile) with
the same 1-D streaming discipline as `sgd`/`wavg`: one HBM pass,
3·BLOCK·4 B input + 2·BLOCK·4 B output VMEM per step. interpret=True for
the CPU PJRT client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 64 * 1024


def _momentum_kernel(scal_ref, p_ref, g_ref, v_ref, po_ref, vo_ref):
    """One grid step: vo = mu*v + g; po = p - lr*vo."""
    lr = scal_ref[0]
    mu = scal_ref[1]
    v_new = mu * v_ref[...] + g_ref[...]
    vo_ref[...] = v_new
    po_ref[...] = p_ref[...] - lr * v_new


@functools.partial(jax.jit, static_argnames=("block",))
def momentum(
    params: jnp.ndarray,
    grads: jnp.ndarray,
    velocity: jnp.ndarray,
    lr_mu: jnp.ndarray,
    *,
    block: int = DEFAULT_BLOCK,
):
    """Momentum update via the Pallas kernel.

    Args:
      params:   [P] flat parameters.
      grads:    [P] flat gradients.
      velocity: [P] momentum buffer.
      lr_mu:    [2] (learning rate, momentum coefficient).
      block:    tile width (P zero-padded to a multiple).

    Returns:
      (new_params [P], new_velocity [P]) — matches `ref.momentum_ref`.
    """
    (p,) = params.shape
    rem = p % block
    if rem != 0:
        pad = block - rem
        params = jnp.pad(params, (0, pad))
        grads = jnp.pad(grads, (0, pad))
        velocity = jnp.pad(velocity, (0, pad))
    p_pad = params.shape[0]
    grid = (p_pad // block,)
    new_p, new_v = pl.pallas_call(
        _momentum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),  # (lr, mu), broadcast
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p_pad,), params.dtype),
            jax.ShapeDtypeStruct((p_pad,), params.dtype),
        ],
        interpret=True,
    )(lr_mu.astype(params.dtype), params, grads, velocity)
    return new_p[:p], new_v[:p]
