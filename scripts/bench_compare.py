#!/usr/bin/env python3
"""Compare a fresh BENCH_eval.json run against the committed baseline.

Perf gate (ROADMAP item 5): prints a per-case evals/sec comparison and
flags every case slower than the baseline by more than its tolerance
band. Two schema versions are accepted:

  version 1  results have no "threads" field; every case ran serially
             and is treated as threads=1.
  version 2+ every result carries "threads" (the worker count used by
             that case — 1 for the serial oracles, N for "sharded").

Cases are keyed by (case, threads) and compared strictly like-for-like:
a sharded case measured at 4 threads is never compared against a run of
the same case at a different worker count (that delta would measure the
machine, not the code). Mismatched thread counts are reported as
informational notes.

Tolerance bands are per-case, derived from the baseline's own noise:

    band = clamp(3 * std_us / mean_us_per_batch, 0.10, 0.50)

i.e. three standard deviations of the baseline's batch-time jitter,
clamped to [10%, 50%]. Cases whose baseline lacks std_us/mean_us fall
back to --threshold (default 25%).

The committed baseline may carry "provisional": true, meaning its
numbers were not measured on the CI hardware class yet. Against a
provisional baseline, regressions emit ::notice:: annotations and the
exit code stays 0. Once the provisional flag is dropped the gate is
hard: regressions emit ::warning:: annotations and the script exits 1.
Refresh the baseline with:

    cargo run --release -- bench --suite eval --samples 3 --warmup 1 \
        --batch 8 --out BENCH_baseline_ci.json

Usage: bench_compare.py CURRENT.json BASELINE.json [--threshold 0.25]
"""

import argparse
import json
import sys

BAND_MIN, BAND_MAX = 0.10, 0.50


def load_results(path):
    """Return (doc, {(case, threads): result-dict}) or exit with a message."""
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        sys.exit(f"bench_compare: cannot read {path}: {e}")
    if doc.get("suite") != "eval" or not isinstance(doc.get("results"), list):
        sys.exit(f"bench_compare: {path} is not a BENCH eval document")
    version = doc.get("version", 1)
    if not isinstance(version, int) or version < 1:
        sys.exit(f"bench_compare: {path}: bad document version {version!r}")
    by_key = {}
    for r in doc["results"]:
        case, eps = r.get("case"), r.get("evals_per_sec")
        if not isinstance(case, str) or not isinstance(eps, (int, float)) or eps <= 0:
            sys.exit(f"bench_compare: {path}: malformed result entry {r!r}")
        threads = r.get("threads", 1 if version < 2 else None)
        if not isinstance(threads, int) or threads < 1:
            sys.exit(
                f"bench_compare: {path}: version {version} result {case!r} "
                f"needs an integer threads >= 1, got {threads!r}"
            )
        key = (case, threads)
        if key in by_key:
            sys.exit(f"bench_compare: {path}: duplicate result for {key}")
        by_key[key] = r
    if not by_key:
        sys.exit(f"bench_compare: {path} has no results")
    return doc, by_key


def tolerance(entry, fallback):
    """Per-case band from the baseline's own batch-time noise."""
    std, mean = entry.get("std_us"), entry.get("mean_us_per_batch")
    if (
        isinstance(std, (int, float))
        and isinstance(mean, (int, float))
        and std >= 0
        and mean > 0
    ):
        return min(BAND_MAX, max(BAND_MIN, 3.0 * std / mean))
    return fallback


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current")
    ap.add_argument("baseline")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="fallback band for cases whose baseline has no std_us")
    opts = ap.parse_args()

    _, current = load_results(opts.current)
    base_doc, baseline = load_results(opts.baseline)
    provisional = bool(base_doc.get("provisional"))
    annotate = "::notice::" if provisional else "::warning::"

    if provisional:
        print("note: the baseline is PROVISIONAL (not measured on this "
              "hardware class); deltas below are informational only")

    cur_cases = {c for c, _ in current}
    regressions = 0
    print(f"{'case':<30} {'thr':>3} {'baseline/s':>13} {'current/s':>13} "
          f"{'delta':>8} {'band':>6}")
    for case, threads in sorted(baseline):
        entry = baseline[(case, threads)]
        base = float(entry["evals_per_sec"])
        band = tolerance(entry, opts.threshold)
        if (case, threads) not in current:
            if case in cur_cases:
                print(f"note: case {case} present only at a different thread "
                      f"count in {opts.current}; skipping (not like-for-like)")
            else:
                print(f"{annotate}bench case {case} (threads={threads}) "
                      f"missing from {opts.current}")
            continue
        cur = float(current[(case, threads)]["evals_per_sec"])
        delta = cur / base - 1.0
        flag = ""
        if delta < -band:
            regressions += 1
            flag = "  <-- regression"
            print(f"{annotate}{case} (threads={threads}): evals/sec fell "
                  f"{-delta:.0%} ({base:.3g} -> {cur:.3g}, band {band:.0%})")
        print(f"{case:<30} {threads:>3} {base:>13.3g} {cur:>13.3g} "
              f"{delta:>+7.1%} {band:>6.0%}{flag}")
    for case, threads in sorted(set(current) - set(baseline)):
        print(f"note: new case {case} (threads={threads}) not in baseline "
              f"({float(current[(case, threads)]['evals_per_sec']):.3g}/s)")

    if regressions:
        if provisional:
            print(f"bench_compare: {regressions} case(s) past their band "
                  f"(notices only: baseline is provisional, exit 0)")
        else:
            sys.exit(f"bench_compare: {regressions} case(s) past their band "
                     f"against a non-provisional baseline")
    else:
        print("bench_compare: no case past its tolerance band")


if __name__ == "__main__":
    main()
