#!/usr/bin/env python3
"""Compare a fresh BENCH_eval.json run against the committed baseline.

Warn-only perf gate (ROADMAP item 5, first cut): prints a per-case
evals/sec comparison and emits a GitHub Actions annotation for every
case slower than the baseline by more than --threshold (default 25%).
The exit code is 0 unless an input file is missing or malformed — a
regression warns, it does not fail the build.

The committed baseline may carry "provisional": true, meaning its
numbers were not measured on the CI hardware class yet. Deltas against
a provisional baseline are reported as notices instead of warnings;
refresh it with:

    cargo run --release -- bench --suite eval --out BENCH_baseline_ci.json
    # then strip nothing — the artifact is committed as-is

Usage: bench_compare.py CURRENT.json BASELINE.json [--threshold 0.25]
"""

import argparse
import json
import sys


def load_results(path):
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        sys.exit(f"bench_compare: cannot read {path}: {e}")
    if doc.get("suite") != "eval" or not isinstance(doc.get("results"), list):
        sys.exit(f"bench_compare: {path} is not a BENCH eval document")
    by_case = {}
    for r in doc["results"]:
        case, eps = r.get("case"), r.get("evals_per_sec")
        if not isinstance(case, str) or not isinstance(eps, (int, float)) or eps <= 0:
            sys.exit(f"bench_compare: {path}: malformed result entry {r!r}")
        by_case[case] = float(eps)
    if not by_case:
        sys.exit(f"bench_compare: {path} has no results")
    return doc, by_case


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current")
    ap.add_argument("baseline")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="relative evals/sec drop that triggers a warning")
    opts = ap.parse_args()

    _, current = load_results(opts.current)
    base_doc, baseline = load_results(opts.baseline)
    provisional = bool(base_doc.get("provisional"))
    annotate = "::notice::" if provisional else "::warning::"

    if provisional:
        print("note: the baseline is PROVISIONAL (not measured on this "
              "hardware class); deltas below are informational only")

    regressions = 0
    print(f"{'case':<28} {'baseline/s':>14} {'current/s':>14} {'delta':>8}")
    for case in sorted(baseline):
        if case not in current:
            print(f"{annotate}bench case {case} missing from {opts.current}")
            continue
        base, cur = baseline[case], current[case]
        delta = cur / base - 1.0
        flag = ""
        if delta < -opts.threshold:
            regressions += 1
            flag = "  <-- regression"
            print(f"{annotate}{case}: evals/sec fell {-delta:.0%} "
                  f"({base:.3g} -> {cur:.3g}, threshold {opts.threshold:.0%})")
        print(f"{case:<28} {base:>14.3g} {cur:>14.3g} {delta:>+7.1%}{flag}")
    for case in sorted(set(current) - set(baseline)):
        print(f"note: new case {case} not in baseline ({current[case]:.3g}/s)")

    if regressions:
        kind = "notice(s)" if provisional else "warning(s)"
        print(f"bench_compare: {regressions} case(s) past the "
              f"{opts.threshold:.0%} threshold ({kind} emitted, exit 0)")
    else:
        print("bench_compare: no case past the threshold")


if __name__ == "__main__":
    main()
