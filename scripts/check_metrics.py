#!/usr/bin/env python3
"""Validate a Prometheus text-format exposition scraped from `repro serve
--metrics-addr` (or `repro obs dump --addr`).

Checks, in order:
  * the exposition parses: every non-comment line is `name[{labels}] value`,
    every samples block is preceded by matching # HELP / # TYPE comments;
  * at least --min-families distinct metric families are present;
  * at least one histogram family exposes cumulative `_bucket{le=...}`
    samples (monotone non-decreasing, closed by `le="+Inf"`) plus `_sum`
    and `_count`, with `_count` equal to the +Inf bucket — i.e. quantiles
    are derivable from the buckets;
  * counter values are finite and non-negative.

Usage: check_metrics.py EXPOSITION_FILE [--min-families 10]
Exit status 0 on success, 1 with a diagnostic on any violation.
"""

import argparse
import math
import re
import sys

SAMPLE_RE = re.compile(
    r'^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(?:\{(?P<labels>[^}]*)\})?'
    r'\s+(?P<value>[^\s]+)\s*$'
)
LABEL_RE = re.compile(r'^[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*"$')


def fail(msg):
    print(f"check_metrics: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def parse_labels(raw):
    if not raw:
        return {}
    labels = {}
    for part in raw.split(","):
        part = part.strip()
        if not LABEL_RE.match(part):
            fail(f"malformed label pair {part!r}")
        key, val = part.split("=", 1)
        labels[key] = val[1:-1]
    return labels


def family_of(sample_name, typed):
    """Map a sample name to its family (histogram samples carry
    _bucket/_sum/_count suffixes on top of the family name)."""
    for suffix in ("_bucket", "_sum", "_count"):
        if sample_name.endswith(suffix) and sample_name[: -len(suffix)] in typed:
            return sample_name[: -len(suffix)]
    return sample_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("exposition", help="scraped /metrics body (file path)")
    ap.add_argument("--min-families", type=int, default=10)
    args = ap.parse_args()

    with open(args.exposition, encoding="utf-8") as f:
        text = f.read()
    if not text.endswith("\n"):
        fail("exposition must end with a newline")

    helped, typed = {}, {}
    samples = []  # (name, labels, value)
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            parts = line.split(" ", 3)
            if len(parts) < 4:
                fail(f"line {lineno}: bare # HELP")
            helped[parts[2]] = parts[3]
            continue
        if line.startswith("# TYPE "):
            parts = line.split(" ", 3)
            if len(parts) < 4 or parts[3] not in ("counter", "gauge", "histogram"):
                fail(f"line {lineno}: bad # TYPE {line!r}")
            typed[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue
        m = SAMPLE_RE.match(line)
        if not m:
            fail(f"line {lineno}: unparseable sample {line!r}")
        try:
            value = float(m.group("value"))
        except ValueError:
            fail(f"line {lineno}: non-numeric value {m.group('value')!r}")
        samples.append((m.group("name"), parse_labels(m.group("labels")), value))

    families = set(typed)
    for name, _, _ in samples:
        fam = family_of(name, typed)
        if fam not in typed:
            fail(f"sample {name} has no # TYPE")
        if fam not in helped:
            fail(f"sample {name} has no # HELP")
    if len(families) < args.min_families:
        fail(f"only {len(families)} families, need >= {args.min_families}: "
             f"{sorted(families)}")

    # Counters: finite, non-negative.
    for name, _, value in samples:
        fam = family_of(name, typed)
        if typed[fam] == "counter" and (not math.isfinite(value) or value < 0):
            fail(f"counter {name} has invalid value {value}")

    # Histograms: group buckets by (family, non-le labels) and require at
    # least one quantile-derivable series overall.
    derivable = 0
    hist_series = {}
    for name, labels, value in samples:
        fam = family_of(name, typed)
        if typed[fam] != "histogram" or not name.endswith("_bucket"):
            continue
        if "le" not in labels:
            fail(f"histogram bucket {name} lacks le label")
        key = (fam, tuple(sorted((k, v) for k, v in labels.items() if k != "le")))
        hist_series.setdefault(key, []).append((labels["le"], value))
    counts = {
        (family_of(n, typed), tuple(sorted(l.items()))): v
        for n, l, v in samples
        if n.endswith("_count") and family_of(n, typed) in typed
        and typed[family_of(n, typed)] == "histogram"
    }
    for (fam, rest), buckets in hist_series.items():
        bounds = []
        for le, v in buckets:
            bounds.append((math.inf if le == "+Inf" else float(le), v))
        bounds.sort(key=lambda bv: bv[0])
        values = [v for _, v in bounds]
        if values != sorted(values):
            fail(f"{fam}{dict(rest)}: buckets not cumulative: {values}")
        if not bounds or bounds[-1][0] != math.inf:
            fail(f"{fam}{dict(rest)}: missing le=\"+Inf\" bucket")
        count = counts.get((fam, rest))
        if count is None:
            fail(f"{fam}{dict(rest)}: histogram without _count")
        if count != bounds[-1][1]:
            fail(f"{fam}{dict(rest)}: _count {count} != +Inf bucket {bounds[-1][1]}")
        derivable += 1
    if derivable < 1:
        fail("no histogram family with quantile-derivable buckets")

    hist_fams = len({fam for fam, _ in hist_series})
    print(f"check_metrics: OK: {len(families)} families "
          f"({hist_fams} histogram series group(s), {len(samples)} samples)")


if __name__ == "__main__":
    main()
