//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Proves all three layers compose: the rust coordinator (L3) drives a
//! PSO-placed hierarchical FL session over the pub/sub broker; every
//! trainer/aggregator executes the AOT-compiled JAX graphs (L2) whose
//! aggregation/SGD hot-spots are Pallas kernels (L1); the global model's
//! eval loss is logged every round alongside the round processing delay.
//!
//! Requires `make artifacts`.
//!
//! ```sh
//! cargo run --release --example e2e_train -- --rounds 50
//! ```

use repro::configio::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env().unwrap_or_default();
    let rounds = args.usize_flag("rounds", 50).map_err(anyhow::Error::msg)?;
    repro::sim::run_e2e(rounds)
}
