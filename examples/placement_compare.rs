//! Fig-4 regeneration: the real (emulated-docker) deployment comparison.
//!
//! Spawns the paper's 10-client heterogeneous population (one fast,
//! two medium, seven memory-constrained), trains the 1.8 M-param MLP
//! through the full broker + agent + PJRT stack for N rounds under each
//! placement strategy, and reports per-round delays, totals, convergence
//! round, and the headline percentage improvements.
//!
//! Requires `make artifacts`.
//!
//! ```sh
//! cargo run --release --example placement_compare -- --rounds 50 --time-scale 1.0
//! cargo run --release --example placement_compare -- --strategies random,uniform,pso,ga
//! ```

use repro::configio::Args;
use repro::placement::registry;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env().unwrap_or_default();
    let rounds = args.usize_flag("rounds", 50).map_err(anyhow::Error::msg)?;
    let time_scale = args
        .f64_flag("time-scale", 1.0)
        .map_err(anyhow::Error::msg)?;
    let out_dir = std::path::PathBuf::from(args.str_flag("out-dir", "results"));
    // Any registry strategies (default: the paper's random/uniform/pso).
    let strategies = args.list_flag("strategies").unwrap_or_default();
    for name in &strategies {
        registry::canonical(name).map_err(anyhow::Error::msg)?;
    }
    repro::sim::run_fig4_comparison(rounds, time_scale, &out_dir, &strategies)
}
