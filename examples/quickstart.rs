//! Quickstart: the public API in ~60 lines.
//!
//! 1. Build a hierarchy + simulated client population (paper §IV.A).
//! 2. Run the Flag-Swap PSO placement optimizer against the TPD fitness.
//! 3. Compare the optimized placement against random/round-robin.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use repro::configio::SimScenario;
use repro::fitness::{tpd, ClientAttrs};
use repro::hierarchy::{Arrangement, HierarchySpec};
use repro::placement::{RandomPlacement, RoundRobinPlacement, Stepwise};
use repro::prng::Pcg32;
use repro::sim::run_sim;

fn main() {
    // A depth-3, width-4 hierarchy: 21 aggregator slots, 53 clients.
    let scenario = SimScenario::default();
    println!(
        "hierarchy: depth={} width={} → {} aggregator slots over {} clients",
        scenario.depth,
        scenario.width,
        scenario.dimensions(),
        scenario.client_count()
    );

    // --- PSO (Flag-Swap): optimize placement against the TPD model. ---
    let result = run_sim(&scenario);
    println!(
        "PSO: best TPD {:.4} after {} iterations (converged: {})",
        result.best_tpd, scenario.pso.iterations, result.converged
    );

    // --- Baselines on the same population. ---
    let spec = HierarchySpec::new(scenario.depth, scenario.width);
    let mut rng = Pcg32::seed_from_u64(scenario.seed);
    let attrs = ClientAttrs::sample_population(
        scenario.client_count(),
        scenario.pspeed_range,
        scenario.memcap_range,
        scenario.mdatasize,
        &mut rng,
    );
    let tpd_of = |placement: &[usize]| -> f64 {
        tpd(
            &Arrangement::from_position(spec, placement, scenario.client_count()),
            &attrs,
        )
        .total
    };

    // The Stepwise adapter exposes the classic one-placement-per-round
    // protocol over any batched Optimizer.
    let mut random = Stepwise::new(Box::new(RandomPlacement::new(
        spec.dimensions(),
        scenario.client_count(),
        Pcg32::seed_from_u64(1),
    )));
    let mut uniform = Stepwise::new(Box::new(RoundRobinPlacement::new(
        spec.dimensions(),
        scenario.client_count(),
    )));
    let avg = |s: &mut Stepwise| -> f64 {
        (0..100)
            .map(|r| {
                let placement = s.propose(r);
                let t = tpd_of(&placement);
                s.feedback(t);
                t
            })
            .sum::<f64>()
            / 100.0
    };
    let rand_avg = avg(&mut random);
    let uni_avg = avg(&mut uniform);

    println!("random placement: mean TPD {rand_avg:.4} over 100 draws");
    println!("uniform round-robin: mean TPD {uni_avg:.4} over 100 rotations");
    println!(
        "PSO finds a placement {:.1}% better than the random average",
        (1.0 - result.best_tpd / rand_avg) * 100.0
    );
    assert!(result.best_tpd < rand_avg, "PSO should beat random");
}
