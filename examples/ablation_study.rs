//! Ablation study: which mechanism actually costs you the round time?
//!
//! 1. Pick a dynamic scenario from the built-in catalog (everything-on
//!    at the paper's scale would also work — here: stragglers).
//! 2. Materialize one-mechanism-off variants and race them against the
//!    untouched baseline under shared replicate seeds (paired trials),
//!    all through the experiment engine.
//! 3. Print the per-mechanism delay deltas with 95% CIs — the library
//!    form of `repro ablate`.
//!
//! ```sh
//! cargo run --release --example ablation_study
//! ```

use repro::des::builtin_catalog;
use repro::exp::{
    enabled_mechanisms, report_ablation, run_ablation, AblationConfig, TrialScheduler,
};

fn main() {
    // --- 1. A catalog scenario with real dynamics switched on. ---
    let ns = builtin_catalog()
        .into_iter()
        .find(|s| s.name == "paper-straggler")
        .expect("builtin catalog carries the paper-scale straggler case");
    let mechanisms = enabled_mechanisms(&ns);
    println!(
        "scenario {} ({} clients): ablating {}",
        ns.name,
        ns.sim.client_count(),
        mechanisms.join(", ")
    );

    // --- 2. Baseline + one variant per mechanism, paired replicates. ---
    let cfg = AblationConfig {
        strategy: "pso".into(),
        evals: Some(60),
        replicates: 5,
    };
    let outcome = run_ablation(&ns, &mechanisms, &cfg, &TrialScheduler::new(0))
        .expect("ablation run");

    // --- 3. The per-mechanism delta table (and what `--out` writes). ---
    report_ablation(&outcome, None).expect("report");
    for e in &outcome.effects {
        if e.delta.mean > 0.0 {
            println!(
                "removing {} would speed the round up by {:.1}%",
                e.mechanism,
                100.0 * e.delta.mean / outcome.baseline.mean
            );
        }
    }
}
