//! Multi-process SDFL deployment over the TCP broker — the closest
//! analogue to the paper's docker testbed: every client is its own OS
//! process (`repro worker`) attached to the edge broker; the coordinator
//! process hosts the broker and drives PSO-placed rounds.
//!
//! Requires `make artifacts` and a release build of the `repro` binary
//! (`cargo build --release`).
//!
//! ```sh
//! cargo run --release --example distributed_tcp -- --workers 6 --rounds 6
//! ```

use anyhow::{anyhow, Context, Result};
use repro::broker::{Broker, TcpBrokerServer};
use repro::configio::Args;
use repro::fl::{Coordinator, CoordinatorConfig, ModelCodec};
use repro::placement::PsoPlacement;
use repro::prng::Pcg32;
use repro::pso::PsoConfig;
use repro::runtime::ModelRuntime;
use std::process::{Child, Command};
use std::sync::Arc;
use std::time::Duration;

fn main() -> Result<()> {
    let args = Args::parse_env().unwrap_or_default();
    let workers = args.usize_flag("workers", 6).map_err(anyhow::Error::msg)?;
    let rounds = args.usize_flag("rounds", 6).map_err(anyhow::Error::msg)?;
    let session = "dist";

    // The coordinator process hosts the edge broker.
    let broker = Broker::new();
    let server = TcpBrokerServer::start("127.0.0.1:0", broker.clone())?;
    let addr = server.addr();
    println!("broker listening on {addr}");

    // Spawn one worker process per client (heterogeneity mirrors the
    // paper's docker mix: worker 0 fast, 1-2 medium, rest constrained).
    let exe = std::env::current_exe()?;
    // examples/ binaries live under target/release/examples/; the main
    // binary sits one level up.
    let repro_bin = exe
        .parent()
        .and_then(|p| p.parent())
        .map(|p| p.join("repro"))
        .filter(|p| p.exists())
        .ok_or_else(|| anyhow!("repro binary not found next to the example — run `cargo build --release` first"))?;

    let mut children: Vec<Child> = Vec::new();
    for id in 0..workers {
        let (speed, mem) = match id {
            0 => (1.0, 1.0),
            1 | 2 => (2.0, 1.5),
            _ => (2.5, 3.0),
        };
        let child = Command::new(&repro_bin)
            .args([
                "worker",
                "--id",
                &id.to_string(),
                "--session",
                session,
                "--broker",
                &addr.to_string(),
                "--speed",
                &speed.to_string(),
                "--mem",
                &mem.to_string(),
                "--time-scale",
                "0.5",
            ])
            .spawn()
            .with_context(|| format!("spawning worker {id}"))?;
        children.push(child);
    }

    // Coordinator attaches in-process to the same broker the TCP workers
    // use; the retained join barrier synchronizes startup.
    let runtime = Arc::new(ModelRuntime::load_default()?);
    let dims = 3; // depth-2 width-2 hierarchy
    let cfg = CoordinatorConfig {
        session: session.into(),
        depth: 2,
        width: 2,
        client_count: workers,
        local_steps: 1,
        lr: 0.05,
        codec: ModelCodec::Binary,
        round_timeout: Duration::from_secs(300),
        eval_every: 1,
        model_seed: [0, 7],
        data_seed: 1234,
    };
    let mut strategy = PsoPlacement::new(
        dims,
        workers,
        PsoConfig::paper(),
        Pcg32::seed_from_u64(5),
    );
    let mut coord = Coordinator::new(cfg, broker.connect("coordinator"), runtime)?;

    println!("waiting for {workers} workers to join ...");
    coord.wait_for_clients(workers, Duration::from_secs(60))?;

    // Drive the optimizer through the live-session environment: every
    // evaluation is one measured FL round over the TCP broker.
    coord.run_session(&mut strategy, rounds)?;

    println!("\nper-round results:");
    for r in coord.recorder().records() {
        println!(
            "  round {:>2}: delay {:>7.3}s loss {:>7.4} placement {:?}",
            r.round,
            r.delay.as_secs_f64(),
            r.loss,
            r.placement
        );
    }
    println!(
        "total {:.1}s over {} rounds (multi-process, TCP transport)",
        coord.recorder().total_delay().as_secs_f64(),
        rounds
    );

    coord.shutdown();
    for mut c in children {
        let _ = c.wait();
    }
    Ok(())
}
