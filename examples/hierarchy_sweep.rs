//! Fig-3 regeneration: PSO convergence across the paper's simulation
//! grid — depth ∈ {3,4,5}, width 4, swarm P ∈ {5,10} — plus the width-5
//! variants. Writes `results/fig3_<panel>.csv` and prints ASCII plots.
//!
//! ```sh
//! cargo run --release --example hierarchy_sweep [-- --out-dir results]
//! ```

use repro::configio::{Args, SimScenario};
use repro::sim::{ascii_plot, run_sim};

fn main() {
    let args = Args::parse_env().unwrap_or_default();
    let out_dir = std::path::PathBuf::from(args.str_flag("out-dir", "results"));
    std::fs::create_dir_all(&out_dir).expect("mkdir results");

    // The paper's six panels.
    for (label, sc) in SimScenario::fig3_panels() {
        run_panel(&format!("fig3_{label}"), &sc, &out_dir, true);
    }

    // Extension: the width-5 grid the paper describes (M ∈ {4,5}).
    for depth in [3usize, 4] {
        let mut sc = SimScenario {
            depth,
            width: 5,
            ..SimScenario::default()
        };
        sc.pso.particles = 10;
        run_panel(&format!("fig3_w5_d{depth}"), &sc, &out_dir, false);
    }
}

fn run_panel(name: &str, sc: &SimScenario, out_dir: &std::path::Path, plot: bool) {
    let result = run_sim(sc);
    let norm = result.trace.normalized();
    let path = out_dir.join(format!("{name}.csv"));
    norm.write_csv(&path).expect("write csv");
    println!(
        "{name}: D={} W={} P={} clients={} slots={} | best TPD {:.4} converged={} | {}",
        sc.depth,
        sc.width,
        sc.pso.particles,
        sc.client_count(),
        sc.dimensions(),
        result.best_tpd,
        result.converged,
        path.display()
    );
    if plot {
        // Grey per-particle traces under worst/mean/best, like the paper.
        let mut series: Vec<(&str, char, &[f64])> = Vec::new();
        for p in &norm.per_particle {
            series.push(("particle", '.', p.as_slice()));
        }
        series.push(("worst", 'r', &norm.worst));
        series.push(("mean", 'o', &norm.mean));
        series.push(("best", 'g', &norm.best));
        println!(
            "{}",
            ascii_plot(
                &format!("{name}: normalized TPD vs iteration"),
                &series,
                72,
                14
            )
        );
    }
}
