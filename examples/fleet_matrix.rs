//! Fleet matrix: the discrete-event tier end to end in ~60 lines.
//!
//! 1. Pick a slice of the built-in dynamic-scenario catalog (static /
//!    churn / dropout / straggler variants of the paper's hierarchy).
//! 2. Race four placement strategies across OS threads, every cell
//!    scored by the `EventDrivenEnv` virtual-time simulator.
//! 3. Print the ranked standings — the library form of `repro fleet`.
//!
//! ```sh
//! cargo run --release --example fleet_matrix
//! ```

use repro::des::{builtin_catalog, report_fleet, run_fleet, EventDrivenEnv, FleetConfig};
use repro::exp::{run_plan, ExperimentPlan, ReplicateRange, TrialScheduler};
use repro::fitness::ClientAttrs;
use repro::hierarchy::HierarchySpec;
use repro::placement::{AnalyticTpd, Environment, Placement};
use repro::prng::{Pcg32, Rng};

fn main() {
    // --- 1. The EventDrivenEnv is a drop-in AnalyticTpd replacement. ---
    let spec = HierarchySpec::new(3, 4);
    let cc = 53;
    let mut rng = Pcg32::seed_from_u64(42);
    let attrs = ClientAttrs::sample_population(cc, (5.0, 15.0), (10.0, 50.0), 5.0, &mut rng);
    let p = Placement::new(rng.sample_distinct(cc, spec.dimensions()));
    let analytic = AnalyticTpd::new(spec, attrs.clone()).eval(&p).unwrap();
    let virtual_time = EventDrivenEnv::conformance(spec, attrs).eval(&p).unwrap();
    println!(
        "one placement, two oracles: analytic TPD {analytic:.6} vs virtual-time {virtual_time:.6}"
    );
    assert!((analytic - virtual_time).abs() < 1e-9, "conformance");

    // --- 2. A scenario × strategy matrix across OS threads. ---
    let scenarios: Vec<_> = builtin_catalog()
        .into_iter()
        .filter(|s| s.name.starts_with("paper"))
        .collect();
    let strategies: Vec<String> = ["pso", "random", "round-robin", "ga"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    println!(
        "\nracing {} strategies over {} dynamic scenarios: {}",
        strategies.len(),
        scenarios.len(),
        scenarios.iter().map(|s| s.name.as_str()).collect::<Vec<_>>().join(", ")
    );
    // Three replicates per cell: the standings report replicate means
    // ± 95% CIs and a paired sign test of the leader vs the field.
    let cfg = FleetConfig { threads: 0, evals: Some(60), replicates: 3 };
    let cells = run_fleet(&scenarios, &strategies, &cfg).expect("fleet run");

    // --- 3. Ranked standings (and the CSV `repro fleet` writes). ---
    report_fleet(&cells, None).expect("report");
    let pso_wins = cells.iter().filter(|c| c.strategy == "pso" && c.rank == 1).count();
    println!("pso won {pso_wins}/{} scenarios outright", scenarios.len());

    // --- 4. The same matrix as an adaptive experiment plan: replicates
    // stop early per scenario once the leader's 95% CI separates from
    // every rival (`repro fleet --replicates 2..6`). ---
    let plan = ExperimentPlan {
        scenarios,
        strategies,
        evals: Some(60),
        env_override: None,
        replicates: ReplicateRange { min: 2, max: 6 },
    };
    let adaptive = run_plan(&plan, &TrialScheduler::new(0)).expect("adaptive plan");
    let spent: usize = adaptive.iter().map(|c| c.replicate_delays.len()).sum();
    println!(
        "\nadaptive 2..6: spent {spent} replicate trials over {} cells (max would be {})",
        adaptive.len(),
        adaptive.len() * 6
    );
}
