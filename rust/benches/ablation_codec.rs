//! Ablation A4: model codec — the paper ships 1.8 M-param models as
//! ~30 MB JSON; this quantifies JSON vs binary encode/decode latency and
//! size at the real model scale, plus the broker fan-out cost of each.
//!
//! Run: `cargo bench --bench ablation_codec`

use repro::bench::{black_box, report_table, Bencher};
use repro::broker::Broker;
use repro::fl::codec::{ModelCodec, ModelUpdate};
use std::time::Duration;

const P: usize = 1_863_690; // the paper's MLP

fn main() {
    repro::logging::set_level(repro::logging::Level::Error);
    let b = Bencher::new(10, 2);

    let update = ModelUpdate {
        sender: 3,
        weight: 64.0,
        params: (0..P).map(|i| ((i % 977) as f32) * 1.37e-3 - 0.5).collect(),
    };

    let mut rows = Vec::new();
    for codec in [ModelCodec::Binary, ModelCodec::Json] {
        let bytes = codec.encode(&update);
        let size_mb = bytes.len() as f64 / 1e6;
        let enc = b.iter(&format!("{}_encode", codec.name()), || {
            black_box(codec.encode(&update))
        });
        let dec = b.iter(&format!("{}_decode", codec.name()), || {
            black_box(ModelCodec::decode(&bytes).unwrap())
        });
        rows.push((
            codec.name().to_string(),
            vec![size_mb, enc.mean / 1e3, dec.mean / 1e3],
        ));
    }
    report_table(
        "Ablation A4 — model codec at 1.8M params",
        &["size_MB", "encode_ms", "decode_ms"],
        &rows,
    );

    // Broker fan-out of a model-sized payload to 10 subscribers.
    let broker = Broker::new();
    let mut subs: Vec<_> = (0..10)
        .map(|i| {
            let mut c = broker.connect(&format!("s{i}"));
            c.subscribe("model").unwrap();
            c
        })
        .collect();
    let publisher = broker.connect("pub");
    let payload = std::sync::Arc::new(ModelCodec::Binary.encode(&update));
    b.iter("broker_fanout_7.5MB_to_10", || {
        publisher.publish_shared("model", payload.clone()).unwrap();
        for s in &mut subs {
            black_box(s.recv_timeout(Duration::from_secs(1)).unwrap());
        }
    });
    println!(
        "expected shape: JSON ≈4–6x larger and ≈an order of magnitude slower\n\
         than binary (the paper's 30 MB-JSON overhead); fan-out is Arc-cheap."
    );
}
