//! Bench exp-µ: experiment-engine throughput — the same tiny-catalog
//! matrix run at a fixed replicate count vs an adaptive range, plus the
//! replicates each mode actually spends. Adaptive allocation should
//! spend no more replicates than `max` and, on clearly-separated
//! scenarios, markedly fewer — this bench makes the saving visible.
//!
//! Run: `cargo bench --bench exp_bench`

use repro::bench::{black_box, Bencher};
use repro::des::builtin_catalog;
use repro::exp::{run_plan, ExperimentPlan, ReplicateRange, TrialScheduler};

fn plan(replicates: ReplicateRange) -> ExperimentPlan {
    ExperimentPlan {
        scenarios: builtin_catalog()
            .into_iter()
            .filter(|s| s.name.starts_with("tiny"))
            .collect(),
        strategies: ["pso", "random", "round-robin"].iter().map(|s| s.to_string()).collect(),
        evals: Some(20),
        env_override: None,
        replicates,
    }
}

fn main() {
    repro::logging::set_level(repro::logging::Level::Error);
    let sched = TrialScheduler::new(0);
    let b = Bencher::new(10, 2);

    for (label, range) in [
        ("fixed r=8", ReplicateRange::fixed(8)),
        ("adaptive r=2..8", ReplicateRange { min: 2, max: 8 }),
    ] {
        let p = plan(range);
        let cells = run_plan(&p, &sched).expect("plan runs");
        let spent: usize = cells.iter().map(|c| c.replicate_delays.len()).sum();
        println!("{label}: {} cells, {} replicate trials", cells.len(), spent);
        // Throughput unit = replicate trials completed per second.
        b.iter_throughput(&format!("exp/tiny-matrix {label}"), || {
            let cells = run_plan(&p, &sched).expect("plan runs");
            black_box(cells.iter().map(|c| c.replicate_delays.len()).sum())
        });
    }
}
