//! Bench des-µ: virtual-time batch scoring throughput of the
//! discrete-event oracle at 100 / 1k / 10k clients, against the
//! closed-form `AnalyticTpd` dispatch on the same populations — the
//! "10k-client scenarios run in milliseconds" claim, measured.
//!
//! Run: `cargo bench --bench des_bench`

use repro::bench::{black_box, Bencher};
use repro::configio::{DynamicsSpec, NetSpec, SimScenario};
use repro::des::EventDrivenEnv;
use repro::fitness::ClientAttrs;
use repro::hierarchy::HierarchySpec;
use repro::placement::{AnalyticTpd, Environment, Placement};
use repro::prng::{Pcg32, Rng};

/// (label, trainers_per_leaf) on the paper's D3 W4 shape (21 slots,
/// 16 leaves): 101 / 997 / 10 005 clients.
const SIZES: [(&str, usize); 3] = [("100", 5), ("1k", 61), ("10k", 624)];

fn scenario(tpl: usize) -> SimScenario {
    SimScenario {
        depth: 3,
        width: 4,
        trainers_per_leaf: tpl,
        env: "event-driven".to_string(),
        ..SimScenario::default()
    }
}

fn population(sc: &SimScenario) -> (Vec<ClientAttrs>, Vec<Placement>) {
    let mut rng = Pcg32::seed_from_u64(sc.seed);
    let cc = sc.client_count();
    let attrs = ClientAttrs::sample_population(
        cc,
        sc.pspeed_range,
        sc.memcap_range,
        sc.mdatasize,
        &mut rng,
    );
    let batch: Vec<Placement> = (0..10)
        .map(|_| Placement::new(rng.sample_distinct(cc, sc.dimensions())))
        .collect();
    (attrs, batch)
}

fn main() {
    repro::logging::set_level(repro::logging::Level::Error);

    for (label, tpl) in SIZES {
        let sc = scenario(tpl);
        let cc = sc.client_count();
        let spec = HierarchySpec::new(sc.depth, sc.width);
        let (attrs, batch) = population(&sc);
        // Fewer samples at 10k clients: each iteration scores 10 whole
        // virtual rounds over the full population.
        let b = if cc > 5_000 { Bencher::new(10, 2) } else { Bencher::new(30, 3) };

        let mut analytic = AnalyticTpd::new(spec, attrs.clone());
        b.iter_throughput(&format!("analytic/batch10 cc={label}"), || {
            black_box(analytic.eval_batch(&batch).unwrap());
            batch.len()
        });

        // One-swap neighbors of a fixed base: the delta fast path SA /
        // tabu / adaptive probing hit.
        let mut delta_env = AnalyticTpd::new(spec, attrs.clone());
        let base = batch[0].clone();
        delta_env.eval(&base).unwrap();
        let mut rng = Pcg32::seed_from_u64(99);
        let neighbors: Vec<Placement> = (0..10)
            .map(|_| {
                let mut p = base.as_slice().to_vec();
                let (slot, id) = repro::placement::draw_slot_replacement(&base, cc, &mut rng);
                p[slot] = id;
                Placement::new(p)
            })
            .collect();
        b.iter_throughput(&format!("analytic-delta/batch10 cc={label}"), || {
            for p in &neighbors {
                black_box(delta_env.eval(p).unwrap());
            }
            neighbors.len()
        });

        // Conformance configuration: identical scores, event-driven path.
        let mut des = EventDrivenEnv::conformance(spec, attrs.clone());
        b.iter_throughput(&format!("des-static/batch10 cc={label}"), || {
            black_box(des.eval_batch(&batch).unwrap());
            batch.len()
        });

        // Fully dynamic scenario: jittered contended links + churn +
        // dropout + stragglers + drift (the fleet workload).
        let mut dynamic = scenario(tpl);
        dynamic.des.train_unit = 1.0;
        dynamic.des.net = NetSpec {
            latency_range_s: (0.001, 0.02),
            bandwidth_range: (5.0, 50.0),
            agg_ingress: 500.0,
            jitter_sigma: 0.5,
            ..NetSpec::default()
        };
        dynamic.des.dynamics = DynamicsSpec {
            dropout_prob: 0.1,
            churn_leave_prob: 0.05,
            churn_join_prob: 0.5,
            straggler_prob: 0.3,
            straggler_frac: 0.2,
            straggler_slowdown: 4.0,
            drift_sigma: 0.05,
            ..DynamicsSpec::default()
        };
        let mut des_dyn = EventDrivenEnv::from_scenario(&dynamic, attrs);
        b.iter_throughput(&format!("des-dynamic/batch10 cc={label}"), || {
            black_box(des_dyn.eval_batch(&batch).unwrap());
            batch.len()
        });
        println!(
            "  ({} clients, {} slots; des fired {} events over {} rounds)\n",
            cc,
            sc.dimensions(),
            des_dyn.events_fired,
            des_dyn.rounds_simulated
        );
    }
}
