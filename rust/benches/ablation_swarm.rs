//! Ablation A1: swarm size. The paper observes larger P finds better
//! placements (Fig. 3 a↔d); this sweeps P ∈ {2, 5, 10, 20} on the D4/W4
//! simulation with a fixed iteration budget.
//!
//! Run: `cargo bench --bench ablation_swarm`

use repro::bench::report_table;
use repro::configio::SimScenario;
use repro::metrics::Stopwatch;
use repro::sim::run_sim;

fn main() {
    repro::logging::set_level(repro::logging::Level::Error);
    let mut rows = Vec::new();
    for particles in [2usize, 5, 10, 20] {
        // Average over a few seeds — single runs of a stochastic
        // optimizer are noise.
        let mut best = Vec::new();
        let mut conv = 0usize;
        let sw = Stopwatch::start();
        for seed in 0..5u64 {
            let mut sc = SimScenario {
                depth: 4,
                width: 4,
                seed: 42 + seed,
                ..SimScenario::default()
            };
            sc.pso.particles = particles;
            let r = run_sim(&sc);
            best.push(r.best_tpd);
            conv += r.converged as usize;
        }
        let secs = sw.elapsed_secs();
        let mean = best.iter().sum::<f64>() / best.len() as f64;
        let min = best.iter().cloned().fold(f64::INFINITY, f64::min);
        rows.push((
            format!("P={particles}"),
            vec![mean, min, conv as f64, secs * 1e3 / 5.0],
        ));
    }
    report_table(
        "Ablation A1 — swarm size (D4 W4, 100 iters, 5 seeds)",
        &["best_tpd_mean", "best_tpd_min", "converged/5", "ms/run"],
        &rows,
    );
    println!("expected shape: best_tpd_mean non-increasing with P (paper Fig. 3 a vs d).");
}
