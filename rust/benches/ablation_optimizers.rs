//! Ablation A2: black-box optimizer comparison under an equal
//! evaluation budget — backs the paper's §II claim that PSO converges
//! faster/better than GA for this problem, and adds SA + pure random
//! search as controls. All four run through the same black-box
//! [`PlacementStrategy`] protocol (one TPD evaluation per "round").
//!
//! Run: `cargo bench --bench ablation_optimizers`

use repro::bench::report_table;
use repro::fitness::{tpd, ClientAttrs};
use repro::hierarchy::{Arrangement, HierarchySpec};
use repro::placement::*;
use repro::prng::Pcg32;
use repro::pso::PsoConfig;

const BUDGET: usize = 400; // fitness evaluations per optimizer
const SEEDS: u64 = 5;

fn main() {
    repro::logging::set_level(repro::logging::Level::Error);
    let spec = HierarchySpec::new(4, 4); // 85 slots
    let dims = spec.dimensions();
    let cc = dims + spec.leaf_slots().len() * 2; // 213 clients

    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    for name in ["random", "pso", "pso-nopin", "ga", "sa", "tabu"] {
        let mut bests = Vec::new();
        let mut best_at_half = Vec::new();
        for seed in 0..SEEDS {
            let mut rng = Pcg32::seed_from_u64(1000 + seed);
            let attrs =
                ClientAttrs::sample_population(cc, (5.0, 15.0), (10.0, 50.0), 5.0, &mut rng);
            let tpd_of = |pos: &[usize]| {
                tpd(&Arrangement::from_position(spec, pos, cc), &attrs).total
            };
            let mut strategy: Box<dyn PlacementStrategy> = match name {
                "random" => Box::new(RandomPlacement::new(dims, cc, Pcg32::seed_from_u64(seed))),
                "pso" => Box::new(PsoPlacement::new(
                    dims,
                    cc,
                    PsoConfig::paper(),
                    Pcg32::seed_from_u64(seed),
                )),
                "pso-nopin" => Box::new(PsoPlacement::without_pinning(
                    dims,
                    cc,
                    PsoConfig::paper(),
                    Pcg32::seed_from_u64(seed),
                )),
                "ga" => Box::new(GaPlacement::new(
                    dims,
                    cc,
                    GaConfig::default(),
                    Pcg32::seed_from_u64(seed),
                )),
                "sa" => Box::new(SaPlacement::new(
                    dims,
                    cc,
                    SaConfig::default(),
                    Pcg32::seed_from_u64(seed),
                )),
                "tabu" => Box::new(TabuPlacement::new(
                    dims,
                    cc,
                    TabuConfig::default(),
                    Pcg32::seed_from_u64(seed),
                )),
                _ => unreachable!(),
            };
            let mut best = f64::INFINITY;
            let mut half = f64::INFINITY;
            for round in 0..BUDGET {
                let p = strategy.propose(round);
                let t = tpd_of(&p);
                strategy.feedback(&p, t);
                best = best.min(t);
                if round == BUDGET / 2 {
                    half = best;
                }
            }
            bests.push(best);
            best_at_half.push(half);
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        rows.push((
            name.to_string(),
            vec![mean(&best_at_half), mean(&bests)],
        ));
    }
    report_table(
        &format!("Ablation A2 — optimizers, D4 W4, {BUDGET} evals, {SEEDS} seeds"),
        &["best_tpd@50%", "best_tpd@100%"],
        &rows,
    );
    println!(
        "expected shape: pso-nopin/ga/sa beat random search. Deployed Flag-Swap\n\
         ('pso') pins gbest after convergence — it stops searching early by\n\
         design, trading search depth for stable low-delay production rounds\n\
         (what Fig. 4 measures). pso-nopin isolates pure PSO search quality."
    );
}
