//! Ablation A2: black-box optimizer comparison under an equal
//! evaluation budget — backs the paper's §II claim that PSO converges
//! faster/better than GA for this problem, and adds SA, tabu search and
//! pure random search as controls. Every optimizer is built through the
//! strategy registry and driven against the [`AnalyticTpd`] environment
//! by the generic `drive` loop — the same code path `repro sim
//! --strategy <name>` uses.
//!
//! Run: `cargo bench --bench ablation_optimizers`

use repro::bench::report_table;
use repro::fitness::ClientAttrs;
use repro::hierarchy::HierarchySpec;
use repro::placement::{drive, registry, AnalyticTpd, Optimizer, PsoPlacement};
use repro::prng::Pcg32;
use repro::pso::PsoConfig;

const BUDGET: usize = 400; // fitness evaluations per optimizer
const SEEDS: u64 = 5;

fn main() {
    repro::logging::set_level(repro::logging::Level::Error);
    let spec = HierarchySpec::new(4, 4); // 85 slots
    let dims = spec.dimensions();
    let cc = dims + spec.leaf_slots().len() * 2; // 213 clients

    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    for name in ["random", "pso", "pso-nopin", "pso-batched", "ga", "sa", "tabu"] {
        let mut bests = Vec::new();
        let mut best_at_half = Vec::new();
        for seed in 0..SEEDS {
            let mut rng = Pcg32::seed_from_u64(1000 + seed);
            let attrs =
                ClientAttrs::sample_population(cc, (5.0, 15.0), (10.0, 50.0), 5.0, &mut rng);
            let mut env = AnalyticTpd::new(spec, attrs);
            // "pso-nopin" isolates pure PSO search quality (no exploit
            // phase); it is intentionally not a registry strategy.
            let mut opt: Box<dyn Optimizer> = if name == "pso-nopin" {
                Box::new(PsoPlacement::without_pinning(
                    dims,
                    cc,
                    PsoConfig::paper(),
                    Pcg32::seed_from_u64(seed),
                ))
            } else {
                registry::build_live(name, dims, cc, PsoConfig::paper(), seed).expect(name)
            };
            let half = drive(opt.as_mut(), &mut env, BUDGET / 2).expect(name);
            let full = drive(opt.as_mut(), &mut env, BUDGET - BUDGET / 2).expect(name);
            best_at_half.push(half.best_delay);
            bests.push(half.best_delay.min(full.best_delay));
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        rows.push((
            name.to_string(),
            vec![mean(&best_at_half), mean(&bests)],
        ));
    }
    report_table(
        &format!("Ablation A2 — optimizers, D4 W4, {BUDGET} evals, {SEEDS} seeds"),
        &["best_tpd@50%", "best_tpd@100%"],
        &rows,
    );
    println!(
        "expected shape: pso-nopin/pso-batched/ga/sa/tabu beat random search.\n\
         Deployed Flag-Swap ('pso') pins gbest after convergence — it stops\n\
         searching early by design, trading search depth for stable low-delay\n\
         production rounds (what Fig. 4 measures)."
    );
}
