//! Bench L3-µ: PSO optimizer step cost. The coordinator must never be
//! the bottleneck (DESIGN.md §Perf) — one full swarm step over the
//! biggest Fig-3 search space (341 dims, 1877 clients) has to stay far
//! under a round's multi-second wall time.
//!
//! Run: `cargo bench --bench pso_bench`

use repro::bench::{black_box, Bencher};
use repro::prng::Pcg32;
use repro::pso::{AsyncSwarm, PsoConfig, Swarm};

fn main() {
    repro::logging::set_level(repro::logging::Level::Error);
    let b = Bencher::new(50, 5);

    for (dims, cc) in [(21usize, 53usize), (85, 213), (341, 1877)] {
        let cfg = PsoConfig::paper();
        let mut swarm = Swarm::new(dims, cc, cfg, Pcg32::seed_from_u64(1));
        b.iter(&format!("swarm_step dims={dims} cc={cc}"), || {
            // Trivial fitness isolates optimizer cost from TPD cost.
            black_box(swarm.step(|pos| pos[0] as f64))
        });
    }

    for (dims, cc) in [(3usize, 10usize), (21, 53), (341, 1877)] {
        let mut swarm = AsyncSwarm::new(dims, cc, PsoConfig::paper(), Pcg32::seed_from_u64(2));
        b.iter(&format!("async propose+report dims={dims}"), || {
            let p = swarm.propose();
            let d = p[0] as f64;
            swarm.report(d);
            black_box(d)
        });
    }

    // TPD fitness evaluation cost (the sim inner loop).
    use repro::fitness::{tpd, ClientAttrs};
    use repro::hierarchy::{Arrangement, HierarchySpec};
    use repro::prng::Rng;
    for (d, w) in [(3usize, 4usize), (4, 4), (5, 4)] {
        let spec = HierarchySpec::new(d, w);
        let dims = spec.dimensions();
        let cc = dims + spec.leaf_slots().len() * 2;
        let mut rng = Pcg32::seed_from_u64(3);
        let attrs = ClientAttrs::sample_population(cc, (5.0, 15.0), (10.0, 50.0), 5.0, &mut rng);
        let pos: Vec<usize> = rng.sample_distinct(cc, dims);
        b.iter(&format!("tpd_eval D{d} W{w} dims={dims}"), || {
            black_box(tpd(&Arrangement::from_position(spec, &pos, cc), &attrs).total)
        });
    }

    // Optimizer×Environment API: one full PSO iteration through the
    // AnalyticTpd environment — exact mode pays one eval_batch dispatch
    // per particle, batched mode one dispatch per iteration (the
    // fig3_sim hot loop).
    use repro::placement::{AnalyticTpd, Environment, Optimizer, SwarmOptimizer};
    for (d, w) in [(4usize, 4usize), (5, 4)] {
        let spec = HierarchySpec::new(d, w);
        let dims = spec.dimensions();
        let cc = dims + spec.leaf_slots().len() * 2;
        let mut rng = Pcg32::seed_from_u64(4);
        let attrs = ClientAttrs::sample_population(cc, (5.0, 15.0), (10.0, 50.0), 5.0, &mut rng);
        let particles = PsoConfig::paper().particles;

        let mut env = AnalyticTpd::new(spec, attrs.clone());
        let mut exact = SwarmOptimizer::exact(dims, cc, PsoConfig::paper(), rng.split());
        b.iter(&format!("iteration/exact D{d} dims={dims}"), || {
            for _ in 0..particles {
                let batch = exact.propose_batch(0);
                let delays = env.eval_batch(&batch).unwrap();
                exact.observe_batch(&batch, &delays);
            }
            black_box(())
        });

        let mut env = AnalyticTpd::new(spec, attrs);
        let mut batched = SwarmOptimizer::batched(dims, cc, PsoConfig::paper(), rng.split());
        b.iter(&format!("iteration/batched D{d} dims={dims}"), || {
            let batch = batched.propose_batch(0);
            let delays = env.eval_batch(&batch).unwrap();
            batched.observe_batch(&batch, &delays);
            black_box(())
        });
    }
}
