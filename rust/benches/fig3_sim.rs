//! Bench E1 (paper Fig. 3): PSO placement convergence in simulation, all
//! six panels. Reports per-panel best/initial TPD, improvement, whether
//! the swarm converged, and wall-clock per run. Writes normalized traces
//! to results/fig3_<panel>.csv.
//!
//! Run: `cargo bench --bench fig3_sim`

use repro::bench::report_table;
use repro::configio::SimScenario;
use repro::metrics::Stopwatch;
use repro::sim::run_sim;

fn main() {
    repro::logging::set_level(repro::logging::Level::Error);
    let out_dir = std::path::PathBuf::from("results");
    std::fs::create_dir_all(&out_dir).unwrap();

    let mut rows = Vec::new();
    for (label, sc) in SimScenario::fig3_panels() {
        let sw = Stopwatch::start();
        let result = run_sim(&sc);
        let secs = sw.elapsed_secs();
        let norm = result.trace.normalized();
        norm.write_csv(&out_dir.join(format!("fig3_{label}.csv"))).unwrap();
        let initial_mean = result.trace.mean[0];
        rows.push((
            format!(
                "({label}) D{} W{} P{} n={}",
                sc.depth,
                sc.width,
                sc.pso.particles,
                sc.client_count()
            ),
            vec![
                initial_mean,
                result.best_tpd,
                (1.0 - result.best_tpd / initial_mean) * 100.0,
                if result.converged { 1.0 } else { 0.0 },
                secs * 1e3,
            ],
        ));
    }
    report_table(
        "Fig. 3 — PSO aggregation placement in simulated SDFL",
        &["tpd_init_mean", "tpd_best", "improve_%", "converged", "ms"],
        &rows,
    );
    println!(
        "shape check (paper): TPD descends and particles converge per panel;\n\
         larger P (panels d–f) finds equal-or-lower TPD than P=5 (panels a–c)."
    );
}
