//! Bench L1/L2-µ: PJRT execution latency of the AOT artifacts — the
//! aggregation kernel (per fan-in K), the train step, init and eval.
//! This is the compute the emulated clients stretch; its baseline cost
//! sets the round-delay floor.
//!
//! Requires `make artifacts` (skips otherwise).
//!
//! Run: `cargo bench --bench agg_bench`

use repro::bench::{black_box, Bencher};
use repro::runtime::ModelRuntime;

fn main() {
    repro::logging::set_level(repro::logging::Level::Error);
    let rt = match ModelRuntime::load_default() {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP agg_bench: {e:#} (run `make artifacts`)");
            return;
        }
    };
    let b = Bencher::new(12, 3);
    let p = rt.meta.param_count;

    let params = rt.init_params([0, 1]).unwrap();
    b.iter("init_params", || black_box(rt.init_params([0, 1]).unwrap()));

    // Aggregation across exported fan-ins.
    for k in [2usize, 4, 8] {
        let models: Vec<&[f32]> = (0..k).map(|_| params.as_slice()).collect();
        let weights = vec![1.0f32; k];
        let s = b.iter(&format!("aggregate_k{k} (P={p})"), || {
            black_box(rt.aggregate(&models, &weights).unwrap())
        });
        // Effective reduction bandwidth: K·P·4 bytes read per aggregate.
        let gb = (k * p * 4) as f64 / 1e9;
        println!(
            "      -> reduction read bandwidth ≈ {:.2} GB/s",
            gb / (s.mean / 1e6)
        );
    }

    // Train step (fwd+bwd+pallas-SGD at batch 32).
    {
        use repro::prng::{Pcg32, Rng};
        let mut rng = Pcg32::seed_from_u64(2);
        let x: Vec<f32> = (0..rt.meta.train_batch * rt.meta.input_dim)
            .map(|_| rng.uniform(-1.0, 1.0) as f32)
            .collect();
        let y: Vec<i32> = (0..rt.meta.train_batch)
            .map(|_| rng.gen_range(10) as i32)
            .collect();
        b.iter("train_step_b32", || {
            black_box(rt.train_step(&params, &x, &y, 0.05).unwrap())
        });

        let xe: Vec<f32> = (0..rt.meta.eval_batch * rt.meta.input_dim)
            .map(|_| rng.uniform(-1.0, 1.0) as f32)
            .collect();
        let ye: Vec<i32> = (0..rt.meta.eval_batch)
            .map(|_| rng.gen_range(10) as i32)
            .collect();
        b.iter("eval_b256", || {
            black_box(rt.evaluate(&params, &xe, &ye).unwrap())
        });
    }
}
