//! Ablation A6 (paper future-work): adaptation to continuous system
//! variation. At round 150 the simulated system drifts (fast clients
//! become slow and vice versa); plain Flag-Swap stays pinned to the
//! stale placement while the adaptive variant detects the delay drift
//! and re-optimizes. The drift is modeled as two [`AnalyticTpd`]
//! environments the same registry-built optimizer is driven through in
//! sequence.
//!
//! Run: `cargo bench --bench ablation_drift`

use repro::bench::report_table;
use repro::fitness::ClientAttrs;
use repro::hierarchy::HierarchySpec;
use repro::placement::{drive, registry, AnalyticTpd};
use repro::prng::Pcg32;
use repro::pso::PsoConfig;

const DRIFT_AT: usize = 150;
const ROUNDS: usize = 400;
const SEEDS: u64 = 5;

fn main() {
    repro::logging::set_level(repro::logging::Level::Error);
    let spec = HierarchySpec::new(3, 4);
    let dims = spec.dimensions();
    let cc = dims + 32;

    let mut rows = Vec::new();
    for name in ["random", "pso", "adaptive-pso"] {
        let mut pre = Vec::new();
        let mut post = Vec::new();
        for seed in 0..SEEDS {
            let mut rng = Pcg32::seed_from_u64(500 + seed);
            let attrs =
                ClientAttrs::sample_population(cc, (5.0, 15.0), (10.0, 50.0), 5.0, &mut rng);
            // Drifted system: every client's speed is mirrored within the
            // paper's (5,15) range, so the optimum placement flips.
            let drifted: Vec<ClientAttrs> = attrs
                .iter()
                .map(|c| ClientAttrs {
                    pspeed: 20.0 - c.pspeed,
                    ..c.clone()
                })
                .collect();
            let mut opt = registry::build_live(name, dims, cc, PsoConfig::paper(), seed)
                .expect(name);
            let mut env_pre = AnalyticTpd::new(spec, attrs);
            let mut env_post = AnalyticTpd::new(spec, drifted);
            let stable = drive(opt.as_mut(), &mut env_pre, DRIFT_AT).expect(name);
            let after = drive(opt.as_mut(), &mut env_post, ROUNDS - DRIFT_AT).expect(name);
            // Score the settled windows before/after the drift (all
            // three strategies have group_size 1 → one row per round).
            pre.extend(stable.stats[DRIFT_AT - 30..].iter().map(|s| s.best));
            post.extend(after.stats[after.stats.len() - 30..].iter().map(|s| s.best));
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        rows.push((name.to_string(), vec![mean(&pre), mean(&post)]));
    }
    report_table(
        &format!("Ablation A6 — system drift at round {DRIFT_AT} (D3 W4, {SEEDS} seeds)"),
        &["tpd_pre_drift", "tpd_post_drift"],
        &rows,
    );
    println!(
        "expected shape: pre-drift pso ≈ adaptive-pso (both converged);\n\
         post-drift plain pso stays pinned to the stale placement while\n\
         adaptive-pso restarts and re-converges to a low TPD."
    );
}
