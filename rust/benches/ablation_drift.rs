//! Ablation A6 (paper future-work): adaptation to continuous system
//! variation. At round 150 the simulated system drifts (fast clients
//! become slow and vice versa); plain Flag-Swap stays pinned to the
//! stale placement while the adaptive variant detects the delay drift
//! and re-optimizes.
//!
//! Run: `cargo bench --bench ablation_drift`

use repro::bench::report_table;
use repro::fitness::{tpd, ClientAttrs};
use repro::hierarchy::{Arrangement, HierarchySpec};
use repro::placement::{AdaptivePsoPlacement, PlacementStrategy, PsoPlacement, RandomPlacement};
use repro::prng::Pcg32;
use repro::pso::PsoConfig;

const DRIFT_AT: usize = 150;
const ROUNDS: usize = 400;
const SEEDS: u64 = 5;

fn main() {
    repro::logging::set_level(repro::logging::Level::Error);
    let spec = HierarchySpec::new(3, 4);
    let dims = spec.dimensions();
    let cc = dims + 32;

    let mut rows = Vec::new();
    for name in ["random", "pso", "pso-adaptive"] {
        let mut pre = Vec::new();
        let mut post = Vec::new();
        for seed in 0..SEEDS {
            let mut rng = Pcg32::seed_from_u64(500 + seed);
            let attrs =
                ClientAttrs::sample_population(cc, (5.0, 15.0), (10.0, 50.0), 5.0, &mut rng);
            // Drifted system: every client's speed is mirrored within the
            // paper's (5,15) range, so the optimum placement flips.
            let drifted: Vec<ClientAttrs> = attrs
                .iter()
                .map(|c| ClientAttrs {
                    pspeed: 20.0 - c.pspeed,
                    ..c.clone()
                })
                .collect();
            let mut strategy: Box<dyn PlacementStrategy> = match name {
                "random" => Box::new(RandomPlacement::new(dims, cc, Pcg32::seed_from_u64(seed))),
                "pso" => Box::new(PsoPlacement::new(
                    dims,
                    cc,
                    PsoConfig::paper(),
                    Pcg32::seed_from_u64(seed),
                )),
                "pso-adaptive" => Box::new(AdaptivePsoPlacement::new(
                    dims,
                    cc,
                    PsoConfig::paper(),
                    Pcg32::seed_from_u64(seed),
                )),
                _ => unreachable!(),
            };
            for round in 0..ROUNDS {
                let at = if round < DRIFT_AT { &attrs } else { &drifted };
                let p = strategy.propose(round);
                let t = tpd(&Arrangement::from_position(spec, &p, cc), at).total;
                strategy.feedback(&p, t);
                // Score the settled windows before/after the drift.
                if (DRIFT_AT - 30..DRIFT_AT).contains(&round) {
                    pre.push(t);
                }
                if (ROUNDS - 30..ROUNDS).contains(&round) {
                    post.push(t);
                }
            }
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        rows.push((name.to_string(), vec![mean(&pre), mean(&post)]));
    }
    report_table(
        &format!("Ablation A6 — system drift at round {DRIFT_AT} (D3 W4, {SEEDS} seeds)"),
        &["tpd_pre_drift", "tpd_post_drift"],
        &rows,
    );
    println!(
        "expected shape: pre-drift pso ≈ pso-adaptive (both converged);\n\
         post-drift plain pso stays pinned to the stale placement while\n\
         pso-adaptive restarts and re-converges to a low TPD."
    );
}
