//! Bench E2/E3 (paper Fig. 4 + headline claims): the emulated-docker
//! deployment comparison — random vs uniform round-robin vs PSO — over
//! the full broker + agent + PJRT stack.
//!
//! Defaults to a compressed run (REPRO_BENCH_ROUNDS=18, time-scale 0.5)
//! so `cargo bench` stays tractable; the paper-faithful 50-round run is
//! `cargo run --release --example placement_compare -- --rounds 50`.
//!
//! Run: `cargo bench --bench fig4_deploy`

use repro::configio::DeployScenario;
use repro::runtime::ModelRuntime;
use repro::sim::{report_fig4, run_strategy};
use std::sync::Arc;

fn main() {
    repro::logging::set_level(repro::logging::Level::Warn);
    let rounds: usize = std::env::var("REPRO_BENCH_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(18);
    let time_scale: f64 = std::env::var("REPRO_BENCH_TIMESCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.5);

    let runtime = match ModelRuntime::load_default() {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            println!("SKIP fig4_deploy: {e:#} (run `make artifacts`)");
            return;
        }
    };
    let mut sc = DeployScenario::paper_docker();
    sc.rounds = rounds;

    let mut outcomes = Vec::new();
    for name in ["random", "uniform", "pso"] {
        println!("running {name} for {rounds} rounds (time_scale {time_scale}) ...");
        outcomes.push(run_strategy(&sc, name, runtime.clone(), time_scale).expect(name));
    }
    report_fig4(&outcomes, std::path::Path::new("results")).unwrap();
    println!(
        "shape check (paper): PSO converges within ~10 rounds, then runs\n\
         strictly faster per round; totals order pso < uniform < random."
    );
}
