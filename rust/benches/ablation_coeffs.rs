//! Ablation A3: PSO coefficient sensitivity. The paper fixes w = 0.01,
//! c1 = 0.01, c2 = 1 "to favor exploitation"; this sweeps each
//! coefficient to show where that choice sits.
//!
//! Run: `cargo bench --bench ablation_coeffs`

use repro::bench::report_table;
use repro::configio::SimScenario;
use repro::sim::run_sim;

const SEEDS: u64 = 5;

fn run_cfg(inertia: f64, cognitive: f64, social: f64) -> (f64, f64) {
    let mut bests = Vec::new();
    let mut conv = 0usize;
    for seed in 0..SEEDS {
        let mut sc = SimScenario {
            depth: 4,
            width: 4,
            seed: 7 + seed,
            ..SimScenario::default()
        };
        sc.pso.inertia = inertia;
        sc.pso.cognitive = cognitive;
        sc.pso.social = social;
        let r = run_sim(&sc);
        bests.push(r.best_tpd);
        conv += r.converged as usize;
    }
    (
        bests.iter().sum::<f64>() / bests.len() as f64,
        conv as f64 / SEEDS as f64,
    )
}

fn main() {
    repro::logging::set_level(repro::logging::Level::Error);
    let mut rows = Vec::new();

    let paper = (0.01, 0.01, 1.0);
    let (b, c) = run_cfg(paper.0, paper.1, paper.2);
    rows.push(("paper (w.01 c1.01 c2=1)".to_string(), vec![b, c]));

    for w in [0.4, 0.9] {
        let (b, c) = run_cfg(w, paper.1, paper.2);
        rows.push((format!("w={w}"), vec![b, c]));
    }
    for c1 in [0.5, 1.0, 2.0] {
        let (b, c) = run_cfg(paper.0, c1, paper.2);
        rows.push((format!("c1={c1}"), vec![b, c]));
    }
    for c2 in [0.5, 2.0] {
        let (b, c) = run_cfg(paper.0, paper.1, c2);
        rows.push((format!("c2={c2}"), vec![b, c]));
    }

    report_table(
        "Ablation A3 — PSO coefficients (D4 W4, 100 iters, 5 seeds)",
        &["best_tpd_mean", "converged_frac"],
        &rows,
    );
    println!(
        "expected shape: the paper's exploitative setting converges reliably;\n\
         large inertia/cognitive terms slow or destabilize convergence."
    );
}
