//! Bench L3-µ: broker throughput/latency (substrate roofline, DESIGN.md
//! §Perf target: ≥100k msg/s in-proc for 1 KB payloads).
//!
//! Run: `cargo bench --bench broker_bench`

use repro::bench::{black_box, Bencher};
use repro::broker::{Broker, TcpBrokerServer, TcpClient};
use std::time::Duration;

fn main() {
    repro::logging::set_level(repro::logging::Level::Error);
    let b = Bencher::new(20, 3);

    // In-proc single pub → single sub, 1 KB.
    {
        let broker = Broker::new();
        let mut sub = broker.connect("sub");
        sub.subscribe("t").unwrap();
        let publisher = broker.connect("pub");
        let payload = vec![7u8; 1024];
        b.iter_throughput("inproc_1KB_pub_recv x1000", || {
            for _ in 0..1000 {
                publisher.publish("t", payload.clone()).unwrap();
                black_box(sub.recv_timeout(Duration::from_secs(1)).unwrap());
            }
            1000
        });
    }

    // Wildcard routing cost with many subscriptions.
    {
        let broker = Broker::new();
        let mut subs = Vec::new();
        for i in 0..100 {
            let mut c = broker.connect(&format!("s{i}"));
            c.subscribe(&format!("fl/{i}/+")).unwrap();
            subs.push(c);
        }
        let publisher = broker.connect("pub");
        b.iter_throughput("route_100filters x1000", || {
            for i in 0..1000 {
                publisher
                    .publish(format!("fl/{}/x", i % 100), vec![1u8; 64])
                    .unwrap();
            }
            1000
        });
    }

    // Retained replay.
    {
        let broker = Broker::new();
        let publisher = broker.connect("pub");
        for i in 0..64 {
            publisher
                .publish_retained(format!("cfg/{i}"), vec![i as u8; 128])
                .unwrap();
        }
        b.iter("subscribe_with_64_retained", || {
            let mut c = broker.connect("late");
            c.subscribe("cfg/#").unwrap();
            let mut n = 0;
            while c.try_recv().is_some() {
                n += 1;
            }
            black_box(n)
        });
    }

    // TCP loopback round-trip, 1 KB and 7.5 MB.
    {
        let broker = Broker::new();
        let server = TcpBrokerServer::start("127.0.0.1:0", broker).unwrap();
        let mut sub = TcpClient::connect(&server.addr()).unwrap();
        sub.subscribe("t").unwrap();
        std::thread::sleep(Duration::from_millis(100));
        let mut publisher = TcpClient::connect(&server.addr()).unwrap();

        let small = vec![7u8; 1024];
        b.iter("tcp_1KB_roundtrip", || {
            publisher.publish("t", &small).unwrap();
            black_box(sub.recv(Duration::from_secs(2)).unwrap())
        });

        let big = vec![7u8; 7_500_000];
        b.iter("tcp_7.5MB_roundtrip", || {
            publisher.publish("t", &big).unwrap();
            black_box(sub.recv(Duration::from_secs(10)).unwrap())
        });
    }
}
