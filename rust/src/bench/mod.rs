//! Micro-bench harness (substrate — no `criterion` offline).
//!
//! `cargo bench` targets use [`Bencher`] for timed inner loops with
//! warmup + sample statistics, and [`report_table`] for paper-style
//! result tables. Output format is stable so `bench_output.txt` diffs
//! cleanly between perf iterations (DESIGN.md §Perf).
//!
//! [`eval_suite`] is the CLI-facing perf harness (`repro bench --suite
//! eval`): delay-oracle throughput at the catalog shapes, emitted as
//! the machine-readable `BENCH_eval.json` trajectory artifact.

pub mod eval_suite;

use crate::metrics::Summary;
use std::time::Instant;

/// Timed micro-benchmark runner.
pub struct Bencher {
    /// Minimum samples collected per `iter` call.
    pub samples: usize,
    /// Warmup iterations before sampling.
    pub warmup: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            samples: 30,
            warmup: 3,
        }
    }
}

impl Bencher {
    pub fn new(samples: usize, warmup: usize) -> Self {
        Bencher { samples, warmup }
    }

    /// Time `f` (one logical operation per call); prints and returns the
    /// per-call summary in microseconds.
    pub fn iter<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> Summary {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed().as_secs_f64() * 1e6);
        }
        let s = Summary::from(&times);
        println!("bench {name:<44} {}", s.render("us"));
        s
    }

    /// Like `iter`, but `f` reports how many items it processed; prints
    /// throughput (items/s) alongside latency.
    pub fn iter_throughput<F: FnMut() -> usize>(&self, name: &str, mut f: F) -> Summary {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut times = Vec::with_capacity(self.samples);
        let mut items_total = 0usize;
        let mut time_total = 0f64;
        for _ in 0..self.samples {
            let t0 = Instant::now();
            let items = black_box(f());
            let dt = t0.elapsed().as_secs_f64();
            times.push(dt * 1e6);
            items_total += items;
            time_total += dt;
        }
        let s = Summary::from(&times);
        let rate = items_total as f64 / time_total.max(1e-12);
        println!(
            "bench {name:<44} {}  throughput={:.0}/s",
            s.render("us"),
            rate
        );
        s
    }
}

/// Opaque value sink preventing the optimizer from deleting benched work.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Render a paper-style results table (rows of label + columns).
pub fn report_table(title: &str, columns: &[&str], rows: &[(String, Vec<f64>)]) {
    println!("\n=== {title} ===");
    print!("{:<28}", "case");
    for c in columns {
        print!("{c:>16}");
    }
    println!();
    for (label, vals) in rows {
        print!("{label:<28}");
        for v in vals {
            print!("{v:>16.4}");
        }
        println!();
    }
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_collects_samples() {
        let b = Bencher::new(5, 1);
        let s = b.iter("noop", || 1 + 1);
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn throughput_counts_items() {
        let b = Bencher::new(3, 0);
        let s = b.iter_throughput("batch", || 100);
        assert_eq!(s.n, 3);
    }
}
