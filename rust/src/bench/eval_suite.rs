//! The `eval` bench suite behind `repro bench --suite eval`: measures
//! delay-oracle throughput (evaluations/second) at the catalog
//! population shapes and emits the machine-readable `BENCH_eval.json`
//! artifact that tracks the repo's perf trajectory.
//!
//! Cases per full-matrix shape (`tiny` 7 / `paper` 53 / `deep` 213 /
//! `mega10k` 10 021 clients):
//!
//! * `analytic` — [`AnalyticTpd::eval_batch`] over the zero-allocation
//!   scratch path (random candidates, so every evaluation streams the
//!   full population — no delta shortcuts).
//! * `analytic-delta` — one-swap neighbors of a fixed base placement
//!   through [`Environment::eval`], exercising the delta fast path the
//!   SA/tabu/probe strategies hit.
//! * `analytic-legacy` — the pre-scratch reference pipeline
//!   (`Arrangement::from_position` + `fitness::tpd` per candidate),
//!   kept callable so the speedup is measured *by the same harness* in
//!   the same process, not against a stale log.
//! * `emulated` — [`EmulatedDelay::eval_batch`] over the throttle-model
//!   oracle.
//! * `event-driven` — [`crate::des::EventDrivenEnv::eval_batch`] in the
//!   conformance configuration (the DES cost floor: heap + tables
//!   reused via [`crate::des::RoundScratch`]).
//!
//! The mega-scale shapes (`mega100k` 100 021 / `mega1M` 1 000 021
//! clients, ROADMAP item 2) run a restricted case set — `analytic`,
//! `analytic-delta`, `emulated`, `sharded` (the same random batch
//! through a [`ParEvalBatch`] worker pool at `--threads N`, the eval
//! path `sharded-pso` sweeps drive — compared against the serial
//! `analytic` case for the sharded-vs-serial speedup report), plus
//! `event-driven-delta` at 100k (the DES level-barrier delta fast path
//! over one-swap neighbors of a fully-simulated base round).
//! `analytic-legacy` (per-candidate
//! allocation) and full `event-driven` rounds (O(clients · log clients)
//! per candidate) are deliberately excluded there: they would dominate
//! the suite's wall clock without informing the delta-speedup
//! criterion, and `repro fleet --filter mega` covers the full-round
//! path. At 1M the single full base round the DES delta case needs is
//! itself seconds-long, so that case stops at 100k.
//!
//! The JSON schema (validated on every write, and by the CI smoke step):
//!
//! ```json
//! {
//!   "suite": "eval", "version": 2,
//!   "samples": 30, "warmup": 3, "batch": 32,
//!   "results": [
//!     { "case": "analytic/mega10k", "oracle": "analytic",
//!       "shape": "mega10k", "clients": 10021, "slots": 21,
//!       "batch": 32, "threads": 1, "evals_per_sec": 1.23e6,
//!       "mean_us_per_batch": 26.0, "p50_us": 25.5, "p90_us": 27.1,
//!       "std_us": 0.8 }
//!   ]
//! }
//! ```
//!
//! Version 2 added the per-result `threads` field (required from v2;
//! v1 documents without it remain readable as all-serial).

use super::{black_box, Bencher};
use crate::configio::ClientSpec;
use crate::des::EventDrivenEnv;
use crate::fitness::{tpd, ClientAttrs};
use crate::hierarchy::{Arrangement, HierarchySpec};
use crate::json::{self, Value};
use crate::metrics::Summary;
use crate::placement::{AnalyticTpd, EmulatedDelay, Environment, ParEvalBatch, Placement};
use crate::prng::{Pcg32, Rng};

/// Suite knobs (CLI: `--samples`, `--warmup`, `--batch`, `--threads`).
#[derive(Debug, Clone, Copy)]
pub struct SuiteConfig {
    pub samples: usize,
    pub warmup: usize,
    /// Candidates scored per timed batch (a typical swarm dispatch).
    pub batch: usize,
    /// Worker threads for the `sharded/*` cases (serial cases always
    /// run at 1). Recorded per case in the JSON so baselines only ever
    /// compare like-for-like thread counts.
    pub threads: usize,
}

impl Default for SuiteConfig {
    fn default() -> Self {
        SuiteConfig { samples: 30, warmup: 3, batch: 32, threads: 4 }
    }
}

/// One timed case of the suite.
#[derive(Debug, Clone)]
pub struct BenchCase {
    /// `oracle/shape`, e.g. `analytic/mega10k`.
    pub case: String,
    pub oracle: &'static str,
    pub shape: &'static str,
    pub clients: usize,
    pub slots: usize,
    pub batch: usize,
    /// Worker threads this case ran with (1 for every serial case).
    pub threads: usize,
    /// Throughput derived from the mean per-batch latency.
    pub evals_per_sec: f64,
    /// Per-batch latency distribution (µs).
    pub summary: Summary,
}

/// The four full-matrix catalog population shapes:
/// (label, depth, width, trainers per leaf).
pub const SHAPES: [(&str, usize, usize, usize); 4] = [
    ("tiny", 2, 2, 2),       // 7 clients
    ("paper", 3, 4, 2),      // 53 clients (Fig-3 panel a)
    ("deep", 4, 4, 2),       // 213 clients (Fig-3 panel b)
    ("mega10k", 3, 4, 625),  // 10 021 clients
];

/// The mega-scale shapes (restricted case set — see the module docs).
pub const MEGA_SHAPES: [(&str, usize, usize, usize); 2] = [
    ("mega100k", 3, 4, 6250),  // 100 021 clients
    ("mega1M", 3, 4, 62_500),  // 1 000 021 clients
];

fn shape_population(depth: usize, width: usize, tpl: usize, seed: u64) -> Vec<ClientAttrs> {
    let spec = HierarchySpec::new(depth, width);
    let cc = spec.dimensions() + spec.leaf_slots().len() * tpl;
    let mut rng = Pcg32::seed_from_u64(seed);
    ClientAttrs::sample_population(cc, (5.0, 15.0), (10.0, 50.0), 5.0, &mut rng)
}

fn random_batch(spec: HierarchySpec, cc: usize, count: usize, seed: u64) -> Vec<Placement> {
    let mut rng = Pcg32::seed_from_u64(seed);
    (0..count).map(|_| Placement::new(rng.sample_distinct(cc, spec.dimensions()))).collect()
}

/// Deterministic heterogeneous throttle specs for the emulated oracle.
fn throttle_specs(cc: usize) -> Vec<ClientSpec> {
    (0..cc)
        .map(|i| ClientSpec {
            name: format!("c{i}"),
            speed_factor: [1.0, 0.5, 0.25][i % 3],
            memory_pressure: [1.0, 2.0][i % 2],
        })
        .collect()
}

/// One-swap neighbors of `base` — drawn by the strategies' own shared
/// move ([`crate::placement::draw_slot_replacement`]), so this case
/// measures exactly the proposal shape the delta path recognizes.
fn neighbor_batch(base: &[usize], cc: usize, count: usize, seed: u64) -> Vec<Placement> {
    let mut rng = Pcg32::seed_from_u64(seed);
    (0..count)
        .map(|_| {
            let mut p = base.to_vec();
            let (slot, id) = crate::placement::draw_slot_replacement(base, cc, &mut rng);
            p[slot] = id;
            Placement::new(p)
        })
        .collect()
}

fn case(
    b: &Bencher,
    oracle: &'static str,
    shape: &'static str,
    clients: usize,
    slots: usize,
    batch: usize,
    threads: usize,
    mut run: impl FnMut() -> usize,
) -> BenchCase {
    let summary = b.iter_throughput(&format!("{oracle}/{shape}"), &mut run);
    // Throughput from the mean per-batch latency (µs → s).
    let evals_per_sec = batch as f64 / (summary.mean * 1e-6).max(1e-12);
    BenchCase {
        case: format!("{oracle}/{shape}"),
        oracle,
        shape,
        clients,
        slots,
        batch,
        threads,
        evals_per_sec,
        summary,
    }
}

/// Run the whole suite. Deterministic inputs (seeded per shape); the
/// timings are whatever the hardware gives.
pub fn run_eval_suite(cfg: &SuiteConfig) -> Vec<BenchCase> {
    let b = Bencher::new(cfg.samples, cfg.warmup);
    let mut cases = Vec::new();
    for (shape, depth, width, tpl) in SHAPES {
        let spec = HierarchySpec::new(depth, width);
        let dims = spec.dimensions();
        let attrs = shape_population(depth, width, tpl, 0xE7A1 ^ dims as u64);
        let cc = attrs.len();
        let batch = random_batch(spec, cc, cfg.batch, 17 + dims as u64);

        // Scratch-based analytic oracle (full streaming path).
        let mut analytic = AnalyticTpd::new(spec, attrs.clone());
        cases.push(case(&b, "analytic", shape, cc, dims, cfg.batch, 1, || {
            analytic.eval_batch(&batch).unwrap().len()
        }));

        // Delta fast path: one-swap neighbors of a fixed base.
        let base = batch[0].clone();
        let neighbors = neighbor_batch(&base, cc, cfg.batch, 23 + dims as u64);
        let mut delta_env = AnalyticTpd::new(spec, attrs.clone());
        delta_env.eval(&base).unwrap();
        cases.push(case(&b, "analytic-delta", shape, cc, dims, cfg.batch, 1, || {
            for p in &neighbors {
                black_box(delta_env.eval(p).unwrap());
            }
            neighbors.len()
        }));

        // The pre-scratch reference pipeline, same candidates.
        let legacy_attrs = attrs.clone();
        cases.push(case(&b, "analytic-legacy", shape, cc, dims, cfg.batch, 1, || {
            for p in &batch {
                black_box(tpd(&Arrangement::from_position(spec, p, cc), &legacy_attrs).total);
            }
            batch.len()
        }));

        // Emulated-testbed throttle model.
        let specs = throttle_specs(cc);
        let mut emulated = EmulatedDelay::new(depth, width, &specs);
        cases.push(case(&b, "emulated", shape, cc, dims, cfg.batch, 1, || {
            emulated.eval_batch(&batch).unwrap().len()
        }));

        // Event-driven oracle, conformance configuration.
        let mut des = EventDrivenEnv::conformance(spec, attrs);
        cases.push(case(&b, "event-driven", shape, cc, dims, cfg.batch, 1, || {
            des.eval_batch(&batch).unwrap().len()
        }));
    }

    // Mega-scale shapes: restricted case set (see the module docs).
    for (shape, depth, width, tpl) in MEGA_SHAPES {
        let spec = HierarchySpec::new(depth, width);
        let dims = spec.dimensions();
        let attrs = shape_population(depth, width, tpl, 0xE7A1 ^ (tpl as u64));
        let cc = attrs.len();
        let batch = random_batch(spec, cc, cfg.batch, 17 + tpl as u64);

        let mut analytic = AnalyticTpd::new(spec, attrs.clone());
        cases.push(case(&b, "analytic", shape, cc, dims, cfg.batch, 1, || {
            analytic.eval_batch(&batch).unwrap().len()
        }));

        let base = batch[0].clone();
        let neighbors = neighbor_batch(&base, cc, cfg.batch, 23 + tpl as u64);
        let mut delta_env = AnalyticTpd::new(spec, attrs.clone());
        delta_env.eval(&base).unwrap();
        cases.push(case(&b, "analytic-delta", shape, cc, dims, cfg.batch, 1, || {
            for p in &neighbors {
                black_box(delta_env.eval(p).unwrap());
            }
            neighbors.len()
        }));

        let specs = throttle_specs(cc);
        let mut emulated = EmulatedDelay::new(depth, width, &specs);
        cases.push(case(&b, "emulated", shape, cc, dims, cfg.batch, 1, || {
            emulated.eval_batch(&batch).unwrap().len()
        }));

        // Sharded evaluation: the same random batch through a
        // ParEvalBatch worker pool (one AnalyticTpd per worker), the
        // eval path ShardedPso's sweeps drive. Serial "analytic" above
        // is the 1-thread reference for the speedup report.
        let threads = cfg.threads.max(1);
        let mut sharded =
            ParEvalBatch::new(threads, |_| AnalyticTpd::new(spec, attrs.clone()));
        cases.push(case(&b, "sharded", shape, cc, dims, cfg.batch, threads, || {
            sharded.eval_batch(&batch).unwrap().len()
        }));

        // DES level-barrier delta path: one fully-simulated base round
        // bases the analytic mirror, then every one-swap neighbor is
        // delta-scored without touching the event loop. The base round
        // at 1M clients is itself seconds-long, so this case stops at
        // 100k (the delta mechanics are scale-invariant O(slots)).
        if shape == "mega100k" {
            let mut des_delta = EventDrivenEnv::conformance(spec, attrs);
            des_delta.eval(&base).unwrap();
            cases.push(case(&b, "event-driven-delta", shape, cc, dims, cfg.batch, 1, || {
                for p in &neighbors {
                    black_box(des_delta.eval(p).unwrap());
                }
                neighbors.len()
            }));
        }
    }
    cases
}

/// Print the scratch-vs-legacy speedup per shape (the acceptance
/// criterion `repro bench --suite eval` exists to track).
pub fn print_speedups(cases: &[BenchCase]) {
    println!("\n=== analytic scratch path vs legacy arrangement pipeline ===");
    for (shape, ..) in SHAPES {
        let rate = |oracle: &str| {
            cases
                .iter()
                .find(|c| c.oracle == oracle && c.shape == shape)
                .map(|c| c.evals_per_sec)
        };
        if let (Some(fast), Some(delta), Some(slow)) =
            (rate("analytic"), rate("analytic-delta"), rate("analytic-legacy"))
        {
            println!(
                "{shape:<10} scratch {fast:>12.0}/s  delta {delta:>12.0}/s  legacy {slow:>12.0}/s  speedup ×{:.1} (delta ×{:.1})",
                fast / slow.max(1e-12),
                delta / slow.max(1e-12),
            );
        }
    }
    println!("\n=== mega-scale delta fast paths vs full streaming evals ===");
    for (shape, ..) in MEGA_SHAPES {
        let rate = |oracle: &str| {
            cases
                .iter()
                .find(|c| c.oracle == oracle && c.shape == shape)
                .map(|c| c.evals_per_sec)
        };
        if let (Some(full), Some(delta)) = (rate("analytic"), rate("analytic-delta")) {
            let des = rate("event-driven-delta")
                .map(|r| format!("  des-delta {r:>12.0}/s"))
                .unwrap_or_default();
            println!(
                "{shape:<10} full {full:>12.0}/s  delta {delta:>12.0}/s  delta speedup ×{:.1}{des}",
                delta / full.max(1e-12),
            );
        }
    }
    println!("\n=== sharded (ParEvalBatch) vs serial analytic at mega scale ===");
    for (shape, ..) in MEGA_SHAPES {
        let find = |oracle: &str| cases.iter().find(|c| c.oracle == oracle && c.shape == shape);
        if let (Some(serial), Some(sharded)) = (find("analytic"), find("sharded")) {
            println!(
                "{shape:<10} serial {:>12.0}/s  sharded({} threads) {:>12.0}/s  speedup ×{:.2}",
                serial.evals_per_sec,
                sharded.threads,
                sharded.evals_per_sec,
                sharded.evals_per_sec / serial.evals_per_sec.max(1e-12),
            );
        }
    }
}

/// Serialize the suite to the `BENCH_eval.json` document.
pub fn suite_to_json(cfg: &SuiteConfig, cases: &[BenchCase]) -> Value {
    let results = cases
        .iter()
        .map(|c| {
            Value::object(vec![
                ("case", Value::from(c.case.as_str())),
                ("oracle", Value::from(c.oracle)),
                ("shape", Value::from(c.shape)),
                ("clients", Value::from(c.clients)),
                ("slots", Value::from(c.slots)),
                ("batch", Value::from(c.batch)),
                ("threads", Value::from(c.threads)),
                ("evals_per_sec", Value::from(c.evals_per_sec)),
                ("mean_us_per_batch", Value::from(c.summary.mean)),
                ("p50_us", Value::from(c.summary.p50)),
                ("p90_us", Value::from(c.summary.p90)),
                ("std_us", Value::from(c.summary.std)),
            ])
        })
        .collect();
    Value::object(vec![
        ("suite", Value::from("eval")),
        ("version", Value::from(2usize)),
        ("samples", Value::from(cfg.samples)),
        ("warmup", Value::from(cfg.warmup)),
        ("batch", Value::from(cfg.batch)),
        ("results", Value::Array(results)),
    ])
}

/// Validate a `BENCH_eval.json` document (schema + sanity): used after
/// every write and by the CI bench smoke step, so a malformed artifact
/// can never land silently.
pub fn validate_bench_json(doc: &Value) -> Result<(), String> {
    let field = |v: &Value, k: &str| -> Result<Value, String> {
        v.get(k).cloned().ok_or_else(|| format!("missing field {k:?}"))
    };
    if field(doc, "suite")?.as_str() != Some("eval") {
        return Err("suite must be \"eval\"".into());
    }
    for k in ["version", "samples", "warmup", "batch"] {
        field(doc, k)?.as_usize().ok_or_else(|| format!("{k} must be a non-negative integer"))?;
    }
    let results = field(doc, "results")?;
    let results = results.as_array().ok_or("results must be an array")?;
    if results.is_empty() {
        return Err("results must not be empty".into());
    }
    for (i, r) in results.iter().enumerate() {
        for k in ["case", "oracle", "shape"] {
            field(r, k)?.as_str().ok_or_else(|| format!("results[{i}].{k} must be a string"))?;
        }
        for k in ["clients", "slots", "batch"] {
            field(r, k)?
                .as_usize()
                .ok_or_else(|| format!("results[{i}].{k} must be an integer"))?;
        }
        // Schema v2: every result carries its worker thread count so
        // comparisons are like-for-like. v1 documents (no field) stay
        // valid — readers treat a missing count as 1 (serial).
        if let Some(t) = r.get("threads") {
            let t =
                t.as_usize().ok_or_else(|| format!("results[{i}].threads must be an integer"))?;
            if t == 0 {
                return Err(format!("results[{i}].threads must be >= 1"));
            }
        } else if field(doc, "version")?.as_usize() >= Some(2) {
            return Err(format!("results[{i}] missing threads (required from version 2)"));
        }
        for k in ["evals_per_sec", "mean_us_per_batch", "p50_us", "p90_us", "std_us"] {
            let x = field(r, k)?
                .as_f64()
                .ok_or_else(|| format!("results[{i}].{k} must be a number"))?;
            if !x.is_finite() || x < 0.0 {
                return Err(format!("results[{i}].{k} = {x} is not a finite non-negative number"));
            }
        }
        if field(r, "evals_per_sec")?.as_f64().unwrap_or(0.0) <= 0.0 {
            return Err(format!("results[{i}].evals_per_sec must be positive"));
        }
    }
    Ok(())
}

/// Write the suite JSON to `path`, then re-parse and re-validate the
/// bytes on disk (self-checking artifact).
pub fn write_bench_json(
    path: &std::path::Path,
    cfg: &SuiteConfig,
    cases: &[BenchCase],
) -> Result<(), String> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).map_err(|e| format!("{dir:?}: {e}"))?;
        }
    }
    let doc = suite_to_json(cfg, cases);
    std::fs::write(path, json::to_string_pretty(&doc)).map_err(|e| format!("{path:?}: {e}"))?;
    let back = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
    let parsed = json::parse(&back).map_err(|e| format!("re-parse of {path:?} failed: {e}"))?;
    validate_bench_json(&parsed).map_err(|e| format!("schema check of {path:?} failed: {e}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> SuiteConfig {
        SuiteConfig { samples: 1, warmup: 0, batch: 2, threads: 2 }
    }

    #[test]
    fn suite_covers_every_oracle_at_every_shape() {
        let cases = run_eval_suite(&tiny_cfg());
        // 5 oracles per full-matrix shape; restricted mega set: 5 cases
        // at 100k (incl. the DES delta + sharded paths), 4 at 1M.
        assert_eq!(cases.len(), SHAPES.len() * 5 + 5 + 4);
        for c in &cases {
            assert!(c.evals_per_sec > 0.0, "{}: {}", c.case, c.evals_per_sec);
            assert!(c.clients >= c.slots);
            assert_eq!(c.batch, 2);
            assert_eq!(c.threads, if c.oracle == "sharded" { 2 } else { 1 }, "{}", c.case);
        }
        // The mega shapes really are the 10k/100k/1M-client cases.
        let clients_of = |case: &str| {
            cases.iter().find(|c| c.case == case).map(|c| (c.clients, c.slots)).unwrap()
        };
        assert_eq!(clients_of("analytic/mega10k"), (10_021, 21));
        assert_eq!(clients_of("analytic/mega100k"), (100_021, 21));
        assert_eq!(clients_of("analytic/mega1M"), (1_000_021, 21));
        assert_eq!(clients_of("event-driven-delta/mega100k"), (100_021, 21));
        assert_eq!(clients_of("sharded/mega100k"), (100_021, 21));
        assert_eq!(clients_of("sharded/mega1M"), (1_000_021, 21));
        assert!(!cases.iter().any(|c| c.case == "event-driven/mega1M"));
        print_speedups(&cases);
    }

    #[test]
    fn validator_accepts_v1_documents_without_threads() {
        // A v1 baseline (no per-result threads) must stay readable.
        let v1 = Value::object(vec![
            ("suite", Value::from("eval")),
            ("version", Value::from(1usize)),
            ("samples", Value::from(1usize)),
            ("warmup", Value::from(0usize)),
            ("batch", Value::from(2usize)),
            (
                "results",
                Value::Array(vec![Value::object(vec![
                    ("case", Value::from("analytic/tiny")),
                    ("oracle", Value::from("analytic")),
                    ("shape", Value::from("tiny")),
                    ("clients", Value::from(7usize)),
                    ("slots", Value::from(3usize)),
                    ("batch", Value::from(2usize)),
                    ("evals_per_sec", Value::from(1.0)),
                    ("mean_us_per_batch", Value::from(1.0)),
                    ("p50_us", Value::from(1.0)),
                    ("p90_us", Value::from(1.0)),
                    ("std_us", Value::from(0.0)),
                ])]),
            ),
        ]);
        validate_bench_json(&v1).unwrap();
        // The same result row under version 2 must be rejected.
        let v2 = Value::object(
            v1.as_object()
                .unwrap()
                .iter()
                .map(|(k, v)| {
                    (k.as_str(), if k == "version" { Value::from(2usize) } else { v.clone() })
                })
                .collect(),
        );
        let err = validate_bench_json(&v2).unwrap_err();
        assert!(err.contains("threads"), "{err}");
    }

    #[test]
    fn json_roundtrips_and_validates() {
        let cfg = tiny_cfg();
        let cases = run_eval_suite(&cfg);
        let doc = suite_to_json(&cfg, &cases);
        validate_bench_json(&doc).unwrap();
        let parsed = json::parse(&json::to_string_pretty(&doc)).unwrap();
        validate_bench_json(&parsed).unwrap();
        // Write path self-checks too.
        let dir = std::env::temp_dir().join("repro_bench_eval_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("BENCH_eval.json");
        write_bench_json(&path, &cfg, &cases).unwrap();
        assert!(path.exists());
    }

    #[test]
    fn validator_rejects_malformed_documents() {
        assert!(validate_bench_json(&Value::object(vec![])).is_err());
        let wrong_suite = Value::object(vec![("suite", Value::from("foo"))]);
        assert!(validate_bench_json(&wrong_suite).is_err());
        let empty = Value::object(vec![
            ("suite", Value::from("eval")),
            ("version", Value::from(1usize)),
            ("samples", Value::from(1usize)),
            ("warmup", Value::from(0usize)),
            ("batch", Value::from(2usize)),
            ("results", Value::Array(vec![])),
        ]);
        let err = validate_bench_json(&empty).unwrap_err();
        assert!(err.contains("empty"), "{err}");
    }
}
