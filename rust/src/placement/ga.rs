//! Genetic-algorithm placement baseline (ablation A2).
//!
//! The paper's §II motivates PSO over GA via convergence speed
//! ("GA yields premature convergence" [23]); this implementation lets us
//! measure that claim under the identical black-box budget: a
//! steady-state GA that evaluates exactly one individual per FL round.
//!
//! Representation matches the PSO particle: a vector of distinct client
//! ids (one per slot). Operators: tournament selection, uniform
//! crossover with increment-until-unique repair (the same repair rule
//! the paper's PSO uses), and random-reset mutation.

use super::{Optimizer, OptimizerState, Placement, PlacementError};
use crate::prng::{Pcg32, Rng};

/// GA hyper-parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GaConfig {
    /// Population size (matched to the paper's PSO swarm: 10).
    pub population: usize,
    /// Tournament size for parent selection.
    pub tournament: usize,
    /// Per-gene mutation probability.
    pub mutation_rate: f64,
    /// Elite individuals copied unchanged each generation.
    pub elitism: usize,
}

impl Default for GaConfig {
    fn default() -> Self {
        GaConfig {
            population: 10,
            tournament: 3,
            mutation_rate: 0.1,
            elitism: 2,
        }
    }
}

struct Individual {
    genome: Vec<usize>,
    /// Delay (lower better); +inf until evaluated.
    delay: f64,
}

/// Steady-state GA under the black-box protocol.
pub struct GaPlacement {
    cfg: GaConfig,
    dims: usize,
    client_count: usize,
    population: Vec<Individual>,
    /// Next individual awaiting evaluation.
    cursor: usize,
    best: Vec<usize>,
    best_delay: f64,
    rng: Pcg32,
}

impl GaPlacement {
    pub fn new(dims: usize, client_count: usize, cfg: GaConfig, mut rng: Pcg32) -> Self {
        assert!(client_count >= dims);
        let population = (0..cfg.population)
            .map(|_| Individual {
                genome: rng.sample_distinct(client_count, dims),
                delay: f64::INFINITY,
            })
            .collect::<Vec<_>>();
        let best = population[0].genome.clone();
        GaPlacement {
            cfg,
            dims,
            client_count,
            population,
            cursor: 0,
            best,
            best_delay: f64::INFINITY,
            rng,
        }
    }

    /// Best (lowest) delay observed so far (`Optimizer::best` returns the
    /// matching placement).
    pub fn best_delay(&self) -> f64 {
        self.best_delay
    }

    fn tournament_pick(&mut self) -> usize {
        let mut winner = self.rng.gen_range(self.population.len() as u64) as usize;
        for _ in 1..self.cfg.tournament {
            let challenger = self.rng.gen_range(self.population.len() as u64) as usize;
            if self.population[challenger].delay < self.population[winner].delay {
                winner = challenger;
            }
        }
        winner
    }

    /// Uniform crossover + repair: child gene comes from either parent;
    /// duplicates resolved by incrementing until unique (the paper's
    /// repair rule, applied uniformly across optimizers for fairness).
    fn crossover(&mut self, a: usize, b: usize) -> Vec<usize> {
        let mut taken = vec![false; self.client_count];
        let mut child = Vec::with_capacity(self.dims);
        for d in 0..self.dims {
            let gene = if self.rng.next_f64() < 0.5 {
                self.population[a].genome[d]
            } else {
                self.population[b].genome[d]
            };
            let mut id = gene;
            while taken[id] {
                id = (id + 1) % self.client_count;
            }
            taken[id] = true;
            child.push(id);
        }
        child
    }

    fn mutate(&mut self, genome: &mut [usize]) {
        for d in 0..genome.len() {
            if self.rng.next_f64() < self.cfg.mutation_rate {
                let mut id = self.rng.gen_range(self.client_count as u64) as usize;
                while genome.contains(&id) {
                    id = (id + 1) % self.client_count;
                }
                genome[d] = id;
            }
        }
    }

    /// Breed the next generation once every individual has a delay.
    fn next_generation(&mut self) {
        let mut order: Vec<usize> = (0..self.population.len()).collect();
        order.sort_by(|&i, &j| {
            self.population[i]
                .delay
                .partial_cmp(&self.population[j].delay)
                .unwrap()
        });
        let mut next: Vec<Individual> = Vec::with_capacity(self.population.len());
        for &i in order.iter().take(self.cfg.elitism) {
            next.push(Individual {
                genome: self.population[i].genome.clone(),
                delay: self.population[i].delay, // elites keep their score
            });
        }
        while next.len() < self.population.len() {
            let a = self.tournament_pick();
            let b = self.tournament_pick();
            let mut child = self.crossover(a, b);
            self.mutate(&mut child);
            next.push(Individual {
                genome: child,
                delay: f64::INFINITY,
            });
        }
        self.population = next;
        // Elites keep scores; evaluation cursor resumes at the first
        // unevaluated child.
        self.cursor = self.cfg.elitism.min(self.population.len() - 1);
    }
}

impl Optimizer for GaPlacement {
    fn name(&self) -> &'static str {
        "ga"
    }

    /// The whole unevaluated cohort of the current generation — a real
    /// batch, so analytic environments score an entire generation in one
    /// dispatch (elites keep their scores and are not re-proposed).
    fn propose_batch(&mut self, _round: usize) -> Vec<Placement> {
        self.population[self.cursor..]
            .iter()
            .map(|ind| Placement::new(ind.genome.clone()))
            .collect()
    }

    fn observe_batch(&mut self, placements: &[Placement], delays: &[f64]) {
        for (p, &delay) in placements.iter().zip(delays) {
            debug_assert_eq!(p.as_slice(), self.population[self.cursor].genome.as_slice());
            self.population[self.cursor].delay = delay;
            if delay < self.best_delay {
                self.best_delay = delay;
                self.best = self.population[self.cursor].genome.clone();
            }
            // Advance to the next unevaluated individual, breeding a new
            // generation when the population is fully scored. A truncated
            // batch (budget boundary) simply leaves the cohort partially
            // scored; the next propose_batch resumes from the cursor.
            self.cursor += 1;
            if self.cursor >= self.population.len() {
                self.next_generation();
            }
        }
    }

    fn best(&self) -> Option<(Placement, f64)> {
        if self.best_delay.is_finite() {
            Some((Placement::new(self.best.clone()), self.best_delay))
        } else {
            None
        }
    }

    fn restore(&mut self, state: &OptimizerState) -> Result<(), PlacementError> {
        super::check_state_name(self.name(), state)?;
        if let Some((placement, delay)) = &state.best {
            super::validate_placement(placement, self.dims, self.client_count)?;
            // Re-seed individual 0 with the checkpointed incumbent so the
            // restored population keeps its best structure.
            self.best = placement.to_vec();
            self.best_delay = *delay;
            self.population[0].genome = placement.to_vec();
            self.population[0].delay = *delay;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::testkit;

    #[test]
    fn improves_on_toy_landscape() {
        let mut ga = GaPlacement::new(4, 25, GaConfig::default(), Pcg32::seed_from_u64(1));
        let delays =
            testkit::run_toy_validated(&mut ga, 4, 25, 200, |p| p.iter().sum::<usize>() as f64 + 1.0);
        let first_window: f64 = delays[..20].iter().sum();
        let last_window: f64 = delays[180..].iter().sum();
        assert!(
            last_window < first_window,
            "GA failed to improve: first {first_window}, last {last_window}"
        );
    }

    #[test]
    fn best_tracks_minimum() {
        let mut ga = GaPlacement::new(3, 12, GaConfig::default(), Pcg32::seed_from_u64(2));
        let delays = testkit::run_toy_validated(&mut ga, 3, 12, 80, |p| {
            p.iter().map(|&c| (c * c) as f64).sum::<f64>()
        });
        let min_seen = delays.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!((ga.best_delay() - min_seen).abs() < 1e-9);
    }

    #[test]
    fn genomes_stay_valid_across_generations() {
        let mut ga = GaPlacement::new(5, 9, GaConfig::default(), Pcg32::seed_from_u64(3));
        let mut counter = 0usize;
        testkit::run_toy_validated(&mut ga, 5, 9, 150, |_| {
            counter += 1;
            1.0 + (counter as f64) % 7.0
        });
    }

    #[test]
    fn first_batch_is_the_whole_population() {
        let mut ga = GaPlacement::new(3, 12, GaConfig::default(), Pcg32::seed_from_u64(4));
        let batch = ga.propose_batch(0);
        assert_eq!(batch.len(), GaConfig::default().population);
        // After scoring the cohort, the next batch skips the elites.
        let delays: Vec<f64> =
            batch.iter().map(|p| p.iter().sum::<usize>() as f64).collect();
        ga.observe_batch(&batch, &delays);
        let next = ga.propose_batch(1);
        assert_eq!(
            next.len(),
            GaConfig::default().population - GaConfig::default().elitism
        );
    }
}
