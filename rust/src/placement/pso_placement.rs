//! Flag-Swap: the paper's live PSO placement as an [`Optimizer`] —
//! a thin adapter over [`crate::pso::AsyncSwarm`] (one fitness
//! evaluation per FL round, see DESIGN.md §5).

use super::{Optimizer, OptimizerState, Placement, PlacementError};
use crate::prng::Pcg32;
use crate::pso::{AsyncSwarm, PsoConfig};

/// PSO-driven placement (the paper's contribution).
pub struct PsoPlacement {
    swarm: AsyncSwarm,
}

impl PsoPlacement {
    pub fn new(dims: usize, client_count: usize, cfg: PsoConfig, rng: Pcg32) -> Self {
        PsoPlacement {
            swarm: AsyncSwarm::new(dims, client_count, cfg, rng),
        }
    }

    /// Pure-exploration variant (pinning disabled) — used by the
    /// optimizer ablation to compare search quality under equal budgets
    /// without the deployment-time exploit phase.
    pub fn without_pinning(dims: usize, client_count: usize, cfg: PsoConfig, rng: Pcg32) -> Self {
        let mut swarm = AsyncSwarm::new(dims, client_count, cfg, rng);
        swarm.set_pinning(false);
        PsoPlacement { swarm }
    }

    /// Expose convergence for experiment logging (Fig. 4's "converged
    /// after the 10th round").
    pub fn pinned(&self) -> bool {
        self.swarm.pinned()
    }

    /// Best placement found so far.
    pub fn gbest(&self) -> Vec<usize> {
        self.swarm.gbest()
    }

    /// Best delay observed so far.
    pub fn gbest_delay(&self) -> f64 {
        self.swarm.gbest_delay()
    }
}

impl Optimizer for PsoPlacement {
    fn name(&self) -> &'static str {
        "pso"
    }

    fn propose_batch(&mut self, _round: usize) -> Vec<Placement> {
        vec![Placement::new(self.swarm.propose())]
    }

    fn observe_batch(&mut self, placements: &[Placement], delays: &[f64]) {
        for (p, &delay) in placements.iter().zip(delays) {
            debug_assert_eq!(
                p.as_slice(),
                self.swarm.propose().as_slice(),
                "feedback must follow the matching propose"
            );
            self.swarm.report(delay);
        }
    }

    fn best(&self) -> Option<(Placement, f64)> {
        if self.swarm.gbest_delay().is_finite() {
            Some((Placement::new(self.swarm.gbest()), self.swarm.gbest_delay()))
        } else {
            None
        }
    }

    fn converged(&self) -> bool {
        self.swarm.pinned()
    }

    fn restore(&mut self, state: &OptimizerState) -> Result<(), PlacementError> {
        super::check_state_name(self.name(), state)?;
        if let Some((placement, delay)) = &state.best {
            if placement.len() != self.swarm.dims() {
                return Err(PlacementError::WrongArity {
                    expected: self.swarm.dims(),
                    got: placement.len(),
                });
            }
            self.swarm.seed_gbest(placement, *delay);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::testkit;

    #[test]
    fn learns_toy_landscape() {
        let mut s = PsoPlacement::new(3, 15, PsoConfig::paper(), Pcg32::seed_from_u64(1));
        let delays =
            testkit::run_toy_validated(&mut s, 3, 15, 150, |p| p.iter().sum::<usize>() as f64 + 1.0);
        let last = *delays.last().unwrap();
        // Optimal is 0+1+2+1 = 4; accept anything clearly better than the
        // random expectation (~22).
        assert!(last <= 12.0, "final delay {last}");
        assert!(s.pinned());
    }

    #[test]
    fn restore_seeds_the_incumbent() {
        let mut a = PsoPlacement::new(3, 15, PsoConfig::paper(), Pcg32::seed_from_u64(2));
        testkit::run_toy_validated(&mut a, 3, 15, 60, |p| p.iter().sum::<usize>() as f64 + 1.0);
        let snap = a.state();
        let mut b = PsoPlacement::new(3, 15, PsoConfig::paper(), Pcg32::seed_from_u64(3));
        b.restore(&snap).unwrap();
        assert_eq!(b.gbest(), a.gbest());
        assert!((b.gbest_delay() - a.gbest_delay()).abs() < 1e-12);
    }
}
