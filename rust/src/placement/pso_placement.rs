//! Flag-Swap: the paper's PSO placement as a [`PlacementStrategy`] —
//! a thin adapter over [`crate::pso::AsyncSwarm`] (one fitness
//! evaluation per FL round, see DESIGN.md §5).

use super::PlacementStrategy;
use crate::prng::Pcg32;
use crate::pso::{AsyncSwarm, PsoConfig};

/// PSO-driven placement (the paper's contribution).
pub struct PsoPlacement {
    swarm: AsyncSwarm,
}

impl PsoPlacement {
    pub fn new(dims: usize, client_count: usize, cfg: PsoConfig, rng: Pcg32) -> Self {
        PsoPlacement {
            swarm: AsyncSwarm::new(dims, client_count, cfg, rng),
        }
    }

    /// Pure-exploration variant (pinning disabled) — used by the
    /// optimizer ablation to compare search quality under equal budgets
    /// without the deployment-time exploit phase.
    pub fn without_pinning(dims: usize, client_count: usize, cfg: PsoConfig, rng: Pcg32) -> Self {
        let mut swarm = AsyncSwarm::new(dims, client_count, cfg, rng);
        swarm.set_pinning(false);
        PsoPlacement { swarm }
    }

    /// Expose convergence for experiment logging (Fig. 4's "converged
    /// after the 10th round").
    pub fn pinned(&self) -> bool {
        self.swarm.pinned()
    }

    /// Best placement found so far.
    pub fn gbest(&self) -> Vec<usize> {
        self.swarm.gbest()
    }

    /// Best delay observed so far.
    pub fn gbest_delay(&self) -> f64 {
        self.swarm.gbest_delay()
    }
}

impl PlacementStrategy for PsoPlacement {
    fn name(&self) -> &'static str {
        "pso"
    }

    fn propose(&mut self, _round: usize) -> Vec<usize> {
        self.swarm.propose()
    }

    fn feedback(&mut self, placement: &[usize], delay_secs: f64) {
        debug_assert_eq!(
            placement,
            self.swarm.propose().as_slice(),
            "feedback must follow the matching propose()"
        );
        self.swarm.report(delay_secs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn learns_toy_landscape() {
        let mut s = PsoPlacement::new(3, 15, PsoConfig::paper(), Pcg32::seed_from_u64(1));
        let mut last = f64::INFINITY;
        for round in 0..150 {
            let p = s.propose(round);
            let d = p.iter().sum::<usize>() as f64 + 1.0;
            s.feedback(&p, d);
            last = d;
        }
        // Optimal is 0+1+2+1 = 4; accept anything clearly better than the
        // random expectation (~22).
        assert!(last <= 12.0, "final delay {last}");
        assert!(s.pinned());
    }
}
