//! String-keyed strategy registry: one place that maps strategy names to
//! boxed [`Optimizer`]s, shared by `repro sim`, `repro compare`, the
//! benches and the examples — so every strategy runs in every
//! environment through the same factory.
//!
//! Canonical names (see [`NAMES`]): `pso`, `pso-batched`, `random`,
//! `round-robin`, `ga`, `sa`, `tabu`, `adaptive-pso`, `sharded-pso`.
//! Aliases accepted for backward compatibility: `uniform` →
//! `round-robin`, `pso-adaptive` → `adaptive-pso`, and
//! `flag-swap-sharded` / `sharded` → `sharded-pso`.

use super::{
    AdaptivePsoPlacement, AnalyticTpd, Environment, EventDrivenEnv, GaConfig, GaPlacement,
    Optimizer, PlacementError, PsoPlacement, RandomPlacement, RoundRobinPlacement, SaConfig,
    SaPlacement, ShardedConfig, ShardedPso, SwarmOptimizer, TabuConfig, TabuPlacement,
};
use crate::configio::SimScenario;
use crate::fitness::ClientAttrs;
use crate::hierarchy::HierarchySpec;
use crate::prng::Pcg32;
use crate::pso::PsoConfig;

/// Every registered strategy name, in presentation order.
pub const NAMES: [&str; 9] = [
    "pso",
    "pso-batched",
    "random",
    "round-robin",
    "ga",
    "sa",
    "tabu",
    "adaptive-pso",
    "sharded-pso",
];

/// Every registered simulation-tier environment (delay oracle) name.
/// Aliases: `analytic-tpd`/`tpd` → `analytic`, `des`/`event` →
/// `event-driven`.
pub const ENV_NAMES: [&str; 2] = ["analytic", "event-driven"];

/// Resolve a (possibly aliased) name to its canonical registry key.
pub fn canonical(name: &str) -> Result<&'static str, PlacementError> {
    match name {
        "pso" => Ok("pso"),
        "pso-batched" => Ok("pso-batched"),
        "random" => Ok("random"),
        "round-robin" | "uniform" => Ok("round-robin"),
        "ga" => Ok("ga"),
        "sa" => Ok("sa"),
        "tabu" => Ok("tabu"),
        "adaptive-pso" | "pso-adaptive" => Ok("adaptive-pso"),
        "sharded-pso" | "flag-swap-sharded" | "sharded" => Ok("sharded-pso"),
        other => Err(PlacementError::UnknownStrategy { name: other.to_string() }),
    }
}

/// Resolve a (possibly aliased) environment name to its canonical key.
pub fn canonical_env(name: &str) -> Result<&'static str, PlacementError> {
    match name {
        "analytic" | "analytic-tpd" | "tpd" => Ok("analytic"),
        "event-driven" | "des" | "event" => Ok("event-driven"),
        other => Err(PlacementError::UnknownEnvironment { name: other.to_string() }),
    }
}

/// Build a simulation-tier delay oracle over an already-sampled
/// population: `analytic` is the closed-form Eq. 6–7 [`AnalyticTpd`],
/// `event-driven` is the [`crate::des`] virtual-time simulator
/// configured from the scenario's `[des]`/`[net]`/`[dynamics]`
/// extensions. Every registry strategy runs against either through the
/// same [`super::drive`] loop.
pub fn build_sim_env(
    name: &str,
    sc: &SimScenario,
    attrs: Vec<ClientAttrs>,
) -> Result<Box<dyn Environment>, PlacementError> {
    let spec = HierarchySpec::new(sc.depth, sc.width);
    Ok(match canonical_env(name)? {
        "analytic" => Box::new(AnalyticTpd::new(spec, attrs)),
        "event-driven" => Box::new(EventDrivenEnv::from_scenario(sc, attrs)),
        _ => unreachable!("canonical_env() covers every environment key"),
    })
}

/// Build a simulation-mode optimizer for a scenario: `pso` is the
/// paper's synchronous Algorithm-1 swarm ([`SwarmOptimizer::exact`],
/// reproducing the legacy `run_sim` trace for the same seed), and the
/// RNG stream is supplied by the caller so the simulation pipeline can
/// split it off the population sampler.
pub fn build_sim(
    name: &str,
    sc: &SimScenario,
    rng: Pcg32,
) -> Result<Box<dyn Optimizer>, PlacementError> {
    let dims = sc.dimensions();
    let cc = sc.client_count();
    Ok(match canonical(name)? {
        "pso" => Box::new(SwarmOptimizer::exact(dims, cc, sc.pso, rng)),
        "pso-batched" => Box::new(SwarmOptimizer::batched(dims, cc, sc.pso, rng)),
        "random" => Box::new(RandomPlacement::new(dims, cc, rng)),
        "round-robin" => Box::new(RoundRobinPlacement::new(dims, cc)),
        "ga" => Box::new(GaPlacement::new(dims, cc, GaConfig::default(), rng)),
        "sa" => Box::new(SaPlacement::new(dims, cc, SaConfig::default(), rng)),
        "tabu" => Box::new(TabuPlacement::new(dims, cc, TabuConfig::default(), rng)),
        "adaptive-pso" => Box::new(AdaptivePsoPlacement::new(dims, cc, sc.pso, rng)),
        "sharded-pso" => Box::new(ShardedPso::from_spec(
            HierarchySpec::new(sc.depth, sc.width),
            cc,
            ShardedConfig::from_pso(&sc.pso),
            rng,
        )),
        _ => unreachable!("canonical() covers every registry key"),
    })
}

/// Build a simulation-mode optimizer from a scenario + seed (the
/// CLI-facing factory).
pub fn build(name: &str, sc: &SimScenario, seed: u64) -> Result<Box<dyn Optimizer>, PlacementError> {
    build_sim(name, sc, Pcg32::seed_from_u64(seed))
}

/// Build a live/deployment-mode optimizer: `pso` is Flag-Swap's
/// steady-state [`PsoPlacement`] (one evaluation per FL round, gbest
/// pinning after convergence — the Fig-4 behavior).
pub fn build_live(
    name: &str,
    dims: usize,
    client_count: usize,
    pso: PsoConfig,
    seed: u64,
) -> Result<Box<dyn Optimizer>, PlacementError> {
    let rng = Pcg32::seed_from_u64(seed);
    Ok(match canonical(name)? {
        "pso" => Box::new(PsoPlacement::new(dims, client_count, pso, rng)),
        "pso-batched" => Box::new(SwarmOptimizer::batched(dims, client_count, pso, rng)),
        "random" => Box::new(RandomPlacement::new(dims, client_count, rng)),
        "round-robin" => Box::new(RoundRobinPlacement::new(dims, client_count)),
        "ga" => Box::new(GaPlacement::new(dims, client_count, GaConfig::default(), rng)),
        "sa" => Box::new(SaPlacement::new(dims, client_count, SaConfig::default(), rng)),
        "tabu" => Box::new(TabuPlacement::new(dims, client_count, TabuConfig::default(), rng)),
        "adaptive-pso" => Box::new(AdaptivePsoPlacement::new(dims, client_count, pso, rng)),
        "sharded-pso" => Box::new(ShardedPso::for_dims(
            dims,
            client_count,
            ShardedConfig::from_pso(&pso),
            rng,
        )),
        _ => unreachable!("canonical() covers every registry key"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_registered_name_round_trips() {
        let sc = SimScenario { depth: 2, width: 2, ..SimScenario::default() };
        for name in NAMES {
            let opt = build(name, &sc, 42).unwrap_or_else(|e| panic!("build({name}): {e}"));
            assert_eq!(opt.name(), name, "canonical name must round-trip");
            let live = build_live(name, 3, 10, PsoConfig::paper(), 42)
                .unwrap_or_else(|e| panic!("build_live({name}): {e}"));
            assert_eq!(live.name(), name);
        }
    }

    #[test]
    fn aliases_resolve_to_canonical_strategies() {
        let uniform = build_live("uniform", 3, 10, PsoConfig::paper(), 1).unwrap();
        assert_eq!(uniform.name(), "round-robin");
        let adaptive = build_live("pso-adaptive", 3, 10, PsoConfig::paper(), 1).unwrap();
        assert_eq!(adaptive.name(), "adaptive-pso");
        let sharded = build_live("flag-swap-sharded", 3, 10, PsoConfig::paper(), 1).unwrap();
        assert_eq!(sharded.name(), "sharded-pso");
    }

    /// Exhaustive spelling coverage: every canonical name AND every
    /// alias in the strategy + environment tables resolves, and each
    /// resolved strategy builds through all three factories.
    #[test]
    fn every_spelling_resolves_and_builds() {
        let strategy_spellings: &[(&str, &str)] = &[
            ("pso", "pso"),
            ("pso-batched", "pso-batched"),
            ("random", "random"),
            ("round-robin", "round-robin"),
            ("uniform", "round-robin"),
            ("ga", "ga"),
            ("sa", "sa"),
            ("tabu", "tabu"),
            ("adaptive-pso", "adaptive-pso"),
            ("pso-adaptive", "adaptive-pso"),
            ("sharded-pso", "sharded-pso"),
            ("flag-swap-sharded", "sharded-pso"),
            ("sharded", "sharded-pso"),
        ];
        // Every canonical name must appear as its own spelling.
        for name in NAMES {
            assert!(
                strategy_spellings.iter().any(|&(s, c)| s == name && c == name),
                "spelling table must cover canonical {name}"
            );
        }
        let sc = SimScenario { depth: 2, width: 2, ..SimScenario::default() };
        for &(spelling, want) in strategy_spellings {
            assert_eq!(canonical(spelling).unwrap(), want, "canonical({spelling})");
            let sim = build(spelling, &sc, 3).unwrap_or_else(|e| panic!("build({spelling}): {e}"));
            assert_eq!(sim.name(), want);
            let live = build_live(spelling, 3, 10, PsoConfig::paper(), 3)
                .unwrap_or_else(|e| panic!("build_live({spelling}): {e}"));
            assert_eq!(live.name(), want);
        }

        let env_spellings: &[(&str, &str)] = &[
            ("analytic", "analytic"),
            ("analytic-tpd", "analytic"),
            ("tpd", "analytic"),
            ("event-driven", "event-driven"),
            ("des", "event-driven"),
            ("event", "event-driven"),
        ];
        for name in ENV_NAMES {
            assert!(
                env_spellings.iter().any(|&(s, c)| s == name && c == name),
                "env spelling table must cover canonical {name}"
            );
        }
        let mut rng = Pcg32::seed_from_u64(1);
        let attrs = ClientAttrs::sample_population(
            sc.client_count(),
            sc.pspeed_range,
            sc.memcap_range,
            sc.mdatasize,
            &mut rng,
        );
        for &(spelling, want) in env_spellings {
            assert_eq!(canonical_env(spelling).unwrap(), want, "canonical_env({spelling})");
            let env = build_sim_env(spelling, &sc, attrs.clone())
                .unwrap_or_else(|e| panic!("build_sim_env({spelling}): {e}"));
            // Oracle self-names are stable per canonical key (the
            // analytic oracle reports its historical "analytic-tpd").
            let oracle = match want {
                "analytic" => "analytic-tpd",
                other => other,
            };
            assert_eq!(env.name(), oracle, "{spelling}");
        }
    }

    #[test]
    fn unknown_name_lists_valid_strategies() {
        let err = build_live("simulated-annealing", 3, 10, PsoConfig::paper(), 1).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown strategy"), "{msg}");
        // The error is actionable: it names the valid keys.
        for name in NAMES {
            assert!(msg.contains(name), "error should list {name:?}: {msg}");
        }
    }

    #[test]
    fn env_names_round_trip_and_reject_unknowns() {
        for name in ENV_NAMES {
            assert_eq!(canonical_env(name).unwrap(), name);
        }
        assert_eq!(canonical_env("des").unwrap(), "event-driven");
        assert_eq!(canonical_env("tpd").unwrap(), "analytic");
        let err = canonical_env("docker").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("event-driven"), "{msg}");
    }

    #[test]
    fn every_environment_scores_every_strategy() {
        use crate::fitness::ClientAttrs;
        use crate::placement::drive;
        let mut sc = SimScenario { depth: 2, width: 2, ..SimScenario::default() };
        sc.pso.particles = 3;
        sc.pso.iterations = 4;
        let mut rng = Pcg32::seed_from_u64(sc.seed);
        let attrs = ClientAttrs::sample_population(
            sc.client_count(),
            sc.pspeed_range,
            sc.memcap_range,
            sc.mdatasize,
            &mut rng,
        );
        for env_name in ENV_NAMES {
            for name in NAMES {
                let mut opt = build_sim(name, &sc, rng.split()).unwrap();
                let mut env = build_sim_env(env_name, &sc, attrs.clone()).unwrap();
                let out = drive(opt.as_mut(), env.as_mut(), 12)
                    .unwrap_or_else(|e| panic!("{env_name}/{name}: {e}"));
                assert_eq!(out.evaluations, 12);
                assert!(out.best_delay.is_finite() && out.best_delay > 0.0);
            }
        }
    }

    #[test]
    fn built_optimizers_propose_valid_placements() {
        let sc = SimScenario { depth: 2, width: 2, ..SimScenario::default() };
        let dims = sc.dimensions();
        let cc = sc.client_count();
        for name in NAMES {
            let mut opt = build(name, &sc, 7).unwrap();
            crate::placement::testkit::run_toy_validated(opt.as_mut(), dims, cc, 30, |p| {
                p.iter().sum::<usize>() as f64 + 1.0
            });
        }
    }
}
