//! Delay oracles — the [`Environment`] side of the Optimizer/Environment
//! split.
//!
//! An environment turns a candidate [`Placement`] into the paper's
//! black-box signal: the round's processing delay. Four implementations
//! cover the repo's execution tiers:
//!
//! * [`AnalyticTpd`] — the closed-form Eq. 6–7 TPD model over a sampled
//!   client population (the Fig-3 simulation fitness). Its `eval_batch`
//!   scores a whole swarm in one dispatch.
//! * [`crate::des::EventDrivenEnv`] — a discrete-event virtual-time
//!   round over a contended network with churn/dropout/straggler
//!   dynamics; in its all-off conformance configuration it reproduces
//!   [`AnalyticTpd`] exactly (registry name `event-driven`).
//! * [`EmulatedDelay`] — a calibrated analytic model of the emulated
//!   docker testbed, built from the same throttle factors
//!   [`crate::fl::emulation::EmulatedClock`] applies to real compute
//!   (speed factor on training, speed × memory pressure on aggregation).
//! * [`crate::fl::LiveSession`] — a *real* measured FL round through the
//!   broker + agent + runtime stack (defined next to the coordinator).
//!
//! ## The zero-allocation hot path
//!
//! The analytic oracles own reusable scratch state
//! ([`crate::fitness::TpdScratch`] / [`crate::hierarchy::EvalScratch`])
//! instead of materializing an [`Arrangement`] per candidate, so a
//! steady-state `eval_batch` performs no heap allocation beyond its
//! result vector — the difference between thousands and millions of
//! evaluations per second at 10k-client populations (`repro bench
//! --suite eval` tracks this). [`AnalyticTpd`] additionally recognizes
//! **single-coordinate neighbors** of the last fully-evaluated
//! placement — exactly what [`super::SaPlacement`],
//! [`super::TabuPlacement`] and [`super::AdaptivePsoPlacement`]'s
//! pinned probing propose — and scores them through the delta fast
//! path, which re-sums only the clusters the swap touches. Every fast
//! path is bit-identical to the legacy `tpd(&Arrangement::..)` pipeline
//! (property-tested in `tests/properties.rs`).

use super::{Placement, PlacementError};
use crate::configio::ClientSpec;
use crate::fitness::{ClientAttrs, TpdScratch};
use crate::fl::emulation::{EmulatedClock, WorkKind};
use crate::hierarchy::{EvalScratch, HierarchySpec};
use crate::obs::defs as obs;

/// Plain (non-atomic) per-dispatch eval-path tally: the hot loop bumps
/// local integers, one [`PathTally::flush`] per `eval`/`eval_batch`
/// dispatch turns them into a handful of relaxed atomic adds — so
/// telemetry costs nothing measurable at millions of evals/sec and
/// adds zero allocations (pinned by `tests/alloc_guard.rs`).
#[derive(Default)]
pub(crate) struct PathTally {
    pub(crate) same: u64,
    pub(crate) delta: u64,
    pub(crate) full: u64,
}

impl PathTally {
    #[inline]
    pub(crate) fn flush(&self, evals: u64) {
        obs::PLACEMENT_EVALS.add(evals);
        obs::PLACEMENT_CACHE_HITS.add(self.same);
        obs::PLACEMENT_DELTA_EVALS.add(self.delta);
        obs::PLACEMENT_FULL_EVALS.add(self.full);
    }
}

/// A delay oracle: scores candidate placements. `Send` so boxed oracles
/// can move into scheduler workers (the service tier runs one session —
/// optimizer + environment — per worker thread).
pub trait Environment: Send {
    /// Environment label for logs and CSV output.
    fn name(&self) -> &'static str;

    /// Delay of one placement (seconds, or TPD units for analytic
    /// environments — the optimizers only compare magnitudes).
    fn eval(&mut self, placement: &Placement) -> Result<f64, PlacementError>;

    /// Delays for a batch of placements, in order. The default loops
    /// over [`Environment::eval`]; analytic environments override this
    /// to score the whole batch in one dispatch.
    fn eval_batch(&mut self, batch: &[Placement]) -> Result<Vec<f64>, PlacementError> {
        batch.iter().map(|p| self.eval(p)).collect()
    }
}

/// How a candidate differs from a cached base position.
pub(crate) enum Diff {
    /// Identical to the base.
    Same,
    /// Exactly one slot changed to a client outside the base placement.
    Replace { slot: usize, client: usize },
    /// Exactly two slots exchanged their base clients.
    Swap { i: usize, j: usize },
    /// Anything else: evaluate in full.
    Full,
}

/// Classify a *validated* candidate against the cached base position.
///
/// Note the `Replace` invariant: because both positions passed
/// validation (distinct clients), the incoming client can never be one
/// of the base's other aggregators — a replace-by-existing-aggregator
/// would duplicate that client in the candidate and fail `validate`
/// before classification ever runs.
pub(crate) fn classify(base: &[usize], candidate: &[usize]) -> Diff {
    debug_assert_eq!(base.len(), candidate.len());
    let (mut first, mut second) = (None, None);
    for (s, (&b, &c)) in base.iter().zip(candidate).enumerate() {
        if b != c {
            match (first, second) {
                (None, _) => first = Some(s),
                (Some(_), None) => second = Some(s),
                _ => return Diff::Full,
            }
        }
    }
    match (first, second) {
        (None, _) => Diff::Same,
        (Some(k), None) => Diff::Replace { slot: k, client: candidate[k] },
        (Some(i), Some(j)) => {
            if candidate[i] == base[j] && candidate[j] == base[i] {
                Diff::Swap { i, j }
            } else {
                Diff::Full
            }
        }
    }
}

/// The Eq. 6–7 Total Processing Delay model over a simulated population
/// (paper §IV.A/B) — the fitness behind Fig. 3.
pub struct AnalyticTpd {
    attrs: Vec<ClientAttrs>,
    scratch: TpdScratch,
}

impl AnalyticTpd {
    pub fn new(spec: HierarchySpec, attrs: Vec<ClientAttrs>) -> AnalyticTpd {
        assert!(attrs.len() >= spec.dimensions(), "population smaller than slot count");
        let scratch = TpdScratch::new(spec, attrs.len());
        AnalyticTpd { attrs, scratch }
    }

    /// The simulated client population.
    pub fn attrs(&self) -> &[ClientAttrs] {
        &self.attrs
    }

    /// Score one *validated* placement. Single-coordinate neighbors of
    /// the cached base position take the delta fast path; everything
    /// else is a full (still allocation-free) streaming evaluation that
    /// becomes the new base.
    fn tpd_of(&mut self, placement: &[usize], tally: &mut PathTally) -> f64 {
        if self.scratch.loaded() {
            match classify(self.scratch.position(), placement) {
                Diff::Same => {
                    tally.same += 1;
                    return self.scratch.total();
                }
                Diff::Replace { slot, client } => {
                    // Unreachable for an existing aggregator: such a
                    // candidate duplicates `client` and fails `validate`
                    // first (see `classify`) — so *every* valid replace
                    // neighbor takes the delta path.
                    debug_assert!(
                        !self.scratch.is_aggregator(client),
                        "validated replace target {client} already placed"
                    );
                    tally.delta += 1;
                    return self.scratch.delta_replace(slot, client, &self.attrs);
                }
                Diff::Swap { i, j } => {
                    tally.delta += 1;
                    return self.scratch.delta_swap(i, j, &self.attrs);
                }
                _ => {}
            }
        }
        tally.full += 1;
        self.scratch.eval_prevalidated(placement, &self.attrs)
    }
}

impl Environment for AnalyticTpd {
    fn name(&self) -> &'static str {
        "analytic-tpd"
    }

    fn eval(&mut self, placement: &Placement) -> Result<f64, PlacementError> {
        self.scratch.validate(placement)?;
        let mut tally = PathTally::default();
        let delay = self.tpd_of(placement, &mut tally);
        tally.flush(1);
        Ok(delay)
    }

    fn eval_batch(&mut self, batch: &[Placement]) -> Result<Vec<f64>, PlacementError> {
        // One dispatch for the whole batch: validate everything first
        // (against the reusable bitset — no per-candidate allocation),
        // then score in a tight loop (no per-candidate virtual calls).
        for p in batch {
            self.scratch.validate(p)?;
        }
        let mut delays = Vec::with_capacity(batch.len());
        let mut tally = PathTally::default();
        for p in batch {
            delays.push(self.tpd_of(p, &mut tally));
        }
        tally.flush(batch.len() as u64);
        Ok(delays)
    }
}

/// Analytic delay model of the emulated heterogeneous testbed
/// (DESIGN.md §4): what a round *would* cost given each client's
/// [`EmulatedClock`] throttle factors, without running broker traffic or
/// training. Useful for fast registry-driven experiments on deployment
/// scenarios.
///
/// The model mirrors the real round structure: *every* client trains in
/// parallel — leaf trainers and aggregators alike (the paper's
/// "agtrainers" train too, which is also why phase 2 merges `fan-in + 1`
/// models) — so the slowest client in the population gates the start of
/// aggregation regardless of who fills which slot. Then each hierarchy
/// level aggregates bottom-up (slowest cluster gates its level; cluster
/// cost scales with fan-in, aggregation pays the memory-pressure
/// factor). Like [`AnalyticTpd`] it evaluates over a reusable
/// [`EvalScratch`] view — no arrangement is materialized per candidate —
/// and since the training gate is placement-independent and per-slot
/// fan-ins are fixed by the population size, a full evaluation is
/// O(slots), with [`classify`]-routed replace/swap delta fast paths that
/// re-fold only the touched levels (bit-identical to the full path,
/// property-tested).
pub struct EmulatedDelay {
    spec: HierarchySpec,
    clocks: Vec<EmulatedClock>,
    scratch: EvalScratch,
    /// Seconds of full-speed compute one local training phase costs.
    pub train_unit_secs: f64,
    /// Seconds of full-speed compute per model merged during aggregation.
    pub agg_unit_secs: f64,
    /// Slowest Train throttle factor in the population. Every client
    /// trains (aggregators are agtrainers), so the phase-1 gate is
    /// `train_factor_max * train_unit_secs` for every placement.
    train_factor_max: f64,
    /// Per-slot merge fan-in (children + the slot's own model). Leaf
    /// partition *sizes* depend only on the population size, never on
    /// which clients land where, so this is fixed at construction.
    fan_in: Vec<f64>,
    /// Delta-path base state (mirrors [`TpdScratch`]): the last fully
    /// evaluated placement with its per-slot delays and per-level maxima.
    base: Vec<usize>,
    slot_delay: Vec<f64>,
    level_max: Vec<f64>,
    base_total: f64,
    base_loaded: bool,
    /// The `(train, agg)` unit values the base was computed with — the
    /// unit fields are `pub`, and mutating them invalidates the cache.
    base_units: (f64, f64),
}

impl EmulatedDelay {
    pub fn new(depth: usize, width: usize, clients: &[ClientSpec]) -> EmulatedDelay {
        let spec = HierarchySpec::new(depth, width);
        let dims = spec.dimensions();
        assert!(clients.len() >= dims, "population smaller than slot count");
        let clocks: Vec<EmulatedClock> =
            clients.iter().map(|c| EmulatedClock::new(c.clone())).collect();
        let train_factor_max = clocks
            .iter()
            .map(|c| c.factor(WorkKind::Train))
            .fold(0.0f64, f64::max);
        // Leaf fan-ins come from the scratch's own round-robin partition
        // (loaded once with an arbitrary valid placement) so the sizes
        // can never drift from the partition the other oracles see.
        let mut scratch = EvalScratch::new(spec, clients.len());
        let ident: Vec<usize> = (0..dims).collect();
        scratch.load_prevalidated(&ident);
        let leaf_start = scratch.leaf_start();
        let fan_in: Vec<f64> = (0..dims)
            .map(|s| {
                if s >= leaf_start {
                    (scratch.leaf_trainers(s - leaf_start).len() + 1) as f64
                } else {
                    (spec.children(s).len() + 1) as f64
                }
            })
            .collect();
        EmulatedDelay {
            spec,
            clocks,
            scratch,
            train_unit_secs: 1.0,
            agg_unit_secs: 0.5,
            train_factor_max,
            fan_in,
            base: Vec::with_capacity(dims),
            slot_delay: vec![0.0; dims],
            level_max: vec![0.0; spec.depth],
            base_total: 0.0,
            base_loaded: false,
            base_units: (1.0, 0.5),
        }
    }

    /// Build for a deployment scenario's hierarchy and client mix.
    pub fn from_scenario(sc: &crate::configio::DeployScenario) -> EmulatedDelay {
        EmulatedDelay::new(sc.depth, sc.width, &sc.clients)
    }

    /// Phase-2 merge delay of `slot` when hosted by client `agg`.
    #[inline]
    fn slot_delay_of(&self, slot: usize, agg: usize) -> f64 {
        self.clocks[agg].factor(WorkKind::Aggregate) * self.agg_unit_secs * self.fan_in[slot]
    }

    /// Full evaluation: rebuild the per-slot/per-level caches and make
    /// `placement` the new delta base.
    fn load_full(&mut self, placement: &[usize]) -> f64 {
        self.base.clear();
        self.base.extend_from_slice(placement);
        let mut total = self.train_factor_max * self.train_unit_secs;
        for l in (0..self.spec.depth).rev() {
            let mut m = 0.0f64;
            for slot in self.spec.level_slots(l) {
                let d = self.slot_delay_of(slot, placement[slot]);
                self.slot_delay[slot] = d;
                m = m.max(d);
            }
            self.level_max[l] = m;
            total += m;
        }
        self.base_total = total;
        self.base_loaded = true;
        self.base_units = (self.train_unit_secs, self.agg_unit_secs);
        total
    }

    /// Non-mutating delta excursion: total with slots `s1`/`s2` scored
    /// as `d1`/`d2` (pass `s1 == s2` for a single replace). Touched
    /// levels are re-folded in the exact full-path slot order, untouched
    /// levels reuse their cached maxima — so the sum is performed in the
    /// same order over the same values and stays bit-identical.
    fn delta_total(&self, s1: usize, d1: f64, s2: usize, d2: f64) -> f64 {
        let (l1, l2) = (self.spec.level_of(s1), self.spec.level_of(s2));
        let mut total = self.train_factor_max * self.train_unit_secs;
        for l in (0..self.spec.depth).rev() {
            let m = if l == l1 || l == l2 {
                let mut m = 0.0f64;
                for s in self.spec.level_slots(l) {
                    let d = if s == s1 {
                        d1
                    } else if s == s2 {
                        d2
                    } else {
                        self.slot_delay[s]
                    };
                    m = m.max(d);
                }
                m
            } else {
                self.level_max[l]
            };
            total += m;
        }
        total
    }

    /// Score one *validated* placement, routing single-coordinate
    /// neighbors of the cached base through the delta fast path.
    fn delay_of(&mut self, placement: &[usize], tally: &mut PathTally) -> f64 {
        if self.base_loaded && self.base_units == (self.train_unit_secs, self.agg_unit_secs) {
            match classify(&self.base, placement) {
                Diff::Same => {
                    tally.same += 1;
                    return self.base_total;
                }
                Diff::Replace { slot, client } => {
                    debug_assert!(
                        !self.base.contains(&client),
                        "validated replace target {client} already placed"
                    );
                    tally.delta += 1;
                    let d = self.slot_delay_of(slot, client);
                    return self.delta_total(slot, d, slot, d);
                }
                Diff::Swap { i, j } => {
                    tally.delta += 1;
                    let di = self.slot_delay_of(i, self.base[j]);
                    let dj = self.slot_delay_of(j, self.base[i]);
                    return self.delta_total(i, di, j, dj);
                }
                Diff::Full => {}
            }
        }
        tally.full += 1;
        self.load_full(placement)
    }
}

impl Environment for EmulatedDelay {
    fn name(&self) -> &'static str {
        "emulated-delay"
    }

    fn eval(&mut self, placement: &Placement) -> Result<f64, PlacementError> {
        self.scratch.validate(placement)?;
        let mut tally = PathTally::default();
        let delay = self.delay_of(placement, &mut tally);
        tally.flush(1);
        Ok(delay)
    }

    fn eval_batch(&mut self, batch: &[Placement]) -> Result<Vec<f64>, PlacementError> {
        for p in batch {
            self.scratch.validate(p)?;
        }
        let mut delays = Vec::with_capacity(batch.len());
        let mut tally = PathTally::default();
        for p in batch {
            delays.push(self.delay_of(p, &mut tally));
        }
        tally.flush(batch.len() as u64);
        Ok(delays)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configio::DeployScenario;
    use crate::fitness::tpd;
    use crate::hierarchy::Arrangement;
    use crate::prng::{Pcg32, Rng};

    fn population(n: usize) -> Vec<ClientAttrs> {
        let mut rng = Pcg32::seed_from_u64(1);
        ClientAttrs::sample_population(n, (5.0, 15.0), (10.0, 50.0), 5.0, &mut rng)
    }

    #[test]
    fn analytic_batch_matches_single_evals() {
        let spec = HierarchySpec::new(2, 2);
        let mut env = AnalyticTpd::new(spec, population(8));
        let batch: Vec<Placement> = vec![
            Placement::new(vec![0, 1, 2]),
            Placement::new(vec![5, 6, 7]),
            Placement::new(vec![3, 0, 4]),
        ];
        let batched = env.eval_batch(&batch).unwrap();
        let singles: Vec<f64> = batch.iter().map(|p| env.eval(p).unwrap()).collect();
        assert_eq!(batched, singles);
        assert!(batched.iter().all(|&t| t > 0.0));
    }

    #[test]
    fn analytic_matches_the_legacy_arrangement_pipeline() {
        // The scratch path must reproduce tpd(&from_position(..)) bit
        // for bit, including across the >64-client bitset fallback.
        for cc in [8usize, 70] {
            let spec = HierarchySpec::new(2, 2);
            let attrs = population(cc);
            let mut env = AnalyticTpd::new(spec, attrs.clone());
            let mut rng = Pcg32::seed_from_u64(9);
            for _ in 0..20 {
                let pos = rng.sample_distinct(cc, 3);
                let got = env.eval(&Placement::new(pos.clone())).unwrap();
                let want = tpd(&Arrangement::from_position(spec, &pos, cc), &attrs).total;
                assert_eq!(got.to_bits(), want.to_bits());
            }
        }
    }

    #[test]
    fn delta_fast_path_scores_neighbors_bit_identically() {
        let spec = HierarchySpec::new(3, 2);
        let cc = 40;
        let attrs = population(cc);
        let mut env = AnalyticTpd::new(spec, attrs.clone());
        let mut rng = Pcg32::seed_from_u64(4);
        let base: Vec<usize> = rng.sample_distinct(cc, 7);
        env.eval(&Placement::new(base.clone())).unwrap();
        for _ in 0..40 {
            // Single-slot replacement neighbor (the SA/tabu/probe move).
            let slot = rng.gen_range(7) as usize;
            let mut id = rng.gen_range(cc as u64) as usize;
            while base.contains(&id) {
                id = (id + 1) % cc;
            }
            let mut neighbor = base.clone();
            neighbor[slot] = id;
            let got = env.eval(&Placement::new(neighbor.clone())).unwrap();
            let want = tpd(&Arrangement::from_position(spec, &neighbor, cc), &attrs).total;
            assert_eq!(got.to_bits(), want.to_bits(), "replace {slot}->{id}");
            // Two-slot swap neighbor (SA's other move).
            let (i, j) = (rng.gen_range(7) as usize, rng.gen_range(7) as usize);
            if i != j {
                let mut swapped = base.clone();
                swapped.swap(i, j);
                let got = env.eval(&Placement::new(swapped.clone())).unwrap();
                let want = tpd(&Arrangement::from_position(spec, &swapped, cc), &attrs).total;
                assert_eq!(got.to_bits(), want.to_bits(), "swap {i}<->{j}");
            }
            // Re-evaluating the base is the cached-total fast path.
            let got = env.eval(&Placement::new(base.clone())).unwrap();
            let want = tpd(&Arrangement::from_position(spec, &base, cc), &attrs).total;
            assert_eq!(got.to_bits(), want.to_bits());
        }
    }

    #[test]
    fn analytic_rejects_invalid_placements() {
        let spec = HierarchySpec::new(2, 2);
        let mut env = AnalyticTpd::new(spec, population(8));
        let err = env.eval(&Placement::new(vec![0, 0, 1])).unwrap_err();
        assert!(matches!(err, PlacementError::DuplicateClient { .. }), "{err}");
        let err = env
            .eval_batch(&[Placement::new(vec![0, 1])])
            .unwrap_err();
        assert!(matches!(err, PlacementError::WrongArity { .. }), "{err}");
    }

    #[test]
    fn every_valid_replace_neighbor_takes_the_delta_path() {
        let spec = HierarchySpec::new(2, 2);
        let cc = 10;
        let mut env = AnalyticTpd::new(spec, population(cc));
        let base = vec![0usize, 1, 2];
        env.eval(&Placement::new(base.clone())).unwrap();
        let before = obs::PLACEMENT_DELTA_EVALS.get();
        let mut tally = PathTally::default();
        let mut neighbors = 0u64;
        for slot in 0..3 {
            for client in 0..cc {
                let mut n = base.clone();
                n[slot] = client;
                if n == base || env.scratch.validate(&n).is_err() {
                    // The base itself, or a replace-by-existing-aggregator
                    // — the duplicate `validate` rejects before classify
                    // ever sees it (the old `!is_aggregator` guard was
                    // unreachable for exactly this reason).
                    continue;
                }
                env.tpd_of(&n, &mut tally);
                neighbors += 1;
            }
        }
        assert_eq!(neighbors, 3 * (cc as u64 - 3)); // every off-base client, per slot
        assert_eq!(tally.delta, neighbors, "every valid replace neighbor must go delta");
        assert_eq!(tally.full, 0);
        assert_eq!(tally.same, 0);
        // The tally is what feeds the public PLACEMENT_DELTA_EVALS counter.
        tally.flush(neighbors);
        assert!(obs::PLACEMENT_DELTA_EVALS.get() >= before + neighbors);
    }

    #[test]
    fn aggregators_train_too_and_gate_phase_one() {
        // 8 clients, one of them (id 7) a severe straggler in training.
        // Whether it is placed as an aggregator or left as a leaf
        // trainer, its local training gates phase 1 — aggregators are
        // agtrainers. (Pre-fix, promoting the straggler to an
        // aggregator slot silently removed its training cost.)
        let mut clients: Vec<ClientSpec> = (0..8)
            .map(|i| ClientSpec {
                name: format!("c{i}"),
                speed_factor: 1.0,
                memory_pressure: 1.0,
            })
            .collect();
        clients[7].speed_factor = 100.0;
        let mut env = EmulatedDelay::new(2, 2, &clients);
        env.agg_unit_secs = 1e-6; // isolate the phase-1 training gate
        let slow_agg = env.eval(&Placement::new(vec![7, 1, 2])).unwrap();
        let all_fast = env.eval(&Placement::new(vec![0, 1, 2])).unwrap();
        assert!(
            slow_agg > all_fast,
            "a slow aggregator still trains: {slow_agg} !> {all_fast}"
        );
        // Both placements pay the straggler's training gate.
        assert!(all_fast >= 100.0, "phase 1 must gate on the slowest client: {all_fast}");
    }

    #[test]
    fn emulated_delta_paths_are_bit_identical_to_full_evals() {
        let clients: Vec<ClientSpec> = (0..12)
            .map(|i| ClientSpec {
                name: format!("c{i}"),
                speed_factor: 1.0 + (i % 5) as f64 * 0.7,
                memory_pressure: 1.0 + (i % 3) as f64 * 1.5,
            })
            .collect();
        let mut env = EmulatedDelay::new(3, 2, &clients);
        let mut rng = Pcg32::seed_from_u64(11);
        let base: Vec<usize> = rng.sample_distinct(12, 7);
        env.eval(&Placement::new(base.clone())).unwrap();
        for _ in 0..40 {
            // Replace neighbor vs a fresh environment's full eval.
            let slot = rng.gen_range(7) as usize;
            let mut id = rng.gen_range(12) as usize;
            while base.contains(&id) {
                id = (id + 1) % 12;
            }
            let mut n = base.clone();
            n[slot] = id;
            let got = env.eval(&Placement::new(n.clone())).unwrap();
            let want =
                EmulatedDelay::new(3, 2, &clients).eval(&Placement::new(n)).unwrap();
            assert_eq!(got.to_bits(), want.to_bits(), "replace {slot}->{id}");
            // Swap neighbor.
            let (i, j) = (rng.gen_range(7) as usize, rng.gen_range(7) as usize);
            if i != j {
                let mut sw = base.clone();
                sw.swap(i, j);
                let got = env.eval(&Placement::new(sw.clone())).unwrap();
                let want =
                    EmulatedDelay::new(3, 2, &clients).eval(&Placement::new(sw)).unwrap();
                assert_eq!(got.to_bits(), want.to_bits(), "swap {i}<->{j}");
            }
            // Re-evaluating the base is the cached-total fast path.
            let got = env.eval(&Placement::new(base.clone())).unwrap();
            let want = EmulatedDelay::new(3, 2, &clients)
                .eval(&Placement::new(base.clone()))
                .unwrap();
            assert_eq!(got.to_bits(), want.to_bits());
        }
    }

    #[test]
    fn emulated_batch_matches_single_evals() {
        let sc = DeployScenario::paper_docker();
        let mut env = EmulatedDelay::from_scenario(&sc);
        let batch: Vec<Placement> = vec![
            Placement::new(vec![0, 1, 2]),
            Placement::new(vec![9, 1, 2]),
            Placement::new(vec![4, 2, 7]),
        ];
        let batched = env.eval_batch(&batch).unwrap();
        let singles: Vec<f64> =
            batch.iter().map(|p| env.eval(p).unwrap()).collect();
        assert_eq!(batched, singles);
    }

    #[test]
    fn emulated_delay_punishes_slow_aggregators() {
        // Paper's docker mix: client 0 fast, clients 3+ memory-starved.
        let sc = DeployScenario::paper_docker();
        let mut env = EmulatedDelay::from_scenario(&sc);
        let fast_root = env.eval(&Placement::new(vec![0, 1, 2])).unwrap();
        let slow_root = env.eval(&Placement::new(vec![9, 1, 2])).unwrap();
        assert!(
            slow_root > fast_root,
            "memory-starved root must cost more: {slow_root} !> {fast_root}"
        );
    }

    #[test]
    fn emulated_delay_is_deterministic() {
        let sc = DeployScenario::paper_docker();
        let mut env = EmulatedDelay::from_scenario(&sc);
        let p = Placement::new(vec![4, 2, 7]);
        assert_eq!(env.eval(&p).unwrap(), env.eval(&p).unwrap());
    }
}
