//! Delay oracles — the [`Environment`] side of the Optimizer/Environment
//! split.
//!
//! An environment turns a candidate [`Placement`] into the paper's
//! black-box signal: the round's processing delay. Four implementations
//! cover the repo's execution tiers:
//!
//! * [`AnalyticTpd`] — the closed-form Eq. 6–7 TPD model over a sampled
//!   client population (the Fig-3 simulation fitness). Its `eval_batch`
//!   scores a whole swarm in one dispatch.
//! * [`crate::des::EventDrivenEnv`] — a discrete-event virtual-time
//!   round over a contended network with churn/dropout/straggler
//!   dynamics; in its all-off conformance configuration it reproduces
//!   [`AnalyticTpd`] exactly (registry name `event-driven`).
//! * [`EmulatedDelay`] — a calibrated analytic model of the emulated
//!   docker testbed, built from the same throttle factors
//!   [`crate::fl::emulation::EmulatedClock`] applies to real compute
//!   (speed factor on training, speed × memory pressure on aggregation).
//! * [`crate::fl::LiveSession`] — a *real* measured FL round through the
//!   broker + agent + runtime stack (defined next to the coordinator).
//!
//! ## The zero-allocation hot path
//!
//! The analytic oracles own reusable scratch state
//! ([`crate::fitness::TpdScratch`] / [`crate::hierarchy::EvalScratch`])
//! instead of materializing an [`Arrangement`] per candidate, so a
//! steady-state `eval_batch` performs no heap allocation beyond its
//! result vector — the difference between thousands and millions of
//! evaluations per second at 10k-client populations (`repro bench
//! --suite eval` tracks this). [`AnalyticTpd`] additionally recognizes
//! **single-coordinate neighbors** of the last fully-evaluated
//! placement — exactly what [`super::SaPlacement`],
//! [`super::TabuPlacement`] and [`super::AdaptivePsoPlacement`]'s
//! pinned probing propose — and scores them through the delta fast
//! path, which re-sums only the clusters the swap touches. Every fast
//! path is bit-identical to the legacy `tpd(&Arrangement::..)` pipeline
//! (property-tested in `tests/properties.rs`).

use super::{Placement, PlacementError};
use crate::configio::ClientSpec;
use crate::fitness::{ClientAttrs, TpdScratch};
use crate::fl::emulation::{EmulatedClock, WorkKind};
use crate::hierarchy::{EvalScratch, HierarchySpec};
use crate::obs::defs as obs;

/// Plain (non-atomic) per-dispatch eval-path tally: the hot loop bumps
/// local integers, one [`PathTally::flush`] per `eval`/`eval_batch`
/// dispatch turns them into a handful of relaxed atomic adds — so
/// telemetry costs nothing measurable at millions of evals/sec and
/// adds zero allocations (pinned by `tests/alloc_guard.rs`).
#[derive(Default)]
struct PathTally {
    same: u64,
    delta: u64,
    full: u64,
}

impl PathTally {
    #[inline]
    fn flush(&self, evals: u64) {
        obs::PLACEMENT_EVALS.add(evals);
        obs::PLACEMENT_CACHE_HITS.add(self.same);
        obs::PLACEMENT_DELTA_EVALS.add(self.delta);
        obs::PLACEMENT_FULL_EVALS.add(self.full);
    }
}

/// A delay oracle: scores candidate placements. `Send` so boxed oracles
/// can move into scheduler workers (the service tier runs one session —
/// optimizer + environment — per worker thread).
pub trait Environment: Send {
    /// Environment label for logs and CSV output.
    fn name(&self) -> &'static str;

    /// Delay of one placement (seconds, or TPD units for analytic
    /// environments — the optimizers only compare magnitudes).
    fn eval(&mut self, placement: &Placement) -> Result<f64, PlacementError>;

    /// Delays for a batch of placements, in order. The default loops
    /// over [`Environment::eval`]; analytic environments override this
    /// to score the whole batch in one dispatch.
    fn eval_batch(&mut self, batch: &[Placement]) -> Result<Vec<f64>, PlacementError> {
        batch.iter().map(|p| self.eval(p)).collect()
    }
}

/// How a candidate differs from a cached base position.
enum Diff {
    /// Identical to the base.
    Same,
    /// Exactly one slot changed to a client outside the base placement.
    Replace { slot: usize, client: usize },
    /// Exactly two slots exchanged their base clients.
    Swap { i: usize, j: usize },
    /// Anything else: evaluate in full.
    Full,
}

/// Classify a *validated* candidate against the cached base position.
fn classify(base: &[usize], candidate: &[usize]) -> Diff {
    debug_assert_eq!(base.len(), candidate.len());
    let (mut first, mut second) = (None, None);
    for (s, (&b, &c)) in base.iter().zip(candidate).enumerate() {
        if b != c {
            match (first, second) {
                (None, _) => first = Some(s),
                (Some(_), None) => second = Some(s),
                _ => return Diff::Full,
            }
        }
    }
    match (first, second) {
        (None, _) => Diff::Same,
        (Some(k), None) => Diff::Replace { slot: k, client: candidate[k] },
        (Some(i), Some(j)) => {
            if candidate[i] == base[j] && candidate[j] == base[i] {
                Diff::Swap { i, j }
            } else {
                Diff::Full
            }
        }
    }
}

/// The Eq. 6–7 Total Processing Delay model over a simulated population
/// (paper §IV.A/B) — the fitness behind Fig. 3.
pub struct AnalyticTpd {
    attrs: Vec<ClientAttrs>,
    scratch: TpdScratch,
}

impl AnalyticTpd {
    pub fn new(spec: HierarchySpec, attrs: Vec<ClientAttrs>) -> AnalyticTpd {
        assert!(attrs.len() >= spec.dimensions(), "population smaller than slot count");
        let scratch = TpdScratch::new(spec, attrs.len());
        AnalyticTpd { attrs, scratch }
    }

    /// The simulated client population.
    pub fn attrs(&self) -> &[ClientAttrs] {
        &self.attrs
    }

    /// Score one *validated* placement. Single-coordinate neighbors of
    /// the cached base position take the delta fast path; everything
    /// else is a full (still allocation-free) streaming evaluation that
    /// becomes the new base.
    fn tpd_of(&mut self, placement: &[usize], tally: &mut PathTally) -> f64 {
        if self.scratch.loaded() {
            match classify(self.scratch.position(), placement) {
                Diff::Same => {
                    tally.same += 1;
                    return self.scratch.total();
                }
                Diff::Replace { slot, client } if !self.scratch.is_aggregator(client) => {
                    tally.delta += 1;
                    return self.scratch.delta_replace(slot, client, &self.attrs);
                }
                Diff::Swap { i, j } => {
                    tally.delta += 1;
                    return self.scratch.delta_swap(i, j, &self.attrs);
                }
                _ => {}
            }
        }
        tally.full += 1;
        self.scratch.eval_prevalidated(placement, &self.attrs)
    }
}

impl Environment for AnalyticTpd {
    fn name(&self) -> &'static str {
        "analytic-tpd"
    }

    fn eval(&mut self, placement: &Placement) -> Result<f64, PlacementError> {
        self.scratch.validate(placement)?;
        let mut tally = PathTally::default();
        let delay = self.tpd_of(placement, &mut tally);
        tally.flush(1);
        Ok(delay)
    }

    fn eval_batch(&mut self, batch: &[Placement]) -> Result<Vec<f64>, PlacementError> {
        // One dispatch for the whole batch: validate everything first
        // (against the reusable bitset — no per-candidate allocation),
        // then score in a tight loop (no per-candidate virtual calls).
        for p in batch {
            self.scratch.validate(p)?;
        }
        let mut delays = Vec::with_capacity(batch.len());
        let mut tally = PathTally::default();
        for p in batch {
            delays.push(self.tpd_of(p, &mut tally));
        }
        tally.flush(batch.len() as u64);
        Ok(delays)
    }
}

/// Analytic delay model of the emulated heterogeneous testbed
/// (DESIGN.md §4): what a round *would* cost given each client's
/// [`EmulatedClock`] throttle factors, without running broker traffic or
/// training. Useful for fast registry-driven experiments on deployment
/// scenarios.
///
/// The model mirrors the real round structure: all trainers work in
/// parallel (slowest trainer gates the leaf level), then each hierarchy
/// level aggregates bottom-up (slowest cluster gates its level; cluster
/// cost scales with fan-in, aggregation pays the memory-pressure
/// factor). Like [`AnalyticTpd`] it evaluates over a reusable
/// [`EvalScratch`] view — no arrangement is materialized per candidate.
pub struct EmulatedDelay {
    spec: HierarchySpec,
    clocks: Vec<EmulatedClock>,
    scratch: EvalScratch,
    /// Seconds of full-speed compute one local training phase costs.
    pub train_unit_secs: f64,
    /// Seconds of full-speed compute per model merged during aggregation.
    pub agg_unit_secs: f64,
}

impl EmulatedDelay {
    pub fn new(depth: usize, width: usize, clients: &[ClientSpec]) -> EmulatedDelay {
        let spec = HierarchySpec::new(depth, width);
        assert!(clients.len() >= spec.dimensions(), "population smaller than slot count");
        EmulatedDelay {
            spec,
            clocks: clients.iter().map(|c| EmulatedClock::new(c.clone())).collect(),
            scratch: EvalScratch::new(spec, clients.len()),
            train_unit_secs: 1.0,
            agg_unit_secs: 0.5,
        }
    }

    /// Build for a deployment scenario's hierarchy and client mix.
    pub fn from_scenario(sc: &crate::configio::DeployScenario) -> EmulatedDelay {
        EmulatedDelay::new(sc.depth, sc.width, &sc.clients)
    }

    fn delay_of(&mut self, placement: &[usize]) -> f64 {
        self.scratch.load_prevalidated(placement);
        // Phase 1: local training in parallel — the slowest trainer
        // (or training aggregator) gates the round start of aggregation.
        let mut train = 0.0f64;
        for leaf in 0..self.scratch.leaf_count() {
            for &t in self.scratch.leaf_trainers(leaf) {
                train = train.max(self.clocks[t].factor(WorkKind::Train) * self.train_unit_secs);
            }
        }
        // Phase 2: aggregation bottom-up, one level at a time.
        let mut total = train;
        let leaf_start = self.scratch.leaf_start();
        for l in (0..self.spec.depth).rev() {
            let mut level_max = 0.0f64;
            for slot in self.spec.level_slots(l) {
                let agg = placement[slot];
                let fan_in = if slot >= leaf_start {
                    self.scratch.leaf_trainers(slot - leaf_start).len() + 1
                } else {
                    self.spec.children(slot).len() + 1
                };
                level_max = level_max.max(
                    self.clocks[agg].factor(WorkKind::Aggregate)
                        * self.agg_unit_secs
                        * fan_in as f64,
                );
            }
            total += level_max;
        }
        total
    }
}

impl Environment for EmulatedDelay {
    fn name(&self) -> &'static str {
        "emulated-delay"
    }

    fn eval(&mut self, placement: &Placement) -> Result<f64, PlacementError> {
        self.scratch.validate(placement)?;
        obs::PLACEMENT_EVALS.inc();
        Ok(self.delay_of(placement))
    }

    fn eval_batch(&mut self, batch: &[Placement]) -> Result<Vec<f64>, PlacementError> {
        for p in batch {
            self.scratch.validate(p)?;
        }
        let mut delays = Vec::with_capacity(batch.len());
        for p in batch {
            delays.push(self.delay_of(p));
        }
        obs::PLACEMENT_EVALS.add(batch.len() as u64);
        Ok(delays)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configio::DeployScenario;
    use crate::fitness::tpd;
    use crate::hierarchy::Arrangement;
    use crate::prng::{Pcg32, Rng};

    fn population(n: usize) -> Vec<ClientAttrs> {
        let mut rng = Pcg32::seed_from_u64(1);
        ClientAttrs::sample_population(n, (5.0, 15.0), (10.0, 50.0), 5.0, &mut rng)
    }

    #[test]
    fn analytic_batch_matches_single_evals() {
        let spec = HierarchySpec::new(2, 2);
        let mut env = AnalyticTpd::new(spec, population(8));
        let batch: Vec<Placement> = vec![
            Placement::new(vec![0, 1, 2]),
            Placement::new(vec![5, 6, 7]),
            Placement::new(vec![3, 0, 4]),
        ];
        let batched = env.eval_batch(&batch).unwrap();
        let singles: Vec<f64> = batch.iter().map(|p| env.eval(p).unwrap()).collect();
        assert_eq!(batched, singles);
        assert!(batched.iter().all(|&t| t > 0.0));
    }

    #[test]
    fn analytic_matches_the_legacy_arrangement_pipeline() {
        // The scratch path must reproduce tpd(&from_position(..)) bit
        // for bit, including across the >64-client bitset fallback.
        for cc in [8usize, 70] {
            let spec = HierarchySpec::new(2, 2);
            let attrs = population(cc);
            let mut env = AnalyticTpd::new(spec, attrs.clone());
            let mut rng = Pcg32::seed_from_u64(9);
            for _ in 0..20 {
                let pos = rng.sample_distinct(cc, 3);
                let got = env.eval(&Placement::new(pos.clone())).unwrap();
                let want = tpd(&Arrangement::from_position(spec, &pos, cc), &attrs).total;
                assert_eq!(got.to_bits(), want.to_bits());
            }
        }
    }

    #[test]
    fn delta_fast_path_scores_neighbors_bit_identically() {
        let spec = HierarchySpec::new(3, 2);
        let cc = 40;
        let attrs = population(cc);
        let mut env = AnalyticTpd::new(spec, attrs.clone());
        let mut rng = Pcg32::seed_from_u64(4);
        let base: Vec<usize> = rng.sample_distinct(cc, 7);
        env.eval(&Placement::new(base.clone())).unwrap();
        for _ in 0..40 {
            // Single-slot replacement neighbor (the SA/tabu/probe move).
            let slot = rng.gen_range(7) as usize;
            let mut id = rng.gen_range(cc as u64) as usize;
            while base.contains(&id) {
                id = (id + 1) % cc;
            }
            let mut neighbor = base.clone();
            neighbor[slot] = id;
            let got = env.eval(&Placement::new(neighbor.clone())).unwrap();
            let want = tpd(&Arrangement::from_position(spec, &neighbor, cc), &attrs).total;
            assert_eq!(got.to_bits(), want.to_bits(), "replace {slot}->{id}");
            // Two-slot swap neighbor (SA's other move).
            let (i, j) = (rng.gen_range(7) as usize, rng.gen_range(7) as usize);
            if i != j {
                let mut swapped = base.clone();
                swapped.swap(i, j);
                let got = env.eval(&Placement::new(swapped.clone())).unwrap();
                let want = tpd(&Arrangement::from_position(spec, &swapped, cc), &attrs).total;
                assert_eq!(got.to_bits(), want.to_bits(), "swap {i}<->{j}");
            }
            // Re-evaluating the base is the cached-total fast path.
            let got = env.eval(&Placement::new(base.clone())).unwrap();
            let want = tpd(&Arrangement::from_position(spec, &base, cc), &attrs).total;
            assert_eq!(got.to_bits(), want.to_bits());
        }
    }

    #[test]
    fn analytic_rejects_invalid_placements() {
        let spec = HierarchySpec::new(2, 2);
        let mut env = AnalyticTpd::new(spec, population(8));
        let err = env.eval(&Placement::new(vec![0, 0, 1])).unwrap_err();
        assert!(matches!(err, PlacementError::DuplicateClient { .. }), "{err}");
        let err = env
            .eval_batch(&[Placement::new(vec![0, 1])])
            .unwrap_err();
        assert!(matches!(err, PlacementError::WrongArity { .. }), "{err}");
    }

    #[test]
    fn emulated_delay_punishes_slow_aggregators() {
        // Paper's docker mix: client 0 fast, clients 3+ memory-starved.
        let sc = DeployScenario::paper_docker();
        let mut env = EmulatedDelay::from_scenario(&sc);
        let fast_root = env.eval(&Placement::new(vec![0, 1, 2])).unwrap();
        let slow_root = env.eval(&Placement::new(vec![9, 1, 2])).unwrap();
        assert!(
            slow_root > fast_root,
            "memory-starved root must cost more: {slow_root} !> {fast_root}"
        );
    }

    #[test]
    fn emulated_delay_is_deterministic() {
        let sc = DeployScenario::paper_docker();
        let mut env = EmulatedDelay::from_scenario(&sc);
        let p = Placement::new(vec![4, 2, 7]);
        assert_eq!(env.eval(&p).unwrap(), env.eval(&p).unwrap());
    }
}
