//! Delay oracles — the [`Environment`] side of the Optimizer/Environment
//! split.
//!
//! An environment turns a candidate [`Placement`] into the paper's
//! black-box signal: the round's processing delay. Four implementations
//! cover the repo's execution tiers:
//!
//! * [`AnalyticTpd`] — the closed-form Eq. 6–7 TPD model over a sampled
//!   client population (the Fig-3 simulation fitness). Its `eval_batch`
//!   scores a whole swarm in one dispatch.
//! * [`crate::des::EventDrivenEnv`] — a discrete-event virtual-time
//!   round over a contended network with churn/dropout/straggler
//!   dynamics; in its all-off conformance configuration it reproduces
//!   [`AnalyticTpd`] exactly (registry name `event-driven`).
//! * [`EmulatedDelay`] — a calibrated analytic model of the emulated
//!   docker testbed, built from the same throttle factors
//!   [`crate::fl::emulation::EmulatedClock`] applies to real compute
//!   (speed factor on training, speed × memory pressure on aggregation).
//! * [`crate::fl::LiveSession`] — a *real* measured FL round through the
//!   broker + agent + runtime stack (defined next to the coordinator).

use super::{validate_placement, Placement, PlacementError};
use crate::configio::ClientSpec;
use crate::fitness::{tpd, ClientAttrs};
use crate::fl::emulation::{EmulatedClock, WorkKind};
use crate::hierarchy::{Arrangement, HierarchySpec};

/// A delay oracle: scores candidate placements.
pub trait Environment {
    /// Environment label for logs and CSV output.
    fn name(&self) -> &'static str;

    /// Delay of one placement (seconds, or TPD units for analytic
    /// environments — the optimizers only compare magnitudes).
    fn eval(&mut self, placement: &Placement) -> Result<f64, PlacementError>;

    /// Delays for a batch of placements, in order. The default loops
    /// over [`Environment::eval`]; analytic environments override this
    /// to score the whole batch in one dispatch.
    fn eval_batch(&mut self, batch: &[Placement]) -> Result<Vec<f64>, PlacementError> {
        batch.iter().map(|p| self.eval(p)).collect()
    }
}

/// The Eq. 6–7 Total Processing Delay model over a simulated population
/// (paper §IV.A/B) — the fitness behind Fig. 3.
pub struct AnalyticTpd {
    spec: HierarchySpec,
    attrs: Vec<ClientAttrs>,
}

impl AnalyticTpd {
    pub fn new(spec: HierarchySpec, attrs: Vec<ClientAttrs>) -> AnalyticTpd {
        assert!(attrs.len() >= spec.dimensions(), "population smaller than slot count");
        AnalyticTpd { spec, attrs }
    }

    /// The simulated client population.
    pub fn attrs(&self) -> &[ClientAttrs] {
        &self.attrs
    }

    fn tpd_of(&self, placement: &[usize]) -> f64 {
        tpd(
            &Arrangement::from_position(self.spec, placement, self.attrs.len()),
            &self.attrs,
        )
        .total
    }
}

impl Environment for AnalyticTpd {
    fn name(&self) -> &'static str {
        "analytic-tpd"
    }

    fn eval(&mut self, placement: &Placement) -> Result<f64, PlacementError> {
        validate_placement(placement, self.spec.dimensions(), self.attrs.len())?;
        Ok(self.tpd_of(placement))
    }

    fn eval_batch(&mut self, batch: &[Placement]) -> Result<Vec<f64>, PlacementError> {
        // One dispatch for the whole batch: validate everything first,
        // then score in a tight loop (no per-candidate virtual calls).
        let dims = self.spec.dimensions();
        for p in batch {
            validate_placement(p, dims, self.attrs.len())?;
        }
        Ok(batch.iter().map(|p| self.tpd_of(p)).collect())
    }
}

/// Analytic delay model of the emulated heterogeneous testbed
/// (DESIGN.md §4): what a round *would* cost given each client's
/// [`EmulatedClock`] throttle factors, without running broker traffic or
/// training. Useful for fast registry-driven experiments on deployment
/// scenarios.
///
/// The model mirrors the real round structure: all trainers work in
/// parallel (slowest trainer gates the leaf level), then each hierarchy
/// level aggregates bottom-up (slowest cluster gates its level; cluster
/// cost scales with fan-in, aggregation pays the memory-pressure
/// factor).
pub struct EmulatedDelay {
    spec: HierarchySpec,
    clocks: Vec<EmulatedClock>,
    /// Seconds of full-speed compute one local training phase costs.
    pub train_unit_secs: f64,
    /// Seconds of full-speed compute per model merged during aggregation.
    pub agg_unit_secs: f64,
}

impl EmulatedDelay {
    pub fn new(depth: usize, width: usize, clients: &[ClientSpec]) -> EmulatedDelay {
        let spec = HierarchySpec::new(depth, width);
        assert!(clients.len() >= spec.dimensions(), "population smaller than slot count");
        EmulatedDelay {
            spec,
            clocks: clients.iter().map(|c| EmulatedClock::new(c.clone())).collect(),
            train_unit_secs: 1.0,
            agg_unit_secs: 0.5,
        }
    }

    /// Build for a deployment scenario's hierarchy and client mix.
    pub fn from_scenario(sc: &crate::configio::DeployScenario) -> EmulatedDelay {
        EmulatedDelay::new(sc.depth, sc.width, &sc.clients)
    }

    fn delay_of(&self, placement: &[usize]) -> f64 {
        let arr = Arrangement::from_position(self.spec, placement, self.clocks.len());
        // Phase 1: local training in parallel — the slowest trainer
        // (or training aggregator) gates the round start of aggregation.
        let train = arr
            .all_trainers()
            .into_iter()
            .map(|c| self.clocks[c].factor(WorkKind::Train) * self.train_unit_secs)
            .fold(0.0_f64, f64::max);
        // Phase 2: aggregation bottom-up, one level at a time.
        let mut total = train;
        for level in self.spec.levels_bottom_up() {
            let level_max = level
                .iter()
                .map(|&slot| {
                    let agg = arr.aggregators[slot];
                    let fan_in = arr.buffer_of(slot).len() + 1;
                    self.clocks[agg].factor(WorkKind::Aggregate)
                        * self.agg_unit_secs
                        * fan_in as f64
                })
                .fold(0.0_f64, f64::max);
            total += level_max;
        }
        total
    }
}

impl Environment for EmulatedDelay {
    fn name(&self) -> &'static str {
        "emulated-delay"
    }

    fn eval(&mut self, placement: &Placement) -> Result<f64, PlacementError> {
        validate_placement(placement, self.spec.dimensions(), self.clocks.len())?;
        Ok(self.delay_of(placement))
    }

    fn eval_batch(&mut self, batch: &[Placement]) -> Result<Vec<f64>, PlacementError> {
        let dims = self.spec.dimensions();
        for p in batch {
            validate_placement(p, dims, self.clocks.len())?;
        }
        Ok(batch.iter().map(|p| self.delay_of(p)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configio::DeployScenario;
    use crate::prng::Pcg32;

    fn population(n: usize) -> Vec<ClientAttrs> {
        let mut rng = Pcg32::seed_from_u64(1);
        ClientAttrs::sample_population(n, (5.0, 15.0), (10.0, 50.0), 5.0, &mut rng)
    }

    #[test]
    fn analytic_batch_matches_single_evals() {
        let spec = HierarchySpec::new(2, 2);
        let mut env = AnalyticTpd::new(spec, population(8));
        let batch: Vec<Placement> = vec![
            Placement::new(vec![0, 1, 2]),
            Placement::new(vec![5, 6, 7]),
            Placement::new(vec![3, 0, 4]),
        ];
        let batched = env.eval_batch(&batch).unwrap();
        let singles: Vec<f64> = batch.iter().map(|p| env.eval(p).unwrap()).collect();
        assert_eq!(batched, singles);
        assert!(batched.iter().all(|&t| t > 0.0));
    }

    #[test]
    fn analytic_rejects_invalid_placements() {
        let spec = HierarchySpec::new(2, 2);
        let mut env = AnalyticTpd::new(spec, population(8));
        let err = env.eval(&Placement::new(vec![0, 0, 1])).unwrap_err();
        assert!(matches!(err, PlacementError::DuplicateClient { .. }), "{err}");
        let err = env
            .eval_batch(&[Placement::new(vec![0, 1])])
            .unwrap_err();
        assert!(matches!(err, PlacementError::WrongArity { .. }), "{err}");
    }

    #[test]
    fn emulated_delay_punishes_slow_aggregators() {
        // Paper's docker mix: client 0 fast, clients 3+ memory-starved.
        let sc = DeployScenario::paper_docker();
        let mut env = EmulatedDelay::from_scenario(&sc);
        let fast_root = env.eval(&Placement::new(vec![0, 1, 2])).unwrap();
        let slow_root = env.eval(&Placement::new(vec![9, 1, 2])).unwrap();
        assert!(
            slow_root > fast_root,
            "memory-starved root must cost more: {slow_root} !> {fast_root}"
        );
    }

    #[test]
    fn emulated_delay_is_deterministic() {
        let sc = DeployScenario::paper_docker();
        let mut env = EmulatedDelay::from_scenario(&sc);
        let p = Placement::new(vec![4, 2, 7]);
        assert_eq!(env.eval(&p).unwrap(), env.eval(&p).unwrap());
    }
}
