//! Simulated-annealing placement baseline (ablation A2).
//!
//! Single-state black-box search under the same one-evaluation-per-round
//! protocol: propose a neighbour of the current placement (swap one slot
//! to a new client, or swap two slots), accept per Metropolis with a
//! geometrically cooling temperature.

use super::{Optimizer, OptimizerState, Placement, PlacementError};
use crate::prng::{Pcg32, Rng};

/// SA hyper-parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SaConfig {
    /// Initial temperature, in delay units.
    pub t0: f64,
    /// Geometric cooling factor per round.
    pub cooling: f64,
    /// Minimum temperature floor.
    pub t_min: f64,
}

impl Default for SaConfig {
    fn default() -> Self {
        SaConfig {
            t0: 2.0,
            cooling: 0.95,
            t_min: 1e-3,
        }
    }
}

/// Metropolis search over placements.
pub struct SaPlacement {
    cfg: SaConfig,
    dims: usize,
    client_count: usize,
    current: Vec<usize>,
    current_delay: f64,
    candidate: Vec<usize>,
    best: Vec<usize>,
    best_delay: f64,
    temperature: f64,
    rng: Pcg32,
}

impl SaPlacement {
    pub fn new(dims: usize, client_count: usize, cfg: SaConfig, mut rng: Pcg32) -> Self {
        assert!(client_count >= dims);
        let current = rng.sample_distinct(client_count, dims);
        SaPlacement {
            cfg,
            dims,
            client_count,
            candidate: current.clone(),
            best: current.clone(),
            current,
            current_delay: f64::INFINITY,
            best_delay: f64::INFINITY,
            temperature: cfg.t0,
            rng,
        }
    }

    /// Best (lowest) delay observed so far (`Optimizer::best` returns the
    /// matching placement).
    pub fn best_delay(&self) -> f64 {
        self.best_delay
    }

    /// Neighbour move: 50% replace one slot's client with an unused one
    /// (the shared single-coordinate move the analytic oracle
    /// delta-evaluates), 50% swap two slots (changes which cluster each
    /// client leads — also a delta-evaluable shape).
    fn neighbour(&mut self) -> Vec<usize> {
        let mut n = self.current.clone();
        if self.dims >= 2 && self.rng.next_f64() < 0.5 {
            let a = self.rng.gen_range(self.dims as u64) as usize;
            let mut b = self.rng.gen_range(self.dims as u64) as usize;
            while b == a {
                b = self.rng.gen_range(self.dims as u64) as usize;
            }
            n.swap(a, b);
        } else {
            let (slot, id) = super::draw_slot_replacement(&n, self.client_count, &mut self.rng);
            n[slot] = id;
        }
        n
    }
}

impl Optimizer for SaPlacement {
    fn name(&self) -> &'static str {
        "sa"
    }

    fn propose_batch(&mut self, round: usize) -> Vec<Placement> {
        if round == 0 || self.current_delay.is_infinite() {
            // First evaluation scores the initial state.
            self.candidate = self.current.clone();
        } else {
            self.candidate = self.neighbour();
        }
        vec![Placement::new(self.candidate.clone())]
    }

    fn observe_batch(&mut self, placements: &[Placement], delays: &[f64]) {
        for (p, &delay_secs) in placements.iter().zip(delays) {
            debug_assert_eq!(p.as_slice(), self.candidate.as_slice());
            if delay_secs < self.best_delay {
                self.best_delay = delay_secs;
                self.best = p.to_vec();
            }
            let accept = if delay_secs <= self.current_delay {
                true
            } else {
                let d = delay_secs - self.current_delay;
                self.rng.next_f64() < (-d / self.temperature.max(self.cfg.t_min)).exp()
            };
            if accept {
                self.current = p.to_vec();
                self.current_delay = delay_secs;
            }
            self.temperature = (self.temperature * self.cfg.cooling).max(self.cfg.t_min);
        }
    }

    fn best(&self) -> Option<(Placement, f64)> {
        if self.best_delay.is_finite() {
            Some((Placement::new(self.best.clone()), self.best_delay))
        } else {
            None
        }
    }

    fn restore(&mut self, state: &OptimizerState) -> Result<(), PlacementError> {
        super::check_state_name(self.name(), state)?;
        if let Some((placement, delay)) = &state.best {
            super::validate_placement(placement, self.dims, self.client_count)?;
            // Resume the walk from the checkpointed incumbent.
            self.best = placement.to_vec();
            self.best_delay = *delay;
            self.current = placement.to_vec();
            self.current_delay = *delay;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::testkit;

    #[test]
    fn improves_on_toy_landscape() {
        let mut sa = SaPlacement::new(4, 25, SaConfig::default(), Pcg32::seed_from_u64(1));
        let delays =
            testkit::run_toy_validated(&mut sa, 4, 25, 200, |p| p.iter().sum::<usize>() as f64 + 1.0);
        let early: f64 = delays[..20].iter().sum();
        let late: f64 = delays[180..].iter().sum();
        assert!(late < early, "SA failed to improve: early {early}, late {late}");
    }

    #[test]
    fn temperature_cools_and_floors() {
        let cfg = SaConfig {
            t0: 1.0,
            cooling: 0.5,
            t_min: 0.1,
        };
        let mut sa = SaPlacement::new(2, 6, cfg, Pcg32::seed_from_u64(2));
        testkit::run_toy_validated(&mut sa, 2, 6, 30, |_| 1.0);
        assert!((sa.temperature - 0.1).abs() < 1e-12);
    }

    #[test]
    fn proposals_always_distinct_ids() {
        let mut sa = SaPlacement::new(3, 7, SaConfig::default(), Pcg32::seed_from_u64(3));
        let mut round = 0usize;
        testkit::run_toy_validated(&mut sa, 3, 7, 100, |_| {
            round += 1;
            (round % 5) as f64
        });
    }
}
