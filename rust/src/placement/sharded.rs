//! Region-sharded discrete PSO: `sharded-pso` (alias
//! `flag-swap-sharded`).
//!
//! The slot vector is partitioned by subtree: each level-1 subtree of
//! the hierarchy becomes a *region* (the root slot rides with region
//! 0), and a [`RegionSwarm`] sub-swarm owns each region's slots,
//! optimizing them against the frozen rest of the placement. Every
//! `exchange_every` full sweeps the regional incumbents are composed
//! into a new global base through an epoch-barrier exchange.
//!
//! # Determinism
//!
//! The composed placement is a pure function of the seed and the
//! observed delay sequence, independent of evaluation thread count:
//!
//! * regions are seeded in fixed region order from one SplitMix64
//!   stream and each sub-swarm consumes only its own `Pcg32`;
//! * candidates are emitted in fixed region-major order and delays are
//!   routed back in that same order, so which thread *scored* a
//!   candidate never matters;
//! * the exchange composes incumbents in fixed region order at a full
//!   batch barrier (`propose_batch` emits the composed placement alone,
//!   so the exchange observation cannot interleave with sweep
//!   observations).
//!
//! Combined with the bit-exact path-independence of the delay oracles
//! (every full/delta/cached path folds with the same
//! [`crate::fitness::ChunkedFold8`] order), the final placement and
//! every downstream CSV are byte-identical at any `--threads` value —
//! property-tested at 1, 2 and 8 workers.
//!
//! # Validity
//!
//! Sub-swarms insert only *free* clients from their own residue class
//! (`client % regions == region`), so two regions can never adopt the
//! same free client concurrently and the composed placement is distinct
//! by construction. After an exchange, particle positions holding a
//! client the new base uses outside their region are snapped back to
//! the base slice ([`RegionSwarm::rebase`]).

use super::{Optimizer, OptimizerState, Placement, PlacementError};
use crate::hierarchy::HierarchySpec;
use crate::obs::defs as obs;
use crate::prng::{Pcg32, Rng, SplitMix64};
use crate::pso::{PsoConfig, RegionSwarm};

/// Tuning knobs for [`ShardedPso`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardedConfig {
    /// Total particle budget, split evenly across regions (each region
    /// gets at least one).
    pub particles: usize,
    /// Full sweeps between incumbent exchanges.
    pub exchange_every: usize,
}

impl Default for ShardedConfig {
    fn default() -> ShardedConfig {
        ShardedConfig { particles: 12, exchange_every: 4 }
    }
}

impl ShardedConfig {
    /// Adopt the swarm size of a [`PsoConfig`] (the scenario's
    /// `[pso]
    /// particles`), keeping the default exchange cadence.
    pub fn from_pso(pso: &PsoConfig) -> ShardedConfig {
        ShardedConfig { particles: pso.particles.max(1), ..ShardedConfig::default() }
    }
}

/// What the last `propose_batch` put in flight, so `observe_batch` can
/// route delays. Sweep/exchange layouts are fixed at propose time; a
/// truncated observation (the `drive` budget prefix) is handled by
/// routing only as many delays as arrived.
enum Pending {
    None,
    /// The initial base placement, alone.
    Bootstrap,
    /// Region-major sweep; per-region candidate counts in region order.
    Sweep(Vec<usize>),
    /// The composed exchange placement, alone.
    Exchange(Vec<usize>),
}

/// Region-sharded PSO over the slot vector (see module docs).
pub struct ShardedPso {
    regions: Vec<RegionSwarm>,
    /// The frozen global placement the sub-swarms optimize against.
    base: Vec<usize>,
    /// Delay of `base`; `None` until the bootstrap observation.
    base_delay: Option<f64>,
    /// `in_base[c]` ⇔ client `c` appears anywhere in `base`.
    in_base: Vec<bool>,
    exchange_every: usize,
    sweeps_since_exchange: usize,
    pending: Pending,
    best: Option<(Placement, f64)>,
}

impl ShardedPso {
    /// Partition by the hierarchy's level-1 subtrees: region `r` owns
    /// the subtree rooted at slot `1 + r`; the root slot rides with
    /// region 0. Depth-1 trees have a single one-slot region.
    pub fn from_spec(
        spec: HierarchySpec,
        client_count: usize,
        cfg: ShardedConfig,
        rng: Pcg32,
    ) -> ShardedPso {
        let mut regions = Vec::new();
        if spec.depth <= 1 {
            regions.push(vec![0]);
        } else {
            for r in 0..spec.width {
                let mut slots = Vec::new();
                let mut stack = vec![1 + r];
                while let Some(s) = stack.pop() {
                    slots.push(s);
                    stack.extend(spec.children(s));
                }
                slots.sort_unstable();
                regions.push(slots);
            }
            regions[0].insert(0, 0);
        }
        ShardedPso::with_regions(regions, spec.dimensions(), client_count, cfg, rng)
    }

    /// Flat partition for non-tree slot vectors: contiguous chunks,
    /// `min(4, dims)` regions.
    pub fn flat(dims: usize, client_count: usize, cfg: ShardedConfig, rng: Pcg32) -> ShardedPso {
        assert!(dims >= 1);
        let r_count = dims.min(4);
        let chunk = dims.div_ceil(r_count);
        let regions = (0..r_count)
            .map(|r| ((r * chunk).min(dims)..((r + 1) * chunk).min(dims)).collect())
            .filter(|s: &Vec<usize>| !s.is_empty())
            .collect();
        ShardedPso::with_regions(regions, dims, client_count, cfg, rng)
    }

    /// Infer the tree shape from a bare dimension count (the live-mode
    /// factory, which has no scenario): the smallest width `w ∈ 2..=8`
    /// whose complete tree has exactly `dims` slots wins (deepest
    /// tree); otherwise fall back to the flat partition.
    pub fn for_dims(
        dims: usize,
        client_count: usize,
        cfg: ShardedConfig,
        rng: Pcg32,
    ) -> ShardedPso {
        for w in 2..=8usize {
            let (mut total, mut pw, mut depth) = (1usize, 1usize, 1usize);
            while total < dims {
                pw *= w;
                total += pw;
                depth += 1;
            }
            if total == dims && depth >= 2 {
                return ShardedPso::from_spec(HierarchySpec::new(depth, w), client_count, cfg, rng);
            }
        }
        ShardedPso::flat(dims, client_count, cfg, rng)
    }

    fn with_regions(
        region_slots: Vec<Vec<usize>>,
        dims: usize,
        client_count: usize,
        cfg: ShardedConfig,
        mut rng: Pcg32,
    ) -> ShardedPso {
        assert!(dims >= 1 && client_count >= dims);
        debug_assert_eq!(region_slots.iter().map(Vec::len).sum::<usize>(), dims);
        let per_region = (cfg.particles / region_slots.len()).max(1);
        let base = rng.sample_distinct(client_count, dims);
        let mut in_base = vec![false; client_count];
        for &c in &base {
            in_base[c] = true;
        }
        // Fixed region order ⇒ fixed seed assignment, thread-independent.
        let mut seeds = SplitMix64::new(rng.next_u64());
        let regions = region_slots
            .into_iter()
            .map(|slots| RegionSwarm::new(slots, per_region, seeds.next()))
            .collect();
        ShardedPso {
            regions,
            base,
            base_delay: None,
            in_base,
            exchange_every: cfg.exchange_every.max(1),
            sweeps_since_exchange: 0,
            pending: Pending::None,
            best: None,
        }
    }

    fn recompute_in_base(&mut self) {
        self.in_base.iter_mut().for_each(|b| *b = false);
        for &c in &self.base {
            self.in_base[c] = true;
        }
    }

    fn rebase_all(&mut self, delay: f64) {
        for region in &mut self.regions {
            region.rebase(&self.base, delay, &self.in_base);
        }
    }
}

impl Optimizer for ShardedPso {
    fn name(&self) -> &'static str {
        "sharded-pso"
    }

    fn propose_batch(&mut self, _round: usize) -> Vec<Placement> {
        if self.base_delay.is_none() {
            self.pending = Pending::Bootstrap;
            return vec![Placement::new(self.base.clone())];
        }
        if self.sweeps_since_exchange >= self.exchange_every {
            // Epoch barrier: compose the regional incumbents in fixed
            // region order and score the composition alone.
            let mut composed = self.base.clone();
            for region in &self.regions {
                let (slice, _) = region.incumbent();
                for (i, &s) in region.slots().iter().enumerate() {
                    composed[s] = slice[i];
                }
            }
            self.pending = Pending::Exchange(composed.clone());
            return vec![Placement::new(composed)];
        }
        // Sweep: every region moves every particle once, region-major.
        let modulus = self.regions.len();
        let mut out = Vec::new();
        let mut counts = Vec::with_capacity(modulus);
        for (r, region) in self.regions.iter_mut().enumerate() {
            let started = std::time::Instant::now();
            let before = out.len();
            region.propose(&self.base, &self.in_base, r, modulus, &mut out);
            counts.push(out.len() - before);
            // Timing feeds telemetry only — never the search — so wall
            // clock cannot perturb determinism.
            obs::SHARDED_SUBSWARM_BUSY.observe(started.elapsed().as_secs_f64());
        }
        self.pending = Pending::Sweep(counts);
        out
    }

    fn observe_batch(&mut self, placements: &[Placement], delays: &[f64]) {
        for (p, &d) in placements.iter().zip(delays) {
            let improved = match &self.best {
                Some((_, bd)) => d < *bd,
                None => true,
            };
            if improved {
                self.best = Some((p.clone(), d));
            }
        }
        match std::mem::replace(&mut self.pending, Pending::None) {
            Pending::None => {}
            Pending::Bootstrap => {
                if let Some(&d) = delays.first() {
                    self.base_delay = Some(d);
                    self.rebase_all(d);
                }
            }
            Pending::Exchange(composed) => {
                if let Some(&d) = delays.first() {
                    self.base = composed;
                    self.recompute_in_base();
                    self.base_delay = Some(d);
                    self.sweeps_since_exchange = 0;
                    self.rebase_all(d);
                    obs::SHARDED_EXCHANGE_ROUNDS.inc();
                }
            }
            Pending::Sweep(counts) => {
                // Route delays region-major; a budget-truncated prefix
                // simply leaves the tail regions unobserved this sweep.
                let mut off = 0;
                let mut complete = true;
                for (region, &k) in self.regions.iter_mut().zip(&counts) {
                    let take = k.min(delays.len().saturating_sub(off));
                    let improvements = region.observe(&delays[off..off + take]);
                    obs::SHARDED_REGION_IMPROVEMENTS.add(improvements);
                    complete &= take == k;
                    off += take;
                }
                if complete {
                    self.sweeps_since_exchange += 1;
                }
            }
        }
    }

    fn best(&self) -> Option<(Placement, f64)> {
        self.best.clone()
    }

    fn group_size(&self) -> usize {
        self.regions.iter().map(RegionSwarm::particles).sum()
    }

    fn restore(&mut self, state: &OptimizerState) -> Result<(), PlacementError> {
        super::check_state_name(self.name(), state)?;
        if let Some((p, d)) = &state.best {
            if p.len() == self.base.len() {
                self.base = p.to_vec();
                self.recompute_in_base();
                self.base_delay = Some(*d);
                self.sweeps_since_exchange = 0;
                self.rebase_all(*d);
                self.best = Some((p.clone(), *d));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::{testkit, validate_placement};

    fn toy_delay(p: &[usize]) -> f64 {
        // Low client ids are fast; slot position weights break ties.
        p.iter().enumerate().map(|(i, &c)| (c as f64 + 1.0) * (1.0 + 0.1 * i as f64)).sum()
    }

    #[test]
    fn emits_valid_placements_across_many_rounds() {
        // Tree shapes and degenerate flat shapes, spanning exchanges.
        for (dims, cc) in [(1usize, 1usize), (2, 5), (3, 10), (7, 7), (21, 40)] {
            let cfg = ShardedConfig { particles: 8, exchange_every: 2 };
            let mut opt = ShardedPso::for_dims(dims, cc, cfg, Pcg32::seed_from_u64(11));
            testkit::run_toy_validated(&mut opt, dims, cc, 60, toy_delay);
        }
    }

    #[test]
    fn search_is_deterministic_for_a_seed() {
        let run = || {
            let cfg = ShardedConfig::default();
            let mut opt =
                ShardedPso::from_spec(HierarchySpec::new(3, 2), 30, cfg, Pcg32::seed_from_u64(9));
            let mut trace = Vec::new();
            for round in 0..40 {
                let batch = opt.propose_batch(round);
                let delays: Vec<f64> = batch.iter().map(|p| toy_delay(p)).collect();
                opt.observe_batch(&batch, &delays);
                trace.extend(batch.into_iter().map(Placement::into_vec));
            }
            (trace, opt.best())
        };
        let (trace_a, best_a) = run();
        let (trace_b, best_b) = run();
        assert_eq!(trace_a, trace_b);
        let (pa, da) = best_a.unwrap();
        let (pb, db) = best_b.unwrap();
        assert_eq!(pa.as_slice(), pb.as_slice());
        assert_eq!(da.to_bits(), db.to_bits());
    }

    #[test]
    fn exchanges_compose_valid_placements_and_improve_over_bootstrap() {
        let spec = HierarchySpec::new(3, 4); // paper shape: 21 slots
        let cc = 100;
        let cfg = ShardedConfig { particles: 16, exchange_every: 3 };
        let mut opt = ShardedPso::from_spec(spec, cc, cfg, Pcg32::seed_from_u64(5));
        let mut first = None;
        for round in 0..80 {
            let batch = opt.propose_batch(round);
            let delays: Vec<f64> = batch
                .iter()
                .map(|p| {
                    validate_placement(p, spec.dimensions(), cc).expect("valid candidate");
                    toy_delay(p)
                })
                .collect();
            if first.is_none() {
                first = Some(delays[0]);
            }
            opt.observe_batch(&batch, &delays);
        }
        let (best, d) = opt.best().expect("observed rounds");
        validate_placement(&best, spec.dimensions(), cc).expect("valid best");
        assert!(d < first.unwrap(), "best {d} should beat bootstrap {}", first.unwrap());
    }

    #[test]
    fn region_partition_covers_every_slot_once() {
        for (depth, width) in [(1usize, 3usize), (2, 2), (3, 4), (4, 2)] {
            let spec = HierarchySpec::new(depth, width);
            let opt =
                ShardedPso::from_spec(spec, 500, ShardedConfig::default(), Pcg32::seed_from_u64(1));
            let mut all: Vec<usize> =
                opt.regions.iter().flat_map(|r| r.slots().to_vec()).collect();
            all.sort_unstable();
            assert_eq!(all, (0..spec.dimensions()).collect::<Vec<_>>(), "D{depth} W{width}");
        }
    }

    #[test]
    fn restore_adopts_a_matching_best_and_rejects_foreign_state() {
        let cfg = ShardedConfig::default();
        let mut opt = ShardedPso::for_dims(3, 10, cfg, Pcg32::seed_from_u64(2));
        let state = OptimizerState {
            name: "sharded-pso".into(),
            best: Some((Placement::new(vec![4, 1, 7]), 12.5)),
        };
        opt.restore(&state).unwrap();
        let (p, d) = opt.best().unwrap();
        assert_eq!(p.as_slice(), &[4, 1, 7]);
        assert_eq!(d, 12.5);
        // And the next sweep still emits valid placements on the new base.
        testkit::run_toy_validated(&mut opt, 3, 10, 20, toy_delay);
        let foreign = OptimizerState { name: "pso".into(), best: None };
        assert!(opt.restore(&foreign).is_err());
    }
}
