//! The paper's synchronous PSO (Algorithm 1) as an [`Optimizer`] — the
//! simulation-mode counterpart of the live [`super::PsoPlacement`].
//!
//! Two proposal modes over the same [`Swarm`] state:
//!
//! * **exact** (`"pso"`) — one-particle batches replaying Algorithm 1
//!   verbatim: each particle moves against the gbest *as of its turn*,
//!   so a same-seed run through the registry reproduces the legacy
//!   `run_sim` trace bit-for-bit. [`Optimizer::group_size`] is the swarm
//!   size, so the driver groups per-particle evaluations back into the
//!   paper's per-iteration trace rows.
//! * **batched** (`"pso-batched"`) — whole-swarm batches: all particles
//!   move first, then the environment scores the entire iteration in a
//!   single [`super::Environment::eval_batch`] dispatch (classic
//!   two-phase synchronous PSO; no within-iteration gbest visibility).

use super::{Optimizer, OptimizerState, Placement, PlacementError};
use crate::prng::Pcg32;
use crate::pso::{PsoConfig, Swarm};

/// Synchronous-PSO placement optimizer over a [`Swarm`].
pub struct SwarmOptimizer {
    swarm: Swarm,
    batched: bool,
}

impl SwarmOptimizer {
    /// Algorithm-1-exact mode (registry name `"pso"`).
    pub fn exact(dims: usize, client_count: usize, cfg: PsoConfig, rng: Pcg32) -> SwarmOptimizer {
        SwarmOptimizer { swarm: Swarm::new(dims, client_count, cfg, rng), batched: false }
    }

    /// Whole-swarm-per-call mode (registry name `"pso-batched"`).
    pub fn batched(dims: usize, client_count: usize, cfg: PsoConfig, rng: Pcg32) -> SwarmOptimizer {
        SwarmOptimizer { swarm: Swarm::new(dims, client_count, cfg, rng), batched: true }
    }

    /// The underlying swarm (trace inspection, convergence checks).
    pub fn swarm(&self) -> &Swarm {
        &self.swarm
    }
}

impl Optimizer for SwarmOptimizer {
    fn name(&self) -> &'static str {
        if self.batched {
            "pso-batched"
        } else {
            "pso"
        }
    }

    fn propose_batch(&mut self, _round: usize) -> Vec<Placement> {
        if self.batched {
            self.swarm.begin_iteration().into_iter().map(Placement::new).collect()
        } else {
            vec![Placement::new(self.swarm.propose_next())]
        }
    }

    fn observe_batch(&mut self, _placements: &[Placement], delays: &[f64]) {
        if self.batched {
            self.swarm.complete_iteration(delays);
        } else {
            for &d in delays {
                self.swarm.observe_next(d);
            }
        }
    }

    fn best(&self) -> Option<(Placement, f64)> {
        if self.swarm.gbest_fitness > f64::NEG_INFINITY {
            Some((Placement::new(self.swarm.gbest_placement()), -self.swarm.gbest_fitness))
        } else {
            None
        }
    }

    fn converged(&self) -> bool {
        self.swarm.converged()
    }

    fn group_size(&self) -> usize {
        self.swarm.cfg.particles
    }

    fn restore(&mut self, state: &OptimizerState) -> Result<(), PlacementError> {
        super::check_state_name(self.name(), state)?;
        if let Some((placement, delay)) = &state.best {
            let dims = self.swarm.particles[0].position.len();
            if placement.len() != dims {
                return Err(PlacementError::WrongArity { expected: dims, got: placement.len() });
            }
            self.swarm.seed_gbest(placement, *delay);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::testkit;

    fn toy(pos: &[usize]) -> f64 {
        pos.chunks(2).map(|l| *l.iter().max().unwrap() as f64).sum::<f64>() + 1.0
    }

    #[test]
    fn exact_mode_replays_algorithm_one() {
        // Driving the optimizer through the batch protocol must equal
        // driving the raw swarm through step() — same seeds, same toys.
        let cfg = PsoConfig { particles: 5, iterations: 40, ..PsoConfig::paper() };
        let mut legacy = Swarm::new(4, 16, cfg, Pcg32::seed_from_u64(9));
        let mut legacy_tpds = Vec::new();
        for _ in 0..40 {
            let st = legacy.step(toy);
            legacy_tpds.extend(st.per_particle_tpd);
        }

        let mut opt = SwarmOptimizer::exact(4, 16, cfg, Pcg32::seed_from_u64(9));
        let new_tpds = testkit::run_toy_validated(&mut opt, 4, 16, 40 * 5, toy);

        assert_eq!(legacy_tpds, new_tpds);
        assert_eq!(opt.swarm().gbest_placement(), legacy.gbest_placement());
    }

    #[test]
    fn batched_mode_proposes_whole_swarm() {
        let cfg = PsoConfig { particles: 6, iterations: 50, ..PsoConfig::paper() };
        let mut opt = SwarmOptimizer::batched(3, 12, cfg, Pcg32::seed_from_u64(4));
        let batch = opt.propose_batch(0);
        assert_eq!(batch.len(), 6);
        let delays = testkit::run_toy_validated(&mut opt, 3, 12, 6 * 49, toy);
        let early: f64 = delays[..6].iter().sum::<f64>() / 6.0;
        let (_, best) = opt.best().expect("evaluated");
        assert!(best < early, "batched PSO should improve: best {best}, early mean {early}");
    }

    #[test]
    fn restore_warm_starts_gbest() {
        let cfg = PsoConfig::paper();
        let mut a = SwarmOptimizer::exact(3, 10, cfg, Pcg32::seed_from_u64(1));
        testkit::run_toy_validated(&mut a, 3, 10, 60, toy);
        let snap = a.state();
        assert_eq!(snap.name, "pso");

        let mut b = SwarmOptimizer::exact(3, 10, cfg, Pcg32::seed_from_u64(2));
        b.restore(&snap).unwrap();
        let (bp, bd) = b.best().expect("restored");
        let (ap, ad) = a.best().unwrap();
        assert_eq!(ap, bp);
        assert!((ad - bd).abs() < 1e-12);
    }
}
