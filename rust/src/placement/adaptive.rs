//! Adaptive Flag-Swap — the paper's future-work extension ("adapting
//! PSO for continuous system variations").
//!
//! Plain Flag-Swap pins the global best once the swarm converges; if the
//! system then *changes* (a fast client gets loaded, a container is
//! rescheduled), the pinned placement silently degrades. This wrapper
//! watches the post-convergence round delays and, when they drift above
//! the converged baseline for several consecutive rounds, restarts the
//! swarm — re-seeding one particle at the incumbent placement so good
//! structure survives the reset.

use super::{Optimizer, OptimizerState, Placement, PlacementError, PsoPlacement};
use crate::log_info;
use crate::prng::Pcg32;
use crate::pso::PsoConfig;

/// Drift-aware PSO placement.
pub struct AdaptivePsoPlacement {
    inner: PsoPlacement,
    dims: usize,
    client_count: usize,
    cfg: PsoConfig,
    rng: Pcg32,
    /// Delay considered "normal" after convergence (the gbest delay at
    /// pin time).
    baseline: Option<f64>,
    /// Rounds in a row whose delay exceeded `baseline * drift_factor`.
    drift_rounds: usize,
    /// Re-optimize when delay exceeds baseline by this factor...
    pub drift_factor: f64,
    /// ...for this many consecutive rounds.
    pub drift_patience: usize,
    /// Number of swarm restarts performed (observable for tests/metrics).
    pub restarts: usize,
}

impl AdaptivePsoPlacement {
    pub fn new(dims: usize, client_count: usize, cfg: PsoConfig, mut rng: Pcg32) -> Self {
        let inner = PsoPlacement::new(dims, client_count, cfg, rng.split());
        AdaptivePsoPlacement {
            inner,
            dims,
            client_count,
            cfg,
            rng,
            baseline: None,
            drift_rounds: 0,
            drift_factor: 1.5,
            drift_patience: 3,
            restarts: 0,
        }
    }

    /// Whether the optimizer is currently in its pinned/exploit phase.
    pub fn pinned(&self) -> bool {
        self.inner.pinned()
    }

    fn restart(&mut self) {
        self.restarts += 1;
        log_info!(
            "adaptive-pso",
            "delay drift detected (baseline {:.3}s exceeded {} rounds) — restarting swarm (#{})",
            self.baseline.unwrap_or(f64::NAN),
            self.drift_patience,
            self.restarts
        );
        // Fresh swarm; the incumbent gbest placement is worth keeping as
        // a starting particle, which we approximate by reporting it first
        // (the new swarm's first proposal replaces a random particle's
        // initial evaluation).
        self.inner = PsoPlacement::new(self.dims, self.client_count, self.cfg, self.rng.split());
        self.baseline = None;
        self.drift_rounds = 0;
    }
}

impl Optimizer for AdaptivePsoPlacement {
    fn name(&self) -> &'static str {
        "adaptive-pso"
    }

    fn propose_batch(&mut self, round: usize) -> Vec<Placement> {
        self.inner.propose_batch(round)
    }

    fn observe_batch(&mut self, placements: &[Placement], delays: &[f64]) {
        for (p, &delay_secs) in placements.iter().zip(delays) {
            let was_pinned = self.inner.pinned();
            self.inner
                .observe_batch(std::slice::from_ref(p), &[delay_secs]);
            if was_pinned {
                let baseline =
                    *self.baseline.get_or_insert(delay_secs.max(self.inner.gbest_delay()));
                if delay_secs > baseline * self.drift_factor {
                    self.drift_rounds += 1;
                    if self.drift_rounds >= self.drift_patience {
                        self.restart();
                    }
                } else {
                    self.drift_rounds = 0;
                }
            }
        }
    }

    fn best(&self) -> Option<(Placement, f64)> {
        self.inner.best()
    }

    fn converged(&self) -> bool {
        self.inner.pinned()
    }

    fn restore(&mut self, state: &OptimizerState) -> Result<(), PlacementError> {
        super::check_state_name(self.name(), state)?;
        if let Some((placement, delay)) = state.best.clone() {
            let inner_state =
                OptimizerState { name: self.inner.name().to_string(), best: Some((placement, delay)) };
            self.inner.restore(&inner_state)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One propose/observe cycle against a toy landscape.
    fn step(s: &mut AdaptivePsoPlacement, round: usize, delay_of: impl Fn(&[usize]) -> f64) -> f64 {
        let p = s.propose_batch(round).pop().unwrap();
        let d = delay_of(&p);
        s.observe_batch(std::slice::from_ref(&p), &[d]);
        d
    }

    /// Landscape whose "fast client" changes at a drift point.
    fn delay(pos: &[usize], drifted: bool) -> f64 {
        let cost = |c: usize| -> f64 {
            if drifted {
                // Previously-fast low ids become slow; high ids fast.
                (20usize.saturating_sub(c)) as f64
            } else {
                c as f64
            }
        };
        pos.chunks(2)
            .map(|l| l.iter().map(|&c| cost(c)).fold(0.0, f64::max))
            .sum::<f64>()
            + 1.0
    }

    #[test]
    fn recovers_from_system_drift() {
        let mut s = AdaptivePsoPlacement::new(3, 21, PsoConfig::paper(), Pcg32::seed_from_u64(1));
        // Phase 1: stable system, let it converge.
        let mut last_stable = f64::INFINITY;
        for round in 0..120 {
            last_stable = step(&mut s, round, |p| delay(p, false));
        }
        assert!(s.pinned(), "should pin in the stable phase");
        // Random expectation ≈ E[max of 2 U{0..20}] + E[U{0..20}] + 1 ≈ 25.
        assert!(last_stable <= 20.0, "stable phase should beat random: {last_stable}");

        // Phase 2: the system drifts — the pinned placement is now bad.
        let mut recovered = f64::INFINITY;
        for round in 120..400 {
            recovered = step(&mut s, round, |p| delay(p, true));
        }
        assert!(s.restarts >= 1, "drift should trigger a restart");
        assert!(
            recovered < 20.0,
            "should re-optimize for the drifted landscape, got {recovered}"
        );
    }

    #[test]
    fn no_restart_without_drift() {
        let mut s = AdaptivePsoPlacement::new(3, 15, PsoConfig::paper(), Pcg32::seed_from_u64(2));
        for round in 0..200 {
            step(&mut s, round, |p| delay(p, false));
        }
        assert_eq!(s.restarts, 0, "stable system must not restart");
    }

    #[test]
    fn transient_spike_does_not_restart() {
        let mut s = AdaptivePsoPlacement::new(3, 15, PsoConfig::paper(), Pcg32::seed_from_u64(3));
        // Converge first.
        for round in 0..120 {
            step(&mut s, round, |p| delay(p, false));
        }
        assert!(s.pinned());
        // One-off spikes below the patience threshold.
        for round in 120..200 {
            let spike = if round % 10 == 0 { 5.0 } else { 1.0 };
            step(&mut s, round, |p| delay(p, false) * spike);
        }
        assert_eq!(s.restarts, 0, "isolated spikes must not restart the swarm");
    }
}
