//! Adaptive Flag-Swap — the paper's future-work extension ("adapting
//! PSO for continuous system variations").
//!
//! Plain Flag-Swap pins the global best once the swarm converges; if the
//! system then *changes* (a fast client gets loaded, a container is
//! rescheduled), the pinned placement silently degrades. This wrapper
//! watches the post-convergence round delays and, when they drift above
//! what the observed noise can explain for several consecutive rounds,
//! restarts the swarm — re-seeding one particle at the incumbent
//! placement so good structure survives the reset.
//!
//! ## Variance-based drift detection
//!
//! The original detector compared each pinned-round delay against
//! `baseline × drift_factor` with constants tuned on the *static*
//! analytic landscape. Against `EventDrivenEnv` that misfires in both
//! directions: jittery/contended scenarios routinely exceed a fixed
//! 1.5× of the (lucky-minimum) baseline without any real drift, while a
//! deterministic environment can degrade 40% without ever crossing it.
//! The detector therefore learns the *inter-round score variance*
//! on-line: the first [`Self::NOISE_WARMUP`] post-pin delays estimate
//! the noise distribution (Welford mean/variance), after which a round
//! counts as drifted only above `mean + drift_z·std` (floored at
//! `mean × 1.05` so zero-variance environments still detect small real
//! shifts). Non-drifted rounds keep refining the estimate; drifted
//! rounds are excluded so a real shift cannot talk its way into the
//! noise model. `drift_patience` consecutive drifted rounds trigger the
//! restart, which resets the noise model for the new regime and
//! warm-starts the fresh swarm at the incumbent placement (at its
//! freshly *measured* cost, so the new regime can displace it).
//!
//! ## Pinned probing
//!
//! A pinned swarm that only ever re-runs its incumbent is blind: it
//! cannot notice that a *neighboring* placement became better under the
//! current conditions. Every [`AdaptivePsoPlacement::PROBE_PERIOD`]-th
//! pinned round therefore proposes a one-swap neighbor of the incumbent
//! instead. Probe delays never enter the drift noise model (they are a
//! different placement's cost), and a probe that strictly beats the
//! incumbent's best observed delay is adopted as the new pinned
//! placement — cheap continuous tracking between full restarts.

use super::{Optimizer, OptimizerState, Placement, PlacementError, PsoPlacement};
use crate::log_info;
use crate::prng::Pcg32;
use crate::pso::PsoConfig;

/// Drift-aware PSO placement.
pub struct AdaptivePsoPlacement {
    inner: PsoPlacement,
    dims: usize,
    client_count: usize,
    cfg: PsoConfig,
    rng: Pcg32,
    /// Welford state over the post-pin, non-drifted round delays: count,
    /// running mean, and sum of squared deviations.
    obs_n: usize,
    obs_mean: f64,
    obs_m2: f64,
    /// Rounds in a row whose delay exceeded the drift threshold.
    drift_rounds: usize,
    /// The probe placement currently in flight, if any (its delay must
    /// bypass both the inner swarm and the drift detector).
    probe: Option<Placement>,
    /// Pinned proposals made since the last (re)start — drives the
    /// probing cadence.
    pinned_proposals: usize,
    /// Re-optimize when a pinned round's delay exceeds the observed
    /// noise mean by this many observed standard deviations...
    pub drift_z: f64,
    /// ...for this many consecutive rounds.
    pub drift_patience: usize,
    /// Number of swarm restarts performed (observable for tests/metrics).
    pub restarts: usize,
}

impl AdaptivePsoPlacement {
    /// Post-pin delays collected before the variance threshold arms.
    /// No drift is ever flagged during warmup — four rounds of latency
    /// against a detector that no longer misfires on noise.
    pub const NOISE_WARMUP: usize = 4;

    /// Relative floor on the drift threshold: even a zero-variance
    /// environment must degrade by 5% before a round counts as drifted.
    const THRESHOLD_FLOOR: f64 = 1.05;

    /// Every `PROBE_PERIOD`-th pinned proposal explores a one-swap
    /// neighbor of the incumbent instead of re-running it verbatim.
    pub const PROBE_PERIOD: usize = 4;

    pub fn new(dims: usize, client_count: usize, cfg: PsoConfig, mut rng: Pcg32) -> Self {
        let inner = PsoPlacement::new(dims, client_count, cfg, rng.split());
        AdaptivePsoPlacement {
            inner,
            dims,
            client_count,
            cfg,
            rng,
            obs_n: 0,
            obs_mean: 0.0,
            obs_m2: 0.0,
            drift_rounds: 0,
            probe: None,
            pinned_proposals: 0,
            drift_z: 4.0,
            drift_patience: 3,
            restarts: 0,
        }
    }

    /// A one-swap neighbor of the incumbent placement: one slot handed
    /// to a uniformly-drawn client not already holding a slot. `None`
    /// when every client holds a slot (nothing to swap in).
    fn probe_placement(&mut self) -> Option<Placement> {
        if self.client_count <= self.dims {
            return None;
        }
        let mut p = self.inner.gbest();
        // The shared single-coordinate move — since the incumbent was
        // just (re-)evaluated, the analytic oracle rescores this probe
        // through its delta fast path.
        let (slot, candidate) = super::draw_slot_replacement(&p, self.client_count, &mut self.rng);
        p[slot] = candidate;
        Some(Placement::new(p))
    }

    /// Whether the optimizer is currently in its pinned/exploit phase.
    pub fn pinned(&self) -> bool {
        self.inner.pinned()
    }

    /// The learned standard deviation of pinned-round delays (`None`
    /// until the warmup completes).
    pub fn noise_std(&self) -> Option<f64> {
        (self.obs_n >= Self::NOISE_WARMUP)
            .then(|| (self.obs_m2.max(0.0) / (self.obs_n - 1) as f64).sqrt())
    }

    /// The delay above which a pinned round counts as drifted (`None`
    /// until the warmup completes).
    pub fn drift_threshold(&self) -> Option<f64> {
        self.noise_std()
            .map(|std| (self.obs_mean + self.drift_z * std).max(self.obs_mean * Self::THRESHOLD_FLOOR))
    }

    fn observe_noise(&mut self, delay_secs: f64) {
        self.obs_n += 1;
        let d = delay_secs - self.obs_mean;
        self.obs_mean += d / self.obs_n as f64;
        self.obs_m2 += d * (delay_secs - self.obs_mean);
    }

    /// One pinned-round delay through the drift detector.
    fn note_pinned_delay(&mut self, delay_secs: f64) {
        match self.drift_threshold() {
            None => {
                // Warmup: everything feeds the noise model, nothing
                // counts as drift yet.
                self.observe_noise(delay_secs);
            }
            Some(threshold) if delay_secs > threshold => {
                self.drift_rounds += 1;
                if self.drift_rounds >= self.drift_patience {
                    self.restart(delay_secs);
                }
            }
            Some(_) => {
                self.drift_rounds = 0;
                self.observe_noise(delay_secs);
            }
        }
    }

    /// Restart the swarm. `drifted_delay` is the delay of the round that
    /// confirmed the drift — the incumbent placement's *current* cost.
    fn restart(&mut self, drifted_delay: f64) {
        self.restarts += 1;
        log_info!(
            "adaptive-pso",
            "delay drift detected (noise mean {:.3}s ± {:.3}s exceeded {} rounds) — restarting swarm (#{})",
            self.obs_mean,
            self.noise_std().unwrap_or(f64::NAN),
            self.drift_patience,
            self.restarts
        );
        // Fresh swarm, warm-started: the incumbent gbest placement is
        // good *structure* even if its pre-drift delay is stale, so it
        // is seeded back as the new swarm's social attractor — but at
        // its freshly *measured* (drifted) cost, so any placement that
        // actually suits the new regime displaces it immediately.
        let incumbent = self.inner.best();
        self.inner = PsoPlacement::new(self.dims, self.client_count, self.cfg, self.rng.split());
        if let Some((placement, _stale_delay)) = incumbent {
            let state = OptimizerState {
                name: self.inner.name().to_string(),
                best: Some((placement, drifted_delay)),
            };
            // Same-strategy restore with a same-arity placement cannot
            // fail; ignore defensively.
            let _ = self.inner.restore(&state);
        }
        self.obs_n = 0;
        self.obs_mean = 0.0;
        self.obs_m2 = 0.0;
        self.drift_rounds = 0;
        self.probe = None;
        self.pinned_proposals = 0;
    }
}

impl Optimizer for AdaptivePsoPlacement {
    fn name(&self) -> &'static str {
        "adaptive-pso"
    }

    fn propose_batch(&mut self, round: usize) -> Vec<Placement> {
        if self.inner.pinned() {
            self.pinned_proposals += 1;
            if self.pinned_proposals % Self::PROBE_PERIOD == 0 {
                if let Some(p) = self.probe_placement() {
                    self.probe = Some(p.clone());
                    return vec![p];
                }
            }
        }
        self.probe = None;
        self.inner.propose_batch(round)
    }

    fn observe_batch(&mut self, placements: &[Placement], delays: &[f64]) {
        for (p, &delay_secs) in placements.iter().zip(delays) {
            if self.probe.as_ref() == Some(p) {
                // Probe round: the inner swarm never proposed this
                // placement, and its delay says nothing about the
                // incumbent's noise — adopt on strict improvement,
                // otherwise discard.
                self.probe = None;
                if delay_secs < self.inner.gbest_delay() {
                    let state = OptimizerState {
                        name: self.inner.name().to_string(),
                        best: Some((p.clone(), delay_secs)),
                    };
                    let _ = self.inner.restore(&state);
                    // The noise model described the previous incumbent;
                    // start a fresh estimate for the adopted one.
                    self.obs_n = 0;
                    self.obs_mean = 0.0;
                    self.obs_m2 = 0.0;
                    self.drift_rounds = 0;
                }
                continue;
            }
            let was_pinned = self.inner.pinned();
            self.inner
                .observe_batch(std::slice::from_ref(p), &[delay_secs]);
            if was_pinned {
                self.note_pinned_delay(delay_secs);
            }
        }
    }

    fn best(&self) -> Option<(Placement, f64)> {
        self.inner.best()
    }

    fn converged(&self) -> bool {
        self.inner.pinned()
    }

    fn restore(&mut self, state: &OptimizerState) -> Result<(), PlacementError> {
        super::check_state_name(self.name(), state)?;
        if let Some((placement, delay)) = state.best.clone() {
            let inner_state =
                OptimizerState { name: self.inner.name().to_string(), best: Some((placement, delay)) };
            self.inner.restore(&inner_state)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One propose/observe cycle against a toy landscape.
    fn step(s: &mut AdaptivePsoPlacement, round: usize, delay_of: impl Fn(&[usize]) -> f64) -> f64 {
        let p = s.propose_batch(round).pop().unwrap();
        let d = delay_of(&p);
        s.observe_batch(std::slice::from_ref(&p), &[d]);
        d
    }

    /// Landscape whose "fast client" changes at a drift point.
    fn delay(pos: &[usize], drifted: bool) -> f64 {
        let cost = |c: usize| -> f64 {
            if drifted {
                // Previously-fast low ids become slow; high ids fast.
                (20usize.saturating_sub(c)) as f64
            } else {
                c as f64
            }
        };
        pos.chunks(2)
            .map(|l| l.iter().map(|&c| cost(c)).fold(0.0, f64::max))
            .sum::<f64>()
            + 1.0
    }

    #[test]
    fn recovers_from_system_drift() {
        let mut s = AdaptivePsoPlacement::new(3, 21, PsoConfig::paper(), Pcg32::seed_from_u64(1));
        // Phase 1: stable system, let it converge.
        let mut last_stable = f64::INFINITY;
        for round in 0..200 {
            last_stable = step(&mut s, round, |p| delay(p, false));
        }
        assert!(s.pinned(), "should pin in the stable phase");
        assert!(s.drift_threshold().is_some(), "noise model should be armed pre-drift");
        // Random expectation ≈ E[max of 2 U{0..20}] + E[U{0..20}] + 1 ≈ 25.
        assert!(last_stable <= 20.0, "stable phase should beat random: {last_stable}");

        // Phase 2: the system drifts — the pinned placement is now bad.
        // Judge recovery on the best of the final rounds: most of them
        // re-run the re-optimized incumbent, but some are deliberate
        // exploration probes and may not score well themselves.
        let mut tail = Vec::new();
        for round in 200..480 {
            tail.push(step(&mut s, round, |p| delay(p, true)));
        }
        let recovered = tail[tail.len() - 8..].iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(s.restarts >= 1, "drift should trigger a restart");
        assert!(
            recovered < 20.0,
            "should re-optimize for the drifted landscape, got {recovered}"
        );
    }

    #[test]
    fn no_restart_without_drift() {
        let mut s = AdaptivePsoPlacement::new(3, 15, PsoConfig::paper(), Pcg32::seed_from_u64(2));
        for round in 0..200 {
            step(&mut s, round, |p| delay(p, false));
        }
        assert_eq!(s.restarts, 0, "stable system must not restart");
    }

    #[test]
    fn transient_spike_does_not_restart() {
        let mut s = AdaptivePsoPlacement::new(3, 15, PsoConfig::paper(), Pcg32::seed_from_u64(3));
        // Converge first.
        for round in 0..120 {
            step(&mut s, round, |p| delay(p, false));
        }
        assert!(s.pinned());
        // One-off spikes below the patience threshold.
        for round in 120..200 {
            let spike = if round % 10 == 0 { 5.0 } else { 1.0 };
            step(&mut s, round, |p| delay(p, false) * spike);
        }
        assert_eq!(s.restarts, 0, "isolated spikes must not restart the swarm");
    }

    #[test]
    fn threshold_retunes_from_observed_variance() {
        // Deterministic post-pin delays: std ≈ 0 ⇒ the threshold sits at
        // the 5% floor just above the mean.
        let mut s = AdaptivePsoPlacement::new(3, 15, PsoConfig::paper(), Pcg32::seed_from_u64(4));
        for round in 0..150 {
            step(&mut s, round, |p| delay(p, false));
        }
        assert!(s.pinned());
        let tight = s.drift_threshold().expect("warmup done after 30 pinned rounds");
        assert!(
            (tight - s.obs_mean * 1.05).abs() < 1e-9,
            "zero-variance threshold should sit at the floor: {tight} vs mean {}",
            s.obs_mean
        );
        assert!(s.noise_std().unwrap() < 1e-9);

        // A noisy-but-stationary environment (round delays swing up to
        // 1.9× from the first round on): the learned threshold must
        // widen to cover the noise band, so no restart fires even though
        // many pinned rounds exceed 1.5× the luckiest observation — the
        // old static detector's misfire mode.
        let mut n = AdaptivePsoPlacement::new(3, 15, PsoConfig::paper(), Pcg32::seed_from_u64(5));
        let noise = [1.0, 1.9, 1.3, 1.8, 1.2, 1.9, 1.4, 1.7];
        for round in 0..400 {
            let mult = noise[round % noise.len()];
            step(&mut n, round, |p| delay(p, false) * mult);
        }
        assert!(n.pinned(), "stationary noise should not prevent pinning");
        assert_eq!(n.restarts, 0, "stationary noise must not restart the swarm");
        let wide = n.drift_threshold().unwrap();
        assert!(
            wide > n.obs_mean * 1.2,
            "threshold {wide} should widen well past the mean {} under noise",
            n.obs_mean
        );
    }

    #[test]
    fn pinned_phase_probes_neighbors_and_adopts_improvements() {
        use crate::placement::assert_valid_placement;
        let mut s = AdaptivePsoPlacement::new(3, 15, PsoConfig::paper(), Pcg32::seed_from_u64(9));
        for round in 0..150 {
            step(&mut s, round, |p| delay(p, false));
        }
        assert!(s.pinned());
        // Post-pin proposals are mostly the incumbent, but every
        // PROBE_PERIOD-th round explores a valid one-swap neighbor.
        let mut distinct = std::collections::BTreeSet::new();
        for round in 150..150 + 4 * AdaptivePsoPlacement::PROBE_PERIOD {
            let p = s.propose_batch(round).pop().unwrap();
            assert_valid_placement(&p, 3, 15);
            distinct.insert(p.clone().into_vec());
            let d = delay(&p, false);
            s.observe_batch(std::slice::from_ref(&p), &[d]);
        }
        assert!(distinct.len() >= 2, "probing should vary the pinned proposals");
        // Adoption: a probe strictly better than the incumbent becomes
        // the new pinned placement (simulate via a probe that scores
        // 0.25, below anything this landscape produces).
        let incumbent = s.best().expect("pinned swarm has a best").0;
        let mut probed = None;
        for round in 0..4 * AdaptivePsoPlacement::PROBE_PERIOD {
            let p = s.propose_batch(1000 + round).pop().unwrap();
            if p != incumbent {
                s.observe_batch(std::slice::from_ref(&p), &[0.25]);
                probed = Some(p);
                break;
            }
            s.observe_batch(std::slice::from_ref(&p), &[delay(&p, false)]);
        }
        let probed = probed.expect("a probe fires within PROBE_PERIOD pinned rounds");
        let (best, d) = s.best().expect("pinned swarm has a best");
        assert_eq!(best, probed, "strictly-better probe must be adopted");
        assert!((d - 0.25).abs() < 1e-12);
    }

    #[test]
    fn sustained_small_shift_is_caught_in_quiet_environments() {
        // A 40% degradation never crossed the old 1.5× static threshold;
        // with learned (near-zero) variance it must trigger a restart.
        let mut s = AdaptivePsoPlacement::new(3, 15, PsoConfig::paper(), Pcg32::seed_from_u64(6));
        for round in 0..150 {
            step(&mut s, round, |p| delay(p, false));
        }
        assert!(s.pinned());
        assert_eq!(s.restarts, 0);
        assert!(s.drift_threshold().is_some(), "noise model should be armed pre-shift");
        for round in 150..200 {
            step(&mut s, round, |p| delay(p, false) * 1.4);
        }
        assert!(s.restarts >= 1, "a sustained 40% shift must restart a quiet system");
    }
}
