//! Intra-batch parallel evaluation: shard one `eval_batch` dispatch
//! across worker threads, each owning its own [`Environment`] instance
//! (and therefore its own `TpdScratch`/`EvalScratch`/`RoundScratch`),
//! with results slotted back by candidate index.
//!
//! # Bit-exactness contract
//!
//! Sharding is *bit-identical to the serial path at any thread count*
//! (property-tested in `tests/properties.rs` at 1, 2 and 8 workers).
//! The contract rests on two invariants the environments already hold:
//!
//! 1. **Path-independence of scores.** Every scoring path — cached
//!    `Same`, `delta_replace`/`delta_swap`, full streaming eval, full
//!    DES round — returns the exact bits a fresh full evaluation of the
//!    same candidate would, with all per-leaf/per-level folds performed
//!    in one fixed order. So it does not matter which worker's rolling
//!    delta base a candidate is classified against.
//! 2. **Lockstep round streams.** For dynamic environments (the DES
//!    oracle), the realized round advances once per `eval_batch`
//!    dispatch and the per-transfer jitter stream reseeds from the
//!    round seed per candidate. [`ParEvalBatch`] dispatches **every**
//!    worker on **every** batch — an empty chunk still advances that
//!    worker's round stream — so all workers realize the same virtual
//!    rounds a serial environment would.
//!
//! Chunks are contiguous, so concatenating worker results in worker
//! order restores candidate order exactly.

use super::{Environment, Placement, PlacementError};
use crate::log_warn;
use crate::obs::defs as obs;

/// Shards [`Environment::eval_batch`] across `N` worker environments on
/// `N` threads (worker 0 runs inline on the dispatching thread). Build
/// with a factory so each worker owns an identically-constructed
/// environment; see the module docs for the bit-exactness contract.
///
/// On an `Err` (an invalid candidate) the globally-first error is
/// returned, but workers that already scored their chunk have advanced
/// their round streams — lockstep is only guaranteed along the
/// all-`Ok` path, which is the only path optimizers drive (they
/// generate validated candidates).
pub struct ParEvalBatch<E: Environment> {
    workers: Vec<E>,
}

impl<E: Environment> ParEvalBatch<E> {
    /// Build `threads` workers by calling `factory(0..threads)`. Each
    /// call must construct the environment identically (same scenario,
    /// same seeds) — the worker index is provided for labeling only.
    ///
    /// `threads == 0` clamps to one worker with a warning: a zero-thread
    /// pool would have no workers to dispatch to, so the first
    /// `eval_batch` would return no results for a non-empty batch (the
    /// `--threads 0` deadlock shape) — clamping keeps every caller-side
    /// "use however many I said" path safe.
    pub fn new(threads: usize, mut factory: impl FnMut(usize) -> E) -> ParEvalBatch<E> {
        if threads == 0 {
            log_warn!("placement", "ParEvalBatch built with 0 threads; clamping to 1 worker");
        }
        let threads = threads.max(1);
        ParEvalBatch { workers: (0..threads).map(&mut factory).collect() }
    }

    /// The configured worker count.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }
}

impl<E: Environment> Environment for ParEvalBatch<E> {
    /// Transparent layer: report the inner oracle's name.
    fn name(&self) -> &'static str {
        self.workers[0].name()
    }

    fn eval(&mut self, placement: &Placement) -> Result<f64, PlacementError> {
        // Single candidates are not worth a thread spawn: worker 0
        // scores, the rest advance one round on an empty batch so every
        // stream stays in lockstep.
        let mut workers = self.workers.iter_mut();
        let first = workers.next().expect("at least one worker");
        let tpd = first.eval(placement)?;
        for w in workers {
            w.eval_batch(&[])?;
        }
        Ok(tpd)
    }

    fn eval_batch(&mut self, batch: &[Placement]) -> Result<Vec<f64>, PlacementError> {
        let n = batch.len();
        let threads = self.workers.len();
        obs::SHARD_BATCHES.inc();
        obs::SHARD_CANDIDATES.add(n as u64);
        obs::SHARD_WORKERS_HIGH_WATER.set_max(threads as i64);
        // Contiguous chunks: concatenation in worker order restores
        // candidate order. Every worker is dispatched, empty or not.
        let chunk = n.div_ceil(threads).max(1);
        let chunk_of = |w: usize| &batch[(w * chunk).min(n)..((w + 1) * chunk).min(n)];

        let mut out: Vec<Option<Result<Vec<f64>, PlacementError>>> =
            (0..threads).map(|_| None).collect();
        if n <= chunk {
            // One non-empty chunk (single worker or tiny batch): skip
            // the thread scope entirely.
            for (w, (worker, slot)) in self.workers.iter_mut().zip(&mut out).enumerate() {
                *slot = Some(worker.eval_batch(chunk_of(w)));
            }
        } else {
            std::thread::scope(|s| {
                let mut inline = None;
                for (w, (worker, slot)) in self.workers.iter_mut().zip(&mut out).enumerate() {
                    let work = chunk_of(w);
                    if w == 0 {
                        inline = Some((worker, slot, work));
                    } else {
                        s.spawn(move || *slot = Some(worker.eval_batch(work)));
                    }
                }
                let (worker, slot, work) = inline.expect("worker 0 exists");
                *slot = Some(worker.eval_batch(work));
            });
        }

        let mut delays = Vec::with_capacity(n);
        for r in out {
            // Worker order == candidate order, so the first erroring
            // worker holds the globally-first invalid candidate.
            delays.append(&mut r.expect("every worker reports")?);
        }
        Ok(delays)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::ClientAttrs;
    use crate::hierarchy::HierarchySpec;
    use crate::placement::AnalyticTpd;
    use crate::prng::{Pcg32, Rng};

    fn population(n: usize, seed: u64) -> Vec<ClientAttrs> {
        let mut rng = Pcg32::seed_from_u64(seed);
        ClientAttrs::sample_population(n, (5.0, 15.0), (10.0, 50.0), 5.0, &mut rng)
    }

    fn neighbor_rich_batch(
        spec: HierarchySpec,
        cc: usize,
        count: usize,
        seed: u64,
    ) -> Vec<Placement> {
        // Random candidates interleaved with replace/swap neighbors of
        // their predecessor, so every scoring path (full, delta, same)
        // is exercised across shard boundaries.
        let dims = spec.dimensions();
        let mut rng = Pcg32::seed_from_u64(seed);
        let mut batch = vec![Placement::new(rng.sample_distinct(cc, dims))];
        while batch.len() < count {
            let prev: Vec<usize> = batch.last().unwrap().to_vec();
            let mut next = prev.clone();
            match rng.gen_range(4) {
                0 => next = rng.sample_distinct(cc, dims),
                1 => {
                    let s = rng.gen_range(dims as u64) as usize;
                    let mut c = rng.gen_range(cc as u64) as usize;
                    while next.contains(&c) {
                        c = (c + 1) % cc;
                    }
                    next[s] = c;
                }
                2 if dims >= 2 => {
                    let i = rng.gen_range(dims as u64) as usize;
                    let j = (i + 1 + rng.gen_range(dims as u64 - 1) as usize) % dims;
                    next.swap(i, j);
                }
                _ => {} // duplicate of prev: the Same path
            }
            batch.push(Placement::new(next));
        }
        batch
    }

    #[test]
    fn sharded_analytic_batches_match_serial_bit_for_bit() {
        let spec = HierarchySpec::new(3, 2);
        let cc = 40;
        let attrs = population(cc, 21);
        let batch = neighbor_rich_batch(spec, cc, 33, 5);
        let mut serial = AnalyticTpd::new(spec, attrs.clone());
        let want = serial.eval_batch(&batch).unwrap();
        for threads in [1usize, 2, 3, 8, 16] {
            let mut par = ParEvalBatch::new(threads, |_| AnalyticTpd::new(spec, attrs.clone()));
            assert_eq!(par.threads(), threads);
            let got = par.eval_batch(&batch).unwrap();
            let want_bits: Vec<u64> = want.iter().map(|d| d.to_bits()).collect();
            let got_bits: Vec<u64> = got.iter().map(|d| d.to_bits()).collect();
            assert_eq!(got_bits, want_bits, "threads = {threads}");
        }
    }

    #[test]
    fn more_workers_than_candidates_is_fine() {
        let spec = HierarchySpec::new(2, 2);
        let cc = 12;
        let attrs = population(cc, 3);
        let batch = neighbor_rich_batch(spec, cc, 2, 9);
        let mut serial = AnalyticTpd::new(spec, attrs.clone());
        let mut par = ParEvalBatch::new(8, |_| AnalyticTpd::new(spec, attrs.clone()));
        assert_eq!(par.eval_batch(&batch).unwrap(), serial.eval_batch(&batch).unwrap());
        // Empty batches and singles dispatch cleanly too.
        assert_eq!(par.eval_batch(&[]).unwrap(), Vec::<f64>::new());
        assert_eq!(
            par.eval(&batch[0]).unwrap().to_bits(),
            serial.eval(&batch[0]).unwrap().to_bits()
        );
    }

    #[test]
    fn zero_threads_clamps_to_one_worker() {
        // `--threads 0` must not construct a worker-less evaluator that
        // returns empty results (the dispatch-deadlock shape): the pool
        // clamps to one inline worker and scores exactly like serial.
        let spec = HierarchySpec::new(2, 2);
        let cc = 12;
        let attrs = population(cc, 6);
        let batch = neighbor_rich_batch(spec, cc, 5, 7);
        let mut par = ParEvalBatch::new(0, |_| AnalyticTpd::new(spec, attrs.clone()));
        assert_eq!(par.threads(), 1);
        let mut serial = AnalyticTpd::new(spec, attrs.clone());
        let got = par.eval_batch(&batch).unwrap();
        let want = serial.eval_batch(&batch).unwrap();
        assert_eq!(got.len(), batch.len());
        let got_bits: Vec<u64> = got.iter().map(|d| d.to_bits()).collect();
        let want_bits: Vec<u64> = want.iter().map(|d| d.to_bits()).collect();
        assert_eq!(got_bits, want_bits);
    }

    #[test]
    fn first_invalid_candidate_wins_across_shards() {
        let spec = HierarchySpec::new(2, 2);
        let cc = 12;
        let attrs = population(cc, 4);
        let mut batch = neighbor_rich_batch(spec, cc, 12, 2);
        batch[3] = Placement::new(vec![0, 0, 1]); // duplicate, in shard 1 of 4
        batch[9] = Placement::new(vec![5]); // wrong arity, in shard 3 of 4
        let mut par = ParEvalBatch::new(4, |_| AnalyticTpd::new(spec, attrs.clone()));
        let err = par.eval_batch(&batch).unwrap_err();
        assert!(matches!(err, PlacementError::DuplicateClient { .. }), "{err}");
    }
}
