//! Aggregation placement strategies (paper §IV.C + related-work
//! baselines).
//!
//! Every strategy implements the black-box [`PlacementStrategy`]
//! interface: propose a placement for the next round, receive the
//! measured round delay afterwards. The paper compares:
//! * [`RandomPlacement`] — SDFLMQ's built-in random strategy,
//! * [`RoundRobinPlacement`] — SDFLMQ's uniform round-robin strategy,
//! * [`PsoPlacement`] — Flag-Swap (the contribution).
//!
//! Two additional black-box meta-heuristics back the §II/§V claims
//! (ablation A2): [`GaPlacement`] (genetic algorithm) and
//! [`SaPlacement`] (simulated annealing).

mod adaptive;
mod ga;
mod pso_placement;
mod random;
mod round_robin;
mod sa;
mod tabu;

pub use adaptive::AdaptivePsoPlacement;
pub use ga::{GaConfig, GaPlacement};
pub use pso_placement::PsoPlacement;
pub use random::RandomPlacement;
pub use round_robin::RoundRobinPlacement;
pub use sa::{SaConfig, SaPlacement};
pub use tabu::{TabuConfig, TabuPlacement};

/// A black-box placement optimizer: proposes aggregator placements and
/// learns only from the measured round delay (never from client
/// internals — the paper's privacy constraint).
pub trait PlacementStrategy: Send {
    /// Strategy label used in CSV output and plots.
    fn name(&self) -> &'static str;

    /// Placement for the next round: `dims` distinct client ids in BFT
    /// slot order.
    fn propose(&mut self, round: usize) -> Vec<usize>;

    /// Black-box feedback: the wall-clock delay of the round that ran
    /// `placement`. Baselines ignore it.
    fn feedback(&mut self, placement: &[usize], delay_secs: f64);
}

/// Shared helper: validate a proposal (distinct ids within range).
pub fn assert_valid_placement(placement: &[usize], dims: usize, client_count: usize) {
    assert_eq!(placement.len(), dims, "placement has wrong arity");
    let mut seen = vec![false; client_count];
    for &c in placement {
        assert!(c < client_count, "client id {c} out of range");
        assert!(!std::mem::replace(&mut seen[c], true), "duplicate client {c}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg32;
    use crate::pso::PsoConfig;

    /// All strategies must emit valid placements for many rounds.
    #[test]
    fn all_strategies_emit_valid_placements() {
        let dims = 3;
        let cc = 10;
        let mk: Vec<Box<dyn PlacementStrategy>> = vec![
            Box::new(RandomPlacement::new(dims, cc, Pcg32::seed_from_u64(1))),
            Box::new(RoundRobinPlacement::new(dims, cc)),
            Box::new(PsoPlacement::new(
                dims,
                cc,
                PsoConfig::paper(),
                Pcg32::seed_from_u64(2),
            )),
            Box::new(GaPlacement::new(
                dims,
                cc,
                GaConfig::default(),
                Pcg32::seed_from_u64(3),
            )),
            Box::new(SaPlacement::new(
                dims,
                cc,
                SaConfig::default(),
                Pcg32::seed_from_u64(4),
            )),
        ];
        for mut s in mk {
            for round in 0..100 {
                let p = s.propose(round);
                assert_valid_placement(&p, dims, cc);
                // Toy delay: favor low ids.
                let d = p.iter().sum::<usize>() as f64 + 0.5;
                s.feedback(&p, d);
            }
        }
    }

    /// Black-box optimizers should, on average, beat random on the toy
    /// landscape after enough rounds.
    #[test]
    fn optimizers_beat_random_on_toy_landscape() {
        let dims = 4;
        let cc = 20;
        let run = |mut s: Box<dyn PlacementStrategy>| -> f64 {
            let mut total_late = 0.0;
            for round in 0..120 {
                let p = s.propose(round);
                let d = p.iter().sum::<usize>() as f64 + 1.0;
                if round >= 60 {
                    total_late += d;
                }
                s.feedback(&p, d);
            }
            total_late / 60.0
        };
        let rand_avg = run(Box::new(RandomPlacement::new(
            dims,
            cc,
            Pcg32::seed_from_u64(10),
        )));
        let pso_avg = run(Box::new(PsoPlacement::new(
            dims,
            cc,
            PsoConfig::paper(),
            Pcg32::seed_from_u64(11),
        )));
        let ga_avg = run(Box::new(GaPlacement::new(
            dims,
            cc,
            GaConfig::default(),
            Pcg32::seed_from_u64(12),
        )));
        let sa_avg = run(Box::new(SaPlacement::new(
            dims,
            cc,
            SaConfig::default(),
            Pcg32::seed_from_u64(13),
        )));
        assert!(pso_avg < rand_avg, "pso {pso_avg} !< random {rand_avg}");
        assert!(ga_avg < rand_avg, "ga {ga_avg} !< random {rand_avg}");
        assert!(sa_avg < rand_avg, "sa {sa_avg} !< random {rand_avg}");
    }

    #[test]
    #[should_panic(expected = "duplicate client")]
    fn validator_catches_duplicates() {
        assert_valid_placement(&[1, 1, 2], 3, 5);
    }
}
