//! Aggregation-placement optimization — the unified `Optimizer` /
//! [`Environment`] API (paper §IV.C + related-work baselines).
//!
//! The paper's core loop is *black-box* placement search: propose which
//! clients should hold the aggregator slots, observe only the resulting
//! round delay, repeat. This module factors that loop into two traits so
//! every search strategy runs against every delay oracle through one
//! code path:
//!
//! * [`Optimizer`] — proposes batches of candidate [`Placement`]s and
//!   learns from the observed delays. Implementations: [`SwarmOptimizer`]
//!   (the paper's synchronous PSO, exact Algorithm-1 semantics or a
//!   batched whole-swarm-per-call variant), [`PsoPlacement`] (Flag-Swap's
//!   steady-state live PSO), [`ShardedPso`] (region-local sub-swarms with
//!   epoch-barrier incumbent exchange), [`RandomPlacement`],
//!   [`RoundRobinPlacement`], [`GaPlacement`] (proposes whole generation
//!   cohorts), [`SaPlacement`], [`TabuPlacement`] and
//!   [`AdaptivePsoPlacement`].
//! * [`Environment`] — scores placements: [`AnalyticTpd`] (the Eq. 6–7
//!   TPD model over a simulated population, one dispatch per batch),
//!   [`EventDrivenEnv`] (the [`crate::des`] virtual-time round over a
//!   contended network with churn/dropout/straggler dynamics),
//!   [`EmulatedDelay`] (the docker-substitute throttling model from
//!   [`crate::fl::emulation`]), and [`crate::fl::LiveSession`] (a real
//!   measured FL round through broker + agents).
//!
//! [`registry`] maps strategy names (`"pso"`, `"random"`, `"round-robin"`,
//! `"ga"`, `"sa"`, `"tabu"`, `"adaptive-pso"`, `"pso-batched"`,
//! `"sharded-pso"`) to boxed optimizers, and [`drive`] is the generic evaluation loop connecting an
//! optimizer to an environment under a fixed evaluation budget.
//! Validation is `Result`-based ([`validate_placement`] /
//! [`PlacementError`]); [`assert_valid_placement`] remains as a thin
//! panicking wrapper for tests.

mod adaptive;
mod environment;
mod ga;
mod par_eval;
mod pso_placement;
mod pso_sim;
mod random;
pub mod registry;
mod round_robin;
mod sa;
mod sharded;
mod tabu;

pub use adaptive::AdaptivePsoPlacement;
pub use crate::des::EventDrivenEnv;
pub use environment::{AnalyticTpd, EmulatedDelay, Environment};
pub(crate) use environment::{classify, Diff, PathTally};
pub use ga::{GaConfig, GaPlacement};
pub use par_eval::ParEvalBatch;
pub use pso_placement::PsoPlacement;
pub use pso_sim::SwarmOptimizer;
pub use random::RandomPlacement;
pub use round_robin::RoundRobinPlacement;
pub use sa::{SaConfig, SaPlacement};
pub use sharded::{ShardedConfig, ShardedPso};
pub use tabu::{TabuConfig, TabuPlacement};

use crate::pso::IterationStats;
use std::fmt;

/// A candidate aggregator placement: `dims` distinct client ids in BFT
/// slot order. Derefs to `[usize]` for slice-style access.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Placement(Vec<usize>);

impl Placement {
    pub fn new(ids: Vec<usize>) -> Placement {
        Placement(ids)
    }

    pub fn as_slice(&self) -> &[usize] {
        &self.0
    }

    pub fn into_vec(self) -> Vec<usize> {
        self.0
    }
}

impl std::ops::Deref for Placement {
    type Target = [usize];

    fn deref(&self) -> &[usize] {
        &self.0
    }
}

impl AsRef<[usize]> for Placement {
    fn as_ref(&self) -> &[usize] {
        &self.0
    }
}

impl From<Vec<usize>> for Placement {
    fn from(ids: Vec<usize>) -> Placement {
        Placement(ids)
    }
}

impl fmt::Display for Placement {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:?}", self.0)
    }
}

/// Errors from placement validation, the strategy registry, optimizer
/// checkpoint restore, and environment evaluation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PlacementError {
    /// Placement length differs from the number of aggregator slots.
    WrongArity { expected: usize, got: usize },
    /// A client id exceeds the population size.
    ClientOutOfRange { client: usize, client_count: usize },
    /// The same client appears in two slots.
    DuplicateClient { client: usize },
    /// Strategy name not present in [`registry`].
    UnknownStrategy { name: String },
    /// The same strategy (after alias resolution) listed twice where a
    /// set of distinct strategies is required (e.g. the fleet matrix).
    DuplicateStrategy { name: String },
    /// Environment name not present in [`registry`] (see
    /// [`registry::ENV_NAMES`]).
    UnknownEnvironment { name: String },
    /// [`Optimizer::restore`] got a snapshot from a different strategy.
    StateMismatch { expected: String, got: String },
    /// The environment failed to produce a delay (e.g. a live round
    /// timed out).
    Environment(String),
}

impl fmt::Display for PlacementError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlacementError::WrongArity { expected, got } => {
                write!(f, "placement has wrong arity: expected {expected} slots, got {got}")
            }
            PlacementError::ClientOutOfRange { client, client_count } => {
                write!(f, "client id {client} out of range (population {client_count})")
            }
            PlacementError::DuplicateClient { client } => {
                write!(f, "duplicate client {client} in placement")
            }
            PlacementError::UnknownStrategy { name } => {
                write!(
                    f,
                    "unknown strategy {name:?}; valid strategies: {}",
                    registry::NAMES.join(", ")
                )
            }
            PlacementError::DuplicateStrategy { name } => {
                write!(f, "duplicate strategy {name:?}: each strategy may appear only once")
            }
            PlacementError::UnknownEnvironment { name } => {
                write!(
                    f,
                    "unknown environment {name:?}; valid environments: {}",
                    registry::ENV_NAMES.join(", ")
                )
            }
            PlacementError::StateMismatch { expected, got } => {
                write!(f, "optimizer state for {got:?} cannot restore a {expected:?} optimizer")
            }
            PlacementError::Environment(msg) => write!(f, "environment error: {msg}"),
        }
    }
}

impl std::error::Error for PlacementError {}

/// Validate a proposal: correct arity, ids in range, no duplicates.
/// Uses a `u64` bitmask when the population fits in one word (the hot
/// per-round path never allocates for the paper-scale populations).
pub fn validate_placement(
    placement: &[usize],
    dims: usize,
    client_count: usize,
) -> Result<(), PlacementError> {
    if placement.len() != dims {
        return Err(PlacementError::WrongArity { expected: dims, got: placement.len() });
    }
    if client_count <= 64 {
        let mut seen = 0u64;
        for &c in placement {
            if c >= client_count {
                return Err(PlacementError::ClientOutOfRange { client: c, client_count });
            }
            let bit = 1u64 << c;
            if seen & bit != 0 {
                return Err(PlacementError::DuplicateClient { client: c });
            }
            seen |= bit;
        }
    } else {
        let mut seen = vec![false; client_count];
        for &c in placement {
            if c >= client_count {
                return Err(PlacementError::ClientOutOfRange { client: c, client_count });
            }
            if std::mem::replace(&mut seen[c], true) {
                return Err(PlacementError::DuplicateClient { client: c });
            }
        }
    }
    Ok(())
}

/// Panicking wrapper over [`validate_placement`] for tests and
/// assert-style call sites.
pub fn assert_valid_placement(placement: &[usize], dims: usize, client_count: usize) {
    if let Err(e) = validate_placement(placement, dims, client_count) {
        panic!("invalid placement: {e}");
    }
}

/// Draw the shared single-coordinate neighbor move: a uniformly-chosen
/// slot hands its client to a uniformly-drawn client not already in
/// `position` (linear probing past collisions keeps the draw cheap and
/// the RNG stream identical to the historical per-strategy loops).
/// Returns `(slot, new_client)`.
///
/// This is the *one* neighbor shape [`SaPlacement`], [`TabuPlacement`]
/// and [`AdaptivePsoPlacement`]'s pinned probing all propose — and
/// exactly the shape [`AnalyticTpd`] recognizes for its one-swap
/// delta-evaluation fast path, so these strategies' evaluations cost
/// O(changed clusters), not O(population). Public so benches and the
/// allocation guard generate the *same* move shape the strategies use
/// (a drifting copy would silently stop measuring the delta path).
pub fn draw_slot_replacement(
    position: &[usize],
    client_count: usize,
    rng: &mut crate::prng::Pcg32,
) -> (usize, usize) {
    use crate::prng::Rng;
    let dims = position.len();
    debug_assert!(client_count > dims, "no free client to swap in");
    let slot = rng.gen_range(dims as u64) as usize;
    let mut id = rng.gen_range(client_count as u64) as usize;
    while position.contains(&id) {
        id = (id + 1) % client_count;
    }
    (slot, id)
}

/// Snapshot of an optimizer's transferable state (checkpointing hook).
#[derive(Debug, Clone, PartialEq)]
pub struct OptimizerState {
    /// Canonical strategy name the snapshot came from.
    pub name: String,
    /// Best placement observed so far and its delay.
    pub best: Option<(Placement, f64)>,
}

/// Shared guard for [`Optimizer::restore`] implementations: a snapshot
/// may only restore the strategy that produced it.
pub fn check_state_name(expected: &str, state: &OptimizerState) -> Result<(), PlacementError> {
    if state.name != expected {
        return Err(PlacementError::StateMismatch {
            expected: expected.to_string(),
            got: state.name.clone(),
        });
    }
    Ok(())
}

/// A black-box placement optimizer: proposes batches of candidate
/// placements and learns only from observed round delays (never from
/// client internals — the paper's privacy constraint).
///
/// Batching is the primitive: single-candidate strategies return
/// one-element batches, while population strategies (the synchronous PSO
/// swarm, the GA's generation cohort) hand the whole population to the
/// environment in one call. The driver may truncate a batch at the
/// evaluation budget, so `observe_batch` must accept a *prefix* of the
/// proposed batch.
pub trait Optimizer: Send {
    /// Canonical strategy label (a [`registry`] key) used in CSV output
    /// and plots.
    fn name(&self) -> &'static str;

    /// Candidate placements to evaluate next. `round` counts
    /// propose/observe cycles (FL rounds in live mode).
    fn propose_batch(&mut self, round: usize) -> Vec<Placement>;

    /// Delays for (a prefix of) the latest proposed batch, in order.
    fn observe_batch(&mut self, placements: &[Placement], delays: &[f64]);

    /// Best placement observed so far with its delay, if any.
    fn best(&self) -> Option<(Placement, f64)> {
        None
    }

    /// Whether the optimizer considers the search converged.
    fn converged(&self) -> bool {
        false
    }

    /// How many evaluations form one logical iteration for trace
    /// grouping (e.g. the PSO swarm size). Defaults to 1.
    fn group_size(&self) -> usize {
        1
    }

    /// Snapshot transferable state for checkpointing.
    fn state(&self) -> OptimizerState {
        OptimizerState { name: self.name().to_string(), best: self.best() }
    }

    /// Restore from a snapshot produced by [`Optimizer::state`] on the
    /// same strategy. The default implementation only validates the
    /// strategy name (via [`check_state_name`]); stateful optimizers
    /// additionally re-seed their incumbent from `state.best`.
    fn restore(&mut self, state: &OptimizerState) -> Result<(), PlacementError> {
        check_state_name(self.name(), state)
    }
}

/// Adapter exposing the classic one-placement-per-round protocol
/// (`propose` → run round → `feedback`) over any batched [`Optimizer`].
///
/// Queues batch proposals and forwards delays back to the optimizer once
/// the whole batch is scored. If a caller abandons a batch (proposes
/// without feeding back), the partially-scored prefix is still observed
/// before the next batch is requested.
pub struct Stepwise {
    opt: Box<dyn Optimizer>,
    batch: Vec<Placement>,
    /// Index of the next batch element to hand out.
    next: usize,
    delays: Vec<f64>,
}

impl Stepwise {
    pub fn new(opt: Box<dyn Optimizer>) -> Stepwise {
        Stepwise { opt, batch: Vec::new(), next: 0, delays: Vec::new() }
    }

    pub fn name(&self) -> &'static str {
        self.opt.name()
    }

    /// The next placement to evaluate.
    pub fn propose(&mut self, round: usize) -> Placement {
        if self.next >= self.batch.len() {
            self.flush();
            self.batch = self.opt.propose_batch(round);
            assert!(
                !self.batch.is_empty(),
                "optimizer {} proposed an empty batch",
                self.opt.name()
            );
        }
        let p = self.batch[self.next].clone();
        self.next += 1;
        p
    }

    /// Report the delay of the most recently proposed placement.
    pub fn feedback(&mut self, delay: f64) {
        self.delays.push(delay);
        if self.next >= self.batch.len() && self.delays.len() == self.batch.len() {
            self.flush();
        }
    }

    /// Observe whatever prefix of the current batch has delays.
    fn flush(&mut self) {
        let k = self.delays.len().min(self.batch.len());
        if k > 0 {
            self.opt.observe_batch(&self.batch[..k], &self.delays[..k]);
        }
        self.batch.clear();
        self.delays.clear();
        self.next = 0;
    }

    pub fn optimizer(&self) -> &dyn Optimizer {
        &*self.opt
    }

    pub fn optimizer_mut(&mut self) -> &mut dyn Optimizer {
        &mut *self.opt
    }

    /// Flush any scored prefix and hand the optimizer back.
    pub fn into_inner(mut self) -> Box<dyn Optimizer> {
        self.flush();
        self.opt
    }
}

/// Outcome of [`drive`]: per-iteration statistics (grouped by the
/// optimizer's [`Optimizer::group_size`]) plus the best observation.
#[derive(Debug, Clone)]
pub struct DriveOutcome {
    pub stats: Vec<IterationStats>,
    pub best_placement: Option<Placement>,
    pub best_delay: f64,
    pub evaluations: usize,
}

/// The generic optimization loop: repeatedly ask `opt` for a batch,
/// score it in `env` (one [`Environment::eval_batch`] dispatch per
/// batch), and feed the delays back — until `max_evals` evaluations have
/// been spent. Batches are truncated at the budget boundary, so the loop
/// performs *exactly* `max_evals` evaluations.
pub fn drive(
    opt: &mut dyn Optimizer,
    env: &mut dyn Environment,
    max_evals: usize,
) -> Result<DriveOutcome, PlacementError> {
    let group = opt.group_size().max(1);
    let mut out = DriveOutcome {
        stats: Vec::new(),
        best_placement: None,
        best_delay: f64::INFINITY,
        evaluations: 0,
    };
    let mut buf: Vec<f64> = Vec::with_capacity(group);
    let mut round = 0usize;
    while out.evaluations < max_evals {
        let mut batch = opt.propose_batch(round);
        if batch.is_empty() {
            return Err(PlacementError::Environment(format!(
                "optimizer {} proposed an empty batch",
                opt.name()
            )));
        }
        batch.truncate(max_evals - out.evaluations);
        let delays = env.eval_batch(&batch)?;
        debug_assert_eq!(delays.len(), batch.len());
        opt.observe_batch(&batch, &delays);
        for (p, &d) in batch.iter().zip(&delays) {
            out.evaluations += 1;
            if d < out.best_delay {
                out.best_delay = d;
                out.best_placement = Some(p.clone());
            }
            buf.push(d);
            if buf.len() == group {
                out.stats.push(stats_row(std::mem::take(&mut buf), out.best_delay));
            }
        }
        round += 1;
    }
    // A trailing partial group (budget not divisible by group_size) is
    // still counted in best/evaluations but emits no trace row.
    crate::obs::defs::DRIVE_BATCHES.add(round as u64);
    crate::obs::defs::DRIVE_RUNS.inc();
    Ok(out)
}

fn stats_row(per_particle_tpd: Vec<f64>, gbest_tpd: f64) -> IterationStats {
    let worst = per_particle_tpd.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let best = per_particle_tpd.iter().cloned().fold(f64::INFINITY, f64::min);
    let mean = per_particle_tpd.iter().sum::<f64>() / per_particle_tpd.len() as f64;
    IterationStats { per_particle_tpd, worst, mean, best, gbest_tpd }
}

#[cfg(test)]
pub(crate) mod testkit {
    use super::Optimizer;

    /// Drive an optimizer against a toy delay function for exactly
    /// `rounds` evaluations, validating every proposal; returns the
    /// per-evaluation delays in order.
    pub fn run_toy_validated(
        opt: &mut dyn Optimizer,
        dims: usize,
        client_count: usize,
        rounds: usize,
        mut delay_of: impl FnMut(&[usize]) -> f64,
    ) -> Vec<f64> {
        let mut delays = Vec::with_capacity(rounds);
        let mut round = 0usize;
        while delays.len() < rounds {
            let mut batch = opt.propose_batch(round);
            batch.truncate(rounds - delays.len());
            let ds: Vec<f64> = batch
                .iter()
                .map(|p| {
                    super::assert_valid_placement(p.as_slice(), dims, client_count);
                    delay_of(p.as_slice())
                })
                .collect();
            delays.extend(&ds);
            opt.observe_batch(&batch, &ds);
            round += 1;
        }
        delays
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg32;
    use crate::pso::PsoConfig;

    /// Every registered strategy must emit valid placements for many
    /// rounds (conformance — includes tabu and adaptive-pso).
    #[test]
    fn all_strategies_emit_valid_placements() {
        let dims = 3;
        let cc = 10;
        for name in registry::NAMES {
            let mut opt = registry::build_live(name, dims, cc, PsoConfig::paper(), 7)
                .unwrap_or_else(|e| panic!("build {name}: {e}"));
            testkit::run_toy_validated(opt.as_mut(), dims, cc, 100, |p| {
                p.iter().sum::<usize>() as f64 + 0.5
            });
        }
    }

    /// Black-box optimizers should, on average, beat random on the toy
    /// landscape after enough rounds (now also covers tabu and
    /// adaptive-pso).
    #[test]
    fn optimizers_beat_random_on_toy_landscape() {
        let dims = 4;
        let cc = 20;
        let run = |name: &str, seed: u64| -> f64 {
            let mut opt = registry::build_live(name, dims, cc, PsoConfig::paper(), seed).unwrap();
            let delays = testkit::run_toy_validated(opt.as_mut(), dims, cc, 120, |p| {
                p.iter().sum::<usize>() as f64 + 1.0
            });
            delays[60..].iter().sum::<f64>() / 60.0
        };
        let rand_avg = run("random", 10);
        for (name, seed) in
            [("pso", 11), ("ga", 12), ("sa", 13), ("tabu", 14), ("adaptive-pso", 15)]
        {
            let avg = run(name, seed);
            assert!(avg < rand_avg, "{name} {avg} !< random {rand_avg}");
        }
    }

    #[test]
    fn validator_reports_typed_errors() {
        assert_eq!(
            validate_placement(&[1, 1, 2], 3, 5),
            Err(PlacementError::DuplicateClient { client: 1 })
        );
        assert_eq!(
            validate_placement(&[0, 9], 2, 5),
            Err(PlacementError::ClientOutOfRange { client: 9, client_count: 5 })
        );
        assert_eq!(
            validate_placement(&[0, 1], 3, 5),
            Err(PlacementError::WrongArity { expected: 3, got: 2 })
        );
        assert_eq!(validate_placement(&[4, 0, 2], 3, 5), Ok(()));
    }

    #[test]
    fn validator_large_population_fallback_agrees() {
        // client_count > 64 exercises the Vec<bool> path.
        let p: Vec<usize> = (0..40).map(|i| i * 3).collect();
        assert_eq!(validate_placement(&p, 40, 200), Ok(()));
        let mut dup = p.clone();
        dup[39] = dup[0];
        assert_eq!(
            validate_placement(&dup, 40, 200),
            Err(PlacementError::DuplicateClient { client: dup[0] })
        );
        assert_eq!(
            validate_placement(&[199, 200], 2, 200),
            Err(PlacementError::ClientOutOfRange { client: 200, client_count: 200 })
        );
    }

    #[test]
    #[should_panic(expected = "duplicate client")]
    fn assert_wrapper_catches_duplicates() {
        assert_valid_placement(&[1, 1, 2], 3, 5);
    }

    #[test]
    fn stepwise_matches_direct_batch_order_for_ga() {
        // The Stepwise adapter must feed a batched optimizer the same
        // (placement, delay) sequence the raw batch protocol produces.
        let delay_of = |p: &[usize]| p.iter().map(|&c| (c * c) as f64).sum::<f64>() + 1.0;

        let mut direct = GaPlacement::new(3, 12, GaConfig::default(), Pcg32::seed_from_u64(5));
        let direct_delays = testkit::run_toy_validated(&mut direct, 3, 12, 60, delay_of);

        let mut step = Stepwise::new(Box::new(GaPlacement::new(
            3,
            12,
            GaConfig::default(),
            Pcg32::seed_from_u64(5),
        )));
        let mut step_delays = Vec::new();
        for round in 0..60 {
            let p = step.propose(round);
            assert_valid_placement(p.as_slice(), 3, 12);
            let d = delay_of(p.as_slice());
            step.feedback(d);
            step_delays.push(d);
        }
        assert_eq!(direct_delays, step_delays);
    }

    #[test]
    fn state_restore_roundtrips_best() {
        let mut sa = SaPlacement::new(3, 15, SaConfig::default(), Pcg32::seed_from_u64(3));
        testkit::run_toy_validated(&mut sa, 3, 15, 50, |p| p.iter().sum::<usize>() as f64 + 1.0);
        let snapshot = sa.state();
        assert_eq!(snapshot.name, "sa");
        let (best_p, best_d) = snapshot.best.clone().expect("sa tracks a best");

        let mut fresh = SaPlacement::new(3, 15, SaConfig::default(), Pcg32::seed_from_u64(99));
        fresh.restore(&snapshot).expect("same-strategy restore");
        let (p2, d2) = fresh.best().expect("restored best");
        assert_eq!(p2, best_p);
        assert!((d2 - best_d).abs() < 1e-12);
    }

    #[test]
    fn restore_rejects_wrong_strategy() {
        let sa = SaPlacement::new(3, 15, SaConfig::default(), Pcg32::seed_from_u64(3));
        let snapshot = sa.state();
        let mut rr = RoundRobinPlacement::new(3, 15);
        let err = rr.restore(&snapshot).unwrap_err();
        assert!(matches!(err, PlacementError::StateMismatch { .. }), "{err}");
    }

    #[test]
    fn drive_respects_budget_and_groups() {
        use crate::fitness::ClientAttrs;
        use crate::hierarchy::HierarchySpec;
        let spec = HierarchySpec::new(2, 2);
        let mut rng = Pcg32::seed_from_u64(8);
        let attrs = ClientAttrs::sample_population(8, (5.0, 15.0), (10.0, 50.0), 5.0, &mut rng);
        let mut env = AnalyticTpd::new(spec, attrs);
        let mut opt = registry::build_live("ga", 3, 8, PsoConfig::paper(), 2).unwrap();
        let out = drive(opt.as_mut(), &mut env, 25).unwrap();
        assert_eq!(out.evaluations, 25);
        // group_size 1 → one trace row per evaluation.
        assert_eq!(out.stats.len(), 25);
        assert!(out.best_delay.is_finite());
        let best = out.best_placement.expect("saw evaluations");
        assert_valid_placement(best.as_slice(), 3, 8);
        // gbest series is monotone non-increasing.
        for w in out.stats.windows(2) {
            assert!(w[1].gbest_tpd <= w[0].gbest_tpd + 1e-12);
        }
    }
}
