//! Tabu-search placement baseline.
//!
//! The paper's related work (§V, [9]) uses a Tabu Search-based Placement
//! (TSP) for edge-server placement in SDFL; this provides the analogous
//! black-box comparator under our one-evaluation-per-round protocol:
//! steepest-descent neighbour moves with a recency-based tabu list and
//! aspiration (a tabu move is allowed if it beats the global best).

use super::{Optimizer, OptimizerState, Placement, PlacementError};
use crate::prng::{Pcg32, Rng};
use std::collections::VecDeque;

/// Tabu-search hyper-parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TabuConfig {
    /// Tabu tenure: how many rounds a reversed move stays forbidden.
    pub tenure: usize,
    /// Candidate neighbours generated per accepted move. Because the
    /// black-box protocol yields ONE evaluation per round, candidates
    /// are evaluated one-per-round and the best non-tabu candidate of
    /// each batch is accepted.
    pub candidates: usize,
}

impl Default for TabuConfig {
    fn default() -> Self {
        TabuConfig {
            tenure: 12,
            candidates: 6,
        }
    }
}

/// A move: slot index + the client id placed there.
type Move = (usize, usize);

/// Steepest-descent tabu search over placements.
pub struct TabuPlacement {
    cfg: TabuConfig,
    dims: usize,
    client_count: usize,
    current: Vec<usize>,
    /// Candidate batch currently being evaluated, with their delays.
    batch: Vec<(Vec<usize>, Move, f64)>,
    /// Index of the candidate awaiting evaluation.
    cursor: usize,
    tabu: VecDeque<Move>,
    best: Vec<usize>,
    best_delay: f64,
    rng: Pcg32,
}

impl TabuPlacement {
    pub fn new(dims: usize, client_count: usize, cfg: TabuConfig, mut rng: Pcg32) -> Self {
        assert!(client_count >= dims);
        let current = rng.sample_distinct(client_count, dims);
        TabuPlacement {
            cfg,
            dims,
            client_count,
            best: current.clone(),
            current,
            batch: Vec::new(),
            cursor: 0,
            tabu: VecDeque::new(),
            best_delay: f64::INFINITY,
            rng,
        }
    }

    /// Best (lowest) delay observed so far (`Optimizer::best` returns the
    /// matching placement).
    pub fn best_delay(&self) -> f64 {
        self.best_delay
    }

    fn is_tabu(&self, mv: &Move) -> bool {
        self.tabu.contains(mv)
    }

    fn push_tabu(&mut self, mv: Move) {
        self.tabu.push_back(mv);
        while self.tabu.len() > self.cfg.tenure {
            self.tabu.pop_front();
        }
    }

    /// Generate the next batch of neighbour candidates.
    fn refill_batch(&mut self) {
        self.batch.clear();
        self.cursor = 0;
        let mut guard = 0;
        while self.batch.len() < self.cfg.candidates && guard < self.cfg.candidates * 10 {
            guard += 1;
            // Single-coordinate neighbor: the shape the analytic
            // oracle's delta fast path rescores in O(changed clusters).
            let (slot, id) =
                super::draw_slot_replacement(&self.current, self.client_count, &mut self.rng);
            let mv: Move = (slot, id);
            if self.is_tabu(&mv) {
                continue;
            }
            let mut cand = self.current.clone();
            cand[slot] = id;
            self.batch.push((cand, mv, f64::INFINITY));
        }
        if self.batch.is_empty() {
            // Everything tabu (tiny spaces): fall back to a random restart.
            let cand = self.rng.sample_distinct(self.client_count, self.dims);
            self.batch.push((cand, (0, 0), f64::INFINITY));
        }
    }

    /// Accept the best candidate of the evaluated batch.
    fn accept_best(&mut self) {
        let (idx, _) = self
            .batch
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| a.2.partial_cmp(&b.2).unwrap())
            .map(|(i, c)| (i, c.2))
            .unwrap();
        let (cand, mv, delay) = self.batch[idx].clone();
        // Reverse move (slot back to its old occupant) becomes tabu.
        let reverse: Move = (mv.0, self.current[mv.0]);
        self.push_tabu(reverse);
        self.current = cand;
        if delay < self.best_delay {
            self.best_delay = delay;
            self.best = self.current.clone();
        }
        self.refill_batch();
    }
}

impl Optimizer for TabuPlacement {
    fn name(&self) -> &'static str {
        "tabu"
    }

    /// One candidate at a time: the aspiration rule (accept a move the
    /// moment it beats the global best, skipping the rest of the
    /// candidate batch) only works when evaluations stay sequential —
    /// batching the whole candidate list would spend live FL rounds on
    /// candidates aspiration would have skipped.
    fn propose_batch(&mut self, _round: usize) -> Vec<Placement> {
        if self.batch.is_empty() {
            // First call evaluates the initial state, then batches begin.
            return vec![Placement::new(self.current.clone())];
        }
        vec![Placement::new(self.batch[self.cursor].0.clone())]
    }

    fn observe_batch(&mut self, placements: &[Placement], delays: &[f64]) {
        for (p, &delay_secs) in placements.iter().zip(delays) {
            if self.batch.is_empty() {
                // Initial state evaluated.
                debug_assert_eq!(p.as_slice(), self.current.as_slice());
                self.best_delay = delay_secs;
                self.best = self.current.clone();
                self.refill_batch();
                continue;
            }
            debug_assert_eq!(p.as_slice(), self.batch[self.cursor].0.as_slice());
            self.batch[self.cursor].2 = delay_secs;
            // Aspiration: accept immediately if it beats the global best.
            if delay_secs < self.best_delay {
                self.accept_best();
                continue;
            }
            self.cursor += 1;
            if self.cursor >= self.batch.len() {
                self.accept_best();
            }
        }
    }

    fn best(&self) -> Option<(Placement, f64)> {
        if self.best_delay.is_finite() {
            Some((Placement::new(self.best.clone()), self.best_delay))
        } else {
            None
        }
    }

    fn restore(&mut self, state: &OptimizerState) -> Result<(), PlacementError> {
        super::check_state_name(self.name(), state)?;
        if let Some((placement, delay)) = &state.best {
            super::validate_placement(placement, self.dims, self.client_count)?;
            // Resume the search from the checkpointed incumbent with a
            // fresh candidate batch around it.
            self.best = placement.to_vec();
            self.best_delay = *delay;
            self.current = placement.to_vec();
            self.refill_batch();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::testkit;

    fn toy(pos: &[usize]) -> f64 {
        pos.chunks(2)
            .map(|l| *l.iter().max().unwrap() as f64)
            .sum::<f64>()
            + 1.0
    }

    #[test]
    fn improves_on_toy_landscape() {
        let mut t = TabuPlacement::new(4, 25, TabuConfig::default(), Pcg32::seed_from_u64(1));
        let delays = testkit::run_toy_validated(&mut t, 4, 25, 300, toy);
        let early: f64 = delays[..30].iter().sum();
        let late: f64 = delays[270..].iter().sum();
        assert!(late < early, "tabu failed to improve: early {early}, late {late}");
        assert!(t.best_delay() < early / 30.0);
    }

    #[test]
    fn proposals_always_valid() {
        let mut t = TabuPlacement::new(3, 8, TabuConfig::default(), Pcg32::seed_from_u64(2));
        let mut round = 0usize;
        testkit::run_toy_validated(&mut t, 3, 8, 200, |_| {
            round += 1;
            (round % 9) as f64 + 0.5
        });
    }

    #[test]
    fn tabu_list_bounded_by_tenure() {
        let cfg = TabuConfig {
            tenure: 4,
            candidates: 3,
        };
        let mut t = TabuPlacement::new(3, 10, cfg, Pcg32::seed_from_u64(3));
        testkit::run_toy_validated(&mut t, 3, 10, 100, toy);
        assert!(t.tabu.len() <= 4);
    }

    #[test]
    fn best_tracks_minimum_observed() {
        let mut t = TabuPlacement::new(2, 12, TabuConfig::default(), Pcg32::seed_from_u64(4));
        let delays = testkit::run_toy_validated(&mut t, 2, 12, 120, toy);
        let min = delays.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!((t.best_delay() - min).abs() < 1e-9);
    }
}
