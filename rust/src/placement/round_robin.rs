//! Uniform round-robin placement — SDFLMQ's built-in "uniform" baseline
//! (paper §IV.C): aggregator duty rotates through the population so
//! every client serves equally often. Registry name `round-robin`
//! (`uniform` accepted as an alias).

use super::{Optimizer, Placement};

/// Rotating window of `dims` consecutive client ids.
pub struct RoundRobinPlacement {
    dims: usize,
    client_count: usize,
}

impl RoundRobinPlacement {
    pub fn new(dims: usize, client_count: usize) -> Self {
        assert!(client_count >= dims);
        RoundRobinPlacement { dims, client_count }
    }
}

impl Optimizer for RoundRobinPlacement {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn propose_batch(&mut self, round: usize) -> Vec<Placement> {
        // Window advances by `dims` each round so the duty cycle is
        // uniform: with cc=10, dims=3 → {0,1,2}, {3,4,5}, {6,7,8},
        // {9,0,1}, ... Consecutive ids are always distinct (dims ≤ cc).
        let start = (round * self.dims) % self.client_count;
        vec![Placement::new(
            (0..self.dims)
                .map(|i| (start + i) % self.client_count)
                .collect(),
        )]
    }

    fn observe_batch(&mut self, _placements: &[Placement], _delays: &[f64]) {
        // Deterministic baseline: learns nothing.
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn draw(s: &mut RoundRobinPlacement, round: usize) -> Vec<usize> {
        s.propose_batch(round).pop().unwrap().into_vec()
    }

    #[test]
    fn rotates_through_population() {
        let mut s = RoundRobinPlacement::new(3, 10);
        assert_eq!(draw(&mut s, 0), vec![0, 1, 2]);
        assert_eq!(draw(&mut s, 1), vec![3, 4, 5]);
        assert_eq!(draw(&mut s, 2), vec![6, 7, 8]);
        assert_eq!(draw(&mut s, 3), vec![9, 0, 1]);
    }

    #[test]
    fn duty_is_uniform_over_full_cycle() {
        let mut s = RoundRobinPlacement::new(2, 8);
        let mut count = vec![0usize; 8];
        for r in 0..8 {
            for &c in draw(&mut s, r).iter() {
                count[c] += 1;
            }
        }
        // 8 rounds × 2 slots = 16 assignments over 8 clients = 2 each.
        assert!(count.iter().all(|&c| c == 2), "{count:?}");
    }

    #[test]
    fn deterministic() {
        let mut a = RoundRobinPlacement::new(4, 11);
        let mut b = RoundRobinPlacement::new(4, 11);
        for r in 0..30 {
            assert_eq!(draw(&mut a, r), draw(&mut b, r));
        }
    }
}
