//! Random placement — SDFLMQ's built-in baseline (paper §IV.C):
//! every round draws a fresh random set of aggregators.

use super::{Optimizer, Placement};
use crate::prng::{Pcg32, Rng};

/// Uniformly random distinct aggregator assignment per round.
pub struct RandomPlacement {
    dims: usize,
    client_count: usize,
    rng: Pcg32,
}

impl RandomPlacement {
    pub fn new(dims: usize, client_count: usize, rng: Pcg32) -> Self {
        assert!(client_count >= dims);
        RandomPlacement {
            dims,
            client_count,
            rng,
        }
    }
}

impl Optimizer for RandomPlacement {
    fn name(&self) -> &'static str {
        "random"
    }

    fn propose_batch(&mut self, _round: usize) -> Vec<Placement> {
        vec![Placement::new(self.rng.sample_distinct(self.client_count, self.dims))]
    }

    fn observe_batch(&mut self, _placements: &[Placement], _delays: &[f64]) {
        // Black-box baseline: learns nothing.
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn draw(s: &mut RandomPlacement, round: usize) -> Placement {
        s.propose_batch(round).pop().unwrap()
    }

    #[test]
    fn proposals_vary_between_rounds() {
        let mut s = RandomPlacement::new(3, 30, Pcg32::seed_from_u64(1));
        let a = draw(&mut s, 0);
        let b = draw(&mut s, 1);
        let c = draw(&mut s, 2);
        assert!(a != b || b != c, "three identical random draws");
    }

    #[test]
    fn covers_population_over_many_rounds() {
        let mut s = RandomPlacement::new(2, 10, Pcg32::seed_from_u64(2));
        let mut seen = vec![false; 10];
        for r in 0..200 {
            for &c in draw(&mut s, r).iter() {
                seen[c] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "some client never sampled: {seen:?}");
    }
}
