//! Steady-state PSO for live FL systems (Flag-Swap, DESIGN.md §5).
//!
//! In the real deployment a fitness evaluation *is* one FL round: the
//! coordinator applies a candidate placement, runs the round, and
//! reports the measured wall-clock delay back. This driver therefore
//! exposes a propose/report interface — one particle per round, cycling
//! through the swarm — instead of the synchronous `step` loop.

use super::particle::derive_placement;
use super::{Particle, PsoConfig};
use crate::prng::Pcg32;

/// Steady-state swarm: `propose()` → run round → `report(delay)`.
pub struct AsyncSwarm {
    pub cfg: PsoConfig,
    particles: Vec<Particle>,
    /// Continuous global-best position.
    gbest: Vec<f64>,
    gbest_fitness: f64,
    client_count: usize,
    rng: Pcg32,
    /// Index of the particle whose position is currently "in flight".
    cursor: usize,
    /// Evaluations completed (rounds observed).
    evaluations: usize,
    /// Sweeps (full passes over the swarm) without a gbest improvement.
    stale_sweeps: usize,
    improved_this_sweep: bool,
    /// When false, the swarm never pins: it keeps exploring forever
    /// (pure steady-state PSO, used by the optimizer ablation). The
    /// deployed Flag-Swap default is true — exploit gbest once converged.
    pin_enabled: bool,
}

impl AsyncSwarm {
    /// Initialize like the synchronous swarm (random distinct positions,
    /// zero velocity).
    pub fn new(dims: usize, client_count: usize, cfg: PsoConfig, mut rng: Pcg32) -> AsyncSwarm {
        assert!(dims >= 1 && client_count >= dims);
        let particles: Vec<Particle> = (0..cfg.particles)
            .map(|_| Particle::init(dims, client_count, &mut rng))
            .collect();
        let gbest = particles[0].position.clone();
        AsyncSwarm {
            cfg,
            particles,
            gbest,
            gbest_fitness: f64::NEG_INFINITY,
            client_count,
            rng,
            cursor: 0,
            evaluations: 0,
            stale_sweeps: 0,
            improved_this_sweep: false,
            pin_enabled: true,
        }
    }

    /// Disable gbest pinning (pure exploration — ablation A2).
    pub fn set_pinning(&mut self, enabled: bool) {
        self.pin_enabled = enabled;
    }

    /// The placement to run the next FL round with. After convergence
    /// this pins the global best rather than continuing to explore.
    pub fn propose(&self) -> Vec<usize> {
        if self.pinned() {
            self.gbest()
        } else {
            self.particles[self.cursor].placement(self.client_count)
        }
    }

    /// Report the measured round delay for the placement returned by the
    /// latest `propose()`. Updates pbest/gbest and advances the particle
    /// (velocity + position update against the current bests).
    pub fn report(&mut self, delay: f64) {
        self.evaluations += 1;
        if self.pinned() {
            // Converged: keep running gbest; nothing to move.
            return;
        }
        let fitness = -delay; // Eq. 1: f = −T
        if fitness > self.gbest_fitness {
            self.gbest_fitness = fitness;
            self.gbest = self.particles[self.cursor].position.clone();
            self.improved_this_sweep = true;
        }
        self.particles[self.cursor].observe(fitness);

        // Move this particle toward the bests for its next proposal —
        // but only once every particle has at least one observation
        // (the first sweep evaluates the random initial positions).
        if self.evaluations >= self.particles.len() {
            let gbest = self.gbest.clone();
            let p = &mut self.particles[self.cursor];
            p.update_velocity(&gbest, &self.cfg, &mut self.rng);
            p.update_position(self.client_count);
        }

        self.cursor = (self.cursor + 1) % self.particles.len();
        if self.cursor == 0 {
            if self.improved_this_sweep {
                self.stale_sweeps = 0;
            } else {
                self.stale_sweeps += 1;
            }
            self.improved_this_sweep = false;
        }
    }

    /// Aggregator slots per placement (the search dimensionality).
    pub fn dims(&self) -> usize {
        self.particles[0].position.len()
    }

    /// Seed the global best from a checkpointed placement + delay (the
    /// optimizer restore hook): the swarm resumes warm, pulled toward
    /// the incumbent.
    pub fn seed_gbest(&mut self, placement: &[usize], delay: f64) {
        self.gbest = placement.iter().map(|&c| c as f64).collect();
        self.gbest_fitness = -delay;
    }

    /// Best placement found so far.
    pub fn gbest(&self) -> Vec<usize> {
        derive_placement(&self.gbest, self.client_count)
    }

    /// Best (lowest) delay observed so far.
    pub fn gbest_delay(&self) -> f64 {
        -self.gbest_fitness
    }

    /// Swarm placements identical (paper's convergence condition).
    pub fn positions_converged(&self) -> bool {
        let first = self.particles[0].placement(self.client_count);
        self.particles[1..]
            .iter()
            .all(|p| p.placement(self.client_count) == first)
    }

    /// Converged-and-pinned: identical placements, or two full sweeps
    /// with no improvement after everyone was evaluated. Once true,
    /// `propose` returns gbest forever (the exploit phase of Fig. 4).
    pub fn pinned(&self) -> bool {
        self.pin_enabled
            && ((self.evaluations >= self.particles.len() && self.positions_converged())
                || self.stale_sweeps >= 2)
    }

    /// Number of `report` calls so far.
    pub fn evaluations(&self) -> usize {
        self.evaluations
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy delay model: placement of low ids is fast (TPD-like chunked
    /// max so intermediate placements can improve on the incumbent).
    fn delay_of(pos: &[usize]) -> f64 {
        pos.chunks(2)
            .map(|lvl| lvl.iter().copied().max().unwrap() as f64)
            .sum::<f64>()
            + 1.0
    }

    fn drive(mut swarm: AsyncSwarm, rounds: usize) -> (AsyncSwarm, Vec<f64>) {
        let mut delays = Vec::new();
        for _ in 0..rounds {
            let placement = swarm.propose();
            let d = delay_of(&placement);
            delays.push(d);
            swarm.report(d);
        }
        (swarm, delays)
    }

    fn new_swarm(seed: u64) -> AsyncSwarm {
        AsyncSwarm::new(3, 12, PsoConfig::paper(), Pcg32::seed_from_u64(seed))
    }

    #[test]
    fn improves_with_rounds() {
        let (swarm, delays) = drive(new_swarm(1), 60);
        let early: f64 = delays[..10].iter().sum::<f64>() / 10.0;
        let late: f64 = delays[50..].iter().sum::<f64>() / 10.0;
        assert!(
            late < early,
            "late rounds should be faster: early {early:.1} late {late:.1}"
        );
        let min = delays.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(swarm.gbest_delay() <= min + 1e-9);
    }

    #[test]
    fn gbest_tracks_minimum_observed() {
        let (swarm, delays) = drive(new_swarm(2), 40);
        let min = delays.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!((swarm.gbest_delay() - min).abs() < 1e-9);
    }

    #[test]
    fn pinning_happens_and_sticks_to_gbest() {
        let (swarm, _) = drive(new_swarm(3), 200);
        assert!(swarm.pinned(), "should pin within 200 toy rounds");
        let p = swarm.propose();
        assert_eq!(p, swarm.gbest());
    }

    #[test]
    fn pinned_proposals_are_stable() {
        let (mut swarm, _) = drive(new_swarm(4), 200);
        assert!(swarm.pinned());
        let a = swarm.propose();
        swarm.report(delay_of(&a));
        let b = swarm.propose();
        assert_eq!(a, b);
    }

    #[test]
    fn first_sweep_evaluates_initial_positions_unmoved() {
        let mut swarm = new_swarm(5);
        let initial: Vec<Vec<usize>> = swarm
            .particles
            .iter()
            .map(|p| p.placement(12))
            .collect();
        for want in initial.iter().take(swarm.cfg.particles - 1) {
            let got = swarm.propose();
            assert_eq!(&got, want);
            swarm.report(delay_of(&got));
        }
    }

    #[test]
    fn proposals_always_valid_placements() {
        let mut swarm = new_swarm(6);
        for _ in 0..100 {
            let p = swarm.propose();
            let mut q = p.clone();
            q.sort_unstable();
            q.dedup();
            assert_eq!(q.len(), 3);
            assert!(p.iter().all(|&c| c < 12));
            swarm.report(delay_of(&p));
        }
    }

    #[test]
    fn converges_by_paper_scale() {
        // Fig. 4: convergence within ~10 rounds of 50 on a 10-client,
        // 3-slot problem. Allow some slack (stochastic), but the swarm
        // must pin well before the 50-round budget.
        let mut pinned_at = None;
        let mut swarm = AsyncSwarm::new(3, 10, PsoConfig::paper(), Pcg32::seed_from_u64(7));
        for round in 0..50 {
            let p = swarm.propose();
            swarm.report(delay_of(&p));
            if pinned_at.is_none() && swarm.pinned() {
                pinned_at = Some(round);
            }
        }
        let at = pinned_at.expect("should pin within 50 rounds");
        assert!(at <= 40, "pinned too late: round {at}");
    }
}
