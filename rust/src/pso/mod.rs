//! Discrete Particle Swarm Optimization for aggregation placement —
//! the paper's core contribution (§III, Algorithm 1).
//!
//! Positions are vectors of **distinct client ids**, one per aggregator
//! slot. Velocities are real vectors updated per Eq. 2, clamped to
//! ±Vmax (Eq. 3); positions advance by Eq. 4 (`(x + v) mod client_count`)
//! with increment-until-unique duplicate resolution.
//!
//! Two drivers over the same particle state:
//! * [`Swarm`] — synchronous: all particles evaluated each iteration
//!   (the simulation mode behind Fig. 3).
//! * [`AsyncSwarm`] — steady-state: one particle evaluated per FL round
//!   against measured wall-clock delay (the live mode behind Fig. 4,
//!   see DESIGN.md §5).

mod async_swarm;
mod config;
mod particle;
mod swarm;

pub use async_swarm::AsyncSwarm;
pub use config::PsoConfig;
pub use particle::Particle;
pub use swarm::{IterationStats, RegionSwarm, Swarm};
