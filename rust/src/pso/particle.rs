//! One PSO particle: a candidate aggregator placement plus its velocity
//! and personal best (paper §III.A–C).
//!
//! Positions are **continuous** (Eq. 4 applies `(x + v) mod client_count`
//! to real-valued coordinates); the discrete client assignment is
//! *derived* per evaluation by rounding + duplicate resolution
//! ("Hierarchy Rearrangement" in Algorithm 1). Keeping the state
//! continuous is what lets the swarm truly collapse onto one placement —
//! with integer state, sub-0.5 velocities round to zero and particles
//! freeze short of the global best.

use super::PsoConfig;
use crate::prng::{Pcg32, Rng};

/// A particle in the placement space.
#[derive(Debug, Clone, PartialEq)]
pub struct Particle {
    /// Continuous position, one coordinate per aggregator slot; each
    /// coordinate lives on the ring `[0, client_count)`.
    pub position: Vec<f64>,
    /// Velocity vector (clamped to ±Vmax, Eq. 3).
    pub velocity: Vec<f64>,
    /// Personal best position (continuous, like `position`).
    pub pbest: Vec<f64>,
    /// Fitness of `pbest` (fitness = −TPD; higher is better).
    pub pbest_fitness: f64,
}

impl Particle {
    /// Random initialization (paper §III.C): a random draw of `dims`
    /// distinct client ids, zero velocity, pbest = init.
    pub fn init(dims: usize, client_count: usize, rng: &mut Pcg32) -> Particle {
        let position: Vec<f64> = rng
            .sample_distinct(client_count, dims)
            .into_iter()
            .map(|c| c as f64)
            .collect();
        Particle {
            pbest: position.clone(),
            position,
            velocity: vec![0.0; dims],
            pbest_fitness: f64::NEG_INFINITY,
        }
    }

    /// Velocity update (Eq. 2) + clamp (Eq. 3):
    /// `v ← w·v + c1·r1·(pbest − x) + c2·r2·(gbest − x)`, with fresh
    /// `r1, r2 ~ U[0,1)` per dimension (standard PSO).
    pub fn update_velocity(&mut self, gbest: &[f64], cfg: &PsoConfig, rng: &mut Pcg32) {
        let vmax = cfg.vmax(self.position.len());
        for d in 0..self.velocity.len() {
            let r1 = rng.next_f64();
            let r2 = rng.next_f64();
            let x = self.position[d];
            let v = cfg.inertia * self.velocity[d]
                + cfg.cognitive * r1 * (self.pbest[d] - x)
                + cfg.social * r2 * (gbest[d] - x);
            self.velocity[d] = v.clamp(-vmax, vmax);
        }
    }

    /// Position update (Eq. 4): `x ← (x + v) mod client_count`,
    /// continuous on the ring.
    pub fn update_position(&mut self, client_count: usize) {
        let cc = client_count as f64;
        for d in 0..self.position.len() {
            self.position[d] = (self.position[d] + self.velocity[d]).rem_euclid(cc);
        }
    }

    /// Derive the discrete placement: round each coordinate to a client
    /// id (mod client_count), then resolve duplicates by incrementing
    /// until unique (paper §III.C).
    pub fn placement(&self, client_count: usize) -> Vec<usize> {
        derive_placement(&self.position, client_count)
    }

    /// Record a fitness observation for the current position; returns
    /// true if it improved the personal best.
    pub fn observe(&mut self, fitness: f64) -> bool {
        if fitness > self.pbest_fitness {
            self.pbest_fitness = fitness;
            self.pbest = self.position.clone();
            true
        } else {
            false
        }
    }
}

/// Round a continuous position to distinct client ids.
pub fn derive_placement(position: &[f64], client_count: usize) -> Vec<usize> {
    let cc = client_count as i64;
    let mut taken = vec![false; client_count];
    let mut out = Vec::with_capacity(position.len());
    for &x in position {
        let mut id = (x.round() as i64).rem_euclid(cc) as usize;
        while taken[id] {
            id = (id + 1) % client_count;
        }
        taken[id] = true;
        out.push(id);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Pcg32 {
        Pcg32::seed_from_u64(11)
    }

    fn assert_distinct(p: &[usize], dims: usize, cc: usize) {
        let mut s = p.to_vec();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), dims, "duplicates in {p:?}");
        assert!(p.iter().all(|&c| c < cc));
    }

    #[test]
    fn init_is_distinct_and_in_range() {
        let mut r = rng();
        for _ in 0..20 {
            let p = Particle::init(7, 20, &mut r);
            assert_distinct(&p.placement(20), 7, 20);
            assert!(p.velocity.iter().all(|&v| v == 0.0));
            assert_eq!(p.pbest, p.position);
        }
    }

    #[test]
    fn velocity_is_clamped() {
        let mut r = rng();
        let cfg = PsoConfig {
            social: 100.0, // force huge pulls
            ..PsoConfig::paper()
        };
        let mut p = Particle::init(5, 50, &mut r);
        let gbest = vec![49.0, 48.0, 47.0, 46.0, 45.0];
        p.update_velocity(&gbest, &cfg, &mut r);
        let vmax = cfg.vmax(5);
        assert!(p.velocity.iter().all(|v| v.abs() <= vmax + 1e-12));
    }

    #[test]
    fn placements_stay_valid_under_updates() {
        let mut r = rng();
        let cfg = PsoConfig::paper();
        let mut p = Particle::init(10, 25, &mut r);
        let gbest: Vec<f64> = (0..10).map(|i| i as f64).collect();
        for _ in 0..100 {
            p.update_velocity(&gbest, &cfg, &mut r);
            p.update_position(25);
            assert!(p.position.iter().all(|&x| (0.0..25.0).contains(&x)));
            assert_distinct(&p.placement(25), 10, 25);
        }
    }

    #[test]
    fn position_converges_to_gbest() {
        // With the paper's coefficients the particle must actually reach
        // the global best (the integer-state freeze this refactor fixes).
        let mut r = rng();
        let cfg = PsoConfig::paper();
        let mut p = Particle::init(4, 30, &mut r);
        let gbest = vec![3.0, 14.0, 7.0, 22.0];
        for _ in 0..200 {
            p.update_velocity(&gbest, &cfg, &mut r);
            p.update_position(30);
        }
        assert_eq!(p.placement(30), vec![3, 14, 7, 22]);
    }

    #[test]
    fn modulo_wraps_negative_moves() {
        let mut p = Particle {
            position: vec![0.0, 1.0],
            velocity: vec![-1.4, 0.0],
            pbest: vec![0.0, 1.0],
            pbest_fitness: f64::NEG_INFINITY,
        };
        p.update_position(10);
        // 0 - 1.4 wraps to 8.6 on the ring; rounds to 9.
        assert!((p.position[0] - 8.6).abs() < 1e-9);
        assert_eq!(p.placement(10), vec![9, 1]);
    }

    #[test]
    fn duplicate_resolution_increments() {
        assert_eq!(derive_placement(&[3.2, 2.9], 5), vec![3, 4]);
        assert_eq!(derive_placement(&[0.0, 0.1, 0.2], 5), vec![0, 1, 2]);
        // Wraps: 4 taken, increments to 0.
        assert_eq!(derive_placement(&[4.0, 4.4], 5), vec![4, 0]);
    }

    #[test]
    fn observe_updates_pbest_only_on_improvement() {
        let mut r = rng();
        let mut p = Particle::init(3, 10, &mut r);
        assert!(p.observe(-5.0));
        let best = p.position.clone();
        p.position = vec![9.0, 8.0, 7.0];
        assert!(!p.observe(-6.0)); // worse — pbest unchanged
        assert_eq!(p.pbest, best);
        assert!(p.observe(-4.0)); // better
        assert_eq!(p.pbest, vec![9.0, 8.0, 7.0]);
    }

    #[test]
    fn zero_velocity_zero_coeffs_is_fixed_point() {
        let cfg = PsoConfig {
            inertia: 0.0,
            cognitive: 0.0,
            social: 0.0,
            ..PsoConfig::paper()
        };
        let mut r = rng();
        let mut p = Particle::init(4, 12, &mut r);
        let before = p.position.clone();
        let gbest = before.clone();
        p.update_velocity(&gbest, &cfg, &mut r);
        p.update_position(12);
        assert_eq!(p.position, before);
    }
}
