//! Synchronous PSO driver (Algorithm 1) — the simulation mode where the
//! fitness function is evaluated instantly for every particle each
//! iteration (Fig. 3).

use super::{Particle, PsoConfig};
use crate::prng::Pcg32;

/// Per-iteration statistics (the grey/red/green/orange curves of Fig. 3).
#[derive(Debug, Clone, PartialEq)]
pub struct IterationStats {
    /// TPD per particle this iteration (grey curves).
    pub per_particle_tpd: Vec<f64>,
    /// Worst (red), mean (orange), best (green) TPD this iteration.
    pub worst: f64,
    pub mean: f64,
    pub best: f64,
    /// Best TPD observed so far (monotone, = −gbest fitness).
    pub gbest_tpd: f64,
}

/// Synchronous swarm over a placement search space.
pub struct Swarm {
    pub cfg: PsoConfig,
    pub particles: Vec<Particle>,
    /// Continuous global-best position.
    pub gbest: Vec<f64>,
    pub gbest_fitness: f64,
    client_count: usize,
    rng: Pcg32,
    /// Index of the particle whose evaluation is next (incremental API).
    cursor: usize,
    /// TPDs observed so far in the in-flight sweep (incremental API).
    pending: Vec<f64>,
}

impl Swarm {
    /// Initialize `cfg.particles` particles over `dims` slots and
    /// `client_count` clients (paper §III.C: random positions, zero
    /// velocities, pbest = init; gbest materializes on the first `step`,
    /// which evaluates the initial fitness).
    pub fn new(dims: usize, client_count: usize, cfg: PsoConfig, mut rng: Pcg32) -> Swarm {
        assert!(dims >= 1 && client_count >= dims);
        let particles = (0..cfg.particles)
            .map(|_| Particle::init(dims, client_count, &mut rng))
            .collect::<Vec<_>>();
        let gbest = particles[0].position.clone();
        Swarm {
            cfg,
            particles,
            gbest,
            gbest_fitness: f64::NEG_INFINITY,
            client_count,
            rng,
            cursor: 0,
            pending: Vec::new(),
        }
    }

    /// The discrete placement of the global best.
    pub fn gbest_placement(&self) -> Vec<usize> {
        super::particle::derive_placement(&self.gbest, self.client_count)
    }

    /// Seed the global best from a checkpointed placement + delay (the
    /// optimizer restore hook): the swarm resumes warm, pulled toward
    /// the incumbent.
    pub fn seed_gbest(&mut self, placement: &[usize], delay: f64) {
        self.gbest = placement.iter().map(|&c| c as f64).collect();
        self.gbest_fitness = -delay;
    }

    /// Incremental API, step 1 of 2: move the cursor particle (once a
    /// gbest exists) and return the placement to evaluate next. Matches
    /// Algorithm 1 exactly: each particle is moved against the gbest *as
    /// of its turn*, so later particles in the same sweep already feel
    /// improvements from earlier ones. Must alternate with
    /// [`Swarm::observe_next`].
    pub fn propose_next(&mut self) -> Vec<usize> {
        debug_assert_eq!(
            self.pending.len(),
            self.cursor,
            "propose_next must alternate with observe_next"
        );
        // First sweep: evaluate initial positions before moving
        // (gbest is at -inf fitness until somebody has been scored).
        if self.gbest_fitness > f64::NEG_INFINITY {
            let gbest = self.gbest.clone();
            let p = &mut self.particles[self.cursor];
            p.update_velocity(&gbest, &self.cfg, &mut self.rng);
            p.update_position(self.client_count);
        }
        self.particles[self.cursor].placement(self.client_count)
    }

    /// Incremental API, step 2 of 2: record the TPD of the placement
    /// returned by the latest [`Swarm::propose_next`]. Returns the sweep
    /// statistics when this observation completes a full pass over the
    /// swarm.
    pub fn observe_next(&mut self, t: f64) -> Option<IterationStats> {
        let i = self.cursor;
        self.pending.push(t);
        let fitness = -t;
        self.particles[i].observe(fitness);
        if fitness > self.gbest_fitness {
            self.gbest_fitness = fitness;
            self.gbest = self.particles[i].position.clone();
        }
        self.cursor += 1;
        if self.cursor == self.particles.len() {
            self.cursor = 0;
            let per_particle = std::mem::take(&mut self.pending);
            Some(self.stats_for(per_particle))
        } else {
            None
        }
    }

    /// Batched API, step 1 of 2: move *all* particles against the current
    /// gbest and return every placement — letting the environment score a
    /// whole iteration in one dispatch. Classic two-phase synchronous
    /// PSO: unlike [`Swarm::step`]/[`Swarm::propose_next`], particles do
    /// not see same-iteration gbest improvements.
    pub fn begin_iteration(&mut self) -> Vec<Vec<usize>> {
        debug_assert!(
            self.cursor == 0 && self.pending.is_empty(),
            "begin_iteration during an in-flight incremental sweep"
        );
        if self.gbest_fitness > f64::NEG_INFINITY {
            let gbest = self.gbest.clone();
            for p in &mut self.particles {
                p.update_velocity(&gbest, &self.cfg, &mut self.rng);
                p.update_position(self.client_count);
            }
        }
        self.particles.iter().map(|p| p.placement(self.client_count)).collect()
    }

    /// Batched API, step 2 of 2: absorb the delays for (a prefix of) the
    /// placements returned by [`Swarm::begin_iteration`].
    pub fn complete_iteration(&mut self, tpds: &[f64]) -> IterationStats {
        debug_assert!(tpds.len() <= self.particles.len());
        for (i, &t) in tpds.iter().enumerate() {
            let fitness = -t;
            self.particles[i].observe(fitness);
            if fitness > self.gbest_fitness {
                self.gbest_fitness = fitness;
                self.gbest = self.particles[i].position.clone();
            }
        }
        self.stats_for(tpds.to_vec())
    }

    fn stats_for(&self, per_particle: Vec<f64>) -> IterationStats {
        let worst = per_particle.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let best = per_particle.iter().cloned().fold(f64::INFINITY, f64::min);
        let mean = per_particle.iter().sum::<f64>() / per_particle.len() as f64;
        IterationStats {
            per_particle_tpd: per_particle,
            worst,
            mean,
            best,
            gbest_tpd: -self.gbest_fitness,
        }
    }

    /// Evaluate all particles with `tpd_of` (lower TPD = better; fitness
    /// is −TPD per the paper's Eq. 1), updating velocities/positions.
    /// Returns this iteration's statistics.
    ///
    /// Implemented over the incremental API, so closure-driven and
    /// batch-driven callers share one Algorithm-1 implementation.
    pub fn step<F: FnMut(&[usize]) -> f64>(&mut self, mut tpd_of: F) -> IterationStats {
        loop {
            let placement = self.propose_next();
            let t = tpd_of(&placement);
            if let Some(stats) = self.observe_next(t) {
                return stats;
            }
        }
    }

    /// Run `cfg.iterations` steps, collecting the per-iteration traces.
    pub fn run<F: FnMut(&[usize]) -> f64>(&mut self, mut tpd_of: F) -> Vec<IterationStats> {
        (0..self.cfg.iterations).map(|_| self.step(&mut tpd_of)).collect()
    }

    /// True when every particle proposes the same placement — the paper's
    /// convergence condition ("all the particles suggest the same
    /// placement which results in the global minimum TPD").
    pub fn converged(&self) -> bool {
        let first = self.particles[0].placement(self.client_count);
        self.particles[1..]
            .iter()
            .all(|p| p.placement(self.client_count) == first)
    }
}

/// Region-masked sub-swarm: the per-region search core of
/// `placement::ShardedPso`. It owns only its region's slot
/// coordinates (`slots`, global slot ids in ascending order) and
/// optimizes them against a frozen rest-of-placement ("the base"),
/// proposing full placements that differ from the base only inside the
/// region.
///
/// The move set is the discrete flag-swap family restricted to the
/// region: with equal odds a particle either *adopts* one coordinate
/// from an attractor (its pbest or the regional incumbent — swapping
/// internally when the adopted client is already held, the classic
/// discrete-PSO swap-toward-gbest operator) or *explores* (an
/// in-region slot swap, or replacing one slot with a free client drawn
/// from the region's residue class — the caller's cross-region
/// conflict-avoidance contract). Every move preserves validity against
/// the frozen base, so every emitted candidate is a valid placement.
///
/// Determinism: the swarm consumes only its own [`Pcg32`] stream (the
/// caller seeds regions in fixed order via SplitMix64) and the
/// observed delays, so its behavior is a pure function of
/// (seed, delay sequence) — independent of thread count.
pub struct RegionSwarm {
    /// Global slot ids owned by this region, ascending.
    slots: Vec<usize>,
    /// Particle positions: the clients at `slots`, one row per particle.
    positions: Vec<Vec<usize>>,
    /// Per-particle best region slice and the global delay it scored.
    pbest: Vec<Vec<usize>>,
    pbest_delay: Vec<f64>,
    /// Regional incumbent (gbest) and its global delay.
    gbest: Vec<usize>,
    gbest_delay: f64,
    rng: Pcg32,
}

impl RegionSwarm {
    /// A sub-swarm of `particles` probes over `slots`. Positions
    /// materialize at the first [`RegionSwarm::rebase`] (the caller's
    /// bootstrap observation supplies the initial base + delay).
    pub fn new(slots: Vec<usize>, particles: usize, seed: u64) -> RegionSwarm {
        assert!(!slots.is_empty() && particles >= 1);
        let len = slots.len();
        RegionSwarm {
            slots,
            positions: vec![vec![0; len]; particles],
            pbest: vec![vec![0; len]; particles],
            pbest_delay: vec![f64::INFINITY; particles],
            gbest: vec![0; len],
            gbest_delay: f64::INFINITY,
            rng: Pcg32::seed_from_u64(seed),
        }
    }

    pub fn slots(&self) -> &[usize] {
        &self.slots
    }

    pub fn particles(&self) -> usize {
        self.positions.len()
    }

    /// The regional incumbent: the best region slice observed since the
    /// last rebase, with the global delay it scored.
    pub fn incumbent(&self) -> (&[usize], f64) {
        (&self.gbest, self.gbest_delay)
    }

    fn base_slice(&self, base: &[usize], out: &mut Vec<usize>) {
        out.clear();
        out.extend(self.slots.iter().map(|&s| base[s]));
    }

    /// Re-anchor on a freshly composed `base` scoring `delay` (the
    /// epoch-barrier exchange, and the initial bootstrap): the incumbent
    /// and every pbest reset to the base's region slice — delays
    /// measured against the old rest-of-placement are not comparable —
    /// and any particle position that went stale (holds a client the
    /// new base now uses *outside* this region) snaps back to the
    /// slice, so every future candidate stays a valid overlay.
    pub fn rebase(&mut self, base: &[usize], delay: f64, in_base: &[bool]) {
        let mut slice = Vec::with_capacity(self.slots.len());
        self.base_slice(base, &mut slice);
        self.gbest.clone_from(&slice);
        self.gbest_delay = delay;
        for (p, d) in self.pbest.iter_mut().zip(&mut self.pbest_delay) {
            p.clone_from(&slice);
            *d = delay;
        }
        for pos in &mut self.positions {
            let stale = pos
                .iter()
                .any(|&c| in_base[c] && !self.slots.iter().any(|&s| base[s] == c));
            if stale || pos.iter().all(|&c| c == 0) {
                pos.clone_from(&slice);
            }
        }
    }

    /// Move every particle once and append one full candidate per
    /// particle to `out`: the frozen `base` with this region's slots
    /// overlaid by the particle's position. `in_base` marks clients the
    /// base currently uses anywhere; replacement draws are confined to
    /// the residue class `class (mod modulus)` so concurrent regions
    /// can never insert the same free client.
    pub fn propose(
        &mut self,
        base: &[usize],
        in_base: &[bool],
        class: usize,
        modulus: usize,
        out: &mut Vec<crate::placement::Placement>,
    ) {
        let client_count = in_base.len();
        for pi in 0..self.positions.len() {
            self.step_particle(pi, in_base, class, modulus, client_count);
            let mut candidate = base.to_vec();
            for (i, &s) in self.slots.iter().enumerate() {
                candidate[s] = self.positions[pi][i];
            }
            out.push(crate::placement::Placement::new(candidate));
        }
    }

    /// One flag-swap move on particle `pi`; preserves validity against
    /// the frozen base by construction.
    fn step_particle(
        &mut self,
        pi: usize,
        in_base: &[bool],
        class: usize,
        modulus: usize,
        client_count: usize,
    ) {
        use crate::prng::Rng;
        let len = self.slots.len();
        // Social phase: adopt one coordinate from an attractor.
        if self.rng.gen_range(2) == 0 {
            let toward_pbest = self.rng.gen_range(2) == 0;
            let att = if toward_pbest { self.pbest[pi].clone() } else { self.gbest.clone() };
            let pos = &mut self.positions[pi];
            let diffs = pos.iter().zip(&att).filter(|(a, b)| a != b).count();
            if diffs > 0 {
                let pick = self.rng.gen_range(diffs as u64) as usize;
                let i = pos
                    .iter()
                    .zip(&att)
                    .enumerate()
                    .filter(|(_, (a, b))| a != b)
                    .nth(pick)
                    .map(|(i, _)| i)
                    .expect("diff index in range");
                let c = att[i];
                match pos.iter().position(|&x| x == c) {
                    Some(j) => pos.swap(i, j),
                    None => pos[i] = c,
                }
                return;
            }
            // Position already equals the attractor: fall through to
            // exploration so the particle keeps moving.
        }
        // Exploration phase: in-region swap or residue-class replace.
        let swap_only = self.rng.gen_range(2) == 0;
        if swap_only && len >= 2 {
            let i = self.rng.gen_range(len as u64) as usize;
            let j = (i + 1 + self.rng.gen_range(len as u64 - 1) as usize) % len;
            self.positions[pi].swap(i, j);
            return;
        }
        // Replace: draw a free client from this region's residue class
        // (not held by the base anywhere, not already in this particle).
        let i = self.rng.gen_range(len as u64) as usize;
        let u = self.rng.gen_range(client_count as u64) as usize;
        let mut c = (u - u % modulus + class).min(client_count - 1);
        if c % modulus != class {
            c = class; // the top partial block lacks this class; wrap
        }
        let pos = &mut self.positions[pi];
        for _ in 0..16 {
            if !in_base[c] && !pos.contains(&c) {
                pos[i] = c;
                return;
            }
            c += modulus;
            if c >= client_count {
                c = class;
            }
        }
        // No free class client within the probe budget: swap instead
        // (1-slot regions with nothing free simply re-propose, which
        // the oracles answer from the Same cache).
        if len >= 2 {
            let j = (i + 1 + self.rng.gen_range(len as u64 - 1) as usize) % len;
            self.positions[pi].swap(i, j);
        }
    }

    /// Absorb the global delays of (a prefix of) the candidates emitted
    /// by the latest [`RegionSwarm::propose`], in particle order.
    /// Returns how many times the regional incumbent improved.
    pub fn observe(&mut self, delays: &[f64]) -> u64 {
        debug_assert!(delays.len() <= self.positions.len());
        let mut improvements = 0;
        for (pi, &d) in delays.iter().enumerate() {
            if d < self.pbest_delay[pi] {
                self.pbest_delay[pi] = d;
                self.pbest[pi].clone_from(&self.positions[pi]);
            }
            if d < self.gbest_delay {
                self.gbest_delay = d;
                self.gbest.clone_from(&self.positions[pi]);
                improvements += 1;
            }
        }
        improvements
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy fitness shaped like the paper's TPD (Eq. 7): chunk the slots
    /// into "levels" of 2 and sum the per-level maxima. Low client ids
    /// are "fast".
    fn toy_tpd(pos: &[usize]) -> f64 {
        pos.chunks(2)
            .map(|lvl| lvl.iter().copied().max().unwrap() as f64)
            .sum()
    }

    fn swarm(dims: usize, cc: usize, particles: usize) -> Swarm {
        let cfg = PsoConfig {
            particles,
            iterations: 100,
            ..PsoConfig::paper()
        };
        Swarm::new(dims, cc, cfg, Pcg32::seed_from_u64(3))
    }

    #[test]
    fn gbest_tpd_is_monotone_nonincreasing() {
        let mut s = swarm(5, 30, 8);
        let stats = s.run(toy_tpd);
        for w in stats.windows(2) {
            assert!(w[1].gbest_tpd <= w[0].gbest_tpd + 1e-12);
        }
    }

    #[test]
    fn improves_over_initial() {
        let mut s = swarm(5, 40, 10);
        let stats = s.run(toy_tpd);
        let first = stats.first().unwrap().best;
        let last = stats.last().unwrap().gbest_tpd;
        assert!(
            last < first,
            "PSO failed to improve: first best {first}, final {last}"
        );
    }

    #[test]
    fn finds_near_optimal_on_toy_problem() {
        // Optimal toy TPD for dims=4 (chunks of 2) is max(0,1)+max(2,3)=4.
        let mut s = swarm(4, 20, 10);
        let stats = s.run(toy_tpd);
        let final_tpd = stats.last().unwrap().gbest_tpd;
        let initial_mean = stats.first().unwrap().mean;
        assert!(
            final_tpd < initial_mean,
            "gbest {final_tpd} should beat the random-init mean {initial_mean}"
        );
        // Random expectation ≈ 2·E[max of two of U{0..19}] ≈ 26; the
        // paper's exploitative coefficients trade optimality for speed.
        assert!(
            final_tpd <= 20.0,
            "expected clearly-better-than-random (≤20), got {final_tpd}"
        );
    }

    #[test]
    fn swarm_converges_to_single_placement() {
        // The paper's convergence criterion: all particles end up
        // proposing the same placement.
        let mut s = swarm(4, 15, 5);
        s.run(toy_tpd);
        assert!(
            s.converged(),
            "swarm should converge within 100 iterations on a small problem"
        );
    }

    #[test]
    fn stats_are_consistent() {
        let mut s = swarm(3, 15, 6);
        let st = s.step(toy_tpd);
        assert_eq!(st.per_particle_tpd.len(), 6);
        assert!(st.best <= st.mean && st.mean <= st.worst);
        assert!(st.gbest_tpd <= st.best + 1e-12);
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed: u64| {
            let cfg = PsoConfig::paper();
            let mut s = Swarm::new(6, 25, cfg, Pcg32::seed_from_u64(seed));
            s.run(toy_tpd).last().unwrap().gbest_tpd
        };
        assert_eq!(run(5), run(5));
    }

    #[test]
    fn placements_stay_valid_throughout() {
        let mut s = swarm(8, 20, 5);
        for _ in 0..50 {
            s.step(toy_tpd);
            for p in &s.particles {
                let mut q = p.placement(20);
                q.sort_unstable();
                q.dedup();
                assert_eq!(q.len(), 8);
            }
        }
    }

    #[test]
    fn exact_fit_population_still_works() {
        // client_count == dims: the only freedom is slot ordering.
        let mut s = swarm(5, 5, 4);
        let stats = s.run(|pos| pos.iter().enumerate().map(|(i, &c)| (i * c) as f64).sum());
        assert!(stats.last().unwrap().gbest_tpd.is_finite());
    }

    #[test]
    fn incremental_api_matches_step_exactly() {
        // propose_next/observe_next is the primitive step() is built on;
        // driving it by hand must yield identical sweeps (same RNG
        // consumption, same placements, same stats).
        let mut a = swarm(4, 20, 6);
        let mut b = swarm(4, 20, 6);
        for _ in 0..30 {
            let sa = a.step(toy_tpd);
            let mut sb = None;
            while sb.is_none() {
                let p = b.propose_next();
                sb = b.observe_next(toy_tpd(&p));
            }
            assert_eq!(Some(sa), sb);
        }
        assert_eq!(a.gbest_placement(), b.gbest_placement());
    }

    #[test]
    fn batched_iterations_improve_and_stay_valid() {
        // Two-phase mode: whole-swarm proposals, one scoring pass per
        // iteration. Semantics differ from Algorithm 1 (no within-sweep
        // gbest visibility) but the search must still descend.
        let mut s = swarm(4, 20, 8);
        let mut first_mean = None;
        let mut last = f64::INFINITY;
        for _ in 0..100 {
            let batch = s.begin_iteration();
            assert_eq!(batch.len(), 8);
            for p in &batch {
                let mut q = p.clone();
                q.sort_unstable();
                q.dedup();
                assert_eq!(q.len(), 4);
            }
            let tpds: Vec<f64> = batch.iter().map(|p| toy_tpd(p)).collect();
            let stats = s.complete_iteration(&tpds);
            first_mean.get_or_insert(stats.mean);
            last = stats.gbest_tpd;
        }
        assert!(
            last < first_mean.unwrap(),
            "batched swarm failed to improve: first mean {:?}, final gbest {last}",
            first_mean
        );
    }

    #[test]
    fn seed_gbest_warm_starts_the_swarm() {
        let mut s = swarm(3, 12, 4);
        s.seed_gbest(&[0, 1, 2], 2.5);
        assert_eq!(s.gbest_placement(), vec![0, 1, 2]);
        assert!((-s.gbest_fitness - 2.5).abs() < 1e-12);
        // A warm gbest means the very first sweep already moves.
        let p = s.propose_next();
        assert_eq!(p.len(), 3);
    }
}
