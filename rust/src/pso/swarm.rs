//! Synchronous PSO driver (Algorithm 1) — the simulation mode where the
//! fitness function is evaluated instantly for every particle each
//! iteration (Fig. 3).

use super::{Particle, PsoConfig};
use crate::prng::Pcg32;

/// Per-iteration statistics (the grey/red/green/orange curves of Fig. 3).
#[derive(Debug, Clone, PartialEq)]
pub struct IterationStats {
    /// TPD per particle this iteration (grey curves).
    pub per_particle_tpd: Vec<f64>,
    /// Worst (red), mean (orange), best (green) TPD this iteration.
    pub worst: f64,
    pub mean: f64,
    pub best: f64,
    /// Best TPD observed so far (monotone, = −gbest fitness).
    pub gbest_tpd: f64,
}

/// Synchronous swarm over a placement search space.
pub struct Swarm {
    pub cfg: PsoConfig,
    pub particles: Vec<Particle>,
    /// Continuous global-best position.
    pub gbest: Vec<f64>,
    pub gbest_fitness: f64,
    client_count: usize,
    rng: Pcg32,
    /// Index of the particle whose evaluation is next (incremental API).
    cursor: usize,
    /// TPDs observed so far in the in-flight sweep (incremental API).
    pending: Vec<f64>,
}

impl Swarm {
    /// Initialize `cfg.particles` particles over `dims` slots and
    /// `client_count` clients (paper §III.C: random positions, zero
    /// velocities, pbest = init; gbest materializes on the first `step`,
    /// which evaluates the initial fitness).
    pub fn new(dims: usize, client_count: usize, cfg: PsoConfig, mut rng: Pcg32) -> Swarm {
        assert!(dims >= 1 && client_count >= dims);
        let particles = (0..cfg.particles)
            .map(|_| Particle::init(dims, client_count, &mut rng))
            .collect::<Vec<_>>();
        let gbest = particles[0].position.clone();
        Swarm {
            cfg,
            particles,
            gbest,
            gbest_fitness: f64::NEG_INFINITY,
            client_count,
            rng,
            cursor: 0,
            pending: Vec::new(),
        }
    }

    /// The discrete placement of the global best.
    pub fn gbest_placement(&self) -> Vec<usize> {
        super::particle::derive_placement(&self.gbest, self.client_count)
    }

    /// Seed the global best from a checkpointed placement + delay (the
    /// optimizer restore hook): the swarm resumes warm, pulled toward
    /// the incumbent.
    pub fn seed_gbest(&mut self, placement: &[usize], delay: f64) {
        self.gbest = placement.iter().map(|&c| c as f64).collect();
        self.gbest_fitness = -delay;
    }

    /// Incremental API, step 1 of 2: move the cursor particle (once a
    /// gbest exists) and return the placement to evaluate next. Matches
    /// Algorithm 1 exactly: each particle is moved against the gbest *as
    /// of its turn*, so later particles in the same sweep already feel
    /// improvements from earlier ones. Must alternate with
    /// [`Swarm::observe_next`].
    pub fn propose_next(&mut self) -> Vec<usize> {
        debug_assert_eq!(
            self.pending.len(),
            self.cursor,
            "propose_next must alternate with observe_next"
        );
        // First sweep: evaluate initial positions before moving
        // (gbest is at -inf fitness until somebody has been scored).
        if self.gbest_fitness > f64::NEG_INFINITY {
            let gbest = self.gbest.clone();
            let p = &mut self.particles[self.cursor];
            p.update_velocity(&gbest, &self.cfg, &mut self.rng);
            p.update_position(self.client_count);
        }
        self.particles[self.cursor].placement(self.client_count)
    }

    /// Incremental API, step 2 of 2: record the TPD of the placement
    /// returned by the latest [`Swarm::propose_next`]. Returns the sweep
    /// statistics when this observation completes a full pass over the
    /// swarm.
    pub fn observe_next(&mut self, t: f64) -> Option<IterationStats> {
        let i = self.cursor;
        self.pending.push(t);
        let fitness = -t;
        self.particles[i].observe(fitness);
        if fitness > self.gbest_fitness {
            self.gbest_fitness = fitness;
            self.gbest = self.particles[i].position.clone();
        }
        self.cursor += 1;
        if self.cursor == self.particles.len() {
            self.cursor = 0;
            let per_particle = std::mem::take(&mut self.pending);
            Some(self.stats_for(per_particle))
        } else {
            None
        }
    }

    /// Batched API, step 1 of 2: move *all* particles against the current
    /// gbest and return every placement — letting the environment score a
    /// whole iteration in one dispatch. Classic two-phase synchronous
    /// PSO: unlike [`Swarm::step`]/[`Swarm::propose_next`], particles do
    /// not see same-iteration gbest improvements.
    pub fn begin_iteration(&mut self) -> Vec<Vec<usize>> {
        debug_assert!(
            self.cursor == 0 && self.pending.is_empty(),
            "begin_iteration during an in-flight incremental sweep"
        );
        if self.gbest_fitness > f64::NEG_INFINITY {
            let gbest = self.gbest.clone();
            for p in &mut self.particles {
                p.update_velocity(&gbest, &self.cfg, &mut self.rng);
                p.update_position(self.client_count);
            }
        }
        self.particles.iter().map(|p| p.placement(self.client_count)).collect()
    }

    /// Batched API, step 2 of 2: absorb the delays for (a prefix of) the
    /// placements returned by [`Swarm::begin_iteration`].
    pub fn complete_iteration(&mut self, tpds: &[f64]) -> IterationStats {
        debug_assert!(tpds.len() <= self.particles.len());
        for (i, &t) in tpds.iter().enumerate() {
            let fitness = -t;
            self.particles[i].observe(fitness);
            if fitness > self.gbest_fitness {
                self.gbest_fitness = fitness;
                self.gbest = self.particles[i].position.clone();
            }
        }
        self.stats_for(tpds.to_vec())
    }

    fn stats_for(&self, per_particle: Vec<f64>) -> IterationStats {
        let worst = per_particle.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let best = per_particle.iter().cloned().fold(f64::INFINITY, f64::min);
        let mean = per_particle.iter().sum::<f64>() / per_particle.len() as f64;
        IterationStats {
            per_particle_tpd: per_particle,
            worst,
            mean,
            best,
            gbest_tpd: -self.gbest_fitness,
        }
    }

    /// Evaluate all particles with `tpd_of` (lower TPD = better; fitness
    /// is −TPD per the paper's Eq. 1), updating velocities/positions.
    /// Returns this iteration's statistics.
    ///
    /// Implemented over the incremental API, so closure-driven and
    /// batch-driven callers share one Algorithm-1 implementation.
    pub fn step<F: FnMut(&[usize]) -> f64>(&mut self, mut tpd_of: F) -> IterationStats {
        loop {
            let placement = self.propose_next();
            let t = tpd_of(&placement);
            if let Some(stats) = self.observe_next(t) {
                return stats;
            }
        }
    }

    /// Run `cfg.iterations` steps, collecting the per-iteration traces.
    pub fn run<F: FnMut(&[usize]) -> f64>(&mut self, mut tpd_of: F) -> Vec<IterationStats> {
        (0..self.cfg.iterations).map(|_| self.step(&mut tpd_of)).collect()
    }

    /// True when every particle proposes the same placement — the paper's
    /// convergence condition ("all the particles suggest the same
    /// placement which results in the global minimum TPD").
    pub fn converged(&self) -> bool {
        let first = self.particles[0].placement(self.client_count);
        self.particles[1..]
            .iter()
            .all(|p| p.placement(self.client_count) == first)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy fitness shaped like the paper's TPD (Eq. 7): chunk the slots
    /// into "levels" of 2 and sum the per-level maxima. Low client ids
    /// are "fast".
    fn toy_tpd(pos: &[usize]) -> f64 {
        pos.chunks(2)
            .map(|lvl| lvl.iter().copied().max().unwrap() as f64)
            .sum()
    }

    fn swarm(dims: usize, cc: usize, particles: usize) -> Swarm {
        let cfg = PsoConfig {
            particles,
            iterations: 100,
            ..PsoConfig::paper()
        };
        Swarm::new(dims, cc, cfg, Pcg32::seed_from_u64(3))
    }

    #[test]
    fn gbest_tpd_is_monotone_nonincreasing() {
        let mut s = swarm(5, 30, 8);
        let stats = s.run(toy_tpd);
        for w in stats.windows(2) {
            assert!(w[1].gbest_tpd <= w[0].gbest_tpd + 1e-12);
        }
    }

    #[test]
    fn improves_over_initial() {
        let mut s = swarm(5, 40, 10);
        let stats = s.run(toy_tpd);
        let first = stats.first().unwrap().best;
        let last = stats.last().unwrap().gbest_tpd;
        assert!(
            last < first,
            "PSO failed to improve: first best {first}, final {last}"
        );
    }

    #[test]
    fn finds_near_optimal_on_toy_problem() {
        // Optimal toy TPD for dims=4 (chunks of 2) is max(0,1)+max(2,3)=4.
        let mut s = swarm(4, 20, 10);
        let stats = s.run(toy_tpd);
        let final_tpd = stats.last().unwrap().gbest_tpd;
        let initial_mean = stats.first().unwrap().mean;
        assert!(
            final_tpd < initial_mean,
            "gbest {final_tpd} should beat the random-init mean {initial_mean}"
        );
        // Random expectation ≈ 2·E[max of two of U{0..19}] ≈ 26; the
        // paper's exploitative coefficients trade optimality for speed.
        assert!(
            final_tpd <= 20.0,
            "expected clearly-better-than-random (≤20), got {final_tpd}"
        );
    }

    #[test]
    fn swarm_converges_to_single_placement() {
        // The paper's convergence criterion: all particles end up
        // proposing the same placement.
        let mut s = swarm(4, 15, 5);
        s.run(toy_tpd);
        assert!(
            s.converged(),
            "swarm should converge within 100 iterations on a small problem"
        );
    }

    #[test]
    fn stats_are_consistent() {
        let mut s = swarm(3, 15, 6);
        let st = s.step(toy_tpd);
        assert_eq!(st.per_particle_tpd.len(), 6);
        assert!(st.best <= st.mean && st.mean <= st.worst);
        assert!(st.gbest_tpd <= st.best + 1e-12);
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed: u64| {
            let cfg = PsoConfig::paper();
            let mut s = Swarm::new(6, 25, cfg, Pcg32::seed_from_u64(seed));
            s.run(toy_tpd).last().unwrap().gbest_tpd
        };
        assert_eq!(run(5), run(5));
    }

    #[test]
    fn placements_stay_valid_throughout() {
        let mut s = swarm(8, 20, 5);
        for _ in 0..50 {
            s.step(toy_tpd);
            for p in &s.particles {
                let mut q = p.placement(20);
                q.sort_unstable();
                q.dedup();
                assert_eq!(q.len(), 8);
            }
        }
    }

    #[test]
    fn exact_fit_population_still_works() {
        // client_count == dims: the only freedom is slot ordering.
        let mut s = swarm(5, 5, 4);
        let stats = s.run(|pos| pos.iter().enumerate().map(|(i, &c)| (i * c) as f64).sum());
        assert!(stats.last().unwrap().gbest_tpd.is_finite());
    }

    #[test]
    fn incremental_api_matches_step_exactly() {
        // propose_next/observe_next is the primitive step() is built on;
        // driving it by hand must yield identical sweeps (same RNG
        // consumption, same placements, same stats).
        let mut a = swarm(4, 20, 6);
        let mut b = swarm(4, 20, 6);
        for _ in 0..30 {
            let sa = a.step(toy_tpd);
            let mut sb = None;
            while sb.is_none() {
                let p = b.propose_next();
                sb = b.observe_next(toy_tpd(&p));
            }
            assert_eq!(Some(sa), sb);
        }
        assert_eq!(a.gbest_placement(), b.gbest_placement());
    }

    #[test]
    fn batched_iterations_improve_and_stay_valid() {
        // Two-phase mode: whole-swarm proposals, one scoring pass per
        // iteration. Semantics differ from Algorithm 1 (no within-sweep
        // gbest visibility) but the search must still descend.
        let mut s = swarm(4, 20, 8);
        let mut first_mean = None;
        let mut last = f64::INFINITY;
        for _ in 0..100 {
            let batch = s.begin_iteration();
            assert_eq!(batch.len(), 8);
            for p in &batch {
                let mut q = p.clone();
                q.sort_unstable();
                q.dedup();
                assert_eq!(q.len(), 4);
            }
            let tpds: Vec<f64> = batch.iter().map(|p| toy_tpd(p)).collect();
            let stats = s.complete_iteration(&tpds);
            first_mean.get_or_insert(stats.mean);
            last = stats.gbest_tpd;
        }
        assert!(
            last < first_mean.unwrap(),
            "batched swarm failed to improve: first mean {:?}, final gbest {last}",
            first_mean
        );
    }

    #[test]
    fn seed_gbest_warm_starts_the_swarm() {
        let mut s = swarm(3, 12, 4);
        s.seed_gbest(&[0, 1, 2], 2.5);
        assert_eq!(s.gbest_placement(), vec![0, 1, 2]);
        assert!((-s.gbest_fitness - 2.5).abs() < 1e-12);
        // A warm gbest means the very first sweep already moves.
        let p = s.propose_next();
        assert_eq!(p.len(), 3);
    }
}
