//! Synchronous PSO driver (Algorithm 1) — the simulation mode where the
//! fitness function is evaluated instantly for every particle each
//! iteration (Fig. 3).

use super::{Particle, PsoConfig};
use crate::prng::Pcg32;

/// Per-iteration statistics (the grey/red/green/orange curves of Fig. 3).
#[derive(Debug, Clone, PartialEq)]
pub struct IterationStats {
    /// TPD per particle this iteration (grey curves).
    pub per_particle_tpd: Vec<f64>,
    /// Worst (red), mean (orange), best (green) TPD this iteration.
    pub worst: f64,
    pub mean: f64,
    pub best: f64,
    /// Best TPD observed so far (monotone, = −gbest fitness).
    pub gbest_tpd: f64,
}

/// Synchronous swarm over a placement search space.
pub struct Swarm {
    pub cfg: PsoConfig,
    pub particles: Vec<Particle>,
    /// Continuous global-best position.
    pub gbest: Vec<f64>,
    pub gbest_fitness: f64,
    client_count: usize,
    rng: Pcg32,
}

impl Swarm {
    /// Initialize `cfg.particles` particles over `dims` slots and
    /// `client_count` clients (paper §III.C: random positions, zero
    /// velocities, pbest = init; gbest materializes on the first `step`,
    /// which evaluates the initial fitness).
    pub fn new(dims: usize, client_count: usize, cfg: PsoConfig, mut rng: Pcg32) -> Swarm {
        assert!(dims >= 1 && client_count >= dims);
        let particles = (0..cfg.particles)
            .map(|_| Particle::init(dims, client_count, &mut rng))
            .collect::<Vec<_>>();
        let gbest = particles[0].position.clone();
        Swarm {
            cfg,
            particles,
            gbest,
            gbest_fitness: f64::NEG_INFINITY,
            client_count,
            rng,
        }
    }

    /// The discrete placement of the global best.
    pub fn gbest_placement(&self) -> Vec<usize> {
        super::particle::derive_placement(&self.gbest, self.client_count)
    }

    /// Evaluate all particles with `tpd_of` (lower TPD = better; fitness
    /// is −TPD per the paper's Eq. 1), then update velocities/positions.
    /// Returns this iteration's statistics.
    ///
    /// Order matches Algorithm 1: each particle is moved, evaluated, and
    /// the bests updated, so later particles in the same iteration
    /// already feel an improved gbest.
    pub fn step<F: FnMut(&[usize]) -> f64>(&mut self, mut tpd_of: F) -> IterationStats {
        let mut per_particle = Vec::with_capacity(self.particles.len());
        for i in 0..self.particles.len() {
            // First sweep: evaluate initial positions before moving
            // (gbest is at -inf fitness until somebody has been scored).
            if self.gbest_fitness > f64::NEG_INFINITY {
                let gbest = self.gbest.clone();
                let p = &mut self.particles[i];
                p.update_velocity(&gbest, &self.cfg, &mut self.rng);
                p.update_position(self.client_count);
            }
            let placement = self.particles[i].placement(self.client_count);
            let t = tpd_of(&placement);
            per_particle.push(t);
            let fitness = -t;
            self.particles[i].observe(fitness);
            if fitness > self.gbest_fitness {
                self.gbest_fitness = fitness;
                self.gbest = self.particles[i].position.clone();
            }
        }
        let worst = per_particle.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let best = per_particle.iter().cloned().fold(f64::INFINITY, f64::min);
        let mean = per_particle.iter().sum::<f64>() / per_particle.len() as f64;
        IterationStats {
            per_particle_tpd: per_particle,
            worst,
            mean,
            best,
            gbest_tpd: -self.gbest_fitness,
        }
    }

    /// Run `cfg.iterations` steps, collecting the per-iteration traces.
    pub fn run<F: FnMut(&[usize]) -> f64>(&mut self, mut tpd_of: F) -> Vec<IterationStats> {
        (0..self.cfg.iterations).map(|_| self.step(&mut tpd_of)).collect()
    }

    /// True when every particle proposes the same placement — the paper's
    /// convergence condition ("all the particles suggest the same
    /// placement which results in the global minimum TPD").
    pub fn converged(&self) -> bool {
        let first = self.particles[0].placement(self.client_count);
        self.particles[1..]
            .iter()
            .all(|p| p.placement(self.client_count) == first)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy fitness shaped like the paper's TPD (Eq. 7): chunk the slots
    /// into "levels" of 2 and sum the per-level maxima. Low client ids
    /// are "fast".
    fn toy_tpd(pos: &[usize]) -> f64 {
        pos.chunks(2)
            .map(|lvl| lvl.iter().copied().max().unwrap() as f64)
            .sum()
    }

    fn swarm(dims: usize, cc: usize, particles: usize) -> Swarm {
        let cfg = PsoConfig {
            particles,
            iterations: 100,
            ..PsoConfig::paper()
        };
        Swarm::new(dims, cc, cfg, Pcg32::seed_from_u64(3))
    }

    #[test]
    fn gbest_tpd_is_monotone_nonincreasing() {
        let mut s = swarm(5, 30, 8);
        let stats = s.run(toy_tpd);
        for w in stats.windows(2) {
            assert!(w[1].gbest_tpd <= w[0].gbest_tpd + 1e-12);
        }
    }

    #[test]
    fn improves_over_initial() {
        let mut s = swarm(5, 40, 10);
        let stats = s.run(toy_tpd);
        let first = stats.first().unwrap().best;
        let last = stats.last().unwrap().gbest_tpd;
        assert!(
            last < first,
            "PSO failed to improve: first best {first}, final {last}"
        );
    }

    #[test]
    fn finds_near_optimal_on_toy_problem() {
        // Optimal toy TPD for dims=4 (chunks of 2) is max(0,1)+max(2,3)=4.
        let mut s = swarm(4, 20, 10);
        let stats = s.run(toy_tpd);
        let final_tpd = stats.last().unwrap().gbest_tpd;
        let initial_mean = stats.first().unwrap().mean;
        assert!(
            final_tpd < initial_mean,
            "gbest {final_tpd} should beat the random-init mean {initial_mean}"
        );
        // Random expectation ≈ 2·E[max of two of U{0..19}] ≈ 26; the
        // paper's exploitative coefficients trade optimality for speed.
        assert!(
            final_tpd <= 20.0,
            "expected clearly-better-than-random (≤20), got {final_tpd}"
        );
    }

    #[test]
    fn swarm_converges_to_single_placement() {
        // The paper's convergence criterion: all particles end up
        // proposing the same placement.
        let mut s = swarm(4, 15, 5);
        s.run(toy_tpd);
        assert!(
            s.converged(),
            "swarm should converge within 100 iterations on a small problem"
        );
    }

    #[test]
    fn stats_are_consistent() {
        let mut s = swarm(3, 15, 6);
        let st = s.step(toy_tpd);
        assert_eq!(st.per_particle_tpd.len(), 6);
        assert!(st.best <= st.mean && st.mean <= st.worst);
        assert!(st.gbest_tpd <= st.best + 1e-12);
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed: u64| {
            let cfg = PsoConfig::paper();
            let mut s = Swarm::new(6, 25, cfg, Pcg32::seed_from_u64(seed));
            s.run(toy_tpd).last().unwrap().gbest_tpd
        };
        assert_eq!(run(5), run(5));
    }

    #[test]
    fn placements_stay_valid_throughout() {
        let mut s = swarm(8, 20, 5);
        for _ in 0..50 {
            s.step(toy_tpd);
            for p in &s.particles {
                let mut q = p.placement(20);
                q.sort_unstable();
                q.dedup();
                assert_eq!(q.len(), 8);
            }
        }
    }

    #[test]
    fn exact_fit_population_still_works() {
        // client_count == dims: the only freedom is slot ordering.
        let mut s = swarm(5, 5, 4);
        let stats = s.run(|pos| pos.iter().enumerate().map(|(i, &c)| (i * c) as f64).sum());
        assert!(stats.last().unwrap().gbest_tpd.is_finite());
    }
}
