//! PSO hyper-parameters (paper §III.C / §IV.B).

/// Hyper-parameters for the placement PSO.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PsoConfig {
    /// Swarm size P (paper simulates P ∈ {5, 10}).
    pub particles: usize,
    /// Iteration budget M (paper: 100 generations).
    pub iterations: usize,
    /// Inertia weight w (paper: 0.01 — strongly exploitative).
    pub inertia: f64,
    /// Cognitive coefficient c1 (paper: 0.01).
    pub cognitive: f64,
    /// Social coefficient c2 (paper: 1 — global best dominates).
    pub social: f64,
    /// Velocity clamp factor: Vmax = max(1, dims · velocity_factor)
    /// (paper Eq. 3, typical value 0.1).
    pub velocity_factor: f64,
}

impl PsoConfig {
    /// The paper's configuration (§IV.B): w=0.01, c1=0.01, c2=1,
    /// velocity_factor=0.1, 10 particles, 100 iterations.
    pub fn paper() -> PsoConfig {
        PsoConfig {
            particles: 10,
            iterations: 100,
            inertia: 0.01,
            cognitive: 0.01,
            social: 1.0,
            velocity_factor: 0.1,
        }
    }

    /// Velocity clamp for a `dims`-dimensional search space (Eq. 3).
    pub fn vmax(&self, dims: usize) -> f64 {
        (dims as f64 * self.velocity_factor).max(1.0)
    }
}

impl Default for PsoConfig {
    fn default() -> Self {
        PsoConfig::paper()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_values() {
        let c = PsoConfig::paper();
        assert_eq!(c.particles, 10);
        assert_eq!(c.iterations, 100);
        assert!((c.inertia - 0.01).abs() < 1e-12);
        assert!((c.cognitive - 0.01).abs() < 1e-12);
        assert!((c.social - 1.0).abs() < 1e-12);
    }

    #[test]
    fn vmax_floor_is_one() {
        let c = PsoConfig::paper();
        assert_eq!(c.vmax(3), 1.0); // 0.3 < 1 ⇒ floor
        assert_eq!(c.vmax(100), 10.0); // 10 > 1
    }
}
