//! [`EvalScratch`] — the zero-allocation view of an [`Arrangement`].
//!
//! The delay oracles score thousands of candidate placements per
//! second; materializing an [`Arrangement`] per candidate
//! (`from_position` allocates the membership table, the trainer buffer
//! and one `Vec` per leaf) dominates the evaluation cost at 10k-client
//! populations. `EvalScratch` holds every buffer an evaluation needs and
//! is reloaded in place per candidate:
//!
//! * a `u64`-word **membership bitset** — doubling as the duplicate/
//!   range validator (`validate_placement`'s bitmask generalized past
//!   64 clients without the `Vec<bool>` fallback allocation). Batch
//!   oracles validate up front into a separate transient bitset and
//!   then rebuild membership branch-free at load time
//!   ([`EvalScratch::load_prevalidated`]) — two cheap word passes,
//!   zero allocations, never a per-candidate `Vec`;
//! * the **flat trainer partition** — the round-robin
//!   trainer-to-leaf assignment streamed in one O(clients) pass into a
//!   single reusable vector, counting-sorted by leaf (segment `i` holds
//!   exactly the clients `Arrangement::from_position` would have pushed
//!   into `trainers[i]`, in the same ascending order — the equivalence
//!   the bit-exactness property tests pin down).
//!
//! The segment boundaries depend only on the population size (the
//! round-robin deal hands leaf `i` `⌈(T−i)/L⌉` trainers), so they are
//! precomputed once at construction.

use super::{Arrangement, HierarchySpec};
use crate::placement::PlacementError;

/// Reusable zero-allocation evaluation state for one (spec,
/// population-size) pair. `load` validates a candidate position and
/// rebuilds the membership bitset and trainer partition in place.
#[derive(Debug, Clone)]
pub struct EvalScratch {
    spec: HierarchySpec,
    client_count: usize,
    dims: usize,
    leaf_start: usize,
    leaf_count: usize,
    /// Membership bitset of the loaded position (one bit per client).
    words: Vec<u64>,
    /// Transient bitset for validating candidates without clobbering
    /// the loaded membership (batch validation runs before scoring).
    val_words: Vec<u64>,
    /// The loaded position (client id per slot, BFT order).
    position: Vec<usize>,
    /// All trainer ids, grouped by leaf: segment `i` is
    /// `trainers[seg[i]..seg[i+1]]`, ascending within each segment.
    trainers: Vec<usize>,
    /// Segment offsets (length `leaf_count + 1`); constant per shape.
    seg: Vec<usize>,
    /// Per-leaf fill cursors during the counting pass.
    cursor: Vec<usize>,
    loaded: bool,
}

impl EvalScratch {
    /// Allocate scratch for `client_count` clients on `spec`'s slots.
    /// This is the only allocating call; every subsequent `load` reuses
    /// these buffers.
    pub fn new(spec: HierarchySpec, client_count: usize) -> EvalScratch {
        let dims = spec.dimensions();
        assert!(client_count >= dims, "population smaller than slot count");
        let leaf_start = spec.level_start(spec.depth - 1);
        let leaf_count = spec.leaf_slots().len();
        let trainer_count = client_count - dims;
        // Round-robin segment sizes: leaf i receives trainers
        // i, i+L, i+2L, … of the ascending buffer.
        let mut seg = Vec::with_capacity(leaf_count + 1);
        let mut acc = 0usize;
        seg.push(0);
        for i in 0..leaf_count {
            acc += trainer_count / leaf_count + usize::from(i < trainer_count % leaf_count);
            seg.push(acc);
        }
        let word_count = client_count.div_ceil(64);
        EvalScratch {
            spec,
            client_count,
            dims,
            leaf_start,
            leaf_count,
            words: vec![0; word_count],
            val_words: vec![0; word_count],
            position: vec![0; dims],
            trainers: vec![0; trainer_count],
            seg,
            cursor: vec![0; leaf_count],
            loaded: false,
        }
    }

    /// Validate a candidate without loading it: correct arity, ids in
    /// range, no duplicates — the same checks (and error order) as
    /// [`crate::placement::validate_placement`], but against a reusable
    /// word bitset, so populations past 64 clients pay no allocation.
    pub fn validate(&mut self, position: &[usize]) -> Result<(), PlacementError> {
        self.val_words.fill(0);
        Self::check(&mut self.val_words, position, self.dims, self.client_count)
    }

    fn check(
        words: &mut [u64],
        position: &[usize],
        dims: usize,
        client_count: usize,
    ) -> Result<(), PlacementError> {
        if position.len() != dims {
            return Err(PlacementError::WrongArity { expected: dims, got: position.len() });
        }
        for &c in position {
            if c >= client_count {
                return Err(PlacementError::ClientOutOfRange { client: c, client_count });
            }
            let (word, bit) = (c / 64, 1u64 << (c % 64));
            if words[word] & bit != 0 {
                return Err(PlacementError::DuplicateClient { client: c });
            }
            words[word] |= bit;
        }
        Ok(())
    }

    /// Load a candidate: validate it, rebuild the membership bitset and
    /// stream the round-robin trainer partition — one O(clients) pass,
    /// zero allocations. On error the scratch is left unloaded.
    pub fn load(&mut self, position: &[usize]) -> Result<(), PlacementError> {
        self.loaded = false;
        self.words.fill(0);
        Self::check(&mut self.words, position, self.dims, self.client_count)?;
        self.finish_load(position);
        Ok(())
    }

    /// Load a candidate that already passed [`EvalScratch::validate`]
    /// (the batch oracles validate everything up front, then score):
    /// rebuilds membership with a branch-free bit pass instead of
    /// re-running the duplicate/range checks.
    pub fn load_prevalidated(&mut self, position: &[usize]) {
        debug_assert_eq!(position.len(), self.dims, "prevalidated position has wrong arity");
        self.loaded = false;
        self.words.fill(0);
        for &c in position {
            debug_assert!(c < self.client_count);
            self.words[c / 64] |= 1u64 << (c % 64);
        }
        self.finish_load(position);
    }

    /// Shared tail of the load paths: membership bits are set; copy the
    /// position and deal the trainer partition.
    fn finish_load(&mut self, position: &[usize]) {
        self.position.copy_from_slice(position);
        self.cursor.copy_from_slice(&self.seg[..self.leaf_count]);
        let mut rank = 0usize;
        for c in 0..self.client_count {
            if self.words[c / 64] & (1u64 << (c % 64)) == 0 {
                let leaf = rank % self.leaf_count;
                self.trainers[self.cursor[leaf]] = c;
                self.cursor[leaf] += 1;
                rank += 1;
            }
        }
        self.loaded = true;
    }

    /// Whether a position is currently loaded.
    pub fn loaded(&self) -> bool {
        self.loaded
    }

    /// The loaded position (client per slot, BFT order).
    pub fn position(&self) -> &[usize] {
        debug_assert!(self.loaded);
        &self.position
    }

    /// Whether `client` holds an aggregator slot in the loaded position.
    pub fn is_aggregator(&self, client: usize) -> bool {
        debug_assert!(self.loaded);
        client < self.client_count && self.words[client / 64] & (1u64 << (client % 64)) != 0
    }

    /// Trainers of leaf `i` (0-based among leaf slots), ascending —
    /// identical contents and order to `Arrangement::trainers[i]`.
    pub fn leaf_trainers(&self, i: usize) -> &[usize] {
        debug_assert!(self.loaded);
        &self.trainers[self.seg[i]..self.seg[i + 1]]
    }

    pub fn spec(&self) -> HierarchySpec {
        self.spec
    }

    pub fn client_count(&self) -> usize {
        self.client_count
    }

    pub fn dims(&self) -> usize {
        self.dims
    }

    /// First leaf slot index (`spec.level_start(depth − 1)`), cached.
    pub fn leaf_start(&self) -> usize {
        self.leaf_start
    }

    pub fn leaf_count(&self) -> usize {
        self.leaf_count
    }

    pub fn trainer_count(&self) -> usize {
        self.trainers.len()
    }

    /// Materialize the loaded position as a full [`Arrangement`]
    /// (allocates; for callers that need the legacy type).
    pub fn to_arrangement(&self) -> Arrangement {
        debug_assert!(self.loaded);
        Arrangement {
            spec: self.spec,
            aggregators: self.position.clone(),
            trainers: (0..self.leaf_count).map(|i| self.leaf_trainers(i).to_vec()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Pcg32, Rng};

    #[test]
    fn partition_matches_from_position_across_shapes() {
        let mut rng = Pcg32::seed_from_u64(11);
        for (d, w, cc) in [(1, 1, 5), (2, 2, 7), (3, 2, 12), (3, 4, 53), (2, 3, 70)] {
            let spec = HierarchySpec::new(d, w);
            let mut scratch = EvalScratch::new(spec, cc);
            for _ in 0..10 {
                let pos = rng.sample_distinct(cc, spec.dimensions());
                scratch.load(&pos).unwrap();
                let arr = Arrangement::from_position(spec, &pos, cc);
                for i in 0..scratch.leaf_count() {
                    assert_eq!(scratch.leaf_trainers(i), &arr.trainers[i][..], "leaf {i}");
                }
                assert_eq!(scratch.to_arrangement(), arr);
                for c in 0..cc {
                    assert_eq!(scratch.is_aggregator(c), pos.contains(&c));
                }
            }
        }
    }

    #[test]
    fn validation_reports_the_same_typed_errors() {
        use crate::placement::validate_placement;
        let spec = HierarchySpec::new(2, 2);
        let mut scratch = EvalScratch::new(spec, 100); // >64: word path
        for bad in [
            vec![0usize, 1],          // arity
            vec![0, 1, 200],          // out of range
            vec![5, 7, 5],            // duplicate
            vec![99, 98, 97],         // valid
        ] {
            assert_eq!(scratch.validate(&bad), validate_placement(&bad, 3, 100), "{bad:?}");
            assert_eq!(
                scratch.load(&bad).is_ok(),
                validate_placement(&bad, 3, 100).is_ok()
            );
        }
        // A failed load leaves the scratch unloaded; a good one loads.
        assert!(scratch.load(&[0, 0, 1]).is_err());
        assert!(!scratch.loaded());
        scratch.load(&[0, 64, 99]).unwrap();
        assert!(scratch.loaded());
        assert!(scratch.is_aggregator(64) && !scratch.is_aggregator(63));
    }

    #[test]
    fn exact_fit_population_has_no_trainers() {
        let spec = HierarchySpec::new(2, 3);
        let mut scratch = EvalScratch::new(spec, spec.dimensions());
        scratch.load(&(0..spec.dimensions()).collect::<Vec<_>>()).unwrap();
        assert_eq!(scratch.trainer_count(), 0);
        for i in 0..scratch.leaf_count() {
            assert!(scratch.leaf_trainers(i).is_empty());
        }
    }
}
