//! The SDFL aggregation hierarchy (paper §IV.A).
//!
//! A complete W-ary tree of aggregator *slots* with depth D, stored in
//! breadth-first order (the paper constructs and traverses it by BFT).
//! An [`Arrangement`] binds client ids to slots — the object PSO
//! optimizes — plus the trainer-to-leaf assignment.
//!
//! Two representations of the same assignment coexist:
//!
//! * [`Arrangement`] — the materialized public type (owned trainer
//!   lists per leaf), used on protocol/wire paths and as the reference
//!   the equivalence tests pin the fast path against.
//! * [`EvalScratch`] — the reusable zero-allocation *view* the delay
//!   oracles reload per candidate placement: a word-bitset membership
//!   table (which is also the validator) plus the round-robin trainer
//!   partition streamed into one flat buffer. Loading it never touches
//!   the heap, which is what makes million-evaluation placement
//!   searches allocation-free.

mod arrangement;
mod scratch;
mod spec;

pub use arrangement::{Arrangement, Role};
pub use scratch::EvalScratch;
pub use spec::HierarchySpec;
