//! The SDFL aggregation hierarchy (paper §IV.A).
//!
//! A complete W-ary tree of aggregator *slots* with depth D, stored in
//! breadth-first order (the paper constructs and traverses it by BFT).
//! An [`Arrangement`] binds client ids to slots — the object PSO
//! optimizes — plus the trainer-to-leaf assignment.

mod arrangement;
mod spec;

pub use arrangement::{Arrangement, Role};
pub use spec::HierarchySpec;
