//! An [`Arrangement`] binds concrete client ids to the hierarchy's
//! aggregator slots and distributes the remaining clients as trainers —
//! the "Hierarchy Rearrangement" step of the paper's Algorithm 1.

use super::HierarchySpec;

/// A concrete client-to-role assignment for one FL round.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Arrangement {
    pub spec: HierarchySpec,
    /// Client id occupying each aggregator slot (BFT order). This is
    /// exactly the PSO particle's position vector.
    pub aggregators: Vec<usize>,
    /// Trainer client ids attached to each leaf aggregator slot, indexed
    /// by position within `spec.leaf_slots()`.
    pub trainers: Vec<Vec<usize>>,
}

impl Arrangement {
    /// Build from a position vector over `client_count` clients.
    ///
    /// Clients named in `position` become aggregators ("agtrainers" in
    /// the paper — they also keep a processing buffer). All remaining
    /// clients are drained from a buffer of available labels and spread
    /// over the leaf aggregators round-robin, matching the paper's
    /// "remaining clients are assigned trainer roles from a buffer of
    /// available labels".
    pub fn from_position(
        spec: HierarchySpec,
        position: &[usize],
        client_count: usize,
    ) -> Arrangement {
        let dims = spec.dimensions();
        assert_eq!(
            position.len(),
            dims,
            "position length {} != dimensions {}",
            position.len(),
            dims
        );
        assert!(
            client_count >= dims,
            "need at least {dims} clients for {dims} aggregator slots"
        );
        debug_assert!(
            {
                let mut seen = vec![false; client_count];
                position.iter().all(|&c| {
                    c < client_count && !std::mem::replace(&mut seen[c], true)
                })
            },
            "position must be distinct client ids < client_count"
        );

        let mut is_aggregator = vec![false; client_count];
        for &c in position {
            is_aggregator[c] = true;
        }
        // Buffer of available trainer labels, ascending for determinism.
        let buffer: Vec<usize> = (0..client_count).filter(|&c| !is_aggregator[c]).collect();

        let leaf_count = spec.leaf_slots().len();
        let mut trainers: Vec<Vec<usize>> = vec![Vec::new(); leaf_count];
        for (i, c) in buffer.into_iter().enumerate() {
            trainers[i % leaf_count].push(c);
        }

        Arrangement {
            spec,
            aggregators: position.to_vec(),
            trainers,
        }
    }

    /// Clients whose round-trip the aggregator at `slot` waits for: the
    /// contents of its processing buffer (trainers for leaf slots, child
    /// aggregators otherwise).
    pub fn buffer_of(&self, slot: usize) -> Vec<usize> {
        if self.spec.is_leaf_slot(slot) {
            let leaf_index = slot - self.spec.level_start(self.spec.depth - 1);
            self.trainers[leaf_index].clone()
        } else {
            self.spec
                .children(slot)
                .into_iter()
                .map(|s| self.aggregators[s])
                .collect()
        }
    }

    /// All trainer client ids (flattened).
    pub fn all_trainers(&self) -> Vec<usize> {
        self.trainers.iter().flatten().copied().collect()
    }

    /// Total clients represented (aggregators + trainers).
    pub fn client_count(&self) -> usize {
        self.aggregators.len() + self.trainers.iter().map(Vec::len).sum::<usize>()
    }

    /// Roles of every client, built in one O(clients + slots) pass —
    /// use this instead of calling [`Arrangement::role_of`] per client
    /// when iterating a whole population (that would be quadratic).
    /// Index `c` holds client `c`'s role; the vector spans up to the
    /// highest client id present (ids not assigned anywhere — possible
    /// in hand-built arrangements with sparse ids — read [`Role::Idle`]).
    pub fn roles(&self) -> Vec<Role> {
        let max_id = self
            .aggregators
            .iter()
            .chain(self.trainers.iter().flatten())
            .max();
        let len = max_id.map_or(0, |&m| m + 1);
        let mut roles = vec![Role::Idle; len];
        for (slot, &c) in self.aggregators.iter().enumerate() {
            roles[c] = Role::Aggregator { slot };
        }
        let leaf_start = self.spec.level_start(self.spec.depth - 1);
        for (i, t) in self.trainers.iter().enumerate() {
            for &c in t {
                roles[c] = Role::Trainer { parent_slot: leaf_start + i };
            }
        }
        roles
    }

    /// Role of a client in this arrangement: a thin lookup, not a scan.
    ///
    /// Aggregators are found in O(slots). Trainers exploit the
    /// round-robin invariant of [`Arrangement::from_position`] — the
    /// k-th non-aggregator client (ascending) sits under leaf
    /// `k % leaf_count` — so the parent leaf is computed arithmetically
    /// and confirmed with one binary search. Arrangements built by hand
    /// with a different trainer layout fall back to scanning the lists.
    pub fn role_of(&self, client: usize) -> Role {
        if let Some(slot) = self.aggregators.iter().position(|&c| c == client) {
            return Role::Aggregator { slot };
        }
        let leaf_start = self.spec.level_start(self.spec.depth - 1);
        if client < self.client_count() && !self.trainers.is_empty() {
            // Trainer rank under the round-robin assignment: clients
            // below `client` minus the aggregators among them.
            let rank = client - self.aggregators.iter().filter(|&&a| a < client).count();
            let leaf = rank % self.trainers.len();
            if self.trainers[leaf].binary_search(&client).is_ok() {
                return Role::Trainer { parent_slot: leaf_start + leaf };
            }
        }
        // Non-standard arrangement (or a client that was dropped):
        // authoritative scan over the trainer lists.
        for (i, t) in self.trainers.iter().enumerate() {
            if t.contains(&client) {
                return Role::Trainer { parent_slot: leaf_start + i };
            }
        }
        Role::Idle
    }
}

/// A client's role within an arrangement.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Occupies aggregator slot `slot` (BFT index).
    Aggregator { slot: usize },
    /// Trains and reports to the aggregator at `parent_slot`.
    Trainer { parent_slot: usize },
    /// Not part of this round (only possible if client_count changed).
    Idle,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> HierarchySpec {
        HierarchySpec::new(3, 2) // dims = 1 + 2 + 4 = 7
    }

    #[test]
    fn trainers_are_the_complement() {
        let s = spec();
        let pos: Vec<usize> = vec![10, 3, 5, 0, 1, 2, 4];
        let a = Arrangement::from_position(s, &pos, 12);
        let mut all: Vec<usize> = a.all_trainers();
        all.extend_from_slice(&a.aggregators);
        all.sort_unstable();
        assert_eq!(all, (0..12).collect::<Vec<_>>());
        assert_eq!(a.client_count(), 12);
    }

    #[test]
    fn trainer_distribution_is_balanced() {
        let s = spec(); // 4 leaf slots
        let pos: Vec<usize> = (0..7).collect();
        let a = Arrangement::from_position(s, &pos, 17); // 10 trainers over 4 leaves
        let sizes: Vec<usize> = a.trainers.iter().map(Vec::len).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn buffer_of_inner_slot_is_child_aggregators() {
        let s = spec();
        let pos: Vec<usize> = vec![6, 5, 4, 3, 2, 1, 0];
        let a = Arrangement::from_position(s, &pos, 8);
        // Root (slot 0) children are slots 1, 2 → clients 5, 4.
        assert_eq!(a.buffer_of(0), vec![5, 4]);
        // Slot 1 children are slots 3, 4 → clients 3, 2.
        assert_eq!(a.buffer_of(1), vec![3, 2]);
    }

    #[test]
    fn buffer_of_leaf_slot_is_trainers() {
        let s = spec();
        let pos: Vec<usize> = (0..7).collect();
        let a = Arrangement::from_position(s, &pos, 11);
        // Leaf slots are 3..7; trainers 7..11 distributed round-robin.
        assert_eq!(a.buffer_of(3), vec![7]);
        assert_eq!(a.buffer_of(4), vec![8]);
        assert_eq!(a.buffer_of(5), vec![9]);
        assert_eq!(a.buffer_of(6), vec![10]);
    }

    #[test]
    fn roles_cover_everyone() {
        let s = spec();
        let pos: Vec<usize> = vec![1, 3, 5, 7, 9, 11, 13];
        let a = Arrangement::from_position(s, &pos, 14);
        let mut aggs = 0;
        let mut trainers = 0;
        for c in 0..14 {
            match a.role_of(c) {
                Role::Aggregator { .. } => aggs += 1,
                Role::Trainer { .. } => trainers += 1,
                Role::Idle => panic!("client {c} idle"),
            }
        }
        assert_eq!(aggs, 7);
        assert_eq!(trainers, 7);
    }

    #[test]
    fn roles_matches_role_of_and_covers_everyone_in_one_pass() {
        let s = spec();
        let pos: Vec<usize> = vec![1, 3, 5, 7, 9, 11, 13];
        let a = Arrangement::from_position(s, &pos, 14);
        let roles = a.roles();
        assert_eq!(roles.len(), 14);
        for (c, &r) in roles.iter().enumerate() {
            assert_eq!(r, a.role_of(c), "client {c}");
            assert_ne!(r, Role::Idle, "client {c} idle in full arrangement");
        }
        // A client beyond the population is idle, not misassigned.
        assert_eq!(a.role_of(99), Role::Idle);
    }

    #[test]
    fn role_of_falls_back_on_hand_built_arrangements() {
        // A wire-format arrangement whose trainer lists do not follow
        // the round-robin-from-ascending-buffer layout must still
        // resolve roles correctly (the agent rebuilds arrangements from
        // RoundStart messages).
        let s = HierarchySpec::new(2, 2); // slots 0; leaves 1, 2
        let a = Arrangement {
            spec: s,
            aggregators: vec![4, 0, 1],
            trainers: vec![vec![5, 2], vec![3]], // unsorted, uneven
        };
        assert_eq!(a.role_of(4), Role::Aggregator { slot: 0 });
        assert_eq!(a.role_of(2), Role::Trainer { parent_slot: 1 });
        assert_eq!(a.role_of(5), Role::Trainer { parent_slot: 1 });
        assert_eq!(a.role_of(3), Role::Trainer { parent_slot: 2 });
        let roles = a.roles();
        assert_eq!(roles[3], Role::Trainer { parent_slot: 2 });
        assert_eq!(roles[0], Role::Aggregator { slot: 1 });

        // Sparse ids (gaps in the assigned population): roles() spans
        // to the max id, gaps read Idle, nothing panics.
        let sparse = Arrangement {
            spec: s,
            aggregators: vec![6, 0, 1],
            trainers: vec![vec![2], vec![3]],
        };
        let roles = sparse.roles();
        assert_eq!(roles.len(), 7);
        assert_eq!(roles[6], Role::Aggregator { slot: 0 });
        assert_eq!(roles[4], Role::Idle);
        assert_eq!(roles[5], Role::Idle);
        assert_eq!(sparse.role_of(2), Role::Trainer { parent_slot: 1 });
    }

    #[test]
    fn exact_fit_no_trainers() {
        let s = HierarchySpec::new(2, 3); // dims 4
        let a = Arrangement::from_position(s, &[0, 1, 2, 3], 4);
        assert!(a.all_trainers().is_empty());
        for slot in s.leaf_slots() {
            assert!(a.buffer_of(slot).is_empty());
        }
    }

    #[test]
    #[should_panic(expected = "position length")]
    fn wrong_position_length_panics() {
        let _ = Arrangement::from_position(spec(), &[0, 1], 10);
    }
}
