//! Shape of the aggregation hierarchy: slot indexing in BFT order.
//!
//! Every accessor here is O(1) and allocation-free — these run inside
//! the delay-oracle hot loop (once per slot per candidate placement),
//! so geometric series are evaluated in closed form and child/leaf slot
//! sets are exposed as index ranges rather than collected vectors.

/// A complete W-ary aggregator tree of depth D (slots only, no clients).
///
/// Slots are numbered in breadth-first order: slot 0 is the root, slots
/// `1..=W` are level 1, and so on. With `dims = Σ_{i<D} W^i` (paper
/// Eq. 5) the standard complete-tree arithmetic applies:
/// `parent(s) = (s-1)/W`, `children(s) = s·W+1 ..= s·W+W`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HierarchySpec {
    pub depth: usize,
    pub width: usize,
}

impl HierarchySpec {
    /// Construct; depth and width must be ≥ 1.
    pub fn new(depth: usize, width: usize) -> HierarchySpec {
        assert!(depth >= 1, "hierarchy depth must be >= 1");
        assert!(width >= 1, "hierarchy width must be >= 1");
        HierarchySpec { depth, width }
    }

    /// Total aggregator slots (paper Eq. 5): Σ_{i=0}^{D-1} W^i, in
    /// closed form — `(W^D − 1)/(W − 1)` for W ≥ 2, `D` for W = 1.
    pub fn dimensions(&self) -> usize {
        if self.width == 1 {
            self.depth
        } else {
            (self.width.pow(self.depth as u32) - 1) / (self.width - 1)
        }
    }

    /// Number of slots on level `l` (0-based): W^l.
    pub fn level_size(&self, l: usize) -> usize {
        assert!(l < self.depth);
        self.width.pow(l as u32)
    }

    /// First slot index of level `l`: the partial geometric sum
    /// `Σ_{i<l} W^i` in closed form (no per-call loop).
    pub fn level_start(&self, l: usize) -> usize {
        assert!(l < self.depth);
        if self.width == 1 {
            l
        } else {
            (self.width.pow(l as u32) - 1) / (self.width - 1)
        }
    }

    /// Level of slot `s` (inverse of the BFT numbering). O(1): slot `s`
    /// sits on level `l` iff `s(W−1)+1 ∈ [W^l, W^{l+1})`, so the level
    /// is an integer logarithm.
    pub fn level_of(&self, s: usize) -> usize {
        assert!(s < self.dimensions());
        if self.width == 1 {
            s
        } else {
            (s * (self.width - 1) + 1).ilog(self.width) as usize
        }
    }

    /// Parent slot of `s` (None for the root).
    pub fn parent(&self, s: usize) -> Option<usize> {
        assert!(s < self.dimensions());
        if s == 0 {
            None
        } else {
            Some((s - 1) / self.width)
        }
    }

    /// Child aggregator slots of `s` as a contiguous index range (empty
    /// for leaf-level slots). Children are consecutive in BFT order, so
    /// no vector needs collecting.
    pub fn children(&self, s: usize) -> std::ops::Range<usize> {
        let dims = self.dimensions();
        assert!(s < dims);
        let first = s * self.width + 1;
        first.min(dims)..(first + self.width).min(dims)
    }

    /// True if `s` is on the leaf aggregator level (D-1) — these slots
    /// receive trainer children instead of aggregator children.
    pub fn is_leaf_slot(&self, s: usize) -> bool {
        self.level_of(s) == self.depth - 1
    }

    /// Slots on the leaf aggregator level, in BFT order.
    pub fn leaf_slots(&self) -> std::ops::Range<usize> {
        self.level_start(self.depth - 1)..self.dimensions()
    }

    /// Slot index range of level `l`, in BFT order.
    pub fn level_slots(&self, l: usize) -> std::ops::Range<usize> {
        let start = self.level_start(l);
        start..start + self.level_size(l)
    }

    /// Slot indices grouped by level, bottom-up (leaf level first) — the
    /// traversal order of the paper's fitness function ("Traverse
    /// hierarchy bottom-up"). Allocates; hot paths iterate
    /// [`HierarchySpec::level_slots`] over `(0..depth).rev()` instead.
    pub fn levels_bottom_up(&self) -> Vec<Vec<usize>> {
        (0..self.depth).rev().map(|l| self.level_slots(l).collect()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dimensions_match_eq5() {
        assert_eq!(HierarchySpec::new(1, 4).dimensions(), 1);
        assert_eq!(HierarchySpec::new(2, 2).dimensions(), 3);
        assert_eq!(HierarchySpec::new(3, 4).dimensions(), 21);
        assert_eq!(HierarchySpec::new(4, 4).dimensions(), 85);
        assert_eq!(HierarchySpec::new(5, 4).dimensions(), 341);
        assert_eq!(HierarchySpec::new(3, 5).dimensions(), 31);
        // Width-1 chains: one slot per level.
        assert_eq!(HierarchySpec::new(4, 1).dimensions(), 4);
    }

    #[test]
    fn parent_child_consistency() {
        let h = HierarchySpec::new(4, 3);
        for s in 0..h.dimensions() {
            for c in h.children(s) {
                assert_eq!(h.parent(c), Some(s));
                assert_eq!(h.level_of(c), h.level_of(s) + 1);
            }
        }
    }

    #[test]
    fn closed_forms_match_the_geometric_series() {
        // The O(1) closed forms must agree with the defining series for
        // every shape in the catalog's range (including width 1).
        for depth in 1..6 {
            for width in 1..6 {
                let h = HierarchySpec::new(depth, width);
                let series: usize = (0..depth).map(|i| width.pow(i as u32)).sum();
                assert_eq!(h.dimensions(), series, "D{depth} W{width}");
                let mut start = 0;
                for l in 0..depth {
                    assert_eq!(h.level_start(l), start, "D{depth} W{width} l{l}");
                    for s in h.level_slots(l) {
                        assert_eq!(h.level_of(s), l, "D{depth} W{width} s{s}");
                    }
                    start += h.level_size(l);
                }
            }
        }
    }

    #[test]
    fn leaf_slots_have_no_children() {
        let h = HierarchySpec::new(3, 4);
        for s in h.leaf_slots() {
            assert!(h.is_leaf_slot(s));
            assert!(h.children(s).is_empty());
        }
        assert_eq!(h.leaf_slots().len(), 16);
    }

    #[test]
    fn levels_bottom_up_covers_all_slots_once() {
        let h = HierarchySpec::new(4, 2);
        let mut seen: Vec<usize> = h.levels_bottom_up().into_iter().flatten().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..h.dimensions()).collect::<Vec<_>>());
        // First group is the leaf level.
        assert_eq!(h.levels_bottom_up()[0], h.leaf_slots().collect::<Vec<_>>());
    }

    #[test]
    fn level_start_and_size() {
        let h = HierarchySpec::new(3, 4);
        assert_eq!(h.level_start(0), 0);
        assert_eq!(h.level_start(1), 1);
        assert_eq!(h.level_start(2), 5);
        assert_eq!(h.level_size(2), 16);
        assert_eq!(h.level_of(0), 0);
        assert_eq!(h.level_of(4), 1);
        assert_eq!(h.level_of(5), 2);
        assert_eq!(h.level_of(20), 2);
    }

    #[test]
    fn depth_one_single_root() {
        let h = HierarchySpec::new(1, 7);
        assert_eq!(h.dimensions(), 1);
        assert!(h.is_leaf_slot(0));
        assert_eq!(h.parent(0), None);
    }
}
