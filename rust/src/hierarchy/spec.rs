//! Shape of the aggregation hierarchy: slot indexing in BFT order.

/// A complete W-ary aggregator tree of depth D (slots only, no clients).
///
/// Slots are numbered in breadth-first order: slot 0 is the root, slots
/// `1..=W` are level 1, and so on. With `dims = Σ_{i<D} W^i` (paper
/// Eq. 5) the standard complete-tree arithmetic applies:
/// `parent(s) = (s-1)/W`, `children(s) = s·W+1 ..= s·W+W`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HierarchySpec {
    pub depth: usize,
    pub width: usize,
}

impl HierarchySpec {
    /// Construct; depth and width must be ≥ 1.
    pub fn new(depth: usize, width: usize) -> HierarchySpec {
        assert!(depth >= 1, "hierarchy depth must be >= 1");
        assert!(width >= 1, "hierarchy width must be >= 1");
        HierarchySpec { depth, width }
    }

    /// Total aggregator slots (paper Eq. 5): Σ_{i=0}^{D-1} W^i.
    pub fn dimensions(&self) -> usize {
        let mut total = 0usize;
        let mut level = 1usize;
        for _ in 0..self.depth {
            total += level;
            level *= self.width;
        }
        total
    }

    /// Number of slots on level `l` (0-based): W^l.
    pub fn level_size(&self, l: usize) -> usize {
        assert!(l < self.depth);
        self.width.pow(l as u32)
    }

    /// First slot index of level `l`.
    pub fn level_start(&self, l: usize) -> usize {
        assert!(l < self.depth);
        let mut start = 0;
        let mut size = 1;
        for _ in 0..l {
            start += size;
            size *= self.width;
        }
        start
    }

    /// Level of slot `s` (inverse of the BFT numbering).
    pub fn level_of(&self, s: usize) -> usize {
        assert!(s < self.dimensions());
        let mut start = 0;
        let mut size = 1;
        for l in 0..self.depth {
            if s < start + size {
                return l;
            }
            start += size;
            size *= self.width;
        }
        unreachable!()
    }

    /// Parent slot of `s` (None for the root).
    pub fn parent(&self, s: usize) -> Option<usize> {
        assert!(s < self.dimensions());
        if s == 0 {
            None
        } else {
            Some((s - 1) / self.width)
        }
    }

    /// Child aggregator slots of `s` (empty for leaf-level slots).
    pub fn children(&self, s: usize) -> Vec<usize> {
        let dims = self.dimensions();
        assert!(s < dims);
        let first = s * self.width + 1;
        (first..first + self.width).filter(|&c| c < dims).collect()
    }

    /// True if `s` is on the leaf aggregator level (D-1) — these slots
    /// receive trainer children instead of aggregator children.
    pub fn is_leaf_slot(&self, s: usize) -> bool {
        self.level_of(s) == self.depth - 1
    }

    /// Slots on the leaf aggregator level, in BFT order.
    pub fn leaf_slots(&self) -> Vec<usize> {
        let start = self.level_start(self.depth - 1);
        (start..self.dimensions()).collect()
    }

    /// Slot indices grouped by level, bottom-up (leaf level first) — the
    /// traversal order of the paper's fitness function ("Traverse
    /// hierarchy bottom-up").
    pub fn levels_bottom_up(&self) -> Vec<Vec<usize>> {
        (0..self.depth)
            .rev()
            .map(|l| {
                let start = self.level_start(l);
                (start..start + self.level_size(l)).collect()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dimensions_match_eq5() {
        assert_eq!(HierarchySpec::new(1, 4).dimensions(), 1);
        assert_eq!(HierarchySpec::new(2, 2).dimensions(), 3);
        assert_eq!(HierarchySpec::new(3, 4).dimensions(), 21);
        assert_eq!(HierarchySpec::new(4, 4).dimensions(), 85);
        assert_eq!(HierarchySpec::new(5, 4).dimensions(), 341);
        assert_eq!(HierarchySpec::new(3, 5).dimensions(), 31);
    }

    #[test]
    fn parent_child_consistency() {
        let h = HierarchySpec::new(4, 3);
        for s in 0..h.dimensions() {
            for c in h.children(s) {
                assert_eq!(h.parent(c), Some(s));
                assert_eq!(h.level_of(c), h.level_of(s) + 1);
            }
        }
    }

    #[test]
    fn leaf_slots_have_no_children() {
        let h = HierarchySpec::new(3, 4);
        for s in h.leaf_slots() {
            assert!(h.is_leaf_slot(s));
            assert!(h.children(s).is_empty());
        }
        assert_eq!(h.leaf_slots().len(), 16);
    }

    #[test]
    fn levels_bottom_up_covers_all_slots_once() {
        let h = HierarchySpec::new(4, 2);
        let mut seen: Vec<usize> = h.levels_bottom_up().into_iter().flatten().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..h.dimensions()).collect::<Vec<_>>());
        // First group is the leaf level.
        assert_eq!(h.levels_bottom_up()[0], h.leaf_slots());
    }

    #[test]
    fn level_start_and_size() {
        let h = HierarchySpec::new(3, 4);
        assert_eq!(h.level_start(0), 0);
        assert_eq!(h.level_start(1), 1);
        assert_eq!(h.level_start(2), 5);
        assert_eq!(h.level_size(2), 16);
        assert_eq!(h.level_of(0), 0);
        assert_eq!(h.level_of(4), 1);
        assert_eq!(h.level_of(5), 2);
        assert_eq!(h.level_of(20), 2);
    }

    #[test]
    fn depth_one_single_root() {
        let h = HierarchySpec::new(1, 7);
        assert_eq!(h.dimensions(), 1);
        assert!(h.is_leaf_slot(0));
        assert_eq!(h.parent(0), None);
    }
}
