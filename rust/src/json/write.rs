//! JSON serialization: compact (wire) and pretty (meta/config files).

use super::Value;

/// Compact serialization — the SDFLMQ wire form.
pub fn to_string(v: &Value) -> String {
    // Model payloads are ~30 MB of numbers; pre-sizing avoids most regrowth.
    let mut out = String::with_capacity(estimate(v));
    write_value(v, &mut out);
    out
}

/// Two-space-indented serialization for human-read files.
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_pretty(v, 0, &mut out);
    out
}

fn estimate(v: &Value) -> usize {
    match v {
        Value::Null => 4,
        Value::Bool(_) => 5,
        Value::Num(_) => 12,
        Value::Str(s) => s.len() + 2,
        Value::Array(xs) => 2 + xs.iter().map(estimate).sum::<usize>() + xs.len(),
        Value::Object(ps) => {
            2 + ps
                .iter()
                .map(|(k, v)| k.len() + 4 + estimate(v))
                .sum::<usize>()
        }
    }
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => write_number(*n, out),
        Value::Str(s) => write_string(s, out),
        Value::Array(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(x, out);
            }
            out.push(']');
        }
        Value::Object(ps) => {
            out.push('{');
            for (i, (k, x)) in ps.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(x, out);
            }
            out.push('}');
        }
    }
}

fn write_pretty(v: &Value, indent: usize, out: &mut String) {
    match v {
        Value::Array(xs) if !xs.is_empty() => {
            out.push_str("[\n");
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                push_indent(indent + 1, out);
                write_pretty(x, indent + 1, out);
            }
            out.push('\n');
            push_indent(indent, out);
            out.push(']');
        }
        Value::Object(ps) if !ps.is_empty() => {
            out.push_str("{\n");
            for (i, (k, x)) in ps.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                push_indent(indent + 1, out);
                write_string(k, out);
                out.push_str(": ");
                write_pretty(x, indent + 1, out);
            }
            out.push('\n');
            push_indent(indent, out);
            out.push('}');
        }
        other => write_value(other, out),
    }
}

fn push_indent(n: usize, out: &mut String) {
    for _ in 0..n {
        out.push_str("  ");
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{0008}' => out.push_str("\\b"),
            '\u{000C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Shortest-roundtrip f64 formatting: rust's `{}` for f64 already emits
/// the shortest string that parses back exactly; integers get no ".0"
/// (matching python's json for whole floats is NOT required — our parser
/// reads both).
fn write_number(n: f64, out: &mut String) {
    if n.is_finite() {
        if n.fract() == 0.0 && n.abs() < 1e15 {
            out.push_str(&format!("{}", n as i64));
        } else {
            out.push_str(&format!("{n}"));
        }
    } else {
        // JSON has no NaN/Inf; SDFLMQ payloads never contain them (model
        // params are finite) — emit null defensively.
        out.push_str("null");
    }
}

#[cfg(test)]
mod tests {
    use super::super::parse;
    use super::*;

    #[test]
    fn compact_has_no_spaces() {
        let v = Value::object(vec![("a", Value::Array(vec![Value::from(1.0)]))]);
        assert_eq!(to_string(&v), "{\"a\":[1]}");
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(to_string(&Value::from(42.0)), "42");
        assert_eq!(to_string(&Value::from(-3.0)), "-3");
        assert_eq!(to_string(&Value::from(2.5)), "2.5");
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(to_string(&Value::Num(f64::NAN)), "null");
        assert_eq!(to_string(&Value::Num(f64::INFINITY)), "null");
    }

    #[test]
    fn control_chars_escaped() {
        let v = Value::from("\u{0001}\u{001F}");
        let s = to_string(&v);
        assert_eq!(s, "\"\\u0001\\u001f\"");
        assert_eq!(parse(&s).unwrap(), v);
    }
}
