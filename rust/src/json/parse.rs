//! Recursive-descent JSON parser (RFC 8259) with a depth guard.

use super::Value;

/// Parse failure with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Nesting guard: SDFLMQ messages are shallow; anything deeper is hostile
/// input and must not overflow the stack.
const MAX_DEPTH: usize = 256;

/// Parse a complete JSON document (trailing content is an error).
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing content"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal (expected {word})")))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Value, ParseError> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(&format!("unexpected byte '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self, depth: usize) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            pairs.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(pairs)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(xs)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        // Fast path: copy unescaped ASCII/UTF-8 runs wholesale.
        loop {
            let start = self.pos;
            while let Some(b) = self.peek() {
                if b == b'"' || b == b'\\' || b < 0x20 {
                    break;
                }
                self.pos += 1;
            }
            // The source is &str, so any run of non-special bytes is valid UTF-8.
            out.push_str(std::str::from_utf8(&self.bytes[start..self.pos]).unwrap());
            match self.bump() {
                Some(b'"') => return Ok(out),
                Some(b'\\') => out.push(self.escape()?),
                Some(_) => return Err(self.err("control character in string")),
                None => return Err(self.err("unterminated string")),
            }
        }
    }

    fn escape(&mut self) -> Result<char, ParseError> {
        match self.bump() {
            Some(b'"') => Ok('"'),
            Some(b'\\') => Ok('\\'),
            Some(b'/') => Ok('/'),
            Some(b'b') => Ok('\u{0008}'),
            Some(b'f') => Ok('\u{000C}'),
            Some(b'n') => Ok('\n'),
            Some(b'r') => Ok('\r'),
            Some(b't') => Ok('\t'),
            Some(b'u') => {
                let hi = self.hex4()?;
                if (0xD800..0xDC00).contains(&hi) {
                    // Surrogate pair.
                    if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                        return Err(self.err("unpaired surrogate"));
                    }
                    let lo = self.hex4()?;
                    if !(0xDC00..0xE000).contains(&lo) {
                        return Err(self.err("invalid low surrogate"));
                    }
                    let c = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                    char::from_u32(c).ok_or_else(|| self.err("invalid surrogate pair"))
                } else if (0xDC00..0xE000).contains(&hi) {
                    Err(self.err("unpaired low surrogate"))
                } else {
                    char::from_u32(hi).ok_or_else(|| self.err("invalid \\u escape"))
                }
            }
            _ => Err(self.err("invalid escape")),
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("eof in \\u escape"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = (v << 4) | d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // Integer part: single 0 or nonzero-led digits.
        match self.peek() {
            Some(b'0') => {
                self.pos += 1;
                if matches!(self.peek(), Some(b'0'..=b'9')) {
                    return Err(self.err("leading zero"));
                }
            }
            Some(b'1'..=b'9') => {
                while matches!(self.peek(), Some(b'0'..=b'9')) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.err("invalid number")),
        }
        // Fraction.
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("digit required after '.'"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        // Exponent.
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("digit required in exponent"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("number out of range"))
    }
}
