//! Minimal JSON (substrate — no `serde`/`serde_json` in the offline image).
//!
//! Two consumers:
//! * `fl::codec` — the paper's SDFLMQ framework ships model parameters as
//!   JSON (~30 MB per 1.8 M-param model); we reproduce that wire format
//!   and benchmark it against a binary codec (`ablation_codec`).
//! * `runtime::artifacts` — parses `artifacts/meta.json`.
//!
//! Full RFC 8259 value model with strict parsing (UTF-8, escapes,
//! exponents), insertion-ordered objects, and a fast bulk `f32`-array
//! path for the codec hot loop.

mod parse;
mod value;
mod write;

pub use parse::{parse, ParseError};
pub use value::Value;
pub use write::{to_string, to_string_pretty};

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(v: &Value) -> Value {
        parse(&to_string(v)).expect("roundtrip parse")
    }

    #[test]
    fn roundtrip_scalars() {
        for v in [
            Value::Null,
            Value::Bool(true),
            Value::Bool(false),
            Value::from(0.0),
            Value::from(-12.5),
            Value::from(1e-9),
            Value::from(3_000_000_000.0_f64),
            Value::from("hello"),
            Value::from(""),
        ] {
            assert_eq!(roundtrip(&v), v);
        }
    }

    #[test]
    fn roundtrip_string_escapes() {
        let s = "quote\" backslash\\ newline\n tab\t unicode\u{263A} nul\u{0001}";
        let v = Value::from(s);
        assert_eq!(roundtrip(&v), v);
    }

    #[test]
    fn roundtrip_nested() {
        let v = Value::object(vec![
            ("id", Value::from(7.0)),
            ("name", Value::from("agg_0")),
            (
                "children",
                Value::Array(vec![Value::from(1.0), Value::from(2.0), Value::Null]),
            ),
            (
                "attrs",
                Value::object(vec![("pspeed", Value::from(9.25)), ("ok", Value::Bool(true))]),
            ),
        ]);
        assert_eq!(roundtrip(&v), v);
    }

    #[test]
    fn parse_whitespace_and_order() {
        let v = parse(" { \"b\" : 1 , \"a\" : [ true , null ] } ").unwrap();
        // Insertion order preserved.
        let obj = v.as_object().unwrap();
        assert_eq!(obj[0].0, "b");
        assert_eq!(obj[1].0, "a");
    }

    #[test]
    fn parse_errors() {
        for bad in [
            "", "{", "}", "[1,", "[1 2]", "{\"a\"}", "{\"a\":}", "tru", "nul", "01", "1.",
            "\"unterminated", "{\"a\":1,}", "[1]trailing", "\"bad\\q\"", "+1", "--1",
        ] {
            assert!(parse(bad).is_err(), "should fail: {bad:?}");
        }
    }

    #[test]
    fn parse_unicode_escape() {
        let v = parse("\"\\u0041\\u263A\\uD83D\\uDE00\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "A\u{263A}\u{1F600}");
    }

    #[test]
    fn f32_array_fast_path() {
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32) * 0.5 - 7.25).collect();
        let v = Value::from_f32_slice(&xs);
        let text = to_string(&v);
        let back = parse(&text).unwrap();
        let ys = back.to_f32_vec().unwrap();
        assert_eq!(xs.len(), ys.len());
        for (a, b) in xs.iter().zip(&ys) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn numbers_preserve_f64_precision() {
        let v = parse("1.7976931348623157e308").unwrap();
        assert_eq!(v.as_f64().unwrap(), f64::MAX);
        let v = parse("-0.000123456789012345").unwrap();
        assert!((v.as_f64().unwrap() + 0.000123456789012345).abs() < 1e-20);
    }

    #[test]
    fn pretty_output_reparses() {
        let v = Value::object(vec![
            ("x", Value::Array(vec![Value::from(1.0)])),
            ("y", Value::object(vec![("z", Value::Null)])),
        ]);
        assert_eq!(parse(&to_string_pretty(&v)).unwrap(), v);
    }

    #[test]
    fn deep_nesting_depth_limit() {
        let mut s = String::new();
        for _ in 0..100_000 {
            s.push('[');
        }
        // Must error (depth guard), not blow the stack.
        assert!(parse(&s).is_err());
    }
}
