//! The JSON value model: insertion-ordered objects, f64 numbers.

/// A JSON document node.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    /// All JSON numbers are f64 (RFC 8259 interoperable range).
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    /// Insertion-ordered key/value pairs (SDFLMQ messages care about
    /// neither uniqueness-violation recovery nor hash lookup speed).
    Object(Vec<(String, Value)>),
}

impl Value {
    /// Build an object from `(&str, Value)` pairs.
    pub fn object(pairs: Vec<(&str, Value)>) -> Value {
        Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(xs) => Some(xs),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Object(pairs) => Some(pairs),
            _ => None,
        }
    }

    /// Bulk construction from an `f32` slice (model-codec fast path).
    pub fn from_f32_slice(xs: &[f32]) -> Value {
        Value::Array(xs.iter().map(|&x| Value::Num(f64::from(x))).collect())
    }

    /// Bulk extraction into `Vec<f32>`; `None` if any element is non-numeric.
    pub fn to_f32_vec(&self) -> Option<Vec<f32>> {
        match self {
            Value::Array(xs) => {
                let mut out = Vec::with_capacity(xs.len());
                for x in xs {
                    out.push(x.as_f64()? as f32);
                }
                Some(out)
            }
            _ => None,
        }
    }
}

impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}

impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}

impl From<u64> for Value {
    fn from(n: u64) -> Self {
        Value::Num(n as f64)
    }
}

impl From<i64> for Value {
    fn from(n: i64) -> Self {
        Value::Num(n as f64)
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}

impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_finds_first_match() {
        let v = Value::object(vec![("a", Value::from(1.0)), ("b", Value::from(2.0))]);
        assert_eq!(v.get("b").unwrap().as_f64(), Some(2.0));
        assert!(v.get("c").is_none());
    }

    #[test]
    fn as_u64_rejects_fractions_and_negatives() {
        assert_eq!(Value::from(5.0).as_u64(), Some(5));
        assert_eq!(Value::from(5.5).as_u64(), None);
        assert_eq!(Value::from(-1.0).as_u64(), None);
    }

    #[test]
    fn to_f32_vec_rejects_mixed() {
        let v = Value::Array(vec![Value::from(1.0), Value::Null]);
        assert!(v.to_f32_vec().is_none());
    }
}
