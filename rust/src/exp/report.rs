//! The report builder: ranked cells → standings, paired significance
//! (sign test + Wilcoxon signed-rank + rank-biserial effect size) and
//! the deterministic CSV trio (`<out>.csv`, `<out>.sig.csv`,
//! `<out>.effect.csv`).
//!
//! The matrix and sig CSV schemas are frozen (golden-tested in
//! `rust/tests/fleet_integration.rs`): the engine refactor and the
//! adaptive allocator must not move a byte at a fixed replicate count.
//! The new effect-size statistics therefore land in their own
//! `<out>.effect.csv` next to the other two.

use crate::log_warn;
use crate::metrics::{
    holm_bonferroni, mean_ci, paired_sign_test, wilcoxon_signed_rank, CsvWriter, SignTest,
    Wilcoxon,
};
use std::path::Path;

/// One (scenario, strategy) cell of an experiment: a replicate set.
/// (Re-exported as `des::FleetCell` for the fleet adapter.)
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentCell {
    pub scenario: String,
    pub strategy: String,
    pub clients: usize,
    pub slots: usize,
    /// Evaluations spent per replicate (equal across replicates).
    pub evaluations: usize,
    /// Best virtual-time round delay found, one entry per replicate in
    /// replicate order. Its length is the cell's `replicates_used` —
    /// under adaptive allocation scenarios stop at different counts.
    pub replicate_delays: Vec<f64>,
    /// Mean of `replicate_delays` — the cell's ranking statistic.
    pub best_delay: f64,
    /// Half-width of the 95% Student-t CI over `replicate_delays`
    /// (0.0 for a single replicate).
    pub ci95: f64,
    /// Mean delay across the whole search (exploration cost), averaged
    /// over replicates.
    pub mean_delay: f64,
    /// Events the simulator fired for this cell, totalled over
    /// replicates.
    pub events: u64,
    /// Rank of `best_delay` among the scenario's strategies (1 = won).
    pub rank: usize,
}

/// Per-strategy aggregate over the matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct StrategyStanding {
    pub strategy: String,
    /// Mean rank across scenarios (1.0 = won everything), ranks taken
    /// on replicate means.
    pub mean_rank: f64,
    /// Scenarios won outright.
    pub wins: usize,
    /// Geometric-mean of `best_delay / scenario winner's best_delay`
    /// (1.0 = always optimal; 2.0 = on average 2× the winner).
    pub regret: f64,
    /// Mean normalized delay: every (scenario, replicate) delay divided
    /// by its scenario winner's mean delay, averaged — the arithmetic,
    /// CI-carrying cousin of `regret` (scale-free across the catalog's
    /// 7-to-10k-client spread).
    pub mean_ratio: f64,
    /// Half-width of the 95% Student-t CI on `mean_ratio`.
    pub ratio_ci: f64,
}

/// Aggregate cells into the final standings, best mean rank first.
/// Scenarios whose winner delay is zero or non-finite cannot anchor a
/// meaningful ratio — `ln(0)` would poison the geometric mean into
/// `-inf`/NaN and silently corrupt the sort — so those terms contribute
/// a neutral regret of 1.0 and a warning is logged instead.
pub fn standings(cells: &[ExperimentCell]) -> Vec<StrategyStanding> {
    let mut order: Vec<&str> = Vec::new();
    for c in cells {
        if !order.contains(&c.strategy.as_str()) {
            order.push(&c.strategy);
        }
    }
    // Scenario winners (on replicate means) for the regret ratio.
    let mut winner: std::collections::BTreeMap<&str, f64> = std::collections::BTreeMap::new();
    for c in cells {
        let w = winner.entry(&c.scenario).or_insert(f64::INFINITY);
        *w = w.min(c.best_delay);
    }
    for (scenario, &w) in &winner {
        if !(w.is_finite() && w > 0.0) {
            log_warn!(
                "exp",
                "scenario {scenario:?} winner delay {w} is unusable as a regret anchor; \
                 treating its regret terms as 1.0"
            );
        }
    }
    let mut out: Vec<StrategyStanding> = order
        .iter()
        .map(|&s| {
            let mine: Vec<&ExperimentCell> = cells.iter().filter(|c| c.strategy == s).collect();
            let n = mine.len().max(1) as f64;
            let mean_rank = mine.iter().map(|c| c.rank as f64).sum::<f64>() / n;
            let wins = mine.iter().filter(|c| c.rank == 1).count();
            let log_regret = mine
                .iter()
                .map(|c| {
                    let ratio = c.best_delay / winner[c.scenario.as_str()];
                    // Guard: zero/NaN winner (or cell) delays collapse to
                    // the neutral ratio instead of poisoning the mean.
                    if ratio.is_finite() && ratio > 0.0 {
                        ratio.ln()
                    } else {
                        0.0
                    }
                })
                .sum::<f64>()
                / n;
            let ratios: Vec<f64> = mine
                .iter()
                .flat_map(|c| {
                    let w = winner[c.scenario.as_str()];
                    c.replicate_delays.iter().map(move |&d| {
                        let r = d / w;
                        if r.is_finite() && r > 0.0 {
                            r
                        } else {
                            1.0
                        }
                    })
                })
                .collect();
            let ci = mean_ci(&ratios);
            StrategyStanding {
                strategy: s.to_string(),
                mean_rank,
                wins,
                regret: log_regret.exp(),
                mean_ratio: ci.mean,
                ratio_ci: ci.half_width,
            }
        })
        .collect();
    out.sort_by(|a, b| a.mean_rank.total_cmp(&b.mean_rank));
    out
}

/// One comparison row of the significance matrix: the best-ranked
/// strategy against one rival over the paired (scenario, replicate)
/// delay series.
#[derive(Debug, Clone, PartialEq)]
pub struct VersusRow {
    /// The rival strategy.
    pub strategy: String,
    /// Two-sided exact paired sign test (`sign.a_wins` counts pairs
    /// where the best strategy was strictly faster).
    pub sign: SignTest,
    /// Wilcoxon signed-rank over the same pairs with both sides divided
    /// by their scenario winner's mean delay (scale-free across the
    /// catalog's 7-to-10k-client spread), with the matched-pairs
    /// rank-biserial correlation as effect size (positive = the best
    /// strategy is faster).
    pub wilcoxon: Wilcoxon,
    /// Holm–Bonferroni-adjusted sign-test p-value: the leader is tested
    /// against every rival simultaneously, so the raw per-row p-values
    /// overstate significance as a family —
    /// [`crate::metrics::holm_bonferroni`] corrects across the rows.
    pub sign_p_holm: f64,
    /// Holm–Bonferroni-adjusted Wilcoxon p-value (same family).
    pub wilcoxon_p_holm: f64,
}

/// The paired-significance report: the best-ranked strategy tested
/// against every other over the (scenario, replicate) delay pairs.
/// Replicate seeds are shared across strategies within a scenario, so
/// each pair compares the identical population/network/dynamics
/// process; between same-cadence strategies (everything except the
/// cohort-batching `ga`/`pso-batched`) the two sides even see the
/// identical per-evaluation realization sequence — exactly the pairing
/// the sign and signed-rank tests want. Under adaptive allocation the
/// per-scenario replicate counts differ, but within a scenario both
/// sides always hold the same count, so the series stay aligned.
#[derive(Debug, Clone, PartialEq)]
pub struct SignificanceMatrix {
    /// Strategy with the best mean rank.
    pub best: String,
    /// One row per rival, in standings order.
    pub versus: Vec<VersusRow>,
}

/// Compute the significance matrix from ranked cells. `None` when the
/// matrix has fewer than two strategies (nothing to compare).
pub fn significance_matrix(cells: &[ExperimentCell]) -> Option<SignificanceMatrix> {
    significance_for(&standings(cells), cells)
}

/// [`significance_matrix`] over an already-computed standings table
/// (avoids re-aggregating — and re-warning — inside [`report_cells`]).
fn significance_for(
    table: &[StrategyStanding],
    cells: &[ExperimentCell],
) -> Option<SignificanceMatrix> {
    if table.len() < 2 {
        return None;
    }
    let best = table[0].strategy.clone();
    // Per-scenario anchors for the signed-rank test: the catalog mixes
    // 7-client and 10k-client scenarios whose delays differ by orders
    // of magnitude, and Wilcoxon ranks |differences| — unnormalized,
    // the big scenarios would monopolize every top rank and the effect
    // size would ignore the small ones. Dividing both sides of a pair
    // by its scenario winner's mean makes the ranks scale-free (the
    // same anchor standings' `mean_ratio` uses); the sign test needs no
    // anchor because positive scaling never flips a sign. Degenerate
    // winners (zero/NaN) fall back to a neutral 1.0 anchor.
    let mut winner: std::collections::BTreeMap<&str, f64> = std::collections::BTreeMap::new();
    for c in cells {
        let w = winner.entry(&c.scenario).or_insert(f64::INFINITY);
        *w = w.min(c.best_delay);
    }
    let anchor = |scenario: &str| -> f64 {
        let w = winner[scenario];
        if w.is_finite() && w > 0.0 {
            w
        } else {
            1.0
        }
    };
    let delays_of = |strategy: &str, normalized: bool| -> Vec<f64> {
        cells
            .iter()
            .filter(|c| c.strategy == strategy)
            .flat_map(|c| {
                let div = if normalized { anchor(&c.scenario) } else { 1.0 };
                c.replicate_delays.iter().map(move |&d| d / div)
            })
            .collect()
    };
    let best_raw = delays_of(&best, false);
    let best_norm = delays_of(&best, true);
    let mut versus: Vec<VersusRow> = table[1..]
        .iter()
        .map(|s| VersusRow {
            strategy: s.strategy.clone(),
            sign: paired_sign_test(&best_raw, &delays_of(&s.strategy, false)),
            wilcoxon: wilcoxon_signed_rank(&best_norm, &delays_of(&s.strategy, true)),
            sign_p_holm: 1.0,
            wilcoxon_p_holm: 1.0,
        })
        .collect();
    // The rows form one family of simultaneous comparisons: adjust each
    // test's p-values across the rivals (Holm step-down).
    let sign_adj = holm_bonferroni(&versus.iter().map(|r| r.sign.p_value).collect::<Vec<_>>());
    let wilcoxon_adj =
        holm_bonferroni(&versus.iter().map(|r| r.wilcoxon.p_value).collect::<Vec<_>>());
    for (row, (s, w)) in versus.iter_mut().zip(sign_adj.into_iter().zip(wilcoxon_adj)) {
        row.sign_p_holm = s;
        row.wilcoxon_p_holm = w;
    }
    Some(SignificanceMatrix { best, versus })
}

/// `foo.csv` → `foo.sig.csv`: where the significance matrix lands next
/// to the cell matrix.
pub(crate) fn sig_csv_path(path: &Path) -> std::path::PathBuf {
    suffixed_csv_path(path, "sig")
}

/// `foo.csv` → `foo.effect.csv`: where the effect sizes land.
pub(crate) fn effect_csv_path(path: &Path) -> std::path::PathBuf {
    suffixed_csv_path(path, "effect")
}

fn suffixed_csv_path(path: &Path, tag: &str) -> std::path::PathBuf {
    let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("fleet");
    path.with_file_name(format!("{stem}.{tag}.csv"))
}

/// Print the ranked summary + significance matrix and (optionally)
/// write the matrix CSV plus `<out>.sig.csv` (sign-test rows, frozen
/// schema) and `<out>.effect.csv` (Wilcoxon + rank-biserial rows). The
/// CSVs contain only seed-deterministic columns, so identical seeds
/// produce byte-identical files regardless of thread count.
pub fn report_cells(cells: &[ExperimentCell], csv: Option<&Path>) -> std::io::Result<()> {
    let scenarios: std::collections::BTreeSet<&str> =
        cells.iter().map(|c| c.scenario.as_str()).collect();
    let rep_min = cells.iter().map(|c| c.replicate_delays.len()).min().unwrap_or(0);
    let rep_max = cells.iter().map(|c| c.replicate_delays.len()).max().unwrap_or(0);
    let rep_str = if rep_min == rep_max {
        format!("{rep_min}")
    } else {
        format!("{rep_min}..{rep_max} (adaptive)")
    };
    let total_evals: usize = cells.iter().map(|c| c.evaluations * c.replicate_delays.len()).sum();
    let total_events: u64 = cells.iter().map(|c| c.events).sum();
    println!(
        "experiment: {} scenarios × {} strategies × {} replicates = {} cells, {} evaluations, {} virtual events",
        scenarios.len(),
        cells.len() / scenarios.len().max(1),
        rep_str,
        cells.len(),
        total_evals,
        total_events,
    );
    println!("\n=== standings (by mean rank; delay ×best ± 95% CI) ===");
    println!(
        "{:<14} {:>10} {:>6} {:>10} {:>20}",
        "strategy", "mean rank", "wins", "regret ×", "delay ×best ± CI"
    );
    let table = standings(cells);
    for s in &table {
        println!(
            "{:<14} {:>10.2} {:>6} {:>10.3} {:>13.3} ± {:.3}",
            s.strategy, s.mean_rank, s.wins, s.regret, s.mean_ratio, s.ratio_ci
        );
    }
    let sig = significance_for(&table, cells);
    if let Some(sig) = &sig {
        println!(
            "\n=== significance: paired tests, {} vs each (n = {} scenario×replicate pairs) ===",
            sig.best,
            cells.iter().filter(|c| c.strategy == sig.best).map(|c| c.replicate_delays.len()).sum::<usize>(),
        );
        println!(
            "{:<14} {:>8} {:>8} {:>6} {:>10} {:>10} {:>12} {:>10} {:>9}",
            "vs strategy", "wins", "losses", "ties", "sign p", "sign holm", "wilcoxon p",
            "wilc holm", "effect r"
        );
        for row in &sig.versus {
            println!(
                "{:<14} {:>8} {:>8} {:>6} {:>10.6} {:>10.6} {:>12.6} {:>10.6} {:>+9.3}",
                row.strategy,
                row.sign.a_wins,
                row.sign.b_wins,
                row.sign.ties,
                row.sign.p_value,
                row.sign_p_holm,
                row.wilcoxon.p_value,
                row.wilcoxon_p_holm,
                row.wilcoxon.rank_biserial,
            );
        }
    }
    if let Some(path) = csv {
        let mut w = CsvWriter::create(
            path,
            &[
                "scenario", "strategy", "clients", "slots", "evaluations", "replicates",
                "best_delay_mean", "best_delay_ci95", "mean_delay", "rank",
            ],
        )?;
        for c in cells {
            w.write_row(&[
                c.scenario.clone(),
                c.strategy.clone(),
                c.clients.to_string(),
                c.slots.to_string(),
                c.evaluations.to_string(),
                c.replicate_delays.len().to_string(),
                format!("{:.9}", c.best_delay),
                format!("{:.9}", c.ci95),
                format!("{:.9}", c.mean_delay),
                c.rank.to_string(),
            ])?;
        }
        w.flush()?;
        println!("matrix CSV: {}", path.display());
        if let Some(sig) = &sig {
            let sig_path = sig_csv_path(path);
            let mut w = CsvWriter::create(
                &sig_path,
                &["best_strategy", "vs_strategy", "best_wins", "losses", "ties", "p_value"],
            )?;
            for row in &sig.versus {
                w.write_row(&[
                    sig.best.clone(),
                    row.strategy.clone(),
                    row.sign.a_wins.to_string(),
                    row.sign.b_wins.to_string(),
                    row.sign.ties.to_string(),
                    format!("{:.6}", row.sign.p_value),
                ])?;
            }
            w.flush()?;
            println!("significance CSV: {}", sig_path.display());
            let effect_path = effect_csv_path(path);
            let mut w = CsvWriter::create(
                &effect_path,
                &[
                    "best_strategy", "vs_strategy", "pairs", "w_plus", "w_minus",
                    "wilcoxon_p", "effect_size",
                ],
            )?;
            for row in &sig.versus {
                w.write_row(&[
                    sig.best.clone(),
                    row.strategy.clone(),
                    row.wilcoxon.n.to_string(),
                    format!("{:.1}", row.wilcoxon.w_plus),
                    format!("{:.1}", row.wilcoxon.w_minus),
                    format!("{:.6}", row.wilcoxon.p_value),
                    format!("{:.6}", row.wilcoxon.rank_biserial),
                ])?;
            }
            w.flush()?;
            println!("effect-size CSV: {}", effect_path.display());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A synthetic cell for standings-level tests.
    pub(crate) fn synthetic_cell(
        scenario: &str,
        strategy: &str,
        delays: &[f64],
        rank: usize,
    ) -> ExperimentCell {
        let ci = mean_ci(delays);
        ExperimentCell {
            scenario: scenario.into(),
            strategy: strategy.into(),
            clients: 7,
            slots: 3,
            evaluations: 10,
            replicate_delays: delays.to_vec(),
            best_delay: ci.mean,
            ci95: ci.half_width,
            mean_delay: ci.mean,
            events: 0,
            rank,
        }
    }

    #[test]
    fn standings_regret_survives_zero_and_nan_winner_delays() {
        // A degenerate scenario whose winner delay is 0 (or NaN) must
        // not poison the geometric regret into -inf/NaN: those terms
        // collapse to the neutral 1.0 and the sort stays meaningful.
        let cells = vec![
            synthetic_cell("zero", "alpha", &[0.0, 0.0], 1),
            synthetic_cell("zero", "beta", &[2.0, 2.0], 2),
            synthetic_cell("nan", "alpha", &[f64::NAN], 2),
            synthetic_cell("nan", "beta", &[1.0], 1),
            synthetic_cell("sane", "alpha", &[1.0], 1),
            synthetic_cell("sane", "beta", &[3.0], 2),
        ];
        let table = standings(&cells);
        assert_eq!(table.len(), 2);
        for s in &table {
            assert!(s.regret.is_finite(), "{}: regret {}", s.strategy, s.regret);
            assert!(s.regret >= 1.0 - 1e-12, "{}: regret {}", s.strategy, s.regret);
            assert!(s.mean_ratio.is_finite(), "{}: ratio {}", s.strategy, s.mean_ratio);
        }
        // alpha's only usable regret term is the "sane" win (ratio 1);
        // beta's is 3× — beta carries the larger regret.
        let by_name = |n: &str| table.iter().find(|s| s.strategy == n).unwrap();
        assert!(by_name("beta").regret > by_name("alpha").regret);
    }

    #[test]
    fn significance_matrix_pairs_best_against_each() {
        // beta strictly faster on all 6 (scenario, replicate) pairs but
        // one: sign test must see 5 wins, 1 loss, and the signed-rank
        // effect must point beta's way.
        let cells = vec![
            synthetic_cell("s1", "alpha", &[2.0, 3.0, 4.0], 2),
            synthetic_cell("s1", "beta", &[1.0, 2.0, 3.0], 1),
            synthetic_cell("s2", "alpha", &[1.0, 5.0, 6.0], 2),
            synthetic_cell("s2", "beta", &[1.5, 4.0, 5.0], 1),
        ];
        let sig = significance_matrix(&cells).expect("two strategies");
        assert_eq!(sig.best, "beta");
        assert_eq!(sig.versus.len(), 1);
        let row = &sig.versus[0];
        assert_eq!(row.strategy, "alpha");
        assert_eq!((row.sign.a_wins, row.sign.b_wins, row.sign.ties), (5, 1, 0));
        assert!(row.sign.p_value > 0.0 && row.sign.p_value <= 1.0);
        assert_eq!(row.wilcoxon.n, 6);
        assert!(row.wilcoxon.rank_biserial > 0.0, "best must carry a positive effect");
        assert!(row.wilcoxon.p_value > 0.0 && row.wilcoxon.p_value <= 1.0);
        // One strategy ⇒ no matrix.
        assert!(significance_matrix(&cells[..1]).is_none());
    }

    #[test]
    fn significance_matrix_carries_holm_adjusted_p_values() {
        // Three rivals ⇒ a family of three simultaneous comparisons:
        // every adjusted p must dominate its raw p, stay in [0, 1], and
        // the smallest raw sign p must carry the full ×3 factor.
        let mut cells = Vec::new();
        for s in ["s1", "s2", "s3"] {
            cells.push(synthetic_cell(s, "best", &[1.0, 1.1, 1.2], 1));
            cells.push(synthetic_cell(s, "mid", &[2.0, 2.1, 2.2], 2));
            cells.push(synthetic_cell(s, "bad", &[3.0, 3.1, 3.2], 3));
            cells.push(synthetic_cell(s, "worse", &[4.0, 4.1, 4.2], 4));
        }
        let sig = significance_matrix(&cells).expect("four strategies");
        assert_eq!(sig.versus.len(), 3);
        let raw: Vec<f64> = sig.versus.iter().map(|r| r.sign.p_value).collect();
        let adj: Vec<f64> = sig.versus.iter().map(|r| r.sign_p_holm).collect();
        assert_eq!(adj, crate::metrics::holm_bonferroni(&raw));
        for row in &sig.versus {
            assert!(row.sign_p_holm >= row.sign.p_value - 1e-15);
            assert!((0.0..=1.0).contains(&row.sign_p_holm));
            assert!(row.wilcoxon_p_holm >= row.wilcoxon.p_value - 1e-15);
            assert!((0.0..=1.0).contains(&row.wilcoxon_p_holm));
        }
        // All three rivals lose all 9 pairs: equal raw p, so every
        // adjusted value is the shared Holm maximum m·p of the family.
        assert!((adj[0] - (3.0 * raw[0]).min(1.0)).abs() < 1e-12, "{adj:?} vs {raw:?}");
    }

    #[test]
    fn report_writes_the_effect_csv_next_to_matrix_and_sig() {
        let cells = vec![
            synthetic_cell("s1", "alpha", &[2.0, 3.0], 2),
            synthetic_cell("s1", "beta", &[1.0, 2.0], 1),
        ];
        let dir = std::env::temp_dir().join("repro_exp_report_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("out.csv");
        report_cells(&cells, Some(&path)).unwrap();
        let matrix = std::fs::read_to_string(&path).unwrap();
        let sig = std::fs::read_to_string(sig_csv_path(&path)).unwrap();
        let effect = std::fs::read_to_string(effect_csv_path(&path)).unwrap();
        // Frozen schemas for matrix + sig; the effect CSV is the new
        // home of the Wilcoxon columns.
        assert!(matrix.starts_with(
            "scenario,strategy,clients,slots,evaluations,replicates,\
             best_delay_mean,best_delay_ci95,mean_delay,rank"
        ));
        assert!(sig.starts_with("best_strategy,vs_strategy,best_wins,losses,ties,p_value"));
        assert!(effect.starts_with(
            "best_strategy,vs_strategy,pairs,w_plus,w_minus,wilcoxon_p,effect_size"
        ));
        assert_eq!(effect.lines().count(), 2);
        // Deterministic: a second report produces identical bytes.
        report_cells(&cells, Some(&path)).unwrap();
        assert_eq!(effect, std::fs::read_to_string(effect_csv_path(&path)).unwrap());
    }
}
