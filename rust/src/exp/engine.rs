//! The experiment engine: turn an [`ExperimentPlan`] into scheduled
//! replicate trials and aggregate them into ranked cells.
//!
//! ## Adaptive replicate allocation
//!
//! With an adaptive range (`--replicates MIN..MAX`) the engine first
//! runs `MIN` replicates for every cell, then adds one replicate at a
//! time *per scenario* (every strategy in the scenario advances
//! together, keeping the trials paired) until either
//!
//! * the scenario's leader separates: the leader's 95% CI upper bound
//!   lies strictly below every rival's CI lower bound on the replicate
//!   means, or
//! * `MAX` replicates have been spent.
//!
//! The stop rule reads only *completed* replicate sets — batch
//! composition is a pure function of prior results, and every trial
//! derives its randomness from `(scenario seed, replicate)` — so the
//! allocation (and therefore every CSV byte) is independent of
//! `--threads`. With `MIN == MAX` the engine degenerates to the fixed
//! `--replicates R` fleet semantics, job for job.

use super::plan::{replicate_seed, ExperimentPlan};
use super::report::ExperimentCell;
use super::scheduler::TrialScheduler;
use super::trial::{run_cell_trial, TrialOutcome};
use crate::metrics::{mean_ci, rank_ascending};
use crate::placement::PlacementError;

/// Does the leader's 95% CI separate from every rival's? `sets` holds
/// one replicate-delay vector per strategy (a scenario's row). With a
/// single strategy there is no rival to separate from, so the answer is
/// vacuously true (the allocator stops at `min`). Sets with fewer than
/// two replicates have degenerate zero-width CIs that say nothing
/// about variance — they never separate, so a `--replicates 1..N`
/// range always spends at least two replicates before stopping instead
/// of degenerating back into the single-seed lottery. Non-finite means
/// never separate either — such a scenario runs to `max` and is
/// surfaced by the report instead of being silently truncated.
pub(crate) fn ci_separated(sets: &[Vec<f64>]) -> bool {
    if sets.len() < 2 {
        return true;
    }
    if sets.iter().any(|s| s.len() < 2) {
        return false;
    }
    let cis: Vec<_> = sets.iter().map(|s| mean_ci(s)).collect();
    let leader = match (0..cis.len()).min_by(|&a, &b| cis[a].mean.total_cmp(&cis[b].mean)) {
        Some(i) => i,
        None => return true,
    };
    if !cis[leader].mean.is_finite() {
        return false;
    }
    cis.iter().enumerate().all(|(i, rival)| {
        i == leader
            || cis[leader].mean + cis[leader].half_width < rival.mean - rival.half_width
    })
}

/// Run the plan's full cell grid through `sched`. The returned vector
/// is ordered scenario-major (plan order) with per-scenario competition
/// ranks (on replicate means) filled in.
pub fn run_plan(
    plan: &ExperimentPlan,
    sched: &TrialScheduler,
) -> Result<Vec<ExperimentCell>, PlacementError> {
    plan.validate()?;
    let n_sc = plan.scenarios.len();
    let n_st = plan.strategies.len();
    let (rmin, rmax) = (plan.replicates.min, plan.replicates.max);
    // runs[si * n_st + ti] = completed replicate outcomes, in replicate
    // order.
    let mut runs: Vec<Vec<TrialOutcome>> = (0..n_sc * n_st).map(|_| Vec::new()).collect();
    let mut active = vec![true; n_sc];
    // Replicates completed so far per scenario (uniform across its
    // strategies — the pairing invariant).
    let mut done = vec![0usize; n_sc];
    loop {
        // Batch: bring every active scenario up to `min`, then advance
        // one replicate at a time. Job order is scenario-major with the
        // replicate index innermost — identical to the fixed-R fleet.
        let mut jobs: Vec<(usize, usize, usize)> = Vec::new();
        for si in 0..n_sc {
            if !active[si] {
                continue;
            }
            let target = if done[si] == 0 { rmin } else { done[si] + 1 };
            for ti in 0..n_st {
                for r in done[si]..target {
                    jobs.push((si, ti, r));
                }
            }
        }
        if jobs.is_empty() {
            break;
        }
        let results = sched.run(jobs.len(), |j| {
            let (si, ti, r) = jobs[j];
            let ns = &plan.scenarios[si];
            let mut sc = ns.sim.clone();
            sc.seed = replicate_seed(ns.sim.seed, r);
            let env = plan.env_of(ns).to_string();
            run_cell_trial(&sc, &plan.strategies[ti], &env, plan.evals, false)
        });
        // Collect in job order (first error wins deterministically).
        for (&(si, ti, _), res) in jobs.iter().zip(results) {
            runs[si * n_st + ti].push(res?);
        }
        for si in 0..n_sc {
            if !active[si] {
                continue;
            }
            done[si] = if done[si] == 0 { rmin } else { done[si] + 1 };
            if done[si] >= rmax {
                active[si] = false;
                continue;
            }
            let sets: Vec<Vec<f64>> = (0..n_st)
                .map(|ti| runs[si * n_st + ti].iter().map(|t| t.best_delay).collect())
                .collect();
            if ci_separated(&sets) {
                active[si] = false;
            }
        }
        if active.iter().all(|a| !a) {
            break;
        }
    }

    // Aggregate replicate runs into cells (scenario-major).
    let mut cells = Vec::with_capacity(n_sc * n_st);
    for (si, ns) in plan.scenarios.iter().enumerate() {
        for ti in 0..n_st {
            let set = &runs[si * n_st + ti];
            let replicate_delays: Vec<f64> = set.iter().map(|t| t.best_delay).collect();
            let ci = mean_ci(&replicate_delays);
            debug_assert!(set.iter().all(|t| t.evaluations == set[0].evaluations));
            cells.push(ExperimentCell {
                scenario: ns.name.clone(),
                strategy: set[0].strategy.clone(),
                clients: ns.sim.client_count(),
                slots: ns.sim.dimensions(),
                evaluations: set[0].evaluations,
                best_delay: ci.mean,
                ci95: ci.half_width,
                mean_delay: set.iter().map(|t| t.mean_delay).sum::<f64>() / set.len() as f64,
                events: set.iter().map(|t| t.events).sum(),
                replicate_delays,
                rank: 0,
            });
        }
    }
    // Rank strategies within each scenario on their replicate means.
    for chunk in cells.chunks_mut(n_st) {
        let delays: Vec<f64> = chunk.iter().map(|c| c.best_delay).collect();
        for (cell, rank) in chunk.iter_mut().zip(rank_ascending(&delays)) {
            cell.rank = rank;
        }
    }
    Ok(cells)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configio::SimScenario;
    use crate::des::NamedScenario;
    use crate::exp::ReplicateRange;

    fn tiny_plan(strategies: &[&str], replicates: ReplicateRange) -> ExperimentPlan {
        let mut a = SimScenario {
            depth: 2,
            width: 2,
            env: "event-driven".into(),
            ..SimScenario::default()
        };
        a.pso.particles = 3;
        a.pso.iterations = 5;
        let mut b = a.clone();
        b.seed = 9;
        b.des.dynamics.dropout_prob = 0.2;
        ExperimentPlan {
            scenarios: vec![
                NamedScenario { name: "a".into(), sim: a },
                NamedScenario { name: "b-dropout".into(), sim: b },
            ],
            strategies: strategies.iter().map(|s| s.to_string()).collect(),
            evals: Some(10),
            env_override: None,
            replicates,
        }
    }

    #[test]
    fn ci_separation_rule() {
        // Far-apart tight sets separate.
        assert!(ci_separated(&[vec![1.0, 1.1, 0.9], vec![9.0, 9.1, 8.9]]));
        // Overlapping intervals do not.
        assert!(!ci_separated(&[vec![1.0, 5.0, 3.0], vec![3.5, 6.0, 2.0]]));
        // The leader must clear EVERY rival.
        assert!(!ci_separated(&[
            vec![1.0, 1.1, 0.9],
            vec![1.05, 1.15, 0.95],
            vec![9.0, 9.1, 8.9],
        ]));
        // Identical means never separate (equal leader and rival).
        assert!(!ci_separated(&[vec![2.0, 2.0], vec![2.0, 2.0]]));
        // Single replicates have degenerate zero-width CIs that carry
        // no variance information: never separated, so a 1..N range
        // always spends a second replicate.
        assert!(!ci_separated(&[vec![1.0], vec![2.0]]));
        assert!(!ci_separated(&[vec![1.0, 1.1], vec![9.0]]));
        // One strategy: vacuously separated (no rival to resolve).
        assert!(ci_separated(&[vec![1.0, 2.0]]));
        // Non-finite leader means never separate.
        assert!(!ci_separated(&[vec![f64::NAN, f64::NAN], vec![1.0, 1.2]]));
    }

    #[test]
    fn adaptive_counts_stay_in_range_uniform_and_thread_independent() {
        let plan = tiny_plan(&["pso", "random"], ReplicateRange { min: 2, max: 6 });
        let one = run_plan(&plan, &TrialScheduler::new(1)).unwrap();
        let many = run_plan(&plan, &TrialScheduler::new(8)).unwrap();
        assert_eq!(one, many, "allocation must not depend on thread count");
        for chunk in one.chunks(2) {
            let used: Vec<usize> = chunk.iter().map(|c| c.replicate_delays.len()).collect();
            assert!(used.iter().all(|&u| (2..=6).contains(&u)), "{used:?}");
            assert_eq!(used[0], used[1], "paired strategies must share the count");
        }
    }

    #[test]
    fn min_one_adaptive_ranges_still_spend_two_replicates() {
        // --replicates 1..N must not collapse into the single-seed
        // lottery: a 1-replicate set has a zero-width CI that proves
        // nothing, so every scenario buys a second replicate first.
        let plan = tiny_plan(&["pso", "random"], ReplicateRange { min: 1, max: 5 });
        let cells = run_plan(&plan, &TrialScheduler::new(2)).unwrap();
        assert!(cells.iter().all(|c| (2..=5).contains(&c.replicate_delays.len())));
    }

    #[test]
    fn single_strategy_plans_stop_at_min() {
        let plan = tiny_plan(&["random"], ReplicateRange { min: 2, max: 9 });
        let cells = run_plan(&plan, &TrialScheduler::new(2)).unwrap();
        assert!(cells.iter().all(|c| c.replicate_delays.len() == 2));
    }

    #[test]
    fn fixed_range_matches_min_equals_max_adaptive_degenerate() {
        let fixed = tiny_plan(&["pso", "random"], ReplicateRange::fixed(3));
        let degen = tiny_plan(&["pso", "random"], ReplicateRange { min: 3, max: 3 });
        assert_eq!(
            run_plan(&fixed, &TrialScheduler::new(2)).unwrap(),
            run_plan(&degen, &TrialScheduler::new(4)).unwrap(),
        );
    }

    #[test]
    fn env_override_pins_the_oracle_for_every_cell() {
        let mut plan = tiny_plan(&["random"], ReplicateRange::fixed(1));
        // Scenario env is event-driven (events > 0); overriding to
        // analytic must silence the simulator for every cell.
        plan.env_override = Some("analytic".into());
        let cells = run_plan(&plan, &TrialScheduler::new(1)).unwrap();
        assert!(cells.iter().all(|c| c.events == 0));
        plan.env_override = None;
        let cells = run_plan(&plan, &TrialScheduler::new(1)).unwrap();
        assert!(cells.iter().all(|c| c.events > 0));
    }
}
