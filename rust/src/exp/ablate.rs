//! Per-mechanism ablation: `repro ablate` materializes one-mechanism-off
//! variants of a dynamic scenario and reports how much round delay each
//! mechanism contributes, with paired 95% CIs.
//!
//! Every variant keeps the scenario's seed, so replicate `r` of the
//! baseline and of each variant share the identical population and (up
//! to the mechanism's own RNG draws) the same dynamics process — the
//! per-replicate deltas are paired differences, and their Student-t CI
//! is the honest error bar on the mechanism's contribution. A mechanism
//! that was never enabled produces a byte-identical variant, so its
//! delta is exactly zero (and a warning is logged).

use super::engine::run_plan;
use super::plan::{ExperimentPlan, ReplicateRange};
use super::scheduler::TrialScheduler;
use crate::des::scenarios::{disable_mechanism, mechanism_enabled, MECHANISMS};
use crate::des::NamedScenario;
use crate::log_warn;
use crate::metrics::{mean_ci, CsvWriter, MeanCi};
use crate::placement::PlacementError;
use std::path::Path;

/// One mechanism's measured contribution to the scenario's delay.
#[derive(Debug, Clone, PartialEq)]
pub struct MechanismEffect {
    /// Registry key (`dynamics.corr_fail`, `net.asym`, ...).
    pub mechanism: String,
    /// Whether the scenario had the mechanism switched on (off ⇒ the
    /// ablated variant is byte-identical and the delta is exactly 0).
    pub enabled: bool,
    /// Replicate mean ± 95% CI with the mechanism removed.
    pub ablated: MeanCi,
    /// Paired per-replicate `baseline − ablated` differences, mean ±
    /// 95% CI. Positive = the mechanism slows the round.
    pub delta: MeanCi,
}

/// The full ablation study over one scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct AblationOutcome {
    pub scenario: String,
    pub strategy: String,
    pub evaluations: usize,
    pub replicates: usize,
    /// Replicate mean ± 95% CI of the untouched scenario.
    pub baseline: MeanCi,
    pub effects: Vec<MechanismEffect>,
}

/// Ablation parameters.
#[derive(Debug, Clone)]
pub struct AblationConfig {
    /// Registry strategy evaluated under every variant.
    pub strategy: String,
    /// Evaluation budget override per replicate.
    pub evals: Option<usize>,
    /// Paired replicates per variant (fixed — the adaptive allocator's
    /// leader-vs-rivals rule has no meaning across variants).
    pub replicates: usize,
}

impl Default for AblationConfig {
    fn default() -> Self {
        AblationConfig { strategy: "pso".into(), evals: None, replicates: 3 }
    }
}

/// The mechanism keys enabled in a scenario — the default `--mechanisms`
/// set for `repro ablate`.
pub fn enabled_mechanisms(ns: &NamedScenario) -> Vec<String> {
    MECHANISMS
        .iter()
        .filter(|(k, _)| mechanism_enabled(&ns.sim.des, k).unwrap_or(false))
        .map(|(k, _)| k.to_string())
        .collect()
}

/// Run the ablation: baseline + one variant per mechanism, all through
/// the experiment engine (one plan, one scheduler), then fold the cells
/// into per-mechanism paired deltas.
pub fn run_ablation(
    ns: &NamedScenario,
    mechanisms: &[String],
    cfg: &AblationConfig,
    sched: &TrialScheduler,
) -> Result<AblationOutcome, PlacementError> {
    if mechanisms.is_empty() {
        return Err(PlacementError::Environment(format!(
            "nothing to ablate in scenario {:?}: no mechanisms requested and none enabled \
             (pass --mechanisms, e.g. --mechanisms dynamics.dropout,net.jitter)",
            ns.name
        )));
    }
    let mut scenarios = vec![ns.clone()];
    let mut enabled_flags = Vec::with_capacity(mechanisms.len());
    let mut seen: Vec<&str> = Vec::with_capacity(mechanisms.len());
    for key in mechanisms {
        if seen.contains(&key.as_str()) {
            // A repeated key would double the trial cost and emit two
            // identically-named variants/rows.
            return Err(PlacementError::Environment(format!(
                "mechanism {key:?} listed more than once"
            )));
        }
        seen.push(key);
        let enabled = mechanism_enabled(&ns.sim.des, key)
            .map_err(PlacementError::Environment)?;
        if !enabled {
            log_warn!(
                "ablate",
                "mechanism {key} is not enabled in scenario {:?}; its delta will be exactly 0",
                ns.name
            );
        }
        let mut variant = ns.clone();
        variant.name = format!("{}-no-{key}", ns.name);
        disable_mechanism(&mut variant.sim.des, key).map_err(PlacementError::Environment)?;
        scenarios.push(variant);
        enabled_flags.push(enabled);
    }
    let plan = ExperimentPlan {
        scenarios,
        strategies: vec![cfg.strategy.clone()],
        evals: cfg.evals,
        env_override: None,
        replicates: ReplicateRange::fixed(cfg.replicates),
    };
    let cells = run_plan(&plan, sched)?;
    let baseline = &cells[0];
    let effects = mechanisms
        .iter()
        .zip(&enabled_flags)
        .zip(&cells[1..])
        .map(|((key, &enabled), cell)| {
            let deltas: Vec<f64> = baseline
                .replicate_delays
                .iter()
                .zip(&cell.replicate_delays)
                .map(|(b, a)| b - a)
                .collect();
            MechanismEffect {
                mechanism: key.clone(),
                enabled,
                ablated: mean_ci(&cell.replicate_delays),
                delta: mean_ci(&deltas),
            }
        })
        .collect();
    Ok(AblationOutcome {
        scenario: ns.name.clone(),
        strategy: baseline.strategy.clone(),
        evaluations: baseline.evaluations,
        replicates: baseline.replicate_delays.len(),
        baseline: mean_ci(&baseline.replicate_delays),
        effects,
    })
}

/// Print the ablation table and optionally persist it as CSV. Rows are
/// deterministic per scenario seed and independent of the thread count.
pub fn report_ablation(out: &AblationOutcome, csv: Option<&Path>) -> std::io::Result<()> {
    println!(
        "ablation: scenario {} · strategy {} · {} replicates × {} evaluations",
        out.scenario, out.strategy, out.replicates, out.evaluations
    );
    println!(
        "baseline delay: {:.6} ± {:.6} (95% CI over replicate bests)\n",
        out.baseline.mean, out.baseline.half_width
    );
    println!(
        "{:<22} {:>22} {:>22} {:>9}",
        "mechanism off", "ablated delay ± CI", "delta ± CI", "share"
    );
    for e in &out.effects {
        let share = if out.baseline.mean != 0.0 {
            format!("{:>+8.1}%", 100.0 * e.delta.mean / out.baseline.mean)
        } else {
            "       -".to_string()
        };
        let tag = if e.enabled { "" } else { "  (mechanism was off)" };
        println!(
            "{:<22} {:>12.6} ± {:>7.6} {:>12.6} ± {:>7.6} {share}{tag}",
            e.mechanism,
            e.ablated.mean,
            e.ablated.half_width,
            e.delta.mean,
            e.delta.half_width,
        );
    }
    if let Some(path) = csv {
        let mut w = CsvWriter::create(
            path,
            &[
                "scenario", "strategy", "mechanism", "enabled", "replicates",
                "baseline_mean", "baseline_ci95", "ablated_mean", "ablated_ci95",
                "delta_mean", "delta_ci95", "delta_pct",
            ],
        )?;
        for e in &out.effects {
            let pct = if out.baseline.mean != 0.0 {
                100.0 * e.delta.mean / out.baseline.mean
            } else {
                f64::NAN
            };
            w.write_row(&[
                out.scenario.clone(),
                out.strategy.clone(),
                e.mechanism.clone(),
                e.enabled.to_string(),
                out.replicates.to_string(),
                format!("{:.9}", out.baseline.mean),
                format!("{:.9}", out.baseline.half_width),
                format!("{:.9}", e.ablated.mean),
                format!("{:.9}", e.ablated.half_width),
                format!("{:.9}", e.delta.mean),
                format!("{:.9}", e.delta.half_width),
                format!("{:.6}", pct),
            ])?;
        }
        w.flush()?;
        println!("\nablation CSV: {}", path.display());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::builtin_catalog;

    fn builtin(name: &str) -> NamedScenario {
        builtin_catalog().into_iter().find(|s| s.name == name).unwrap()
    }

    #[test]
    fn ablation_reports_paired_deltas_with_cis_on_a_builtin_scenario() {
        // The acceptance scenario: a real catalog entry, one enabled
        // mechanism, per-mechanism deltas with 95% CIs.
        let ns = builtin("tiny-straggler");
        let cfg = AblationConfig { evals: Some(30), replicates: 4, ..AblationConfig::default() };
        let out = run_ablation(
            &ns,
            &["dynamics.straggler".to_string()],
            &cfg,
            &TrialScheduler::new(2),
        )
        .unwrap();
        assert_eq!(out.scenario, "tiny-straggler");
        assert_eq!(out.strategy, "pso");
        assert_eq!(out.replicates, 4);
        assert!(out.baseline.mean.is_finite() && out.baseline.mean > 0.0);
        assert_eq!(out.effects.len(), 1);
        let e = &out.effects[0];
        assert!(e.enabled);
        assert!(e.ablated.mean.is_finite() && e.ablated.mean > 0.0);
        assert!(e.delta.mean.is_finite());
        assert!(e.delta.half_width.is_finite() && e.delta.half_width >= 0.0);
        // Deterministic and thread-count independent.
        let again = run_ablation(
            &ns,
            &["dynamics.straggler".to_string()],
            &cfg,
            &TrialScheduler::new(1),
        )
        .unwrap();
        assert_eq!(out, again);
    }

    #[test]
    fn disabled_mechanisms_yield_exactly_zero_deltas() {
        // Ablating a mechanism the scenario never enabled produces a
        // byte-identical variant: same seeds, same trials, delta == 0.
        let ns = builtin("tiny-static");
        let cfg = AblationConfig { evals: Some(20), replicates: 3, ..AblationConfig::default() };
        let out = run_ablation(
            &ns,
            &["dynamics.corr_fail".to_string()],
            &cfg,
            &TrialScheduler::new(2),
        )
        .unwrap();
        let e = &out.effects[0];
        assert!(!e.enabled);
        assert_eq!(e.delta.mean, 0.0);
        assert_eq!(e.delta.half_width, 0.0);
        assert_eq!(e.ablated.mean, out.baseline.mean);
    }

    #[test]
    fn enabled_mechanisms_default_and_empty_request_error() {
        let ns = builtin("tiny-dropout");
        assert_eq!(enabled_mechanisms(&ns), vec!["dynamics.dropout".to_string()]);
        let none = enabled_mechanisms(&builtin("tiny-static"));
        assert!(none.is_empty());
        let err = run_ablation(&ns, &[], &AblationConfig::default(), &TrialScheduler::new(1))
            .unwrap_err();
        assert!(err.to_string().contains("nothing to ablate"), "{err}");
        // Unknown mechanism keys are typed, actionable errors.
        let err = run_ablation(
            &ns,
            &["dynamics.gremlins".to_string()],
            &AblationConfig { evals: Some(5), replicates: 1, ..AblationConfig::default() },
            &TrialScheduler::new(1),
        )
        .unwrap_err();
        assert!(err.to_string().contains("valid mechanisms"), "{err}");
        // Repeated keys are rejected before any trial runs.
        let err = run_ablation(
            &ns,
            &["dynamics.dropout".to_string(), "dynamics.dropout".to_string()],
            &AblationConfig { evals: Some(5), replicates: 1, ..AblationConfig::default() },
            &TrialScheduler::new(1),
        )
        .unwrap_err();
        assert!(err.to_string().contains("more than once"), "{err}");
    }

    #[test]
    fn report_ablation_writes_deterministic_csv() {
        let ns = builtin("tiny-dropout");
        let cfg = AblationConfig { evals: Some(20), replicates: 3, ..AblationConfig::default() };
        let out =
            run_ablation(&ns, &enabled_mechanisms(&ns), &cfg, &TrialScheduler::new(2)).unwrap();
        let dir = std::env::temp_dir().join("repro_exp_ablate_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("ablate.csv");
        report_ablation(&out, Some(&path)).unwrap();
        let first = std::fs::read_to_string(&path).unwrap();
        report_ablation(&out, Some(&path)).unwrap();
        assert_eq!(first, std::fs::read_to_string(&path).unwrap());
        assert!(first.lines().next().unwrap().contains("delta_ci95"));
        assert_eq!(first.lines().count(), 1 + out.effects.len());
    }
}
