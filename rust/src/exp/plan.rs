//! Experiment plans: the declarative description of a comparison —
//! which scenarios, which strategies, which delay oracle, how many
//! replicates — that the engine turns into scheduled trials.
//!
//! A plan's cell grid is scenario × strategy × replicate; the
//! environment axis rides on each scenario (`sim.env`) unless
//! [`ExperimentPlan::env_override`] pins one oracle for the whole plan.
//! Replicate seeds are derived from the scenario seed only (SplitMix64
//! stream), so within a scenario every strategy faces the identical
//! population/network/dynamics process per replicate — paired trials.

use crate::des::NamedScenario;
use crate::placement::{registry, PlacementError};
use crate::prng::SplitMix64;

/// Inclusive replicate budget `[min, max]` per (scenario, strategy)
/// cell. `min == max` is a fixed count (the classic `--replicates R`);
/// `min < max` enables the adaptive allocator: the engine runs `min`
/// replicates, then adds one replicate at a time to a scenario until
/// the leader's 95% CI separates from every rival or `max` is reached.
///
/// CLI syntax: `R` (fixed) or `MIN..MAX` (adaptive, **inclusive** of
/// `MAX` — this is a replicate budget, not a Rust range).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplicateRange {
    pub min: usize,
    pub max: usize,
}

impl ReplicateRange {
    /// A fixed replicate count (0 and 1 both mean a single run, the
    /// historical `FleetConfig::replicates` contract).
    pub fn fixed(r: usize) -> ReplicateRange {
        let r = r.max(1);
        ReplicateRange { min: r, max: r }
    }

    /// Whether the range is a single fixed count (no adaptation).
    pub fn is_fixed(&self) -> bool {
        self.min == self.max
    }

    /// Parse the CLI syntax: `"5"` or `"2..10"` (inclusive).
    pub fn parse(s: &str) -> Result<ReplicateRange, String> {
        let parse_one = |tok: &str| -> Result<usize, String> {
            tok.trim()
                .parse::<usize>()
                .map_err(|_| format!("--replicates: expected integer, got {tok:?}"))
        };
        match s.split_once("..") {
            None => Ok(ReplicateRange::fixed(parse_one(s)?)),
            Some((lo, hi)) => {
                let min = parse_one(lo)?.max(1);
                let max = parse_one(hi)?;
                if max < min {
                    return Err(format!(
                        "--replicates: empty range {s:?} (max {max} < min {min}; \
                         the syntax is MIN..MAX, inclusive)"
                    ));
                }
                Ok(ReplicateRange { min, max })
            }
        }
    }
}

/// One experiment: the full cell grid the engine will schedule.
#[derive(Debug, Clone)]
pub struct ExperimentPlan {
    /// Scenarios (catalog order is report order).
    pub scenarios: Vec<NamedScenario>,
    /// Registry strategy names (aliases accepted, duplicates rejected).
    pub strategies: Vec<String>,
    /// Evaluation budget override per replicate (None = each scenario's
    /// `pso.iterations × pso.particles`).
    pub evals: Option<usize>,
    /// Delay oracle override for every cell (None = each scenario's
    /// `sim.env`).
    pub env_override: Option<String>,
    /// Replicates per cell (fixed or adaptive).
    pub replicates: ReplicateRange,
}

impl ExperimentPlan {
    /// Fail fast on a typo or an empty grid before paying for
    /// simulations: at least one scenario and strategy, no
    /// alias-duplicated strategies (they would double-count cells and
    /// desync the paired significance series), and every environment
    /// name resolvable.
    pub fn validate(&self) -> Result<(), PlacementError> {
        if self.scenarios.is_empty() || self.strategies.is_empty() {
            return Err(PlacementError::Environment(
                "experiment plan is empty: need at least one scenario and one strategy".into(),
            ));
        }
        let mut canon: Vec<&'static str> = Vec::with_capacity(self.strategies.len());
        for s in &self.strategies {
            let c = registry::canonical(s)?;
            if canon.contains(&c) {
                return Err(PlacementError::DuplicateStrategy { name: s.clone() });
            }
            canon.push(c);
        }
        if let Some(env) = &self.env_override {
            registry::canonical_env(env)?;
        } else {
            for ns in &self.scenarios {
                registry::canonical_env(&ns.sim.env)?;
            }
        }
        if self.replicates.min == 0 || self.replicates.max < self.replicates.min {
            return Err(PlacementError::Environment(format!(
                "bad replicate range {}..{}: need 1 <= min <= max",
                self.replicates.min, self.replicates.max
            )));
        }
        Ok(())
    }

    /// The environment name cell (si) runs under.
    pub fn env_of(&self, scenario: &NamedScenario) -> &str {
        self.env_override.as_deref().unwrap_or(&scenario.sim.env)
    }
}

/// Derive the seed for replicate `r` of a scenario. Replicate 0 keeps
/// the scenario's own seed, so `--replicates 1` reproduces the
/// single-run fleet byte for byte; later replicates walk a SplitMix64
/// stream salted off the scenario seed. Strategy-independent by
/// construction: candidates within a scenario compete under identical
/// realizations each replicate.
pub fn replicate_seed(base: u64, r: usize) -> u64 {
    if r == 0 {
        return base;
    }
    let mut sm = SplitMix64::new(base ^ 0xF1EE_7C0D_ED5E_ED5Eu64);
    let mut seed = 0u64;
    for _ in 0..r {
        seed = sm.next();
    }
    seed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configio::SimScenario;

    fn plan_of(strategies: &[&str]) -> ExperimentPlan {
        ExperimentPlan {
            scenarios: vec![NamedScenario {
                name: "t".into(),
                sim: SimScenario { depth: 2, width: 2, ..SimScenario::default() },
            }],
            strategies: strategies.iter().map(|s| s.to_string()).collect(),
            evals: None,
            env_override: None,
            replicates: ReplicateRange::fixed(1),
        }
    }

    #[test]
    fn replicate_range_parses_fixed_and_adaptive() {
        assert_eq!(ReplicateRange::parse("5").unwrap(), ReplicateRange { min: 5, max: 5 });
        assert_eq!(ReplicateRange::parse("2..10").unwrap(), ReplicateRange { min: 2, max: 10 });
        // 0 clamps to 1 (the historical `--replicates 0` contract).
        assert_eq!(ReplicateRange::parse("0").unwrap(), ReplicateRange::fixed(1));
        assert_eq!(ReplicateRange::parse("0..3").unwrap(), ReplicateRange { min: 1, max: 3 });
        // A one-point range is fixed.
        assert!(ReplicateRange::parse("4..4").unwrap().is_fixed());
        assert!(ReplicateRange::parse("x").is_err());
        assert!(ReplicateRange::parse("2..z").is_err());
        let err = ReplicateRange::parse("5..2").unwrap_err();
        assert!(err.contains("inclusive"), "{err}");
    }

    #[test]
    fn validate_rejects_bad_plans() {
        let mut p = plan_of(&["pso", "nope"]);
        assert!(matches!(p.validate(), Err(PlacementError::UnknownStrategy { .. })));
        p = plan_of(&["uniform", "round-robin"]);
        assert!(matches!(p.validate(), Err(PlacementError::DuplicateStrategy { .. })));
        p = plan_of(&[]);
        assert!(p.validate().unwrap_err().to_string().contains("empty"));
        p = plan_of(&["pso"]);
        p.scenarios.clear();
        assert!(p.validate().unwrap_err().to_string().contains("empty"));
        p = plan_of(&["pso"]);
        p.scenarios[0].sim.env = "dokcer".into();
        assert!(matches!(p.validate(), Err(PlacementError::UnknownEnvironment { .. })));
        // An env override is validated instead of the scenarios' envs.
        p.env_override = Some("des".into());
        p.validate().unwrap();
        p.env_override = Some("dokcer".into());
        assert!(matches!(p.validate(), Err(PlacementError::UnknownEnvironment { .. })));
        p = plan_of(&["pso"]);
        p.replicates = ReplicateRange { min: 3, max: 2 };
        assert!(p.validate().unwrap_err().to_string().contains("replicate range"));
    }

    #[test]
    fn replicate_seeds_are_distinct_and_anchor_replicate_zero() {
        assert_eq!(replicate_seed(42, 0), 42);
        let seeds: std::collections::BTreeSet<u64> =
            (0..64).map(|r| replicate_seed(42, r)).collect();
        assert_eq!(seeds.len(), 64);
        // Strategy-independent: the derivation has no strategy input, and
        // the same (base, r) always maps to the same seed.
        assert_eq!(replicate_seed(7, 5), replicate_seed(7, 5));
    }
}
