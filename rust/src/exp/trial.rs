//! One trial = one optimizer driven against one delay oracle under one
//! seed. This is the single code path behind `repro sim`, the sim-tier
//! `repro compare`, `repro fleet` and `repro ablate` — previously
//! `sim::runner` and `des::fleet` each hand-rolled this loop with
//! subtly duplicated seeding discipline.

use crate::configio::SimScenario;
use crate::des::EventDrivenEnv;
use crate::fitness::ClientAttrs;
use crate::placement::{drive, registry, Placement, PlacementError};
use crate::prng::Pcg32;
use crate::pso::IterationStats;

/// Everything a single trial can report. Heavy fields (`stats`,
/// `attrs`) are only populated when the caller asks for a trace —
/// fleet-scale runs aggregate thousands of trials and keep cells light.
#[derive(Debug, Clone)]
pub struct TrialOutcome {
    /// Canonical strategy name the trial ran (alias-resolved).
    pub strategy: String,
    /// Fitness evaluations spent.
    pub evaluations: usize,
    /// Best delay observed by the drive loop (the fleet's ranking raw
    /// material).
    pub best_delay: f64,
    /// The drive loop's best placement (None only for a zero-eval run).
    pub drive_best_placement: Option<Placement>,
    /// The optimizer's own notion of its best, when it tracks one
    /// (e.g. adaptive-pso re-measures its incumbent under drift).
    pub opt_best: Option<(Placement, f64)>,
    /// Whether the optimizer reports convergence.
    pub converged: bool,
    /// Mean delay across the whole search (exploration cost).
    pub mean_delay: f64,
    /// Events the discrete-event simulator fired (0 for analytic runs).
    pub events: u64,
    /// Per-iteration trace rows (empty unless `keep_trace`).
    pub stats: Vec<IterationStats>,
    /// The sampled client population (empty unless `keep_trace`).
    pub attrs: Vec<ClientAttrs>,
}

/// Run one trial: seed-derived population, registry optimizer, generic
/// [`drive`] loop against the named delay oracle. The seeding
/// discipline is the legacy `run_sim` contract — population sampled
/// first from `sc.seed`, the optimizer stream split off after — so
/// same-seed runs reproduce the original pipeline bit for bit. The
/// event-driven oracle is built concretely to keep its event counter;
/// any other environment goes through the registry factory.
pub fn run_cell_trial(
    sc: &SimScenario,
    strategy: &str,
    env_name: &str,
    evals: Option<usize>,
    keep_trace: bool,
) -> Result<TrialOutcome, PlacementError> {
    let cc = sc.client_count();
    let mut rng = Pcg32::seed_from_u64(sc.seed);
    let attrs = ClientAttrs::sample_population(
        cc,
        sc.pspeed_range,
        sc.memcap_range,
        sc.mdatasize,
        &mut rng,
    );
    let mut opt = registry::build_sim(strategy, sc, rng.split())?;
    let budget = evals.unwrap_or(sc.pso.iterations * sc.pso.particles).max(1);
    let kept_attrs = if keep_trace { attrs.clone() } else { Vec::new() };
    let (out, events) = if registry::canonical_env(env_name)? == "event-driven" {
        let mut env = EventDrivenEnv::from_scenario(sc, attrs);
        (drive(opt.as_mut(), &mut env, budget)?, env.events_fired)
    } else {
        let mut env = registry::build_sim_env(env_name, sc, attrs)?;
        (drive(opt.as_mut(), env.as_mut(), budget)?, 0)
    };
    let mean_delay = if out.stats.is_empty() {
        out.best_delay
    } else {
        out.stats.iter().map(|s| s.mean).sum::<f64>() / out.stats.len() as f64
    };
    Ok(TrialOutcome {
        strategy: opt.name().to_string(),
        evaluations: out.evaluations,
        best_delay: out.best_delay,
        drive_best_placement: out.best_placement,
        opt_best: opt.best(),
        converged: opt.converged(),
        mean_delay,
        events,
        stats: if keep_trace { out.stats } else { Vec::new() },
        attrs: kept_attrs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SimScenario {
        let mut sc = SimScenario { depth: 2, width: 2, ..SimScenario::default() };
        sc.pso.particles = 3;
        sc.pso.iterations = 5;
        sc
    }

    #[test]
    fn trial_is_deterministic_and_trace_gating_only_drops_heavy_fields() {
        let sc = tiny();
        let full = run_cell_trial(&sc, "pso", "analytic", None, true).unwrap();
        let lean = run_cell_trial(&sc, "pso", "analytic", None, false).unwrap();
        assert_eq!(full.best_delay, lean.best_delay);
        assert_eq!(full.mean_delay, lean.mean_delay);
        assert_eq!(full.evaluations, lean.evaluations);
        assert_eq!(full.evaluations, 15);
        assert_eq!(full.strategy, "pso");
        assert!(!full.stats.is_empty() && !full.attrs.is_empty());
        assert!(lean.stats.is_empty() && lean.attrs.is_empty());
        assert_eq!(full.attrs.len(), sc.client_count());
    }

    #[test]
    fn event_driven_trials_count_events_and_honor_eval_overrides() {
        let sc = tiny();
        let t = run_cell_trial(&sc, "random", "event-driven", Some(7), false).unwrap();
        assert_eq!(t.evaluations, 7);
        assert!(t.events > 0, "des oracle must fire events");
        let a = run_cell_trial(&sc, "random", "analytic", Some(7), false).unwrap();
        assert_eq!(a.events, 0, "analytic oracle fires none");
        // The default-config des oracle is conformant to the analytic
        // TPD, so the same seed scores identically under both.
        assert!((t.best_delay - a.best_delay).abs() < 1e-9);
    }

    #[test]
    fn unknown_names_are_typed_errors() {
        let sc = tiny();
        assert!(matches!(
            run_cell_trial(&sc, "nope", "analytic", None, false),
            Err(PlacementError::UnknownStrategy { .. })
        ));
        assert!(matches!(
            run_cell_trial(&sc, "pso", "docker", None, false),
            Err(PlacementError::UnknownEnvironment { .. })
        ));
    }
}
