//! The trial scheduler: the atomic-queue `std::thread::scope` worker
//! pool that `des::fleet` used to hard-code, generalized to any
//! `Fn(usize) -> T` trial. Results land in a slot vector indexed by job
//! id, so the output order — and therefore every downstream statistic —
//! is independent of the thread count and of which worker ran which
//! job.
//!
//! This parallelizes *across* trials (one trial = one cell replicate).
//! For parallelism *within* a single candidate batch — one optimizer
//! step fanned across threads — see [`crate::placement::ParEvalBatch`],
//! which applies the same slot-vector/bit-identity discipline at the
//! `eval_batch` level.

use crate::obs::defs as obs;
use crate::obs::WallSpan;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// One trial's panic, caught at the worker boundary instead of
/// unwinding through `std::thread::scope` and killing every other
/// in-flight trial with it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TrialPanic {
    /// Job index of the trial that panicked.
    pub job: usize,
    /// The panic payload, when it was a string (the common case).
    pub message: String,
}

impl std::fmt::Display for TrialPanic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "trial {} panicked: {}", self.job, self.message)
    }
}

impl std::error::Error for TrialPanic {}

/// Stringify a caught panic payload (`&str` / `String` cover what
/// `panic!` produces; anything else gets a placeholder).
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Telemetry around one claimed job: queue-wait histogram at claim
/// time, busy-time counter + done counter after the trial, and (when
/// `--trace-out` is active) one wall span on the worker's trace lane.
#[inline]
fn observed<T>(pool_start: Instant, worker: u32, f: impl FnOnce() -> T) -> T {
    obs::EXP_QUEUE_WAIT.observe(pool_start.elapsed().as_secs_f64());
    let _span = WallSpan::start("trial", "exp", worker);
    let started = Instant::now();
    let out = f();
    obs::EXP_WORKER_BUSY_US.add(started.elapsed().as_micros() as u64);
    obs::EXP_JOBS_DONE.inc();
    out
}

/// A deterministic fan-out executor over OS threads.
#[derive(Debug, Clone, Copy)]
pub struct TrialScheduler {
    /// Worker OS threads (0 = one per available core).
    threads: usize,
}

impl TrialScheduler {
    pub fn new(threads: usize) -> TrialScheduler {
        TrialScheduler { threads }
    }

    /// Worker count for a batch of `jobs` trials.
    fn resolve(&self, jobs: usize) -> usize {
        let t = if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            self.threads
        };
        t.min(jobs)
    }

    /// Run `jobs` trials and return their results in job order. The
    /// trial function must derive all of its randomness from the job
    /// index (e.g. via scenario/replicate seeds) — under that contract
    /// the returned vector is byte-identical for any thread count.
    pub fn run<T, F>(&self, jobs: usize, trial: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if jobs == 0 {
            return Vec::new();
        }
        obs::EXP_JOBS_QUEUED.add(jobs as u64);
        let pool_start = Instant::now();
        let threads = self.resolve(jobs);
        if threads <= 1 {
            return (0..jobs).map(|j| observed(pool_start, 0, || trial(j))).collect();
        }
        let next = AtomicUsize::new(0);
        let slots: Mutex<Vec<Option<T>>> = Mutex::new((0..jobs).map(|_| None).collect());
        std::thread::scope(|scope| {
            for w in 0..threads as u32 {
                let (next, slots, trial) = (&next, &slots, &trial);
                scope.spawn(move || loop {
                    let j = next.fetch_add(1, Ordering::Relaxed);
                    if j >= jobs {
                        break;
                    }
                    let out = observed(pool_start, w, || trial(j));
                    slots.lock().expect("trial scheduler slots lock")[j] = Some(out);
                });
            }
        });
        slots
            .into_inner()
            .expect("trial scheduler slots lock")
            .into_iter()
            .map(|s| s.expect("every trial job ran"))
            .collect()
    }

    /// Like [`TrialScheduler::run`], but each job carries an owned value
    /// the trial *consumes* — the service tier moves one session runner
    /// into whichever worker claims it. Results land in job order under
    /// the same determinism contract.
    pub fn run_consuming<J, T, F>(&self, jobs: Vec<J>, trial: F) -> Vec<T>
    where
        J: Send,
        T: Send,
        F: Fn(usize, J) -> T + Sync,
    {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        obs::EXP_JOBS_QUEUED.add(n as u64);
        let pool_start = Instant::now();
        let threads = self.resolve(n);
        if threads <= 1 {
            return jobs
                .into_iter()
                .enumerate()
                .map(|(i, job)| observed(pool_start, 0, || trial(i, job)))
                .collect();
        }
        let next = AtomicUsize::new(0);
        let input: Mutex<Vec<Option<J>>> = Mutex::new(jobs.into_iter().map(Some).collect());
        let slots: Mutex<Vec<Option<T>>> = Mutex::new((0..n).map(|_| None).collect());
        std::thread::scope(|scope| {
            for w in 0..threads as u32 {
                let (next, input, slots, trial) = (&next, &input, &slots, &trial);
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let job = input.lock().expect("consuming scheduler input lock")[i]
                        .take()
                        .expect("each job is claimed exactly once");
                    let out = observed(pool_start, w, || trial(i, job));
                    slots.lock().expect("consuming scheduler slots lock")[i] = Some(out);
                });
            }
        });
        slots
            .into_inner()
            .expect("consuming scheduler slots lock")
            .into_iter()
            .map(|s| s.expect("every consuming job ran"))
            .collect()
    }

    /// Like [`TrialScheduler::run`], but a panicking trial becomes a
    /// per-slot `Err(TrialPanic)` instead of unwinding through the
    /// thread scope and aborting every other in-flight trial.
    pub fn run_catching<T, F>(&self, jobs: usize, trial: F) -> Vec<Result<T, TrialPanic>>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        self.run(jobs, |j| {
            catch_unwind(AssertUnwindSafe(|| trial(j)))
                .map_err(|payload| TrialPanic { job: j, message: panic_message(payload) })
        })
    }

    /// Panic-isolating [`TrialScheduler::run_consuming`]: the service
    /// tier routes each `Err(TrialPanic)` into session quarantine
    /// instead of losing every concurrent session to one poisoned one.
    pub fn run_consuming_catching<J, T, F>(
        &self,
        jobs: Vec<J>,
        trial: F,
    ) -> Vec<Result<T, TrialPanic>>
    where
        J: Send,
        T: Send,
        F: Fn(usize, J) -> T + Sync,
    {
        self.run_consuming(jobs, |i, job| {
            catch_unwind(AssertUnwindSafe(|| trial(i, job)))
                .map_err(|payload| TrialPanic { job: i, message: panic_message(payload) })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_arrive_in_job_order_for_any_thread_count() {
        let f = |j: usize| j * j;
        let expect: Vec<usize> = (0..40).map(f).collect();
        for threads in [0, 1, 3, 8, 64] {
            assert_eq!(TrialScheduler::new(threads).run(40, f), expect, "threads={threads}");
        }
        assert_eq!(TrialScheduler::new(4).run(0, f), Vec::<usize>::new());
        // More workers than jobs is fine (workers are capped at jobs).
        assert_eq!(TrialScheduler::new(16).run(2, f), vec![0, 1]);
    }

    #[test]
    fn consuming_jobs_keep_order_and_move_their_payloads() {
        // Owned, non-Clone payloads: each must be consumed exactly once
        // and the results must come back in job order for any width.
        struct Payload(usize);
        for threads in [0, 1, 4, 16] {
            let jobs: Vec<Payload> = (0..25).map(Payload).collect();
            let got = TrialScheduler::new(threads).run_consuming(jobs, |i, p: Payload| {
                assert_eq!(i, p.0, "job index must match its payload");
                p.0 * 3
            });
            assert_eq!(got, (0..25).map(|j| j * 3).collect::<Vec<_>>(), "threads={threads}");
        }
        let empty: Vec<usize> =
            TrialScheduler::new(4).run_consuming(Vec::<Payload>::new(), |_, p| p.0);
        assert!(empty.is_empty());
    }

    #[test]
    fn a_panicking_trial_is_isolated_and_the_rest_survive() {
        // One poisoned trial out of 24; every other slot must come back
        // intact and in job order, for any thread count — the regression
        // that used to unwind through std::thread::scope and abort the
        // whole run.
        for threads in [1, 2, 8] {
            let got = TrialScheduler::new(threads).run_catching(24, |j| {
                if j == 7 {
                    panic!("poisoned trial {j}");
                }
                j * 10
            });
            assert_eq!(got.len(), 24, "threads={threads}");
            for (j, slot) in got.iter().enumerate() {
                if j == 7 {
                    let p = slot.as_ref().unwrap_err();
                    assert_eq!(p.job, 7);
                    assert_eq!(p.message, "poisoned trial 7");
                } else {
                    assert_eq!(*slot, Ok(j * 10), "threads={threads}");
                }
            }
        }
    }

    #[test]
    fn consuming_panics_report_their_job_and_consume_their_payload() {
        struct Payload(usize);
        let jobs: Vec<Payload> = (0..10).map(Payload).collect();
        let got = TrialScheduler::new(4).run_consuming_catching(jobs, |i, p: Payload| {
            if p.0 == 3 {
                panic!("bad payload");
            }
            i + p.0
        });
        for (i, slot) in got.iter().enumerate() {
            match slot {
                Ok(v) => assert_eq!(*v, i * 2),
                Err(p) => {
                    assert_eq!(i, 3);
                    assert_eq!(p.job, 3);
                    assert_eq!(p.message, "bad payload");
                }
            }
        }
    }

    #[test]
    fn trials_run_concurrently_but_slot_deterministically() {
        // Each trial sleeps inversely to its index, so completion order
        // is roughly reversed — slots must still come back in job order.
        let f = |j: usize| {
            std::thread::sleep(std::time::Duration::from_micros((20 - j as u64) * 50));
            j
        };
        assert_eq!(TrialScheduler::new(8).run(20, f), (0..20).collect::<Vec<_>>());
    }
}
