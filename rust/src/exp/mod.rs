//! The experiment engine — one plan → schedule → report pipeline behind
//! every comparative claim the reproduction makes.
//!
//! The paper's headline numbers are *comparisons* (Fig. 4: PSO ≈43%
//! faster than random, ≈32% faster than uniform placement; Fig. 3:
//! swarm-size and depth sweeps). This module owns the machinery for
//! producing such comparisons trustworthily:
//!
//! | concept | type | paper anchor |
//! |---------|------|--------------|
//! | what to compare | [`ExperimentPlan`] (scenario × strategy × env × replicate) | the Fig. 3 panel grid, the Fig. 4 strategy line-up |
//! | how to execute | [`TrialScheduler`] (deterministic thread pool) + [`run_cell_trial`] | one trial = one seeded optimizer-vs-oracle run |
//! | how many seeds | [`ReplicateRange`] + the adaptive allocator in [`run_plan`] | replaces the single-seed lottery behind any one table entry |
//! | what to report | [`report_cells`]: ranks, standings, sign test, Wilcoxon signed-rank + rank-biserial | the "X% faster" claims, with error bars and significance |
//! | why it is faster | [`run_ablation`] (`repro ablate`): one-mechanism-off deltas | attributes delay to churn/jitter/contention/... mechanisms |
//!
//! `des::fleet` is a thin adapter over this engine (its fixed
//! `--replicates R` CSVs are byte-frozen), `sim::runner` routes
//! `repro sim`/`fig3` through [`run_cell_trial`] on a
//! [`TrialScheduler`], and the sim-tier `repro compare --replicates`
//! builds a one-scenario plan. Live-tier replication goes through the
//! service tier instead ([`crate::service`]): `repro compare --env
//! live --replicates R` submits one session per derived seed to a
//! [`crate::service::CoordinatorService`], whose workers multiplex the
//! sessions over one shared broker — each replicate is a real,
//! independently seeded FL session, not a re-scored trace.

pub mod ablate;
pub mod engine;
pub mod plan;
pub mod report;
pub mod scheduler;
pub mod trial;

pub use ablate::{
    enabled_mechanisms, report_ablation, run_ablation, AblationConfig, AblationOutcome,
    MechanismEffect,
};
pub use engine::run_plan;
pub use plan::{replicate_seed, ExperimentPlan, ReplicateRange};
pub use report::{
    report_cells, significance_matrix, standings, ExperimentCell, SignificanceMatrix,
    StrategyStanding, VersusRow,
};
pub use scheduler::{TrialPanic, TrialScheduler};
pub use trial::{run_cell_trial, TrialOutcome};
