//! Wrapper injectors: how a [`FaultPlan`] reaches the system's seams.
//!
//! Each injector decorates an existing abstraction — [`FaultyStore`]
//! wraps any [`Store`], [`FaultyBackend`] wraps any
//! [`RoundBackend`], [`BrokerFaults`] implements the broker's
//! [`Interceptor`] hook — so the production types never know the fault
//! plane exists. [`RetryStore`] is the matching *hardening* layer:
//! capped exponential backoff with deterministic jitter around any
//! store, which also defines the recovery behavior chaos mode checks.
//!
//! Keying discipline: store decisions are keyed by per-session call
//! ordinals (one save per completed round, so the ordinal *is* the
//! round position and survives kills/resumes); round decisions by
//! `(round, attempt)`; broker decisions by a per-session publish
//! ordinal (deterministic wherever publish order is — the single-seam
//! in-process broker serializes it).

use super::plan::{fnv64, BrokerFault, FaultPlan, RoundFault, SaveFault};
use crate::broker::{Intercept, Interceptor};
use crate::obs::defs as obs;
use crate::placement::Placement;
use crate::prng::SplitMix64;
use crate::service::backend::{RoundBackend, RoundOutcome};
use crate::service::storage::{SessionSnapshot, Store};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

fn bump(map: &Mutex<HashMap<String, u64>>, session: &str) -> u64 {
    let mut map = map.lock().expect("fault counter lock");
    let n = map.entry(session.to_string()).or_insert(0);
    let now = *n;
    *n += 1;
    now
}

/// A [`Store`] decorator that realizes the plan's store faults:
/// plain save/load IO errors and simulated torn writes in both
/// directions. Torn saves persist a *hybrid* snapshot to the inner
/// store (one half new, one half stale) and then return an error —
/// exactly what a crash between `DirStore`'s two file writes leaves
/// behind — so the resume path's optimizer cross-check and torn-save
/// recovery get exercised against any backend.
pub struct FaultyStore {
    inner: Arc<dyn Store>,
    plan: Arc<FaultPlan>,
    saves: Mutex<HashMap<String, u64>>,
    loads: Mutex<HashMap<String, u64>>,
}

impl FaultyStore {
    pub fn new(inner: Arc<dyn Store>, plan: Arc<FaultPlan>) -> FaultyStore {
        FaultyStore { inner, plan, saves: Mutex::new(HashMap::new()), loads: Mutex::new(HashMap::new()) }
    }
}

impl Store for FaultyStore {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn save(&self, session: &str, snap: &SessionSnapshot) -> Result<()> {
        let attempt = bump(&self.saves, session);
        match self.plan.save_fault(session, attempt) {
            None => self.inner.save(session, snap),
            Some(SaveFault::Fail) => {
                obs::FAULT_INJECTED.inc("store_save_fail");
                Err(anyhow!("injected store save failure (session {session}, save #{attempt})"))
            }
            Some(SaveFault::TornCkpt) => {
                obs::FAULT_INJECTED.inc("torn_ckpt");
                // Ckpt written, crash before state.json: new ckpt half
                // under the previous state half. With no prior snapshot
                // the crash left nothing visible at all.
                if let Some(old) = self.inner.load(session).unwrap_or(None) {
                    let hybrid = SessionSnapshot {
                        summary: snap.summary.clone(),
                        next_round: old.next_round,
                        phase: old.phase.clone(),
                        trace: old.trace.clone(),
                        optimizer: snap.optimizer.clone(),
                        params: snap.params.clone(),
                        loss: snap.loss,
                    };
                    self.inner.save(session, &hybrid)?;
                }
                Err(anyhow!("injected torn save (ckpt new, state stale) for session {session}"))
            }
            Some(SaveFault::TornState) => {
                obs::FAULT_INJECTED.inc("torn_state");
                // The reverse tear: state half new, ckpt half stale
                // (or absent — optimizer None skips the cross-check,
                // replay still rebuilds the optimizer exactly).
                let hybrid = match self.inner.load(session).unwrap_or(None) {
                    Some(old) => SessionSnapshot {
                        summary: snap.summary.clone(),
                        next_round: snap.next_round,
                        phase: snap.phase.clone(),
                        trace: snap.trace.clone(),
                        optimizer: old.optimizer.clone(),
                        params: old.params.clone(),
                        loss: old.loss,
                    },
                    None => SessionSnapshot {
                        optimizer: None,
                        params: Vec::new(),
                        loss: f64::NAN,
                        ..snap.clone()
                    },
                };
                self.inner.save(session, &hybrid)?;
                Err(anyhow!("injected torn save (state new, ckpt stale) for session {session}"))
            }
        }
    }

    fn load(&self, session: &str) -> Result<Option<SessionSnapshot>> {
        let attempt = bump(&self.loads, session);
        if self.plan.load_fails(session, attempt) {
            obs::FAULT_INJECTED.inc("store_load_fail");
            return Err(anyhow!(
                "injected store load failure (session {session}, load #{attempt})"
            ));
        }
        self.inner.load(session)
    }

    fn sessions(&self) -> Result<Vec<String>> {
        self.inner.sessions()
    }

    fn remove(&self, session: &str) -> Result<()> {
        self.inner.remove(session)
    }
}

/// Capped exponential backoff with deterministic jitter. The jitter
/// multiplier for retry `attempt` of `session` is a pure function of
/// `(seed, session, attempt)` in `[0.5, 1.5)` — no wall-clock or
/// thread-local entropy, so chaos runs stay reproducible. `sleep`
/// selects whether delays are actually slept (live mode) or only
/// accounted (sim mode, where time is virtual and a wall sleep would
/// slow tests for nothing).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BackoffPolicy {
    /// Total attempts per operation (1 = no retries).
    pub attempts: usize,
    /// Delay before the first retry.
    pub base: Duration,
    /// Upper bound on the un-jittered delay.
    pub cap: Duration,
    /// Sleep for real between attempts (live mode) or not (sim mode).
    pub sleep: bool,
    /// Jitter stream seed.
    pub seed: u64,
}

impl Default for BackoffPolicy {
    fn default() -> Self {
        BackoffPolicy {
            attempts: 3,
            base: Duration::from_millis(10),
            cap: Duration::from_millis(500),
            sleep: false,
            seed: 0,
        }
    }
}

impl BackoffPolicy {
    /// Jittered delay before retry `attempt` (1-based).
    pub fn delay(&self, session: &str, attempt: u32) -> Duration {
        let exp = self.base.as_secs_f64() * 2f64.powi(attempt.saturating_sub(1) as i32);
        let capped = exp.min(self.cap.as_secs_f64());
        let mut sm = SplitMix64::new(
            self.seed ^ fnv64(session) ^ (attempt as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        let unit = (sm.next() >> 11) as f64 / (1u64 << 53) as f64;
        Duration::from_secs_f64(capped * (0.5 + unit))
    }
}

/// A [`Store`] decorator that retries failed saves/loads under a
/// [`BackoffPolicy`]. Neutral when the inner store never errors; under
/// a fault plan it is what turns transient injected IO errors into
/// recovered operations instead of failed sessions. Each retry bumps
/// `repro_service_store_retries_total`.
pub struct RetryStore {
    inner: Arc<dyn Store>,
    policy: BackoffPolicy,
}

impl RetryStore {
    pub fn new(inner: Arc<dyn Store>, policy: BackoffPolicy) -> RetryStore {
        RetryStore { inner, policy: BackoffPolicy { attempts: policy.attempts.max(1), ..policy } }
    }

    fn with_retries<T>(
        &self,
        session: &str,
        mut op: impl FnMut() -> Result<T>,
    ) -> Result<T> {
        let mut last = None;
        for attempt in 0..self.policy.attempts {
            match op() {
                Ok(v) => return Ok(v),
                Err(e) => last = Some(e),
            }
            if attempt + 1 < self.policy.attempts {
                obs::SERVICE_STORE_RETRIES.inc();
                if self.policy.sleep {
                    std::thread::sleep(self.policy.delay(session, attempt as u32 + 1));
                }
            }
        }
        Err(last.expect("attempts >= 1").context(format!(
            "store operation failed after {} attempts (session {session})",
            self.policy.attempts
        )))
    }
}

impl Store for RetryStore {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn save(&self, session: &str, snap: &SessionSnapshot) -> Result<()> {
        self.with_retries(session, || self.inner.save(session, snap))
    }

    fn load(&self, session: &str) -> Result<Option<SessionSnapshot>> {
        self.with_retries(session, || self.inner.load(session))
    }

    fn sessions(&self) -> Result<Vec<String>> {
        self.inner.sessions()
    }

    fn remove(&self, session: &str) -> Result<()> {
        self.inner.remove(session)
    }
}

/// A [`RoundBackend`] decorator that realizes the plan's round faults:
/// injected round errors (spend the retry budget) and injected panics
/// (quarantined at the service's worker boundary). Everything else
/// forwards, including the label — so a session's storage fingerprint
/// is identical with and without the fault plane, and a snapshot taken
/// under faults resumes cleanly without them.
pub struct FaultyBackend {
    inner: Box<dyn RoundBackend>,
    plan: Arc<FaultPlan>,
    session: String,
    /// Attempts so far per round (fault keying, mirrors the machine's
    /// retry accounting).
    attempts: HashMap<usize, usize>,
}

impl FaultyBackend {
    pub fn new(inner: Box<dyn RoundBackend>, plan: Arc<FaultPlan>, session: &str) -> FaultyBackend {
        FaultyBackend { inner, plan, session: session.to_string(), attempts: HashMap::new() }
    }
}

impl RoundBackend for FaultyBackend {
    fn label(&self) -> &str {
        self.inner.label()
    }

    fn rendezvous(&mut self, clients: usize, timeout: Duration) -> Result<()> {
        self.inner.rendezvous(clients, timeout)
    }

    fn run_round(
        &mut self,
        round: usize,
        placement: &Placement,
        active: &[bool],
    ) -> Result<RoundOutcome> {
        let attempt = *self
            .attempts
            .entry(round)
            .and_modify(|a| *a += 1)
            .or_insert(0);
        match self.plan.round_fault(&self.session, round, attempt) {
            Some(RoundFault::Panic) => {
                obs::FAULT_INJECTED.inc("worker_panic");
                panic!("injected worker panic (session {}, round {round})", self.session);
            }
            Some(RoundFault::Error) => {
                obs::FAULT_INJECTED.inc("round_error");
                Err(anyhow!(
                    "injected round error (session {}, round {round}, attempt {attempt})",
                    self.session
                ))
            }
            None => self.inner.run_round(round, placement, active),
        }
    }

    fn set_strategy_label(&mut self, label: &str) {
        self.inner.set_strategy_label(label);
    }

    fn params(&self) -> Vec<f32> {
        self.inner.params()
    }

    fn install_params(&mut self, params: Vec<f32>, round: usize, loss: f64) -> Result<()> {
        self.inner.install_params(params, round, loss)
    }

    fn heartbeats(&mut self) -> Option<Vec<bool>> {
        self.inner.heartbeats()
    }

    fn shutdown(&mut self) {
        self.inner.shutdown();
    }
}

/// The broker-level injector: an [`Interceptor`] that maps the plan's
/// broker faults onto publish verdicts. Only `fl/{session}/...` topics
/// are eligible (service topics stay reliable); each session's messages
/// are keyed by a per-session publish ordinal.
pub struct BrokerFaults {
    plan: Arc<FaultPlan>,
    seq: Mutex<HashMap<String, u64>>,
}

impl BrokerFaults {
    pub fn new(plan: Arc<FaultPlan>) -> BrokerFaults {
        BrokerFaults { plan, seq: Mutex::new(HashMap::new()) }
    }
}

/// The session segment of an `fl/{session}/...` topic.
fn session_of(topic: &str) -> Option<&str> {
    let mut parts = topic.split('/');
    if parts.next() != Some("fl") {
        return None;
    }
    parts.next().filter(|s| !s.is_empty())
}

impl Interceptor for BrokerFaults {
    fn intercept(&self, topic: &str, _payload_len: usize) -> Intercept {
        let Some(session) = session_of(topic) else {
            return Intercept::Deliver;
        };
        let key = bump(&self.seq, session);
        match self.plan.broker_fault(session, key) {
            None => Intercept::Deliver,
            Some(BrokerFault::Drop) => {
                obs::FAULT_INJECTED.inc("broker_drop");
                Intercept::Drop
            }
            Some(BrokerFault::Duplicate) => {
                obs::FAULT_INJECTED.inc("broker_duplicate");
                Intercept::Duplicate
            }
            Some(BrokerFault::DelayMs(ms)) => {
                obs::FAULT_INJECTED.inc("broker_delay");
                Intercept::DelayMs(ms)
            }
            Some(BrokerFault::Reorder) => {
                obs::FAULT_INJECTED.inc("broker_reorder");
                Intercept::Reorder
            }
        }
    }
}

/// Apply heartbeat loss to a liveness mask: clients whose beat the plan
/// loses at this round read as silent even though they are alive. The
/// round still executes with the true `active` set — loss is telemetry
/// erasure, which is exactly what stresses the machine's grace-window
/// logic.
pub fn apply_heartbeat_loss(
    plan: &FaultPlan,
    session: &str,
    round: usize,
    mask: &[bool],
) -> Vec<bool> {
    mask.iter()
        .enumerate()
        .map(|(client, &alive)| {
            if alive && plan.heartbeat_lost(session, round, client) {
                obs::FAULT_INJECTED.inc("heartbeat_loss");
                false
            } else {
                alive
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::storage::{NoopStore, SpecSummary, TraceRow};

    fn snap(next_round: usize, delay: f64) -> SessionSnapshot {
        SessionSnapshot {
            summary: SpecSummary {
                strategy: "pso".into(),
                rounds: 8,
                seed: 1,
                client_count: 8,
                dims: 2,
                backend: "analytic".into(),
            },
            next_round,
            phase: format!("round({next_round})"),
            trace: (0..next_round)
                .map(|r| TraceRow {
                    round: r,
                    placement: vec![r, r + 1],
                    delay_s: delay,
                    loss: f64::NAN,
                    live: 8,
                })
                .collect(),
            optimizer: None,
            params: vec![next_round as f32],
            loss: f64::NAN,
        }
    }

    #[test]
    fn torn_ckpt_saves_a_hybrid_and_errors() {
        let plan = Arc::new(FaultPlan {
            store: super::super::plan::StoreFaultCfg { torn_ckpt_prob: 1.0, ..Default::default() },
            ..FaultPlan::empty()
        });
        let inner = Arc::new(NoopStore::new());
        let store = FaultyStore::new(inner.clone(), plan);
        // No prior snapshot: the tear leaves nothing visible.
        assert!(store.save("s", &snap(1, 2.0)).is_err());
        assert!(inner.load("s").unwrap().is_none());
        // Seed a prior snapshot directly, then tear over it: the hybrid
        // keeps the OLD trace under the NEW ckpt half.
        inner.save("s", &snap(1, 2.0)).unwrap();
        assert!(store.save("s", &snap(2, 3.0)).is_err());
        let hybrid = inner.load("s").unwrap().unwrap();
        assert_eq!(hybrid.next_round, 1, "state half must stay stale");
        assert_eq!(hybrid.trace.len(), 1);
        assert_eq!(hybrid.params, vec![2.0], "ckpt half must be new");
    }

    #[test]
    fn torn_state_saves_the_reverse_hybrid() {
        let plan = Arc::new(FaultPlan {
            store: super::super::plan::StoreFaultCfg { torn_state_prob: 1.0, ..Default::default() },
            ..FaultPlan::empty()
        });
        let inner = Arc::new(NoopStore::new());
        let store = FaultyStore::new(inner.clone(), plan);
        inner.save("s", &snap(1, 2.0)).unwrap();
        assert!(store.save("s", &snap(2, 3.0)).is_err());
        let hybrid = inner.load("s").unwrap().unwrap();
        assert_eq!(hybrid.next_round, 2, "state half must be new");
        assert_eq!(hybrid.params, vec![1.0], "ckpt half must stay stale");
    }

    #[test]
    fn retry_store_retries_then_surfaces_the_last_error() {
        // A store that fails the first `fails` calls, then succeeds.
        struct Flaky {
            inner: NoopStore,
            fails: Mutex<usize>,
        }
        impl Store for Flaky {
            fn name(&self) -> &'static str {
                "flaky"
            }
            fn save(&self, session: &str, snap: &SessionSnapshot) -> Result<()> {
                let mut fails = self.fails.lock().unwrap();
                if *fails > 0 {
                    *fails -= 1;
                    return Err(anyhow!("transient"));
                }
                self.inner.save(session, snap)
            }
            fn load(&self, session: &str) -> Result<Option<SessionSnapshot>> {
                self.inner.load(session)
            }
            fn sessions(&self) -> Result<Vec<String>> {
                self.inner.sessions()
            }
            fn remove(&self, session: &str) -> Result<()> {
                self.inner.remove(session)
            }
        }
        let policy = BackoffPolicy { attempts: 3, ..Default::default() };
        // Two transient failures: recovered within the budget.
        let flaky = Arc::new(Flaky { inner: NoopStore::new(), fails: Mutex::new(2) });
        let store = RetryStore::new(flaky.clone(), policy);
        store.save("s", &snap(1, 2.0)).unwrap();
        assert!(flaky.load("s").unwrap().is_some());
        // Three failures exceed the budget and surface with context.
        let flaky = Arc::new(Flaky { inner: NoopStore::new(), fails: Mutex::new(3) });
        let store = RetryStore::new(flaky, policy);
        let err = format!("{:#}", store.save("s", &snap(1, 2.0)).unwrap_err());
        assert!(err.contains("after 3 attempts"), "{err}");
        assert!(err.contains("transient"), "{err}");
    }

    #[test]
    fn backoff_delays_are_deterministic_capped_and_jittered() {
        let policy = BackoffPolicy {
            attempts: 5,
            base: Duration::from_millis(100),
            cap: Duration::from_millis(400),
            sleep: false,
            seed: 7,
        };
        for attempt in 1..=6u32 {
            let d = policy.delay("sess", attempt);
            assert_eq!(d, policy.delay("sess", attempt), "jitter must be deterministic");
            let uncapped = 0.1 * 2f64.powi(attempt as i32 - 1);
            let capped = uncapped.min(0.4);
            let secs = d.as_secs_f64();
            assert!(
                (capped * 0.5..capped * 1.5).contains(&secs),
                "attempt {attempt}: {secs}s outside jitter band around {capped}s"
            );
        }
        // Different sessions jitter differently.
        assert_ne!(policy.delay("a", 1), policy.delay("b", 1));
    }

    #[test]
    fn broker_faults_skip_non_session_topics() {
        let mut plan = FaultPlan::empty();
        plan.broker.drop_prob = 1.0;
        let hook = BrokerFaults::new(Arc::new(plan));
        assert_eq!(hook.intercept("metrics/scrape", 8), Intercept::Deliver);
        assert_eq!(hook.intercept("fl/s1/round", 8), Intercept::Drop);
    }

    #[test]
    fn heartbeat_loss_only_erases_live_clients() {
        let mut plan = FaultPlan::empty();
        plan.heartbeats.loss_prob = 1.0;
        let lossy = apply_heartbeat_loss(&plan, "s", 0, &[true, false, true]);
        assert_eq!(lossy, vec![false, false, false]);
        let neutral = apply_heartbeat_loss(&FaultPlan::empty(), "s", 0, &[true, false, true]);
        assert_eq!(neutral, vec![true, false, true]);
    }
}
