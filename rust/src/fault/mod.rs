//! The deterministic fault-injection plane (robustness tier).
//!
//! Reproduction claims are only trustworthy if the service tier's
//! recovery machinery — resume-by-replay, retry budgets, heartbeat
//! liveness, panic quarantine — actually holds up under faults. This
//! module makes faults *first-class and reproducible*:
//!
//! * [`plan`] — [`FaultPlan`]: a TOML-loadable description of broker
//!   message faults (drop / duplicate / delay / reorder), store IO
//!   errors and torn writes (both directions), round errors / worker
//!   panics, and heartbeat-loss bursts. Every realization is a pure
//!   function of `(plan seed, injection point, session, key)` — same
//!   plan, same sessions ⇒ same faults, byte-identical metrics CSVs.
//!   The empty plan is provably neutral.
//! * [`inject`] — decorators at the existing seams: [`FaultyStore`] /
//!   [`RetryStore`] around any [`crate::service::Store`],
//!   [`FaultyBackend`] around any round backend, [`BrokerFaults`] as
//!   the broker's publish interceptor, and heartbeat-mask erasure.
//!
//! Wired up by `CoordinatorService::with_faults` (`repro serve
//! --faults PLAN.toml`) and soak-tested by `repro chaos`, which runs a
//! session fleet under a plan and checks the terminal-phase /
//! reproducibility invariants. Realized faults are counted in
//! `repro_fault_injected_total{kind}`.

pub mod inject;
pub mod plan;

pub use inject::{
    apply_heartbeat_loss, BackoffPolicy, BrokerFaults, FaultyBackend, FaultyStore, RetryStore,
};
pub use plan::{
    BrokerFault, BrokerFaultCfg, FaultPlan, HeartbeatFaultCfg, RoundFault, RoundFaultCfg,
    SaveFault, StoreFaultCfg,
};
