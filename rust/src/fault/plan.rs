//! The fault plan: a declarative, TOML-loadable description of which
//! faults to inject where, realized through *pure* seeded streams.
//!
//! Every decision the plan makes is a pure function of
//! `(plan seed, injection point, session name, key)` — never of call
//! order, thread interleaving, or wall time. Two runs of the same
//! sessions under the same plan therefore realize the *same* faults at
//! the *same* places, which is what makes `repro chaos` reproducible
//! and lets the resume tests stitch a killed session back together
//! under the same plan. The stream derivation mirrors the
//! [`crate::exp::replicate_seed`] idiom: chained [`SplitMix64`]
//! expansions seeding one [`Pcg32`] per decision.
//!
//! An all-zero plan ([`FaultPlan::empty`], or a TOML file with every
//! probability 0) is provably neutral: every decision method returns
//! `None`/`false` before touching its stream.

use crate::configio::TomlDoc;
use crate::prng::{Pcg32, Rng, SplitMix64};
use anyhow::{anyhow, Context, Result};
use std::path::Path;

// One salt per injection point so streams never alias across seams.
const POINT_BROKER: u64 = 0x4252_4F4B; // "BROK"
const POINT_STORE_SAVE: u64 = 0x5356_4553; // "SVES"
const POINT_STORE_LOAD: u64 = 0x4C4F_4144; // "LOAD"
const POINT_ROUND: u64 = 0x524E_4421; // "RND!"
const POINT_HEARTBEAT: u64 = 0x4842_5431; // "HBT1"

/// FNV-1a 64 over the session name — folds the (arbitrary-length)
/// session identity into the stream seed.
pub(crate) fn fnv64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Broker-seam fault rates (`[broker]` in the plan TOML).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct BrokerFaultCfg {
    /// Probability a published message is silently lost.
    pub drop_prob: f64,
    /// Probability a published message is delivered twice.
    pub duplicate_prob: f64,
    /// Probability a published message is delayed by `delay_ms`.
    pub delay_prob: f64,
    /// Wall milliseconds a delayed message sleeps before routing.
    pub delay_ms: u64,
    /// Probability a message is held back behind the next publish.
    pub reorder_prob: f64,
}

/// Store-seam fault rates (`[store]`).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct StoreFaultCfg {
    /// Probability a snapshot save returns an IO error (nothing written).
    pub save_fail_prob: f64,
    /// Probability a snapshot load returns an IO error.
    pub load_fail_prob: f64,
    /// Probability a save tears ckpt-first: the new checkpoint half
    /// lands, the state half stays stale (crash between the two writes
    /// of [`crate::service::DirStore`]).
    pub torn_ckpt_prob: f64,
    /// The reverse tear: state half new, checkpoint half stale.
    pub torn_state_prob: f64,
}

/// Round-execution fault rates (`[rounds]`).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct RoundFaultCfg {
    /// Probability a round execution returns an error (spends the
    /// session's retry budget).
    pub error_prob: f64,
    /// Probability a round execution panics (quarantines the session).
    pub panic_prob: f64,
    /// Exact `(session, round)` pairs that always panic — the
    /// deterministic hook the CI chaos smoke uses (`panic_at =
    /// ["sess:round", ...]` in TOML).
    pub panic_at: Vec<(String, usize)>,
}

/// Heartbeat-loss rates (`[heartbeats]`). Loss is telemetry-only: the
/// client stays alive, but its beat never reaches the machine's
/// liveness table for `burst_len` consecutive rounds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HeartbeatFaultCfg {
    /// Probability a client's heartbeat starts being lost at a round.
    pub loss_prob: f64,
    /// Consecutive rounds a triggered loss persists.
    pub burst_len: usize,
}

impl Default for HeartbeatFaultCfg {
    fn default() -> Self {
        HeartbeatFaultCfg { loss_prob: 0.0, burst_len: 1 }
    }
}

/// What the store seam should do to one save call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SaveFault {
    /// Plain IO error, nothing written.
    Fail,
    /// Torn write: new ckpt half + stale state half persisted, then error.
    TornCkpt,
    /// Torn write: new state half + stale ckpt half persisted, then error.
    TornState,
}

/// What the round seam should do to one round execution attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundFault {
    /// Return an error (consumes one retry).
    Error,
    /// Panic (the worker-crash shape; quarantined by the service).
    Panic,
}

/// What the broker seam should do to one published message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BrokerFault {
    Drop,
    Duplicate,
    DelayMs(u64),
    Reorder,
}

/// A complete fault plan. See the module docs for the purity contract.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    /// Root seed every stream derives from.
    pub seed: u64,
    pub broker: BrokerFaultCfg,
    pub store: StoreFaultCfg,
    pub rounds: RoundFaultCfg,
    pub heartbeats: HeartbeatFaultCfg,
}

fn prob(doc: &TomlDoc, table: &str, key: &str) -> Result<f64> {
    match doc.get(table, key) {
        None => Ok(0.0),
        Some(v) => {
            let p = v
                .as_f64()
                .ok_or_else(|| anyhow!("fault plan: [{table}] {key} must be a number"))?;
            if !(0.0..=1.0).contains(&p) {
                return Err(anyhow!("fault plan: [{table}] {key} = {p} outside [0, 1]"));
            }
            Ok(p)
        }
    }
}

impl FaultPlan {
    /// The provably neutral plan: every decision returns no-fault.
    pub fn empty() -> FaultPlan {
        FaultPlan::default()
    }

    /// True when no decision method can ever realize a fault.
    pub fn is_empty(&self) -> bool {
        let b = &self.broker;
        let s = &self.store;
        let r = &self.rounds;
        b.drop_prob == 0.0
            && b.duplicate_prob == 0.0
            && b.delay_prob == 0.0
            && b.reorder_prob == 0.0
            && s.save_fail_prob == 0.0
            && s.load_fail_prob == 0.0
            && s.torn_ckpt_prob == 0.0
            && s.torn_state_prob == 0.0
            && r.error_prob == 0.0
            && r.panic_prob == 0.0
            && r.panic_at.is_empty()
            && self.heartbeats.loss_prob == 0.0
    }

    /// Parse a plan from TOML text (the `toml_lite` subset: a top-level
    /// `seed` plus `[broker]` / `[store]` / `[rounds]` / `[heartbeats]`
    /// tables; every key optional, probabilities validated to `[0, 1]`).
    pub fn from_toml(text: &str) -> Result<FaultPlan> {
        let doc = TomlDoc::parse(text).map_err(|e| anyhow!("fault plan: {e}"))?;
        let seed = match doc.get("", "seed") {
            None => 0,
            Some(v) => v
                .as_i64()
                .ok_or_else(|| anyhow!("fault plan: seed must be an integer"))?
                as u64,
        };
        let mut panic_at = Vec::new();
        if let Some(v) = doc.get("rounds", "panic_at") {
            let items = v
                .as_array()
                .ok_or_else(|| anyhow!("fault plan: [rounds] panic_at must be an array"))?;
            for item in items {
                let s = item.as_str().ok_or_else(|| {
                    anyhow!("fault plan: [rounds] panic_at entries must be \"session:round\"")
                })?;
                let (session, round) = s.rsplit_once(':').ok_or_else(|| {
                    anyhow!("fault plan: panic_at entry {s:?} is not \"session:round\"")
                })?;
                let round: usize = round
                    .parse()
                    .map_err(|_| anyhow!("fault plan: panic_at round in {s:?} is not a number"))?;
                panic_at.push((session.to_string(), round));
            }
        }
        let plan = FaultPlan {
            seed,
            broker: BrokerFaultCfg {
                drop_prob: prob(&doc, "broker", "drop_prob")?,
                duplicate_prob: prob(&doc, "broker", "duplicate_prob")?,
                delay_prob: prob(&doc, "broker", "delay_prob")?,
                delay_ms: doc
                    .get("broker", "delay_ms")
                    .map(|v| {
                        v.as_i64()
                            .filter(|&ms| ms >= 0)
                            .ok_or_else(|| anyhow!("fault plan: [broker] delay_ms must be >= 0"))
                    })
                    .transpose()?
                    .unwrap_or(5) as u64,
                reorder_prob: prob(&doc, "broker", "reorder_prob")?,
            },
            store: StoreFaultCfg {
                save_fail_prob: prob(&doc, "store", "save_fail_prob")?,
                load_fail_prob: prob(&doc, "store", "load_fail_prob")?,
                torn_ckpt_prob: prob(&doc, "store", "torn_ckpt_prob")?,
                torn_state_prob: prob(&doc, "store", "torn_state_prob")?,
            },
            rounds: RoundFaultCfg {
                error_prob: prob(&doc, "rounds", "error_prob")?,
                panic_prob: prob(&doc, "rounds", "panic_prob")?,
                panic_at,
            },
            heartbeats: HeartbeatFaultCfg {
                loss_prob: prob(&doc, "heartbeats", "loss_prob")?,
                burst_len: doc
                    .get("heartbeats", "burst_len")
                    .map(|v| {
                        v.as_usize().filter(|&n| n >= 1).ok_or_else(|| {
                            anyhow!("fault plan: [heartbeats] burst_len must be >= 1")
                        })
                    })
                    .transpose()?
                    .unwrap_or(1),
            },
        };
        let sums = [
            ("store", plan.store.save_fail_prob
                + plan.store.torn_ckpt_prob
                + plan.store.torn_state_prob),
            ("broker", plan.broker.drop_prob
                + plan.broker.duplicate_prob
                + plan.broker.delay_prob
                + plan.broker.reorder_prob),
            ("rounds", plan.rounds.error_prob + plan.rounds.panic_prob),
        ];
        for (table, sum) in sums {
            if sum > 1.0 {
                return Err(anyhow!(
                    "fault plan: [{table}] probabilities sum to {sum} > 1"
                ));
            }
        }
        Ok(plan)
    }

    /// Load a plan from a TOML file.
    pub fn load(path: &Path) -> Result<FaultPlan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading fault plan {path:?}"))?;
        FaultPlan::from_toml(&text).with_context(|| format!("fault plan {path:?}"))
    }

    /// The one stream derivation everything uses: a [`Pcg32`] that is a
    /// pure function of `(seed, point, session, key)`.
    fn stream(&self, point: u64, session: &str, key: u64) -> Pcg32 {
        let mut sm = SplitMix64::new(self.seed ^ point);
        let a = sm.next() ^ fnv64(session);
        let b = SplitMix64::new(a).next() ^ key.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Pcg32::seed_from_u64(SplitMix64::new(b).next())
    }

    /// One `[0, 1)` draw from a decision stream.
    fn draw(&self, point: u64, session: &str, key: u64) -> f64 {
        self.stream(point, session, key).next_f64()
    }

    /// Fate of save call number `attempt` (0-based, per session).
    pub fn save_fault(&self, session: &str, attempt: u64) -> Option<SaveFault> {
        let s = &self.store;
        if s.save_fail_prob == 0.0 && s.torn_ckpt_prob == 0.0 && s.torn_state_prob == 0.0 {
            return None;
        }
        let r = self.draw(POINT_STORE_SAVE, session, attempt);
        if r < s.save_fail_prob {
            Some(SaveFault::Fail)
        } else if r < s.save_fail_prob + s.torn_ckpt_prob {
            Some(SaveFault::TornCkpt)
        } else if r < s.save_fail_prob + s.torn_ckpt_prob + s.torn_state_prob {
            Some(SaveFault::TornState)
        } else {
            None
        }
    }

    /// Whether load call number `attempt` (0-based, per session) fails.
    pub fn load_fails(&self, session: &str, attempt: u64) -> bool {
        self.store.load_fail_prob > 0.0
            && self.draw(POINT_STORE_LOAD, session, attempt) < self.store.load_fail_prob
    }

    /// Fate of executing `round` (attempt `attempt` within this round).
    /// `panic_at` entries match regardless of attempt — an explicitly
    /// scheduled panic always fires.
    pub fn round_fault(&self, session: &str, round: usize, attempt: usize) -> Option<RoundFault> {
        let r = &self.rounds;
        if r.panic_at.iter().any(|(s, k)| s == session && *k == round) {
            return Some(RoundFault::Panic);
        }
        if r.error_prob == 0.0 && r.panic_prob == 0.0 {
            return None;
        }
        let key = (round as u64) << 8 | (attempt as u64 & 0xFF);
        let x = self.draw(POINT_ROUND, session, key);
        if x < r.error_prob {
            Some(RoundFault::Error)
        } else if x < r.error_prob + r.panic_prob {
            Some(RoundFault::Panic)
        } else {
            None
        }
    }

    /// Whether `client`'s heartbeat is lost at `round`. A loss triggered
    /// at round `r0` persists through `r0 + burst_len - 1`; membership
    /// is decided by re-deriving the trigger for the last `burst_len`
    /// rounds, so the answer stays a pure function of
    /// `(session, round, client)`.
    pub fn heartbeat_lost(&self, session: &str, round: usize, client: usize) -> bool {
        let h = &self.heartbeats;
        if h.loss_prob == 0.0 {
            return false;
        }
        let burst = h.burst_len.max(1);
        (0..burst).any(|back| {
            round.checked_sub(back).is_some_and(|r0| {
                let key = ((r0 as u64) << 20) | (client as u64 & 0xF_FFFF);
                self.draw(POINT_HEARTBEAT, session, key) < h.loss_prob
            })
        })
    }

    /// Fate of the `key`-th message published into `session`'s topics.
    pub fn broker_fault(&self, session: &str, key: u64) -> Option<BrokerFault> {
        let b = &self.broker;
        if b.drop_prob == 0.0
            && b.duplicate_prob == 0.0
            && b.delay_prob == 0.0
            && b.reorder_prob == 0.0
        {
            return None;
        }
        let r = self.draw(POINT_BROKER, session, key);
        if r < b.drop_prob {
            Some(BrokerFault::Drop)
        } else if r < b.drop_prob + b.duplicate_prob {
            Some(BrokerFault::Duplicate)
        } else if r < b.drop_prob + b.duplicate_prob + b.delay_prob {
            Some(BrokerFault::DelayMs(b.delay_ms))
        } else if r < b.drop_prob + b.duplicate_prob + b.delay_prob + b.reorder_prob {
            Some(BrokerFault::Reorder)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PLAN: &str = r#"
seed = 99

[broker]
drop_prob = 0.2
duplicate_prob = 0.1
delay_prob = 0.05
delay_ms = 3
reorder_prob = 0.05

[store]
save_fail_prob = 0.1
load_fail_prob = 0.05
torn_ckpt_prob = 0.1
torn_state_prob = 0.1

[rounds]
error_prob = 0.15
panic_prob = 0.02
panic_at = ["alpha-pso-r0:3"]

[heartbeats]
loss_prob = 0.2
burst_len = 2
"#;

    #[test]
    fn toml_roundtrip_and_validation() {
        let plan = FaultPlan::from_toml(PLAN).unwrap();
        assert_eq!(plan.seed, 99);
        assert_eq!(plan.broker.delay_ms, 3);
        assert_eq!(plan.heartbeats.burst_len, 2);
        assert_eq!(plan.rounds.panic_at, vec![("alpha-pso-r0".to_string(), 3)]);
        assert!(!plan.is_empty());
        // Out-of-range and malformed inputs are rejected.
        assert!(FaultPlan::from_toml("[store]\nsave_fail_prob = 1.5\n").is_err());
        assert!(FaultPlan::from_toml("[store]\nsave_fail_prob = 0.6\ntorn_ckpt_prob = 0.6\n")
            .is_err());
        assert!(FaultPlan::from_toml("[rounds]\npanic_at = [\"no-round\"]\n").is_err());
        assert!(FaultPlan::from_toml("[heartbeats]\nburst_len = 0\n").is_err());
        // An all-defaults document is the empty plan.
        let empty = FaultPlan::from_toml("seed = 7\n").unwrap();
        assert!(empty.is_empty());
    }

    #[test]
    fn decisions_are_pure_functions_of_their_coordinates() {
        let plan = FaultPlan::from_toml(PLAN).unwrap();
        // Query in two different orders; every answer must agree.
        let forward: Vec<Option<SaveFault>> =
            (0..200).map(|k| plan.save_fault("s0", k)).collect();
        let backward: Vec<Option<SaveFault>> =
            (0..200).rev().map(|k| plan.save_fault("s0", k)).collect();
        assert_eq!(forward, backward.into_iter().rev().collect::<Vec<_>>());
        // Interleaving other decision points changes nothing.
        let _ = plan.round_fault("s0", 3, 0);
        let _ = plan.broker_fault("s0", 17);
        let again: Vec<Option<SaveFault>> =
            (0..200).map(|k| plan.save_fault("s0", k)).collect();
        assert_eq!(forward, again);
        // And two identically-built plans realize identical sequences.
        let twin = FaultPlan::from_toml(PLAN).unwrap();
        for k in 0..200 {
            assert_eq!(plan.round_fault("s1", k as usize, 1), twin.round_fault("s1", k as usize, 1));
            assert_eq!(plan.broker_fault("s1", k), twin.broker_fault("s1", k));
            assert_eq!(plan.load_fails("s1", k), twin.load_fails("s1", k));
        }
    }

    #[test]
    fn sessions_and_points_get_disjoint_streams() {
        let plan = FaultPlan::from_toml(PLAN).unwrap();
        // Same keys, different sessions → materially different sequences.
        let a: Vec<bool> = (0..400).map(|k| plan.save_fault("alpha", k).is_some()).collect();
        let b: Vec<bool> = (0..400).map(|k| plan.save_fault("beta", k).is_some()).collect();
        assert_ne!(a, b, "per-session streams must be disjoint");
        // Same session+keys, different points → also different.
        let saves: Vec<bool> = (0..400).map(|k| plan.save_fault("alpha", k).is_some()).collect();
        let loads: Vec<bool> = (0..400).map(|k| plan.load_fails("alpha", k)).collect();
        assert_ne!(saves, loads, "per-point streams must be disjoint");
        // Different seeds → different realizations.
        let mut reseeded = plan.clone();
        reseeded.seed ^= 1;
        let c: Vec<bool> = (0..400).map(|k| reseeded.save_fault("alpha", k).is_some()).collect();
        assert_ne!(a, c, "the seed must matter");
    }

    #[test]
    fn rates_are_roughly_honored() {
        let plan = FaultPlan::from_toml(PLAN).unwrap();
        let n = 20_000;
        let drops = (0..n)
            .filter(|&k| plan.broker_fault("rate", k) == Some(BrokerFault::Drop))
            .count();
        let frac = drops as f64 / n as f64;
        assert!((0.17..0.23).contains(&frac), "drop rate {frac} vs configured 0.2");
    }

    #[test]
    fn empty_plan_is_provably_neutral() {
        let plan = FaultPlan::empty();
        assert!(plan.is_empty());
        for k in 0..100u64 {
            assert_eq!(plan.save_fault("s", k), None);
            assert!(!plan.load_fails("s", k));
            assert_eq!(plan.round_fault("s", k as usize, 0), None);
            assert_eq!(plan.broker_fault("s", k), None);
            assert!(!plan.heartbeat_lost("s", k as usize, 0));
        }
    }

    #[test]
    fn heartbeat_bursts_persist_for_burst_len_rounds() {
        let mut plan = FaultPlan::empty();
        plan.heartbeats = HeartbeatFaultCfg { loss_prob: 0.1, burst_len: 3 };
        // Find a triggered (round, client) and check persistence.
        let mut checked = 0;
        for r in 0..200usize {
            for c in 0..8usize {
                let key = ((r as u64) << 20) | c as u64;
                let triggered = plan.draw(super::POINT_HEARTBEAT, "s", key) < 0.1;
                if triggered {
                    assert!(plan.heartbeat_lost("s", r, c));
                    assert!(plan.heartbeat_lost("s", r + 1, c));
                    assert!(plan.heartbeat_lost("s", r + 2, c));
                    checked += 1;
                }
            }
        }
        assert!(checked > 50, "only {checked} triggers in 1600 draws at p=0.1");
    }

    #[test]
    fn explicit_panic_at_always_fires() {
        let plan = FaultPlan::from_toml(PLAN).unwrap();
        for attempt in 0..4 {
            assert_eq!(
                plan.round_fault("alpha-pso-r0", 3, attempt),
                Some(RoundFault::Panic)
            );
        }
        assert_ne!(plan.round_fault("alpha-pso-r0", 4, 0), Some(RoundFault::Panic));
    }
}
