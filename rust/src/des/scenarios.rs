//! Dynamic-scenario catalog: the per-round dynamics state machine
//! (churn, dropout, straggler bursts, speed drift) plus a built-in
//! matrix of named scenarios — from the paper's Fig-3 shapes up to
//! 10k-client populations — and a loader for user TOML directories.

use super::round::RoundRealization;
use crate::configio::{DesSpec, DynamicsSpec, NetSpec, SimScenario, TomlDoc};
use crate::prng::{Pcg32, Rng};

/// Session-lifetime dynamics: evolves churn membership and speed drift
/// across rounds and realizes one [`RoundRealization`] per round.
#[derive(Debug, Clone)]
pub struct Dynamics {
    spec: DynamicsSpec,
    /// Churn membership (applies to clients assigned as trainers).
    present: Vec<bool>,
    /// Drift random-walk state (slowdown component, clamped).
    drift: Vec<f64>,
    rng: Pcg32,
}

impl Dynamics {
    pub fn new(spec: DynamicsSpec, rng: Pcg32) -> Dynamics {
        Dynamics { spec, present: Vec::new(), drift: Vec::new(), rng }
    }

    /// The static no-op dynamics (conformance configuration).
    pub fn off() -> Dynamics {
        Dynamics::new(DynamicsSpec::default(), Pcg32::seed_from_u64(0))
    }

    /// Realize the next round for a population of `n` clients.
    pub fn next_round(&mut self, n: usize) -> RoundRealization {
        if self.present.len() != n {
            self.present = vec![true; n];
            self.drift = vec![1.0; n];
        }
        let round_seed = self.rng.next_u64();
        let s = self.spec.clone();
        // Churn: leave/rejoin transitions on the membership state.
        if s.churn_leave_prob > 0.0 || s.churn_join_prob > 0.0 {
            for p in &mut self.present {
                let flip = if *p { s.churn_leave_prob } else { s.churn_join_prob };
                if flip > 0.0 && self.rng.next_f64() < flip {
                    *p = !*p;
                }
            }
        }
        // Speed drift: bounded lognormal random walk per client.
        if s.drift_sigma > 0.0 {
            for d in &mut self.drift {
                *d = (*d * self.rng.lognormal(s.drift_sigma)).clamp(0.25, 4.0);
            }
        }
        let mut slowdown = self.drift.clone();
        // Straggler burst: this round, a sampled fraction runs slower.
        if s.straggler_prob > 0.0 && self.rng.next_f64() < s.straggler_prob {
            let k = ((n as f64 * s.straggler_frac).ceil() as usize).min(n);
            for i in self.rng.sample_distinct(n, k) {
                slowdown[i] *= s.straggler_slowdown;
            }
        }
        // Dropout: per-round one-off absences on top of churn.
        let mut active = self.present.clone();
        if s.dropout_prob > 0.0 {
            for a in &mut active {
                if *a && self.rng.next_f64() < s.dropout_prob {
                    *a = false;
                }
            }
        }
        RoundRealization { active, slowdown, round_seed }
    }
}

/// A catalog entry: a scenario plus its presentation name.
#[derive(Debug, Clone, PartialEq)]
pub struct NamedScenario {
    pub name: String,
    pub sim: SimScenario,
}

/// Dynamics variants crossed with every base size in the built-in
/// catalog (name suffix, spec editor).
fn variants() -> Vec<(&'static str, fn(&mut DesSpec))> {
    vec![
        ("static", |_| {}),
        ("dropout", |d| d.dynamics.dropout_prob = 0.15),
        ("churn", |d| {
            d.dynamics.churn_leave_prob = 0.05;
            d.dynamics.churn_join_prob = 0.5;
        }),
        ("straggler", |d| {
            d.dynamics.straggler_prob = 0.3;
            d.dynamics.straggler_frac = 0.2;
            d.dynamics.straggler_slowdown = 4.0;
        }),
        ("jitter", |d| {
            d.net.latency_range_s = (0.001, 0.02);
            d.net.bandwidth_range = (5.0, 50.0);
            d.net.jitter_sigma = 0.5;
        }),
        ("drift", |d| d.dynamics.drift_sigma = 0.05),
    ]
}

/// The built-in scenario matrix: four population scales (7 → 10k+
/// clients) × six dynamics variants, plus a contended-uplink case and a
/// 10k-client everything-on stress case. 26 scenarios, every one with a
/// distinct seed, all scored by the event-driven oracle.
pub fn builtin_catalog() -> Vec<NamedScenario> {
    // (name, depth, width, trainers_per_leaf, pso iterations)
    let sizes: [(&str, usize, usize, usize, usize); 4] = [
        ("tiny", 2, 2, 2, 20),      // 7 clients
        ("paper", 3, 4, 2, 12),     // 53 clients (Fig-3 panel a)
        ("deep", 4, 4, 2, 8),       // 213 clients (Fig-3 panel b)
        ("mega10k", 3, 4, 625, 4),  // 10 021 clients
    ];
    let mut catalog = Vec::new();
    let base = |name: &str, i: usize| -> SimScenario {
        let (_, depth, width, tpl, iters) = sizes[i];
        let mut sc = SimScenario {
            depth,
            width,
            trainers_per_leaf: tpl,
            env: "event-driven".to_string(),
            ..SimScenario::default()
        };
        sc.pso.particles = 5;
        sc.pso.iterations = iters;
        // Distinct, stable seed per scenario name.
        sc.seed = 1000 + catalog_seed(name);
        sc
    };
    for (i, (size, ..)) in sizes.iter().enumerate() {
        for (variant, edit) in variants() {
            let name = format!("{size}-{variant}");
            let mut sc = base(&name, i);
            edit(&mut sc.des);
            catalog.push(NamedScenario { name, sim: sc });
        }
    }
    // Contended shared uplink at the paper scale.
    let mut contended = base("paper-contended", 1);
    contended.des.net.latency_range_s = (0.001, 0.01);
    contended.des.net.bandwidth_range = (5.0, 50.0);
    contended.des.net.agg_ingress = 25.0;
    catalog.push(NamedScenario { name: "paper-contended".into(), sim: contended });
    // Everything on at 10k clients.
    let mut mixed = base("mega10k-mixed", 3);
    for (_, edit) in variants() {
        edit(&mut mixed.des);
    }
    mixed.des.net.agg_ingress = 500.0;
    mixed.des.train_unit = 1.0;
    catalog.push(NamedScenario { name: "mega10k-mixed".into(), sim: mixed });
    catalog
}

/// FNV-1a over the scenario name — stable seeds without global state.
fn catalog_seed(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h % 100_000
}

/// Load every `*.toml` scenario in a directory (sorted by file name;
/// the scenario name is the file stem). Files use the `[sim]`/`[pso]`
/// tables plus the `[des]`/`[net]`/`[dynamics]` extensions.
pub fn load_dir(dir: &std::path::Path) -> Result<Vec<NamedScenario>, String> {
    let mut paths: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| format!("{dir:?}: {e}"))?
        .filter_map(|r| r.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "toml"))
        .collect();
    paths.sort();
    if paths.is_empty() {
        return Err(format!("{dir:?}: no .toml scenario files"));
    }
    let mut out = Vec::with_capacity(paths.len());
    for p in paths {
        let doc = TomlDoc::load(&p)?;
        let sim = SimScenario::from_toml(&doc).map_err(|e| format!("{p:?}: {e}"))?;
        let name = p
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("scenario")
            .to_string();
        out.push(NamedScenario { name, sim });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_covers_the_acceptance_matrix() {
        let cat = builtin_catalog();
        assert!(cat.len() >= 20, "only {} scenarios", cat.len());
        let names: Vec<&str> = cat.iter().map(|s| s.name.as_str()).collect();
        for required in ["churn", "dropout", "straggler"] {
            assert!(
                names.iter().any(|n| n.contains(required)),
                "missing a {required} scenario"
            );
        }
        // 10k-client cases present, including dynamic ones.
        let mega: Vec<&NamedScenario> =
            cat.iter().filter(|s| s.sim.client_count() >= 10_000).collect();
        assert!(mega.len() >= 4, "only {} 10k-client scenarios", mega.len());
        assert!(mega.iter().any(|s| !s.sim.des.dynamics.is_static()));
        // Names and seeds are unique (independent randomness per cell).
        let mut uniq: Vec<&str> = names.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), cat.len(), "duplicate scenario names");
        let mut seeds: Vec<u64> = cat.iter().map(|s| s.sim.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), cat.len(), "seed collision in catalog");
        // Everything is scored by the event-driven oracle.
        assert!(cat.iter().all(|s| s.sim.env == "event-driven"));
    }

    #[test]
    fn dynamics_are_deterministic_per_seed() {
        let spec = DynamicsSpec {
            dropout_prob: 0.2,
            churn_leave_prob: 0.1,
            churn_join_prob: 0.4,
            straggler_prob: 0.5,
            straggler_frac: 0.25,
            straggler_slowdown: 3.0,
            drift_sigma: 0.1,
        };
        let mut a = Dynamics::new(spec.clone(), Pcg32::seed_from_u64(9));
        let mut b = Dynamics::new(spec, Pcg32::seed_from_u64(9));
        for _ in 0..20 {
            assert_eq!(a.next_round(30), b.next_round(30));
        }
    }

    #[test]
    fn static_dynamics_realize_identity() {
        let mut d = Dynamics::off();
        for _ in 0..5 {
            let r = d.next_round(12);
            assert!(r.active.iter().all(|&a| a));
            assert!(r.slowdown.iter().all(|&s| s == 1.0));
        }
    }

    #[test]
    fn churn_members_come_and_go() {
        let spec = DynamicsSpec {
            churn_leave_prob: 0.3,
            churn_join_prob: 0.3,
            ..DynamicsSpec::default()
        };
        let mut d = Dynamics::new(spec, Pcg32::seed_from_u64(4));
        let mut ever_absent = vec![false; 40];
        let mut rejoined = false;
        let mut was_absent = vec![false; 40];
        for _ in 0..40 {
            let r = d.next_round(40);
            for (i, &on) in r.active.iter().enumerate() {
                if !on {
                    ever_absent[i] = true;
                    was_absent[i] = true;
                } else if was_absent[i] {
                    rejoined = true;
                    was_absent[i] = false;
                }
            }
        }
        assert!(ever_absent.iter().any(|&x| x), "nobody ever left");
        assert!(rejoined, "nobody ever rejoined");
    }

    #[test]
    fn drift_stays_bounded() {
        let spec = DynamicsSpec { drift_sigma: 0.5, ..DynamicsSpec::default() };
        let mut d = Dynamics::new(spec, Pcg32::seed_from_u64(8));
        for _ in 0..200 {
            let r = d.next_round(10);
            assert!(r.slowdown.iter().all(|&s| (0.25..=4.0).contains(&s)));
        }
    }

    #[test]
    fn load_dir_roundtrips_toml_scenarios() {
        let dir = std::env::temp_dir().join("repro_des_scenarios_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("b_churny.toml"),
            "[sim]\ndepth = 2\nwidth = 2\nenv = \"event-driven\"\n[dynamics]\nleave = 0.1\njoin = 0.5\n",
        )
        .unwrap();
        std::fs::write(dir.join("a_static.toml"), "[sim]\ndepth = 3\nwidth = 2\n").unwrap();
        std::fs::write(dir.join("notes.txt"), "ignored").unwrap();
        let got = load_dir(&dir).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].name, "a_static");
        assert_eq!(got[1].name, "b_churny");
        assert_eq!(got[1].sim.des.dynamics.churn_leave_prob, 0.1);
        assert!(load_dir(&dir.join("missing")).is_err());
    }
}
