//! Dynamic-scenario catalog: the per-round dynamics state machine
//! (churn, dropout, straggler bursts, speed drift, correlated regional
//! failures, multi-round network partitions) plus a built-in matrix of
//! named scenarios — from the paper's Fig-3 shapes up to 10k-client
//! populations — and a loader for user TOML directories.

use super::round::RoundRealization;
use crate::configio::{DesSpec, DynamicsSpec, NetSpec, SimScenario, TomlDoc};
use crate::prng::{Pcg32, Rng};

/// Session-lifetime dynamics: evolves churn membership, speed drift and
/// partition state across rounds and realizes one [`RoundRealization`]
/// per round.
///
/// Invariants the fleet's statistics rely on (property-tested in
/// `tests/properties.rs`): the same seed yields the identical
/// realization sequence; the live-client count never leaves `[1, n]`
/// (a fully-dark round is floored to one deterministic survivor); and
/// because `active` only gates clients *assigned as trainers* — slots
/// always serve — no failure mechanism can orphan an aggregator that
/// still has uploads scheduled toward it.
#[derive(Debug, Clone)]
pub struct Dynamics {
    spec: DynamicsSpec,
    /// Churn membership (applies to clients assigned as trainers).
    present: Vec<bool>,
    /// Drift random-walk state (slowdown component, clamped).
    drift: Vec<f64>,
    /// Active network partition: (region start, region len, rounds left
    /// *after* the current one).
    partition: Option<(usize, usize, usize)>,
    rng: Pcg32,
}

impl Dynamics {
    pub fn new(spec: DynamicsSpec, rng: Pcg32) -> Dynamics {
        Dynamics { spec, present: Vec::new(), drift: Vec::new(), partition: None, rng }
    }

    /// The static no-op dynamics (conformance configuration).
    pub fn off() -> Dynamics {
        Dynamics::new(DynamicsSpec::default(), Pcg32::seed_from_u64(0))
    }

    /// Realize the next round for a population of `n` clients.
    pub fn next_round(&mut self, n: usize) -> RoundRealization {
        let mut real = RoundRealization { active: Vec::new(), slowdown: Vec::new(), round_seed: 0 };
        self.next_round_into(n, &mut real);
        real
    }

    /// [`Dynamics::next_round`] writing into an existing realization —
    /// the oracle's steady-state path, reusing `real`'s buffers so
    /// advancing the dynamics between batches allocates nothing. Same
    /// RNG draw order as `next_round`, so realizations are identical.
    pub fn next_round_into(&mut self, n: usize, real: &mut RoundRealization) {
        if self.present.len() != n {
            self.present = vec![true; n];
            self.drift = vec![1.0; n];
            self.partition = None;
        }
        let round_seed = self.rng.next_u64();
        let s = self.spec.clone();
        // Churn: leave/rejoin transitions on the membership state.
        if s.churn_leave_prob > 0.0 || s.churn_join_prob > 0.0 {
            for p in &mut self.present {
                let flip = if *p { s.churn_leave_prob } else { s.churn_join_prob };
                if flip > 0.0 && self.rng.next_f64() < flip {
                    *p = !*p;
                }
            }
        }
        // Speed drift: bounded lognormal random walk per client.
        if s.drift_sigma > 0.0 {
            for d in &mut self.drift {
                *d = (*d * self.rng.lognormal(s.drift_sigma)).clamp(0.25, 4.0);
            }
        }
        real.slowdown.clear();
        real.slowdown.extend_from_slice(&self.drift);
        let slowdown = &mut real.slowdown;
        // Straggler burst: this round, a sampled fraction runs slower.
        if s.straggler_prob > 0.0 && self.rng.next_f64() < s.straggler_prob {
            let k = ((n as f64 * s.straggler_frac).ceil() as usize).min(n);
            for i in self.rng.sample_distinct(n, k) {
                slowdown[i] *= s.straggler_slowdown;
            }
        }
        // Dropout: per-round one-off absences on top of churn.
        real.active.clear();
        real.active.extend_from_slice(&self.present);
        let active = &mut real.active;
        if s.dropout_prob > 0.0 {
            for a in active.iter_mut() {
                if *a && self.rng.next_f64() < s.dropout_prob {
                    *a = false;
                }
            }
        }
        // Correlated failure: one contiguous id region (a rack / edge
        // site) fails together for this round only, re-sampled per round.
        if s.corr_fail_prob > 0.0 && self.rng.next_f64() < s.corr_fail_prob {
            let start = self.rng.gen_range(n as u64) as usize;
            mark_region_inactive(active, start, region_len(n, s.corr_fail_frac));
        }
        // Network partition: a sampled region goes unreachable and stays
        // unreachable for `partition_rounds` consecutive rounds.
        if s.partition_prob > 0.0 {
            if self.partition.is_none() && self.rng.next_f64() < s.partition_prob {
                let start = self.rng.gen_range(n as u64) as usize;
                self.partition =
                    Some((start, region_len(n, s.partition_frac), s.partition_rounds));
            }
            if let Some((start, len, rounds_left)) = self.partition {
                mark_region_inactive(active, start, len);
                self.partition =
                    (rounds_left > 1).then_some((start, len, rounds_left - 1));
            }
        }
        // Live-count floor: a session with zero reachable trainers is
        // not a round the paper's protocol can run, so one
        // deterministically-chosen survivor always participates.
        if !active.iter().any(|&a| a) {
            active[(round_seed % n as u64) as usize] = true;
        }
        real.round_seed = round_seed;
    }
}

/// Clients inside a failing region: `ceil(n · frac)`, clamped to
/// `[1, n]` (a region never empties the whole mechanism into a no-op).
fn region_len(n: usize, frac: f64) -> usize {
    ((n as f64 * frac).ceil() as usize).clamp(1, n)
}

/// Deactivate the contiguous (wrapping) id region `start..start+len`.
fn mark_region_inactive(active: &mut [bool], start: usize, len: usize) {
    let n = active.len();
    for i in 0..len.min(n) {
        active[(start + i) % n] = false;
    }
}

/// A catalog entry: a scenario plus its presentation name.
#[derive(Debug, Clone, PartialEq)]
pub struct NamedScenario {
    pub name: String,
    pub sim: SimScenario,
}

/// The ablatable mechanism registry: every dynamic/network behavior a
/// scenario can switch on, addressable by a stable dotted key (used by
/// `repro ablate --mechanisms k1,k2`). Each entry is `(key, summary)`.
pub const MECHANISMS: [(&str, &str); 9] = [
    ("dynamics.dropout", "per-round trainer dropout"),
    ("dynamics.churn", "leave/rejoin membership churn"),
    ("dynamics.straggler", "straggler bursts"),
    ("dynamics.drift", "per-client speed-drift random walk"),
    ("dynamics.corr_fail", "correlated regional failures"),
    ("dynamics.partition", "multi-round network partitions"),
    ("net.jitter", "lognormal per-transfer latency jitter"),
    ("net.contention", "shared aggregator ingress capacity"),
    ("net.asym", "up/down bandwidth asymmetry"),
];

fn unknown_mechanism(key: &str) -> String {
    let valid: Vec<&str> = MECHANISMS.iter().map(|(k, _)| *k).collect();
    format!("unknown mechanism {key:?}; valid mechanisms: {}", valid.join(", "))
}

/// Whether `key`'s mechanism is switched on in `des`.
pub fn mechanism_enabled(des: &DesSpec, key: &str) -> Result<bool, String> {
    Ok(match key {
        "dynamics.dropout" => des.dynamics.dropout_prob > 0.0,
        "dynamics.churn" => {
            des.dynamics.churn_leave_prob > 0.0 || des.dynamics.churn_join_prob > 0.0
        }
        "dynamics.straggler" => des.dynamics.straggler_prob > 0.0,
        "dynamics.drift" => des.dynamics.drift_sigma > 0.0,
        "dynamics.corr_fail" => des.dynamics.corr_fail_prob > 0.0,
        "dynamics.partition" => des.dynamics.partition_prob > 0.0,
        "net.jitter" => des.net.jitter_sigma > 0.0,
        "net.contention" => des.net.agg_ingress > 0.0,
        "net.asym" => des.net.up_asymmetry_enabled() || des.net.down_asymmetry_enabled(),
        other => return Err(unknown_mechanism(other)),
    })
}

/// Switch `key`'s mechanism off in place (the one-mechanism-off
/// scenario variants `repro ablate` materializes). Disabling an
/// already-off mechanism is a no-op, so ablated variants of a scenario
/// that never had the mechanism reproduce the baseline bit for bit.
pub fn disable_mechanism(des: &mut DesSpec, key: &str) -> Result<(), String> {
    match key {
        "dynamics.dropout" => des.dynamics.dropout_prob = 0.0,
        "dynamics.churn" => {
            des.dynamics.churn_leave_prob = 0.0;
            des.dynamics.churn_join_prob = 0.0;
        }
        "dynamics.straggler" => des.dynamics.straggler_prob = 0.0,
        "dynamics.drift" => des.dynamics.drift_sigma = 0.0,
        "dynamics.corr_fail" => des.dynamics.corr_fail_prob = 0.0,
        "dynamics.partition" => des.dynamics.partition_prob = 0.0,
        "net.jitter" => des.net.jitter_sigma = 0.0,
        "net.contention" => des.net.agg_ingress = 0.0,
        "net.asym" => {
            des.net.up_mult_range = (0.0, 0.0);
            des.net.down_mult_range = (0.0, 0.0);
        }
        other => return Err(unknown_mechanism(other)),
    }
    Ok(())
}

/// Dynamics variants crossed with every base size in the built-in
/// catalog (name suffix, spec editor).
fn variants() -> Vec<(&'static str, fn(&mut DesSpec))> {
    vec![
        ("static", |_| {}),
        ("dropout", |d| d.dynamics.dropout_prob = 0.15),
        ("churn", |d| {
            d.dynamics.churn_leave_prob = 0.05;
            d.dynamics.churn_join_prob = 0.5;
        }),
        ("straggler", |d| {
            d.dynamics.straggler_prob = 0.3;
            d.dynamics.straggler_frac = 0.2;
            d.dynamics.straggler_slowdown = 4.0;
        }),
        ("jitter", |d| {
            d.net.latency_range_s = (0.001, 0.02);
            d.net.bandwidth_range = (5.0, 50.0);
            d.net.jitter_sigma = 0.5;
        }),
        ("drift", |d| d.dynamics.drift_sigma = 0.05),
        ("corrfail", |d| {
            d.dynamics.corr_fail_prob = 0.25;
            d.dynamics.corr_fail_frac = 0.3;
        }),
        ("partition", |d| {
            d.dynamics.partition_prob = 0.15;
            d.dynamics.partition_frac = 0.25;
            d.dynamics.partition_rounds = 3;
        }),
        ("asym", |d| {
            d.net.latency_range_s = (0.001, 0.01);
            d.net.bandwidth_range = (5.0, 50.0);
            d.net.up_mult_range = (0.5, 1.0);
            d.net.down_mult_range = (0.2, 1.0);
        }),
    ]
}

/// The built-in scenario matrix: four population scales (7 → 10k+
/// clients) × nine dynamics variants, plus a contended-uplink case, a
/// 10k-client everything-on stress case, and two static mega-scale
/// cases (`mega100k` / `mega1M` — ROADMAP item 2's 100k–1M-client
/// regime, kept static so the level-barrier delta fast path applies).
/// 40 scenarios, every one with a distinct seed, all scored by the
/// event-driven oracle.
pub fn builtin_catalog() -> Vec<NamedScenario> {
    // (name, depth, width, trainers_per_leaf, pso iterations)
    let sizes: [(&str, usize, usize, usize, usize); 4] = [
        ("tiny", 2, 2, 2, 20),      // 7 clients
        ("paper", 3, 4, 2, 12),     // 53 clients (Fig-3 panel a)
        ("deep", 4, 4, 2, 8),       // 213 clients (Fig-3 panel b)
        ("mega10k", 3, 4, 625, 4),  // 10 021 clients
    ];
    let mut catalog = Vec::new();
    let base = |name: &str, i: usize| -> SimScenario {
        let (_, depth, width, tpl, iters) = sizes[i];
        let mut sc = SimScenario {
            depth,
            width,
            trainers_per_leaf: tpl,
            env: "event-driven".to_string(),
            ..SimScenario::default()
        };
        sc.pso.particles = 5;
        sc.pso.iterations = iters;
        // Distinct, stable seed per scenario name.
        sc.seed = 1000 + catalog_seed(name);
        sc
    };
    for (i, (size, ..)) in sizes.iter().enumerate() {
        for (variant, edit) in variants() {
            let name = format!("{size}-{variant}");
            let mut sc = base(&name, i);
            edit(&mut sc.des);
            catalog.push(NamedScenario { name, sim: sc });
        }
    }
    // Contended shared uplink at the paper scale.
    let mut contended = base("paper-contended", 1);
    contended.des.net.latency_range_s = (0.001, 0.01);
    contended.des.net.bandwidth_range = (5.0, 50.0);
    contended.des.net.agg_ingress = 25.0;
    catalog.push(NamedScenario { name: "paper-contended".into(), sim: contended });
    // Everything on at 10k clients.
    let mut mixed = base("mega10k-mixed", 3);
    for (_, edit) in variants() {
        edit(&mut mixed.des);
    }
    mixed.des.net.agg_ingress = 500.0;
    mixed.des.train_unit = 1.0;
    catalog.push(NamedScenario { name: "mega10k-mixed".into(), sim: mixed });
    // Mega-scale static cases: free network, nominal realization, so
    // every single-coordinate PSO/SA move is delta-scored at O(slots)
    // while full candidates still simulate. Iteration budgets shrink
    // with scale — the full base rounds dominate the wall clock.
    for (name, tpl, iters) in [("mega100k", 6250usize, 2usize), ("mega1M", 62_500, 1)] {
        let mut sc = SimScenario {
            depth: 3,
            width: 4,
            trainers_per_leaf: tpl,
            env: "event-driven".to_string(),
            ..SimScenario::default()
        };
        sc.pso.particles = 5;
        sc.pso.iterations = iters;
        sc.seed = 1000 + catalog_seed(name);
        catalog.push(NamedScenario { name: name.into(), sim: sc });
    }
    catalog
}

/// FNV-1a over the scenario name — stable seeds without global state.
fn catalog_seed(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h % 100_000
}

/// Load every `*.toml` scenario in a directory (sorted by file name;
/// the scenario name is the file stem). Files use the `[sim]`/`[pso]`
/// tables plus the `[des]`/`[net]`/`[dynamics]` extensions.
pub fn load_dir(dir: &std::path::Path) -> Result<Vec<NamedScenario>, String> {
    let mut paths: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| format!("{dir:?}: {e}"))?
        .filter_map(|r| r.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "toml"))
        .collect();
    paths.sort();
    if paths.is_empty() {
        return Err(format!("{dir:?}: no .toml scenario files"));
    }
    let mut out = Vec::with_capacity(paths.len());
    for p in paths {
        let doc = TomlDoc::load(&p)?;
        let sim = SimScenario::from_toml(&doc).map_err(|e| format!("{p:?}: {e}"))?;
        let name = p
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("scenario")
            .to_string();
        out.push(NamedScenario { name, sim });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_covers_the_acceptance_matrix() {
        let cat = builtin_catalog();
        assert!(cat.len() >= 34, "only {} scenarios", cat.len());
        let names: Vec<&str> = cat.iter().map(|s| s.name.as_str()).collect();
        for required in ["churn", "dropout", "straggler", "corrfail", "partition", "asym"] {
            assert!(
                names.iter().any(|n| n.contains(required)),
                "missing a {required} scenario"
            );
        }
        // The new mechanisms are actually switched on in their variants.
        let by_suffix = |suffix: &str| {
            cat.iter()
                .find(|s| s.name == format!("tiny-{suffix}"))
                .unwrap_or_else(|| panic!("no tiny-{suffix}"))
        };
        assert!(by_suffix("corrfail").sim.des.dynamics.corr_fail_prob > 0.0);
        assert!(by_suffix("partition").sim.des.dynamics.partition_rounds >= 1);
        assert!(by_suffix("asym").sim.des.net.down_asymmetry_enabled());
        // Every built-in passes its own validation (the TOML gate).
        for s in &cat {
            s.sim.des.validate().unwrap_or_else(|e| panic!("{}: {e}", s.name));
        }
        // 10k-client cases present, including dynamic ones.
        let mega: Vec<&NamedScenario> =
            cat.iter().filter(|s| s.sim.client_count() >= 10_000).collect();
        assert!(mega.len() >= 4, "only {} 10k-client scenarios", mega.len());
        assert!(mega.iter().any(|s| !s.sim.des.dynamics.is_static()));
        // The ROADMAP item-2 scales, static so the delta path applies.
        let by_name = |name: &str| {
            cat.iter().find(|s| s.name == name).unwrap_or_else(|| panic!("no {name}"))
        };
        assert_eq!(by_name("mega100k").sim.client_count(), 100_021);
        assert_eq!(by_name("mega1M").sim.client_count(), 1_000_021);
        for name in ["mega100k", "mega1M"] {
            let s = by_name(name);
            assert!(s.sim.des.dynamics.is_static(), "{name} must be static");
            assert_eq!(s.sim.des.train_unit, 0.0);
        }
        // Names and seeds are unique (independent randomness per cell).
        let mut uniq: Vec<&str> = names.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), cat.len(), "duplicate scenario names");
        let mut seeds: Vec<u64> = cat.iter().map(|s| s.sim.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), cat.len(), "seed collision in catalog");
        // Everything is scored by the event-driven oracle.
        assert!(cat.iter().all(|s| s.sim.env == "event-driven"));
    }

    #[test]
    fn dynamics_are_deterministic_per_seed() {
        let spec = DynamicsSpec {
            dropout_prob: 0.2,
            churn_leave_prob: 0.1,
            churn_join_prob: 0.4,
            straggler_prob: 0.5,
            straggler_frac: 0.25,
            straggler_slowdown: 3.0,
            drift_sigma: 0.1,
            corr_fail_prob: 0.3,
            corr_fail_frac: 0.2,
            partition_prob: 0.2,
            partition_frac: 0.25,
            partition_rounds: 2,
        };
        let mut a = Dynamics::new(spec.clone(), Pcg32::seed_from_u64(9));
        let mut b = Dynamics::new(spec, Pcg32::seed_from_u64(9));
        for _ in 0..20 {
            assert_eq!(a.next_round(30), b.next_round(30));
        }
    }

    #[test]
    fn next_round_into_matches_next_round_exactly() {
        // The buffer-reusing path must realize the identical sequence
        // (same RNG draw order) as the allocating wrapper.
        let spec = DynamicsSpec {
            dropout_prob: 0.2,
            churn_leave_prob: 0.1,
            churn_join_prob: 0.4,
            straggler_prob: 0.5,
            straggler_frac: 0.25,
            straggler_slowdown: 3.0,
            drift_sigma: 0.1,
            corr_fail_prob: 0.3,
            corr_fail_frac: 0.2,
            partition_prob: 0.2,
            partition_frac: 0.25,
            partition_rounds: 2,
        };
        let mut a = Dynamics::new(spec.clone(), Pcg32::seed_from_u64(13));
        let mut b = Dynamics::new(spec, Pcg32::seed_from_u64(13));
        let mut reused =
            RoundRealization { active: Vec::new(), slowdown: Vec::new(), round_seed: 0 };
        for _ in 0..25 {
            let fresh = a.next_round(30);
            b.next_round_into(30, &mut reused);
            assert_eq!(fresh, reused);
        }
    }

    #[test]
    fn correlated_failure_takes_out_a_region_together() {
        let spec = DynamicsSpec {
            corr_fail_prob: 1.0, // every round has a failing region
            corr_fail_frac: 0.25,
            ..DynamicsSpec::default()
        };
        let mut d = Dynamics::new(spec, Pcg32::seed_from_u64(21));
        let n = 40;
        for _ in 0..30 {
            let r = d.next_round(n);
            let down: Vec<usize> =
                (0..n).filter(|&i| !r.active[i]).collect();
            // ceil(40 · 0.25) = 10 contiguous (wrapping) ids fail.
            assert_eq!(down.len(), 10, "{down:?}");
            let start = down[0];
            let contiguous = (0..n).any(|s| {
                (0..down.len()).all(|k| !r.active[(s + k) % n])
                    && down.len() == r.active.iter().filter(|&&a| !a).count()
            });
            assert!(contiguous, "region not contiguous: {down:?} (first {start})");
        }
    }

    #[test]
    fn partition_outage_spans_consecutive_rounds() {
        let spec = DynamicsSpec {
            partition_prob: 1.0, // starts immediately, restarts when over
            partition_frac: 0.2,
            partition_rounds: 3,
            ..DynamicsSpec::default()
        };
        let mut d = Dynamics::new(spec, Pcg32::seed_from_u64(5));
        let n = 30;
        // Collect the inactive set per round; the same region must stay
        // down for 3 rounds before a new one is sampled.
        let downs: Vec<Vec<usize>> = (0..9)
            .map(|_| {
                let r = d.next_round(n);
                (0..n).filter(|&i| !r.active[i]).collect()
            })
            .collect();
        for chunk in downs.chunks(3) {
            assert_eq!(chunk[0], chunk[1]);
            assert_eq!(chunk[1], chunk[2]);
            assert_eq!(chunk[0].len(), 6); // ceil(30 · 0.2)
        }
        // Across epochs the region re-samples (same would be a 1-in-30
        // coincidence for this seed; assert it differs somewhere).
        assert!(downs[0] != downs[3] || downs[3] != downs[6], "region never moved");
    }

    #[test]
    fn live_count_never_hits_zero_even_under_total_failure() {
        // corr_fail_frac 1.0 would darken everyone; the floor keeps one.
        let spec = DynamicsSpec {
            corr_fail_prob: 1.0,
            corr_fail_frac: 1.0,
            dropout_prob: 1.0,
            ..DynamicsSpec::default()
        };
        let mut d = Dynamics::new(spec, Pcg32::seed_from_u64(3));
        for _ in 0..20 {
            let r = d.next_round(15);
            let live = r.active.iter().filter(|&&a| a).count();
            assert_eq!(live, 1, "floor must keep exactly the one survivor");
        }
    }

    #[test]
    fn static_dynamics_realize_identity() {
        let mut d = Dynamics::off();
        for _ in 0..5 {
            let r = d.next_round(12);
            assert!(r.active.iter().all(|&a| a));
            assert!(r.slowdown.iter().all(|&s| s == 1.0));
        }
    }

    #[test]
    fn churn_members_come_and_go() {
        let spec = DynamicsSpec {
            churn_leave_prob: 0.3,
            churn_join_prob: 0.3,
            ..DynamicsSpec::default()
        };
        let mut d = Dynamics::new(spec, Pcg32::seed_from_u64(4));
        let mut ever_absent = vec![false; 40];
        let mut rejoined = false;
        let mut was_absent = vec![false; 40];
        for _ in 0..40 {
            let r = d.next_round(40);
            for (i, &on) in r.active.iter().enumerate() {
                if !on {
                    ever_absent[i] = true;
                    was_absent[i] = true;
                } else if was_absent[i] {
                    rejoined = true;
                    was_absent[i] = false;
                }
            }
        }
        assert!(ever_absent.iter().any(|&x| x), "nobody ever left");
        assert!(rejoined, "nobody ever rejoined");
    }

    #[test]
    fn drift_stays_bounded() {
        let spec = DynamicsSpec { drift_sigma: 0.5, ..DynamicsSpec::default() };
        let mut d = Dynamics::new(spec, Pcg32::seed_from_u64(8));
        for _ in 0..200 {
            let r = d.next_round(10);
            assert!(r.slowdown.iter().all(|&s| (0.25..=4.0).contains(&s)));
        }
    }

    #[test]
    fn mechanism_registry_covers_every_catalog_variant() {
        // Every dynamics variant in the catalog is addressable by a
        // mechanism key, toggling off round-trips validation, and
        // disabling an off mechanism is a spec no-op.
        let cat = builtin_catalog();
        for (key, _) in MECHANISMS {
            let hit = cat
                .iter()
                .any(|s| mechanism_enabled(&s.sim.des, key).unwrap());
            assert!(hit, "no builtin scenario enables {key}");
        }
        let mixed = cat.iter().find(|s| s.name == "mega10k-mixed").unwrap();
        for (key, _) in MECHANISMS {
            assert!(mechanism_enabled(&mixed.sim.des, key).unwrap(), "{key} off in mixed");
            let mut des = mixed.sim.des.clone();
            disable_mechanism(&mut des, key).unwrap();
            assert!(!mechanism_enabled(&des, key).unwrap(), "{key} survived disabling");
            des.validate().unwrap_or_else(|e| panic!("{key}: disabled spec invalid: {e}"));
            // Only that mechanism changed: re-disabling is idempotent.
            let mut again = des.clone();
            disable_mechanism(&mut again, key).unwrap();
            assert_eq!(des, again);
        }
        // Disabling a mechanism that was never on leaves the spec
        // untouched (the ablate no-op contract).
        let tiny = cat.iter().find(|s| s.name == "tiny-static").unwrap();
        let mut des = tiny.sim.des.clone();
        disable_mechanism(&mut des, "dynamics.corr_fail").unwrap();
        assert_eq!(des, tiny.sim.des);
        // Unknown keys are actionable errors.
        let err = mechanism_enabled(&des, "dynamics.gremlins").unwrap_err();
        assert!(err.contains("valid mechanisms"), "{err}");
        assert!(disable_mechanism(&mut des, "net.gremlins").is_err());
    }

    #[test]
    fn load_dir_roundtrips_toml_scenarios() {
        let dir = std::env::temp_dir().join("repro_des_scenarios_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("b_churny.toml"),
            "[sim]\ndepth = 2\nwidth = 2\nenv = \"event-driven\"\n[dynamics]\nleave = 0.1\njoin = 0.5\n",
        )
        .unwrap();
        std::fs::write(dir.join("a_static.toml"), "[sim]\ndepth = 3\nwidth = 2\n").unwrap();
        std::fs::write(dir.join("notes.txt"), "ignored").unwrap();
        let got = load_dir(&dir).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].name, "a_static");
        assert_eq!(got[1].name, "b_churny");
        assert_eq!(got[1].sim.des.dynamics.churn_leave_prob, 0.1);
        assert!(load_dir(&dir.join("missing")).is_err());
    }
}
