//! The `repro fleet` runner: a scenario × strategy × replicate matrix
//! executed across OS threads, every cell driving one registry optimizer
//! against the event-driven oracle in virtual time. Results are
//! deterministic per seed and independent of the thread count — each
//! job derives all of its randomness from its scenario's seed (plus a
//! per-replicate derivation), and cells are ranked and reported in a
//! fixed order after the join.
//!
//! ## Statistics
//!
//! A single seed per cell makes the standings a lottery: one lucky
//! dynamics realization can flip who "wins" a scenario. With
//! `--replicates R` every (scenario, strategy) cell is scored `R` times
//! under `R` *derived* seeds. The seed for replicate `r` depends only on
//! the scenario (not the strategy), so within a scenario all strategies
//! face the identical population, network and dynamics *process* per
//! replicate — paired trials. The pairing is evaluation-exact between
//! strategies that propose one candidate per round (every registry
//! strategy except `ga` and `pso-batched`): [`EventDrivenEnv`] advances
//! its realization once per `eval_batch`, so cohort-batching optimizers
//! see the same realization sequence per *batch* rather than per
//! evaluation. Cells then report the replicate mean ± a
//! 95% Student-t confidence interval, per-scenario ranks are computed on
//! replicate means, and [`significance_matrix`] runs a paired sign test
//! of the best-ranked strategy against every other over the
//! (scenario, replicate) pairs.

use super::round::EventDrivenEnv;
use super::scenarios::NamedScenario;
use crate::fitness::ClientAttrs;
use crate::log_warn;
use crate::metrics::{mean_ci, paired_sign_test, rank_ascending, CsvWriter, SignTest};
use crate::placement::{drive, registry, PlacementError};
use crate::prng::{Pcg32, SplitMix64};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Fleet execution parameters.
#[derive(Debug, Clone, Default)]
pub struct FleetConfig {
    /// Worker OS threads (0 = one per available core).
    pub threads: usize,
    /// Evaluation budget override per replicate (None = the scenario's
    /// `pso.iterations × pso.particles`).
    pub evals: Option<usize>,
    /// Replicates per (scenario, strategy) cell (0 and 1 both mean a
    /// single run). Replicate seeds are derived from the scenario seed
    /// only, so all strategies within a scenario share each replicate's
    /// dynamics realization.
    pub replicates: usize,
}

/// One (scenario, strategy) cell of the matrix: a replicate set.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetCell {
    pub scenario: String,
    pub strategy: String,
    pub clients: usize,
    pub slots: usize,
    /// Evaluations spent per replicate (equal across replicates).
    pub evaluations: usize,
    /// Best virtual-time round delay found, one entry per replicate in
    /// replicate order.
    pub replicate_delays: Vec<f64>,
    /// Mean of `replicate_delays` — the cell's ranking statistic.
    pub best_delay: f64,
    /// Half-width of the 95% Student-t CI over `replicate_delays`
    /// (0.0 for a single replicate).
    pub ci95: f64,
    /// Mean delay across the whole search (exploration cost), averaged
    /// over replicates.
    pub mean_delay: f64,
    /// Events the simulator fired for this cell, totalled over
    /// replicates.
    pub events: u64,
    /// Rank of `best_delay` among the scenario's strategies (1 = won).
    pub rank: usize,
}

/// One replicate's raw result (pre-aggregation).
#[derive(Debug, Clone)]
struct ReplicateRun {
    strategy: String,
    evaluations: usize,
    best_delay: f64,
    mean_delay: f64,
    events: u64,
}

/// Derive the seed for replicate `r` of a scenario. Replicate 0 keeps
/// the scenario's own seed, so `--replicates 1` reproduces the
/// single-run fleet byte for byte; later replicates walk a SplitMix64
/// stream salted off the scenario seed. Strategy-independent by
/// construction: candidates within a scenario compete under identical
/// realizations each replicate.
fn replicate_seed(base: u64, r: usize) -> u64 {
    if r == 0 {
        return base;
    }
    let mut sm = SplitMix64::new(base ^ 0xF1EE_7C0D_ED5E_ED5Eu64);
    let mut seed = 0u64;
    for _ in 0..r {
        seed = sm.next();
    }
    seed
}

/// Run one replicate: seed-derived population + dynamics, registry
/// optimizer, generic `drive` loop against the scenario's configured
/// delay oracle (`sim.env`; the built-in catalog uses `event-driven`
/// throughout, but user TOML scenarios may pick `analytic`).
fn run_replicate(
    ns: &NamedScenario,
    strategy: &str,
    evals: Option<usize>,
    seed: u64,
) -> Result<ReplicateRun, PlacementError> {
    let mut sc = ns.sim.clone();
    sc.seed = seed;
    let cc = sc.client_count();
    // Same seeding discipline as `sim::run_sim_with`: population first,
    // optimizer stream split off after.
    let mut rng = Pcg32::seed_from_u64(sc.seed);
    let attrs = ClientAttrs::sample_population(
        cc,
        sc.pspeed_range,
        sc.memcap_range,
        sc.mdatasize,
        &mut rng,
    );
    let mut opt = registry::build_sim(strategy, &sc, rng.split())?;
    let budget = evals.unwrap_or(sc.pso.iterations * sc.pso.particles).max(1);
    // The event-driven oracle is built concretely to keep its event
    // counter; any other registry environment goes through the factory.
    let (out, events) = if registry::canonical_env(&sc.env)? == "event-driven" {
        let mut env = EventDrivenEnv::from_scenario(&sc, attrs);
        (drive(opt.as_mut(), &mut env, budget)?, env.events_fired)
    } else {
        let mut env = registry::build_sim_env(&sc.env, &sc, attrs)?;
        (drive(opt.as_mut(), env.as_mut(), budget)?, 0)
    };
    let mean_delay = if out.stats.is_empty() {
        out.best_delay
    } else {
        out.stats.iter().map(|s| s.mean).sum::<f64>() / out.stats.len() as f64
    };
    Ok(ReplicateRun {
        strategy: opt.name().to_string(),
        evaluations: out.evaluations,
        best_delay: out.best_delay,
        mean_delay,
        events,
    })
}

/// Run the full matrix. Replicate jobs are scheduled over `cfg.threads`
/// workers; the returned vector is ordered scenario-major (catalog
/// order) with per-scenario ranks (on replicate means) filled in.
pub fn run_fleet(
    scenarios: &[NamedScenario],
    strategies: &[String],
    cfg: &FleetConfig,
) -> Result<Vec<FleetCell>, PlacementError> {
    // Fail fast on a typo or an empty matrix (reachable from the CLI via
    // `--strategies ,` or a bad scenario TOML) before paying for
    // thousands of simulations.
    if scenarios.is_empty() || strategies.is_empty() {
        return Err(PlacementError::Environment(
            "fleet matrix is empty: need at least one scenario and one strategy".into(),
        ));
    }
    // Canonicalize and reject duplicates: two entries that resolve to
    // the same optimizer (e.g. `uniform` and `round-robin`) would
    // double-count that strategy's cells and desync the paired
    // significance series.
    let mut canon: Vec<&'static str> = Vec::with_capacity(strategies.len());
    for s in strategies {
        let c = registry::canonical(s)?;
        if canon.contains(&c) {
            return Err(PlacementError::DuplicateStrategy { name: s.clone() });
        }
        canon.push(c);
    }
    for ns in scenarios {
        registry::canonical_env(&ns.sim.env)?;
    }
    let replicates = cfg.replicates.max(1);
    // Job j = ((si · |strategies|) + ti) · R + r — replicate-level
    // parallelism, so even a two-cell matrix saturates the workers.
    let jobs: Vec<(usize, usize, usize)> = (0..scenarios.len())
        .flat_map(|si| {
            (0..strategies.len())
                .flat_map(move |ti| (0..replicates).map(move |r| (si, ti, r)))
        })
        .collect();
    let threads = if cfg.threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        cfg.threads
    }
    .min(jobs.len());

    type RunSlot = Option<Result<ReplicateRun, PlacementError>>;
    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<RunSlot>> = Mutex::new(vec![None; jobs.len()]);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let j = next.fetch_add(1, Ordering::Relaxed);
                let Some(&(si, ti, r)) = jobs.get(j) else { break };
                let ns = &scenarios[si];
                let seed = replicate_seed(ns.sim.seed, r);
                let run = run_replicate(ns, &strategies[ti], cfg.evals, seed);
                slots.lock().expect("fleet results lock")[j] = Some(run);
            });
        }
    });

    let mut runs = Vec::with_capacity(jobs.len());
    for slot in slots.into_inner().expect("fleet results lock") {
        runs.push(slot.expect("every job ran")?);
    }
    // Aggregate replicate runs into cells (jobs are replicate-minor).
    let mut cells = Vec::with_capacity(scenarios.len() * strategies.len());
    for (si, ns) in scenarios.iter().enumerate() {
        for ti in 0..strategies.len() {
            let base = ((si * strategies.len()) + ti) * replicates;
            let set = &runs[base..base + replicates];
            let replicate_delays: Vec<f64> = set.iter().map(|x| x.best_delay).collect();
            let ci = mean_ci(&replicate_delays);
            debug_assert!(set.iter().all(|x| x.evaluations == set[0].evaluations));
            cells.push(FleetCell {
                scenario: ns.name.clone(),
                strategy: set[0].strategy.clone(),
                clients: ns.sim.client_count(),
                slots: ns.sim.dimensions(),
                evaluations: set[0].evaluations,
                best_delay: ci.mean,
                ci95: ci.half_width,
                mean_delay: set.iter().map(|x| x.mean_delay).sum::<f64>() / replicates as f64,
                events: set.iter().map(|x| x.events).sum(),
                replicate_delays,
                rank: 0,
            });
        }
    }
    // Rank strategies within each scenario on their replicate means
    // (cells are scenario-major).
    for chunk in cells.chunks_mut(strategies.len()) {
        let delays: Vec<f64> = chunk.iter().map(|c| c.best_delay).collect();
        for (cell, rank) in chunk.iter_mut().zip(rank_ascending(&delays)) {
            cell.rank = rank;
        }
    }
    Ok(cells)
}

/// Per-strategy aggregate over the matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct StrategyStanding {
    pub strategy: String,
    /// Mean rank across scenarios (1.0 = won everything), ranks taken
    /// on replicate means.
    pub mean_rank: f64,
    /// Scenarios won outright.
    pub wins: usize,
    /// Geometric-mean of `best_delay / scenario winner's best_delay`
    /// (1.0 = always optimal; 2.0 = on average 2× the winner).
    pub regret: f64,
    /// Mean normalized delay: every (scenario, replicate) delay divided
    /// by its scenario winner's mean delay, averaged — the arithmetic,
    /// CI-carrying cousin of `regret` (scale-free across the catalog's
    /// 7-to-10k-client spread).
    pub mean_ratio: f64,
    /// Half-width of the 95% Student-t CI on `mean_ratio`.
    pub ratio_ci: f64,
}

/// Aggregate cells into the final standings, best mean rank first.
/// Scenarios whose winner delay is zero or non-finite cannot anchor a
/// meaningful ratio — `ln(0)` would poison the geometric mean into
/// `-inf`/NaN and silently corrupt the sort — so those terms contribute
/// a neutral regret of 1.0 and a warning is logged instead.
pub fn standings(cells: &[FleetCell]) -> Vec<StrategyStanding> {
    let mut order: Vec<&str> = Vec::new();
    for c in cells {
        if !order.contains(&c.strategy.as_str()) {
            order.push(&c.strategy);
        }
    }
    // Scenario winners (on replicate means) for the regret ratio.
    let mut winner: std::collections::BTreeMap<&str, f64> = std::collections::BTreeMap::new();
    for c in cells {
        let w = winner.entry(&c.scenario).or_insert(f64::INFINITY);
        *w = w.min(c.best_delay);
    }
    for (scenario, &w) in &winner {
        if !(w.is_finite() && w > 0.0) {
            log_warn!(
                "fleet",
                "scenario {scenario:?} winner delay {w} is unusable as a regret anchor; \
                 treating its regret terms as 1.0"
            );
        }
    }
    let mut out: Vec<StrategyStanding> = order
        .iter()
        .map(|&s| {
            let mine: Vec<&FleetCell> = cells.iter().filter(|c| c.strategy == s).collect();
            let n = mine.len().max(1) as f64;
            let mean_rank = mine.iter().map(|c| c.rank as f64).sum::<f64>() / n;
            let wins = mine.iter().filter(|c| c.rank == 1).count();
            let log_regret = mine
                .iter()
                .map(|c| {
                    let ratio = c.best_delay / winner[c.scenario.as_str()];
                    // Guard: zero/NaN winner (or cell) delays collapse to
                    // the neutral ratio instead of poisoning the mean.
                    if ratio.is_finite() && ratio > 0.0 {
                        ratio.ln()
                    } else {
                        0.0
                    }
                })
                .sum::<f64>()
                / n;
            let ratios: Vec<f64> = mine
                .iter()
                .flat_map(|c| {
                    let w = winner[c.scenario.as_str()];
                    c.replicate_delays.iter().map(move |&d| {
                        let r = d / w;
                        if r.is_finite() && r > 0.0 {
                            r
                        } else {
                            1.0
                        }
                    })
                })
                .collect();
            let ci = mean_ci(&ratios);
            StrategyStanding {
                strategy: s.to_string(),
                mean_rank,
                wins,
                regret: log_regret.exp(),
                mean_ratio: ci.mean,
                ratio_ci: ci.half_width,
            }
        })
        .collect();
    out.sort_by(|a, b| a.mean_rank.total_cmp(&b.mean_rank));
    out
}

/// The paired-significance report: the best-ranked strategy tested
/// against every other with a two-sided paired sign test over the
/// (scenario, replicate) delay pairs. Replicate seeds are shared across
/// strategies within a scenario, so each pair compares the identical
/// population/network/dynamics process; between same-cadence strategies
/// (everything except the cohort-batching `ga`/`pso-batched`) the two
/// sides even see the identical per-evaluation realization sequence —
/// exactly the pairing the sign test wants. Comparisons involving a
/// cohort-batching strategy remain seed-deterministic but are paired at
/// replicate granularity only.
#[derive(Debug, Clone, PartialEq)]
pub struct SignificanceMatrix {
    /// Strategy with the best mean rank.
    pub best: String,
    /// `(other strategy, sign test of best vs other)`, in standings
    /// order. `a_wins` counts pairs where `best` was strictly faster.
    pub versus: Vec<(String, SignTest)>,
}

/// Compute the significance matrix from ranked cells. `None` when the
/// matrix has fewer than two strategies (nothing to compare).
pub fn significance_matrix(cells: &[FleetCell]) -> Option<SignificanceMatrix> {
    significance_for(&standings(cells), cells)
}

/// [`significance_matrix`] over an already-computed standings table
/// (avoids re-aggregating — and re-warning — inside `report_fleet`).
fn significance_for(
    table: &[StrategyStanding],
    cells: &[FleetCell],
) -> Option<SignificanceMatrix> {
    if table.len() < 2 {
        return None;
    }
    let best = table[0].strategy.clone();
    let delays_of = |strategy: &str| -> Vec<f64> {
        cells
            .iter()
            .filter(|c| c.strategy == strategy)
            .flat_map(|c| c.replicate_delays.iter().copied())
            .collect()
    };
    let best_delays = delays_of(&best);
    let versus = table[1..]
        .iter()
        .map(|s| {
            let other = delays_of(&s.strategy);
            (s.strategy.clone(), paired_sign_test(&best_delays, &other))
        })
        .collect();
    Some(SignificanceMatrix { best, versus })
}

/// `foo.csv` → `foo.sig.csv`: where the significance matrix lands next
/// to the cell matrix.
fn sig_csv_path(path: &Path) -> std::path::PathBuf {
    let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("fleet");
    path.with_file_name(format!("{stem}.sig.csv"))
}

/// Print the ranked summary + significance matrix and (optionally)
/// write the full matrix CSV (plus `<out>.sig.csv` with the sign-test
/// rows). The CSVs contain only seed-deterministic columns, so
/// identical seeds produce byte-identical files regardless of thread
/// count.
pub fn report_fleet(cells: &[FleetCell], csv: Option<&Path>) -> std::io::Result<()> {
    let scenarios: std::collections::BTreeSet<&str> =
        cells.iter().map(|c| c.scenario.as_str()).collect();
    let replicates = cells.first().map_or(0, |c| c.replicate_delays.len());
    let total_evals: usize = cells.iter().map(|c| c.evaluations * c.replicate_delays.len()).sum();
    let total_events: u64 = cells.iter().map(|c| c.events).sum();
    println!(
        "fleet: {} scenarios × {} strategies × {} replicates = {} cells, {} evaluations, {} virtual events",
        scenarios.len(),
        cells.len() / scenarios.len().max(1),
        replicates,
        cells.len(),
        total_evals,
        total_events,
    );
    println!("\n=== fleet standings (by mean rank; delay ×best ± 95% CI) ===");
    println!(
        "{:<14} {:>10} {:>6} {:>10} {:>20}",
        "strategy", "mean rank", "wins", "regret ×", "delay ×best ± CI"
    );
    let table = standings(cells);
    for s in &table {
        println!(
            "{:<14} {:>10.2} {:>6} {:>10.3} {:>13.3} ± {:.3}",
            s.strategy, s.mean_rank, s.wins, s.regret, s.mean_ratio, s.ratio_ci
        );
    }
    let sig = significance_for(&table, cells);
    if let Some(sig) = &sig {
        println!(
            "\n=== significance: paired sign test, {} vs each (n = {} scenario×replicate pairs) ===",
            sig.best,
            cells.iter().filter(|c| c.strategy == sig.best).map(|c| c.replicate_delays.len()).sum::<usize>(),
        );
        println!("{:<14} {:>8} {:>8} {:>6} {:>10}", "vs strategy", "wins", "losses", "ties", "p");
        for (name, t) in &sig.versus {
            println!(
                "{:<14} {:>8} {:>8} {:>6} {:>10.6}",
                name, t.a_wins, t.b_wins, t.ties, t.p_value
            );
        }
    }
    if let Some(path) = csv {
        let mut w = CsvWriter::create(
            path,
            &[
                "scenario", "strategy", "clients", "slots", "evaluations", "replicates",
                "best_delay_mean", "best_delay_ci95", "mean_delay", "rank",
            ],
        )?;
        for c in cells {
            w.write_row(&[
                c.scenario.clone(),
                c.strategy.clone(),
                c.clients.to_string(),
                c.slots.to_string(),
                c.evaluations.to_string(),
                c.replicate_delays.len().to_string(),
                format!("{:.9}", c.best_delay),
                format!("{:.9}", c.ci95),
                format!("{:.9}", c.mean_delay),
                c.rank.to_string(),
            ])?;
        }
        w.flush()?;
        println!("matrix CSV: {}", path.display());
        if let Some(sig) = &sig {
            let sig_path = sig_csv_path(path);
            let mut w = CsvWriter::create(
                &sig_path,
                &["best_strategy", "vs_strategy", "best_wins", "losses", "ties", "p_value"],
            )?;
            for (name, t) in &sig.versus {
                w.write_row(&[
                    sig.best.clone(),
                    name.clone(),
                    t.a_wins.to_string(),
                    t.b_wins.to_string(),
                    t.ties.to_string(),
                    format!("{:.6}", t.p_value),
                ])?;
            }
            w.flush()?;
            println!("significance CSV: {}", sig_path.display());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configio::SimScenario;

    fn tiny_matrix() -> (Vec<NamedScenario>, Vec<String>) {
        let mut a = SimScenario {
            depth: 2,
            width: 2,
            env: "event-driven".into(),
            ..SimScenario::default()
        };
        a.pso.particles = 3;
        a.pso.iterations = 5;
        let mut b = a.clone();
        b.seed = 9;
        b.des.dynamics.dropout_prob = 0.2;
        let mut c = a.clone();
        c.seed = 13;
        c.env = "analytic".into();
        let scenarios = vec![
            NamedScenario { name: "a".into(), sim: a },
            NamedScenario { name: "b-dropout".into(), sim: b },
            NamedScenario { name: "c-analytic".into(), sim: c },
        ];
        let strategies = vec!["pso".to_string(), "random".to_string(), "round-robin".to_string()];
        (scenarios, strategies)
    }

    /// A synthetic two-strategy cell pair for standings-level tests.
    fn synthetic_cell(scenario: &str, strategy: &str, delays: &[f64], rank: usize) -> FleetCell {
        let ci = mean_ci(delays);
        FleetCell {
            scenario: scenario.into(),
            strategy: strategy.into(),
            clients: 7,
            slots: 3,
            evaluations: 10,
            replicate_delays: delays.to_vec(),
            best_delay: ci.mean,
            ci95: ci.half_width,
            mean_delay: ci.mean,
            events: 0,
            rank,
        }
    }

    #[test]
    fn fleet_results_are_independent_of_thread_count() {
        let (scenarios, strategies) = tiny_matrix();
        let one = run_fleet(
            &scenarios,
            &strategies,
            &FleetConfig { threads: 1, ..FleetConfig::default() },
        )
        .unwrap();
        let many = run_fleet(
            &scenarios,
            &strategies,
            &FleetConfig { threads: 4, ..FleetConfig::default() },
        )
        .unwrap();
        assert_eq!(one, many);
        assert_eq!(one.len(), 9);
        // Scenario-major order; competition ranks start at 1 and stay in
        // range (ties share a rank).
        for chunk in one.chunks(3) {
            let ranks: Vec<usize> = chunk.iter().map(|c| c.rank).collect();
            assert_eq!(ranks.iter().min(), Some(&1), "{ranks:?}");
            assert!(ranks.iter().all(|&r| (1..=3).contains(&r)), "{ranks:?}");
            assert!(chunk.iter().all(|c| c.scenario == chunk[0].scenario));
            assert!(chunk.iter().all(|c| c.best_delay.is_finite() && c.best_delay > 0.0));
            assert!(chunk.iter().all(|c| c.evaluations == 15));
            // Single replicate: degenerate CI, one delay equal to the mean.
            assert!(chunk.iter().all(|c| c.replicate_delays == vec![c.best_delay]));
            assert!(chunk.iter().all(|c| c.ci95 == 0.0));
        }
        // The scenario's env is honored: event-driven cells count events,
        // the analytic scenario fires none.
        assert!(one.iter().filter(|c| c.scenario == "a").all(|c| c.events > 0));
        assert!(one.iter().filter(|c| c.scenario == "c-analytic").all(|c| c.events == 0));
    }

    #[test]
    fn replicates_derive_distinct_seeds_and_pair_across_strategies() {
        let (scenarios, strategies) = tiny_matrix();
        let cells = run_fleet(
            &scenarios,
            &strategies[..2],
            &FleetConfig { threads: 2, evals: Some(10), replicates: 3 },
        )
        .unwrap();
        assert_eq!(cells.len(), 6);
        for c in &cells {
            assert_eq!(c.replicate_delays.len(), 3);
            // Distinct derived seeds ⇒ distinct populations ⇒ the
            // replicate delays differ from one another.
            let mut uniq = c.replicate_delays.clone();
            uniq.sort_by(f64::total_cmp);
            uniq.dedup();
            assert!(uniq.len() > 1, "replicates identical: {:?}", c.replicate_delays);
            // The mean is the ranking statistic.
            let mean = c.replicate_delays.iter().sum::<f64>() / 3.0;
            assert!((c.best_delay - mean).abs() < 1e-12);
            assert!(c.ci95 > 0.0, "non-degenerate replicate set must have a CI");
        }
        // Replicate 0 keeps the scenario seed: it equals the
        // single-replicate run exactly.
        let single = run_fleet(
            &scenarios,
            &strategies[..2],
            &FleetConfig { threads: 1, evals: Some(10), replicates: 1 },
        )
        .unwrap();
        for (c3, c1) in cells.iter().zip(&single) {
            assert_eq!(c3.replicate_delays[0], c1.replicate_delays[0]);
        }
        // Derived seeds are distinct for many replicates.
        let seeds: std::collections::BTreeSet<u64> =
            (0..64).map(|r| replicate_seed(42, r)).collect();
        assert_eq!(seeds.len(), 64);
    }

    #[test]
    fn fleet_rejects_unknown_strategies_and_empty_matrices_up_front() {
        let (scenarios, strategies) = tiny_matrix();
        let err = run_fleet(
            &scenarios,
            &["pso".to_string(), "nope".to_string()],
            &FleetConfig::default(),
        )
        .unwrap_err();
        assert!(matches!(err, PlacementError::UnknownStrategy { .. }), "{err}");
        // Alias-duplicated strategies (uniform == round-robin) would
        // double-count cells and desync the significance pairing —
        // rejected before any simulation runs.
        let err = run_fleet(
            &scenarios,
            &["pso".to_string(), "uniform".to_string(), "round-robin".to_string()],
            &FleetConfig::default(),
        )
        .unwrap_err();
        assert!(matches!(err, PlacementError::DuplicateStrategy { .. }), "{err}");
        assert!(err.to_string().contains("duplicate strategy"), "{err}");
        // `repro fleet --strategies ,` reaches the library as an empty
        // list — a typed error, not a panic.
        let err = run_fleet(&scenarios, &[], &FleetConfig::default()).unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");
        let err = run_fleet(&[], &strategies, &FleetConfig::default()).unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");
        // A typo'd env in any scenario TOML fails before the matrix runs.
        let mut bad = scenarios.clone();
        bad[0].sim.env = "dokcer".into();
        let err = run_fleet(&bad, &strategies, &FleetConfig::default()).unwrap_err();
        assert!(matches!(err, PlacementError::UnknownEnvironment { .. }), "{err}");
    }

    #[test]
    fn evals_override_caps_the_budget() {
        let (scenarios, strategies) = tiny_matrix();
        let cells = run_fleet(
            &scenarios[..1],
            &strategies[..2],
            &FleetConfig { threads: 2, evals: Some(7), replicates: 2 },
        )
        .unwrap();
        assert!(cells.iter().all(|c| c.evaluations == 7));
        assert!(cells.iter().all(|c| c.replicate_delays.len() == 2));
    }

    #[test]
    fn standings_rank_winner_first_with_unit_regret() {
        let (scenarios, strategies) = tiny_matrix();
        let cells = run_fleet(
            &scenarios,
            &strategies,
            &FleetConfig { threads: 2, replicates: 2, ..FleetConfig::default() },
        )
        .unwrap();
        let table = standings(&cells);
        assert_eq!(table.len(), 3);
        assert!(table.windows(2).all(|w| w[0].mean_rank <= w[1].mean_rank));
        let total_wins: usize = table.iter().map(|s| s.wins).sum();
        // At least one winner per scenario; ties can add more.
        assert!(total_wins >= 3, "wins {total_wins}");
        for s in &table {
            assert!(s.regret >= 1.0 - 1e-12, "{}: regret {}", s.strategy, s.regret);
            assert!(s.mean_ratio.is_finite() && s.mean_ratio > 0.0);
            assert!(s.ratio_ci.is_finite() && s.ratio_ci >= 0.0);
        }
    }

    #[test]
    fn standings_regret_survives_zero_and_nan_winner_delays() {
        // A degenerate scenario whose winner delay is 0 (or NaN) must
        // not poison the geometric regret into -inf/NaN: those terms
        // collapse to the neutral 1.0 and the sort stays meaningful.
        let cells = vec![
            synthetic_cell("zero", "alpha", &[0.0, 0.0], 1),
            synthetic_cell("zero", "beta", &[2.0, 2.0], 2),
            synthetic_cell("nan", "alpha", &[f64::NAN], 2),
            synthetic_cell("nan", "beta", &[1.0], 1),
            synthetic_cell("sane", "alpha", &[1.0], 1),
            synthetic_cell("sane", "beta", &[3.0], 2),
        ];
        let table = standings(&cells);
        assert_eq!(table.len(), 2);
        for s in &table {
            assert!(s.regret.is_finite(), "{}: regret {}", s.strategy, s.regret);
            assert!(s.regret >= 1.0 - 1e-12, "{}: regret {}", s.strategy, s.regret);
            assert!(s.mean_ratio.is_finite(), "{}: ratio {}", s.strategy, s.mean_ratio);
        }
        // alpha's only usable regret term is the "sane" win (ratio 1);
        // beta's is 3× — beta carries the larger regret.
        let by_name = |n: &str| table.iter().find(|s| s.strategy == n).unwrap();
        assert!(by_name("beta").regret > by_name("alpha").regret);
    }

    #[test]
    fn significance_matrix_pairs_best_against_each() {
        // beta strictly faster on all 6 (scenario, replicate) pairs but
        // one: sign test must see 5 wins, 1 loss.
        let cells = vec![
            synthetic_cell("s1", "alpha", &[2.0, 3.0, 4.0], 2),
            synthetic_cell("s1", "beta", &[1.0, 2.0, 3.0], 1),
            synthetic_cell("s2", "alpha", &[1.0, 5.0, 6.0], 2),
            synthetic_cell("s2", "beta", &[1.5, 4.0, 5.0], 1),
        ];
        let sig = significance_matrix(&cells).expect("two strategies");
        assert_eq!(sig.best, "beta");
        assert_eq!(sig.versus.len(), 1);
        let (name, t) = &sig.versus[0];
        assert_eq!(name, "alpha");
        assert_eq!((t.a_wins, t.b_wins, t.ties), (5, 1, 0));
        assert!(t.p_value > 0.0 && t.p_value <= 1.0);
        // One strategy ⇒ no matrix.
        assert!(significance_matrix(&cells[..1]).is_none());
    }

    #[test]
    fn report_writes_deterministic_csv() {
        let (scenarios, strategies) = tiny_matrix();
        let cfg = |threads| FleetConfig { threads, replicates: 2, ..FleetConfig::default() };
        let cells = run_fleet(&scenarios, &strategies, &cfg(3)).unwrap();
        let path = std::env::temp_dir().join("repro_fleet_test.csv");
        report_fleet(&cells, Some(&path)).unwrap();
        let sig_path = sig_csv_path(&path);
        let first = std::fs::read_to_string(&path).unwrap();
        let first_sig = std::fs::read_to_string(&sig_path).unwrap();
        let cells2 = run_fleet(&scenarios, &strategies, &cfg(1)).unwrap();
        report_fleet(&cells2, Some(&path)).unwrap();
        let second = std::fs::read_to_string(&path).unwrap();
        let second_sig = std::fs::read_to_string(&sig_path).unwrap();
        assert_eq!(first, second, "CSV must be byte-identical per seed");
        assert_eq!(first_sig, second_sig, "sig CSV must be byte-identical per seed");
        assert_eq!(first.lines().count(), 10); // header + 9 cells
        assert!(first.lines().next().unwrap().contains("best_delay_ci95"));
        assert_eq!(first_sig.lines().count(), 3); // header + 2 comparisons
        assert!(first_sig.lines().next().unwrap().contains("p_value"));
    }
}
