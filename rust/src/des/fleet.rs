//! The `repro fleet` runner — now a thin adapter over the experiment
//! engine ([`crate::exp`]): a fixed-replicate [`ExperimentPlan`] over a
//! scenario catalog, scheduled on a [`TrialScheduler`] and reported by
//! the shared [`crate::exp::report_cells`] builder. The fixed
//! `--replicates R` behavior (job order, seed derivation, CSV bytes) is
//! frozen: this module's tests pin it, and the engine's adaptive
//! allocator degenerates to exactly this path when `min == max`.
//!
//! ## Statistics
//!
//! A single seed per cell makes the standings a lottery: one lucky
//! dynamics realization can flip who "wins" a scenario. With
//! `--replicates R` every (scenario, strategy) cell is scored `R` times
//! under `R` *derived* seeds (see [`crate::exp::replicate_seed`]). The
//! seed for replicate `r` depends only on the scenario (not the
//! strategy), so within a scenario all strategies face the identical
//! population, network and dynamics *process* per replicate — paired
//! trials. Cells report the replicate mean ± a 95% Student-t CI,
//! per-scenario ranks are computed on replicate means, and
//! [`significance_matrix`] runs a paired sign test (plus a Wilcoxon
//! signed-rank test with rank-biserial effect size) of the best-ranked
//! strategy against every other over the (scenario, replicate) pairs.
//! The adaptive `--replicates MIN..MAX` syntax lives in the engine; see
//! [`crate::exp::ReplicateRange`].

use super::scenarios::NamedScenario;
use crate::exp::{run_plan, ExperimentPlan, ReplicateRange, TrialScheduler};
use crate::placement::PlacementError;

pub use crate::exp::report_cells as report_fleet;
pub use crate::exp::{
    replicate_seed, significance_matrix, standings, ExperimentCell as FleetCell,
    SignificanceMatrix, StrategyStanding, VersusRow,
};

/// Fleet execution parameters (the classic fixed-replicate surface; the
/// CLI's adaptive `--replicates MIN..MAX` builds an [`ExperimentPlan`]
/// directly).
#[derive(Debug, Clone, Default)]
pub struct FleetConfig {
    /// Worker OS threads (0 = one per available core).
    pub threads: usize,
    /// Evaluation budget override per replicate (None = the scenario's
    /// `pso.iterations × pso.particles`).
    pub evals: Option<usize>,
    /// Replicates per (scenario, strategy) cell (0 and 1 both mean a
    /// single run). Replicate seeds are derived from the scenario seed
    /// only, so all strategies within a scenario share each replicate's
    /// dynamics realization.
    pub replicates: usize,
}

/// Run the full matrix at a fixed replicate count. Replicate jobs are
/// scheduled over `cfg.threads` workers; the returned vector is ordered
/// scenario-major (catalog order) with per-scenario ranks (on replicate
/// means) filled in.
pub fn run_fleet(
    scenarios: &[NamedScenario],
    strategies: &[String],
    cfg: &FleetConfig,
) -> Result<Vec<FleetCell>, PlacementError> {
    let plan = ExperimentPlan {
        scenarios: scenarios.to_vec(),
        strategies: strategies.to_vec(),
        evals: cfg.evals,
        env_override: None,
        replicates: ReplicateRange::fixed(cfg.replicates),
    };
    run_plan(&plan, &TrialScheduler::new(cfg.threads))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configio::SimScenario;
    use crate::metrics::mean_ci;

    fn tiny_matrix() -> (Vec<NamedScenario>, Vec<String>) {
        let mut a = SimScenario {
            depth: 2,
            width: 2,
            env: "event-driven".into(),
            ..SimScenario::default()
        };
        a.pso.particles = 3;
        a.pso.iterations = 5;
        let mut b = a.clone();
        b.seed = 9;
        b.des.dynamics.dropout_prob = 0.2;
        let mut c = a.clone();
        c.seed = 13;
        c.env = "analytic".into();
        let scenarios = vec![
            NamedScenario { name: "a".into(), sim: a },
            NamedScenario { name: "b-dropout".into(), sim: b },
            NamedScenario { name: "c-analytic".into(), sim: c },
        ];
        let strategies = vec!["pso".to_string(), "random".to_string(), "round-robin".to_string()];
        (scenarios, strategies)
    }

    #[test]
    fn fleet_results_are_independent_of_thread_count() {
        let (scenarios, strategies) = tiny_matrix();
        let one = run_fleet(
            &scenarios,
            &strategies,
            &FleetConfig { threads: 1, ..FleetConfig::default() },
        )
        .unwrap();
        let many = run_fleet(
            &scenarios,
            &strategies,
            &FleetConfig { threads: 4, ..FleetConfig::default() },
        )
        .unwrap();
        assert_eq!(one, many);
        assert_eq!(one.len(), 9);
        // Scenario-major order; competition ranks start at 1 and stay in
        // range (ties share a rank).
        for chunk in one.chunks(3) {
            let ranks: Vec<usize> = chunk.iter().map(|c| c.rank).collect();
            assert_eq!(ranks.iter().min(), Some(&1), "{ranks:?}");
            assert!(ranks.iter().all(|&r| (1..=3).contains(&r)), "{ranks:?}");
            assert!(chunk.iter().all(|c| c.scenario == chunk[0].scenario));
            assert!(chunk.iter().all(|c| c.best_delay.is_finite() && c.best_delay > 0.0));
            assert!(chunk.iter().all(|c| c.evaluations == 15));
            // Single replicate: degenerate CI, one delay equal to the mean.
            assert!(chunk.iter().all(|c| c.replicate_delays == vec![c.best_delay]));
            assert!(chunk.iter().all(|c| c.ci95 == 0.0));
        }
        // The scenario's env is honored: event-driven cells count events,
        // the analytic scenario fires none.
        assert!(one.iter().filter(|c| c.scenario == "a").all(|c| c.events > 0));
        assert!(one.iter().filter(|c| c.scenario == "c-analytic").all(|c| c.events == 0));
    }

    #[test]
    fn replicates_derive_distinct_seeds_and_pair_across_strategies() {
        let (scenarios, strategies) = tiny_matrix();
        let cells = run_fleet(
            &scenarios,
            &strategies[..2],
            &FleetConfig { threads: 2, evals: Some(10), replicates: 3 },
        )
        .unwrap();
        assert_eq!(cells.len(), 6);
        for c in &cells {
            assert_eq!(c.replicate_delays.len(), 3);
            // Distinct derived seeds ⇒ distinct populations ⇒ the
            // replicate delays differ from one another.
            let mut uniq = c.replicate_delays.clone();
            uniq.sort_by(f64::total_cmp);
            uniq.dedup();
            assert!(uniq.len() > 1, "replicates identical: {:?}", c.replicate_delays);
            // The mean is the ranking statistic.
            let mean = c.replicate_delays.iter().sum::<f64>() / 3.0;
            assert!((c.best_delay - mean).abs() < 1e-12);
            assert!(c.ci95 > 0.0, "non-degenerate replicate set must have a CI");
            assert!((c.ci95 - mean_ci(&c.replicate_delays).half_width).abs() < 1e-12);
        }
        // Replicate 0 keeps the scenario seed: it equals the
        // single-replicate run exactly.
        let single = run_fleet(
            &scenarios,
            &strategies[..2],
            &FleetConfig { threads: 1, evals: Some(10), replicates: 1 },
        )
        .unwrap();
        for (c3, c1) in cells.iter().zip(&single) {
            assert_eq!(c3.replicate_delays[0], c1.replicate_delays[0]);
        }
        // Derived seeds are distinct for many replicates.
        let seeds: std::collections::BTreeSet<u64> =
            (0..64).map(|r| replicate_seed(42, r)).collect();
        assert_eq!(seeds.len(), 64);
    }

    #[test]
    fn fleet_rejects_unknown_strategies_and_empty_matrices_up_front() {
        let (scenarios, strategies) = tiny_matrix();
        let err = run_fleet(
            &scenarios,
            &["pso".to_string(), "nope".to_string()],
            &FleetConfig::default(),
        )
        .unwrap_err();
        assert!(matches!(err, PlacementError::UnknownStrategy { .. }), "{err}");
        // Alias-duplicated strategies (uniform == round-robin) would
        // double-count cells and desync the significance pairing —
        // rejected before any simulation runs.
        let err = run_fleet(
            &scenarios,
            &["pso".to_string(), "uniform".to_string(), "round-robin".to_string()],
            &FleetConfig::default(),
        )
        .unwrap_err();
        assert!(matches!(err, PlacementError::DuplicateStrategy { .. }), "{err}");
        assert!(err.to_string().contains("duplicate strategy"), "{err}");
        // `repro fleet --strategies ,` reaches the library as an empty
        // list — a typed error, not a panic.
        let err = run_fleet(&scenarios, &[], &FleetConfig::default()).unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");
        let err = run_fleet(&[], &strategies, &FleetConfig::default()).unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");
        // A typo'd env in any scenario TOML fails before the matrix runs.
        let mut bad = scenarios.clone();
        bad[0].sim.env = "dokcer".into();
        let err = run_fleet(&bad, &strategies, &FleetConfig::default()).unwrap_err();
        assert!(matches!(err, PlacementError::UnknownEnvironment { .. }), "{err}");
    }

    #[test]
    fn evals_override_caps_the_budget() {
        let (scenarios, strategies) = tiny_matrix();
        let cells = run_fleet(
            &scenarios[..1],
            &strategies[..2],
            &FleetConfig { threads: 2, evals: Some(7), replicates: 2 },
        )
        .unwrap();
        assert!(cells.iter().all(|c| c.evaluations == 7));
        assert!(cells.iter().all(|c| c.replicate_delays.len() == 2));
    }

    #[test]
    fn standings_rank_winner_first_with_unit_regret() {
        let (scenarios, strategies) = tiny_matrix();
        let cells = run_fleet(
            &scenarios,
            &strategies,
            &FleetConfig { threads: 2, replicates: 2, ..FleetConfig::default() },
        )
        .unwrap();
        let table = standings(&cells);
        assert_eq!(table.len(), 3);
        assert!(table.windows(2).all(|w| w[0].mean_rank <= w[1].mean_rank));
        let total_wins: usize = table.iter().map(|s| s.wins).sum();
        // At least one winner per scenario; ties can add more.
        assert!(total_wins >= 3, "wins {total_wins}");
        for s in &table {
            assert!(s.regret >= 1.0 - 1e-12, "{}: regret {}", s.strategy, s.regret);
            assert!(s.mean_ratio.is_finite() && s.mean_ratio > 0.0);
            assert!(s.ratio_ci.is_finite() && s.ratio_ci >= 0.0);
        }
    }

    #[test]
    fn report_writes_deterministic_csv() {
        let (scenarios, strategies) = tiny_matrix();
        let cfg = |threads| FleetConfig { threads, replicates: 2, ..FleetConfig::default() };
        let cells = run_fleet(&scenarios, &strategies, &cfg(3)).unwrap();
        let dir = std::env::temp_dir().join("repro_fleet_adapter_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("fleet.csv");
        report_fleet(&cells, Some(&path)).unwrap();
        let sig_path = dir.join("fleet.sig.csv");
        let effect_path = dir.join("fleet.effect.csv");
        let first = std::fs::read_to_string(&path).unwrap();
        let first_sig = std::fs::read_to_string(&sig_path).unwrap();
        let first_effect = std::fs::read_to_string(&effect_path).unwrap();
        let cells2 = run_fleet(&scenarios, &strategies, &cfg(1)).unwrap();
        report_fleet(&cells2, Some(&path)).unwrap();
        assert_eq!(first, std::fs::read_to_string(&path).unwrap());
        assert_eq!(first_sig, std::fs::read_to_string(&sig_path).unwrap());
        assert_eq!(first_effect, std::fs::read_to_string(&effect_path).unwrap());
        assert_eq!(first.lines().count(), 10); // header + 9 cells
        assert!(first.lines().next().unwrap().contains("best_delay_ci95"));
        assert_eq!(first_sig.lines().count(), 3); // header + 2 comparisons
        assert!(first_sig.lines().next().unwrap().contains("p_value"));
        assert_eq!(first_effect.lines().count(), 3);
        assert!(first_effect.lines().next().unwrap().contains("effect_size"));
    }
}
