//! The `repro fleet` runner: a scenario × strategy matrix executed
//! across OS threads, every cell driving one registry optimizer against
//! the event-driven oracle in virtual time. Results are deterministic
//! per seed and independent of the thread count — each cell derives all
//! of its randomness from its scenario's seed, and cells are ranked and
//! reported in a fixed order after the join.

use super::round::EventDrivenEnv;
use super::scenarios::NamedScenario;
use crate::fitness::ClientAttrs;
use crate::metrics::{rank_ascending, CsvWriter};
use crate::placement::{drive, registry, PlacementError};
use crate::prng::Pcg32;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Fleet execution parameters.
#[derive(Debug, Clone, Default)]
pub struct FleetConfig {
    /// Worker OS threads (0 = one per available core).
    pub threads: usize,
    /// Evaluation budget override per cell (None = the scenario's
    /// `pso.iterations × pso.particles`).
    pub evals: Option<usize>,
}

/// One scored (scenario, strategy) cell of the matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetCell {
    pub scenario: String,
    pub strategy: String,
    pub clients: usize,
    pub slots: usize,
    pub evaluations: usize,
    /// Best virtual-time round delay the strategy found.
    pub best_delay: f64,
    /// Mean delay across the whole search (exploration cost).
    pub mean_delay: f64,
    /// Events the simulator fired for this cell.
    pub events: u64,
    /// Rank of `best_delay` among the scenario's strategies (1 = won).
    pub rank: usize,
}

/// Run one cell: seed-derived population + dynamics, registry optimizer,
/// generic `drive` loop against the scenario's configured delay oracle
/// (`sim.env`; the built-in catalog uses `event-driven` throughout, but
/// user TOML scenarios may pick `analytic`).
fn run_cell(
    ns: &NamedScenario,
    strategy: &str,
    evals: Option<usize>,
) -> Result<FleetCell, PlacementError> {
    let sc = &ns.sim;
    let cc = sc.client_count();
    // Same seeding discipline as `sim::run_sim_with`: population first,
    // optimizer stream split off after.
    let mut rng = Pcg32::seed_from_u64(sc.seed);
    let attrs = ClientAttrs::sample_population(
        cc,
        sc.pspeed_range,
        sc.memcap_range,
        sc.mdatasize,
        &mut rng,
    );
    let mut opt = registry::build_sim(strategy, sc, rng.split())?;
    let budget = evals.unwrap_or(sc.pso.iterations * sc.pso.particles).max(1);
    // The event-driven oracle is built concretely to keep its event
    // counter; any other registry environment goes through the factory.
    let (out, events) = if registry::canonical_env(&sc.env)? == "event-driven" {
        let mut env = EventDrivenEnv::from_scenario(sc, attrs);
        (drive(opt.as_mut(), &mut env, budget)?, env.events_fired)
    } else {
        let mut env = registry::build_sim_env(&sc.env, sc, attrs)?;
        (drive(opt.as_mut(), env.as_mut(), budget)?, 0)
    };
    let mean_delay = if out.stats.is_empty() {
        out.best_delay
    } else {
        out.stats.iter().map(|s| s.mean).sum::<f64>() / out.stats.len() as f64
    };
    Ok(FleetCell {
        scenario: ns.name.clone(),
        strategy: opt.name().to_string(),
        clients: cc,
        slots: sc.dimensions(),
        evaluations: out.evaluations,
        best_delay: out.best_delay,
        mean_delay,
        events,
        rank: 0,
    })
}

/// Run the full matrix. Cells are scheduled over `cfg.threads` workers;
/// the returned vector is ordered scenario-major (catalog order) with
/// per-scenario ranks filled in.
pub fn run_fleet(
    scenarios: &[NamedScenario],
    strategies: &[String],
    cfg: &FleetConfig,
) -> Result<Vec<FleetCell>, PlacementError> {
    // Fail fast on a typo or an empty matrix (reachable from the CLI via
    // `--strategies ,` or a bad scenario TOML) before paying for
    // thousands of simulations.
    if scenarios.is_empty() || strategies.is_empty() {
        return Err(PlacementError::Environment(
            "fleet matrix is empty: need at least one scenario and one strategy".into(),
        ));
    }
    for s in strategies {
        registry::canonical(s)?;
    }
    for ns in scenarios {
        registry::canonical_env(&ns.sim.env)?;
    }
    let jobs: Vec<(usize, usize)> = (0..scenarios.len())
        .flat_map(|si| (0..strategies.len()).map(move |ti| (si, ti)))
        .collect();
    let threads = if cfg.threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        cfg.threads
    }
    .min(jobs.len());

    type CellSlot = Option<Result<FleetCell, PlacementError>>;
    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<CellSlot>> = Mutex::new(vec![None; jobs.len()]);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let j = next.fetch_add(1, Ordering::Relaxed);
                let Some(&(si, ti)) = jobs.get(j) else { break };
                let cell = run_cell(&scenarios[si], &strategies[ti], cfg.evals);
                slots.lock().expect("fleet results lock")[j] = Some(cell);
            });
        }
    });

    let mut cells = Vec::with_capacity(jobs.len());
    for slot in slots.into_inner().expect("fleet results lock") {
        cells.push(slot.expect("every job ran")?);
    }
    // Rank strategies within each scenario (cells are scenario-major).
    for chunk in cells.chunks_mut(strategies.len()) {
        let delays: Vec<f64> = chunk.iter().map(|c| c.best_delay).collect();
        for (cell, rank) in chunk.iter_mut().zip(rank_ascending(&delays)) {
            cell.rank = rank;
        }
    }
    Ok(cells)
}

/// Per-strategy aggregate over the matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct StrategyStanding {
    pub strategy: String,
    /// Mean rank across scenarios (1.0 = won everything).
    pub mean_rank: f64,
    /// Scenarios won outright.
    pub wins: usize,
    /// Geometric-mean of `best_delay / scenario winner's best_delay`
    /// (1.0 = always optimal; 2.0 = on average 2× the winner).
    pub regret: f64,
}

/// Aggregate cells into the final standings, best mean rank first.
pub fn standings(cells: &[FleetCell]) -> Vec<StrategyStanding> {
    let mut order: Vec<&str> = Vec::new();
    for c in cells {
        if !order.contains(&c.strategy.as_str()) {
            order.push(&c.strategy);
        }
    }
    // Scenario winners for the regret ratio.
    let mut winner: std::collections::BTreeMap<&str, f64> = std::collections::BTreeMap::new();
    for c in cells {
        let w = winner.entry(&c.scenario).or_insert(f64::INFINITY);
        *w = w.min(c.best_delay);
    }
    let mut out: Vec<StrategyStanding> = order
        .iter()
        .map(|&s| {
            let mine: Vec<&FleetCell> = cells.iter().filter(|c| c.strategy == s).collect();
            let n = mine.len().max(1) as f64;
            let mean_rank = mine.iter().map(|c| c.rank as f64).sum::<f64>() / n;
            let wins = mine.iter().filter(|c| c.rank == 1).count();
            let log_regret = mine
                .iter()
                .map(|c| (c.best_delay / winner[c.scenario.as_str()]).ln())
                .sum::<f64>()
                / n;
            StrategyStanding {
                strategy: s.to_string(),
                mean_rank,
                wins,
                regret: log_regret.exp(),
            }
        })
        .collect();
    out.sort_by(|a, b| a.mean_rank.total_cmp(&b.mean_rank));
    out
}

/// Print the ranked summary and (optionally) write the full matrix CSV.
/// The CSV contains only seed-deterministic columns, so identical seeds
/// produce byte-identical files regardless of thread count.
pub fn report_fleet(cells: &[FleetCell], csv: Option<&Path>) -> std::io::Result<()> {
    let scenarios: std::collections::BTreeSet<&str> =
        cells.iter().map(|c| c.scenario.as_str()).collect();
    let total_evals: usize = cells.iter().map(|c| c.evaluations).sum();
    let total_events: u64 = cells.iter().map(|c| c.events).sum();
    println!(
        "fleet: {} scenarios × {} strategies = {} cells, {} evaluations, {} virtual events",
        scenarios.len(),
        cells.len() / scenarios.len().max(1),
        cells.len(),
        total_evals,
        total_events,
    );
    println!("\n=== fleet standings (by mean rank) ===");
    println!(
        "{:<14} {:>10} {:>6} {:>10}",
        "strategy", "mean rank", "wins", "regret ×"
    );
    for s in standings(cells) {
        println!(
            "{:<14} {:>10.2} {:>6} {:>10.3}",
            s.strategy, s.mean_rank, s.wins, s.regret
        );
    }
    if let Some(path) = csv {
        let mut w = CsvWriter::create(
            path,
            &[
                "scenario", "strategy", "clients", "slots", "evaluations", "best_delay",
                "mean_delay", "rank",
            ],
        )?;
        for c in cells {
            w.write_row(&[
                c.scenario.clone(),
                c.strategy.clone(),
                c.clients.to_string(),
                c.slots.to_string(),
                c.evaluations.to_string(),
                format!("{:.9}", c.best_delay),
                format!("{:.9}", c.mean_delay),
                c.rank.to_string(),
            ])?;
        }
        w.flush()?;
        println!("matrix CSV: {}", path.display());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configio::SimScenario;

    fn tiny_matrix() -> (Vec<NamedScenario>, Vec<String>) {
        let mut a = SimScenario {
            depth: 2,
            width: 2,
            env: "event-driven".into(),
            ..SimScenario::default()
        };
        a.pso.particles = 3;
        a.pso.iterations = 5;
        let mut b = a.clone();
        b.seed = 9;
        b.des.dynamics.dropout_prob = 0.2;
        let mut c = a.clone();
        c.seed = 13;
        c.env = "analytic".into();
        let scenarios = vec![
            NamedScenario { name: "a".into(), sim: a },
            NamedScenario { name: "b-dropout".into(), sim: b },
            NamedScenario { name: "c-analytic".into(), sim: c },
        ];
        let strategies = vec!["pso".to_string(), "random".to_string(), "round-robin".to_string()];
        (scenarios, strategies)
    }

    #[test]
    fn fleet_results_are_independent_of_thread_count() {
        let (scenarios, strategies) = tiny_matrix();
        let one = run_fleet(
            &scenarios,
            &strategies,
            &FleetConfig { threads: 1, evals: None },
        )
        .unwrap();
        let many = run_fleet(
            &scenarios,
            &strategies,
            &FleetConfig { threads: 4, evals: None },
        )
        .unwrap();
        assert_eq!(one, many);
        assert_eq!(one.len(), 9);
        // Scenario-major order; competition ranks start at 1 and stay in
        // range (ties share a rank).
        for chunk in one.chunks(3) {
            let ranks: Vec<usize> = chunk.iter().map(|c| c.rank).collect();
            assert_eq!(ranks.iter().min(), Some(&1), "{ranks:?}");
            assert!(ranks.iter().all(|&r| (1..=3).contains(&r)), "{ranks:?}");
            assert!(chunk.iter().all(|c| c.scenario == chunk[0].scenario));
            assert!(chunk.iter().all(|c| c.best_delay.is_finite() && c.best_delay > 0.0));
            assert!(chunk.iter().all(|c| c.evaluations == 15));
        }
        // The scenario's env is honored: event-driven cells count events,
        // the analytic scenario fires none.
        assert!(one.iter().filter(|c| c.scenario == "a").all(|c| c.events > 0));
        assert!(one.iter().filter(|c| c.scenario == "c-analytic").all(|c| c.events == 0));
    }

    #[test]
    fn fleet_rejects_unknown_strategies_and_empty_matrices_up_front() {
        let (scenarios, strategies) = tiny_matrix();
        let err = run_fleet(
            &scenarios,
            &["pso".to_string(), "nope".to_string()],
            &FleetConfig::default(),
        )
        .unwrap_err();
        assert!(matches!(err, PlacementError::UnknownStrategy { .. }), "{err}");
        // `repro fleet --strategies ,` reaches the library as an empty
        // list — a typed error, not a panic.
        let err = run_fleet(&scenarios, &[], &FleetConfig::default()).unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");
        let err = run_fleet(&[], &strategies, &FleetConfig::default()).unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");
        // A typo'd env in any scenario TOML fails before the matrix runs.
        let mut bad = scenarios.clone();
        bad[0].sim.env = "dokcer".into();
        let err = run_fleet(&bad, &strategies, &FleetConfig::default()).unwrap_err();
        assert!(matches!(err, PlacementError::UnknownEnvironment { .. }), "{err}");
    }

    #[test]
    fn evals_override_caps_the_budget() {
        let (scenarios, strategies) = tiny_matrix();
        let cells = run_fleet(
            &scenarios[..1],
            &strategies[..2],
            &FleetConfig { threads: 2, evals: Some(7) },
        )
        .unwrap();
        assert!(cells.iter().all(|c| c.evaluations == 7));
    }

    #[test]
    fn standings_rank_winner_first_with_unit_regret() {
        let (scenarios, strategies) = tiny_matrix();
        let cells =
            run_fleet(&scenarios, &strategies, &FleetConfig { threads: 2, evals: None }).unwrap();
        let table = standings(&cells);
        assert_eq!(table.len(), 3);
        assert!(table.windows(2).all(|w| w[0].mean_rank <= w[1].mean_rank));
        let total_wins: usize = table.iter().map(|s| s.wins).sum();
        // At least one winner per scenario; ties can add more.
        assert!(total_wins >= 3, "wins {total_wins}");
        for s in &table {
            assert!(s.regret >= 1.0 - 1e-12, "{}: regret {}", s.strategy, s.regret);
        }
    }

    #[test]
    fn report_writes_deterministic_csv() {
        let (scenarios, strategies) = tiny_matrix();
        let cells =
            run_fleet(&scenarios, &strategies, &FleetConfig { threads: 3, evals: None }).unwrap();
        let path = std::env::temp_dir().join("repro_fleet_test.csv");
        report_fleet(&cells, Some(&path)).unwrap();
        let first = std::fs::read_to_string(&path).unwrap();
        let cells2 =
            run_fleet(&scenarios, &strategies, &FleetConfig { threads: 1, evals: None }).unwrap();
        report_fleet(&cells2, Some(&path)).unwrap();
        let second = std::fs::read_to_string(&path).unwrap();
        assert_eq!(first, second, "CSV must be byte-identical per seed");
        assert_eq!(first.lines().count(), 10); // header + 9 cells
    }
}
