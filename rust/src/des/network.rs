//! Link-level network model for the event-driven simulator: per-client
//! uplinks (propagation latency + serialization bandwidth, optional
//! lognormal jitter) and a shared ingress capacity at each aggregator
//! through which concurrent uploads serialize (the contention the
//! closed-form Eq. 6–7 model cannot express).

use crate::configio::NetSpec;
use crate::prng::{Pcg32, Rng};

/// One client's uplink.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkParams {
    /// Propagation latency (virtual seconds).
    pub latency_s: f64,
    /// Serialization bandwidth (data units / virtual second;
    /// `f64::INFINITY` = free).
    pub bandwidth: f64,
}

/// The scenario's network: every client's uplink plus the shared
/// aggregator-side ingress capacity and the jitter amplitude.
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkModel {
    pub uplinks: Vec<LinkParams>,
    /// Ingress service rate at each aggregator (data units / virtual
    /// second). Uploads into the same aggregator queue FIFO through it;
    /// `f64::INFINITY` disables contention.
    pub agg_ingress: f64,
    /// Lognormal sigma applied per transfer to the link latency.
    pub jitter_sigma: f64,
}

impl NetworkModel {
    /// The free network: zero latency, unlimited bandwidth, no
    /// contention, no jitter — transfers are instantaneous, which is the
    /// conformance configuration against the analytic TPD.
    pub fn zero_cost(clients: usize) -> NetworkModel {
        NetworkModel {
            uplinks: vec![
                LinkParams {
                    latency_s: 0.0,
                    bandwidth: f64::INFINITY,
                };
                clients
            ],
            agg_ingress: f64::INFINITY,
            jitter_sigma: 0.0,
        }
    }

    /// Sample per-client uplinks from a [`NetSpec`]'s ranges (a spec
    /// bandwidth of `0.0` means unlimited).
    pub fn sample(clients: usize, spec: &NetSpec, rng: &mut Pcg32) -> NetworkModel {
        let unlimited = |x: f64| if x == 0.0 { f64::INFINITY } else { x };
        let range = |rng: &mut Pcg32, (lo, hi): (f64, f64)| {
            if hi > lo {
                rng.uniform(lo, hi)
            } else {
                lo
            }
        };
        let uplinks = (0..clients)
            .map(|_| LinkParams {
                latency_s: range(rng, spec.latency_range_s),
                bandwidth: unlimited(range(rng, spec.bandwidth_range)),
            })
            .collect();
        NetworkModel {
            uplinks,
            agg_ingress: unlimited(spec.agg_ingress),
            jitter_sigma: spec.jitter_sigma,
        }
    }

    /// Sender-side delay of uploading `data` units from `client`:
    /// jittered latency + serialization time. The receiver-side ingress
    /// queueing is resolved by the event loop (it needs arrival order).
    pub fn transfer_delay(&self, client: usize, data: f64, jitter: &mut Option<Pcg32>) -> f64 {
        let link = &self.uplinks[client];
        let jitter_mult = match jitter {
            Some(rng) => rng.lognormal(self.jitter_sigma),
            None => 1.0,
        };
        link.latency_s * jitter_mult + data / link.bandwidth
    }

    /// Ingress service time of `data` units at an aggregator (0 when
    /// contention is disabled).
    pub fn ingress_service(&self, data: f64) -> f64 {
        data / self.agg_ingress
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_cost_transfers_are_instant() {
        let net = NetworkModel::zero_cost(5);
        let mut jitter = None;
        for c in 0..5 {
            assert_eq!(net.transfer_delay(c, 5.0, &mut jitter), 0.0);
        }
        assert_eq!(net.ingress_service(30.0), 0.0);
    }

    #[test]
    fn transfer_is_latency_plus_serialization() {
        let net = NetworkModel {
            uplinks: vec![LinkParams {
                latency_s: 0.01,
                bandwidth: 10.0,
            }],
            agg_ingress: 20.0,
            jitter_sigma: 0.0,
        };
        let mut jitter = None;
        assert!((net.transfer_delay(0, 5.0, &mut jitter) - 0.51).abs() < 1e-12);
        assert!((net.ingress_service(5.0) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn sampled_links_respect_ranges() {
        let spec = NetSpec {
            latency_range_s: (0.001, 0.02),
            bandwidth_range: (5.0, 50.0),
            agg_ingress: 100.0,
            jitter_sigma: 0.3,
        };
        let mut rng = Pcg32::seed_from_u64(1);
        let net = NetworkModel::sample(200, &spec, &mut rng);
        assert_eq!(net.uplinks.len(), 200);
        for l in &net.uplinks {
            assert!((0.001..0.02).contains(&l.latency_s));
            assert!((5.0..50.0).contains(&l.bandwidth));
        }
        assert_eq!(net.agg_ingress, 100.0);
    }

    #[test]
    fn zero_spec_bandwidth_means_unlimited() {
        let mut rng = Pcg32::seed_from_u64(2);
        let net = NetworkModel::sample(3, &NetSpec::default(), &mut rng);
        assert!(net.uplinks.iter().all(|l| l.bandwidth.is_infinite()));
        assert!(net.agg_ingress.is_infinite());
    }

    #[test]
    fn jitter_perturbs_latency_only() {
        let net = NetworkModel {
            uplinks: vec![LinkParams {
                latency_s: 1.0,
                bandwidth: f64::INFINITY,
            }],
            agg_ingress: f64::INFINITY,
            jitter_sigma: 0.5,
        };
        let mut jitter = Some(Pcg32::seed_from_u64(3));
        let draws: Vec<f64> = (0..100).map(|_| net.transfer_delay(0, 5.0, &mut jitter)).collect();
        assert!(draws.iter().all(|&d| d > 0.0 && d.is_finite()));
        // Jitter actually varies the delay.
        assert!(draws.iter().any(|&d| (d - draws[0]).abs() > 1e-9));
        // Same seed reproduces the same sequence.
        let mut jitter2 = Some(Pcg32::seed_from_u64(3));
        let draws2: Vec<f64> =
            (0..100).map(|_| net.transfer_delay(0, 5.0, &mut jitter2)).collect();
        assert_eq!(draws, draws2);
    }
}
