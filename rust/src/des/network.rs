//! Link-level network model for the event-driven simulator: per-client
//! uplinks (propagation latency + serialization bandwidth, optional
//! lognormal jitter) and a shared ingress capacity at each aggregator
//! through which concurrent uploads serialize (the contention the
//! closed-form Eq. 6–7 model cannot express).

use crate::configio::NetSpec;
use crate::prng::{Pcg32, Rng};

/// One client's link (asymmetric: upload and download sides differ).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkParams {
    /// Propagation latency (virtual seconds).
    pub latency_s: f64,
    /// Upload serialization bandwidth (data units / virtual second;
    /// `f64::INFINITY` = free). Already includes the scenario's
    /// per-client upload multiplier when bandwidth asymmetry is on.
    pub bandwidth: f64,
    /// Download capacity (data units / virtual second). Caps the
    /// ingress service rate whenever this client serves as an
    /// aggregator — the bandwidth-asymmetry mechanism. `f64::INFINITY`
    /// (the default when asymmetry is off) leaves `agg_ingress` as the
    /// only ingress limit.
    pub down_bandwidth: f64,
}

/// The scenario's network: every client's uplink plus the shared
/// aggregator-side ingress capacity and the jitter amplitude.
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkModel {
    pub uplinks: Vec<LinkParams>,
    /// Ingress service rate at each aggregator (data units / virtual
    /// second). Uploads into the same aggregator queue FIFO through it;
    /// `f64::INFINITY` disables contention.
    pub agg_ingress: f64,
    /// Lognormal sigma applied per transfer to the link latency.
    pub jitter_sigma: f64,
}

impl NetworkModel {
    /// The free network: zero latency, unlimited bandwidth, no
    /// contention, no jitter — transfers are instantaneous, which is the
    /// conformance configuration against the analytic TPD.
    pub fn zero_cost(clients: usize) -> NetworkModel {
        NetworkModel {
            uplinks: vec![
                LinkParams {
                    latency_s: 0.0,
                    bandwidth: f64::INFINITY,
                    down_bandwidth: f64::INFINITY,
                };
                clients
            ],
            agg_ingress: f64::INFINITY,
            jitter_sigma: 0.0,
        }
    }

    /// True when every transfer is *exactly* free: `transfer_delay` ≡ 0
    /// and `ingress_service` ≡ 0 for any data size (zero latency,
    /// infinite bandwidth everywhere, infinite ingress, no jitter).
    /// This is the static gate the DES level-barrier delta fast path
    /// checks before trusting the analytic [`crate::fitness::TpdScratch`]
    /// mirror — see [`crate::des::EventDrivenEnv`].
    pub fn is_free(&self) -> bool {
        self.jitter_sigma == 0.0
            && self.agg_ingress.is_infinite()
            && self.uplinks.iter().all(|l| {
                l.latency_s == 0.0 && l.bandwidth.is_infinite() && l.down_bandwidth.is_infinite()
            })
    }

    /// Sample per-client links from a [`NetSpec`]'s ranges (a spec
    /// bandwidth of `0.0` means unlimited). With bandwidth asymmetry on,
    /// each client's upload bandwidth is the sampled base times an
    /// up-multiplier, and its download capacity the base times a
    /// down-multiplier; asymmetry draws happen only when the mechanism
    /// is enabled, so symmetric scenarios keep their exact RNG streams.
    pub fn sample(clients: usize, spec: &NetSpec, rng: &mut Pcg32) -> NetworkModel {
        let unlimited = |x: f64| if x == 0.0 { f64::INFINITY } else { x };
        let range = |rng: &mut Pcg32, (lo, hi): (f64, f64)| {
            if hi > lo {
                rng.uniform(lo, hi)
            } else {
                lo
            }
        };
        let uplinks = (0..clients)
            .map(|_| {
                let latency_s = range(rng, spec.latency_range_s);
                let base = unlimited(range(rng, spec.bandwidth_range));
                let up = if spec.up_asymmetry_enabled() {
                    range(rng, spec.up_mult_range)
                } else {
                    1.0
                };
                let down_bandwidth = if spec.down_asymmetry_enabled() {
                    base * range(rng, spec.down_mult_range)
                } else {
                    f64::INFINITY
                };
                LinkParams { latency_s, bandwidth: base * up, down_bandwidth }
            })
            .collect();
        NetworkModel {
            uplinks,
            agg_ingress: unlimited(spec.agg_ingress),
            jitter_sigma: spec.jitter_sigma,
        }
    }

    /// Sender-side delay of uploading `data` units from `client`:
    /// jittered latency + serialization time. The receiver-side ingress
    /// queueing is resolved by the event loop (it needs arrival order).
    pub fn transfer_delay(&self, client: usize, data: f64, jitter: &mut Option<Pcg32>) -> f64 {
        let link = &self.uplinks[client];
        let jitter_mult = match jitter {
            Some(rng) => rng.lognormal(self.jitter_sigma),
            None => 1.0,
        };
        link.latency_s * jitter_mult + data / link.bandwidth
    }

    /// Ingress service time of `data` units at the aggregator hosted by
    /// client `agg_client`: the shared ingress capacity and the hosting
    /// client's own download bandwidth both cap the rate (0 when both
    /// are unlimited).
    pub fn ingress_service(&self, agg_client: usize, data: f64) -> f64 {
        data / self.agg_ingress.min(self.uplinks[agg_client].down_bandwidth)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_cost_transfers_are_instant() {
        let net = NetworkModel::zero_cost(5);
        let mut jitter = None;
        for c in 0..5 {
            assert_eq!(net.transfer_delay(c, 5.0, &mut jitter), 0.0);
        }
        assert_eq!(net.ingress_service(0, 30.0), 0.0);
        assert!(net.is_free());
    }

    #[test]
    fn any_finite_cost_disqualifies_is_free() {
        let free = NetworkModel::zero_cost(3);
        let perturb: Vec<(&str, Box<dyn Fn(&mut NetworkModel)>)> = vec![
            ("latency", Box::new(|n: &mut NetworkModel| n.uplinks[1].latency_s = 1e-9)),
            ("bandwidth", Box::new(|n: &mut NetworkModel| n.uplinks[2].bandwidth = 1e12)),
            ("downlink", Box::new(|n: &mut NetworkModel| n.uplinks[0].down_bandwidth = 1e12)),
            ("ingress", Box::new(|n: &mut NetworkModel| n.agg_ingress = 1e12)),
            ("jitter", Box::new(|n: &mut NetworkModel| n.jitter_sigma = 0.1)),
        ];
        for (what, f) in perturb {
            let mut net = free.clone();
            f(&mut net);
            assert!(!net.is_free(), "{what} should disqualify");
        }
    }

    #[test]
    fn transfer_is_latency_plus_serialization() {
        let net = NetworkModel {
            uplinks: vec![LinkParams {
                latency_s: 0.01,
                bandwidth: 10.0,
                down_bandwidth: f64::INFINITY,
            }],
            agg_ingress: 20.0,
            jitter_sigma: 0.0,
        };
        let mut jitter = None;
        assert!((net.transfer_delay(0, 5.0, &mut jitter) - 0.51).abs() < 1e-12);
        assert!((net.ingress_service(0, 5.0) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn sampled_links_respect_ranges() {
        let spec = NetSpec {
            latency_range_s: (0.001, 0.02),
            bandwidth_range: (5.0, 50.0),
            agg_ingress: 100.0,
            jitter_sigma: 0.3,
            ..NetSpec::default()
        };
        let mut rng = Pcg32::seed_from_u64(1);
        let net = NetworkModel::sample(200, &spec, &mut rng);
        assert_eq!(net.uplinks.len(), 200);
        for l in &net.uplinks {
            assert!((0.001..0.02).contains(&l.latency_s));
            assert!((5.0..50.0).contains(&l.bandwidth));
            assert!(l.down_bandwidth.is_infinite(), "symmetric spec leaves downlink free");
        }
        assert_eq!(net.agg_ingress, 100.0);
    }

    #[test]
    fn zero_spec_bandwidth_means_unlimited() {
        let mut rng = Pcg32::seed_from_u64(2);
        let net = NetworkModel::sample(3, &NetSpec::default(), &mut rng);
        assert!(net.uplinks.iter().all(|l| l.bandwidth.is_infinite()));
        assert!(net.agg_ingress.is_infinite());
    }

    #[test]
    fn asymmetric_links_scale_up_and_down_sides_independently() {
        let spec = NetSpec {
            bandwidth_range: (10.0, 10.0), // fixed base isolates the multipliers
            up_mult_range: (0.5, 0.9),
            down_mult_range: (0.1, 0.4),
            ..NetSpec::default()
        };
        let mut rng = Pcg32::seed_from_u64(9);
        let net = NetworkModel::sample(100, &spec, &mut rng);
        for l in &net.uplinks {
            assert!((5.0..9.0).contains(&l.bandwidth), "up {:?}", l);
            assert!((1.0..4.0).contains(&l.down_bandwidth), "down {:?}", l);
        }
        // A weak downlink caps ingress below the shared capacity.
        let mut weak = net.clone();
        weak.agg_ingress = 100.0;
        weak.uplinks[0].down_bandwidth = 2.0;
        assert!((weak.ingress_service(0, 10.0) - 5.0).abs() < 1e-12);
        // A strong downlink leaves agg_ingress as the binding cap.
        weak.uplinks[1].down_bandwidth = 1e6;
        assert!((weak.ingress_service(1, 10.0) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn symmetric_spec_rng_stream_is_unchanged_by_asymmetry_support() {
        // The asymmetry draws are gated: a symmetric spec must sample
        // the exact same links it did before the mechanism existed.
        let spec = NetSpec {
            latency_range_s: (0.001, 0.02),
            bandwidth_range: (5.0, 50.0),
            ..NetSpec::default()
        };
        let a = NetworkModel::sample(50, &spec, &mut Pcg32::seed_from_u64(7));
        // Reference: draw latency and bandwidth pairs straight off the
        // same stream.
        let mut rng = Pcg32::seed_from_u64(7);
        for l in &a.uplinks {
            assert_eq!(l.latency_s, rng.uniform(0.001, 0.02));
            assert_eq!(l.bandwidth, rng.uniform(5.0, 50.0));
        }
    }

    #[test]
    fn jitter_perturbs_latency_only() {
        let net = NetworkModel {
            uplinks: vec![LinkParams {
                latency_s: 1.0,
                bandwidth: f64::INFINITY,
                down_bandwidth: f64::INFINITY,
            }],
            agg_ingress: f64::INFINITY,
            jitter_sigma: 0.5,
        };
        let mut jitter = Some(Pcg32::seed_from_u64(3));
        let draws: Vec<f64> = (0..100).map(|_| net.transfer_delay(0, 5.0, &mut jitter)).collect();
        assert!(draws.iter().all(|&d| d > 0.0 && d.is_finite()));
        // Jitter actually varies the delay.
        assert!(draws.iter().any(|&d| (d - draws[0]).abs() > 1e-9));
        // Same seed reproduces the same sequence.
        let mut jitter2 = Some(Pcg32::seed_from_u64(3));
        let draws2: Vec<f64> =
            (0..100).map(|_| net.transfer_delay(0, 5.0, &mut jitter2)).collect();
        assert_eq!(draws, draws2);
    }
}
