//! Discrete-event simulation of hierarchical FL rounds — the scale and
//! dynamic-scenario tier the closed-form model cannot reach.
//!
//! The paper scores a placement with the Total Processing Delay of
//! Eq. 6–7: per-aggregator cluster delay
//! `d_a = (mdatasize_a + Σ_{c ∈ buffer(a)} mdatasize_c) / pspeed_a`
//! summed over per-level maxima, bottom-up. This module replays that
//! round as *events on a virtual clock* instead of a formula, which
//! makes churn, dropout, stragglers, link contention and 10k-client
//! populations all simulable in milliseconds of wall time.
//!
//! ## Event types ↔ paper terms
//!
//! | event | paper term |
//! |-------|-----------|
//! | `TrainDone { client }` | local training the round waits on before any aggregation (§IV.C round anatomy; not part of Eq. 6, so its workload defaults to 0) |
//! | `Arrive` / `Deliver { slot }` | an update entering aggregator *a*'s *processing buffer* (`buffer(a)` in Eq. 6) after crossing the network; `Deliver` is delayed by the shared-ingress queue — the contention term Eq. 6 has no word for |
//! | `AggDone { slot }` | cluster delay `d_a` elapsing: merge starts when the buffer is full and costs `(mdatasize_a + Σ mdatasize_c) / pspeed_a` virtual seconds — Eq. 6 verbatim |
//! | root `AggDone` | the round's TPD. In [`SyncMode::LevelBarrier`] each level starts only when the level below finished (Eq. 7's per-level `max`, summed), so with a free network the virtual completion time *equals* Eq. 7's TPD; [`SyncMode::Pipelined`] lets subtrees overlap and is never slower |
//!
//! [`EventDrivenEnv`] packages this as the fourth
//! [`crate::placement::Environment`] oracle (selectable anywhere
//! `analytic` is, e.g. `repro sim --env event-driven`), [`scenarios`]
//! holds the dynamic-scenario catalog (churn / dropout / straggler /
//! jitter / drift / correlated-failure / partition / asymmetric-links /
//! 10k-client cases, loadable from TOML, each mechanism addressable for
//! ablation via [`scenarios::MECHANISMS`]), and [`fleet`] adapts the
//! scenario × strategy × replicate matrix of `repro fleet` onto the
//! experiment engine ([`crate::exp`]), reporting replicate means ± 95%
//! CIs, a paired sign-test significance matrix and Wilcoxon
//! signed-rank effect sizes.

pub mod engine;
pub mod fleet;
pub mod network;
pub mod round;
pub mod scenarios;

pub use engine::EventQueue;
pub use fleet::{
    report_fleet, run_fleet, significance_matrix, standings, FleetCell, FleetConfig,
    SignificanceMatrix, StrategyStanding, VersusRow,
};
pub use network::{LinkParams, NetworkModel};
pub use round::{
    simulate_round, EventDrivenEnv, RoundOutcome, RoundRealization, RoundScratch, SyncMode,
};
pub use scenarios::{
    builtin_catalog, disable_mechanism, load_dir, mechanism_enabled, Dynamics, NamedScenario,
    MECHANISMS,
};
