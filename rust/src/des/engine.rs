//! The discrete-event core: a binary-heap event queue over a virtual
//! clock. Events fire in time order; simultaneous events fire in
//! scheduling (FIFO) order via a monotone sequence number, so every
//! simulation is deterministic regardless of heap internals.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One scheduled entry: fire time + insertion sequence + payload.
struct Entry<E> {
    time: f64,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert so the earliest (time, seq)
        // pops first. total_cmp gives a total order on finite times.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// A deterministic discrete-event queue with a virtual clock.
///
/// `pop` advances the clock to the fired event's time; scheduling into
/// the past is a logic error and panics (simulations only look forward).
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: f64,
    processed: u64,
    /// Largest heap length seen since construction (survives `reset` —
    /// it tracks the queue's lifetime, not one round). Plain field: the
    /// caller flushes it into the obs gauge off the hot path.
    high_water: usize,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> EventQueue<E> {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
            processed: 0,
            high_water: 0,
        }
    }

    /// Rewind to a fresh queue without releasing the heap's capacity —
    /// the clear-and-refill reuse the zero-allocation round scratch
    /// relies on (a new round starts at virtual time 0 with sequence
    /// numbers and the processed counter reset, exactly like a
    /// freshly-constructed queue).
    pub fn reset(&mut self) {
        self.heap.clear();
        self.seq = 0;
        self.now = 0.0;
        self.processed = 0;
    }

    /// Current virtual time (seconds).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Events fired so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Largest heap length observed over the queue's lifetime.
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `ev` at absolute virtual time `at` (>= now, finite).
    pub fn schedule_at(&mut self, at: f64, ev: E) {
        assert!(at.is_finite(), "non-finite event time {at}");
        assert!(
            at >= self.now,
            "scheduling into the past: {at} < now {}",
            self.now
        );
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { time: at, seq, ev });
        if self.heap.len() > self.high_water {
            self.high_water = self.heap.len();
        }
    }

    /// Schedule `ev` after a non-negative virtual delay.
    pub fn schedule_in(&mut self, delay: f64, ev: E) {
        assert!(delay >= 0.0, "negative delay {delay}");
        self.schedule_at(self.now + delay, ev);
    }

    /// Fire the next event: advances the clock and returns (time, event).
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let e = self.heap.pop()?;
        self.now = e.time;
        self.processed += 1;
        Some((e.time, e.ev))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(3.0, "c");
        q.schedule_at(1.0, "a");
        q.schedule_at(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, ["a", "b", "c"]);
        assert_eq!(q.now(), 3.0);
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn simultaneous_events_fire_fifo() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.schedule_at(1.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn clock_is_monotone_under_interleaved_scheduling() {
        let mut q = EventQueue::new();
        q.schedule_at(1.0, 0u32);
        let mut last = 0.0;
        let mut fired = 0;
        while let Some((t, n)) = q.pop() {
            assert!(t >= last);
            last = t;
            fired += 1;
            if n < 5 {
                // Chain: each event schedules two more, one at the same
                // instant (FIFO) and one later.
                q.schedule_in(0.0, n + 1);
                q.schedule_in(0.5, n + 1);
            }
        }
        assert!(fired > 5);
        assert_eq!(q.now(), last);
    }

    #[test]
    fn reset_rewinds_clock_sequence_and_counter() {
        let mut q = EventQueue::new();
        q.schedule_at(5.0, 1u32);
        q.schedule_at(6.0, 2);
        q.pop();
        q.reset();
        assert!(q.is_empty());
        assert_eq!(q.now(), 0.0);
        assert_eq!(q.processed(), 0);
        // Post-reset FIFO ordering restarts from sequence zero.
        q.schedule_at(1.0, 10);
        q.schedule_at(1.0, 11);
        assert_eq!(q.pop(), Some((1.0, 10)));
        assert_eq!(q.pop(), Some((1.0, 11)));
    }

    #[test]
    fn high_water_tracks_lifetime_peak() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.schedule_at(i as f64, i);
        }
        assert_eq!(q.high_water(), 10);
        while q.pop().is_some() {}
        assert_eq!(q.high_water(), 10);
        // reset keeps the lifetime peak (gauge semantics).
        q.reset();
        assert_eq!(q.high_water(), 10);
        q.schedule_at(0.0, 0);
        assert_eq!(q.high_water(), 10);
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn rejects_past_events() {
        let mut q = EventQueue::new();
        q.schedule_at(2.0, ());
        q.pop();
        q.schedule_at(1.0, ());
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn rejects_non_finite_times() {
        let mut q = EventQueue::new();
        q.schedule_at(f64::NAN, ());
    }
}
