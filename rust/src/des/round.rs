//! One FL round as a discrete-event simulation, and the
//! [`EventDrivenEnv`] delay oracle built on it.
//!
//! Events model the paper's round anatomy (see the module docs in
//! [`crate::des`] for the Eq. 6–7 mapping): trainers finish local work
//! (`TrainDone`), updates travel the network and queue through the
//! receiving aggregator's shared ingress (`Arrive` → `Deliver`), and an
//! aggregator merges once its processing buffer is full (`AggDone`),
//! forwarding its own update upward until the root completes the round.
//!
//! Two entry points share one event loop:
//!
//! * [`simulate_round`] — the reference API over a materialized
//!   [`Arrangement`] (allocates its per-round tables; fine for tests
//!   and one-off rounds).
//! * [`RoundScratch`] — the oracle hot path: every per-round table plus
//!   the [`EventQueue`] heap lives in a reusable scratch that is
//!   cleared and refilled per candidate, so steady-state batch scoring
//!   performs no heap allocation. Event *scheduling order* (which
//!   breaks virtual-time ties and therefore drives the per-round jitter
//!   stream) is identical between the two paths — same-seed rounds are
//!   bit-for-bit equal, property-tested in `tests/properties.rs`.

use super::engine::EventQueue;
use super::network::NetworkModel;
use super::scenarios::Dynamics;
use crate::configio::SimScenario;
use crate::fitness::{ChunkedFold8, ClientAttrs, TpdScratch};
use crate::hierarchy::{Arrangement, EvalScratch, HierarchySpec};
use crate::placement::{classify, Diff, Environment, PathTally, Placement, PlacementError};
use crate::prng::Pcg32;

/// Synchronization semantics of the simulated round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncMode {
    /// The paper's Eq. 7 semantics: a level's merges start only once the
    /// whole level below has delivered (the coordinator FSM's per-level
    /// barrier). With a free network and zero training this reproduces
    /// the analytic TPD exactly.
    LevelBarrier,
    /// Fully event-driven overlap: each aggregator merges the moment its
    /// own buffer fills. Never slower than [`SyncMode::LevelBarrier`].
    Pipelined,
}

/// One round's realized dynamics, shared by every placement scored in
/// the same batch so candidates compete under identical conditions.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundRealization {
    /// Which clients participate this round *when assigned as trainers*
    /// (aggregator slots always serve — the session would abort
    /// otherwise, and the paper's agtrainers are the stable nodes).
    pub active: Vec<bool>,
    /// Per-client compute slowdown multiplier (>= 1 slows; straggler
    /// bursts × speed drift). Effective speed = `pspeed / slowdown`.
    pub slowdown: Vec<f64>,
    /// Seeds this round's per-transfer jitter stream.
    pub round_seed: u64,
}

impl RoundRealization {
    /// The static realization: everyone present, nominal speeds.
    pub fn all_on(clients: usize, round_seed: u64) -> RoundRealization {
        RoundRealization {
            active: vec![true; clients],
            slowdown: vec![1.0; clients],
            round_seed,
        }
    }
}

/// Result of simulating one round.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundOutcome {
    /// Virtual time at which the root finished aggregating — the round's
    /// total processing delay.
    pub tpd: f64,
    /// Events fired by the queue.
    pub events: u64,
    /// Trainers whose update never arrived (churned away or dropped).
    pub dropped_trainers: usize,
}

enum Ev {
    /// A trainer finished local training and starts uploading.
    TrainDone { client: usize },
    /// An upload reached aggregator `slot`'s ingress (pre-queueing).
    Arrive { slot: usize, data: f64 },
    /// An upload cleared the ingress and sits in `slot`'s buffer.
    Deliver { slot: usize },
    /// Aggregator `slot` finished merging its buffer (Eq. 6 delay).
    AggDone { slot: usize },
}

/// The shared event loop: drains a pre-seeded queue until the root's
/// `AggDone` fires, returning `(tpd, events)`. Both the reference and
/// the scratch path feed it identically-ordered kickoff events, so
/// their virtual rounds are indistinguishable.
#[allow(clippy::too_many_arguments)]
fn run_event_loop(
    spec: HierarchySpec,
    aggs: &[usize],
    attrs: &[ClientAttrs],
    net: &NetworkModel,
    parent_slot: &[usize],
    expected: &[usize],
    merge_delay: &[f64],
    received: &mut [usize],
    ingress_free: &mut [f64],
    level_waiting: &mut [usize],
    q: &mut EventQueue<Ev>,
    jitter: &mut Option<Pcg32>,
    mode: SyncMode,
) -> (f64, u64) {
    while let Some((t, ev)) = q.pop() {
        match ev {
            Ev::TrainDone { client } => {
                let slot = parent_slot[client];
                let dt = net.transfer_delay(client, attrs[client].mdatasize, jitter);
                q.schedule_at(t + dt, Ev::Arrive { slot, data: attrs[client].mdatasize });
            }
            Ev::Arrive { slot, data } => {
                // FIFO ingress queue: chronological pop order guarantees
                // arrivals are serviced in arrival order. Service rate is
                // capped by both the shared ingress and the hosting
                // client's own download bandwidth (asymmetric links).
                let start = if t > ingress_free[slot] { t } else { ingress_free[slot] };
                let done = start + net.ingress_service(aggs[slot], data);
                ingress_free[slot] = done;
                q.schedule_at(done, Ev::Deliver { slot });
            }
            Ev::Deliver { slot } => {
                if expected[slot] > 0 {
                    received[slot] += 1;
                    if received[slot] < expected[slot] {
                        continue;
                    }
                }
                // Buffer full: this slot may merge.
                match mode {
                    SyncMode::Pipelined => {
                        q.schedule_at(t + merge_delay[slot], Ev::AggDone { slot });
                    }
                    SyncMode::LevelBarrier => {
                        // Bottom-up level index (leaf level first).
                        let li = spec.depth - 1 - spec.level_of(slot);
                        level_waiting[li] -= 1;
                        if level_waiting[li] == 0 {
                            for s in spec.level_slots(spec.depth - 1 - li) {
                                q.schedule_at(t + merge_delay[s], Ev::AggDone { slot: s });
                            }
                        }
                    }
                }
            }
            Ev::AggDone { slot } => {
                if slot == 0 {
                    return (t, q.processed());
                }
                let parent = spec.parent(slot).expect("non-root slot has a parent");
                let c = aggs[slot];
                let dt = net.transfer_delay(c, attrs[c].mdatasize, jitter);
                q.schedule_at(t + dt, Ev::Arrive { slot: parent, data: attrs[c].mdatasize });
            }
        }
    }
    unreachable!("event queue drained before the root aggregation completed")
}

/// Simulate one FL round for `arr` under the given network and realized
/// dynamics. `train_unit` is the local-training workload (0 = training
/// not modeled, matching the analytic TPD). This is the reference path
/// over a materialized [`Arrangement`]; the oracle hot loop runs the
/// same round through a reusable [`RoundScratch`].
pub fn simulate_round(
    arr: &Arrangement,
    attrs: &[ClientAttrs],
    net: &NetworkModel,
    real: &RoundRealization,
    train_unit: f64,
    mode: SyncMode,
) -> RoundOutcome {
    let spec = arr.spec;
    let dims = spec.dimensions();
    debug_assert_eq!(attrs.len(), real.active.len());
    let pspeed_eff = |c: usize| attrs[c].pspeed / real.slowdown[c];

    // Per-slot expectations: how many deliveries fill the buffer, and
    // the Eq. 6 merge delay once it does. Inner slots always hear from
    // every child aggregator; leaf slots only from *active* trainers.
    let mut expected = vec![0usize; dims];
    let mut merge_delay = vec![0.0f64; dims];
    let mut parent_slot = vec![usize::MAX; attrs.len()];
    let mut dropped_trainers = 0usize;
    for slot in 0..dims {
        let agg = arr.aggregators[slot];
        let buffer = arr.buffer_of(slot);
        let data = if spec.is_leaf_slot(slot) {
            // Same chunked fold as `fitness::cluster_delay`, restricted
            // to active trainers, so the all-on case is bit-identical.
            let mut fold = ChunkedFold8::new();
            for &t in &buffer {
                parent_slot[t] = slot;
                if real.active[t] {
                    expected[slot] += 1;
                    fold.push(attrs[t].mdatasize);
                } else {
                    dropped_trainers += 1;
                }
            }
            attrs[agg].mdatasize + fold.finish()
        } else {
            expected[slot] = buffer.len();
            attrs[agg].mdatasize + ChunkedFold8::sum(buffer.iter().map(|&c| attrs[c].mdatasize))
        };
        merge_delay[slot] = data / pspeed_eff(agg);
    }

    let mut q: EventQueue<Ev> = EventQueue::new();
    let mut jitter = (net.jitter_sigma > 0.0).then(|| Pcg32::seed_from_u64(real.round_seed));
    let mut received = vec![0usize; dims];
    let mut ingress_free = vec![0.0f64; dims];
    let mut level_waiting: Vec<usize> =
        (0..spec.depth).map(|li| spec.level_size(spec.depth - 1 - li)).collect();

    // Kick off: trainers start training; slots whose buffer is already
    // full (no active trainers / exact-fit leaves) are ready at t = 0.
    for slot in 0..dims {
        if spec.is_leaf_slot(slot) {
            for t in arr.buffer_of(slot) {
                if real.active[t] {
                    q.schedule_at(train_unit / pspeed_eff(t), Ev::TrainDone { client: t });
                }
            }
        }
        if expected[slot] == 0 {
            q.schedule_at(0.0, Ev::Deliver { slot });
            // Deliver on an empty buffer marks readiness without
            // incrementing `received` past `expected`; see below.
        }
    }

    let (tpd, events) = run_event_loop(
        spec,
        &arr.aggregators,
        attrs,
        net,
        &parent_slot,
        &expected,
        &merge_delay,
        &mut received,
        &mut ingress_free,
        &mut level_waiting,
        &mut q,
        &mut jitter,
        mode,
    );
    RoundOutcome { tpd, events, dropped_trainers }
}

/// Reusable per-round state for the event-driven oracle: the
/// [`EvalScratch`] placement view plus every per-slot table and the
/// event-queue heap, cleared and refilled per candidate. One
/// [`RoundScratch::simulate`] call allocates nothing in steady state.
pub struct RoundScratch {
    view: EvalScratch,
    expected: Vec<usize>,
    merge_delay: Vec<f64>,
    parent_slot: Vec<usize>,
    received: Vec<usize>,
    ingress_free: Vec<f64>,
    level_waiting: Vec<usize>,
    queue: EventQueue<Ev>,
}

impl RoundScratch {
    /// Lifetime peak of the reusable event heap (obs gauge feed).
    pub fn heap_high_water(&self) -> usize {
        self.queue.high_water()
    }

    pub fn new(spec: HierarchySpec, client_count: usize) -> RoundScratch {
        let view = EvalScratch::new(spec, client_count);
        let dims = view.dims();
        RoundScratch {
            view,
            expected: vec![0; dims],
            merge_delay: vec![0.0; dims],
            parent_slot: vec![usize::MAX; client_count],
            received: vec![0; dims],
            ingress_free: vec![0.0; dims],
            level_waiting: vec![0; spec.depth],
            queue: EventQueue::new(),
        }
    }

    /// Validate a candidate against the reusable bitset (no allocation,
    /// no disturbance of any in-flight state).
    pub fn validate(&mut self, position: &[usize]) -> Result<(), PlacementError> {
        self.view.validate(position)
    }

    /// Simulate one round of `position` — bit-identical to
    /// `simulate_round(&Arrangement::from_position(..), ..)`, with zero
    /// steady-state allocation.
    pub fn simulate(
        &mut self,
        position: &[usize],
        attrs: &[ClientAttrs],
        net: &NetworkModel,
        real: &RoundRealization,
        train_unit: f64,
        mode: SyncMode,
    ) -> Result<RoundOutcome, PlacementError> {
        self.view.load(position)?;
        Ok(self.run(position, attrs, net, real, train_unit, mode))
    }

    /// [`RoundScratch::simulate`] for a position that already passed
    /// [`RoundScratch::validate`] — the oracle's batch path, skipping
    /// the redundant per-candidate re-validation.
    pub fn simulate_prevalidated(
        &mut self,
        position: &[usize],
        attrs: &[ClientAttrs],
        net: &NetworkModel,
        real: &RoundRealization,
        train_unit: f64,
        mode: SyncMode,
    ) -> RoundOutcome {
        self.view.load_prevalidated(position);
        self.run(position, attrs, net, real, train_unit, mode)
    }

    /// Setup + kickoff + event loop over the freshly-loaded view.
    fn run(
        &mut self,
        position: &[usize],
        attrs: &[ClientAttrs],
        net: &NetworkModel,
        real: &RoundRealization,
        train_unit: f64,
        mode: SyncMode,
    ) -> RoundOutcome {
        let spec = self.view.spec();
        let dims = self.view.dims();
        let leaf_start = self.view.leaf_start();
        debug_assert_eq!(attrs.len(), real.active.len());
        let pspeed_eff = |c: usize| attrs[c].pspeed / real.slowdown[c];

        self.expected.fill(0);
        let mut dropped_trainers = 0usize;
        for slot in 0..dims {
            let agg = position[slot];
            let data = if slot >= leaf_start {
                let mut fold = ChunkedFold8::new();
                for &t in self.view.leaf_trainers(slot - leaf_start) {
                    self.parent_slot[t] = slot;
                    if real.active[t] {
                        self.expected[slot] += 1;
                        fold.push(attrs[t].mdatasize);
                    } else {
                        dropped_trainers += 1;
                    }
                }
                attrs[agg].mdatasize + fold.finish()
            } else {
                self.expected[slot] = spec.children(slot).len();
                let mut fold = ChunkedFold8::new();
                for child in spec.children(slot) {
                    fold.push(attrs[position[child]].mdatasize);
                }
                attrs[agg].mdatasize + fold.finish()
            };
            self.merge_delay[slot] = data / pspeed_eff(agg);
        }

        self.queue.reset();
        let mut jitter = (net.jitter_sigma > 0.0).then(|| Pcg32::seed_from_u64(real.round_seed));
        self.received.fill(0);
        self.ingress_free.fill(0.0);
        for li in 0..spec.depth {
            self.level_waiting[li] = spec.level_size(spec.depth - 1 - li);
        }

        // Kickoff in the exact reference order (slot-major, trainers in
        // list order): the sequence numbers break virtual-time ties, so
        // this order is part of the bit-exactness contract.
        for slot in 0..dims {
            if slot >= leaf_start {
                for &t in self.view.leaf_trainers(slot - leaf_start) {
                    if real.active[t] {
                        self.queue
                            .schedule_at(train_unit / pspeed_eff(t), Ev::TrainDone { client: t });
                    }
                }
            }
            if self.expected[slot] == 0 {
                self.queue.schedule_at(0.0, Ev::Deliver { slot });
            }
        }

        let (tpd, events) = run_event_loop(
            spec,
            self.view.position(),
            attrs,
            net,
            &self.parent_slot,
            &self.expected,
            &self.merge_delay,
            &mut self.received,
            &mut self.ingress_free,
            &mut self.level_waiting,
            &mut self.queue,
            &mut jitter,
            mode,
        );
        RoundOutcome { tpd, events, dropped_trainers }
    }
}

/// The fourth [`Environment`] oracle: scores placements by simulating a
/// whole FL round in virtual time over the configured network and
/// dynamic-scenario state. Every `eval`/`eval_batch` call is one virtual
/// round; all placements inside one batch are scored under the *same*
/// realized dynamics so candidates compete fairly, and the dynamics
/// advance once per batch. Rounds run on an owned [`RoundScratch`], so
/// batch scoring reuses the event heap and every per-slot table.
///
/// # The level-barrier delta fast path
///
/// When the configured round is *statically analyzable* — level-barrier
/// semantics, an exactly-free network ([`NetworkModel::is_free`]), no
/// modeled training, and an all-on nominal realization — the simulated
/// TPD equals the analytic Eq. 6–7 fold **bit for bit** (same float
/// operations in the same association; see
/// `barrier_mode_reproduces_analytic_tpd_exactly`). In that regime the
/// env keeps a [`TpdScratch`] mirror of the last fully-simulated
/// placement and scores single-replace/single-swap neighbors through
/// `delta_replace`/`delta_swap` at O(slots) instead of running the
/// event loop — the ~100× lever that makes `mega100k`/`mega1M`
/// conformance scoring tractable. Delta-scored candidates fire no
/// events (`events_fired` counts simulated rounds only); every full
/// simulation under the gate re-bases the mirror, with the bit-equality
/// contract asserted in debug builds.
pub struct EventDrivenEnv {
    attrs: Vec<ClientAttrs>,
    net: NetworkModel,
    train_unit: f64,
    mode: SyncMode,
    dynamics: Dynamics,
    realization: RoundRealization,
    scratch: RoundScratch,
    /// Analytic mirror backing the level-barrier delta fast path.
    delta: TpdScratch,
    /// Virtual FL rounds simulated so far (batches + single evals).
    pub rounds_simulated: usize,
    /// Total events fired across all simulated rounds.
    pub events_fired: u64,
    /// Portion of `events_fired` already flushed to the obs counters.
    events_reported: u64,
}

impl EventDrivenEnv {
    pub fn new(
        spec: HierarchySpec,
        attrs: Vec<ClientAttrs>,
        net: NetworkModel,
        train_unit: f64,
        mode: SyncMode,
        mut dynamics: Dynamics,
    ) -> EventDrivenEnv {
        assert!(
            attrs.len() >= spec.dimensions(),
            "population smaller than slot count"
        );
        assert_eq!(net.uplinks.len(), attrs.len(), "one uplink per client");
        let realization = dynamics.next_round(attrs.len());
        let scratch = RoundScratch::new(spec, attrs.len());
        let delta = TpdScratch::new(spec, attrs.len());
        EventDrivenEnv {
            attrs,
            net,
            train_unit,
            mode,
            dynamics,
            realization,
            scratch,
            delta,
            rounds_simulated: 0,
            events_fired: 0,
            events_reported: 0,
        }
    }

    /// The conformance configuration: free network, no jitter, static
    /// population, zero training cost, level-barrier mode — scores equal
    /// [`crate::placement::AnalyticTpd`] for identical placements.
    pub fn conformance(spec: HierarchySpec, attrs: Vec<ClientAttrs>) -> EventDrivenEnv {
        let net = NetworkModel::zero_cost(attrs.len());
        EventDrivenEnv::new(spec, attrs, net, 0.0, SyncMode::LevelBarrier, Dynamics::off())
    }

    /// Build from a scenario's `[des]`/`[net]`/`[dynamics]` extensions.
    /// The network and dynamics draw from streams derived from the
    /// scenario seed, independent of the population/optimizer streams.
    pub fn from_scenario(sc: &SimScenario, attrs: Vec<ClientAttrs>) -> EventDrivenEnv {
        let spec = HierarchySpec::new(sc.depth, sc.width);
        let mut rng = Pcg32::seed_from_u64(sc.seed ^ 0x0DE5_CA7A_106B_00C5);
        let net = NetworkModel::sample(attrs.len(), &sc.des.net, &mut rng);
        let dynamics = Dynamics::new(sc.des.dynamics.clone(), rng.split());
        let mode = if sc.des.pipelined { SyncMode::Pipelined } else { SyncMode::LevelBarrier };
        EventDrivenEnv::new(spec, attrs, net, sc.des.train_unit, mode, dynamics)
    }

    /// The simulated client population.
    pub fn attrs(&self) -> &[ClientAttrs] {
        &self.attrs
    }

    /// The configured network (for conformance/equivalence tests).
    pub fn net(&self) -> &NetworkModel {
        &self.net
    }

    /// The configured synchronization mode.
    pub fn sync_mode(&self) -> SyncMode {
        self.mode
    }

    /// The configured local-training workload.
    pub fn train_unit(&self) -> f64 {
        self.train_unit
    }

    /// The realization the *next* eval/batch will be scored under.
    pub fn realization(&self) -> &RoundRealization {
        &self.realization
    }

    /// True when the *next* round is statically analyzable, i.e. a
    /// simulated round provably equals the analytic fold bit for bit:
    /// level-barrier semantics, no modeled training, an exactly-free
    /// network, and an all-on nominal realization (`pspeed / 1.0`
    /// preserves bits). Checked once per dispatch — O(clients), paid
    /// only on `eval`/`eval_batch` entry, never per candidate.
    fn barrier_delta_eligible(&self) -> bool {
        self.mode == SyncMode::LevelBarrier
            && self.train_unit == 0.0
            && self.net.is_free()
            && self.realization.active.iter().all(|&a| a)
            && self.realization.slowdown.iter().all(|&s| s == 1.0)
    }

    /// Score one *validated* placement. Under the level-barrier gate,
    /// single-coordinate neighbors of the mirrored base placement take
    /// the analytic delta fast path; everything else simulates the full
    /// round and (when gated) re-bases the mirror.
    fn score(&mut self, placement: &[usize], delta_ok: bool, tally: &mut PathTally) -> f64 {
        if delta_ok && self.delta.loaded() {
            match classify(self.delta.position(), placement) {
                Diff::Same => {
                    tally.same += 1;
                    return self.delta.total();
                }
                Diff::Replace { slot, client } => {
                    tally.delta += 1;
                    return self.delta.delta_replace(slot, client, &self.attrs);
                }
                Diff::Swap { i, j } => {
                    tally.delta += 1;
                    return self.delta.delta_swap(i, j, &self.attrs);
                }
                Diff::Full => {}
            }
        }
        tally.full += 1;
        let out = self.scratch.simulate_prevalidated(
            placement,
            &self.attrs,
            &self.net,
            &self.realization,
            self.train_unit,
            self.mode,
        );
        self.events_fired += out.events;
        if delta_ok {
            // Re-base the analytic mirror on this fully-simulated
            // placement so subsequent neighbors classify against it.
            // Bit-equality between the two pipelines in this regime is
            // the fast path's soundness contract (property-tested in
            // tests/properties.rs; asserted here in debug builds).
            let _mirrored = self.delta.eval_prevalidated(placement, &self.attrs);
            debug_assert_eq!(
                _mirrored.to_bits(),
                out.tpd.to_bits(),
                "DES round diverged from its analytic mirror"
            );
        }
        out.tpd
    }

    fn advance_round(&mut self) {
        // In-place advance: the realization's buffers are reused, so
        // batch-to-batch dynamics evolution allocates nothing.
        self.dynamics.next_round_into(self.attrs.len(), &mut self.realization);
        self.rounds_simulated += 1;
        // Flush telemetry once per batch dispatch, never per candidate:
        // three relaxed atomics and no allocation (alloc-guard-pinned).
        crate::obs::defs::DES_ROUNDS.inc();
        crate::obs::defs::DES_EVENTS.add(self.events_fired - self.events_reported);
        self.events_reported = self.events_fired;
        crate::obs::defs::DES_HEAP_HIGH_WATER.set_max(self.scratch.heap_high_water() as i64);
    }
}

impl Environment for EventDrivenEnv {
    fn name(&self) -> &'static str {
        "event-driven"
    }

    fn eval(&mut self, placement: &Placement) -> Result<f64, PlacementError> {
        self.scratch.validate(placement)?;
        let delta_ok = self.barrier_delta_eligible();
        let mut tally = PathTally::default();
        let tpd = self.score(placement, delta_ok, &mut tally);
        tally.flush(1);
        self.advance_round();
        Ok(tpd)
    }

    fn eval_batch(&mut self, batch: &[Placement]) -> Result<Vec<f64>, PlacementError> {
        for p in batch {
            self.scratch.validate(p)?;
        }
        let delta_ok = self.barrier_delta_eligible();
        let mut delays = Vec::with_capacity(batch.len());
        let mut tally = PathTally::default();
        for p in batch {
            delays.push(self.score(p, delta_ok, &mut tally));
        }
        tally.flush(batch.len() as u64);
        self.advance_round();
        Ok(delays)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configio::{DesSpec, DynamicsSpec, NetSpec};
    use crate::fitness::tpd;
    use crate::prng::Rng;

    fn population(n: usize, seed: u64) -> Vec<ClientAttrs> {
        let mut rng = Pcg32::seed_from_u64(seed);
        ClientAttrs::sample_population(n, (5.0, 15.0), (10.0, 50.0), 5.0, &mut rng)
    }

    fn random_placements(
        spec: HierarchySpec,
        cc: usize,
        count: usize,
        seed: u64,
    ) -> Vec<Placement> {
        let mut rng = Pcg32::seed_from_u64(seed);
        (0..count)
            .map(|_| Placement::new(rng.sample_distinct(cc, spec.dimensions())))
            .collect()
    }

    #[test]
    fn barrier_mode_reproduces_analytic_tpd_exactly() {
        for (d, w) in [(1usize, 3usize), (2, 2), (3, 4), (4, 2)] {
            let spec = HierarchySpec::new(d, w);
            let cc = spec.dimensions() + spec.leaf_slots().len() * 2 + 3;
            let attrs = population(cc, 7 + d as u64);
            let real = RoundRealization::all_on(cc, 0);
            let net = NetworkModel::zero_cost(cc);
            for p in random_placements(spec, cc, 8, 11) {
                let arr = Arrangement::from_position(spec, &p, cc);
                let expect = tpd(&arr, &attrs).total;
                let out =
                    simulate_round(&arr, &attrs, &net, &real, 0.0, SyncMode::LevelBarrier);
                assert!(
                    (out.tpd - expect).abs() < 1e-9,
                    "D{d} W{w}: des {} != analytic {}",
                    out.tpd,
                    expect
                );
                assert_eq!(out.dropped_trainers, 0);
            }
        }
    }

    #[test]
    fn scratch_round_is_bit_identical_to_simulate_round() {
        // Across shapes, dynamics and jitter: the reusable scratch must
        // reproduce the reference path bit for bit — tpd, event count
        // and dropped-trainer count — including when the same scratch is
        // reused across many placements.
        for (d, w, seed) in [(1usize, 3usize, 1u64), (2, 2, 2), (3, 3, 3), (2, 4, 4)] {
            let spec = HierarchySpec::new(d, w);
            let cc = spec.dimensions() + spec.leaf_slots().len() * 3 + 5;
            let attrs = population(cc, seed);
            let mut net = NetworkModel::zero_cost(cc);
            // Exercise latency, bandwidth, contention and jitter.
            for (i, l) in net.uplinks.iter_mut().enumerate() {
                l.latency_s = 0.01 + i as f64 * 1e-4;
                l.bandwidth = 20.0 + i as f64;
            }
            net.agg_ingress = 40.0;
            net.jitter_sigma = 0.3;
            let mut dyn_rng = Pcg32::seed_from_u64(seed * 77);
            let mut scratch = RoundScratch::new(spec, cc);
            for (n, p) in random_placements(spec, cc, 6, seed * 13).iter().enumerate() {
                // A realization with dropouts and slowdowns.
                let mut real = RoundRealization::all_on(cc, seed * 1000 + n as u64);
                for a in real.active.iter_mut() {
                    *a = dyn_rng.next_f64() > 0.2;
                }
                for s in real.slowdown.iter_mut() {
                    *s = 1.0 + dyn_rng.next_f64();
                }
                for (train_unit, mode) in
                    [(0.0, SyncMode::LevelBarrier), (2.5, SyncMode::Pipelined)]
                {
                    let arr = Arrangement::from_position(spec, p, cc);
                    let want = simulate_round(&arr, &attrs, &net, &real, train_unit, mode);
                    let got =
                        scratch.simulate(p, &attrs, &net, &real, train_unit, mode).unwrap();
                    assert_eq!(got.tpd.to_bits(), want.tpd.to_bits(), "D{d} W{w} p{n}");
                    assert_eq!(got.events, want.events);
                    assert_eq!(got.dropped_trainers, want.dropped_trainers);
                }
            }
        }
    }

    #[test]
    fn pipelined_mode_is_never_slower_than_barrier() {
        let spec = HierarchySpec::new(3, 3);
        let cc = spec.dimensions() + 20;
        let attrs = population(cc, 3);
        let real = RoundRealization::all_on(cc, 0);
        let net = NetworkModel::zero_cost(cc);
        let mut strictly_faster = 0;
        for p in random_placements(spec, cc, 12, 5) {
            let arr = Arrangement::from_position(spec, &p, cc);
            let barrier = simulate_round(&arr, &attrs, &net, &real, 0.0, SyncMode::LevelBarrier);
            let piped = simulate_round(&arr, &attrs, &net, &real, 0.0, SyncMode::Pipelined);
            assert!(piped.tpd <= barrier.tpd + 1e-12, "{} > {}", piped.tpd, barrier.tpd);
            strictly_faster += (piped.tpd < barrier.tpd - 1e-9) as usize;
        }
        assert!(strictly_faster > 0, "overlap should win somewhere");
    }

    #[test]
    fn training_and_network_costs_extend_the_round() {
        let spec = HierarchySpec::new(2, 2);
        let cc = 9;
        let attrs = population(cc, 4);
        let real = RoundRealization::all_on(cc, 0);
        let arr = Arrangement::from_position(spec, &[0, 1, 2], cc);
        let free = NetworkModel::zero_cost(cc);
        let base = simulate_round(&arr, &attrs, &free, &real, 0.0, SyncMode::LevelBarrier).tpd;
        let trained =
            simulate_round(&arr, &attrs, &free, &real, 10.0, SyncMode::LevelBarrier).tpd;
        assert!(trained > base, "{trained} !> {base}");
        let mut slow = NetworkModel::zero_cost(cc);
        for l in &mut slow.uplinks {
            l.latency_s = 0.25;
            l.bandwidth = 10.0;
        }
        let netted = simulate_round(&arr, &attrs, &slow, &real, 0.0, SyncMode::LevelBarrier).tpd;
        assert!(netted > base, "{netted} !> {base}");
    }

    #[test]
    fn weak_aggregator_downlink_throttles_its_ingress() {
        // Same shape, same uploads; give the root's hosting client a
        // weak downlink — every upload must now serialize through it.
        let spec = HierarchySpec::new(1, 1);
        let cc = 11;
        let attrs = population(cc, 6);
        let real = RoundRealization::all_on(cc, 0);
        let arr = Arrangement::from_position(spec, &[0], cc);
        let mut net = NetworkModel::zero_cost(cc);
        let free = simulate_round(&arr, &attrs, &net, &real, 0.0, SyncMode::LevelBarrier).tpd;
        net.uplinks[0].down_bandwidth = 2.0; // root is client 0
        let throttled =
            simulate_round(&arr, &attrs, &net, &real, 0.0, SyncMode::LevelBarrier).tpd;
        // 10 uploads × 5 units / 2 per s = 25 s of queueing.
        assert!(throttled >= free + 24.0, "downlink cap must bind: {throttled} vs {free}");
        // The same cap on a non-aggregator client changes nothing.
        let mut other = NetworkModel::zero_cost(cc);
        other.uplinks[5].down_bandwidth = 2.0;
        let unaffected =
            simulate_round(&arr, &attrs, &other, &real, 0.0, SyncMode::LevelBarrier).tpd;
        assert_eq!(unaffected, free);
    }

    #[test]
    fn ingress_contention_serializes_uploads() {
        // Wide leaf fan-in: many trainers upload into one aggregator.
        let spec = HierarchySpec::new(1, 1);
        let cc = 11;
        let attrs = population(cc, 6);
        let real = RoundRealization::all_on(cc, 0);
        let arr = Arrangement::from_position(spec, &[0], cc);
        let mut net = NetworkModel::zero_cost(cc);
        let free = simulate_round(&arr, &attrs, &net, &real, 0.0, SyncMode::LevelBarrier).tpd;
        net.agg_ingress = 2.0; // 10 uploads × 5 units / 2 per s = 25 s queueing
        let contended = simulate_round(&arr, &attrs, &net, &real, 0.0, SyncMode::LevelBarrier).tpd;
        assert!(
            contended >= free + 24.0,
            "contention must serialize: {contended} vs {free}"
        );
    }

    #[test]
    fn dropped_trainers_shrink_the_merge() {
        let spec = HierarchySpec::new(2, 2);
        let cc = 15;
        let attrs = population(cc, 9);
        let arr = Arrangement::from_position(spec, &[0, 1, 2], cc);
        let net = NetworkModel::zero_cost(cc);
        let full = RoundRealization::all_on(cc, 0);
        let mut half = full.clone();
        for t in arr.all_trainers().into_iter().step_by(2) {
            half.active[t] = false;
        }
        let base = simulate_round(&arr, &attrs, &net, &full, 0.0, SyncMode::LevelBarrier);
        let degraded = simulate_round(&arr, &attrs, &net, &half, 0.0, SyncMode::LevelBarrier);
        assert!(degraded.dropped_trainers > 0);
        // Less data to merge at the leaves ⇒ never slower.
        assert!(degraded.tpd <= base.tpd + 1e-12);
        assert!(degraded.tpd < base.tpd, "dropouts must shrink leaf merges");
    }

    #[test]
    fn stragglers_slow_the_round() {
        let spec = HierarchySpec::new(2, 2);
        let cc = 9;
        let attrs = population(cc, 2);
        let arr = Arrangement::from_position(spec, &[0, 1, 2], cc);
        let net = NetworkModel::zero_cost(cc);
        let nominal = RoundRealization::all_on(cc, 0);
        let mut burst = nominal.clone();
        burst.slowdown = vec![4.0; cc];
        let base = simulate_round(&arr, &attrs, &net, &nominal, 0.0, SyncMode::LevelBarrier);
        let slow = simulate_round(&arr, &attrs, &net, &burst, 0.0, SyncMode::LevelBarrier);
        assert!((slow.tpd - base.tpd * 4.0).abs() < 1e-9, "{} vs {}", slow.tpd, base.tpd);
    }

    #[test]
    fn env_batch_matches_singles_in_static_scenarios() {
        let spec = HierarchySpec::new(2, 3);
        let cc = 20;
        let attrs = population(cc, 5);
        let batch = random_placements(spec, cc, 5, 3);
        let mut env = EventDrivenEnv::conformance(spec, attrs.clone());
        let batched = env.eval_batch(&batch).unwrap();
        let mut env2 = EventDrivenEnv::conformance(spec, attrs);
        let singles: Vec<f64> = batch.iter().map(|p| env2.eval(p).unwrap()).collect();
        assert_eq!(batched, singles);
        assert_eq!(env.rounds_simulated, 1);
        assert_eq!(env2.rounds_simulated, 5);
        assert!(env.events_fired > 0);
    }

    #[test]
    fn barrier_delta_fast_path_is_bit_identical_to_full_simulation() {
        // Conformance env (static gate holds): after one fully-simulated
        // base round, every replace/swap neighbor must be delta-scored
        // to the exact bits a fresh env's full simulation produces, and
        // must fire zero events doing it.
        let spec = HierarchySpec::new(3, 2);
        let cc = 24;
        let attrs = population(cc, 13);
        let dims = spec.dimensions();
        let mut env = EventDrivenEnv::conformance(spec, attrs.clone());
        let base: Vec<usize> = (0..dims).collect();
        env.eval(&Placement::new(base.clone())).unwrap();
        let events_after_base = env.events_fired;
        let mut rng = Pcg32::seed_from_u64(99);
        for round in 0..40 {
            let mut n = base.clone();
            if round % 2 == 0 {
                // Replace: hand one slot to a client outside the base.
                let s = rng.gen_range(dims as u64) as usize;
                n[s] = dims + rng.gen_range((cc - dims) as u64) as usize;
            } else {
                // Swap two distinct slots' clients.
                let i = rng.gen_range(dims as u64) as usize;
                let j = (i + 1 + rng.gen_range(dims as u64 - 1) as usize) % dims;
                n.swap(i, j);
            }
            let got = env.eval(&Placement::new(n.clone())).unwrap();
            let mut fresh = EventDrivenEnv::conformance(spec, attrs.clone());
            let want = fresh.eval(&Placement::new(n)).unwrap();
            assert_eq!(got.to_bits(), want.to_bits(), "round {round}");
        }
        assert_eq!(
            env.events_fired, events_after_base,
            "delta-scored neighbors must not run the event loop"
        );

        // With modeled training the gate is off: the same neighbors
        // must go through the event loop again.
        let net = NetworkModel::zero_cost(cc);
        let mut gated_off =
            EventDrivenEnv::new(spec, attrs, net, 1.0, SyncMode::LevelBarrier, Dynamics::off());
        gated_off.eval(&Placement::new(base.clone())).unwrap();
        let before = gated_off.events_fired;
        let mut neighbor = base;
        neighbor.swap(0, 1);
        gated_off.eval(&Placement::new(neighbor)).unwrap();
        assert!(gated_off.events_fired > before, "non-free round must simulate");
    }

    #[test]
    fn env_rejects_invalid_placements() {
        let spec = HierarchySpec::new(2, 2);
        let mut env = EventDrivenEnv::conformance(spec, population(8, 1));
        let err = env.eval(&Placement::new(vec![0, 0, 1])).unwrap_err();
        assert!(matches!(err, PlacementError::DuplicateClient { .. }), "{err}");
        let err = env.eval_batch(&[Placement::new(vec![0, 1])]).unwrap_err();
        assert!(matches!(err, PlacementError::WrongArity { .. }), "{err}");
    }

    #[test]
    fn dynamic_env_is_deterministic_per_seed_and_fair_within_a_batch() {
        let mut sc = SimScenario { depth: 2, width: 3, ..SimScenario::default() };
        sc.seed = 77;
        sc.des = DesSpec {
            train_unit: 1.0,
            pipelined: false,
            net: NetSpec {
                latency_range_s: (0.001, 0.05),
                bandwidth_range: (5.0, 50.0),
                agg_ingress: 50.0,
                jitter_sigma: 0.4,
                up_mult_range: (0.5, 1.0),
                down_mult_range: (0.25, 1.0),
            },
            dynamics: DynamicsSpec {
                dropout_prob: 0.2,
                churn_leave_prob: 0.05,
                churn_join_prob: 0.5,
                straggler_prob: 0.5,
                straggler_frac: 0.3,
                straggler_slowdown: 4.0,
                drift_sigma: 0.05,
                corr_fail_prob: 0.2,
                corr_fail_frac: 0.25,
                partition_prob: 0.1,
                partition_frac: 0.25,
                partition_rounds: 2,
            },
        };
        let cc = sc.client_count();
        let spec = HierarchySpec::new(sc.depth, sc.width);
        let attrs = population(cc, sc.seed);
        let batch = random_placements(spec, cc, 6, 8);

        let mut a = EventDrivenEnv::from_scenario(&sc, attrs.clone());
        let mut b = EventDrivenEnv::from_scenario(&sc, attrs);
        for _ in 0..5 {
            let da = a.eval_batch(&batch).unwrap();
            let db = b.eval_batch(&batch).unwrap();
            assert_eq!(da, db, "same seed must reproduce the same virtual rounds");
            // Identical placements in one batch score identically (same
            // realization + same per-eval jitter stream).
            let dup = a.eval_batch(&[batch[0].clone(), batch[0].clone()]).unwrap();
            assert_eq!(dup[0], dup[1]);
            let _ = b.eval_batch(&[batch[0].clone(), batch[0].clone()]).unwrap();
        }
    }
}
