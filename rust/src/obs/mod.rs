//! Runtime telemetry: zero-cost counters/histograms, two-clock span
//! tracing, and Prometheus/Chrome-trace exposition.
//!
//! The paper argues aggregation placement should work *without*
//! exchanging systematic monitoring data between nodes; this module
//! inverts that constraint into a design rule for the repro itself —
//! telemetry must cost ~nothing and perturb nothing:
//!
//! * **Metrics** ([`registry`], [`defs`]) — static atomic counters,
//!   gauges and 64-bucket log-linear histograms declared with the
//!   [`crate::metric!`] macro. Mutation is a relaxed RMW; snapshots
//!   never stop writers; nothing on the `eval_batch` hot path
//!   allocates (enforced by `tests/alloc_guard.rs`), touches an RNG
//!   stream, or alters any frozen CSV byte (enforced by
//!   `tests/obs_neutrality.rs`).
//! * **Spans** ([`spans`]) — bounded-ring trace events in two clock
//!   domains: wall time for live/service paths, **virtual time** (the
//!   DES clock that Eq. 6–7 TPD terms are measured in) for simulated
//!   rounds. `--trace-out trace.json` exports Chrome trace-event JSON
//!   viewable in Perfetto. Disabled-path cost: one relaxed load.
//! * **Exposition** ([`expose`]) — `GET /metrics` in Prometheus text
//!   format on a listener thread inside `repro serve`
//!   (`--metrics-addr`), and `repro obs dump` / `--obs-dump` for a
//!   human-readable snapshot (count/p50/p90/p99/max per histogram).
//!
//! See the README "Observability" section for the metric reference
//! table and a Perfetto walkthrough.

pub mod defs;
pub mod expose;
pub mod registry;
pub mod spans;

pub use defs::register_builtin;
pub use expose::{render_dump, render_prometheus, scrape, MetricsServer};
pub use registry::{
    bucket_bound, bucket_of, snapshot, Counter, CounterVec, FamilySnapshot, FamilyValue, Gauge,
    Histogram, HistogramSnapshot, HistogramVec, Metric, HIST_BUCKETS,
};
pub use spans::{
    collect_spans, dropped_spans, record_virtual, render_chrome_trace, reset_spans, set_tracing,
    tracing_enabled, write_chrome_trace, ClockDomain, SpanRec, WallSpan, SPAN_CAPACITY,
};
