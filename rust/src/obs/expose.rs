//! Metric exposition: Prometheus text rendering, the `/metrics` HTTP
//! listener that rides `repro serve`, and a human-oriented dump.
//!
//! The listener reuses the `broker::tcp` plumbing pattern — a
//! nonblocking accept loop on its own thread with an `AtomicBool`
//! stop flag, joined on drop — because the offline image has no
//! hyper/tokio. It speaks just enough HTTP/1.1 for a scraper:
//! `GET /metrics` → `200 text/plain; version=0.0.4`, anything else →
//! `404`, connection closed per request.

use super::registry::{self, bucket_bound, FamilySnapshot, FamilyValue, HistogramSnapshot};
use std::fmt::Write as _;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn format_f64(v: f64) -> String {
    if v.is_infinite() {
        if v > 0.0 { "+Inf".into() } else { "-Inf".into() }
    } else if v == v.trunc() && v.abs() < 1e15 {
        // Integral values render without an exponent ("3" not "3e0").
        format!("{v:.0}")
    } else {
        format!("{v}")
    }
}

fn render_histogram(
    out: &mut String,
    name: &str,
    extra_label: Option<(&str, &str)>,
    snap: &HistogramSnapshot,
) {
    let prefix = |le: &str| match extra_label {
        Some((k, v)) => format!("{name}_bucket{{{k}=\"{v}\",le=\"{le}\"}}"),
        None => format!("{name}_bucket{{le=\"{le}\"}}"),
    };
    let mut cum = 0u64;
    for (i, n) in snap.buckets.iter().enumerate() {
        cum += n;
        // Elide interior empty buckets: scrape stays ≤ a handful of
        // lines per family while cumulative counts remain exact.
        if *n == 0 && i + 1 < snap.buckets.len() {
            continue;
        }
        let _ = writeln!(out, "{} {}", prefix(&format_f64(bucket_bound(i))), cum);
    }
    let suffix = match extra_label {
        Some((k, v)) => format!("{{{k}=\"{v}\"}}"),
        None => String::new(),
    };
    let _ = writeln!(out, "{name}_sum{suffix} {}", format_f64(snap.sum));
    let _ = writeln!(out, "{name}_count{suffix} {cum}");
}

/// Render a snapshot in Prometheus text exposition format 0.0.4.
pub fn render_prometheus(families: &[FamilySnapshot]) -> String {
    let mut out = String::with_capacity(families.len() * 160);
    for f in families {
        let kind = match &f.value {
            FamilyValue::Counter(_) | FamilyValue::CounterVec(..) => "counter",
            FamilyValue::Gauge(_) => "gauge",
            FamilyValue::Histogram(_) | FamilyValue::HistogramVec(..) => "histogram",
        };
        let _ = writeln!(out, "# HELP {} {}", f.name, f.help);
        let _ = writeln!(out, "# TYPE {} {}", f.name, kind);
        match &f.value {
            FamilyValue::Counter(v) => {
                let _ = writeln!(out, "{} {}", f.name, v);
            }
            FamilyValue::CounterVec(label_key, children) => {
                // Keep an untouched family visible (HELP/TYPE only).
                for (label, v) in children {
                    let _ = writeln!(out, "{}{{{label_key}=\"{label}\"}} {v}", f.name);
                }
            }
            FamilyValue::Gauge(v) => {
                let _ = writeln!(out, "{} {}", f.name, v);
            }
            FamilyValue::Histogram(h) => render_histogram(&mut out, f.name, None, h),
            FamilyValue::HistogramVec(label_key, children) => {
                if children.is_empty() {
                    // Keep the family visible (HELP/TYPE only).
                    continue;
                }
                for (label, h) in children {
                    render_histogram(&mut out, f.name, Some((label_key, label)), h);
                }
            }
        }
    }
    out
}

/// Render a snapshot for humans (`repro obs dump`): counters/gauges as
/// `name = value`, histograms as count/p50/p90/p99/max.
pub fn render_dump(families: &[FamilySnapshot]) -> String {
    let mut out = String::new();
    let hist_line = |out: &mut String, name: &str, suffix: &str, h: &HistogramSnapshot| {
        let q = |p: f64| h.quantile(p).map(|v| format!("{v:.6}")).unwrap_or_else(|| "-".into());
        let _ = writeln!(
            out,
            "{name}{suffix}  count={} sum={:.6} p50={} p90={} p99={} max={:.6}",
            h.count(),
            h.sum,
            q(0.5),
            q(0.9),
            q(0.99),
            h.max,
        );
    };
    for f in families {
        match &f.value {
            FamilyValue::Counter(v) => {
                let _ = writeln!(out, "{} = {}", f.name, v);
            }
            FamilyValue::CounterVec(key, children) => {
                if children.is_empty() {
                    let _ = writeln!(out, "{}  (no series yet)", f.name);
                }
                for (label, v) in children {
                    let _ = writeln!(out, "{}{{{key}=\"{label}\"}} = {v}", f.name);
                }
            }
            FamilyValue::Gauge(v) => {
                let _ = writeln!(out, "{} = {}", f.name, v);
            }
            FamilyValue::Histogram(h) => hist_line(&mut out, f.name, "", h),
            FamilyValue::HistogramVec(key, children) => {
                if children.is_empty() {
                    let _ = writeln!(out, "{}  (no series yet)", f.name);
                }
                for (label, h) in children {
                    hist_line(&mut out, f.name, &format!("{{{key}=\"{label}\"}}"), h);
                }
            }
        }
    }
    out
}

/// Minimal `/metrics` HTTP responder on a background accept thread.
pub struct MetricsServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9898`, port 0 for tests) and start
    /// answering `GET /metrics` with a fresh registry snapshot.
    pub fn start(addr: &str) -> std::io::Result<MetricsServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let accept_thread = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        // Scrapes are tiny; answer inline so one slow
                        // client can't pile up threads.
                        let _ = serve_request(stream);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(MetricsServer {
            addr: local,
            stop,
            accept_thread: Some(accept_thread),
        })
    }

    /// Bound address (use with port 0 for tests).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn serve_request(mut stream: TcpStream) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    // Read until the header terminator (or the 4 KiB cap — scrape
    // requests are one line plus a couple of headers).
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    loop {
        let n = match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => n,
            Err(_) => break,
        };
        buf.extend_from_slice(&chunk[..n]);
        if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > 4096 {
            break;
        }
    }
    let request_line = std::str::from_utf8(&buf)
        .unwrap_or("")
        .lines()
        .next()
        .unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    let (status, content_type, body) = if method == "GET"
        && (path == "/metrics" || path == "/metrics/")
    {
        super::defs::register_builtin();
        let body = render_prometheus(&registry::snapshot());
        ("200 OK", "text/plain; version=0.0.4; charset=utf-8", body)
    } else {
        ("404 Not Found", "text/plain; charset=utf-8", "not found: scrape GET /metrics\n".into())
    };
    let header = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(header.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// One-shot scrape of a running `/metrics` endpoint (`repro obs dump
/// --addr`). Returns the response body.
pub fn scrape(addr: &str) -> std::io::Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    stream.write_all(
        format!("GET /metrics HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n").as_bytes(),
    )?;
    let mut response = String::new();
    stream.read_to_string(&mut response)?;
    let Some((head, body)) = response.split_once("\r\n\r\n") else {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "malformed HTTP response",
        ));
    };
    if !head.starts_with("HTTP/1.1 200") && !head.starts_with("HTTP/1.0 200") {
        return Err(std::io::Error::new(
            std::io::ErrorKind::Other,
            format!("scrape failed: {}", head.lines().next().unwrap_or("?")),
        ));
    }
    Ok(body.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric;

    #[test]
    fn prometheus_rendering_shapes() {
        metric!(counter C, "test_expose_counter_total", "counts things");
        metric!(histogram H, "test_expose_hist_seconds", "times things");
        C.add(3);
        H.observe(0.02);
        H.observe(0.5);
        let text = render_prometheus(&registry::snapshot());
        assert!(text.contains("# HELP test_expose_counter_total counts things"));
        assert!(text.contains("# TYPE test_expose_counter_total counter"));
        assert!(text.contains("test_expose_counter_total 3"));
        assert!(text.contains("# TYPE test_expose_hist_seconds histogram"));
        assert!(text.contains("test_expose_hist_seconds_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("test_expose_hist_seconds_count 2"));
        // Cumulative buckets are monotone.
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.starts_with("test_expose_hist_seconds_bucket")) {
            let n: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(n >= last);
            last = n;
        }
    }

    #[test]
    fn http_listener_serves_metrics() {
        metric!(counter C, "test_expose_http_total", "t");
        C.inc();
        let server = MetricsServer::start("127.0.0.1:0").unwrap();
        let body = scrape(&server.addr().to_string()).unwrap();
        assert!(body.contains("test_expose_http_total 1"));
        // Built-ins are force-registered by the handler: ≥ 10 families.
        let families = body.lines().filter(|l| l.starts_with("# TYPE")).count();
        assert!(families >= 10, "only {families} families in scrape");
        assert!(body.contains("_bucket{le="), "no histogram in scrape");
        // Non-/metrics paths 404 without killing the listener.
        let mut s = TcpStream::connect(server.addr()).unwrap();
        s.write_all(b"GET /nope HTTP/1.1\r\n\r\n").unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 404"));
        assert!(scrape(&server.addr().to_string()).is_ok());
    }

    #[test]
    fn dump_renders_quantiles() {
        metric!(histogram H, "test_expose_dump_seconds", "t");
        for _ in 0..10 {
            H.observe(0.1);
        }
        let text = render_dump(&registry::snapshot());
        let line = text
            .lines()
            .find(|l| l.starts_with("test_expose_dump_seconds"))
            .expect("histogram line");
        assert!(line.contains("count=10"));
        assert!(line.contains("p50="));
        assert!(line.contains("max=0.100000"));
    }
}
