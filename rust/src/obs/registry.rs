//! Lock-free metric primitives + the process-wide registry.
//!
//! Counters, gauges and log-linear histograms are plain statics built
//! from atomics: mutation is one relaxed RMW (plus one relaxed load of
//! the `registered` flag), so the hot path never locks, never
//! allocates, and never syscalls. The first mutation of a metric
//! self-registers it into the global registry (cold path, once);
//! [`crate::obs::defs::register_builtin`] additionally force-registers
//! every built-in so exposition is complete and deterministic even for
//! metrics nothing has touched yet.
//!
//! Snapshots ([`snapshot`]) read every atomic with relaxed loads while
//! writers keep writing — values are per-cell consistent, not a global
//! cut, which is the standard contract for monitoring counters.

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;

/// One registered metric (statics only — registration leaks nothing).
#[derive(Clone, Copy)]
pub enum Metric {
    Counter(&'static Counter),
    CounterVec(&'static CounterVec),
    Gauge(&'static Gauge),
    Histogram(&'static Histogram),
    HistogramVec(&'static HistogramVec),
}

impl Metric {
    /// Exposition name of the underlying metric.
    pub fn name(&self) -> &'static str {
        match self {
            Metric::Counter(c) => c.name,
            Metric::CounterVec(v) => v.name,
            Metric::Gauge(g) => g.name,
            Metric::Histogram(h) => h.name,
            Metric::HistogramVec(v) => v.name,
        }
    }
}

static REGISTRY: Mutex<Vec<Metric>> = Mutex::new(Vec::new());

fn push_registry(m: Metric) {
    REGISTRY.lock().unwrap().push(m);
}

/// Monotonic event counter.
pub struct Counter {
    name: &'static str,
    help: &'static str,
    value: AtomicU64,
    registered: AtomicBool,
}

impl Counter {
    /// Const-construct (use via the [`crate::metric!`] macro).
    pub const fn new(name: &'static str, help: &'static str) -> Counter {
        Counter {
            name,
            help,
            value: AtomicU64::new(0),
            registered: AtomicBool::new(false),
        }
    }

    #[inline]
    fn ensure_registered(&'static self) {
        if !self.registered.load(Ordering::Relaxed) {
            self.register_slow();
        }
    }

    #[cold]
    #[inline(never)]
    fn register_slow(&'static self) {
        if self
            .registered
            .compare_exchange(false, true, Ordering::Relaxed, Ordering::Relaxed)
            .is_ok()
        {
            push_registry(Metric::Counter(self));
        }
    }

    /// Force registration without mutating (exposition completeness).
    pub fn register(&'static self) {
        self.ensure_registered();
    }

    /// Add 1.
    #[inline]
    pub fn inc(&'static self) {
        self.add(1);
    }

    /// Add `n` (one relaxed fetch-add).
    #[inline]
    pub fn add(&'static self, n: u64) {
        self.ensure_registered();
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// One-label counter family: children materialize per label value on
/// first use (each one `Counter`; bounded by label cardinality — fault
/// kinds, store kinds), then behave exactly like static counters.
pub struct CounterVec {
    name: &'static str,
    help: &'static str,
    label_key: &'static str,
    children: Mutex<Vec<(String, &'static Counter)>>,
    registered: AtomicBool,
}

impl CounterVec {
    /// Const-construct (use via the [`crate::metric!`] macro).
    pub const fn new(name: &'static str, help: &'static str, label_key: &'static str) -> CounterVec {
        CounterVec {
            name,
            help,
            label_key,
            children: Mutex::new(Vec::new()),
            registered: AtomicBool::new(false),
        }
    }

    /// Force registration without mutating (exposition completeness).
    pub fn register(&'static self) {
        if !self.registered.load(Ordering::Relaxed)
            && self
                .registered
                .compare_exchange(false, true, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
        {
            push_registry(Metric::CounterVec(self));
        }
    }

    /// Child counter for `label` (created + leaked on first use).
    pub fn with(&'static self, label: &str) -> &'static Counter {
        self.register();
        let mut children = self.children.lock().unwrap();
        if let Some(&(_, c)) = children.iter().find(|(l, _)| l == label) {
            return c;
        }
        let c: &'static Counter = Box::leak(Box::new(Counter::new(self.name, self.help)));
        // Children bypass self-registration — the parent renders them.
        c.registered.store(true, Ordering::Relaxed);
        children.push((label.to_string(), c));
        c
    }

    /// Add 1 to the `label` child.
    pub fn inc(&'static self, label: &str) {
        self.with(label).inc();
    }

    /// Current value of the `label` child (0 when never touched).
    pub fn get(&self, label: &str) -> u64 {
        let children = self.children.lock().unwrap();
        children.iter().find(|(l, _)| l == label).map_or(0, |(_, c)| c.get())
    }

    /// Sum over every child.
    pub fn total(&self) -> u64 {
        self.children.lock().unwrap().iter().map(|(_, c)| c.get()).sum()
    }

    /// `(label, value)` per child, sorted by label.
    pub fn snapshot(&self) -> Vec<(String, u64)> {
        let children = self.children.lock().unwrap();
        let mut out: Vec<(String, u64)> =
            children.iter().map(|(l, c)| (l.clone(), c.get())).collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }
}

/// Last-value (or high-water) gauge.
pub struct Gauge {
    name: &'static str,
    help: &'static str,
    value: AtomicI64,
    registered: AtomicBool,
}

impl Gauge {
    /// Const-construct (use via the [`crate::metric!`] macro).
    pub const fn new(name: &'static str, help: &'static str) -> Gauge {
        Gauge {
            name,
            help,
            value: AtomicI64::new(0),
            registered: AtomicBool::new(false),
        }
    }

    #[inline]
    fn ensure_registered(&'static self) {
        if !self.registered.load(Ordering::Relaxed) {
            self.register_slow();
        }
    }

    #[cold]
    #[inline(never)]
    fn register_slow(&'static self) {
        if self
            .registered
            .compare_exchange(false, true, Ordering::Relaxed, Ordering::Relaxed)
            .is_ok()
        {
            push_registry(Metric::Gauge(self));
        }
    }

    /// Force registration without mutating (exposition completeness).
    pub fn register(&'static self) {
        self.ensure_registered();
    }

    /// Overwrite the value.
    #[inline]
    pub fn set(&'static self, v: i64) {
        self.ensure_registered();
        self.value.store(v, Ordering::Relaxed);
    }

    /// Raise to `v` if larger (high-water tracking).
    #[inline]
    pub fn set_max(&'static self, v: i64) {
        self.ensure_registered();
        self.value.fetch_max(v, Ordering::Relaxed);
    }

    /// Add (possibly negative) `d`.
    #[inline]
    pub fn add(&'static self, d: i64) {
        self.ensure_registered();
        self.value.fetch_add(d, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> i64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Bucket count for [`Histogram`] (63 bounded + 1 overflow).
pub const HIST_BUCKETS: usize = 64;
/// Sub-buckets per octave (√2 bucket-width ratio → ≤ ~20% quantile error).
const HIST_SUB: f64 = 2.0;
/// Lower edge of bucket 0 — everything at or below lands there.
const HIST_MIN: f64 = 1e-5;

/// Upper bound of bucket `i` (`+Inf` for the last).
pub fn bucket_bound(i: usize) -> f64 {
    if i + 1 >= HIST_BUCKETS {
        f64::INFINITY
    } else {
        // bound(i) = MIN · 2^(i/SUB): log-linear, 2 sub-buckets/octave,
        // 1e-5 .. ~2.1e4 over 63 bounded buckets.
        HIST_MIN * (i as f64 / HIST_SUB).exp2()
    }
}

/// Bucket index for value `v` (pure float math, no table, no alloc).
///
/// Prometheus `le` semantics: a value exactly equal to an exposed
/// [`bucket_bound`] counts *in* that bucket. The `log2`/`ceil`
/// estimate can disagree with the `exp2`-computed bound by one ulp
/// (e.g. `bound(1) = 1e-5·√2` rounds up into bucket 2), so the
/// estimate is nudged until `bound(b-1) < v ≤ bound(b)` holds
/// exactly — property-tested as `bucket_of(bucket_bound(i)) == i`.
#[inline]
pub fn bucket_of(v: f64) -> usize {
    if !(v > HIST_MIN) {
        // NaN and everything ≤ MIN collapse into bucket 0.
        return 0;
    }
    let idx = (HIST_SUB * (v / HIST_MIN).log2()).ceil();
    let mut b = if idx >= (HIST_BUCKETS - 1) as f64 {
        HIST_BUCKETS - 1
    } else {
        idx as usize
    };
    if b > 0 && v <= bucket_bound(b - 1) {
        b -= 1;
    } else if v > bucket_bound(b) {
        // Never fires for b = HIST_BUCKETS-1 (that bound is +Inf).
        b += 1;
    }
    b.min(HIST_BUCKETS - 1)
}

/// Log-linear histogram: 64 atomic buckets + exact sum/max.
///
/// `observe` is three relaxed RMWs (bucket, sum-CAS, max) — no locks,
/// no allocation. Quantiles come from a cumulative bucket walk, so
/// p50/p90/p99 carry the √2 bucket-width error; `max` is exact.
pub struct Histogram {
    name: &'static str,
    help: &'static str,
    buckets: [AtomicU64; HIST_BUCKETS],
    /// Σ observed values, stored as f64 bits (CAS-accumulated).
    sum_bits: AtomicU64,
    /// Max observed value as f64 bits — non-negative IEEE-754 floats
    /// order like their bit patterns, so `fetch_max` on bits is exact.
    max_bits: AtomicU64,
    registered: AtomicBool,
}

impl Histogram {
    /// Const-construct (use via the [`crate::metric!`] macro).
    pub const fn new(name: &'static str, help: &'static str) -> Histogram {
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Histogram {
            name,
            help,
            buckets: [ZERO; HIST_BUCKETS],
            sum_bits: ZERO,
            max_bits: ZERO,
            registered: AtomicBool::new(false),
        }
    }

    #[inline]
    fn ensure_registered(&'static self) {
        if !self.registered.load(Ordering::Relaxed) {
            self.register_slow();
        }
    }

    #[cold]
    #[inline(never)]
    fn register_slow(&'static self) {
        if self
            .registered
            .compare_exchange(false, true, Ordering::Relaxed, Ordering::Relaxed)
            .is_ok()
        {
            push_registry(Metric::Histogram(self));
        }
    }

    /// Force registration without mutating (exposition completeness).
    pub fn register(&'static self) {
        self.ensure_registered();
    }

    /// Record one value.
    ///
    /// Input classes: finite `v > 0` land in their log-linear bucket
    /// and feed `sum`/`max`; `v ≤ 0` (including `-Inf`) clamps to 0 in
    /// bucket 0; `+Inf` counts in the overflow bucket (an infinite
    /// round delay must drag quantiles *up*, not vanish into bucket 0)
    /// but is excluded from `sum`/`max` so both stay finite and exact
    /// over the finite observations; `NaN` carries no magnitude at all
    /// and is dropped, counted by `repro_obs_nan_observations_total`.
    /// Every non-NaN observation increments exactly one bucket, so the
    /// `_count == +Inf-bucket` exposition invariant holds.
    #[inline]
    pub fn observe(&'static self, v: f64) {
        self.ensure_registered();
        self.record(v);
    }

    #[inline]
    fn record(&self, v: f64) {
        if v.is_nan() {
            super::defs::NAN_OBSERVATIONS.inc();
            return;
        }
        let v = v.max(0.0);
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        if !v.is_finite() {
            return;
        }
        // f64 sum via CAS on the bit pattern — writers never block.
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
        self.max_bits.fetch_max(v.to_bits(), Ordering::Relaxed);
    }

    /// Point-in-time copy (writers keep writing).
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut buckets = [0u64; HIST_BUCKETS];
        for (dst, src) in buckets.iter_mut().zip(self.buckets.iter()) {
            *dst = src.load(Ordering::Relaxed);
        }
        HistogramSnapshot {
            buckets,
            sum: f64::from_bits(self.sum_bits.load(Ordering::Relaxed)),
            max: f64::from_bits(self.max_bits.load(Ordering::Relaxed)),
        }
    }
}

/// Frozen histogram state: per-bucket counts + exact sum and max.
#[derive(Clone)]
pub struct HistogramSnapshot {
    /// Count per bucket (bounds from [`bucket_bound`]).
    pub buckets: [u64; HIST_BUCKETS],
    /// Exact Σ of observed values.
    pub sum: f64,
    /// Exact max observed value (0 when empty).
    pub max: f64,
}

impl HistogramSnapshot {
    /// Total observation count.
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Quantile estimate: upper bound of the bucket where the
    /// cumulative count crosses `q·count` (`None` when empty; the
    /// last bucket reports the exact max instead of +Inf).
    pub fn quantile(&self, q: f64) -> Option<f64> {
        let total = self.count();
        if total == 0 {
            return None;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, n) in self.buckets.iter().enumerate() {
            cum += n;
            if cum >= target {
                let b = bucket_bound(i);
                return Some(if b.is_finite() { b } else { self.max });
            }
        }
        Some(self.max)
    }
}

/// A histogram family keyed by one label (e.g. per-strategy delays).
///
/// Children are created on first use (cold path: short lock + leak of
/// one `Histogram`; bounded by label cardinality — strategies, store
/// kinds), then behave exactly like static histograms.
pub struct HistogramVec {
    name: &'static str,
    help: &'static str,
    label_key: &'static str,
    children: Mutex<Vec<(String, &'static Histogram)>>,
    registered: AtomicBool,
}

impl HistogramVec {
    /// Const-construct (use via the [`crate::metric!`] macro).
    pub const fn new(
        name: &'static str,
        help: &'static str,
        label_key: &'static str,
    ) -> HistogramVec {
        HistogramVec {
            name,
            help,
            label_key,
            children: Mutex::new(Vec::new()),
            registered: AtomicBool::new(false),
        }
    }

    /// Force registration without mutating (exposition completeness).
    pub fn register(&'static self) {
        if !self.registered.load(Ordering::Relaxed)
            && self
                .registered
                .compare_exchange(false, true, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
        {
            push_registry(Metric::HistogramVec(self));
        }
    }

    /// Child histogram for `label` (created + leaked on first use).
    pub fn with(&'static self, label: &str) -> &'static Histogram {
        self.register();
        let mut children = self.children.lock().unwrap();
        if let Some(&(_, h)) = children.iter().find(|(l, _)| l == label) {
            return h;
        }
        let h: &'static Histogram =
            Box::leak(Box::new(Histogram::new(self.name, self.help)));
        // Children bypass self-registration — the parent renders them.
        h.registered.store(true, Ordering::Relaxed);
        children.push((label.to_string(), h));
        h
    }

    /// Record into the `label` child.
    pub fn observe(&'static self, label: &str, v: f64) {
        self.with(label).record(v);
    }

    /// `(label, snapshot)` per child, sorted by label.
    pub fn snapshot(&self) -> Vec<(String, HistogramSnapshot)> {
        let children = self.children.lock().unwrap();
        let mut out: Vec<(String, HistogramSnapshot)> = children
            .iter()
            .map(|(l, h)| (l.clone(), h.snapshot()))
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }
}

/// Snapshot of one metric family (one series, or one per label).
pub struct FamilySnapshot {
    /// Exposition name (`repro_*`).
    pub name: &'static str,
    /// `# HELP` text.
    pub help: &'static str,
    /// Family value.
    pub value: FamilyValue,
}

/// Value variants a family snapshot can carry.
pub enum FamilyValue {
    /// Monotonic counter.
    Counter(u64),
    /// Labeled counter family: `(label_key, [(label, value)])`.
    CounterVec(&'static str, Vec<(String, u64)>),
    /// Point-in-time gauge.
    Gauge(i64),
    /// Unlabeled histogram.
    Histogram(HistogramSnapshot),
    /// Labeled histogram family: `(label_key, [(label, snap)])`.
    HistogramVec(&'static str, Vec<(String, HistogramSnapshot)>),
}

/// Snapshot every registered metric, sorted by name (writers are not
/// paused — each cell is read atomically, the set is not a global cut).
pub fn snapshot() -> Vec<FamilySnapshot> {
    let metrics: Vec<Metric> = REGISTRY.lock().unwrap().clone();
    let mut out: Vec<FamilySnapshot> = metrics
        .into_iter()
        .map(|m| match m {
            Metric::Counter(c) => FamilySnapshot {
                name: c.name,
                help: c.help,
                value: FamilyValue::Counter(c.get()),
            },
            Metric::CounterVec(v) => FamilySnapshot {
                name: v.name,
                help: v.help,
                value: FamilyValue::CounterVec(v.label_key, v.snapshot()),
            },
            Metric::Gauge(g) => FamilySnapshot {
                name: g.name,
                help: g.help,
                value: FamilyValue::Gauge(g.get()),
            },
            Metric::Histogram(h) => FamilySnapshot {
                name: h.name,
                help: h.help,
                value: FamilyValue::Histogram(h.snapshot()),
            },
            Metric::HistogramVec(v) => FamilySnapshot {
                name: v.name,
                help: v.help,
                value: FamilyValue::HistogramVec(v.label_key, v.snapshot()),
            },
        })
        .collect();
    out.sort_by(|a, b| a.name.cmp(b.name));
    out
}

/// Declare a static metric: `metric!(counter EVALS, "repro_evals_total",
/// "Total placement evaluations");` — also `gauge`, `histogram`, and the
/// one-label `counter_vec` / `histogram_vec NAME, "name", "help",
/// "label_key"` families.
#[macro_export]
macro_rules! metric {
    (counter $vis:vis $NAME:ident, $name:expr, $help:expr) => {
        $vis static $NAME: $crate::obs::Counter = $crate::obs::Counter::new($name, $help);
    };
    (counter_vec $vis:vis $NAME:ident, $name:expr, $help:expr, $label:expr) => {
        $vis static $NAME: $crate::obs::CounterVec =
            $crate::obs::CounterVec::new($name, $help, $label);
    };
    (gauge $vis:vis $NAME:ident, $name:expr, $help:expr) => {
        $vis static $NAME: $crate::obs::Gauge = $crate::obs::Gauge::new($name, $help);
    };
    (histogram $vis:vis $NAME:ident, $name:expr, $help:expr) => {
        $vis static $NAME: $crate::obs::Histogram = $crate::obs::Histogram::new($name, $help);
    };
    (histogram_vec $vis:vis $NAME:ident, $name:expr, $help:expr, $label:expr) => {
        $vis static $NAME: $crate::obs::HistogramVec =
            $crate::obs::HistogramVec::new($name, $help, $label);
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        metric!(counter C, "test_registry_counter_total", "t");
        metric!(gauge G, "test_registry_gauge", "t");
        C.inc();
        C.add(4);
        assert_eq!(C.get(), 5);
        G.set(7);
        G.set_max(3); // lower → no change
        assert_eq!(G.get(), 7);
        G.set_max(11);
        assert_eq!(G.get(), 11);
        G.add(-1);
        assert_eq!(G.get(), 10);
        // Both self-registered exactly once.
        let names: Vec<&str> = snapshot().iter().map(|f| f.name).collect();
        assert_eq!(
            names.iter().filter(|n| **n == "test_registry_counter_total").count(),
            1
        );
        assert_eq!(names.iter().filter(|n| **n == "test_registry_gauge").count(), 1);
    }

    #[test]
    fn bucket_boundaries_are_log_linear() {
        // Bucket 0 swallows ≤ MIN, negatives and NaN.
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(-3.0), 0);
        assert_eq!(bucket_of(f64::NAN), 0);
        assert_eq!(bucket_of(HIST_MIN), 0);
        // A value just above a bound lands in the next bucket; the
        // bound itself (ceil ⇒ inclusive upper edge) stays put.
        for i in 1..HIST_BUCKETS - 1 {
            let b = bucket_bound(i);
            assert_eq!(bucket_of(b * 1.0000001), i + 1, "just above bound {i}");
            assert!(bucket_of(b * 0.999999) <= i, "at-or-below bound {i}");
        }
        // Monotone non-decreasing in v.
        let mut last = 0;
        let mut v = 1e-7;
        while v < 1e6 {
            let b = bucket_of(v);
            assert!(b >= last);
            last = b;
            v *= 1.7;
        }
        // Huge values clamp to the overflow bucket.
        assert_eq!(bucket_of(1e12), HIST_BUCKETS - 1);
        assert!(bucket_bound(HIST_BUCKETS - 1).is_infinite());
    }

    #[test]
    fn bucket_bound_roundtrips_through_bucket_of() {
        // Prometheus `le` semantics: a value exactly equal to an
        // exposed bound counts *in* that bucket, for every bounded
        // bucket (the log2/exp2 ulp nudge makes this exact).
        for i in 0..HIST_BUCKETS - 1 {
            let b = bucket_bound(i);
            assert!(b.is_finite());
            assert_eq!(bucket_of(b), i, "bound({i}) = {b}");
        }
        assert_eq!(bucket_of(f64::INFINITY), HIST_BUCKETS - 1);
    }

    #[test]
    fn histogram_infinite_observations_count_in_overflow_bucket() {
        metric!(histogram H, "test_registry_hist_inf_seconds", "t");
        H.observe(0.5);
        H.observe(f64::INFINITY);
        let snap = H.snapshot();
        assert_eq!(snap.count(), 2, "+Inf must be counted");
        assert_eq!(snap.buckets[HIST_BUCKETS - 1], 1);
        assert!((snap.sum - 0.5).abs() < 1e-12, "sum stays finite and exact");
        assert_eq!(snap.max, 0.5, "max stays the exact finite max");
        // An infinite delay drags the tail quantile up into the
        // overflow bucket, never down toward bucket 0.
        assert!(snap.quantile(0.99).unwrap() >= 0.5);
    }

    #[test]
    fn histogram_nan_observations_are_dropped_and_counted() {
        metric!(histogram H, "test_registry_hist_nan_seconds", "t");
        let before = crate::obs::defs::NAN_OBSERVATIONS.get();
        H.observe(f64::NAN);
        assert_eq!(H.snapshot().count(), 0, "NaN must not land in any bucket");
        assert!(crate::obs::defs::NAN_OBSERVATIONS.get() >= before + 1);
        H.observe(1.0);
        let snap = H.snapshot();
        assert_eq!(snap.count(), 1);
        assert!((snap.sum - 1.0).abs() < 1e-12);
        assert_eq!(snap.max, 1.0);
    }

    #[test]
    fn histogram_nonpositive_observations_land_in_bucket_zero() {
        metric!(histogram H, "test_registry_hist_neg_seconds", "t");
        H.observe(-3.0);
        H.observe(0.0);
        H.observe(f64::NEG_INFINITY);
        let snap = H.snapshot();
        assert_eq!(snap.count(), 3);
        assert_eq!(snap.buckets[0], 3);
        assert_eq!(snap.sum, 0.0);
        assert_eq!(snap.max, 0.0);
    }

    #[test]
    fn histogram_quantiles_and_max() {
        metric!(histogram H, "test_registry_hist_seconds", "t");
        for i in 1..=100 {
            H.observe(i as f64 * 0.01); // 0.01 .. 1.00
        }
        let snap = H.snapshot();
        assert_eq!(snap.count(), 100);
        assert!((snap.sum - 50.5).abs() < 1e-9);
        assert_eq!(snap.max, 1.0);
        let p50 = snap.quantile(0.5).unwrap();
        // √2-width buckets: the p50 bucket bound is within [0.5, 0.72].
        assert!((0.5..=0.75).contains(&p50), "p50 = {p50}");
        let p99 = snap.quantile(0.99).unwrap();
        assert!((0.99..=1.5).contains(&p99), "p99 = {p99}");
        assert!(snap.quantile(1.0).unwrap() >= 1.0);
    }

    #[test]
    fn histogram_empty_quantile_is_none() {
        metric!(histogram H, "test_registry_hist_empty", "t");
        assert!(H.snapshot().quantile(0.5).is_none());
        H.register();
        assert_eq!(H.snapshot().count(), 0);
    }

    #[test]
    fn counter_vec_labels() {
        metric!(counter_vec V, "test_registry_cvec_total", "t", "kind");
        assert_eq!(V.get("drop"), 0);
        V.inc("drop");
        V.inc("drop");
        V.with("panic").add(3);
        assert_eq!(V.get("drop"), 2);
        assert_eq!(V.get("panic"), 3);
        assert_eq!(V.total(), 5);
        // Snapshot is label-sorted; the parent registers exactly once.
        assert_eq!(
            V.snapshot(),
            vec![("drop".to_string(), 2), ("panic".to_string(), 3)]
        );
        let names: Vec<&str> = snapshot()
            .iter()
            .filter(|f| f.name == "test_registry_cvec_total")
            .map(|f| f.name)
            .collect();
        assert_eq!(names.len(), 1);
    }

    #[test]
    fn histogram_vec_labels() {
        metric!(histogram_vec V, "test_registry_vec_seconds", "t", "strategy");
        V.observe("pso", 0.5);
        V.observe("random", 2.0);
        V.observe("pso", 0.25);
        let snaps = V.snapshot();
        assert_eq!(snaps.len(), 2);
        assert_eq!(snaps[0].0, "pso"); // sorted by label
        assert_eq!(snaps[0].1.count(), 2);
        assert_eq!(snaps[1].1.count(), 1);
        // Same label twice returns the same child.
        assert!(std::ptr::eq(V.with("pso"), V.with("pso")));
    }

    #[test]
    fn snapshot_under_concurrent_writers() {
        metric!(counter C, "test_registry_concurrent_total", "t");
        metric!(histogram H, "test_registry_concurrent_seconds", "t");
        C.register();
        H.register();
        std::thread::scope(|scope| {
            for t in 0..4 {
                scope.spawn(move || {
                    for i in 0..5_000 {
                        C.inc();
                        H.observe((t * 5_000 + i) as f64 * 1e-6);
                    }
                });
            }
            // Reader races the writers: every snapshot must be sane
            // (monotone counter, count ≥ 0, sum finite).
            let mut last = 0u64;
            for _ in 0..50 {
                let c = C.get();
                assert!(c >= last);
                last = c;
                let s = H.snapshot();
                assert!(s.count() <= 20_000);
                assert!(s.sum.is_finite());
            }
        });
        assert_eq!(C.get(), 20_000);
        let s = H.snapshot();
        assert_eq!(s.count(), 20_000);
        assert!((s.max - 19_999e-6).abs() < 1e-12);
    }
}
