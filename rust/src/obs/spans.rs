//! Span tracing with two clock domains, exported as Chrome trace JSON.
//!
//! * **Wall spans** time real execution (service rounds against live
//!   clients, scheduler jobs) with `Instant` relative to process start.
//! * **Virtual spans** are keyed on the simulation clock — the DES /
//!   session-machine virtual seconds that the paper's Eq. 6–7 TPD
//!   terms live in — so a fleet run's round/upload/aggregate timeline
//!   is inspectable in Perfetto on the *model's* time axis.
//!
//! Recording is off by default: the only cost on any path is one
//! relaxed atomic load. When enabled (`--trace-out`), spans go into a
//! bounded ring buffer (oldest dropped first, drops counted) guarded
//! by a mutex — spans are round/job granularity, never per-eval, so
//! the lock is uncontended in practice. [`write_chrome_trace`] emits
//! the `trace.json` Perfetto / `chrome://tracing` consumes: wall spans
//! under pid 1, virtual spans under pid 2 (µs ticks = virtual seconds
//! × 1e6).

use std::collections::VecDeque;
use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Ring-buffer capacity (spans; oldest evicted beyond this).
pub const SPAN_CAPACITY: usize = 65_536;

/// Which clock a span's timestamps belong to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClockDomain {
    /// Real time, µs since process start (Chrome pid 1).
    Wall,
    /// Simulation time, µs of virtual seconds (Chrome pid 2).
    Virtual,
}

/// One completed span.
#[derive(Debug, Clone)]
pub struct SpanRec {
    /// Event name (static: no allocation at record time for wall spans).
    pub name: &'static str,
    /// Chrome `cat` — the emitting layer (`service`, `exp`, `des`...).
    pub cat: &'static str,
    /// Optional instance label rendered into `args.label` (session id,
    /// strategy); allocated only when tracing is enabled.
    pub label: Option<String>,
    /// Chrome `tid` lane within the clock-domain pid.
    pub tid: u32,
    /// Clock domain (selects the Chrome pid).
    pub clock: ClockDomain,
    /// Start, µs in the span's clock domain.
    pub ts_us: u64,
    /// Duration, µs.
    pub dur_us: u64,
}

static TRACING: AtomicBool = AtomicBool::new(false);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static RING: Mutex<VecDeque<SpanRec>> = Mutex::new(VecDeque::new());
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Turn span recording on/off (off by default; `--trace-out` enables).
pub fn set_tracing(on: bool) {
    if on {
        // Pin the wall epoch before the first span closes.
        EPOCH.get_or_init(Instant::now);
    }
    TRACING.store(on, Ordering::Relaxed);
}

/// One relaxed load — the entire disabled-path cost.
#[inline]
pub fn tracing_enabled() -> bool {
    TRACING.load(Ordering::Relaxed)
}

/// µs of wall time since the tracing epoch.
fn wall_now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

fn push(span: SpanRec) {
    let mut ring = RING.lock().unwrap();
    if ring.len() >= SPAN_CAPACITY {
        ring.pop_front();
        DROPPED.fetch_add(1, Ordering::Relaxed);
        super::defs::SPANS_DROPPED.inc();
    }
    ring.push_back(span);
}

/// Record a completed virtual-time span (`start_s`/`end_s` in virtual
/// seconds on the DES clock). No-op unless tracing is enabled.
pub fn record_virtual(
    name: &'static str,
    cat: &'static str,
    tid: u32,
    start_s: f64,
    end_s: f64,
    label: Option<String>,
) {
    if !tracing_enabled() {
        return;
    }
    let ts_us = (start_s.max(0.0) * 1e6) as u64;
    let end_us = (end_s.max(0.0) * 1e6) as u64;
    push(SpanRec {
        name,
        cat,
        label,
        tid,
        clock: ClockDomain::Virtual,
        ts_us,
        dur_us: end_us.saturating_sub(ts_us),
    });
}

/// Drop-guard for a wall-clock span: times from construction to drop.
/// Construction is free when tracing is disabled.
pub struct WallSpan {
    name: &'static str,
    cat: &'static str,
    tid: u32,
    label: Option<String>,
    /// `None` ⇔ tracing was off at open time (drop is then free too).
    start_us: Option<u64>,
}

impl WallSpan {
    /// Open a wall span on lane `tid`.
    pub fn start(name: &'static str, cat: &'static str, tid: u32) -> WallSpan {
        WallSpan {
            name,
            cat,
            tid,
            label: None,
            start_us: tracing_enabled().then(wall_now_us),
        }
    }

    /// Attach an instance label (only materialized while tracing).
    pub fn with_label(mut self, label: &str) -> WallSpan {
        if self.start_us.is_some() {
            self.label = Some(label.to_string());
        }
        self
    }

    /// Seconds elapsed since the span opened (0 when tracing is off —
    /// use a real clock for timing that feeds metrics).
    pub fn elapsed_s(&self) -> f64 {
        match self.start_us {
            Some(t0) => (wall_now_us().saturating_sub(t0)) as f64 * 1e-6,
            None => 0.0,
        }
    }
}

impl Drop for WallSpan {
    fn drop(&mut self) {
        let Some(t0) = self.start_us else { return };
        let now = wall_now_us();
        push(SpanRec {
            name: self.name,
            cat: self.cat,
            label: self.label.take(),
            tid: self.tid,
            clock: ClockDomain::Wall,
            ts_us: t0,
            dur_us: now.saturating_sub(t0),
        });
    }
}

/// Copy out the ring buffer (spans stay recorded).
pub fn collect_spans() -> Vec<SpanRec> {
    RING.lock().unwrap().iter().cloned().collect()
}

/// Spans evicted by the ring bound so far.
pub fn dropped_spans() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// Clear the ring (tests / between commands).
pub fn reset_spans() {
    RING.lock().unwrap().clear();
    DROPPED.store(0, Ordering::Relaxed);
}

fn json_escape(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
}

/// Render spans as Chrome trace-event JSON (`ph:"X"` complete events).
/// Wall spans live in the process named `repro wall clock` (pid 1),
/// virtual spans in `repro virtual clock (DES)` (pid 2).
pub fn render_chrome_trace(spans: &[SpanRec]) -> String {
    let mut out = String::with_capacity(128 + spans.len() * 96);
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n");
    // Name the two clock-domain "processes" for the Perfetto sidebar.
    out.push_str(
        "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":1,\"tid\":0,\
         \"args\":{\"name\":\"repro wall clock\"}},\n",
    );
    out.push_str(
        "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":2,\"tid\":0,\
         \"args\":{\"name\":\"repro virtual clock (DES)\"}}",
    );
    for s in spans {
        let pid = match s.clock {
            ClockDomain::Wall => 1,
            ClockDomain::Virtual => 2,
        };
        out.push_str(",\n{\"name\":\"");
        json_escape(s.name, &mut out);
        out.push_str("\",\"cat\":\"");
        json_escape(s.cat, &mut out);
        out.push_str("\",\"ph\":\"X\",\"ts\":");
        out.push_str(&s.ts_us.to_string());
        out.push_str(",\"dur\":");
        out.push_str(&s.dur_us.to_string());
        out.push_str(",\"pid\":");
        out.push_str(&pid.to_string());
        out.push_str(",\"tid\":");
        out.push_str(&s.tid.to_string());
        if let Some(label) = &s.label {
            out.push_str(",\"args\":{\"label\":\"");
            json_escape(label, &mut out);
            out.push_str("\"}");
        }
        out.push('}');
    }
    out.push_str("\n]}\n");
    out
}

/// Write the current ring buffer to `path` as Chrome trace JSON.
/// Returns the number of spans written.
pub fn write_chrome_trace(path: &std::path::Path) -> std::io::Result<usize> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let spans = collect_spans();
    let mut f = std::fs::File::create(path)?;
    f.write_all(render_chrome_trace(&spans).as_bytes())?;
    f.flush()?;
    Ok(spans.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Span tests share the global ring; serialize them.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_tracing_records_nothing() {
        let _g = TEST_LOCK.lock().unwrap();
        set_tracing(false);
        reset_spans();
        record_virtual("round", "des", 1, 0.0, 2.0, None);
        {
            let _s = WallSpan::start("job", "exp", 0);
        }
        assert!(collect_spans().is_empty());
    }

    #[test]
    fn virtual_and_wall_spans_roundtrip() {
        let _g = TEST_LOCK.lock().unwrap();
        set_tracing(true);
        reset_spans();
        record_virtual("round", "service", 3, 1.5, 4.0, Some("pso".into()));
        {
            let _s = WallSpan::start("trial", "exp", 0).with_label("cell-0");
        }
        set_tracing(false);
        let spans = collect_spans();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].clock, ClockDomain::Virtual);
        assert_eq!(spans[0].ts_us, 1_500_000);
        assert_eq!(spans[0].dur_us, 2_500_000);
        assert_eq!(spans[1].clock, ClockDomain::Wall);
        let json = render_chrome_trace(&spans);
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"pid\":2"));
        assert!(json.contains("\"label\":\"pso\""));
        // Parseable by our own JSON reader.
        let v = crate::json::parse(&json).expect("valid JSON");
        let events = v.get("traceEvents").and_then(|e| e.as_array()).unwrap();
        assert_eq!(events.len(), 2 + 2); // 2 metadata + 2 spans
        reset_spans();
    }

    #[test]
    fn ring_is_bounded() {
        let _g = TEST_LOCK.lock().unwrap();
        set_tracing(true);
        reset_spans();
        for i in 0..(SPAN_CAPACITY + 10) {
            record_virtual("e", "t", 0, i as f64, i as f64 + 1.0, None);
        }
        set_tracing(false);
        assert_eq!(collect_spans().len(), SPAN_CAPACITY);
        assert_eq!(dropped_spans(), 10);
        reset_spans();
    }
}
