//! Built-in metric definitions — the single place every `repro_*`
//! family is named, helped, and force-registered.
//!
//! Metrics self-register on first mutation, which is enough for
//! correctness but makes exposition depend on which code paths ran.
//! [`register_builtin`] pins the full set so `/metrics` and
//! `repro obs dump` always show every family (zero-valued when
//! untouched) in a deterministic order. Keep this table in sync with
//! the README "Observability" reference table.

use crate::metric;

// --- placement: optimizer drive loop + analytic TPD oracle ----------------

metric!(
    counter pub PLACEMENT_EVALS,
    "repro_placement_evals_total",
    "Placement evaluations scored, all oracles and strategies"
);
metric!(
    counter pub PLACEMENT_CACHE_HITS,
    "repro_placement_cache_hits_total",
    "Analytic evals answered from the incumbent scratch total (Diff::Same)"
);
metric!(
    counter pub PLACEMENT_DELTA_EVALS,
    "repro_placement_delta_evals_total",
    "Analytic evals scored via replace/swap delta fast paths"
);
metric!(
    counter pub PLACEMENT_FULL_EVALS,
    "repro_placement_full_evals_total",
    "Analytic evals requiring a full TPD recomputation"
);
metric!(
    counter pub DRIVE_BATCHES,
    "repro_drive_batches_total",
    "Optimizer propose/observe batches executed by placement::drive"
);
metric!(
    counter pub DRIVE_RUNS,
    "repro_drive_runs_total",
    "placement::drive optimization runs completed"
);
metric!(
    counter pub SHARD_BATCHES,
    "repro_placement_shard_batches_total",
    "eval_batch calls sharded across ParEvalBatch workers"
);
metric!(
    counter pub SHARD_CANDIDATES,
    "repro_placement_shard_candidates_total",
    "Candidates scored by ParEvalBatch shard workers"
);
metric!(
    gauge pub SHARD_WORKERS_HIGH_WATER,
    "repro_placement_shard_workers_high_water",
    "Largest ParEvalBatch worker count used (high-water mark)"
);
metric!(
    counter pub SHARDED_EXCHANGE_ROUNDS,
    "repro_placement_sharded_exchange_rounds_total",
    "ShardedPso epoch-barrier incumbent exchanges performed"
);
metric!(
    counter pub SHARDED_REGION_IMPROVEMENTS,
    "repro_placement_sharded_region_improvements_total",
    "Regional incumbent improvements accepted by ShardedPso sub-swarms"
);
metric!(
    histogram pub SHARDED_SUBSWARM_BUSY,
    "repro_placement_sharded_subswarm_busy_seconds",
    "Wall seconds per sub-swarm propose step in ShardedPso sweeps"
);

// --- des: virtual-time event core ----------------------------------------

metric!(
    counter pub DES_EVENTS,
    "repro_des_events_total",
    "Discrete events popped by the DES engine across all simulations"
);
metric!(
    counter pub DES_ROUNDS,
    "repro_des_rounds_total",
    "Virtual FL rounds simulated by the DES tier"
);
metric!(
    gauge pub DES_HEAP_HIGH_WATER,
    "repro_des_heap_high_water",
    "Largest DES event-heap length observed (high-water mark)"
);

// --- exp: trial scheduler pool -------------------------------------------

metric!(
    counter pub EXP_JOBS_QUEUED,
    "repro_exp_jobs_queued_total",
    "Trial jobs submitted to the exp scheduler pool"
);
metric!(
    counter pub EXP_JOBS_DONE,
    "repro_exp_jobs_done_total",
    "Trial jobs completed by the exp scheduler pool"
);
metric!(
    counter pub EXP_WORKER_BUSY_US,
    "repro_exp_worker_busy_us_total",
    "Cumulative wall microseconds scheduler workers spent running jobs"
);
metric!(
    histogram pub EXP_QUEUE_WAIT,
    "repro_exp_queue_wait_seconds",
    "Wall seconds between pool start and a worker claiming each job"
);

// --- service: coordinator session tier -----------------------------------

metric!(
    counter pub SERVICE_PHASE_TRANSITIONS,
    "repro_service_phase_transitions_total",
    "Session state-machine phase transitions"
);
metric!(
    counter pub SERVICE_RETRIES,
    "repro_service_retries_total",
    "Round retries spent across all sessions"
);
metric!(
    counter pub SERVICE_HEARTBEAT_MISSES,
    "repro_service_heartbeat_misses_total",
    "Clients dropped from quorum for missing the heartbeat grace window"
);
metric!(
    counter pub SERVICE_SESSIONS_FINISHED,
    "repro_service_sessions_finished_total",
    "Coordinator sessions that reached Finished"
);
metric!(
    counter pub SERVICE_SESSIONS_FAILED,
    "repro_service_sessions_failed_total",
    "Coordinator sessions that reached Failed"
);
metric!(
    histogram_vec pub SERVICE_ROUND_DELAY,
    "repro_service_round_delay_seconds",
    "Per-round TPD in virtual seconds (the paper's Eq. 6-7 objective)",
    "strategy"
);
metric!(
    counter pub SERVICE_STORE_RETRIES,
    "repro_service_store_retries_total",
    "Store save/load attempts retried under the backoff policy"
);
metric!(
    counter pub SERVICE_SESSIONS_QUARANTINED,
    "repro_service_sessions_quarantined_total",
    "Sessions quarantined to Failed after a worker panic"
);
metric!(
    histogram pub STORE_SAVE,
    "repro_store_save_seconds",
    "Wall seconds per session snapshot save"
);
metric!(
    histogram pub STORE_LOAD,
    "repro_store_load_seconds",
    "Wall seconds per session snapshot load"
);

// --- broker: pub/sub plane ------------------------------------------------

metric!(
    counter pub BROKER_MSGS_IN,
    "repro_broker_messages_in_total",
    "Messages published into the broker"
);
metric!(
    counter pub BROKER_BYTES_IN,
    "repro_broker_bytes_in_total",
    "Payload bytes published into the broker"
);
metric!(
    counter pub BROKER_MSGS_OUT,
    "repro_broker_messages_out_total",
    "Messages delivered to broker subscribers"
);
metric!(
    counter pub BROKER_BYTES_OUT,
    "repro_broker_bytes_out_total",
    "Payload bytes delivered to broker subscribers"
);

// --- fault: the deterministic fault-injection plane ------------------------

metric!(
    counter_vec pub FAULT_INJECTED,
    "repro_fault_injected_total",
    "Faults realized by the injection plane, by kind",
    "kind"
);

// --- obs: the telemetry layer itself -------------------------------------

metric!(
    counter pub SPANS_DROPPED,
    "repro_obs_spans_dropped_total",
    "Trace spans evicted from the bounded ring buffer"
);
metric!(
    counter pub NAN_OBSERVATIONS,
    "repro_obs_nan_observations_total",
    "NaN histogram observations dropped (no bucket, no sum, no count)"
);

/// Force-register every built-in family so exposition is complete and
/// deterministic regardless of which code paths have run. Idempotent.
pub fn register_builtin() {
    PLACEMENT_EVALS.register();
    PLACEMENT_CACHE_HITS.register();
    PLACEMENT_DELTA_EVALS.register();
    PLACEMENT_FULL_EVALS.register();
    DRIVE_BATCHES.register();
    DRIVE_RUNS.register();
    SHARD_BATCHES.register();
    SHARD_CANDIDATES.register();
    SHARD_WORKERS_HIGH_WATER.register();
    SHARDED_EXCHANGE_ROUNDS.register();
    SHARDED_REGION_IMPROVEMENTS.register();
    SHARDED_SUBSWARM_BUSY.register();
    DES_EVENTS.register();
    DES_ROUNDS.register();
    DES_HEAP_HIGH_WATER.register();
    EXP_JOBS_QUEUED.register();
    EXP_JOBS_DONE.register();
    EXP_WORKER_BUSY_US.register();
    EXP_QUEUE_WAIT.register();
    SERVICE_PHASE_TRANSITIONS.register();
    SERVICE_RETRIES.register();
    SERVICE_HEARTBEAT_MISSES.register();
    SERVICE_SESSIONS_FINISHED.register();
    SERVICE_SESSIONS_FAILED.register();
    SERVICE_ROUND_DELAY.register();
    SERVICE_STORE_RETRIES.register();
    SERVICE_SESSIONS_QUARANTINED.register();
    STORE_SAVE.register();
    STORE_LOAD.register();
    FAULT_INJECTED.register();
    BROKER_MSGS_IN.register();
    BROKER_BYTES_IN.register();
    BROKER_MSGS_OUT.register();
    BROKER_BYTES_OUT.register();
    SPANS_DROPPED.register();
    NAN_OBSERVATIONS.register();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_families_are_complete() {
        register_builtin();
        register_builtin(); // idempotent
        let names: Vec<&str> = crate::obs::snapshot()
            .iter()
            .map(|f| f.name)
            .filter(|n| n.starts_with("repro_"))
            .collect();
        assert!(names.len() >= 10, "only {} builtin families", names.len());
        // No duplicate registrations.
        let mut dedup = names.clone();
        dedup.dedup();
        assert_eq!(names, dedup);
        // Everything follows the exposition naming conventions.
        for n in &names {
            assert!(
                n.ends_with("_total")
                    || n.ends_with("_seconds")
                    || n.ends_with("_us_total")
                    || n.ends_with("_high_water"),
                "unconventional metric name {n}"
            );
        }
    }
}
