//! # repro — Flag-Swap: PSO aggregation placement for hierarchical SDFL
//!
//! Reproduction of *"Towards a Distributed Federated Learning Aggregation
//! Placement using Particle Swarm Intelligence"* (Ali-Pour et al., 2025)
//! as a three-layer rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the SDFL coordination plane: an MQTT-lite
//!   pub/sub [`broker`], the SDFLMQ-style [`fl`] framework
//!   (roles-as-topics, coordinator, agtrainer agents, round FSM), the
//!   paper's [`pso`] optimizer and the [`placement`] layer — a
//!   registry-driven `Optimizer` × `Environment` API running every
//!   strategy (PSO, GA, SA, tabu, adaptive, baselines) against every
//!   delay oracle (analytic TPD, emulated testbed, live rounds) — the
//!   [`hierarchy`] model and its [`fitness`] (TPD) function, the
//!   [`sim`]ulator that regenerates the paper's Fig. 3, the [`des`]
//!   discrete-event tier (virtual-time rounds over a contended network
//!   with churn/dropout/straggler dynamics, the scenario catalog and
//!   the multi-threaded `repro fleet` matrix runner), and the
//!   [`service`] tier — a persistent multi-session coordinator state
//!   machine with pluggable storage and a metrics sink (`repro serve`).
//! * **L2/L1 (python, build-time only)** — the 1.8 M-parameter MLP and
//!   the Pallas aggregation/SGD kernels, AOT-lowered to HLO text in
//!   `artifacts/` and executed from rust through [`runtime`] (PJRT).
//!
//! The offline build image lacks tokio/serde/clap/criterion/rand/proptest,
//! so their narrow slices are built from scratch here: [`prng`], [`json`],
//! [`configio`], [`metrics`], [`logging`], [`bench`] and [`proplite`]
//! (see DESIGN.md §4). Runtime telemetry — lock-free counters and
//! histograms, wall/virtual-clock span tracing, a `/metrics` endpoint on
//! `repro serve` — lives in [`obs`].

pub mod bench;
pub mod broker;
pub mod configio;
pub mod data;
pub mod des;
pub mod exp;
pub mod fault;
pub mod fitness;
pub mod fl;
pub mod hierarchy;
pub mod json;
pub mod logging;
pub mod metrics;
pub mod obs;
pub mod placement;
pub mod prng;
pub mod proplite;
pub mod pso;
pub mod runtime;
pub mod service;
pub mod sim;
