//! Deterministic synthetic classification data.
//!
//! Class `c` is a Gaussian blob around a fixed random unit-ish center
//! `mu_c` in R^784 with noise sigma; labels are exact. A linear+MLP
//! model learns this quickly, giving the descending loss curve the E2E
//! experiment must show.

use crate::prng::{Pcg32, Rng};

/// Generation parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SynthConfig {
    /// Input dimensionality (must match the model's 784).
    pub input_dim: usize,
    /// Number of classes (10).
    pub num_classes: usize,
    /// Samples per client shard.
    pub samples_per_client: usize,
    /// Blob noise standard deviation.
    pub noise: f64,
    /// Class-skew exponent: 0.0 = IID shards; larger = each client's
    /// shard concentrates on a few classes (non-IID federated setting).
    pub skew: f64,
    /// Root seed (class centers + shard draws derive from it).
    pub seed: u64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            input_dim: 784,
            num_classes: 10,
            samples_per_client: 256,
            noise: 0.8,
            skew: 0.0,
            seed: 1234,
        }
    }
}

/// One client's shard of the synthetic dataset.
#[derive(Debug, Clone)]
pub struct SynthDataset {
    pub cfg: SynthConfig,
    /// Row-major `[n, input_dim]` features.
    pub x: Vec<f32>,
    /// Class ids `[n]`.
    pub y: Vec<i32>,
}

/// Gaussian sample via Box–Muller (we only need mediocre quality).
fn normal(rng: &mut Pcg32) -> f64 {
    let u1 = rng.next_f64().max(1e-12);
    let u2 = rng.next_f64();
    (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
}

impl SynthDataset {
    /// Class centers are derived from `cfg.seed` only — every client
    /// shares the same underlying task (the federated assumption).
    fn class_centers(cfg: &SynthConfig) -> Vec<Vec<f64>> {
        let mut rng = Pcg32::seed_from_u64(cfg.seed ^ 0xC1A5_5E5);
        (0..cfg.num_classes)
            .map(|_| (0..cfg.input_dim).map(|_| normal(&mut rng) * 1.5).collect())
            .collect()
    }

    /// Generate the shard for `client_id`.
    pub fn for_client(cfg: SynthConfig, client_id: usize) -> SynthDataset {
        let centers = Self::class_centers(&cfg);
        let mut rng = Pcg32::seed_from_u64(cfg.seed.wrapping_add(client_id as u64 * 0x9E37));
        // Class distribution for this shard: IID if skew == 0, otherwise
        // a power-law reweighting rotated by client id.
        let mut weights: Vec<f64> = (0..cfg.num_classes)
            .map(|c| {
                let rank = (c + client_id) % cfg.num_classes;
                1.0 / (1.0 + rank as f64).powf(cfg.skew)
            })
            .collect();
        let total: f64 = weights.iter().sum();
        for w in &mut weights {
            *w /= total;
        }
        let mut x = Vec::with_capacity(cfg.samples_per_client * cfg.input_dim);
        let mut y = Vec::with_capacity(cfg.samples_per_client);
        for _ in 0..cfg.samples_per_client {
            // Sample class from the shard distribution.
            let mut u = rng.next_f64();
            let mut class = cfg.num_classes - 1;
            for (c, w) in weights.iter().enumerate() {
                if u < *w {
                    class = c;
                    break;
                }
                u -= w;
            }
            let mu = &centers[class];
            for dim in 0..cfg.input_dim {
                x.push((mu[dim] + normal(&mut rng) * cfg.noise) as f32);
            }
            y.push(class as i32);
        }
        SynthDataset { cfg, x, y }
    }

    /// Number of samples in the shard.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Borrow sample `i` as (features, label).
    pub fn sample(&self, i: usize) -> (&[f32], i32) {
        let d = self.cfg.input_dim;
        (&self.x[i * d..(i + 1) * d], self.y[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> SynthConfig {
        SynthConfig {
            input_dim: 16,
            num_classes: 4,
            samples_per_client: 64,
            ..SynthConfig::default()
        }
    }

    #[test]
    fn deterministic_per_client() {
        let a = SynthDataset::for_client(small_cfg(), 3);
        let b = SynthDataset::for_client(small_cfg(), 3);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn clients_get_different_shards() {
        let a = SynthDataset::for_client(small_cfg(), 0);
        let b = SynthDataset::for_client(small_cfg(), 1);
        assert_ne!(a.x, b.x);
    }

    #[test]
    fn shapes_and_labels_valid() {
        let d = SynthDataset::for_client(small_cfg(), 0);
        assert_eq!(d.len(), 64);
        assert_eq!(d.x.len(), 64 * 16);
        assert!(d.y.iter().all(|&c| (0..4).contains(&c)));
        let (feat, label) = d.sample(5);
        assert_eq!(feat.len(), 16);
        assert_eq!(label, d.y[5]);
    }

    #[test]
    fn iid_shards_cover_all_classes() {
        let d = SynthDataset::for_client(small_cfg(), 0);
        let mut seen = vec![false; 4];
        for &c in &d.y {
            seen[c as usize] = true;
        }
        assert!(seen.iter().all(|&b| b), "{seen:?}");
    }

    #[test]
    fn skew_concentrates_classes() {
        let mut cfg = small_cfg();
        cfg.skew = 4.0;
        cfg.samples_per_client = 400;
        let d = SynthDataset::for_client(cfg, 0);
        let mut counts = vec![0usize; 4];
        for &c in &d.y {
            counts[c as usize] += 1;
        }
        // With heavy skew, the top class dominates.
        let max = *counts.iter().max().unwrap();
        assert!(max > 200, "expected dominant class, got {counts:?}");
    }

    #[test]
    fn classes_are_separable() {
        // Mean same-class distance must be well below mean cross-class
        // distance — otherwise training can't descend.
        let cfg = SynthConfig {
            noise: 0.5,
            ..small_cfg()
        };
        let d = SynthDataset::for_client(cfg, 0);
        let dist = |a: &[f32], b: &[f32]| -> f64 {
            a.iter()
                .zip(b)
                .map(|(x, y)| ((x - y) as f64).powi(2))
                .sum::<f64>()
        };
        let mut same = (0.0, 0usize);
        let mut diff = (0.0, 0usize);
        for i in 0..d.len() {
            for j in (i + 1)..d.len() {
                let (xi, yi) = d.sample(i);
                let (xj, yj) = d.sample(j);
                if yi == yj {
                    same = (same.0 + dist(xi, xj), same.1 + 1);
                } else {
                    diff = (diff.0 + dist(xi, xj), diff.1 + 1);
                }
            }
        }
        let same_mean = same.0 / same.1 as f64;
        let diff_mean = diff.0 / diff.1 as f64;
        assert!(
            diff_mean > same_mean * 1.5,
            "classes not separable: same {same_mean:.2} diff {diff_mean:.2}"
        );
    }
}
