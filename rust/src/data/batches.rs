//! Mini-batch iteration over a client shard (with wrap-around so any
//! number of local steps is possible regardless of shard size).

use super::SynthDataset;

/// Cycling batch iterator producing `[batch, input_dim]` feature rows
/// and `[batch]` labels for `ModelRuntime::train_step`.
pub struct BatchIter<'a> {
    data: &'a SynthDataset,
    batch: usize,
    cursor: usize,
}

impl<'a> BatchIter<'a> {
    pub fn new(data: &'a SynthDataset, batch: usize) -> Self {
        assert!(batch > 0 && !data.is_empty());
        BatchIter {
            data,
            batch,
            cursor: 0,
        }
    }

    /// Next batch (wraps around the shard).
    pub fn next_batch(&mut self) -> (Vec<f32>, Vec<i32>) {
        let d = self.data.cfg.input_dim;
        let n = self.data.len();
        let mut x = Vec::with_capacity(self.batch * d);
        let mut y = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let (feat, label) = self.data.sample(self.cursor);
            x.extend_from_slice(feat);
            y.push(label);
            self.cursor = (self.cursor + 1) % n;
        }
        (x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::super::SynthConfig;
    use super::*;

    fn data() -> SynthDataset {
        SynthDataset::for_client(
            SynthConfig {
                input_dim: 8,
                num_classes: 3,
                samples_per_client: 10,
                ..SynthConfig::default()
            },
            0,
        )
    }

    #[test]
    fn batch_shapes() {
        let d = data();
        let mut it = BatchIter::new(&d, 4);
        let (x, y) = it.next_batch();
        assert_eq!(x.len(), 4 * 8);
        assert_eq!(y.len(), 4);
    }

    #[test]
    fn wraps_around() {
        let d = data();
        let mut it = BatchIter::new(&d, 7);
        let (_, y1) = it.next_batch(); // samples 0..7
        let (_, y2) = it.next_batch(); // samples 7..10 + 0..4 (wrap)
        assert_eq!(y1.len(), 7);
        assert_eq!(y2.len(), 7);
        assert_eq!(y2[3], d.y[0], "wrap should restart at sample 0");
    }

    #[test]
    fn batch_larger_than_shard_wraps_within_one_batch() {
        let d = data();
        let mut it = BatchIter::new(&d, 25);
        let (x, y) = it.next_batch();
        assert_eq!(x.len(), 25 * 8);
        assert_eq!(y[0], y[10], "sample 0 repeats at index 10");
    }
}
