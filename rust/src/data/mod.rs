//! Synthetic federated dataset (substrate; DESIGN.md §4).
//!
//! The paper measures processing delay, not accuracy, and never names its
//! dataset — any fixed-size workload with a learnable signal preserves
//! the measurement. We generate a deterministic 10-class Gaussian-blob
//! classification problem in the MLP's 784-d input space, sharded
//! per-client (each client gets its own slice, optionally non-IID by
//! class skew) so the federated semantics are real.

mod batches;
mod synth;

pub use batches::BatchIter;
pub use synth::{SynthConfig, SynthDataset};
