//! Wall-clock stopwatch used for round-delay measurement.

use std::time::{Duration, Instant};

/// A resettable stopwatch. The coordinator wraps each FL round in one of
/// these; `elapsed()` at round end *is* the paper's processing delay
/// (round end time − round start time, §III).
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    /// Time since start.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Time since start, in seconds.
    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Restart and return the lap time.
    pub fn lap(&mut self) -> Duration {
        let d = self.start.elapsed();
        self.start = Instant::now();
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elapsed_monotonic() {
        let sw = Stopwatch::start();
        let a = sw.elapsed();
        let b = sw.elapsed();
        assert!(b >= a);
    }

    #[test]
    fn lap_resets() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        let lap = sw.lap();
        assert!(lap >= Duration::from_millis(2));
        assert!(sw.elapsed() < lap);
    }
}
