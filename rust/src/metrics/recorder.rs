//! Per-round measurement records — the data behind Fig. 4 and
//! EXPERIMENTS.md.

use std::time::Duration;

/// One FL round's measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundRecord {
    /// 0-based round index.
    pub round: usize,
    /// Placement strategy label ("random" | "uniform" | "pso" | ...).
    pub strategy: String,
    /// Wall-clock processing delay of the round (the black-box signal).
    pub delay: Duration,
    /// Global-model training loss at round end (NaN if not evaluated).
    pub loss: f64,
    /// The aggregator placement used this round (client ids per slot).
    pub placement: Vec<usize>,
}

/// Accumulates [`RoundRecord`]s for one experiment run.
#[derive(Debug, Default, Clone)]
pub struct RoundRecorder {
    records: Vec<RoundRecord>,
}

impl RoundRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, rec: RoundRecord) {
        self.records.push(rec);
    }

    pub fn records(&self) -> &[RoundRecord] {
        &self.records
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Total processing time across all rounds — the paper's headline
    /// comparison metric ("about 43% minutes faster than random ...").
    pub fn total_delay(&self) -> Duration {
        self.records.iter().map(|r| r.delay).sum()
    }

    /// Mean per-round delay in seconds.
    pub fn mean_delay_secs(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.total_delay().as_secs_f64() / self.records.len() as f64
    }

    /// Per-round delays in seconds, in round order.
    pub fn delays_secs(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.delay.as_secs_f64()).collect()
    }

    /// Export the records as JSON-lines (one object per round) — the
    /// machine-readable round event log consumed by analysis tooling.
    pub fn write_jsonl(&self, path: &std::path::Path) -> std::io::Result<()> {
        use crate::json::{to_string, Value};
        use std::io::Write;
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        for r in &self.records {
            let v = Value::object(vec![
                ("round", Value::from(r.round)),
                ("strategy", Value::from(r.strategy.as_str())),
                ("delay_s", Value::Num(r.delay.as_secs_f64())),
                ("loss", Value::Num(r.loss)),
                (
                    "placement",
                    Value::Array(r.placement.iter().map(|&c| Value::from(c)).collect()),
                ),
            ]);
            writeln!(f, "{}", to_string(&v))?;
        }
        f.flush()
    }

    /// First round index from which the placement never changes again
    /// (`None` if it keeps moving) — Fig. 4's "converged after round 10".
    pub fn convergence_round(&self) -> Option<usize> {
        let last = &self.records.last()?.placement;
        let mut conv = self.records.len() - 1;
        for (i, r) in self.records.iter().enumerate().rev() {
            if &r.placement == last {
                conv = i;
            } else {
                break;
            }
        }
        // "Never changed" counts as converged at 0; "changed on the last
        // round" means not converged.
        if conv == self.records.len() - 1 && self.records.len() > 1 {
            None
        } else {
            Some(conv)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, secs: f64, placement: Vec<usize>) -> RoundRecord {
        RoundRecord {
            round,
            strategy: "test".into(),
            delay: Duration::from_secs_f64(secs),
            loss: f64::NAN,
            placement,
        }
    }

    #[test]
    fn totals_and_means() {
        let mut r = RoundRecorder::new();
        r.push(rec(0, 1.0, vec![0]));
        r.push(rec(1, 3.0, vec![0]));
        assert!((r.total_delay().as_secs_f64() - 4.0).abs() < 1e-9);
        assert!((r.mean_delay_secs() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn convergence_detected() {
        let mut r = RoundRecorder::new();
        r.push(rec(0, 1.0, vec![1, 2]));
        r.push(rec(1, 1.0, vec![2, 1]));
        r.push(rec(2, 1.0, vec![3, 1]));
        r.push(rec(3, 1.0, vec![3, 1]));
        r.push(rec(4, 1.0, vec![3, 1]));
        assert_eq!(r.convergence_round(), Some(2));
    }

    #[test]
    fn no_convergence_when_last_changes() {
        let mut r = RoundRecorder::new();
        r.push(rec(0, 1.0, vec![1]));
        r.push(rec(1, 1.0, vec![2]));
        assert_eq!(r.convergence_round(), None);
    }

    #[test]
    fn jsonl_export_parses_back() {
        let mut r = RoundRecorder::new();
        r.push(rec(0, 1.5, vec![1, 2]));
        r.push(rec(1, 2.5, vec![2, 1]));
        let path = std::env::temp_dir().join("repro_recorder_test.jsonl");
        r.write_jsonl(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for (i, line) in lines.iter().enumerate() {
            let v = crate::json::parse(line).unwrap();
            assert_eq!(v.get("round").unwrap().as_usize(), Some(i));
            assert!(v.get("delay_s").unwrap().as_f64().unwrap() > 0.0);
            assert_eq!(v.get("placement").unwrap().as_array().unwrap().len(), 2);
        }
    }

    #[test]
    fn stable_from_start() {
        let mut r = RoundRecorder::new();
        r.push(rec(0, 1.0, vec![5]));
        r.push(rec(1, 1.0, vec![5]));
        assert_eq!(r.convergence_round(), Some(0));
    }
}
