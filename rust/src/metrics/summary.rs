//! Summary statistics over a series of measurements (bench reporting).

/// Order statistics + moments for a sample of f64 measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute from a sample (empty sample yields all-zero summary).
    pub fn from(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
                max: 0.0,
            };
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile(&sorted, 0.50),
            p90: percentile(&sorted, 0.90),
            p99: percentile(&sorted, 0.99),
            max: sorted[n - 1],
        }
    }

    /// One-line human rendering used by the bench harness.
    pub fn render(&self, unit: &str) -> String {
        format!(
            "n={} mean={:.4}{u} std={:.4}{u} min={:.4}{u} p50={:.4}{u} p90={:.4}{u} p99={:.4}{u} max={:.4}{u}",
            self.n, self.mean, self.std, self.min, self.p50, self.p90, self.p99, self.max,
            u = unit
        )
    }
}

/// Competition ranks (1-based, ascending: smallest value gets rank 1,
/// ties share the lowest rank and the next distinct value skips — "1224"
/// ranking); NaNs sort last. Used by the fleet runner to rank strategies
/// inside each scenario, so tying the winner still counts as a win.
pub fn rank_ascending(xs: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]));
    let mut ranks = vec![0usize; xs.len()];
    let mut rank = 1usize;
    for (pos, &i) in idx.iter().enumerate() {
        if pos > 0 && xs[i].total_cmp(&xs[idx[pos - 1]]).is_gt() {
            rank = pos + 1;
        }
        ranks[i] = rank;
    }
    ranks
}

/// Nearest-rank percentile over a pre-sorted sample.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    let idx = ((sorted.len() as f64) * q).ceil() as usize;
    sorted[idx.clamp(1, sorted.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    fn empty_is_zero() {
        let s = Summary::from(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn single_sample() {
        let s = Summary::from(&[7.5]);
        assert_eq!(s.p50, 7.5);
        assert_eq!(s.p99, 7.5);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn rank_ascending_is_competition_ranking() {
        assert_eq!(rank_ascending(&[3.0, 1.0, 2.0]), vec![3, 1, 2]);
        // Ties share the lowest rank; the next distinct value skips.
        assert_eq!(rank_ascending(&[2.0, 1.0, 1.0]), vec![3, 1, 1]);
        assert_eq!(rank_ascending(&[5.0, 5.0, 5.0]), vec![1, 1, 1]);
        assert_eq!(rank_ascending(&[1.0, 1.0, 2.0, 2.0, 3.0]), vec![1, 1, 3, 3, 5]);
        assert_eq!(rank_ascending(&[]), Vec::<usize>::new());
        // NaN sorts last instead of poisoning the ordering.
        let r = rank_ascending(&[f64::NAN, 1.0]);
        assert_eq!(r, vec![2, 1]);
    }

    #[test]
    fn percentile_monotone() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let s = Summary::from(&xs);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p99 && s.p99 <= s.max);
    }
}
