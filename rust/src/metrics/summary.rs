//! Summary statistics over a series of measurements (bench reporting).

/// Order statistics + moments for a sample of f64 measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute from a sample (empty sample yields all-zero summary).
    pub fn from(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
                max: 0.0,
            };
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile(&sorted, 0.50),
            p90: percentile(&sorted, 0.90),
            p99: percentile(&sorted, 0.99),
            max: sorted[n - 1],
        }
    }

    /// One-line human rendering used by the bench harness.
    pub fn render(&self, unit: &str) -> String {
        format!(
            "n={} mean={:.4}{u} std={:.4}{u} min={:.4}{u} p50={:.4}{u} p90={:.4}{u} p99={:.4}{u} max={:.4}{u}",
            self.n, self.mean, self.std, self.min, self.p50, self.p90, self.p99, self.max,
            u = unit
        )
    }
}

/// Competition ranks (1-based, ascending: smallest value gets rank 1,
/// ties share the lowest rank and the next distinct value skips — "1224"
/// ranking); NaNs sort last. Used by the fleet runner to rank strategies
/// inside each scenario, so tying the winner still counts as a win.
pub fn rank_ascending(xs: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]));
    let mut ranks = vec![0usize; xs.len()];
    let mut rank = 1usize;
    for (pos, &i) in idx.iter().enumerate() {
        if pos > 0 && xs[i].total_cmp(&xs[idx[pos - 1]]).is_gt() {
            rank = pos + 1;
        }
        ranks[i] = rank;
    }
    ranks
}

/// Nearest-rank percentile over a pre-sorted sample.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    let idx = ((sorted.len() as f64) * q).ceil() as usize;
    sorted[idx.clamp(1, sorted.len()) - 1]
}

/// Sample mean with a two-sided 95% Student-t confidence interval,
/// the statistic behind the fleet's replicate columns.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MeanCi {
    pub n: usize,
    pub mean: f64,
    /// Half-width of the 95% CI (`mean ± half_width`). Degenerate
    /// samples (n <= 1, or all values equal) report `0.0` so the
    /// statistic stays finite and CSV-printable.
    pub half_width: f64,
}

/// Two-sided 95% Student-t critical value for `df` degrees of freedom.
fn t_critical_95(df: usize) -> f64 {
    const TABLE: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, 2.201, 2.179,
        2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, 2.080, 2.074, 2.069, 2.064,
        2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    match df {
        0 => f64::INFINITY,
        1..=30 => TABLE[df - 1],
        31..=40 => 2.021,
        41..=60 => 2.000,
        61..=120 => 1.980,
        _ => 1.960,
    }
}

/// Mean ± 95% CI (Student-t) of a sample. `n = 0` yields all zeros and
/// `n = 1` a degenerate zero-width interval — both deterministic, finite
/// values rather than NaNs, so downstream sorting/CSV stay well-formed.
pub fn mean_ci(samples: &[f64]) -> MeanCi {
    let n = samples.len();
    if n == 0 {
        return MeanCi { n: 0, mean: 0.0, half_width: 0.0 };
    }
    let mean = samples.iter().sum::<f64>() / n as f64;
    if n == 1 {
        return MeanCi { n, mean, half_width: 0.0 };
    }
    let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64;
    MeanCi { n, mean, half_width: t_critical_95(n - 1) * (var / n as f64).sqrt() }
}

/// Result of a two-sided exact paired sign test.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SignTest {
    /// Pairs where the first series was strictly smaller (better, for
    /// delays).
    pub a_wins: usize,
    /// Pairs where the second series was strictly smaller.
    pub b_wins: usize,
    /// Exactly-equal pairs (dropped from the test, the usual treatment).
    pub ties: usize,
    /// Two-sided p-value of H0 "neither series is systematically
    /// smaller" (exact binomial, `2·min(tails)` capped at 1; `1.0` when
    /// every pair ties).
    pub p_value: f64,
}

/// Two-sided exact paired sign test over two equal-length series — the
/// fleet's significance test between two strategies' per-(scenario,
/// replicate) delays. Distribution-free, so it is safe on the wildly
/// non-normal delay scales the scenario catalog mixes. Symmetric:
/// swapping the series swaps `a_wins`/`b_wins` and keeps `p_value`.
pub fn paired_sign_test(a: &[f64], b: &[f64]) -> SignTest {
    assert_eq!(a.len(), b.len(), "paired sign test needs equal-length series");
    let (mut a_wins, mut b_wins, mut ties) = (0usize, 0usize, 0usize);
    for (&x, &y) in a.iter().zip(b) {
        match x.total_cmp(&y) {
            std::cmp::Ordering::Less => a_wins += 1,
            std::cmp::Ordering::Greater => b_wins += 1,
            std::cmp::Ordering::Equal => ties += 1,
        }
    }
    let n = a_wins + b_wins;
    let p_value = if n == 0 {
        1.0
    } else {
        let k = a_wins.min(b_wins);
        (2.0 * binomial_cdf_half(n, k)).min(1.0)
    };
    SignTest { a_wins, b_wins, ties, p_value }
}

/// P(X <= k) for X ~ Binomial(n, 1/2). Exact summation for the sizes the
/// fleet produces; falls back to a continuity-corrected normal
/// approximation once `0.5^n` underflows f64.
fn binomial_cdf_half(n: usize, k: usize) -> f64 {
    if k >= n {
        return 1.0;
    }
    if n <= 1000 {
        // pmf(i) built iteratively: pmf(0) = 0.5^n, pmf(i+1) = pmf(i)·(n-i)/(i+1).
        let mut pmf = 0.5f64.powi(n as i32);
        let mut cdf = pmf;
        for i in 0..k {
            pmf *= (n - i) as f64 / (i + 1) as f64;
            cdf += pmf;
        }
        cdf.min(1.0)
    } else {
        // Normal approximation with continuity correction.
        let mean = n as f64 / 2.0;
        let sd = (n as f64).sqrt() / 2.0;
        normal_cdf((k as f64 + 0.5 - mean) / sd)
    }
}

/// Standard normal CDF via the Abramowitz–Stegun 7.1.26 erf polynomial
/// (|error| < 1.5e-7 — plenty for a significance report).
fn normal_cdf(z: f64) -> f64 {
    let x = z / std::f64::consts::SQRT_2;
    let (sign, x) = if x < 0.0 { (-1.0, -x) } else { (1.0, x) };
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let poly = t
        * (0.254_829_592
            + t * (-0.284_496_736 + t * (1.421_413_741 + t * (-1.453_152_027 + t * 1.061_405_429))));
    let erf = sign * (1.0 - poly * (-x * x).exp());
    0.5 * (1.0 + erf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    fn empty_is_zero() {
        let s = Summary::from(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn single_sample() {
        let s = Summary::from(&[7.5]);
        assert_eq!(s.p50, 7.5);
        assert_eq!(s.p99, 7.5);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn rank_ascending_is_competition_ranking() {
        assert_eq!(rank_ascending(&[3.0, 1.0, 2.0]), vec![3, 1, 2]);
        // Ties share the lowest rank; the next distinct value skips.
        assert_eq!(rank_ascending(&[2.0, 1.0, 1.0]), vec![3, 1, 1]);
        assert_eq!(rank_ascending(&[5.0, 5.0, 5.0]), vec![1, 1, 1]);
        assert_eq!(rank_ascending(&[1.0, 1.0, 2.0, 2.0, 3.0]), vec![1, 1, 3, 3, 5]);
        assert_eq!(rank_ascending(&[]), Vec::<usize>::new());
        // NaN sorts last instead of poisoning the ordering.
        let r = rank_ascending(&[f64::NAN, 1.0]);
        assert_eq!(r, vec![2, 1]);
    }

    #[test]
    fn percentile_monotone() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let s = Summary::from(&xs);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn mean_ci_matches_hand_computation() {
        // Sample [1, 2, 3, 4]: mean 2.5, s = sqrt(5/3), df = 3 → t = 3.182.
        let ci = mean_ci(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(ci.n, 4);
        assert!((ci.mean - 2.5).abs() < 1e-12);
        let expect = 3.182 * (5.0f64 / 3.0).sqrt() / 2.0;
        assert!((ci.half_width - expect).abs() < 1e-9, "{} vs {expect}", ci.half_width);
    }

    #[test]
    fn mean_ci_degenerate_single_sample() {
        let ci = mean_ci(&[7.25]);
        assert_eq!(ci, MeanCi { n: 1, mean: 7.25, half_width: 0.0 });
        let empty = mean_ci(&[]);
        assert_eq!(empty, MeanCi { n: 0, mean: 0.0, half_width: 0.0 });
    }

    #[test]
    fn mean_ci_all_equal_samples_have_zero_width() {
        let ci = mean_ci(&[3.5; 12]);
        assert_eq!(ci.mean, 3.5);
        assert_eq!(ci.half_width, 0.0);
        assert!(ci.half_width.is_finite());
    }

    #[test]
    fn mean_ci_shrinks_with_more_samples() {
        // Same alternating spread, growing n: the interval must tighten.
        let sample = |n: usize| -> Vec<f64> {
            (0..n).map(|i| if i % 2 == 0 { 1.0 } else { 3.0 }).collect()
        };
        let small = mean_ci(&sample(4));
        let big = mean_ci(&sample(64));
        assert!(big.half_width < small.half_width);
        assert!(big.half_width > 0.0);
    }

    #[test]
    fn rank_ascending_on_replicate_means_with_exact_ties() {
        // Replicate means that tie exactly (identical realizations can
        // produce identical delays): competition ranking shares rank 1.
        let means = [2.0, 2.0, 5.0];
        assert_eq!(rank_ascending(&means), vec![1, 1, 3]);
        let all_tied = [4.25, 4.25, 4.25, 4.25];
        assert_eq!(rank_ascending(&all_tied), vec![1, 1, 1, 1]);
    }

    #[test]
    fn paired_sign_test_exact_small_sample() {
        // a < b on every one of 5 pairs: p = 2 · 0.5^5 = 0.0625.
        let a = [1.0, 1.0, 1.0, 1.0, 1.0];
        let b = [2.0, 3.0, 4.0, 5.0, 6.0];
        let t = paired_sign_test(&a, &b);
        assert_eq!((t.a_wins, t.b_wins, t.ties), (5, 0, 0));
        assert!((t.p_value - 0.0625).abs() < 1e-12, "{}", t.p_value);
    }

    #[test]
    fn paired_sign_test_is_symmetric() {
        let a = [1.0, 5.0, 2.0, 9.0, 4.0, 4.0, 8.0];
        let b = [2.0, 3.0, 2.0, 1.0, 6.0, 7.0, 3.0];
        let ab = paired_sign_test(&a, &b);
        let ba = paired_sign_test(&b, &a);
        assert_eq!(ab.a_wins, ba.b_wins);
        assert_eq!(ab.b_wins, ba.a_wins);
        assert_eq!(ab.ties, ba.ties);
        assert!((ab.p_value - ba.p_value).abs() < 1e-15);
        assert!(ab.p_value <= 1.0 && ab.p_value > 0.0);
    }

    #[test]
    fn paired_sign_test_all_ties_is_insignificant() {
        let a = [2.0, 2.0, 2.0];
        let t = paired_sign_test(&a, &a);
        assert_eq!((t.a_wins, t.b_wins, t.ties), (0, 0, 3));
        assert_eq!(t.p_value, 1.0);
    }

    #[test]
    fn paired_sign_test_balanced_split_is_insignificant() {
        // 3 wins each way out of 6: p must be 1 (capped two-sided).
        let a = [1.0, 1.0, 1.0, 9.0, 9.0, 9.0];
        let b = [5.0, 5.0, 5.0, 5.0, 5.0, 5.0];
        let t = paired_sign_test(&a, &b);
        assert_eq!((t.a_wins, t.b_wins), (3, 3));
        assert_eq!(t.p_value, 1.0);
    }

    #[test]
    fn binomial_tail_large_n_uses_normal_tail_sanely() {
        // Far-out tail at large n: tiny p, never NaN/negative.
        let a = vec![1.0; 1500];
        let b = vec![2.0; 1500];
        let t = paired_sign_test(&a, &b);
        assert!(t.p_value >= 0.0 && t.p_value < 1e-6, "{}", t.p_value);
        // Balanced at large n: p ≈ 1.
        let mut c = vec![0.0; 1500];
        for (i, x) in c.iter_mut().enumerate() {
            *x = if i % 2 == 0 { 0.5 } else { 1.5 };
        }
        let u = paired_sign_test(&c, &vec![1.0; 1500]);
        assert!(u.p_value > 0.9, "{}", u.p_value);
    }
}
