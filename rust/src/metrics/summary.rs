//! Summary statistics over a series of measurements (bench reporting).

/// Order statistics + moments for a sample of f64 measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute from a sample (empty sample yields all-zero summary).
    pub fn from(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
                max: 0.0,
            };
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile(&sorted, 0.50),
            p90: percentile(&sorted, 0.90),
            p99: percentile(&sorted, 0.99),
            max: sorted[n - 1],
        }
    }

    /// One-line human rendering used by the bench harness.
    pub fn render(&self, unit: &str) -> String {
        format!(
            "n={} mean={:.4}{u} std={:.4}{u} min={:.4}{u} p50={:.4}{u} p90={:.4}{u} p99={:.4}{u} max={:.4}{u}",
            self.n, self.mean, self.std, self.min, self.p50, self.p90, self.p99, self.max,
            u = unit
        )
    }
}

/// Competition ranks (1-based, ascending: smallest value gets rank 1,
/// ties share the lowest rank and the next distinct value skips — "1224"
/// ranking); NaNs sort last. Used by the fleet runner to rank strategies
/// inside each scenario, so tying the winner still counts as a win.
pub fn rank_ascending(xs: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]));
    let mut ranks = vec![0usize; xs.len()];
    let mut rank = 1usize;
    for (pos, &i) in idx.iter().enumerate() {
        if pos > 0 && xs[i].total_cmp(&xs[idx[pos - 1]]).is_gt() {
            rank = pos + 1;
        }
        ranks[i] = rank;
    }
    ranks
}

/// Nearest-rank percentile over a pre-sorted sample.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    let idx = ((sorted.len() as f64) * q).ceil() as usize;
    sorted[idx.clamp(1, sorted.len()) - 1]
}

/// Sample mean with a two-sided 95% Student-t confidence interval,
/// the statistic behind the fleet's replicate columns.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MeanCi {
    pub n: usize,
    pub mean: f64,
    /// Half-width of the 95% CI (`mean ± half_width`). Degenerate
    /// samples (n <= 1, or all values equal) report `0.0` so the
    /// statistic stays finite and CSV-printable.
    pub half_width: f64,
}

/// Two-sided 95% Student-t critical value for `df` degrees of freedom.
fn t_critical_95(df: usize) -> f64 {
    const TABLE: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, 2.201, 2.179,
        2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, 2.080, 2.074, 2.069, 2.064,
        2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    match df {
        0 => f64::INFINITY,
        1..=30 => TABLE[df - 1],
        31..=40 => 2.021,
        41..=60 => 2.000,
        61..=120 => 1.980,
        _ => 1.960,
    }
}

/// Mean ± 95% CI (Student-t) of a sample. `n = 0` yields all zeros and
/// `n = 1` a degenerate zero-width interval — both deterministic, finite
/// values rather than NaNs, so downstream sorting/CSV stay well-formed.
pub fn mean_ci(samples: &[f64]) -> MeanCi {
    let n = samples.len();
    if n == 0 {
        return MeanCi { n: 0, mean: 0.0, half_width: 0.0 };
    }
    let mean = samples.iter().sum::<f64>() / n as f64;
    if n == 1 {
        return MeanCi { n, mean, half_width: 0.0 };
    }
    let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64;
    MeanCi { n, mean, half_width: t_critical_95(n - 1) * (var / n as f64).sqrt() }
}

/// Result of a two-sided exact paired sign test.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SignTest {
    /// Pairs where the first series was strictly smaller (better, for
    /// delays).
    pub a_wins: usize,
    /// Pairs where the second series was strictly smaller.
    pub b_wins: usize,
    /// Exactly-equal pairs (dropped from the test, the usual treatment).
    pub ties: usize,
    /// Two-sided p-value of H0 "neither series is systematically
    /// smaller" (exact binomial, `2·min(tails)` capped at 1; `1.0` when
    /// every pair ties).
    pub p_value: f64,
}

/// Two-sided exact paired sign test over two equal-length series — the
/// fleet's significance test between two strategies' per-(scenario,
/// replicate) delays. Distribution-free, so it is safe on the wildly
/// non-normal delay scales the scenario catalog mixes. Symmetric:
/// swapping the series swaps `a_wins`/`b_wins` and keeps `p_value`.
pub fn paired_sign_test(a: &[f64], b: &[f64]) -> SignTest {
    assert_eq!(a.len(), b.len(), "paired sign test needs equal-length series");
    let (mut a_wins, mut b_wins, mut ties) = (0usize, 0usize, 0usize);
    for (&x, &y) in a.iter().zip(b) {
        match x.total_cmp(&y) {
            std::cmp::Ordering::Less => a_wins += 1,
            std::cmp::Ordering::Greater => b_wins += 1,
            std::cmp::Ordering::Equal => ties += 1,
        }
    }
    let n = a_wins + b_wins;
    let p_value = if n == 0 {
        1.0
    } else {
        let k = a_wins.min(b_wins);
        (2.0 * binomial_cdf_half(n, k)).min(1.0)
    };
    SignTest { a_wins, b_wins, ties, p_value }
}

/// Holm–Bonferroni step-down adjustment of a family of p-values — the
/// multiple-comparisons correction for the fleet report, where the
/// best-ranked strategy is tested against *every* rival at once (m − 1
/// simultaneous hypotheses would otherwise inflate the family-wise
/// error rate).
///
/// Returns the adjusted p-values in the input order:
/// `p'_(i) = max_{j ≤ i} min(1, (m − j + 1) · p_(j))` over the
/// ascending order statistics — uniformly more powerful than plain
/// Bonferroni while still controlling the family-wise error rate, with
/// no independence assumption. NaNs are treated as 1.0 (an unusable
/// p-value can never gain significance from adjustment).
pub fn holm_bonferroni(ps: &[f64]) -> Vec<f64> {
    let m = ps.len();
    let mut order: Vec<usize> = (0..m).collect();
    order.sort_by(|&a, &b| ps[a].total_cmp(&ps[b]));
    let mut adjusted = vec![0.0f64; m];
    let mut running_max = 0.0f64;
    for (j, &i) in order.iter().enumerate() {
        let p = if ps[i].is_nan() { 1.0 } else { ps[i] };
        running_max = running_max.max(((m - j) as f64 * p).min(1.0));
        adjusted[i] = running_max;
    }
    adjusted
}

/// Result of a two-sided Wilcoxon signed-rank test with the
/// matched-pairs rank-biserial correlation as effect size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Wilcoxon {
    /// Pairs used by the test (zero and non-finite differences are
    /// dropped, the standard Wilcoxon treatment).
    pub n: usize,
    /// Sum of the |difference| ranks where the *first* series was
    /// strictly smaller (better, for delays).
    pub w_plus: f64,
    /// Sum of the ranks where the second series was strictly smaller.
    pub w_minus: f64,
    /// Two-sided p-value of H0 "the differences are symmetric about 0".
    pub p_value: f64,
    /// Matched-pairs rank-biserial correlation
    /// `(w_plus − w_minus) / (n(n+1)/2)` ∈ [−1, 1]: +1 = the first
    /// series smaller on every pair, 0 = no systematic direction.
    pub rank_biserial: f64,
    /// Whether the exact null distribution was used (n ≤ 25, no ties
    /// among |differences|); otherwise the tie-corrected,
    /// continuity-corrected normal approximation.
    pub exact: bool,
}

/// Two-sided Wilcoxon signed-rank test over two equal-length paired
/// series. Unlike the sign test it weights pairs by the *magnitude*
/// rank of their difference, so it detects consistent-but-small shifts
/// the sign test dilutes — at the price of assuming the difference
/// distribution is symmetric under H0. Zero differences are dropped;
/// ties among |differences| share average ranks. Exact null
/// distribution (subset-sum DP over ranks) for n ≤ 25 without ties;
/// beyond that, the normal approximation with the standard tie
/// correction `Σ(t³−t)/48` and a 0.5 continuity correction.
pub fn wilcoxon_signed_rank(a: &[f64], b: &[f64]) -> Wilcoxon {
    assert_eq!(a.len(), b.len(), "wilcoxon signed-rank needs equal-length series");
    // d > 0 ⇔ the first series is smaller — the same orientation as
    // `paired_sign_test::a_wins`.
    let diffs: Vec<f64> = a
        .iter()
        .zip(b)
        .map(|(&x, &y)| y - x)
        .filter(|d| d.is_finite() && *d != 0.0)
        .collect();
    let n = diffs.len();
    if n == 0 {
        return Wilcoxon {
            n: 0,
            w_plus: 0.0,
            w_minus: 0.0,
            p_value: 1.0,
            rank_biserial: 0.0,
            exact: true,
        };
    }
    // Average ranks of |d| (ascending); record tie-group sizes for the
    // normal path's variance correction.
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| diffs[i].abs().total_cmp(&diffs[j].abs()));
    let mut ranks = vec![0.0f64; n];
    let mut tie_groups: Vec<usize> = Vec::new();
    let mut i = 0;
    while i < n {
        let mut j = i + 1;
        while j < n && diffs[idx[j]].abs() == diffs[idx[i]].abs() {
            j += 1;
        }
        tie_groups.push(j - i);
        let avg = (i + 1 + j) as f64 / 2.0;
        for k in i..j {
            ranks[idx[k]] = avg;
        }
        i = j;
    }
    let has_ties = tie_groups.iter().any(|&t| t > 1);
    let w_plus: f64 = (0..n).filter(|&k| diffs[k] > 0.0).map(|k| ranks[k]).sum();
    let total = (n * (n + 1)) as f64 / 2.0;
    let w_minus = total - w_plus;
    let w_min = w_plus.min(w_minus);
    let (p_value, exact) = if n <= 25 && !has_ties {
        // Without ties every rank is an integer, so w_min is too.
        (wilcoxon_exact_two_sided(n, w_min.round() as usize), true)
    } else {
        let mu = total / 2.0;
        let tie_term: f64 =
            tie_groups.iter().map(|&t| (t * t * t - t) as f64).sum::<f64>() / 48.0;
        let var = (n * (n + 1) * (2 * n + 1)) as f64 / 24.0 - tie_term;
        if var <= 0.0 {
            (1.0, false)
        } else {
            let z = (w_min + 0.5 - mu) / var.sqrt();
            ((2.0 * normal_cdf(z)).min(1.0), false)
        }
    };
    Wilcoxon {
        n,
        w_plus,
        w_minus,
        p_value,
        rank_biserial: (w_plus - w_minus) / total,
        exact,
    }
}

/// Matched-pairs rank-biserial correlation of two paired series — the
/// effect size companion to [`wilcoxon_signed_rank`] (positive = the
/// first series is systematically smaller).
pub fn rank_biserial(a: &[f64], b: &[f64]) -> f64 {
    wilcoxon_signed_rank(a, b).rank_biserial
}

/// Exact two-sided p-value for the signed-rank statistic: P(W ≤ w)
/// doubled, where W's null distribution is the subset-sum count over
/// ranks 1..=n (each pair signs + or − with probability ½). Counts stay
/// below 2^25 for the exact range, so f64 accumulation is lossless.
fn wilcoxon_exact_two_sided(n: usize, w: usize) -> f64 {
    let total = n * (n + 1) / 2;
    let mut counts = vec![0.0f64; total + 1];
    counts[0] = 1.0;
    for r in 1..=n {
        for s in (r..=total).rev() {
            counts[s] += counts[s - r];
        }
    }
    let cdf: f64 = counts[..=w.min(total)].iter().sum::<f64>() * 0.5f64.powi(n as i32);
    (2.0 * cdf).min(1.0)
}

/// P(X <= k) for X ~ Binomial(n, 1/2). Exact summation for the sizes the
/// fleet produces; falls back to a continuity-corrected normal
/// approximation once `0.5^n` underflows f64.
fn binomial_cdf_half(n: usize, k: usize) -> f64 {
    if k >= n {
        return 1.0;
    }
    if n <= 1000 {
        // pmf(i) built iteratively: pmf(0) = 0.5^n, pmf(i+1) = pmf(i)·(n-i)/(i+1).
        let mut pmf = 0.5f64.powi(n as i32);
        let mut cdf = pmf;
        for i in 0..k {
            pmf *= (n - i) as f64 / (i + 1) as f64;
            cdf += pmf;
        }
        cdf.min(1.0)
    } else {
        // Normal approximation with continuity correction.
        let mean = n as f64 / 2.0;
        let sd = (n as f64).sqrt() / 2.0;
        normal_cdf((k as f64 + 0.5 - mean) / sd)
    }
}

/// Standard normal CDF via the Abramowitz–Stegun 7.1.26 erf polynomial
/// (|error| < 1.5e-7 — plenty for a significance report).
fn normal_cdf(z: f64) -> f64 {
    let x = z / std::f64::consts::SQRT_2;
    let (sign, x) = if x < 0.0 { (-1.0, -x) } else { (1.0, x) };
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let poly = t
        * (0.254_829_592
            + t * (-0.284_496_736 + t * (1.421_413_741 + t * (-1.453_152_027 + t * 1.061_405_429))));
    let erf = sign * (1.0 - poly * (-x * x).exp());
    0.5 * (1.0 + erf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    fn empty_is_zero() {
        let s = Summary::from(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn single_sample() {
        let s = Summary::from(&[7.5]);
        assert_eq!(s.p50, 7.5);
        assert_eq!(s.p99, 7.5);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn rank_ascending_is_competition_ranking() {
        assert_eq!(rank_ascending(&[3.0, 1.0, 2.0]), vec![3, 1, 2]);
        // Ties share the lowest rank; the next distinct value skips.
        assert_eq!(rank_ascending(&[2.0, 1.0, 1.0]), vec![3, 1, 1]);
        assert_eq!(rank_ascending(&[5.0, 5.0, 5.0]), vec![1, 1, 1]);
        assert_eq!(rank_ascending(&[1.0, 1.0, 2.0, 2.0, 3.0]), vec![1, 1, 3, 3, 5]);
        assert_eq!(rank_ascending(&[]), Vec::<usize>::new());
        // NaN sorts last instead of poisoning the ordering.
        let r = rank_ascending(&[f64::NAN, 1.0]);
        assert_eq!(r, vec![2, 1]);
    }

    #[test]
    fn percentile_monotone() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let s = Summary::from(&xs);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn mean_ci_matches_hand_computation() {
        // Sample [1, 2, 3, 4]: mean 2.5, s = sqrt(5/3), df = 3 → t = 3.182.
        let ci = mean_ci(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(ci.n, 4);
        assert!((ci.mean - 2.5).abs() < 1e-12);
        let expect = 3.182 * (5.0f64 / 3.0).sqrt() / 2.0;
        assert!((ci.half_width - expect).abs() < 1e-9, "{} vs {expect}", ci.half_width);
    }

    #[test]
    fn mean_ci_degenerate_single_sample() {
        let ci = mean_ci(&[7.25]);
        assert_eq!(ci, MeanCi { n: 1, mean: 7.25, half_width: 0.0 });
        let empty = mean_ci(&[]);
        assert_eq!(empty, MeanCi { n: 0, mean: 0.0, half_width: 0.0 });
    }

    #[test]
    fn mean_ci_all_equal_samples_have_zero_width() {
        let ci = mean_ci(&[3.5; 12]);
        assert_eq!(ci.mean, 3.5);
        assert_eq!(ci.half_width, 0.0);
        assert!(ci.half_width.is_finite());
    }

    #[test]
    fn mean_ci_shrinks_with_more_samples() {
        // Same alternating spread, growing n: the interval must tighten.
        let sample = |n: usize| -> Vec<f64> {
            (0..n).map(|i| if i % 2 == 0 { 1.0 } else { 3.0 }).collect()
        };
        let small = mean_ci(&sample(4));
        let big = mean_ci(&sample(64));
        assert!(big.half_width < small.half_width);
        assert!(big.half_width > 0.0);
    }

    #[test]
    fn rank_ascending_on_replicate_means_with_exact_ties() {
        // Replicate means that tie exactly (identical realizations can
        // produce identical delays): competition ranking shares rank 1.
        let means = [2.0, 2.0, 5.0];
        assert_eq!(rank_ascending(&means), vec![1, 1, 3]);
        let all_tied = [4.25, 4.25, 4.25, 4.25];
        assert_eq!(rank_ascending(&all_tied), vec![1, 1, 1, 1]);
    }

    #[test]
    fn paired_sign_test_exact_small_sample() {
        // a < b on every one of 5 pairs: p = 2 · 0.5^5 = 0.0625.
        let a = [1.0, 1.0, 1.0, 1.0, 1.0];
        let b = [2.0, 3.0, 4.0, 5.0, 6.0];
        let t = paired_sign_test(&a, &b);
        assert_eq!((t.a_wins, t.b_wins, t.ties), (5, 0, 0));
        assert!((t.p_value - 0.0625).abs() < 1e-12, "{}", t.p_value);
    }

    #[test]
    fn paired_sign_test_is_symmetric() {
        let a = [1.0, 5.0, 2.0, 9.0, 4.0, 4.0, 8.0];
        let b = [2.0, 3.0, 2.0, 1.0, 6.0, 7.0, 3.0];
        let ab = paired_sign_test(&a, &b);
        let ba = paired_sign_test(&b, &a);
        assert_eq!(ab.a_wins, ba.b_wins);
        assert_eq!(ab.b_wins, ba.a_wins);
        assert_eq!(ab.ties, ba.ties);
        assert!((ab.p_value - ba.p_value).abs() < 1e-15);
        assert!(ab.p_value <= 1.0 && ab.p_value > 0.0);
    }

    #[test]
    fn paired_sign_test_all_ties_is_insignificant() {
        let a = [2.0, 2.0, 2.0];
        let t = paired_sign_test(&a, &a);
        assert_eq!((t.a_wins, t.b_wins, t.ties), (0, 0, 3));
        assert_eq!(t.p_value, 1.0);
    }

    #[test]
    fn paired_sign_test_balanced_split_is_insignificant() {
        // 3 wins each way out of 6: p must be 1 (capped two-sided).
        let a = [1.0, 1.0, 1.0, 9.0, 9.0, 9.0];
        let b = [5.0, 5.0, 5.0, 5.0, 5.0, 5.0];
        let t = paired_sign_test(&a, &b);
        assert_eq!((t.a_wins, t.b_wins), (3, 3));
        assert_eq!(t.p_value, 1.0);
    }

    #[test]
    fn wilcoxon_all_one_direction_matches_the_exact_table() {
        // n = 5, every difference positive: W− = 0, two-sided
        // p = 2 · (1/2)^5 = 0.0625 — the textbook smallest-p row.
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [2.0, 4.0, 6.0, 8.0, 10.0];
        let t = wilcoxon_signed_rank(&a, &b);
        assert_eq!(t.n, 5);
        assert!(t.exact);
        assert_eq!((t.w_plus, t.w_minus), (15.0, 0.0));
        assert!((t.p_value - 0.0625).abs() < 1e-12, "{}", t.p_value);
        assert_eq!(t.rank_biserial, 1.0);
        assert_eq!(rank_biserial(&a, &b), 1.0);
    }

    #[test]
    fn wilcoxon_matches_the_n10_critical_value_table() {
        // Standard table: at n = 10 the two-sided α = 0.05 critical
        // value is W = 8 — exactly P = 0.048828125; W = 9 is already
        // 0.064453125 (> 0.05). Distinct magnitudes 1..10; negatives at
        // magnitude ranks {1, 3, 4} give W = 8, ranks {1, 3, 5} give 9.
        let zeros = [0.0; 10];
        let d8 = [-1.0, 2.0, -3.0, -4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0];
        let t = wilcoxon_signed_rank(&zeros, &d8);
        assert!(t.exact);
        assert_eq!(t.w_minus, 8.0);
        assert!((t.p_value - 0.048828125).abs() < 1e-12, "{}", t.p_value);
        let d9 = [-1.0, 2.0, -3.0, 4.0, -5.0, 6.0, 7.0, 8.0, 9.0, 10.0];
        let t = wilcoxon_signed_rank(&zeros, &d9);
        assert_eq!(t.w_minus, 9.0);
        assert!((t.p_value - 0.064453125).abs() < 1e-12, "{}", t.p_value);
        // n = 6, W = 1: p = 2 · (2/64) = 0.0625.
        let d = [10.0, -1.0, 20.0, 30.0, 40.0, 50.0];
        let t = wilcoxon_signed_rank(&[0.0; 6], &d);
        assert_eq!(t.w_minus, 1.0);
        assert!((t.p_value - 0.0625).abs() < 1e-12, "{}", t.p_value);
    }

    #[test]
    fn wilcoxon_handles_ties_and_zeros_via_the_corrected_normal_path() {
        // The classic worked example (9 non-zero pairs, tied |d|
        // magnitudes): average ranks give W+ = 18, W− = 27; the
        // tie-corrected normal approximation lands near p ≈ 0.635
        // (cross-checked against an independent Python computation).
        let a = [125.0, 115.0, 130.0, 140.0, 140.0, 115.0, 140.0, 125.0, 140.0, 135.0];
        let b = [110.0, 122.0, 125.0, 120.0, 140.0, 124.0, 123.0, 137.0, 135.0, 145.0];
        let t = wilcoxon_signed_rank(&a, &b);
        assert_eq!(t.n, 9, "the zero pair is dropped");
        assert!(!t.exact, "tied magnitudes must use the normal path");
        assert!((t.w_plus - 18.0).abs() < 1e-12, "{}", t.w_plus);
        assert!((t.w_minus - 27.0).abs() < 1e-12, "{}", t.w_minus);
        assert!((t.p_value - 0.6352893188).abs() < 1e-6, "{}", t.p_value);
        assert!((t.rank_biserial + 0.2).abs() < 1e-12, "{}", t.rank_biserial);
    }

    #[test]
    fn wilcoxon_is_symmetric_and_degenerates_sanely() {
        let a = [1.0, 5.0, 2.0, 9.0, 4.0, 4.5, 8.0];
        let b = [2.0, 3.0, 2.5, 1.0, 6.0, 7.0, 3.0];
        let ab = wilcoxon_signed_rank(&a, &b);
        let ba = wilcoxon_signed_rank(&b, &a);
        assert_eq!(ab.w_plus, ba.w_minus);
        assert_eq!(ab.w_minus, ba.w_plus);
        assert!((ab.p_value - ba.p_value).abs() < 1e-12);
        assert!((ab.rank_biserial + ba.rank_biserial).abs() < 1e-12);
        // All-equal series: every pair drops, p = 1, zero effect.
        let t = wilcoxon_signed_rank(&[3.0; 4], &[3.0; 4]);
        assert_eq!((t.n, t.p_value, t.rank_biserial), (0, 1.0, 0.0));
        // Non-finite differences are dropped, not propagated.
        let t = wilcoxon_signed_rank(&[1.0, f64::NAN, 2.0], &[3.0, 1.0, 5.0]);
        assert_eq!(t.n, 2);
        assert!(t.p_value.is_finite());
    }

    #[test]
    fn wilcoxon_large_n_normal_path_is_sane() {
        // 40 distinct-magnitude positive differences: far beyond the
        // exact range, strongly one-sided — tiny p, full effect.
        let a: Vec<f64> = (0..40).map(|i| i as f64).collect();
        let b: Vec<f64> = (0..40).map(|i| i as f64 + 1.0 + i as f64 * 0.01).collect();
        let t = wilcoxon_signed_rank(&a, &b);
        assert!(!t.exact);
        assert_eq!(t.n, 40);
        assert!(t.p_value < 1e-6, "{}", t.p_value);
        assert_eq!(t.rank_biserial, 1.0);
        // Alternating direction with matched magnitudes: p ≈ 1.
        let sign = |i: usize| if i % 2 == 0 { 1.0 } else { -1.0 };
        let c: Vec<f64> = (0..40).map(|i| sign(i) * (i + 1) as f64).collect();
        let zeros = vec![0.0; 40];
        let t = wilcoxon_signed_rank(&zeros, &c);
        assert!(t.p_value > 0.5, "{}", t.p_value);
        assert!(t.rank_biserial.abs() < 0.2, "{}", t.rank_biserial);
    }

    #[test]
    fn holm_bonferroni_matches_the_textbook_vector() {
        // Known worked example: raw p = [0.01, 0.04, 0.03, 0.005], m=4.
        // Sorted: 0.005·4=0.02, 0.01·3=0.03, 0.03·2=0.06, 0.04·1=0.04
        // → monotone max → [0.02, 0.03, 0.06, 0.06], mapped back.
        let adj = holm_bonferroni(&[0.01, 0.04, 0.03, 0.005]);
        let expect = [0.03, 0.06, 0.06, 0.02];
        for (a, e) in adj.iter().zip(expect) {
            assert!((a - e).abs() < 1e-12, "{adj:?}");
        }
        // Single comparison: no adjustment.
        assert_eq!(holm_bonferroni(&[0.04]), vec![0.04]);
        // Empty family: empty result.
        assert!(holm_bonferroni(&[]).is_empty());
    }

    #[test]
    fn holm_bonferroni_is_monotone_capped_and_nan_safe() {
        let adj = holm_bonferroni(&[0.9, 0.5, 0.2, f64::NAN]);
        assert!(adj.iter().all(|p| (0.0..=1.0).contains(p)), "{adj:?}");
        // Adjusted values never fall below the raw ones.
        for (raw, a) in [0.9, 0.5, 0.2].iter().zip(&adj) {
            assert!(a >= raw, "{adj:?}");
        }
        // NaN is treated as 1.0 (never significant).
        assert_eq!(adj[3], 1.0);
        // The smallest raw p gets the full Bonferroni factor.
        assert!((adj[2] - 0.8).abs() < 1e-12, "{adj:?}");
    }

    #[test]
    fn binomial_tail_large_n_uses_normal_tail_sanely() {
        // Far-out tail at large n: tiny p, never NaN/negative.
        let a = vec![1.0; 1500];
        let b = vec![2.0; 1500];
        let t = paired_sign_test(&a, &b);
        assert!(t.p_value >= 0.0 && t.p_value < 1e-6, "{}", t.p_value);
        // Balanced at large n: p ≈ 1.
        let mut c = vec![0.0; 1500];
        for (i, x) in c.iter_mut().enumerate() {
            *x = if i % 2 == 0 { 0.5 } else { 1.5 };
        }
        let u = paired_sign_test(&c, &vec![1.0; 1500]);
        assert!(u.p_value > 0.9, "{}", u.p_value);
    }
}
