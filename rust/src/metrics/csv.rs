//! CSV emission for experiment results (`results/*.csv`), with proper
//! quoting so plots/spreadsheets ingest them directly.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Streaming CSV writer.
pub struct CsvWriter<W: Write> {
    out: W,
    columns: usize,
}

impl CsvWriter<BufWriter<File>> {
    /// Create `path` (parents included) and write the header row. The
    /// header takes any string-ish slice (`&[&str]`, `&[String]`, ...),
    /// so callers with computed column names pass them directly instead
    /// of hand-rolling a `Vec<&str>` view first.
    pub fn create<S: AsRef<str>>(path: &Path, header: &[S]) -> std::io::Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let file = BufWriter::new(File::create(path)?);
        let mut w = CsvWriter {
            out: file,
            columns: header.len(),
        };
        w.write_row(header)?;
        Ok(w)
    }
}

impl<W: Write> CsvWriter<W> {
    /// Wrap any writer (tests use `Vec<u8>`).
    pub fn new<S: AsRef<str>>(out: W, header: &[S]) -> std::io::Result<Self> {
        let mut w = CsvWriter {
            out,
            columns: header.len(),
        };
        w.write_row(header)?;
        Ok(w)
    }

    /// Write one row of string fields (must match the header width).
    pub fn write_row<S: AsRef<str>>(&mut self, fields: &[S]) -> std::io::Result<()> {
        assert_eq!(
            fields.len(),
            self.columns,
            "csv row width {} != header width {}",
            fields.len(),
            self.columns
        );
        let mut line = String::new();
        for (i, f) in fields.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            line.push_str(&quote(f.as_ref()));
        }
        line.push('\n');
        self.out.write_all(line.as_bytes())
    }

    /// Convenience: row of f64s formatted with 6 significant decimals.
    pub fn write_f64_row(&mut self, fields: &[f64]) -> std::io::Result<()> {
        let strs: Vec<String> = fields.iter().map(|x| format!("{x:.6}")).collect();
        self.write_row(&strs)
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

fn quote(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let mut buf = Vec::new();
        {
            let mut w = CsvWriter::new(&mut buf, &["a", "b"]).unwrap();
            w.write_row(&["1", "x,y"]).unwrap();
            w.write_f64_row(&[1.5, 2.0]).unwrap();
            w.flush().unwrap();
        }
        let text = String::from_utf8(buf).unwrap();
        assert_eq!(text, "a,b\n1,\"x,y\"\n1.500000,2.000000\n");
    }

    #[test]
    #[should_panic(expected = "csv row width")]
    fn width_mismatch_panics() {
        let mut buf = Vec::new();
        let mut w = CsvWriter::new(&mut buf, &["a", "b"]).unwrap();
        let _ = w.write_row(&["only-one"]);
    }

    #[test]
    fn quotes_embedded_quotes() {
        assert_eq!(quote("say \"hi\""), "\"say \"\"hi\"\"\"");
        assert_eq!(quote("plain"), "plain");
    }

    #[test]
    fn owned_string_headers_need_no_ref_view() {
        // The idiom the sim/fleet writers used to hand-roll:
        // Vec<String> header → Vec<&str> → CsvWriter. Now direct.
        let header: Vec<String> = (0..3).map(|i| format!("c{i}")).collect();
        let mut buf = Vec::new();
        {
            let mut w = CsvWriter::new(&mut buf, &header).unwrap();
            w.write_f64_row(&[1.0, 2.0, 3.0]).unwrap();
            w.flush().unwrap();
        }
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("c0,c1,c2\n"));
    }
}
