//! Round/record metrics: timers, per-round recorders, CSV emission and
//! summary statistics. This is where the paper's black-box signal comes
//! from — the coordinator measures each FL round's wall-clock Total
//! Processing Delay here and feeds `-TPD` to PSO as fitness.

mod csv;
mod recorder;
mod summary;
mod timer;

pub use csv::CsvWriter;
pub use recorder::{RoundRecord, RoundRecorder};
pub use summary::{
    holm_bonferroni, mean_ci, paired_sign_test, rank_ascending, rank_biserial,
    wilcoxon_signed_rank, MeanCi, SignTest, Summary, Wilcoxon,
};
pub use timer::Stopwatch;
