//! Model checkpointing: persist/restore the flat parameter vector, so a
//! deployment can resume training or serve a converged model.
//!
//! Format (little-endian):
//! ```text
//! magic "RPCKPT1\n" | u32 header_len | header JSON | f32 params...
//! ```
//! The JSON header carries the parameter count plus free-form metadata
//! (round, session, loss) for tooling.

use crate::json::{self, Value};
use anyhow::{anyhow, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"RPCKPT1\n";

/// Checkpoint metadata (stored in the JSON header).
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointMeta {
    pub param_count: usize,
    /// FL round the model was captured at.
    pub round: usize,
    /// Session label.
    pub session: String,
    /// Eval loss at capture time (NaN if unknown).
    pub loss: f64,
}

/// Write a checkpoint atomically (tmp + rename).
pub fn save(path: &Path, params: &[f32], meta: &CheckpointMeta) -> Result<()> {
    if meta.param_count != params.len() {
        return Err(anyhow!(
            "checkpoint meta param_count {} != params len {}",
            meta.param_count,
            params.len()
        ));
    }
    let header = json::to_string(&Value::object(vec![
        ("param_count", Value::from(meta.param_count)),
        ("round", Value::from(meta.round)),
        ("session", Value::from(meta.session.as_str())),
        ("loss", Value::Num(meta.loss)),
    ]));
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension("ckpt.tmp");
    {
        let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u32).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        // SAFETY: f32 → bytes view, host-native layout.
        let bytes = unsafe {
            std::slice::from_raw_parts(params.as_ptr().cast::<u8>(), std::mem::size_of_val(params))
        };
        f.write_all(bytes)?;
        f.flush()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Load a checkpoint; validates magic, header and payload length.
pub fn load(path: &Path) -> Result<(Vec<f32>, CheckpointMeta)> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening checkpoint {path:?}"))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(anyhow!("{path:?}: not a repro checkpoint (bad magic)"));
    }
    let mut len4 = [0u8; 4];
    f.read_exact(&mut len4)?;
    let hlen = u32::from_le_bytes(len4) as usize;
    if hlen > 1 << 20 {
        return Err(anyhow!("{path:?}: implausible header length {hlen}"));
    }
    let mut header = vec![0u8; hlen];
    f.read_exact(&mut header)?;
    let v = json::parse(std::str::from_utf8(&header)?).map_err(|e| anyhow!("{e}"))?;
    let meta = CheckpointMeta {
        param_count: v
            .get("param_count")
            .and_then(Value::as_usize)
            .ok_or_else(|| anyhow!("checkpoint header missing param_count"))?,
        round: v.get("round").and_then(Value::as_usize).unwrap_or(0),
        session: v
            .get("session")
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string(),
        loss: v.get("loss").and_then(Value::as_f64).unwrap_or(f64::NAN),
    };
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    if bytes.len() != meta.param_count * 4 {
        return Err(anyhow!(
            "{path:?}: payload {} bytes, expected {}",
            bytes.len(),
            meta.param_count * 4
        ));
    }
    let mut params = Vec::with_capacity(meta.param_count);
    for chunk in bytes.chunks_exact(4) {
        params.push(f32::from_le_bytes(chunk.try_into().unwrap()));
    }
    Ok((params, meta))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("repro_ckpt_{name}"))
    }

    fn meta(n: usize) -> CheckpointMeta {
        CheckpointMeta {
            param_count: n,
            round: 17,
            session: "test".into(),
            loss: 0.25,
        }
    }

    #[test]
    fn roundtrip_exact() {
        let params: Vec<f32> = (0..5000).map(|i| (i as f32) * 0.37 - 9.0).collect();
        let path = tmp("roundtrip");
        save(&path, &params, &meta(5000)).unwrap();
        let (back, m) = load(&path).unwrap();
        assert_eq!(back, params, "payload must be bit-exact");
        assert_eq!(m, meta(5000));
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmp("badmagic");
        std::fs::write(&path, b"NOTACKPT........").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn rejects_truncated_payload() {
        let params: Vec<f32> = vec![1.0; 100];
        let path = tmp("trunc");
        save(&path, &params, &meta(100)).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 8]).unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn rejects_meta_mismatch() {
        let params: Vec<f32> = vec![0.0; 10];
        assert!(save(&tmp("mismatch"), &params, &meta(11)).is_err());
    }

    #[test]
    fn special_floats_preserved() {
        let params = vec![f32::MIN, f32::MAX, 0.0, -0.0, 1e-38, -1e38];
        let path = tmp("special");
        save(&path, &params, &meta(6)).unwrap();
        let (back, _) = load(&path).unwrap();
        assert_eq!(back.len(), 6);
        for (a, b) in params.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
