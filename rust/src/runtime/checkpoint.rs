//! Model checkpointing: persist/restore the flat parameter vector plus
//! the placement optimizer's transferable state, so a resumed session
//! restores both its model *and* its search progress.
//!
//! Format (little-endian):
//! ```text
//! magic "RPCKPT1\n" | u32 header_len | header JSON | f32 params...
//! ```
//! The JSON header carries the parameter count plus free-form metadata
//! (round, session, loss, optimizer snapshot) for tooling. Headers
//! written before the optimizer extension simply lack the `optimizer`
//! key and load as `optimizer: None`.

use crate::json::{self, Value};
use crate::placement::{OptimizerState, Placement};
use anyhow::{anyhow, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"RPCKPT1\n";

/// Checkpoint metadata (stored in the JSON header).
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointMeta {
    pub param_count: usize,
    /// FL round the model was captured at.
    pub round: usize,
    /// Session label.
    pub session: String,
    /// Eval loss at capture time (NaN if unknown).
    pub loss: f64,
    /// Placement-optimizer snapshot (strategy name + best observation),
    /// restored into the same strategy via `Optimizer::restore`. `None`
    /// for model-only checkpoints and pre-extension files.
    pub optimizer: Option<OptimizerState>,
}

/// Write a checkpoint atomically (tmp + rename).
pub fn save(path: &Path, params: &[f32], meta: &CheckpointMeta) -> Result<()> {
    if meta.param_count != params.len() {
        return Err(anyhow!(
            "checkpoint meta param_count {} != params len {}",
            meta.param_count,
            params.len()
        ));
    }
    let mut fields = vec![
        ("param_count", Value::from(meta.param_count)),
        ("round", Value::from(meta.round)),
        ("session", Value::from(meta.session.as_str())),
        ("loss", Value::Num(meta.loss)),
    ];
    if let Some(opt) = &meta.optimizer {
        let mut o = vec![("strategy", Value::from(opt.name.as_str()))];
        if let Some((p, d)) = &opt.best {
            o.push((
                "best_placement",
                Value::Array(p.iter().map(|&c| Value::from(c)).collect()),
            ));
            o.push(("best_delay", Value::Num(*d)));
        }
        fields.push(("optimizer", Value::object(o)));
    }
    let header = json::to_string(&Value::object(fields));
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension("ckpt.tmp");
    {
        let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u32).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        // SAFETY: f32 → bytes view, host-native layout.
        let bytes = unsafe {
            std::slice::from_raw_parts(params.as_ptr().cast::<u8>(), std::mem::size_of_val(params))
        };
        f.write_all(bytes)?;
        f.flush()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Load a checkpoint; validates magic, header and payload length.
pub fn load(path: &Path) -> Result<(Vec<f32>, CheckpointMeta)> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening checkpoint {path:?}"))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(anyhow!("{path:?}: not a repro checkpoint (bad magic)"));
    }
    let mut len4 = [0u8; 4];
    f.read_exact(&mut len4)?;
    let hlen = u32::from_le_bytes(len4) as usize;
    if hlen > 1 << 20 {
        return Err(anyhow!("{path:?}: implausible header length {hlen}"));
    }
    let mut header = vec![0u8; hlen];
    f.read_exact(&mut header)?;
    let v = json::parse(std::str::from_utf8(&header)?).map_err(|e| anyhow!("{e}"))?;
    let meta = CheckpointMeta {
        param_count: v
            .get("param_count")
            .and_then(Value::as_usize)
            .ok_or_else(|| anyhow!("checkpoint header missing param_count"))?,
        round: v.get("round").and_then(Value::as_usize).unwrap_or(0),
        session: v
            .get("session")
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string(),
        loss: v.get("loss").and_then(Value::as_f64).unwrap_or(f64::NAN),
        optimizer: parse_optimizer(v.get("optimizer"))
            .map_err(|e| anyhow!("{path:?}: {e}"))?,
    };
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    if bytes.len() != meta.param_count * 4 {
        return Err(anyhow!(
            "{path:?}: payload {} bytes, expected {}",
            bytes.len(),
            meta.param_count * 4
        ));
    }
    let mut params = Vec::with_capacity(meta.param_count);
    for chunk in bytes.chunks_exact(4) {
        params.push(f32::from_le_bytes(chunk.try_into().unwrap()));
    }
    Ok((params, meta))
}

/// Decode the optional optimizer snapshot from the header: missing key
/// ⇒ `None` (pre-extension checkpoints); present but malformed ⇒ error.
fn parse_optimizer(v: Option<&Value>) -> Result<Option<OptimizerState>, String> {
    let Some(v) = v else { return Ok(None) };
    let name = v
        .get("strategy")
        .and_then(Value::as_str)
        .ok_or("optimizer snapshot missing strategy name")?
        .to_string();
    let best = match v.get("best_placement") {
        None => None,
        Some(arr) => {
            let ids = arr
                .as_array()
                .ok_or("optimizer best_placement is not an array")?
                .iter()
                .map(|x| x.as_usize().ok_or("optimizer best_placement holds a non-integer"))
                .collect::<Result<Vec<usize>, _>>()?;
            let delay = v
                .get("best_delay")
                .and_then(Value::as_f64)
                .ok_or("optimizer best_placement without best_delay")?;
            Some((Placement::new(ids), delay))
        }
    };
    Ok(Some(OptimizerState { name, best }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("repro_ckpt_{name}"))
    }

    fn meta(n: usize) -> CheckpointMeta {
        CheckpointMeta {
            param_count: n,
            round: 17,
            session: "test".into(),
            loss: 0.25,
            optimizer: None,
        }
    }

    #[test]
    fn roundtrip_exact() {
        let params: Vec<f32> = (0..5000).map(|i| (i as f32) * 0.37 - 9.0).collect();
        let path = tmp("roundtrip");
        save(&path, &params, &meta(5000)).unwrap();
        let (back, m) = load(&path).unwrap();
        assert_eq!(back, params, "payload must be bit-exact");
        assert_eq!(m, meta(5000));
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmp("badmagic");
        std::fs::write(&path, b"NOTACKPT........").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn rejects_truncated_payload() {
        let params: Vec<f32> = vec![1.0; 100];
        let path = tmp("trunc");
        save(&path, &params, &meta(100)).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 8]).unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn rejects_meta_mismatch() {
        let params: Vec<f32> = vec![0.0; 10];
        assert!(save(&tmp("mismatch"), &params, &meta(11)).is_err());
    }

    #[test]
    fn optimizer_state_roundtrips() {
        let params: Vec<f32> = vec![1.0; 16];
        let mut m = meta(16);
        m.optimizer = Some(OptimizerState {
            name: "sa".into(),
            best: Some((Placement::new(vec![4, 0, 9]), 12.625)),
        });
        let path = tmp("optstate");
        save(&path, &params, &m).unwrap();
        let (_, back) = load(&path).unwrap();
        assert_eq!(back, m);
        // Snapshot without a best observation (fresh optimizer).
        m.optimizer = Some(OptimizerState { name: "pso".into(), best: None });
        save(&path, &params, &m).unwrap();
        let (_, back) = load(&path).unwrap();
        assert_eq!(back.optimizer, m.optimizer);
    }

    #[test]
    fn model_only_checkpoints_load_without_optimizer() {
        // The pre-extension header shape: no "optimizer" key at all.
        let path = tmp("no_opt");
        save(&path, &[0.5; 4], &meta(4)).unwrap();
        let (_, m) = load(&path).unwrap();
        assert_eq!(m.optimizer, None);
    }

    #[test]
    fn restored_state_feeds_a_live_optimizer() {
        use crate::placement::{registry, Optimizer};
        use crate::pso::PsoConfig;
        // Run a strategy, snapshot it through a checkpoint file, restore
        // into a fresh instance of the same strategy.
        let mut opt = registry::build_live("tabu", 3, 12, PsoConfig::paper(), 5).unwrap();
        for round in 0..30 {
            let batch = opt.propose_batch(round);
            let delays: Vec<f64> =
                batch.iter().map(|p| p.iter().sum::<usize>() as f64 + 1.0).collect();
            opt.observe_batch(&batch, &delays);
        }
        let mut m = meta(4);
        m.optimizer = Some(opt.state());
        let path = tmp("live_restore");
        save(&path, &[0.0; 4], &m).unwrap();
        let (_, back) = load(&path).unwrap();
        let snapshot = back.optimizer.expect("optimizer persisted");

        let mut fresh = registry::build_live("tabu", 3, 12, PsoConfig::paper(), 99).unwrap();
        fresh.restore(&snapshot).expect("same-strategy restore");
        assert_eq!(fresh.best(), opt.best());
        // Wrong strategy is still rejected after the file roundtrip.
        let mut other = registry::build_live("random", 3, 12, PsoConfig::paper(), 1).unwrap();
        assert!(other.restore(&snapshot).is_err());
    }

    #[test]
    fn special_floats_preserved() {
        let params = vec![f32::MIN, f32::MAX, 0.0, -0.0, 1e-38, -1e38];
        let path = tmp("special");
        save(&path, &params, &meta(6)).unwrap();
        let (back, _) = load(&path).unwrap();
        assert_eq!(back.len(), 6);
        for (a, b) in params.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
