//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! by `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the only place rust touches XLA. Everything above it (the FL
//! framework, the coordinator, the emulated clients) moves opaque flat
//! `Vec<f32>` parameter vectors. Python never runs at request time.
//!
//! Interchange is HLO *text* (see aot.py / /opt/xla-example/README.md).

mod artifacts;
pub mod checkpoint;
mod model_exec;

pub use artifacts::ArtifactMeta;
pub use checkpoint::CheckpointMeta;
pub use model_exec::ModelRuntime;
