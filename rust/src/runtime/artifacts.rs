//! `artifacts/meta.json`: the contract between the python build path and
//! the rust runtime (parameter count, batch sizes, artifact filenames).

use crate::json::{self, Value};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Parsed artifact metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    /// Directory the artifacts live in.
    pub dir: PathBuf,
    /// Flat parameter-vector length P (1,863,690 for the paper's MLP).
    pub param_count: usize,
    /// MLP input dimension (784).
    pub input_dim: usize,
    /// Number of classes (10).
    pub num_classes: usize,
    /// Static train/eval batch sizes baked into the artifacts.
    pub train_batch: usize,
    pub eval_batch: usize,
    /// K (fan-in) → aggregate artifact filename.
    pub aggregate: BTreeMap<usize, String>,
    /// init / train_step / eval artifact filenames.
    pub init_file: String,
    pub train_step_file: String,
    /// Optional heavy-ball momentum variant (absent in older exports).
    pub train_step_momentum_file: Option<String>,
    pub eval_file: String,
}

impl ArtifactMeta {
    /// Load `dir/meta.json`.
    pub fn load(dir: &Path) -> Result<ArtifactMeta> {
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        let need = |key: &str| -> Result<&Value> {
            v.get(key).ok_or_else(|| anyhow!("meta.json: missing {key:?}"))
        };
        let need_usize = |key: &str| -> Result<usize> {
            need(key)?
                .as_usize()
                .ok_or_else(|| anyhow!("meta.json: {key:?} not an integer"))
        };
        let arts = need("artifacts")?;
        let art_str = |key: &str| -> Result<String> {
            arts.get(key)
                .and_then(Value::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("meta.json: artifacts.{key} missing"))
        };
        let mut aggregate = BTreeMap::new();
        let agg = arts
            .get("aggregate")
            .and_then(Value::as_object)
            .ok_or_else(|| anyhow!("meta.json: artifacts.aggregate missing"))?;
        for (k, file) in agg {
            let k: usize = k.parse().map_err(|_| anyhow!("bad aggregate key {k:?}"))?;
            let file = file
                .as_str()
                .ok_or_else(|| anyhow!("aggregate[{k}] not a string"))?;
            aggregate.insert(k, file.to_string());
        }
        if aggregate.is_empty() {
            return Err(anyhow!("meta.json: no aggregate artifacts"));
        }
        Ok(ArtifactMeta {
            dir: dir.to_path_buf(),
            param_count: need_usize("param_count")?,
            input_dim: need_usize("input_dim")?,
            num_classes: need_usize("num_classes")?,
            train_batch: need_usize("train_batch")?,
            eval_batch: need_usize("eval_batch")?,
            aggregate,
            init_file: art_str("init")?,
            train_step_file: art_str("train_step")?,
            train_step_momentum_file: arts
                .get("train_step_momentum")
                .and_then(Value::as_str)
                .map(str::to_string),
            eval_file: art_str("eval")?,
        })
    }

    /// Default artifact directory: `$REPRO_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("REPRO_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Smallest exported aggregate fan-in K' ≥ `k` (zero-weight padding
    /// makes K' > k exact — see `test_wavg_zero_weight_child_ignored`).
    pub fn aggregate_k_for(&self, k: usize) -> Result<usize> {
        self.aggregate
            .keys()
            .copied()
            .find(|&kk| kk >= k)
            .ok_or_else(|| {
                anyhow!(
                    "no aggregate artifact for fan-in {k} (max exported: {})",
                    self.aggregate.keys().max().unwrap()
                )
            })
    }

    /// Absolute path of an artifact file.
    pub fn path_of(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_meta(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            r#"{
  "param_count": 100,
  "input_dim": 4,
  "num_classes": 3,
  "train_batch": 8,
  "eval_batch": 16,
  "aggregate_ks": [2, 4],
  "artifacts": {
    "init": "init.hlo.txt",
    "train_step": "train_step_b8.hlo.txt",
    "eval": "eval_b16.hlo.txt",
    "aggregate": {"2": "aggregate_k2.hlo.txt", "4": "aggregate_k4.hlo.txt"}
  }
}"#,
        )
        .unwrap();
    }

    #[test]
    fn parses_meta() {
        let dir = std::env::temp_dir().join("repro_meta_test");
        write_meta(&dir);
        let m = ArtifactMeta::load(&dir).unwrap();
        assert_eq!(m.param_count, 100);
        assert_eq!(m.train_batch, 8);
        assert_eq!(m.aggregate.len(), 2);
        assert_eq!(m.init_file, "init.hlo.txt");
    }

    #[test]
    fn aggregate_k_rounds_up() {
        let dir = std::env::temp_dir().join("repro_meta_test2");
        write_meta(&dir);
        let m = ArtifactMeta::load(&dir).unwrap();
        assert_eq!(m.aggregate_k_for(1).unwrap(), 2);
        assert_eq!(m.aggregate_k_for(2).unwrap(), 2);
        assert_eq!(m.aggregate_k_for(3).unwrap(), 4);
        assert!(m.aggregate_k_for(5).is_err());
    }

    #[test]
    fn missing_dir_is_helpful() {
        let err = ArtifactMeta::load(Path::new("/nonexistent_dir_xyz")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
