//! The compiled-model runtime: one PJRT CPU client + one compiled
//! executable per artifact. Thread-safe (`&self` methods; the underlying
//! PJRT CPU client serializes or parallelizes internally), shared across
//! all emulated clients via `Arc`.

use super::ArtifactMeta;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Loaded + compiled model graphs, ready to execute from the L3 hot path.
pub struct ModelRuntime {
    pub meta: ArtifactMeta,
    #[allow(dead_code)]
    client: xla::PjRtClient,
    init_exe: xla::PjRtLoadedExecutable,
    train_exe: xla::PjRtLoadedExecutable,
    train_momentum_exe: Option<xla::PjRtLoadedExecutable>,
    eval_exe: xla::PjRtLoadedExecutable,
    /// Fan-in K → compiled aggregate executable.
    agg_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
}

// SAFETY: the xla wrapper types hold raw pointers into the PJRT C API,
// which is documented thread-safe for compilation and execution
// (PJRT_Client/PJRT_LoadedExecutable methods may be called concurrently).
// ModelRuntime exposes only &self execution over immutable executables.
unsafe impl Send for ModelRuntime {}
unsafe impl Sync for ModelRuntime {}

impl ModelRuntime {
    /// Load every artifact under `dir` and compile on the CPU PJRT client.
    pub fn load(dir: &Path) -> Result<ModelRuntime> {
        let meta = ArtifactMeta::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        let compile = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = meta.path_of(file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(wrap)
                .with_context(|| format!("parsing {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(wrap)
                .with_context(|| format!("compiling {path:?}"))
        };
        let init_exe = compile(&meta.init_file)?;
        let train_exe = compile(&meta.train_step_file)?;
        let train_momentum_exe = match &meta.train_step_momentum_file {
            Some(f) => Some(compile(f)?),
            None => None,
        };
        let eval_exe = compile(&meta.eval_file)?;
        let mut agg_exes = BTreeMap::new();
        for (&k, file) in &meta.aggregate {
            agg_exes.insert(k, compile(file)?);
        }
        Ok(ModelRuntime {
            meta,
            client,
            init_exe,
            train_exe,
            train_momentum_exe,
            eval_exe,
            agg_exes,
        })
    }

    /// Load from the default artifact directory (`$REPRO_ARTIFACTS` or
    /// `./artifacts`).
    pub fn load_default() -> Result<ModelRuntime> {
        Self::load(&ArtifactMeta::default_dir())
    }

    /// Initialize a flat parameter vector from a 2-word threefry seed.
    pub fn init_params(&self, seed: [u32; 2]) -> Result<Vec<f32>> {
        let key = xla::Literal::vec1(&seed[..]);
        let result = self.init_exe.execute::<xla::Literal>(&[key]).map_err(wrap)?;
        let out = result[0][0].to_literal_sync().map_err(wrap)?.to_tuple1().map_err(wrap)?;
        let params = out.to_vec::<f32>().map_err(wrap)?;
        debug_assert_eq!(params.len(), self.meta.param_count);
        Ok(params)
    }

    /// One local SGD step. `x` is row-major `[train_batch, input_dim]`,
    /// `y` class ids `[train_batch]`. Returns (new_params, loss).
    pub fn train_step(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let b = self.meta.train_batch;
        let d = self.meta.input_dim;
        if params.len() != self.meta.param_count {
            return Err(anyhow!(
                "train_step: params len {} != {}",
                params.len(),
                self.meta.param_count
            ));
        }
        if x.len() != b * d || y.len() != b {
            return Err(anyhow!(
                "train_step: batch shape mismatch (x {} want {}, y {} want {})",
                x.len(),
                b * d,
                y.len(),
                b
            ));
        }
        let args = [
            literal_f32(params, &[params.len()])?,
            literal_f32(x, &[b, d])?,
            xla::Literal::vec1(y),
            xla::Literal::vec1(&[lr]),
        ];
        let result = self.train_exe.execute::<xla::Literal>(&args).map_err(wrap)?;
        let (new_params, loss) = result[0][0]
            .to_literal_sync()
            .map_err(wrap)?
            .to_tuple2()
            .map_err(wrap)?;
        Ok((
            new_params.to_vec::<f32>().map_err(wrap)?,
            loss.get_first_element::<f32>().map_err(wrap)?,
        ))
    }

    /// One heavy-ball momentum step (optional artifact). `velocity` is
    /// the per-client momentum buffer; returns (params', velocity', loss).
    pub fn train_step_momentum(
        &self,
        params: &[f32],
        velocity: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
        mu: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        let exe = self
            .train_momentum_exe
            .as_ref()
            .ok_or_else(|| anyhow!("momentum artifact not exported — re-run `make artifacts`"))?;
        let b = self.meta.train_batch;
        let d = self.meta.input_dim;
        if params.len() != self.meta.param_count || velocity.len() != params.len() {
            return Err(anyhow!("train_step_momentum: param/velocity length mismatch"));
        }
        if x.len() != b * d || y.len() != b {
            return Err(anyhow!("train_step_momentum: batch shape mismatch"));
        }
        let args = [
            literal_f32(params, &[params.len()])?,
            literal_f32(velocity, &[velocity.len()])?,
            literal_f32(x, &[b, d])?,
            xla::Literal::vec1(y),
            xla::Literal::vec1(&[lr, mu]),
        ];
        let result = exe.execute::<xla::Literal>(&args).map_err(wrap)?;
        let (new_p, new_v, loss) = result[0][0]
            .to_literal_sync()
            .map_err(wrap)?
            .to_tuple3()
            .map_err(wrap)?;
        Ok((
            new_p.to_vec::<f32>().map_err(wrap)?,
            new_v.to_vec::<f32>().map_err(wrap)?,
            loss.get_first_element::<f32>().map_err(wrap)?,
        ))
    }

    /// Whether the momentum artifact was exported and compiled.
    pub fn has_momentum(&self) -> bool {
        self.train_momentum_exe.is_some()
    }

    /// Evaluate on one `[eval_batch]`-sized batch: returns (loss, accuracy).
    pub fn evaluate(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        let b = self.meta.eval_batch;
        let d = self.meta.input_dim;
        if x.len() != b * d || y.len() != b {
            return Err(anyhow!("evaluate: batch shape mismatch"));
        }
        let args = [
            literal_f32(params, &[params.len()])?,
            literal_f32(x, &[b, d])?,
            xla::Literal::vec1(y),
        ];
        let result = self.eval_exe.execute::<xla::Literal>(&args).map_err(wrap)?;
        let (loss, acc) = result[0][0]
            .to_literal_sync()
            .map_err(wrap)?
            .to_tuple2()
            .map_err(wrap)?;
        Ok((
            loss.get_first_element::<f32>().map_err(wrap)?,
            acc.get_first_element::<f32>().map_err(wrap)?,
        ))
    }

    /// FedAvg over `models` with `weights` (raw, e.g. sample counts).
    ///
    /// Picks the smallest exported fan-in K' ≥ models.len() and zero-pads
    /// both the stack and the weights — a zero-weight child contributes
    /// nothing (L1 kernel invariant, tested in python and here).
    pub fn aggregate(&self, models: &[&[f32]], weights: &[f32]) -> Result<Vec<f32>> {
        let p = self.meta.param_count;
        let k = models.len();
        if k == 0 {
            return Err(anyhow!("aggregate: no models"));
        }
        if weights.len() != k {
            return Err(anyhow!("aggregate: {} weights for {} models", weights.len(), k));
        }
        if weights.iter().any(|w| *w < 0.0) || weights.iter().sum::<f32>() <= 0.0 {
            return Err(anyhow!("aggregate: weights must be non-negative with positive sum"));
        }
        for (i, m) in models.iter().enumerate() {
            if m.len() != p {
                return Err(anyhow!("aggregate: model {i} len {} != {p}", m.len()));
            }
        }
        let kk = self.meta.aggregate_k_for(k)?;
        let exe = &self.agg_exes[&kk];
        // Stack into [K', P] row-major with zero padding, then hand the
        // bytes straight to the literal (single copy into XLA).
        let mut stacked = vec![0.0f32; kk * p];
        for (i, m) in models.iter().enumerate() {
            stacked[i * p..(i + 1) * p].copy_from_slice(m);
        }
        let mut w = vec![0.0f32; kk];
        w[..k].copy_from_slice(weights);
        let args = [literal_f32(&stacked, &[kk, p])?, xla::Literal::vec1(&w)];
        let result = exe.execute::<xla::Literal>(&args).map_err(wrap)?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(wrap)?
            .to_tuple1()
            .map_err(wrap)?;
        Ok(out.to_vec::<f32>().map_err(wrap)?)
    }
}

/// xla::Error does not implement std::error::Error compatibly with
/// anyhow's blanket From; wrap by formatting.
fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e:?}")
}

/// View an f32 slice as raw bytes (host-native layout — exactly what the
/// PJRT host-buffer API expects). Perf: avoids the `Literal::vec1` +
/// `reshape` double copy on the 7.5–60 MB hot-path buffers
/// (EXPERIMENTS.md §Perf iteration 2).
fn f32_bytes(xs: &[f32]) -> &[u8] {
    // SAFETY: f32 has no invalid bit patterns and &[u8] has alignment 1.
    unsafe { std::slice::from_raw_parts(xs.as_ptr().cast::<u8>(), std::mem::size_of_val(xs)) }
}

/// Build an f32 literal of arbitrary shape with a single copy.
fn literal_f32(xs: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    debug_assert_eq!(dims.iter().product::<usize>(), xs.len());
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, f32_bytes(xs))
        .map_err(wrap)
}
