//! Tiny leveled logger (substrate — no `env_logger` offline).
//!
//! Thread-safe, monotonic-timestamped, level-filtered via `REPRO_LOG`
//! (error|warn|info|debug|trace, default info). Used by the broker,
//! coordinator and agents; benches set `error` to keep hot loops quiet.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Log severity (ascending verbosity).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // u8::MAX == uninitialized
static START: OnceLock<Instant> = OnceLock::new();
static SINK: Mutex<()> = Mutex::new(());

fn max_level() -> u8 {
    let cur = MAX_LEVEL.load(Ordering::Relaxed);
    if cur != u8::MAX {
        return cur;
    }
    let lvl = std::env::var("REPRO_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(Level::Info) as u8;
    MAX_LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Override the level programmatically (benches/tests).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// True if `level` would be emitted (guards expensive format args).
pub fn enabled(level: Level) -> bool {
    (level as u8) <= max_level()
}

/// Core emit function — use the [`crate::log_info!`]-family macros instead.
pub fn log(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed();
    let _guard = SINK.lock().unwrap();
    let mut err = std::io::stderr().lock();
    let _ = writeln!(
        err,
        "[{:>9.4}s {} {}] {}",
        t.as_secs_f64(),
        level.tag(),
        target,
        msg
    );
}

/// `log_error!(target, fmt...)`
#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Error, $target, format_args!($($arg)*))
    };
}

/// `log_warn!(target, fmt...)`
#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Warn, $target, format_args!($($arg)*))
    };
}

/// `log_info!(target, fmt...)`
#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Info, $target, format_args!($($arg)*))
    };
}

/// `log_debug!(target, fmt...)`
#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Debug, $target, format_args!($($arg)*))
    };
}

/// `log_trace!(target, fmt...)`
#[macro_export]
macro_rules! log_trace {
    ($target:expr, $($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Trace, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("DEBUG"), Some(Level::Debug));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn set_level_filters() {
        set_level(Level::Error);
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default-ish for other tests
    }
}
