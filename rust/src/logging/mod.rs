//! Tiny leveled logger (substrate — no `env_logger` offline).
//!
//! Thread-safe, monotonic-timestamped, level-filtered via `REPRO_LOG`
//! (error|warn|info|debug|trace, default info) or the `--log-level`
//! launcher flag (which wins). Used by the broker, coordinator and
//! agents; benches set `error` to keep hot loops quiet.
//!
//! `REPRO_LOG_FORMAT=json` switches the sink to one JSON object per
//! line (`t_s`, `level`, `target`, `msg`) for machine ingestion; the
//! default remains the human-readable text format.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Log severity (ascending verbosity).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    /// Parse a level name (case-insensitive); `None` on unknown input.
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }
}

/// Sink line format, selected once via `REPRO_LOG_FORMAT` or
/// [`set_format`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Format {
    /// `[   0.0123s INFO  target] message` (default).
    Text,
    /// One JSON object per line: `{"t_s":…,"level":…,"target":…,"msg":…}`.
    Json,
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // u8::MAX == uninitialized
static FORMAT: AtomicU8 = AtomicU8::new(u8::MAX); // u8::MAX == uninitialized
static START: OnceLock<Instant> = OnceLock::new();
static SINK: Mutex<()> = Mutex::new(());

fn max_level() -> u8 {
    let cur = MAX_LEVEL.load(Ordering::Relaxed);
    if cur != u8::MAX {
        return cur;
    }
    let lvl = std::env::var("REPRO_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(Level::Info) as u8;
    MAX_LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

fn format() -> Format {
    let cur = FORMAT.load(Ordering::Relaxed);
    if cur != u8::MAX {
        return if cur == 1 { Format::Json } else { Format::Text };
    }
    let fmt = match std::env::var("REPRO_LOG_FORMAT").ok().as_deref() {
        Some("json") => Format::Json,
        _ => Format::Text,
    };
    FORMAT.store(if fmt == Format::Json { 1 } else { 0 }, Ordering::Relaxed);
    fmt
}

/// Override the level programmatically (the `--log-level` launcher
/// flag, benches, tests). Wins over `REPRO_LOG`.
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Override the sink format programmatically. Wins over
/// `REPRO_LOG_FORMAT`.
pub fn set_format(format: Format) {
    FORMAT.store(if format == Format::Json { 1 } else { 0 }, Ordering::Relaxed);
}

/// Escape `s` into `out` as JSON string *contents* (no surrounding
/// quotes). Covers the mandatory set: quote, backslash, and control
/// characters below U+0020.
fn escape_json_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
}

/// True if `level` would be emitted (guards expensive format args).
pub fn enabled(level: Level) -> bool {
    (level as u8) <= max_level()
}

/// Core emit function — use the [`crate::log_info!`]-family macros instead.
pub fn log(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed();
    let line = match format() {
        Format::Text => None,
        Format::Json => Some(render_json_line(t.as_secs_f64(), level, target, &msg.to_string())),
    };
    let _guard = SINK.lock().unwrap();
    let mut err = std::io::stderr().lock();
    let _ = match line {
        Some(json) => writeln!(err, "{json}"),
        None => writeln!(
            err,
            "[{:>9.4}s {} {}] {}",
            t.as_secs_f64(),
            level.tag(),
            target,
            msg
        ),
    };
}

fn render_json_line(t_s: f64, level: Level, target: &str, msg: &str) -> String {
    let mut out = String::with_capacity(64 + msg.len());
    out.push_str(&format!("{{\"t_s\":{t_s:.4},\"level\":\"{}\",\"target\":\"", level.name()));
    escape_json_into(&mut out, target);
    out.push_str("\",\"msg\":\"");
    escape_json_into(&mut out, msg);
    out.push_str("\"}");
    out
}

/// `log_error!(target, fmt...)`
#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Error, $target, format_args!($($arg)*))
    };
}

/// `log_warn!(target, fmt...)`
#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Warn, $target, format_args!($($arg)*))
    };
}

/// `log_info!(target, fmt...)`
#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Info, $target, format_args!($($arg)*))
    };
}

/// `log_debug!(target, fmt...)`
#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Debug, $target, format_args!($($arg)*))
    };
}

/// `log_trace!(target, fmt...)`
#[macro_export]
macro_rules! log_trace {
    ($target:expr, $($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Trace, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("DEBUG"), Some(Level::Debug));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn set_level_filters() {
        set_level(Level::Error);
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default-ish for other tests
    }

    #[test]
    fn json_lines_are_valid_and_escaped() {
        let line = render_json_line(1.25, Level::Warn, "svc", "said \"hi\"\n\\done\t<x01>");
        // Round-trips through the vendored parser — i.e. it really is JSON.
        let v = crate::json::parse(&line).expect("log line must be valid JSON");
        assert_eq!(v.get("level").and_then(|l| l.as_str()), Some("warn"));
        assert_eq!(v.get("target").and_then(|t| t.as_str()), Some("svc"));
        assert_eq!(
            v.get("msg").and_then(|m| m.as_str()),
            Some("said \"hi\"\n\\done\t<x01>")
        );
        assert_eq!(v.get("t_s").and_then(|t| t.as_f64()), Some(1.25));
        // Control chars below U+0020 take the \u form.
        let ctl = render_json_line(0.0, Level::Info, "t", "\u{1}");
        assert!(ctl.contains("\\u0001"), "{ctl}");
        crate::json::parse(&ctl).expect("control-char line must parse");
    }

    #[test]
    fn format_override_round_trips() {
        set_format(Format::Json);
        assert_eq!(format(), Format::Json);
        set_format(Format::Text); // restore for other tests
        assert_eq!(format(), Format::Text);
    }
}
