//! SDFLMQ-style federated-learning framework over pub/sub (paper §II).
//!
//! Roles are topics: the coordinator announces each round's arrangement,
//! aggregator slots subscribe to round-scoped slot topics, trainers and
//! child aggregators publish model updates to their parent's slot topic,
//! and the root aggregator publishes the round result. Clients never
//! share internal metrics — the coordinator's only signal is the round's
//! wall-clock processing delay (the paper's black-box constraint).
//!
//! Module map:
//! * [`roles`] — the topic naming scheme.
//! * [`messages`] — JSON control-plane messages (round start / ready).
//! * [`codec`] — model-update payloads: JSON (the paper's ~30 MB format)
//!   or length-prefixed binary (perf variant; ablation A4).
//! * [`emulation`] — heterogeneous-client throttling (docker substitute).
//! * [`agent`] — the client agent: trains and/or aggregates per role.
//! * [`coordinator`] — executes rounds, measures TPD, exposes the
//!   [`LiveSession`] environment the placement optimizers run against,
//!   records Fig-4 data.
//! * [`session`] — wires broker + agents + coordinator + optimizer into
//!   a running deployment.

pub mod agent;
pub mod codec;
pub mod coordinator;
pub mod emulation;
pub mod messages;
pub mod roles;
pub mod session;

pub use agent::ClientAgent;
pub use codec::ModelCodec;
pub use coordinator::{Coordinator, CoordinatorConfig, LiveSession};
pub use emulation::EmulatedClock;
pub use messages::{ReadyMsg, ResultMeta, RoundStart};
pub use session::Deployment;
