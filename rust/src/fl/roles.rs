//! Topic naming: the SDFLMQ "roles are topics" scheme, round-scoped so
//! a slow client's late update can never contaminate the next round.

/// Round-announcement topic (coordinator → everyone).
pub fn round_topic(session: &str) -> String {
    format!("fl/{session}/round")
}

/// Global-model broadcast for a round (coordinator → trainers).
pub fn global_topic(session: &str, round: usize) -> String {
    format!("fl/{session}/r/{round}/global")
}

/// Aggregator slot inbox for a round (children → slot owner).
pub fn slot_topic(session: &str, round: usize, slot: usize) -> String {
    format!("fl/{session}/r/{round}/slot/{slot}")
}

/// Aggregator-ready barrier (slot owner → coordinator).
pub fn ready_topic(session: &str, round: usize) -> String {
    format!("fl/{session}/r/{round}/ready")
}

/// Round result (root aggregator → coordinator).
pub fn result_topic(session: &str, round: usize) -> String {
    format!("fl/{session}/r/{round}/result")
}

/// Per-client heartbeat (client → coordinator, once per handled round).
pub fn hb_topic(session: &str, client: usize) -> String {
    format!("fl/{session}/hb/{client}")
}

/// Subscription filter covering all heartbeats of a session.
pub fn hb_filter(session: &str) -> String {
    format!("fl/{session}/hb/+")
}

/// Session shutdown broadcast.
pub fn shutdown_topic(session: &str) -> String {
    format!("fl/{session}/shutdown")
}

/// Per-client join announcement (retained — the join barrier for
/// multi-process deployments).
pub fn join_topic(session: &str, client: usize) -> String {
    format!("fl/{session}/join/{client}")
}

/// Subscription filter covering all join announcements of a session.
pub fn join_filter(session: &str) -> String {
    format!("fl/{session}/join/+")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::{topic_matches, validate_topic};

    #[test]
    fn topics_are_valid_and_distinct() {
        let ts = [
            round_topic("s1"),
            global_topic("s1", 3),
            slot_topic("s1", 3, 0),
            slot_topic("s1", 3, 1),
            ready_topic("s1", 3),
            result_topic("s1", 3),
            shutdown_topic("s1"),
            hb_topic("s1", 0),
            hb_topic("s1", 1),
        ];
        for t in &ts {
            validate_topic(t).unwrap();
        }
        let mut sorted = ts.to_vec();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), ts.len());
    }

    #[test]
    fn round_scoping_prevents_cross_round_matches() {
        assert!(!topic_matches(
            &slot_topic("s", 4, 0),
            &slot_topic("s", 5, 0)
        ));
    }

    #[test]
    fn sessions_are_isolated() {
        assert_ne!(round_topic("a"), round_topic("b"));
        assert!(!topic_matches("fl/a/#", &round_topic("b")));
    }

    #[test]
    fn hb_filter_matches_only_its_sessions_heartbeats() {
        assert!(topic_matches(&hb_filter("s"), &hb_topic("s", 7)));
        assert!(!topic_matches(&hb_filter("s"), &hb_topic("other", 7)));
        assert!(!topic_matches(&hb_filter("s"), &join_topic("s", 7)));
    }
}
