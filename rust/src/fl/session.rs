//! Deployment wiring: broker + agents + coordinator for one scenario —
//! the programmatic equivalent of the paper's docker-compose setup.

use super::agent::ClientAgent;
use super::coordinator::{Coordinator, CoordinatorConfig};
use super::emulation::EmulatedClock;
use crate::broker::Broker;
use crate::configio::DeployScenario;
use crate::data::{SynthConfig, SynthDataset};
use crate::placement::Optimizer;
use crate::runtime::ModelRuntime;
use anyhow::Result;
use std::sync::Arc;
use std::time::Duration;

/// A running SDFL deployment (agents on threads, coordinator inline,
/// placement optimizer driven through the live-session environment).
pub struct Deployment {
    pub coordinator: Coordinator,
    pub broker: Broker,
    optimizer: Box<dyn Optimizer>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Deployment {
    /// Spawn one agent thread per client in the scenario and build the
    /// coordinator; `optimizer` proposes each round's placement.
    pub fn launch(
        scenario: &DeployScenario,
        session: &str,
        runtime: Arc<ModelRuntime>,
        optimizer: Box<dyn Optimizer>,
        time_scale: f64,
    ) -> Result<Deployment> {
        let broker = Broker::new();
        let (coordinator, handles) =
            Deployment::wire(scenario, session, runtime, &broker, time_scale)?;
        Ok(Deployment {
            coordinator,
            broker,
            optimizer,
            handles,
        })
    }

    /// Spawn this scenario's agents and build its coordinator on an
    /// existing — possibly shared — broker. Topics are session-scoped,
    /// so the service tier multiplexes many concurrent sessions over one
    /// broker this way; [`Deployment::launch`] is the single-session
    /// convenience over a private broker. The child timeout comes from
    /// the scenario (`[deploy] child_timeout_secs`, default 120 s).
    pub fn wire(
        scenario: &DeployScenario,
        session: &str,
        runtime: Arc<ModelRuntime>,
        broker: &Broker,
        time_scale: f64,
    ) -> Result<(Coordinator, Vec<std::thread::JoinHandle<()>>)> {
        scenario.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut handles = Vec::with_capacity(scenario.clients.len());
        let child_timeout = Duration::from_secs_f64(scenario.child_timeout_secs);

        for (id, spec) in scenario.clients.iter().enumerate() {
            let mut clock = EmulatedClock::new(spec.clone());
            clock.time_scale = time_scale;
            let data = SynthDataset::for_client(
                SynthConfig {
                    input_dim: runtime.meta.input_dim,
                    num_classes: runtime.meta.num_classes,
                    samples_per_client: 64,
                    seed: scenario.seed,
                    ..SynthConfig::default()
                },
                id,
            );
            let client = broker.connect(&format!("{session}-{}", spec.name));
            let agent = ClientAgent::new(
                id,
                session,
                clock,
                runtime.clone(),
                data,
                client,
                child_timeout,
            );
            handles.push(
                std::thread::Builder::new()
                    .name(format!("{session}-agent-{id}"))
                    .spawn(move || agent.run())
                    .expect("spawn agent"),
            );
        }

        let cfg = CoordinatorConfig {
            session: session.to_string(),
            depth: scenario.depth,
            width: scenario.width,
            client_count: scenario.clients.len(),
            local_steps: scenario.local_steps,
            lr: scenario.lr,
            codec: super::ModelCodec::Binary,
            round_timeout: Duration::from_secs(300),
            eval_every: 1,
            model_seed: [0, scenario.seed as u32],
            data_seed: scenario.seed,
        };
        let name = format!("{session}-coordinator");
        let coordinator = Coordinator::new(cfg, broker.connect(&name), runtime)?;
        Ok((coordinator, handles))
    }

    /// Run `rounds` rounds (optimizer propose → live round → observe),
    /// then return self for inspection.
    pub fn run(&mut self, rounds: usize) -> Result<()> {
        self.coordinator.run_session(self.optimizer.as_mut(), rounds)
    }

    /// The placement optimizer driving this deployment.
    pub fn optimizer(&self) -> &dyn Optimizer {
        &*self.optimizer
    }

    /// Persist the global model *and* the optimizer's transferable state
    /// in one checkpoint, so `restore_checkpoint` resumes both.
    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        self.coordinator
            .save_checkpoint_with(path, Some(self.optimizer.state()))
    }

    /// Restore the global model and, when the checkpoint carries one,
    /// the placement-optimizer snapshot (the snapshot must come from the
    /// same strategy, at this deployment's shape). Validation runs
    /// before any state is replaced: the parameter count is pre-checked,
    /// and `Optimizer::restore` implementations validate the snapshot
    /// (strategy name + placement shape) before mutating — so a
    /// mismatched checkpoint leaves the deployment untouched.
    pub fn restore_checkpoint(&mut self, path: &std::path::Path) -> Result<()> {
        let (params, meta) = crate::runtime::checkpoint::load(path)?;
        if params.len() != self.coordinator.expected_param_count() {
            return Err(anyhow::anyhow!(
                "checkpoint has {} params, artifacts expect {}",
                params.len(),
                self.coordinator.expected_param_count()
            ));
        }
        // Optimizer first: its restore is validate-then-mutate, and the
        // model install below can no longer fail after the pre-check.
        if let Some(state) = &meta.optimizer {
            self.optimizer
                .restore(state)
                .map_err(|e| anyhow::anyhow!("restoring optimizer: {e}"))?;
        }
        self.coordinator.install_checkpoint(params, &meta)
    }

    /// Shut down agents and join their threads.
    pub fn shutdown(mut self) {
        self.coordinator.shutdown();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}
