//! Model-update payload codecs.
//!
//! The paper's SDFLMQ writes model parameters as JSON (~30 MB for the
//! 1.8 M-param MLP) — reproduced here as [`ModelCodec::Json`]. The
//! [`ModelCodec::Binary`] variant is the perf alternative (little-endian
//! f32, length-prefixed); ablation A4 quantifies the gap.
//!
//! Envelope (both codecs): sender id, aggregation weight, flat params.

use crate::json::{self, Value};

/// One model update as it travels between FL nodes.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelUpdate {
    /// Sending client id (`usize::MAX` marks a coordinator broadcast).
    pub sender: usize,
    /// Aggregation weight (e.g. sample count), summed up the hierarchy.
    pub weight: f32,
    pub params: Vec<f32>,
}

/// Wire format selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelCodec {
    /// The paper's JSON format.
    Json,
    /// Length-prefixed little-endian f32 (perf variant).
    Binary,
}

const BIN_MAGIC: &[u8; 4] = b"FLB1";

impl ModelCodec {
    pub fn name(self) -> &'static str {
        match self {
            ModelCodec::Json => "json",
            ModelCodec::Binary => "binary",
        }
    }

    pub fn from_name(name: &str) -> Result<ModelCodec, String> {
        match name {
            "json" => Ok(ModelCodec::Json),
            "binary" => Ok(ModelCodec::Binary),
            other => Err(format!("unknown codec {other:?}")),
        }
    }

    /// Serialize an update.
    pub fn encode(self, update: &ModelUpdate) -> Vec<u8> {
        match self {
            ModelCodec::Json => {
                let v = Value::object(vec![
                    ("sender", Value::from(update.sender as u64)),
                    ("weight", Value::from(update.weight as f64)),
                    ("params", Value::from_f32_slice(&update.params)),
                ]);
                json::to_string(&v).into_bytes()
            }
            ModelCodec::Binary => {
                let mut out = Vec::with_capacity(16 + update.params.len() * 4);
                out.extend_from_slice(BIN_MAGIC);
                out.extend_from_slice(&(update.sender as u64).to_le_bytes());
                out.extend_from_slice(&update.weight.to_le_bytes());
                out.extend_from_slice(&(update.params.len() as u32).to_le_bytes());
                // Bulk-copy the f32 payload (LE hosts: this is memcpy).
                for &p in &update.params {
                    out.extend_from_slice(&p.to_le_bytes());
                }
                out
            }
        }
    }

    /// Deserialize; auto-detects the wire format (binary magic vs JSON),
    /// so mixed-codec sessions cannot mis-parse.
    pub fn decode(bytes: &[u8]) -> Result<ModelUpdate, String> {
        if bytes.starts_with(BIN_MAGIC) {
            Self::decode_binary(bytes)
        } else {
            Self::decode_json(bytes)
        }
    }

    fn decode_binary(bytes: &[u8]) -> Result<ModelUpdate, String> {
        if bytes.len() < 20 {
            return Err("binary update: truncated header".into());
        }
        let sender = u64::from_le_bytes(bytes[4..12].try_into().unwrap()) as usize;
        let weight = f32::from_le_bytes(bytes[12..16].try_into().unwrap());
        let n = u32::from_le_bytes(bytes[16..20].try_into().unwrap()) as usize;
        let body = &bytes[20..];
        if body.len() != n * 4 {
            return Err(format!(
                "binary update: payload {} bytes, expected {}",
                body.len(),
                n * 4
            ));
        }
        let mut params = Vec::with_capacity(n);
        for chunk in body.chunks_exact(4) {
            params.push(f32::from_le_bytes(chunk.try_into().unwrap()));
        }
        Ok(ModelUpdate {
            sender,
            weight,
            params,
        })
    }

    fn decode_json(bytes: &[u8]) -> Result<ModelUpdate, String> {
        let text = std::str::from_utf8(bytes).map_err(|e| e.to_string())?;
        let v = json::parse(text).map_err(|e| e.to_string())?;
        let sender = v
            .get("sender")
            .and_then(Value::as_u64)
            .ok_or("json update: bad sender")? as usize;
        let weight = v
            .get("weight")
            .and_then(Value::as_f64)
            .ok_or("json update: bad weight")? as f32;
        let params = v
            .get("params")
            .and_then(Value::to_f32_vec)
            .ok_or("json update: bad params")?;
        Ok(ModelUpdate {
            sender,
            weight,
            params,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn update() -> ModelUpdate {
        ModelUpdate {
            sender: 3,
            weight: 256.0,
            params: (0..1000).map(|i| (i as f32) * 0.001 - 0.5).collect(),
        }
    }

    #[test]
    fn binary_roundtrip_exact() {
        let u = update();
        let bytes = ModelCodec::Binary.encode(&u);
        let back = ModelCodec::decode(&bytes).unwrap();
        assert_eq!(u, back, "binary must be bit-exact");
    }

    #[test]
    fn json_roundtrip_close() {
        let u = update();
        let bytes = ModelCodec::Json.encode(&u);
        let back = ModelCodec::decode(&bytes).unwrap();
        assert_eq!(back.sender, u.sender);
        assert_eq!(back.weight, u.weight);
        assert_eq!(back.params.len(), u.params.len());
        for (a, b) in u.params.iter().zip(&back.params) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn json_is_larger_than_binary() {
        // The paper's 30 MB-JSON observation, in miniature.
        let u = update();
        let j = ModelCodec::Json.encode(&u).len();
        let b = ModelCodec::Binary.encode(&u).len();
        assert!(j > b * 2, "json {j} bytes vs binary {b} bytes");
    }

    #[test]
    fn autodetect_both() {
        let u = update();
        for codec in [ModelCodec::Json, ModelCodec::Binary] {
            let back = ModelCodec::decode(&codec.encode(&u)).unwrap();
            assert_eq!(back.sender, u.sender);
        }
    }

    #[test]
    fn corrupt_binary_rejected() {
        let u = update();
        let mut bytes = ModelCodec::Binary.encode(&u);
        bytes.truncate(bytes.len() - 3);
        assert!(ModelCodec::decode(&bytes).is_err());
        assert!(ModelCodec::decode(b"FLB1").is_err());
    }

    #[test]
    fn corrupt_json_rejected() {
        assert!(ModelCodec::decode(b"{\"sender\": }").is_err());
        assert!(ModelCodec::decode(b"{\"sender\":1,\"weight\":2}").is_err());
    }

    #[test]
    fn codec_names_roundtrip() {
        for c in [ModelCodec::Json, ModelCodec::Binary] {
            assert_eq!(ModelCodec::from_name(c.name()).unwrap(), c);
        }
        assert!(ModelCodec::from_name("protobuf").is_err());
    }

    #[test]
    fn special_values_binary() {
        let u = ModelUpdate {
            sender: usize::MAX,
            weight: 0.5,
            params: vec![f32::MIN, f32::MAX, 0.0, -0.0, 1e-38],
        };
        let back = ModelCodec::decode(&ModelCodec::Binary.encode(&u)).unwrap();
        assert_eq!(u, back);
    }
}
