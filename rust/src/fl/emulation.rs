//! Heterogeneous-client emulation — the docker substitute (DESIGN.md §4).
//!
//! The paper's testbed throttles clients with docker cpu/memory limits
//! (1×3-core/2 GB, 2×1-core/1 GB, 7×1-core/64 MB+swap). PSO only needs a
//! stable, placement-dependent delay landscape, so we reproduce the same
//! signal by *stretching measured compute time*: a client with
//! `speed_factor = s` sleeps `(s-1)·t` after a computation that took `t`,
//! and aggregation work is additionally stretched by `memory_pressure`
//! (swap thrash while merging 30 MB models). The code path (real PJRT
//! training/aggregation, real pub/sub) is identical to full speed.

use crate::configio::ClientSpec;
use std::time::{Duration, Instant};

/// Work categories with distinct throttle factors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkKind {
    /// Local SGD training steps.
    Train,
    /// Model aggregation (decode + wavg + encode).
    Aggregate,
}

/// Per-client virtual clock.
#[derive(Debug, Clone)]
pub struct EmulatedClock {
    spec: ClientSpec,
    /// Global time-scale multiplier (lets experiments compress the
    /// paper's tens-of-seconds rounds into hundreds of ms).
    pub time_scale: f64,
}

impl EmulatedClock {
    pub fn new(spec: ClientSpec) -> EmulatedClock {
        EmulatedClock {
            spec,
            time_scale: 1.0,
        }
    }

    /// Effective slowdown multiplier for a work kind.
    pub fn factor(&self, kind: WorkKind) -> f64 {
        match kind {
            WorkKind::Train => self.spec.speed_factor,
            WorkKind::Aggregate => self.spec.speed_factor * self.spec.memory_pressure,
        }
    }

    /// Run `f`, then sleep so total elapsed ≈ `factor(kind) · compute`.
    /// Returns (result, emulated_duration).
    pub fn run<T>(&self, kind: WorkKind, f: impl FnOnce() -> T) -> (T, Duration) {
        let t0 = Instant::now();
        let out = f();
        let compute = t0.elapsed();
        let extra = compute.mul_f64((self.factor(kind) - 1.0).max(0.0) * self.time_scale);
        if !extra.is_zero() {
            std::thread::sleep(extra);
        }
        (out, t0.elapsed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(speed: f64, mem: f64) -> ClientSpec {
        ClientSpec {
            name: "t".into(),
            speed_factor: speed,
            memory_pressure: mem,
        }
    }

    #[test]
    fn full_speed_adds_nothing() {
        let clock = EmulatedClock::new(spec(1.0, 1.0));
        let (out, d) = clock.run(WorkKind::Train, || {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(out, 42);
        assert!(d < Duration::from_millis(12), "{d:?}");
    }

    #[test]
    fn slow_client_is_proportionally_slower() {
        let clock = EmulatedClock::new(spec(3.0, 1.0));
        let (_, d) = clock.run(WorkKind::Train, || {
            std::thread::sleep(Duration::from_millis(10));
        });
        assert!(d >= Duration::from_millis(28), "expected ≈3x: {d:?}");
        assert!(d < Duration::from_millis(60), "{d:?}");
    }

    #[test]
    fn memory_pressure_hits_aggregation_only() {
        let clock = EmulatedClock::new(spec(1.0, 4.0));
        assert_eq!(clock.factor(WorkKind::Train), 1.0);
        assert_eq!(clock.factor(WorkKind::Aggregate), 4.0);
    }

    #[test]
    fn factors_compose() {
        let clock = EmulatedClock::new(spec(2.0, 3.0));
        assert_eq!(clock.factor(WorkKind::Aggregate), 6.0);
    }
}
