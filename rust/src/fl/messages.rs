//! JSON control-plane messages (round orchestration). Model payloads go
//! through [`super::codec`], not here.

use crate::hierarchy::{Arrangement, HierarchySpec};
use crate::json::{self, Value};

/// Coordinator → everyone: the arrangement and hyper-parameters of a round.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundStart {
    pub round: usize,
    /// Hierarchy shape.
    pub depth: usize,
    pub width: usize,
    /// Client id per aggregator slot (BFT order) — the PSO position.
    pub aggregators: Vec<usize>,
    /// Trainer ids per leaf slot.
    pub trainers: Vec<Vec<usize>>,
    /// Local SGD steps per trainer.
    pub local_steps: usize,
    /// Learning rate.
    pub lr: f32,
    /// "json" | "binary" — model payload codec for this round.
    pub codec: String,
}

impl RoundStart {
    /// Build from an arrangement.
    pub fn from_arrangement(
        round: usize,
        arr: &Arrangement,
        local_steps: usize,
        lr: f32,
        codec: &str,
    ) -> RoundStart {
        RoundStart {
            round,
            depth: arr.spec.depth,
            width: arr.spec.width,
            aggregators: arr.aggregators.clone(),
            trainers: arr.trainers.clone(),
            local_steps,
            lr,
            codec: codec.to_string(),
        }
    }

    /// Reconstruct the arrangement (agents recompute roles from it).
    pub fn arrangement(&self) -> Arrangement {
        Arrangement {
            spec: HierarchySpec::new(self.depth, self.width),
            aggregators: self.aggregators.clone(),
            trainers: self.trainers.clone(),
        }
    }

    /// Drop inactive trainers from every leaf (live session membership
    /// under a `--dynamics` replay). Aggregator slots are untouched —
    /// slots must serve; the optimizer re-places between rounds. A leaf
    /// whose trainers all went inactive keeps its first original
    /// trainer, because every aggregator must receive ≥ 1 child update
    /// or the round wedges on an empty buffer. Order-preserving retain
    /// keeps trainer lists sorted, which `Arrangement::role_of` relies
    /// on for its binary search.
    pub fn filter_trainers(&mut self, active: &[bool]) {
        for leaf in &mut self.trainers {
            let original = leaf.clone();
            leaf.retain(|&c| active.get(c).copied().unwrap_or(true));
            if leaf.is_empty() && !original.is_empty() {
                leaf.push(original[0]);
            }
        }
    }

    pub fn to_json(&self) -> String {
        let trainers = Value::Array(
            self.trainers
                .iter()
                .map(|t| Value::Array(t.iter().map(|&c| Value::from(c)).collect()))
                .collect(),
        );
        json::to_string(&Value::object(vec![
            ("round", Value::from(self.round)),
            ("depth", Value::from(self.depth)),
            ("width", Value::from(self.width)),
            (
                "aggregators",
                Value::Array(self.aggregators.iter().map(|&c| Value::from(c)).collect()),
            ),
            ("trainers", trainers),
            ("local_steps", Value::from(self.local_steps)),
            ("lr", Value::from(self.lr as f64)),
            ("codec", Value::from(self.codec.as_str())),
        ]))
    }

    pub fn from_json(text: &str) -> Result<RoundStart, String> {
        let v = json::parse(text).map_err(|e| e.to_string())?;
        let usize_of = |key: &str| -> Result<usize, String> {
            v.get(key)
                .and_then(Value::as_usize)
                .ok_or_else(|| format!("round msg: bad {key}"))
        };
        let aggregators = v
            .get("aggregators")
            .and_then(Value::as_array)
            .ok_or("round msg: bad aggregators")?
            .iter()
            .map(|x| x.as_usize().ok_or("bad aggregator id"))
            .collect::<Result<Vec<_>, _>>()?;
        let trainers = v
            .get("trainers")
            .and_then(Value::as_array)
            .ok_or("round msg: bad trainers")?
            .iter()
            .map(|t| {
                t.as_array()
                    .ok_or("bad trainer group")?
                    .iter()
                    .map(|x| x.as_usize().ok_or("bad trainer id"))
                    .collect::<Result<Vec<_>, _>>()
            })
            .collect::<Result<Vec<_>, _>>()?;
        Ok(RoundStart {
            round: usize_of("round")?,
            depth: usize_of("depth")?,
            width: usize_of("width")?,
            aggregators,
            trainers,
            local_steps: usize_of("local_steps")?,
            lr: v
                .get("lr")
                .and_then(Value::as_f64)
                .ok_or("round msg: bad lr")? as f32,
            codec: v
                .get("codec")
                .and_then(Value::as_str)
                .ok_or("round msg: bad codec")?
                .to_string(),
        })
    }
}

/// Aggregator → coordinator: "slot N subscribed, ready for updates".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReadyMsg {
    pub round: usize,
    pub slot: usize,
    pub client: usize,
}

impl ReadyMsg {
    pub fn to_json(&self) -> String {
        json::to_string(&Value::object(vec![
            ("round", Value::from(self.round)),
            ("slot", Value::from(self.slot)),
            ("client", Value::from(self.client)),
        ]))
    }

    pub fn from_json(text: &str) -> Result<ReadyMsg, String> {
        let v = json::parse(text).map_err(|e| e.to_string())?;
        let get = |k: &str| v.get(k).and_then(Value::as_usize).ok_or(format!("ready msg: bad {k}"));
        Ok(ReadyMsg {
            round: get("round")?,
            slot: get("slot")?,
            client: get("client")?,
        })
    }
}

/// Metadata accompanying a round result (root aggregator → coordinator).
#[derive(Debug, Clone, PartialEq)]
pub struct ResultMeta {
    pub round: usize,
    /// Total weight aggregated into the result (Σ sample counts).
    pub weight: f32,
    /// How many updates were aggregated at the root.
    pub contributors: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hierarchy::HierarchySpec;

    #[test]
    fn round_start_roundtrip() {
        let spec = HierarchySpec::new(2, 2);
        let arr = Arrangement::from_position(spec, &[4, 1, 2], 8);
        let rs = RoundStart::from_arrangement(7, &arr, 2, 0.05, "binary");
        let back = RoundStart::from_json(&rs.to_json()).unwrap();
        assert_eq!(rs, back);
        assert_eq!(back.arrangement(), arr);
    }

    #[test]
    fn filter_trainers_respects_liveness_and_order() {
        let spec = HierarchySpec::new(2, 2);
        // 3 slots over 8 clients: aggregators {4,1,2}, trainers split
        // over 2 leaves in sorted order.
        let arr = Arrangement::from_position(spec, &[4, 1, 2], 8);
        let mut rs = RoundStart::from_arrangement(0, &arr, 1, 0.05, "binary");
        let mut active = vec![true; 8];
        active[0] = false;
        active[3] = false;
        rs.filter_trainers(&active);
        for leaf in &rs.trainers {
            assert!(!leaf.is_empty(), "every leaf keeps at least one trainer");
            assert!(!leaf.contains(&0) || leaf.len() == 1);
            assert!(leaf.windows(2).all(|w| w[0] < w[1]), "lists stay sorted");
        }
        // Aggregators are never filtered.
        assert_eq!(rs.aggregators, vec![4, 1, 2]);
        // All-inactive: every leaf falls back to its first trainer.
        let mut rs2 = RoundStart::from_arrangement(0, &arr, 1, 0.05, "binary");
        let originals: Vec<usize> = rs2.trainers.iter().map(|t| t[0]).collect();
        rs2.filter_trainers(&[false; 8]);
        let kept: Vec<usize> = rs2.trainers.iter().map(|t| t[0]).collect();
        assert_eq!(kept, originals);
        assert!(rs2.trainers.iter().all(|t| t.len() == 1));
    }

    #[test]
    fn ready_roundtrip() {
        let r = ReadyMsg {
            round: 3,
            slot: 1,
            client: 9,
        };
        assert_eq!(ReadyMsg::from_json(&r.to_json()).unwrap(), r);
    }

    #[test]
    fn malformed_rejected() {
        assert!(RoundStart::from_json("{}").is_err());
        assert!(RoundStart::from_json("not json").is_err());
        assert!(ReadyMsg::from_json("{\"round\":1}").is_err());
    }
}
