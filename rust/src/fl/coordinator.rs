//! The session coordinator: executes FL rounds and measures each round's
//! wall-clock Total Processing Delay (the paper's black-box fitness
//! signal). Placement *search* lives outside: the coordinator exposes
//! [`Coordinator::execute_round`] (run one round with a given placement)
//! and [`LiveSession`] (the [`Environment`] adapter over measured
//! rounds), and [`Coordinator::run_session`] drives any [`Optimizer`]
//! through the generic [`drive`] loop — the XAIN-style controller /
//! aggregator split that lets every strategy run against live rounds,
//! emulated delays, or the analytic TPD model unchanged.

use super::codec::{ModelCodec, ModelUpdate};
use super::messages::{ReadyMsg, RoundStart};
use super::roles;
use crate::broker::BrokerClient;
use crate::hierarchy::{Arrangement, HierarchySpec};
use crate::log_info;
use crate::metrics::{RoundRecord, RoundRecorder, Stopwatch};
use crate::placement::{
    drive, validate_placement, Environment, Optimizer, Placement, PlacementError,
};
use crate::runtime::ModelRuntime;
use anyhow::{anyhow, Result};
use std::sync::Arc;
use std::time::Duration;

/// Coordinator parameters.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub session: String,
    /// Hierarchy shape over the client population.
    pub depth: usize,
    pub width: usize,
    pub client_count: usize,
    /// Local SGD steps per trainer per round.
    pub local_steps: usize,
    pub lr: f32,
    /// Model payload codec for the session.
    pub codec: ModelCodec,
    /// Max wall-clock to wait for the ready barrier / round result.
    pub round_timeout: Duration,
    /// Evaluate global loss every N rounds (0 = never). Evaluation runs
    /// *outside* the measured round delay.
    pub eval_every: usize,
    /// Seed for the initial global model.
    pub model_seed: [u32; 2],
    /// Data-generation seed — MUST match the agents' shards so the
    /// held-out eval set comes from the same task (same class centers).
    pub data_seed: u64,
}

impl CoordinatorConfig {
    /// Aggregator slots (Eq. 5).
    pub fn dimensions(&self) -> usize {
        HierarchySpec::new(self.depth, self.width).dimensions()
    }
}

/// The coordinator node: round execution + measurement (no placement
/// policy of its own).
pub struct Coordinator {
    cfg: CoordinatorConfig,
    spec: HierarchySpec,
    client: BrokerClient,
    runtime: Arc<ModelRuntime>,
    /// Current global model (flat params).
    global: Vec<f32>,
    recorder: RoundRecorder,
    /// Held-out eval batch.
    eval_x: Vec<f32>,
    eval_y: Vec<i32>,
    /// Strategy label stamped on round records (set by `run_session`).
    strategy_label: String,
    /// Clients whose heartbeat arrived since the last
    /// [`Coordinator::take_heartbeats`] (the session-machine liveness
    /// feed; topic `fl/{session}/hb/{client}`).
    heartbeat_seen: Vec<bool>,
    /// Cached `fl/{session}/hb/` prefix for heartbeat-topic parsing.
    hb_prefix: String,
}

impl Coordinator {
    pub fn new(
        cfg: CoordinatorConfig,
        client: BrokerClient,
        runtime: Arc<ModelRuntime>,
    ) -> Result<Coordinator> {
        let spec = HierarchySpec::new(cfg.depth, cfg.width);
        if cfg.client_count < spec.dimensions() {
            return Err(anyhow!(
                "need ≥ {} clients for a {}×{} hierarchy, have {}",
                spec.dimensions(),
                cfg.depth,
                cfg.width,
                cfg.client_count
            ));
        }
        let mut client = client;
        // Heartbeats flow for the whole session lifetime — every recv
        // site notes them, whatever it was actually waiting for.
        client
            .subscribe(&roles::hb_filter(&cfg.session))
            .map_err(|e| anyhow!(e))?;
        let global = runtime.init_params(cfg.model_seed)?;
        // Held-out eval data: a reserved shard id far above any client.
        let (eval_x, eval_y) = {
            use crate::data::{SynthConfig, SynthDataset};
            let data = SynthDataset::for_client(
                SynthConfig {
                    input_dim: runtime.meta.input_dim,
                    num_classes: runtime.meta.num_classes,
                    samples_per_client: runtime.meta.eval_batch,
                    seed: cfg.data_seed,
                    ..SynthConfig::default()
                },
                1_000_000,
            );
            (data.x.clone(), data.y.clone())
        };
        let heartbeat_seen = vec![false; cfg.client_count];
        let hb_prefix = format!("fl/{}/hb/", cfg.session);
        Ok(Coordinator {
            cfg,
            spec,
            client,
            runtime,
            global,
            recorder: RoundRecorder::new(),
            eval_x,
            eval_y,
            strategy_label: "manual".to_string(),
            heartbeat_seen,
            hb_prefix,
        })
    }

    /// Record a heartbeat if `topic` is this session's hb topic for a
    /// known client. Called from every recv site, so beats are noted no
    /// matter which message the coordinator was actually waiting for.
    fn note_heartbeat(&mut self, topic: &str) {
        if let Some(id) =
            topic.strip_prefix(&self.hb_prefix).and_then(|t| t.parse::<usize>().ok())
        {
            if let Some(flag) = self.heartbeat_seen.get_mut(id) {
                *flag = true;
            }
        }
    }

    /// Drain any queued heartbeats and return (then reset) the
    /// per-client seen-flags — the liveness mask the service tier feeds
    /// into the session machine's heartbeat table after each round.
    pub fn take_heartbeats(&mut self) -> Vec<bool> {
        while let Some(msg) = self.client.try_recv() {
            self.note_heartbeat(&msg.topic);
        }
        let fresh = vec![false; self.cfg.client_count];
        std::mem::replace(&mut self.heartbeat_seen, fresh)
    }

    /// The recorded per-round measurements.
    pub fn recorder(&self) -> &RoundRecorder {
        &self.recorder
    }

    /// Current global model.
    pub fn global_model(&self) -> &[f32] {
        &self.global
    }

    /// Strategy label stamped on round records.
    pub fn strategy_label(&self) -> &str {
        &self.strategy_label
    }

    /// Override the label stamped on subsequent round records (set
    /// automatically by [`Coordinator::run_session`]).
    pub fn set_strategy_label(&mut self, label: &str) {
        self.strategy_label = label.to_string();
    }

    /// Block until `n` distinct clients have announced themselves on the
    /// retained join topics (multi-process deployments start workers
    /// asynchronously; rounds must not begin before everyone listens).
    pub fn wait_for_clients(&mut self, n: usize, timeout: Duration) -> Result<()> {
        let filter = roles::join_filter(&self.cfg.session);
        self.client.subscribe(&filter).map_err(|e| anyhow!(e))?;
        let mut seen = std::collections::BTreeSet::new();
        let deadline = std::time::Instant::now() + timeout;
        while seen.len() < n {
            let remain = deadline
                .checked_duration_since(std::time::Instant::now())
                .ok_or_else(|| anyhow!("join barrier: {}/{} clients after {timeout:?}", seen.len(), n))?;
            let msg = self
                .client
                .recv_timeout(remain.min(Duration::from_millis(500)))
                .map_err(|_| ())
                .ok();
            if let Some(msg) = msg {
                self.note_heartbeat(&msg.topic);
                if crate::broker::topic_matches(&filter, &msg.topic) {
                    if let Ok(id) = msg.text().unwrap_or("").parse::<usize>() {
                        seen.insert(id);
                    }
                }
            }
        }
        self.client.unsubscribe(&filter);
        log_info!("coord", "join barrier complete: {} clients", seen.len());
        Ok(())
    }

    /// Execute one FL round with a given placement and measure its
    /// wall-clock delay; returns the round's record. This is the
    /// policy-free primitive both [`LiveSession`] and external drivers
    /// build on.
    pub fn execute_round(&mut self, round: usize, placement: &Placement) -> Result<RoundRecord> {
        self.execute_round_with_membership(round, placement, None)
    }

    /// [`Coordinator::execute_round`] with a client-liveness mask: when
    /// `active` is given, inactive clients are dropped from the round's
    /// trainer lists (see [`RoundStart::filter_trainers`]) — the service
    /// tier feeds a `des::scenarios::Dynamics` realization through this
    /// to replay churn/dropout against live rounds. Aggregator slots
    /// always serve; the placement optimizer reacts between rounds.
    pub fn execute_round_with_membership(
        &mut self,
        round: usize,
        placement: &Placement,
        active: Option<&[bool]>,
    ) -> Result<RoundRecord> {
        validate_placement(placement, self.spec.dimensions(), self.cfg.client_count)
            .map_err(|e| anyhow!("round {round}: {e}"))?;
        let arr = Arrangement::from_position(self.spec, placement, self.cfg.client_count);

        // Subscribe result/ready before announcing the round.
        let ready_topic = roles::ready_topic(&self.cfg.session, round);
        let result_topic = roles::result_topic(&self.cfg.session, round);
        self.client.subscribe(&ready_topic).map_err(|e| anyhow!(e))?;
        self.client.subscribe(&result_topic).map_err(|e| anyhow!(e))?;

        let sw = Stopwatch::start();

        // 1. Announce the arrangement (trainer lists filtered to the
        // live membership when a mask is supplied).
        let mut rs = RoundStart::from_arrangement(
            round,
            &arr,
            self.cfg.local_steps,
            self.cfg.lr,
            self.cfg.codec.name(),
        );
        if let Some(mask) = active {
            rs.filter_trainers(mask);
        }
        self.client
            .publish(roles::round_topic(&self.cfg.session), rs.to_json().into_bytes())
            .map_err(|e| anyhow!(e))?;

        // 2. Ready barrier: every aggregator slot listening.
        let dims = self.spec.dimensions();
        let mut ready = vec![false; dims];
        let mut ready_count = 0usize;
        while ready_count < dims {
            let msg = self
                .client
                .recv_timeout(self.cfg.round_timeout)
                .map_err(|e| anyhow!("round {round}: ready barrier: {e}"))?;
            self.note_heartbeat(&msg.topic);
            if msg.topic == ready_topic {
                let r = ReadyMsg::from_json(msg.text().map_err(|e| anyhow!(e))?)
                    .map_err(|e| anyhow!(e))?;
                if r.round == round && !std::mem::replace(&mut ready[r.slot], true) {
                    ready_count += 1;
                }
            }
        }

        // 3. Release the global model. Retained + round-scoped: a trainer
        // whose subscription lands after this publish (thread preemption
        // under load) still receives it via retained replay — without
        // this, QoS-0 delivery can starve a whole round.
        let payload = Arc::new(self.cfg.codec.encode(&ModelUpdate {
            sender: usize::MAX,
            weight: 0.0,
            params: std::mem::take(&mut self.global),
        }));
        let global_topic = roles::global_topic(&self.cfg.session, round);
        self.client
            .publish_shared_retained(&global_topic, payload)
            .map_err(|e| anyhow!(e))?;

        // 4. Wait for the root aggregate.
        let new_global = loop {
            let msg = self
                .client
                .recv_timeout(self.cfg.round_timeout)
                .map_err(|e| anyhow!("round {round}: waiting for result: {e}"))?;
            self.note_heartbeat(&msg.topic);
            if msg.topic == result_topic {
                break ModelCodec::decode(&msg.payload).map_err(|e| anyhow!(e))?;
            }
        };
        let delay = sw.elapsed();
        self.global = new_global.params;

        self.client.unsubscribe(&ready_topic);
        self.client.unsubscribe(&result_topic);
        // Drop the retained global (7.5 MB/round would otherwise pile up
        // in the broker's retained store).
        let _ = self.client.clear_retained(&global_topic);

        // 5. Optional evaluation (outside the measured delay).
        let loss = if self.cfg.eval_every > 0 && round % self.cfg.eval_every == 0 {
            let (loss, _acc) = self
                .runtime
                .evaluate(&self.global, &self.eval_x, &self.eval_y)?;
            loss as f64
        } else {
            f64::NAN
        };

        let rec = RoundRecord {
            round,
            strategy: self.strategy_label.clone(),
            delay,
            loss,
            placement: placement.to_vec(),
        };
        log_info!(
            "coord",
            "round {round} [{}] delay={:.3}s loss={:.4} placement={:?}",
            rec.strategy,
            delay.as_secs_f64(),
            loss,
            rec.placement
        );
        self.recorder.push(rec.clone());
        Ok(rec)
    }

    /// Drive `optimizer` for `rounds` live FL rounds through the
    /// [`LiveSession`] environment: propose → execute round → observe
    /// measured delay (the paper's black-box loop).
    pub fn run_session(&mut self, optimizer: &mut dyn Optimizer, rounds: usize) -> Result<()> {
        self.strategy_label = optimizer.name().to_string();
        let mut env = LiveSession::new(self);
        drive(optimizer, &mut env, rounds)?;
        Ok(())
    }

    /// Evaluate the current global model on the held-out batch.
    pub fn evaluate(&self) -> Result<(f32, f32)> {
        Ok(self
            .runtime
            .evaluate(&self.global, &self.eval_x, &self.eval_y)?)
    }

    /// Broadcast session shutdown to all agents.
    pub fn shutdown(&self) {
        let _ = self
            .client
            .publish(roles::shutdown_topic(&self.cfg.session), Vec::new());
    }

    /// Persist the current global model (resume/serve workflows).
    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        self.save_checkpoint_with(path, None)
    }

    /// Persist the current global model together with a placement
    /// optimizer's transferable state, so the resumed session restores
    /// its search progress too (use [`crate::placement::Optimizer::state`]
    /// to take the snapshot).
    pub fn save_checkpoint_with(
        &self,
        path: &std::path::Path,
        optimizer: Option<crate::placement::OptimizerState>,
    ) -> Result<()> {
        let last = self.recorder.records().last();
        crate::runtime::checkpoint::save(
            path,
            &self.global,
            &crate::runtime::CheckpointMeta {
                param_count: self.global.len(),
                round: last.map_or(0, |r| r.round),
                session: self.cfg.session.clone(),
                loss: last.map_or(f64::NAN, |r| r.loss),
                optimizer,
            },
        )
    }

    /// Replace the global model from a checkpoint (e.g. to resume a
    /// session). The parameter count must match the loaded artifacts.
    /// Returns the checkpoint metadata so the caller can also restore
    /// the placement optimizer (`meta.optimizer`).
    pub fn restore_checkpoint(
        &mut self,
        path: &std::path::Path,
    ) -> Result<crate::runtime::CheckpointMeta> {
        let (params, meta) = crate::runtime::checkpoint::load(path)?;
        self.install_checkpoint(params, &meta)?;
        Ok(meta)
    }

    /// Parameter count the loaded artifacts expect (checkpoint
    /// compatibility pre-checks).
    pub fn expected_param_count(&self) -> usize {
        self.runtime.meta.param_count
    }

    /// Install an already-loaded checkpoint payload — for callers that
    /// inspect the metadata before committing (one file read, no state
    /// touched on error). The parameter count must match the artifacts.
    pub fn install_checkpoint(
        &mut self,
        params: Vec<f32>,
        meta: &crate::runtime::CheckpointMeta,
    ) -> Result<()> {
        if params.len() != self.runtime.meta.param_count {
            return Err(anyhow!(
                "checkpoint has {} params, artifacts expect {}",
                params.len(),
                self.runtime.meta.param_count
            ));
        }
        log_info!(
            "coord",
            "restored checkpoint (round {}, session {:?}, loss {:.4})",
            meta.round,
            meta.session,
            meta.loss
        );
        self.global = params;
        Ok(())
    }
}

/// The live-measurement [`Environment`]: every evaluation runs one real
/// FL round through the coordinator and returns its measured wall-clock
/// delay. Round numbering continues from the coordinator's recorder, so
/// repeated sessions extend the same series.
pub struct LiveSession<'a> {
    coord: &'a mut Coordinator,
    next_round: usize,
}

impl<'a> LiveSession<'a> {
    pub fn new(coord: &'a mut Coordinator) -> LiveSession<'a> {
        let next_round = coord.recorder.len();
        LiveSession { coord, next_round }
    }
}

impl Environment for LiveSession<'_> {
    fn name(&self) -> &'static str {
        "live-session"
    }

    fn eval(&mut self, placement: &Placement) -> Result<f64, PlacementError> {
        let round = self.next_round;
        let rec = self
            .coord
            .execute_round(round, placement)
            .map_err(|e| PlacementError::Environment(format!("{e:#}")))?;
        self.next_round += 1;
        Ok(rec.delay.as_secs_f64())
    }
}
