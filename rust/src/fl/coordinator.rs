//! The session coordinator: drives FL rounds, measures each round's
//! wall-clock Total Processing Delay (the paper's black-box fitness
//! signal), feeds it to the placement strategy, and records the series
//! behind Fig. 4.

use super::codec::{ModelCodec, ModelUpdate};
use super::messages::{ReadyMsg, RoundStart};
use super::roles;
use crate::broker::BrokerClient;
use crate::hierarchy::{Arrangement, HierarchySpec};
use crate::log_info;
use crate::metrics::{RoundRecord, RoundRecorder, Stopwatch};
use crate::placement::{assert_valid_placement, PlacementStrategy};
use crate::runtime::ModelRuntime;
use anyhow::{anyhow, Result};
use std::sync::Arc;
use std::time::Duration;

/// Coordinator parameters.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub session: String,
    /// Hierarchy shape over the client population.
    pub depth: usize,
    pub width: usize,
    pub client_count: usize,
    /// Local SGD steps per trainer per round.
    pub local_steps: usize,
    pub lr: f32,
    /// Model payload codec for the session.
    pub codec: ModelCodec,
    /// Max wall-clock to wait for the ready barrier / round result.
    pub round_timeout: Duration,
    /// Evaluate global loss every N rounds (0 = never). Evaluation runs
    /// *outside* the measured round delay.
    pub eval_every: usize,
    /// Seed for the initial global model.
    pub model_seed: [u32; 2],
    /// Data-generation seed — MUST match the agents' shards so the
    /// held-out eval set comes from the same task (same class centers).
    pub data_seed: u64,
}

impl CoordinatorConfig {
    /// Aggregator slots (Eq. 5).
    pub fn dimensions(&self) -> usize {
        HierarchySpec::new(self.depth, self.width).dimensions()
    }
}

/// The coordinator node.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    spec: HierarchySpec,
    client: BrokerClient,
    strategy: Box<dyn PlacementStrategy>,
    runtime: Arc<ModelRuntime>,
    /// Current global model (flat params).
    global: Vec<f32>,
    recorder: RoundRecorder,
    /// Held-out eval batch.
    eval_x: Vec<f32>,
    eval_y: Vec<i32>,
}

impl Coordinator {
    pub fn new(
        cfg: CoordinatorConfig,
        client: BrokerClient,
        strategy: Box<dyn PlacementStrategy>,
        runtime: Arc<ModelRuntime>,
    ) -> Result<Coordinator> {
        let spec = HierarchySpec::new(cfg.depth, cfg.width);
        if cfg.client_count < spec.dimensions() {
            return Err(anyhow!(
                "need ≥ {} clients for a {}×{} hierarchy, have {}",
                spec.dimensions(),
                cfg.depth,
                cfg.width,
                cfg.client_count
            ));
        }
        let global = runtime.init_params(cfg.model_seed)?;
        // Held-out eval data: a reserved shard id far above any client.
        let (eval_x, eval_y) = {
            use crate::data::{SynthConfig, SynthDataset};
            let data = SynthDataset::for_client(
                SynthConfig {
                    input_dim: runtime.meta.input_dim,
                    num_classes: runtime.meta.num_classes,
                    samples_per_client: runtime.meta.eval_batch,
                    seed: cfg.data_seed,
                    ..SynthConfig::default()
                },
                1_000_000,
            );
            (data.x.clone(), data.y.clone())
        };
        Ok(Coordinator {
            cfg,
            spec,
            client,
            strategy,
            runtime,
            global,
            recorder: RoundRecorder::new(),
            eval_x,
            eval_y,
        })
    }

    /// The recorded per-round measurements.
    pub fn recorder(&self) -> &RoundRecorder {
        &self.recorder
    }

    /// Current global model.
    pub fn global_model(&self) -> &[f32] {
        &self.global
    }

    /// Strategy label (for CSV output).
    pub fn strategy_name(&self) -> &'static str {
        self.strategy.name()
    }

    /// Block until `n` distinct clients have announced themselves on the
    /// retained join topics (multi-process deployments start workers
    /// asynchronously; rounds must not begin before everyone listens).
    pub fn wait_for_clients(&mut self, n: usize, timeout: Duration) -> Result<()> {
        let filter = roles::join_filter(&self.cfg.session);
        self.client.subscribe(&filter).map_err(|e| anyhow!(e))?;
        let mut seen = std::collections::BTreeSet::new();
        let deadline = std::time::Instant::now() + timeout;
        while seen.len() < n {
            let remain = deadline
                .checked_duration_since(std::time::Instant::now())
                .ok_or_else(|| anyhow!("join barrier: {}/{} clients after {timeout:?}", seen.len(), n))?;
            let msg = self
                .client
                .recv_timeout(remain.min(Duration::from_millis(500)))
                .map_err(|_| ())
                .ok();
            if let Some(msg) = msg {
                if let Ok(id) = msg.text().unwrap_or("").parse::<usize>() {
                    seen.insert(id);
                }
            }
        }
        self.client.unsubscribe(&filter);
        log_info!("coord", "join barrier complete: {} clients", seen.len());
        Ok(())
    }

    /// Run one FL round; returns its record.
    pub fn run_round(&mut self, round: usize) -> Result<RoundRecord> {
        let placement = self.strategy.propose(round);
        assert_valid_placement(&placement, self.spec.dimensions(), self.cfg.client_count);
        let arr = Arrangement::from_position(self.spec, &placement, self.cfg.client_count);

        // Subscribe result/ready before announcing the round.
        let ready_topic = roles::ready_topic(&self.cfg.session, round);
        let result_topic = roles::result_topic(&self.cfg.session, round);
        self.client.subscribe(&ready_topic).map_err(|e| anyhow!(e))?;
        self.client.subscribe(&result_topic).map_err(|e| anyhow!(e))?;

        let sw = Stopwatch::start();

        // 1. Announce the arrangement.
        let rs = RoundStart::from_arrangement(
            round,
            &arr,
            self.cfg.local_steps,
            self.cfg.lr,
            self.cfg.codec.name(),
        );
        self.client
            .publish(roles::round_topic(&self.cfg.session), rs.to_json().into_bytes())
            .map_err(|e| anyhow!(e))?;

        // 2. Ready barrier: every aggregator slot listening.
        let dims = self.spec.dimensions();
        let mut ready = vec![false; dims];
        let mut ready_count = 0usize;
        while ready_count < dims {
            let msg = self
                .client
                .recv_timeout(self.cfg.round_timeout)
                .map_err(|e| anyhow!("round {round}: ready barrier: {e}"))?;
            if msg.topic == ready_topic {
                let r = ReadyMsg::from_json(msg.text().map_err(|e| anyhow!(e))?)
                    .map_err(|e| anyhow!(e))?;
                if r.round == round && !std::mem::replace(&mut ready[r.slot], true) {
                    ready_count += 1;
                }
            }
        }

        // 3. Release the global model. Retained + round-scoped: a trainer
        // whose subscription lands after this publish (thread preemption
        // under load) still receives it via retained replay — without
        // this, QoS-0 delivery can starve a whole round.
        let payload = Arc::new(self.cfg.codec.encode(&ModelUpdate {
            sender: usize::MAX,
            weight: 0.0,
            params: std::mem::take(&mut self.global),
        }));
        let global_topic = roles::global_topic(&self.cfg.session, round);
        self.client
            .publish_shared_retained(&global_topic, payload)
            .map_err(|e| anyhow!(e))?;

        // 4. Wait for the root aggregate.
        let new_global = loop {
            let msg = self
                .client
                .recv_timeout(self.cfg.round_timeout)
                .map_err(|e| anyhow!("round {round}: waiting for result: {e}"))?;
            if msg.topic == result_topic {
                break ModelCodec::decode(&msg.payload).map_err(|e| anyhow!(e))?;
            }
        };
        let delay = sw.elapsed();
        self.global = new_global.params;

        self.client.unsubscribe(&ready_topic);
        self.client.unsubscribe(&result_topic);
        // Drop the retained global (7.5 MB/round would otherwise pile up
        // in the broker's retained store).
        let _ = self.client.clear_retained(&global_topic);

        // 5. Black-box feedback to the optimizer.
        self.strategy.feedback(&placement, delay.as_secs_f64());

        // 6. Optional evaluation (outside the measured delay).
        let loss = if self.cfg.eval_every > 0 && round % self.cfg.eval_every == 0 {
            let (loss, _acc) = self
                .runtime
                .evaluate(&self.global, &self.eval_x, &self.eval_y)?;
            loss as f64
        } else {
            f64::NAN
        };

        let rec = RoundRecord {
            round,
            strategy: self.strategy.name().to_string(),
            delay,
            loss,
            placement,
        };
        log_info!(
            "coord",
            "round {round} [{}] delay={:.3}s loss={:.4} placement={:?}",
            rec.strategy,
            delay.as_secs_f64(),
            loss,
            rec.placement
        );
        self.recorder.push(rec.clone());
        Ok(rec)
    }

    /// Run `rounds` rounds.
    pub fn run(&mut self, rounds: usize) -> Result<()> {
        for r in 0..rounds {
            self.run_round(r)?;
        }
        Ok(())
    }

    /// Evaluate the current global model on the held-out batch.
    pub fn evaluate(&self) -> Result<(f32, f32)> {
        Ok(self
            .runtime
            .evaluate(&self.global, &self.eval_x, &self.eval_y)?)
    }

    /// Broadcast session shutdown to all agents.
    pub fn shutdown(&self) {
        let _ = self
            .client
            .publish(roles::shutdown_topic(&self.cfg.session), Vec::new());
    }

    /// Persist the current global model (resume/serve workflows).
    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        let last = self.recorder.records().last();
        crate::runtime::checkpoint::save(
            path,
            &self.global,
            &crate::runtime::CheckpointMeta {
                param_count: self.global.len(),
                round: last.map_or(0, |r| r.round),
                session: self.cfg.session.clone(),
                loss: last.map_or(f64::NAN, |r| r.loss),
            },
        )
    }

    /// Replace the global model from a checkpoint (e.g. to resume a
    /// session). The parameter count must match the loaded artifacts.
    pub fn restore_checkpoint(&mut self, path: &std::path::Path) -> Result<()> {
        let (params, meta) = crate::runtime::checkpoint::load(path)?;
        if params.len() != self.runtime.meta.param_count {
            return Err(anyhow!(
                "checkpoint has {} params, artifacts expect {}",
                params.len(),
                self.runtime.meta.param_count
            ));
        }
        log_info!(
            "coord",
            "restored checkpoint {:?} (round {}, loss {:.4})",
            path,
            meta.round,
            meta.loss
        );
        self.global = params;
        Ok(())
    }
}
