//! The client agent: one per (emulated) device. Receives the round
//! arrangement, plays whichever role the placement assigned it —
//! trainer or aggregator ("agtrainer" candidacy in SDFLMQ terms) — and
//! never reports anything but its model updates. All computation goes
//! through the shared PJRT [`ModelRuntime`]; all communication goes
//! through the broker.

use super::codec::{ModelCodec, ModelUpdate};
use super::emulation::{EmulatedClock, WorkKind};
use super::messages::RoundStart;
use super::roles;
use crate::broker::PubSub;
use crate::data::SynthDataset;
use crate::hierarchy::Role;
use crate::log_warn;
use crate::runtime::ModelRuntime;
use std::sync::Arc;
use std::time::Duration;

/// One FL client (thread body: [`ClientAgent::run`]), generic over the
/// messaging transport: in-process for single-process deployments,
/// TCP for real multi-process runs (`repro worker`).
pub struct ClientAgent<C: PubSub = crate::broker::BrokerClient> {
    pub id: usize,
    session: String,
    clock: EmulatedClock,
    runtime: Arc<ModelRuntime>,
    data: SynthDataset,
    client: C,
    /// How long an aggregator waits for its children before proceeding
    /// with whatever arrived (failure resilience).
    child_timeout: Duration,
    /// Rotating batch cursor (persists across rounds).
    cursor: usize,
}

impl<C: PubSub> ClientAgent<C> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: usize,
        session: &str,
        clock: EmulatedClock,
        runtime: Arc<ModelRuntime>,
        data: SynthDataset,
        client: C,
        child_timeout: Duration,
    ) -> ClientAgent<C> {
        assert_eq!(data.cfg.input_dim, runtime.meta.input_dim);
        ClientAgent {
            id,
            session: session.to_string(),
            clock,
            runtime,
            data,
            client,
            child_timeout,
            cursor: 0,
        }
        .into_subscribed()
    }

    fn into_subscribed(mut self) -> Self {
        self.client
            .subscribe(&roles::round_topic(&self.session))
            .expect("subscribe round");
        self.client
            .subscribe(&roles::shutdown_topic(&self.session))
            .expect("subscribe shutdown");
        // Join barrier: retained, so a coordinator that attaches later
        // (multi-process deployments) still sees this worker.
        self.client
            .publish_retained(
                &roles::join_topic(&self.session, self.id),
                self.id.to_string().into_bytes(),
            )
            .expect("publish join");
        self
    }

    /// Agent main loop; returns when the session shuts down.
    pub fn run(mut self) {
        let round_topic = roles::round_topic(&self.session);
        let shutdown_topic = roles::shutdown_topic(&self.session);
        loop {
            let msg = match self.client.recv_timeout(Duration::from_secs(300)) {
                Ok(m) => m,
                Err(_) => return, // orphaned session
            };
            if msg.topic == shutdown_topic {
                return;
            }
            if msg.topic != round_topic {
                continue; // stale slot/global message from a finished round
            }
            let rs = match msg.text().ok().and_then(|t| RoundStart::from_json(t).ok()) {
                Some(rs) => rs,
                None => {
                    log_warn!("agent", "client {}: malformed round message", self.id);
                    continue;
                }
            };
            if let Err(e) = self.handle_round(&rs) {
                log_warn!("agent", "client {} round {}: {}", self.id, rs.round, e);
            }
        }
    }

    fn handle_round(&mut self, rs: &RoundStart) -> Result<(), String> {
        // Liveness heartbeat: one beat per handled round, even for Idle
        // roles — receiving the round announcement proves this client is
        // alive, which is what the coordinator's liveness table tracks.
        let _ = self.client.publish(
            &roles::hb_topic(&self.session, self.id),
            self.id.to_string().into_bytes(),
        );
        let arr = rs.arrangement();
        let codec = ModelCodec::from_name(&rs.codec)?;
        match arr.role_of(self.id) {
            Role::Trainer { parent_slot } => self.run_trainer(rs, parent_slot, codec),
            Role::Aggregator { slot } => self.run_aggregator(rs, &arr, slot, codec),
            Role::Idle => Ok(()),
        }
    }

    /// Trainer role: receive the global model, run local SGD, send the
    /// update to the parent aggregator's slot topic.
    fn run_trainer(
        &mut self,
        rs: &RoundStart,
        parent_slot: usize,
        codec: ModelCodec,
    ) -> Result<(), String> {
        let global_topic = roles::global_topic(&self.session, rs.round);
        self.client.subscribe(&global_topic)?;
        let global = loop {
            let msg = self
                .client
                .recv_timeout(self.child_timeout)
                .map_err(|e| format!("waiting for global model: {e}"))?;
            if msg.topic == global_topic && !msg.payload.is_empty() {
                break ModelCodec::decode(&msg.payload)?;
            }
            if msg.topic == roles::shutdown_topic(&self.session) {
                return Err("shutdown mid-round".into());
            }
            // Anything else (stale messages) is skipped.
        };
        let _ = self.client.unsubscribe(&global_topic);

        let b = self.runtime.meta.train_batch;
        let clock = self.clock.clone();
        let (update, _elapsed) = clock.run(WorkKind::Train, || {
            let mut params = global.params;
            for _ in 0..rs.local_steps {
                let (x, y) = self.draw_batch(b);
                match self.runtime.train_step(&params, &x, &y, rs.lr) {
                    Ok((np, _loss)) => params = np,
                    Err(e) => return Err(format!("train_step: {e}")),
                }
            }
            Ok(codec.encode(&ModelUpdate {
                sender: self.id,
                weight: self.data.len() as f32,
                params,
            }))
        });
        let payload = update?;
        self.client
            .publish(&roles::slot_topic(&self.session, rs.round, parent_slot), payload)?;
        Ok(())
    }

    /// Aggregator role: subscribe the slot inbox, signal readiness,
    /// collect child updates, aggregate, forward up (or publish the
    /// round result from the root).
    fn run_aggregator(
        &mut self,
        rs: &RoundStart,
        arr: &crate::hierarchy::Arrangement,
        slot: usize,
        codec: ModelCodec,
    ) -> Result<(), String> {
        let slot_topic = roles::slot_topic(&self.session, rs.round, slot);
        self.client.subscribe(&slot_topic)?;
        // Ready barrier: the coordinator releases the global model only
        // after every aggregator slot is listening — no lost updates.
        self.client.publish(
            &roles::ready_topic(&self.session, rs.round),
            super::messages::ReadyMsg {
                round: rs.round,
                slot,
                client: self.id,
            }
            .to_json()
            .into_bytes(),
        )?;

        let expected = arr.buffer_of(slot).len();
        let mut raw_updates: Vec<Vec<u8>> = Vec::with_capacity(expected);
        while raw_updates.len() < expected {
            let msg = match self.client.recv_timeout(self.child_timeout) {
                Ok(m) => m,
                Err(_) => {
                    log_warn!(
                        "agent",
                        "aggregator {} slot {slot}: {}/{} children after timeout — proceeding",
                        self.id,
                        raw_updates.len(),
                        expected
                    );
                    break;
                }
            };
            if msg.topic == slot_topic {
                raw_updates.push(msg.payload.to_vec());
            } else if msg.topic == roles::shutdown_topic(&self.session) {
                let _ = self.client.unsubscribe(&slot_topic);
                return Err("shutdown mid-round".into());
            }
        }
        let _ = self.client.unsubscribe(&slot_topic);
        if raw_updates.is_empty() {
            return Err(format!("aggregator slot {slot}: no child updates"));
        }

        // Decode + aggregate + encode, all inside the aggregation clock
        // (this is the work the paper's memory-constrained containers
        // swap on).
        let (result, _elapsed) = self.clock.run(WorkKind::Aggregate, || {
            let mut updates = Vec::with_capacity(raw_updates.len());
            for raw in &raw_updates {
                updates.push(ModelCodec::decode(raw)?);
            }
            let models: Vec<&[f32]> = updates.iter().map(|u| u.params.as_slice()).collect();
            let weights: Vec<f32> = updates.iter().map(|u| u.weight).collect();
            let aggregated = self
                .runtime
                .aggregate(&models, &weights)
                .map_err(|e| format!("aggregate: {e}"))?;
            Ok::<Vec<u8>, String>(codec.encode(&ModelUpdate {
                sender: self.id,
                weight: weights.iter().sum(),
                params: aggregated,
            }))
        });
        let payload = result?;

        let out_topic = match arr.spec.parent(slot) {
            Some(parent) => roles::slot_topic(&self.session, rs.round, parent),
            None => roles::result_topic(&self.session, rs.round),
        };
        self.client.publish(&out_topic, payload)?;
        Ok(())
    }

    /// Draw a wrapped mini-batch from this client's shard.
    fn draw_batch(&mut self, batch: usize) -> (Vec<f32>, Vec<i32>) {
        let d = self.data.cfg.input_dim;
        let n = self.data.len();
        let mut x = Vec::with_capacity(batch * d);
        let mut y = Vec::with_capacity(batch);
        for _ in 0..batch {
            let (feat, label) = self.data.sample(self.cursor);
            x.extend_from_slice(feat);
            y.push(label);
            self.cursor = (self.cursor + 1) % n;
        }
        (x, y)
    }
}
